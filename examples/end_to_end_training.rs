//! End-to-end driver (the DESIGN.md §3 validation run): the full
//! three-layer system on a real small workload.
//!
//!   L3 Rust coordinator — simulated-FPGA ETL (bit-exact operators) over a
//!     synthetic Criteo dataset, format-aware packing, credit-gated
//!     staging with double buffering;
//!   L2/L1 — the AOT-compiled JAX DLRM (Pallas dot-interaction + fused
//!     MLP kernels) executed via PJRT with a device-resident state buffer.
//!
//! Logs the loss curve, GPU(-stand-in) utilization, and the simulated
//! FPGA-clock comparison vs the CPU baseline. Recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end_training -- --steps 300
//! # Big (~100M-param) model: PIPEREC_PRESET=big make artifacts, then rerun.
//! # Record + export a Chrome trace of a 2-lane fleet run:
//! cargo run --release --example end_to_end_training -- --devices 2 --trace trace.json
//! ```

use piperec::baselines::{PandasModel, CPU_ETL_BW_12CORE};
use piperec::coordinator::{train, TrainConfig};
use piperec::dataio::dataset::DatasetSpec;
use piperec::etl::pipelines::{build, PipelineKind};
use piperec::fpga::Pipeline;
use piperec::planner::{compile, PlannerConfig};
use piperec::runtime::artifacts::ArtifactPaths;
use piperec::runtime::Trainer;
use piperec::util::cli::Args;
use piperec::util::{fmt_bytes, fmt_rate, fmt_secs};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    let steps: usize = args.get("steps", 300);
    let scale: f64 = args.get("scale", 0.05);
    let devices: usize = args.get("devices", 1);
    let trace_path = args.opt_str("trace");

    // Dataset: synthetic Criteo (Dataset-I schema), sharded.
    let mut spec = DatasetSpec::dataset_i(scale);
    spec.shards = args.get("shards", 8usize);
    println!(
        "dataset : {} — {} rows, {} ({} shards)",
        spec.name,
        spec.rows,
        fmt_bytes(spec.total_bytes()),
        spec.shards
    );

    // ETL: Pipeline II (stateful, small vocab) compiled to a vFPGA plan.
    let kind = PipelineKind::II;
    let dag = build(kind, &spec.schema);
    let plan = compile(&dag, &spec.schema, &PlannerConfig::default())?;
    println!(
        "pipeline: {} — {} stages, II={}, line rate {}",
        kind.label(),
        plan.stages.len(),
        plan.dataflow_ii,
        fmt_rate(plan.line_rate())
    );
    let mut pipeline = Pipeline::new(plan);
    pipeline.fit(&spec.shard(0, 42))?;

    // Trainer: AOT-compiled DLRM via PJRT, state resident on device.
    let paths = ArtifactPaths::default_dir();
    let mut trainer = Trainer::load(&paths, 7)?;
    println!(
        "trainer : DLRM {} params (batch {}, vocab {}/feature, dim {})\n",
        trainer.param_count(),
        trainer.meta.batch,
        trainer.meta.vocab,
        trainer.meta.embed_dim
    );

    // Run the live loop.
    let cfg = TrainConfig {
        max_steps: steps,
        loss_every: (steps / 20).max(1),
        staging_buffers: 2,
        seed: 42,
        devices,
        trace: trace_path.is_some(),
        ..Default::default()
    };
    let report = train(&pipeline, &spec, &mut trainer, &cfg)?;

    println!("loss curve:");
    for (s, l) in &report.losses {
        println!("  step {s:>6}  loss {l:.5}");
    }
    if let Some((first, last)) = report.loss_delta() {
        println!("  Δloss {first:.5} → {last:.5}");
    }

    println!("\nrun summary:");
    println!("  steps            : {}", report.steps);
    println!("  wall time        : {}", fmt_secs(report.wall_s));
    println!("  trainer busy     : {}", fmt_secs(report.train_busy_s));
    println!("  GPU-standin util : {:.1}%", report.util * 100.0);
    println!("  util trace       : {}", report.util_trace.sparkline(48));
    println!("  producer stalls  : {} (backpressure credits)", report.producer_stalls);
    println!("  ETL host time    : {}", fmt_secs(report.etl_host_s));
    println!("  ETL FPGA-sim time: {}", fmt_secs(report.etl_sim_s));

    // --trace: export the dual-clock span trace as Chrome trace-event
    // JSON (self-validated before writing) and print the per-lane stall
    // ledger the trace closes.
    if let Some(path) = &trace_path {
        let trace = report.trace.as_ref().expect("trace was enabled for this run");
        let json = trace.to_chrome_json();
        let stats = piperec::trace::chrome::validate_chrome_trace(&json)
            .map_err(|e| format!("exported trace failed validation: {e}"))?;
        std::fs::write(path, &json)?;
        println!(
            "\ntrace   : wrote {path} — {} spans, {} events, {} tracks \
             (load in chrome://tracing or ui.perfetto.dev)",
            trace.span_count(),
            stats.events,
            stats.tracks
        );
        if let Some(att) = &report.stall_attribution {
            println!("stall attribution (host seconds; every lane's ledger closes):");
            print!("{}", att.render());
        }
    }

    // Paper-frame comparison: what the same byte volume costs each system.
    let bytes = spec.total_bytes();
    let cpu12 = bytes as f64 / CPU_ETL_BW_12CORE;
    let pandas =
        PandasModel::default().pipeline_seconds(kind, &spec) / spec.paper_scale_factor();
    println!("\nETL time for these {} (models):", fmt_bytes(bytes));
    println!("  PipeRec (simulated FPGA clock): {}", fmt_secs(report.etl_sim_s));
    println!("  pandas 64-thread model        : {}", fmt_secs(pandas));
    println!("  production 12-core CPU (~10MB/s): {}", fmt_secs(cpu12));
    println!(
        "  → PipeRec vs pandas: {:.1}×, vs 12-core CPU: {:.1}×",
        pandas / report.etl_sim_s.max(1e-12),
        cpu12 / report.etl_sim_s.max(1e-12)
    );
    Ok(())
}
