//! Platform shoot-out: run the same ETL pipeline through the real
//! multithreaded Rust CPU engine (measured), the calibrated
//! pandas/Beam/NVTabular models (paper scale), and the PipeRec vFPGA
//! simulation — the Fig. 13/15/16 comparison in miniature.
//!
//! ```bash
//! cargo run --release --example etl_compare -- --pipeline 3 --dataset 1
//! ```

use piperec::baselines::{BeamModel, GpuKind, GpuModel, PandasModel, RustCpuEtl};
use piperec::bench_harness::Table;
use piperec::dataio::dataset::{DatasetKind, DatasetSpec};
use piperec::etl::pipelines::{build, PipelineKind};
use piperec::fpga::Pipeline;
use piperec::memsys::IngestSource;
use piperec::prelude::*;
use piperec::util::cli::Args;
use piperec::util::fmt_secs;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    let kind = match args.get_str("pipeline", "2").as_str() {
        "1" => PipelineKind::I,
        "3" => PipelineKind::III,
        _ => PipelineKind::II,
    };
    let dkind = match args.get_str("dataset", "1").as_str() {
        "2" => DatasetKind::II,
        "3" => DatasetKind::III,
        _ => DatasetKind::I,
    };
    let mut spec = DatasetSpec::by_kind(dkind, args.get("scale", 0.02));
    spec.shards = 2;

    let dag = build(kind, &spec.schema);
    let plan = compile(&dag, &spec.schema, &PlannerConfig::default())?;
    let mut pipe = Pipeline::new(plan);

    // Measured: our real Rust CPU baseline on this machine — the columnar
    // reference interpreter vs the fused tiled engine (same DAG, same
    // thread budget, apply+pack in one pass).
    let shard = spec.shard(0, 42);
    let threads = piperec::util::pool::default_threads();
    let (_, rust_cpu_s) = RustCpuEtl::new(threads).run(&dag, &shard)?;
    let (_, rust_fused_s) = RustCpuEtl::new(threads).run_fused(&dag, &shard)?;

    // Measured (simulated clock): PipeRec on the same shard.
    pipe.fit(&shard)?;
    let (_, t) = pipe.process(&shard)?;

    // Models at paper scale (per DESIGN.md §1 substitutions).
    let source = if spec.ssd_bound { IngestSource::Ssd } else { IngestSource::Host };
    let profile = piperec::planner::StreamProfile::from_schema(&spec.schema, spec.paper_rows);
    let piperec_paper = pipe.projected_seconds_profiled(profile, source);
    let pandas = PandasModel::default().pipeline_seconds(kind, &spec);
    let beam = BeamModel::new(128).pipeline_seconds(kind, &spec);
    let gpu3090 = GpuModel::new(GpuKind::Rtx3090).pipeline_seconds(kind, &spec);
    let a100 = GpuModel::new(GpuKind::A100).pipeline_seconds(kind, &spec);

    let mut table = Table::new(
        format!("{} + {} — ETL latency", spec.name, kind.label()),
        &["platform", "latency", "vs PipeRec"],
    );
    let mut row = |name: &str, secs: f64| {
        table.row(vec![
            name.into(),
            fmt_secs(secs),
            format!("{:.1}×", secs / piperec_paper),
        ]);
    };
    row("CPU pandas (64T, model)", pandas);
    row("CPU Beam 128 vCPU (model)", beam);
    row("RTX 3090 NVTabular (model)", gpu3090);
    row("A100 NVTabular (model)", a100);
    row("PipeRec (sim, paper scale)", piperec_paper);
    table.print();

    println!(
        "\nmeasured on this machine ({} rows): Rust CPU {} ({} threads), \
         fused engine {} ({:.1}x), PipeRec sim {}",
        shard.rows(),
        fmt_secs(rust_cpu_s),
        threads,
        fmt_secs(rust_fused_s),
        rust_cpu_s / rust_fused_s.max(1e-12),
        fmt_secs(t.elapsed_s),
    );
    Ok(())
}
