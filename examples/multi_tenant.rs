//! Multi-tenant elasticity demo (paper §3.4 Q1/Q2, §4.8): load
//! heterogeneous ETL pipelines into the vFPGA's dynamic regions via
//! partial reconfiguration, then scale one pipeline across 1–7 regions
//! and watch throughput and resource usage (Fig. 17).
//!
//! ```bash
//! cargo run --release --example multi_tenant
//! ```

use piperec::dataio::dataset::DatasetSpec;
use piperec::etl::pipelines::{build, PipelineKind};
use piperec::fpga::VFpga;
use piperec::memsys::IngestSource;
use piperec::planner::resources::Device;
use piperec::prelude::*;
use piperec::util::fmt_rate;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let device = Device::alveo_u55c();

    // ---- Q1: heterogeneous pipelines coexist ----------------------------
    println!("== multi-tenancy: heterogeneous pipelines ==");
    let mut fpga = VFpga::new(device);
    let mut spec = DatasetSpec::dataset_i(0.002);
    spec.shards = 1;
    let shard = spec.shard(0, 42);

    let mut regions = Vec::new();
    for kind in PipelineKind::all() {
        let dag = build(kind, &spec.schema);
        let plan = compile(&dag, &spec.schema, &PlannerConfig::default())?;
        let id = fpga.load(plan)?;
        if kind != PipelineKind::I {
            fpga.fit(id, &shard)?;
        }
        regions.push((kind, id));
    }
    let util = fpga.utilization();
    println!(
        "loaded {} pipelines; device: CLB {:.1}% BRAM {:.1}% DSP {:.2}% (reconfig {:.1} ms total)",
        fpga.active(),
        util.clb_frac * 100.0,
        util.bram_frac * 100.0,
        util.dsp_frac * 100.0,
        fpga.reconfig_s * 1e3,
    );
    for (kind, id) in &regions {
        let (out, t) = fpga.process(*id, &shard)?;
        println!(
            "  region {:>2} runs {:>5}: {} rows in {:.2} ms (sim) → {}",
            id.0,
            kind.label(),
            out.rows(),
            t.elapsed_s * 1e3,
            fmt_rate(t.throughput()),
        );
    }

    // Tenant churn: swap P-I out for another P-III within milliseconds.
    let (_, first) = regions[0];
    fpga.unload(first)?;
    let dag = build(PipelineKind::III, &spec.schema);
    let plan = compile(&dag, &spec.schema, &PlannerConfig::default())?;
    let id = fpga.load(plan)?;
    println!("swapped region {} → P-III (partial reconfiguration)", id.0);

    // ---- Q2: elasticity — Fig. 17-style scaling -------------------------
    println!("\n== elasticity: concurrent instances of P-I on Dataset-II ==");
    let wide = DatasetSpec::dataset_ii(1.0);
    let dag = build(PipelineKind::I, &wide.schema);
    let plan = compile(&dag, &wide.schema, &PlannerConfig::default())?;
    let fresh = VFpga::new(device);
    println!("{:>9}  {:>14}  {:>10}  {:>8}", "pipelines", "throughput", "scaling", "clock");
    let base = fresh.concurrent_throughput(&plan, 1, IngestSource::OnBoard);
    for n in [1usize, 2, 4, 7] {
        let tput = fresh.concurrent_throughput(&plan, n, IngestSource::OnBoard);
        let clock = match n {
            0..=4 => 200,
            5 | 6 => 180,
            _ => 150,
        };
        println!(
            "{:>9}  {:>14}  {:>9.2}×  {:>5} MHz",
            n,
            fmt_rate(tput),
            tput / base,
            clock
        );
    }
    Ok(())
}
