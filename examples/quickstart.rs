//! Quickstart: compose an ETL pipeline with the public API, compile it to
//! a vFPGA plan, run it over a synthetic Criteo shard, and inspect the
//! training-ready output.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use piperec::coordinator::{pack, PackLayout};
use piperec::fpga::Pipeline;
use piperec::prelude::*;
use piperec::util::{fmt_bytes, fmt_rate, fmt_secs};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. A dataset schema: 4 dense + 3 sparse features (Criteo-style).
    let schema = Schema::tabular("demo", 4, 3, 10_000);

    // 2. Compose the ETL DAG with software-defined operators (Table 1).
    let mut dag = Dag::new("quickstart");
    let label = dag.source("demo_label", ColType::F32);
    dag.sink("label", label, SinkRole::Label);
    for (i, f) in schema.dense_fields().enumerate() {
        let s = dag.source(&f.name, ColType::F32);
        let fm = dag.op(OpSpec::FillMissing { dense_default: 0.0, sparse_default: 0 }, &[s]);
        let cl = dag.op(OpSpec::Clamp { lo: 0.0, hi: f32::MAX }, &[fm]);
        let lg = dag.op(OpSpec::Logarithm, &[cl]);
        dag.sink(format!("dense{i}"), lg, SinkRole::Dense);
    }
    for (i, f) in schema.sparse_fields().enumerate() {
        let s = dag.source(&f.name, ColType::Hex8);
        let h = dag.op(OpSpec::Hex2Int, &[s]);
        let m = dag.op(OpSpec::Modulus { m: 8192 }, &[h]);
        let v = dag.vocab_op(OpSpec::VocabGen { expected: 8192 }, m, format!("v{i}"));
        dag.sink(format!("sparse{i}"), v, SinkRole::SparseIndex);
    }

    // 3. Compile: freeze → fuse → place state → emit the runtime plan.
    let plan = compile(&dag, &schema, &PlannerConfig::default())?;
    println!("compiled '{}':", plan.name);
    println!("  fused stages : {}", plan.stages.len());
    println!("  dataflow II  : {} cycle(s)", plan.dataflow_ii);
    println!("  line rate    : {}", fmt_rate(plan.line_rate()));
    println!(
        "  resources    : CLB {:.1}%  BRAM {:.1}%  DSP {:.2}%",
        plan.device_report.clb_frac * 100.0,
        plan.device_report.bram_frac * 100.0,
        plan.device_report.dsp_frac * 100.0,
    );

    // 4. Deploy on the simulated device and run a shard through it.
    let mut pipeline = Pipeline::new(plan);
    let raw = piperec::dataio::synth::generate(
        &schema,
        100_000,
        42,
        &piperec::dataio::synth::SynthConfig::default(),
    );
    println!("\nprocessing {} rows ({})", raw.rows(), fmt_bytes(raw.total_bytes() as u64));
    let fit_t = pipeline.fit(&raw)?;
    println!("  fit phase    : {} (simulated)", fmt_secs(fit_t.elapsed_s));
    let (out, t) = pipeline.process(&raw)?;
    println!("  apply phase  : {} (simulated), {}", fmt_secs(t.elapsed_s), fmt_rate(t.throughput()));

    // 5. Pack into the GPU-ready layout (what P2P DMA would stream).
    let layout = PackLayout::of(&pipeline.plan.dag)?;
    let packed = pack(&out, &layout)?;
    println!(
        "\npacked batch: {} rows × ({} dense + {} sparse + label) = {}",
        packed.rows,
        packed.n_dense,
        packed.n_sparse,
        fmt_bytes(packed.bytes()),
    );
    println!("  first row dense  : {:?}", &packed.dense[..packed.n_dense]);
    println!("  first row sparse : {:?}", &packed.sparse[..packed.n_sparse]);
    println!("  vocabularies     : {:?} entries",
        pipeline.state.vocabs.values().map(|t| t.len()).collect::<Vec<_>>());
    Ok(())
}
