"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py),
including Hypothesis sweeps over shapes and value ranges."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dot_interact, mlp, ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestDotInteraction:
    def test_matches_ref_basic(self):
        feats = rand(0, 64, 27, 16)
        got = dot_interact.dot_interaction(feats)
        want = ref.dot_interaction_ref(feats)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_single_tile(self):
        feats = rand(1, 32, 5, 8)
        got = dot_interact.dot_interaction(feats)
        assert got.shape == (32, 10)
        np.testing.assert_allclose(got, ref.dot_interaction_ref(feats), rtol=1e-5)

    def test_multiple_tiles(self):
        feats = rand(2, 128, 9, 4)
        got = dot_interact.dot_interaction(feats, block_b=32)
        np.testing.assert_allclose(got, ref.dot_interaction_ref(feats), rtol=1e-5, atol=1e-5)

    def test_gradients_flow(self):
        feats = rand(3, 32, 6, 8)
        g_pallas = jax.grad(lambda f: jnp.sum(dot_interact.dot_interaction(f) ** 2))(feats)
        g_ref = jax.grad(lambda f: jnp.sum(ref.dot_interaction_ref(f) ** 2))(feats)
        np.testing.assert_allclose(g_pallas, g_ref, rtol=1e-4, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        b_tiles=st.integers(1, 4),
        f=st.integers(2, 12),
        d=st.sampled_from([1, 4, 8, 16, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shape_sweep(self, b_tiles, f, d, seed):
        b = 16 * b_tiles
        feats = jax.random.normal(jax.random.PRNGKey(seed), (b, f, d), jnp.float32)
        got = dot_interact.dot_interaction(feats, block_b=16)
        want = ref.dot_interaction_ref(feats)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_vmem_estimate_within_budget(self):
        # Default DLRM tile must sit far below the 16 MiB VMEM budget.
        assert dot_interact.vmem_bytes(32, 27, 16) < 1 << 20


class TestMlpLayer:
    def test_matches_ref_with_relu(self):
        x, w, b = rand(0, 128, 32), rand(1, 32, 64), rand(2, 64)
        got = mlp.mlp_layer(x, w, b, True)
        np.testing.assert_allclose(got, ref.mlp_layer_ref(x, w, b, True), rtol=1e-5, atol=1e-5)

    def test_matches_ref_no_relu(self):
        x, w, b = rand(3, 64, 16), rand(4, 16, 8), rand(5, 8)
        got = mlp.mlp_layer(x, w, b, False)
        np.testing.assert_allclose(got, ref.mlp_layer_ref(x, w, b, False), rtol=1e-5, atol=1e-5)
        assert bool(jnp.any(got < 0))  # negatives survive without relu

    def test_relu_clips_negatives(self):
        x, w, b = rand(6, 32, 8), rand(7, 8, 4), rand(8, 4)
        got = mlp.mlp_layer(x, w, b, True)
        assert bool(jnp.all(got >= 0))

    def test_tiling_grid(self):
        x, w, b = rand(9, 256, 48), rand(10, 48, 256), rand(11, 256)
        got = mlp.mlp_layer(x, w, b, True, block_m=128, block_n=128)
        np.testing.assert_allclose(got, ref.mlp_layer_ref(x, w, b, True), rtol=1e-4, atol=1e-4)

    def test_gradients_flow(self):
        x, w, b = rand(12, 32, 8), rand(13, 8, 4), rand(14, 4)
        f_pallas = lambda w: jnp.sum(mlp.mlp_layer(x, w, b, True) ** 2)
        f_ref = lambda w: jnp.sum(ref.mlp_layer_ref(x, w, b, True) ** 2)
        np.testing.assert_allclose(
            jax.grad(f_pallas)(w), jax.grad(f_ref)(w), rtol=1e-4, atol=1e-4
        )

    @settings(max_examples=20, deadline=None)
    @given(
        m_tiles=st.integers(1, 4),
        k=st.integers(1, 64),
        n=st.sampled_from([1, 4, 16, 64]),
        relu=st.booleans(),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shape_sweep(self, m_tiles, k, n, relu, seed):
        m = 32 * m_tiles
        key = jax.random.PRNGKey(seed)
        kx, kw, kb = jax.random.split(key, 3)
        x = jax.random.normal(kx, (m, k), jnp.float32)
        w = jax.random.normal(kw, (k, n), jnp.float32)
        b = jax.random.normal(kb, (n,), jnp.float32)
        got = mlp.mlp_layer(x, w, b, relu, block_m=32, block_n=min(n, 128))
        np.testing.assert_allclose(got, ref.mlp_layer_ref(x, w, b, relu), rtol=1e-4, atol=1e-4)

    def test_mxu_utilization_model(self):
        assert mlp.mxu_utilization(128, 128, 128) == 1.0
        assert mlp.mxu_utilization(128, 1, 128) < 0.01


class TestEmbeddingRef:
    def test_gather_shape(self):
        table = rand(0, 100, 8)
        idx = jnp.array([[0, 1], [99, 50]], jnp.int32)
        out = ref.embedding_gather_ref(table, idx)
        assert out.shape == (2, 2, 8)
        np.testing.assert_allclose(out[1, 0], table[99])


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_dtype_passthrough(dtype):
    feats = rand(0, 32, 4, 8).astype(dtype)
    assert dot_interact.dot_interaction(feats).dtype == dtype
