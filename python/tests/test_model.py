"""L2 correctness: DLRM model shapes, flat-state round-trip, training
dynamics, and Pallas-vs-reference agreement of the full forward pass."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    DlrmConfig,
    batch_specs,
    bce_loss,
    flatten_params,
    forward,
    init_params,
    loss_fn,
    read_loss,
    train_step,
    unflatten_params,
)

jax.config.update("jax_platform_name", "cpu")

CFG = DlrmConfig(batch=32, n_dense=4, n_sparse=3, vocab=50, embed_dim=8,
                 bot_hidden=16, top_hidden=16)


def make_batch(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    kd, ks, kl = jax.random.split(key, 3)
    dense = jax.random.normal(kd, (cfg.batch, cfg.n_dense), jnp.float32)
    sparse = jax.random.randint(ks, (cfg.batch, cfg.n_sparse), 0, cfg.vocab, jnp.int32)
    labels = (jax.random.uniform(kl, (cfg.batch,)) < 0.3).astype(jnp.float32)
    return dense, sparse, labels


def test_param_specs_count():
    assert CFG.param_count() == sum(
        int(np.prod(s)) for _, s in CFG.param_specs()
    )
    assert CFG.state_len() == CFG.param_count() + 1


def test_flatten_roundtrip():
    params = init_params(CFG, jax.random.PRNGKey(0))
    state = flatten_params(CFG, params, jnp.float32(3.5))
    assert state.shape == (CFG.state_len(),)
    back = unflatten_params(CFG, state)
    for name, _ in CFG.param_specs():
        np.testing.assert_array_equal(back[name], params[name])
    assert state[-1] == 3.5


def test_forward_shapes():
    params = init_params(CFG, jax.random.PRNGKey(1))
    dense, sparse, _ = make_batch(CFG)
    logits = forward(CFG, params, dense, sparse)
    assert logits.shape == (CFG.batch,)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_pallas_matches_reference_forward():
    cfg_p = CFG
    cfg_r = DlrmConfig(**{**cfg_p.__dict__, "use_pallas": False})
    params = init_params(cfg_p, jax.random.PRNGKey(2))
    dense, sparse, _ = make_batch(cfg_p)
    lp = forward(cfg_p, params, dense, sparse)
    lr_ = forward(cfg_r, params, dense, sparse)
    np.testing.assert_allclose(lp, lr_, rtol=1e-4, atol=1e-4)


def test_bce_loss_known_values():
    logits = jnp.array([0.0, 100.0, -100.0])
    labels = jnp.array([0.5, 1.0, 0.0])
    # log(2) for the first, ~0 for the saturated ones.
    assert abs(float(bce_loss(logits, labels)) - float(jnp.log(2.0)) / 3) < 1e-4


def test_loss_decreases_over_steps():
    cfg = DlrmConfig(**{**CFG.__dict__, "lr": 0.5})
    params = init_params(cfg, jax.random.PRNGKey(3))
    state = flatten_params(cfg, params, jnp.float32(0))
    step = jax.jit(functools.partial(train_step, cfg))
    dense, sparse, labels = make_batch(cfg, seed=7)
    losses = []
    for _ in range(80):
        state = step(state, dense, sparse, labels)
        losses.append(float(read_loss(cfg, state)))
    # Overfitting a fixed batch must drive the loss down substantially.
    assert losses[-1] < losses[0] * 0.8, losses[:3] + losses[-3:]
    assert all(np.isfinite(l) for l in losses)


def test_train_step_only_touches_used_embeddings():
    params = init_params(CFG, jax.random.PRNGKey(4))
    state = flatten_params(CFG, params, jnp.float32(0))
    dense, sparse, labels = make_batch(CFG, seed=9)
    new_state = train_step(CFG, state, dense, sparse, labels)
    new_params = unflatten_params(CFG, new_state)
    # Embedding rows never indexed must be untouched by the sparse update.
    offsets = np.arange(CFG.n_sparse) * CFG.vocab
    used = set((np.asarray(sparse) + offsets[None, :]).reshape(-1).tolist())
    emb_old = np.asarray(params["emb"])
    emb_new = np.asarray(new_params["emb"])
    untouched = [r for r in range(CFG.emb_rows) if r not in used]
    np.testing.assert_array_equal(emb_new[untouched], emb_old[untouched])
    # And at least one used row changed.
    assert any(not np.allclose(emb_new[r], emb_old[r]) for r in used)


def test_read_loss_slot():
    params = init_params(CFG, jax.random.PRNGKey(5))
    state = flatten_params(CFG, params, jnp.float32(1.25))
    assert float(read_loss(CFG, state)) == 1.25


def test_batch_specs_shapes():
    s, d, sp, l = batch_specs(CFG)
    assert s.shape == (CFG.state_len(),)
    assert d.shape == (CFG.batch, CFG.n_dense)
    assert sp.shape == (CFG.batch, CFG.n_sparse)
    assert sp.dtype == jnp.int32
    assert l.shape == (CFG.batch,)


def test_deterministic_step():
    params = init_params(CFG, jax.random.PRNGKey(6))
    state = flatten_params(CFG, params, jnp.float32(0))
    dense, sparse, labels = make_batch(CFG, seed=11)
    a = train_step(CFG, state, dense, sparse, labels)
    b = train_step(CFG, state, dense, sparse, labels)
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("vocab", [10, 100])
def test_config_scaling(vocab):
    cfg = DlrmConfig(batch=32, n_dense=2, n_sparse=2, vocab=vocab, embed_dim=4,
                     bot_hidden=8, top_hidden=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    dense, sparse, labels = make_batch(cfg)
    sparse = sparse % vocab
    loss = loss_fn(cfg, params, dense, sparse, labels)
    assert np.isfinite(float(loss))
