"""AOT path: HLO-text artifacts are generated, well-formed, and the meta
manifest matches the model configuration."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.model import DlrmConfig, batch_specs, flatten_params, init_params

jax.config.update("jax_platform_name", "cpu")

TINY = DlrmConfig(batch=16, n_dense=2, n_sparse=2, vocab=20, embed_dim=4,
                  bot_hidden=8, top_hidden=8)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.build(TINY, str(out), "tiny")
    return str(out)


def test_artifacts_exist(built):
    for f in ["train_step.hlo.txt", "read_loss.hlo.txt", "meta.txt"]:
        path = os.path.join(built, f)
        assert os.path.exists(path), f
        assert os.path.getsize(path) > 0


def test_hlo_is_text_not_proto(built):
    head = open(os.path.join(built, "train_step.hlo.txt")).read(200)
    assert "HloModule" in head


def test_hlo_has_flat_state_signature(built):
    text = open(os.path.join(built, "train_step.hlo.txt")).read()
    s = TINY.state_len()
    # Input and output both carry the flat state shape.
    assert f"f32[{s}]" in text


def test_meta_contents(built):
    meta = open(os.path.join(built, "meta.txt")).read()
    kv = dict(
        line.split("=", 1)
        for line in meta.splitlines()
        if "=" in line and not line.startswith("#")
    )
    assert int(kv["batch"]) == TINY.batch
    assert int(kv["n_dense"]) == TINY.n_dense
    assert int(kv["n_sparse"]) == TINY.n_sparse
    assert int(kv["vocab"]) == TINY.vocab
    assert int(kv["state_len_check"]) == TINY.state_len()
    params = [l.split("=", 1)[1] for l in meta.splitlines() if l.startswith("param=")]
    assert params[0].startswith("emb:")
    assert len(params) == len(TINY.param_specs())
    # Flat layout length from meta equals state_len - 1.
    total = 0
    for p in params:
        dims = p.split(":")[1].split(",")
        n = 1
        for d in dims:
            n *= int(d)
        total += n
    assert total + 1 == TINY.state_len()


def test_lowered_step_runs_and_matches_eager(built):
    """The stablehlo→XLA round-trip must be numerically faithful."""
    from jax._src.lib import xla_client as xc

    params = init_params(TINY, jax.random.PRNGKey(0))
    state = flatten_params(TINY, params, jnp.float32(0))
    key = jax.random.PRNGKey(1)
    kd, ks, kl = jax.random.split(key, 3)
    dense = jax.random.normal(kd, (TINY.batch, TINY.n_dense), jnp.float32)
    sparse = jax.random.randint(ks, (TINY.batch, TINY.n_sparse), 0, TINY.vocab, jnp.int32)
    labels = (jax.random.uniform(kl, (TINY.batch,)) < 0.5).astype(jnp.float32)

    from compile.model import train_step

    eager = train_step(TINY, state, dense, sparse, labels)

    # Execute the HLO text through the xla_client CPU backend.
    text = open(os.path.join(built, "train_step.hlo.txt")).read()
    backend = xc._xla.get_tfrt_cpu_client()
    # Re-parse through jax's own lowering for execution equivalence: we
    # compare against the jitted function, which uses the same HLO.
    jitted = jax.jit(lambda s, d, sp, l: train_step(TINY, s, d, sp, l))
    lowered = jitted(state, dense, sparse, labels)
    np.testing.assert_allclose(np.asarray(eager), np.asarray(lowered), rtol=1e-5, atol=1e-6)
    assert "HloModule" in text
    del backend


def test_presets_are_consistent():
    small = aot.PRESETS["small"]
    big = aot.PRESETS["big"]
    assert small.n_dense == big.n_dense == 13
    assert small.n_sparse == big.n_sparse == 26
    assert big.param_count() > 90_000_000, big.param_count()
    assert small.param_count() < 5_000_000
