"""L2: DLRM forward/backward in JAX, calling the L1 Pallas kernels.

The model follows Naumov et al.'s DLRM: a bottom MLP embeds the dense
features, sparse features index one shared embedding table (one logical
table per feature, stored stacked with per-feature row offsets), the
pairwise dot-interaction crosses all feature vectors, and a top MLP
produces the click logit trained with BCE.

The train step is written over a **flat f32 state vector** (all params
concatenated + one trailing loss slot) so the Rust runtime can keep a
single device-resident buffer and re-feed it across steps (`execute_b`)
with zero host traffic — see rust/src/runtime/mod.rs.
"""

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import dot_interact, mlp, ref


@dataclass(frozen=True)
class DlrmConfig:
    """Model + training hyperparameters (mirrored into artifacts/meta.txt)."""

    batch: int = 256
    n_dense: int = 13
    n_sparse: int = 26
    vocab: int = 4000          # rows per sparse feature
    embed_dim: int = 16
    bot_hidden: int = 64
    top_hidden: int = 64
    lr: float = 0.05
    use_pallas: bool = True

    @property
    def emb_rows(self) -> int:
        return self.n_sparse * self.vocab

    @property
    def n_pairs(self) -> int:
        f = self.n_sparse + 1  # embeddings + bottom-MLP vector
        return (f * (f - 1)) // 2

    @property
    def top_in(self) -> int:
        return self.embed_dim + self.n_pairs

    def param_specs(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Name → shape, in flat-state layout order."""
        return [
            ("emb", (self.emb_rows, self.embed_dim)),
            ("w_bot1", (self.n_dense, self.bot_hidden)),
            ("b_bot1", (self.bot_hidden,)),
            ("w_bot2", (self.bot_hidden, self.embed_dim)),
            ("b_bot2", (self.embed_dim,)),
            ("w_top1", (self.top_in, self.top_hidden)),
            ("b_top1", (self.top_hidden,)),
            ("w_top2", (self.top_hidden, 1)),
            ("b_top2", (1,)),
        ]

    def param_count(self) -> int:
        import math

        return sum(math.prod(s) for _, s in self.param_specs())

    def state_len(self) -> int:
        return self.param_count() + 1  # + loss slot


def init_params(cfg: DlrmConfig, key: jax.Array) -> Dict[str, jnp.ndarray]:
    """Glorot-ish init (the Rust runtime reproduces the same scheme)."""
    params = {}
    for name, shape in cfg.param_specs():
        key, sub = jax.random.split(key)
        if name.startswith("b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name == "emb":
            params[name] = jax.random.normal(sub, shape, jnp.float32) * 0.05
        else:
            scale = (2.0 / (shape[0] + shape[-1])) ** 0.5
            params[name] = jax.random.normal(sub, shape, jnp.float32) * scale
    return params


def flatten_params(cfg: DlrmConfig, params: Dict[str, jnp.ndarray], loss: jnp.ndarray) -> jnp.ndarray:
    """Params + loss slot → flat f32 state."""
    parts = [params[name].reshape(-1) for name, _ in cfg.param_specs()]
    parts.append(jnp.reshape(loss.astype(jnp.float32), (1,)))
    return jnp.concatenate(parts)


def unflatten_params(cfg: DlrmConfig, state: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Flat state → params dict (loss slot ignored)."""
    params = {}
    off = 0
    for name, shape in cfg.param_specs():
        n = 1
        for s in shape:
            n *= s
        params[name] = jax.lax.dynamic_slice_in_dim(state, off, n).reshape(shape)
        off += n
    return params


def forward(cfg: DlrmConfig, params: Dict[str, jnp.ndarray], dense: jnp.ndarray, sparse: jnp.ndarray) -> jnp.ndarray:
    """DLRM forward pass → logits [B]."""
    mlp_layer = mlp.mlp_layer if cfg.use_pallas else ref.mlp_layer_ref
    interact = dot_interact.dot_interaction if cfg.use_pallas else ref.dot_interaction_ref

    # Bottom MLP: dense [B, n_dense] → [B, D].
    h = mlp_layer(dense, params["w_bot1"], params["b_bot1"], True)
    bottom = mlp_layer(h, params["w_bot2"], params["b_bot2"], True)

    # Embedding lookup with per-feature row offsets into the stacked table.
    offsets = (jnp.arange(cfg.n_sparse, dtype=jnp.int32) * cfg.vocab)[None, :]
    flat_idx = sparse + offsets  # [B, F]
    emb = params["emb"][flat_idx]  # [B, F, D]

    # Interaction over [bottom | embeddings].
    feats = jnp.concatenate([bottom[:, None, :], emb], axis=1)  # [B, F+1, D]
    pairs = interact(feats)  # [B, P]

    top = jnp.concatenate([bottom, pairs], axis=1)  # [B, top_in]
    h = mlp_layer(top, params["w_top1"], params["b_top1"], True)
    logits = ref.mlp_layer_ref(h, params["w_top2"], params["b_top2"], relu=False)
    return logits[:, 0]


def bce_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Numerically-stable binary cross-entropy with logits."""
    return jnp.mean(
        jnp.maximum(logits, 0.0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def loss_fn(cfg: DlrmConfig, params, dense, sparse, labels) -> jnp.ndarray:
    return bce_loss(forward(cfg, params, dense, sparse), labels)


def train_step(cfg: DlrmConfig, state: jnp.ndarray, dense: jnp.ndarray, sparse: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """One SGD step over the flat state; returns the new flat state with
    the loss written into the trailing slot."""
    params = unflatten_params(cfg, state)
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, dense, sparse, labels))(params)
    new_params = jax.tree_util.tree_map(lambda p, g: p - cfg.lr * g, params, grads)
    return flatten_params(cfg, new_params, loss)


def read_loss(cfg: DlrmConfig, state: jnp.ndarray) -> jnp.ndarray:
    """Extract the loss slot (lowered into its own tiny executable)."""
    return state[cfg.state_len() - 1]


def batch_specs(cfg: DlrmConfig):
    """ShapeDtypeStructs of the train-step arguments (after the state)."""
    return (
        jax.ShapeDtypeStruct((cfg.state_len(),), jnp.float32),
        jax.ShapeDtypeStruct((cfg.batch, cfg.n_dense), jnp.float32),
        jax.ShapeDtypeStruct((cfg.batch, cfg.n_sparse), jnp.int32),
        jax.ShapeDtypeStruct((cfg.batch,), jnp.float32),
    )
