"""Pallas kernel: fused dense layer (matmul + bias + ReLU) for the DLRM
MLP stacks (L1).

Hardware adaptation (DESIGN.md §6): instead of porting a CUDA GEMM, the
layer is tiled for the MXU — [BM, K] × [K, BN] blocks staged through VMEM
with the bias add and activation fused into the epilogue so the
activation tensor never round-trips to HBM between ops (the same fusion
motivation as the paper's FPGA operator fusion, applied to the trainer).

Grid is (M/BM, N/BN); K is kept whole per block (DLRM layer widths are
small: K ≤ 512), so each grid step is a single MXU pass: VMEM per step at
BM=128, BN=128, K=512, f32 ≈ 128·512·4 + 512·128·4 + 128·128·4 ≈ 576 KiB.

``interpret=True``: see dot_interact.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp_kernel(x_ref, w_ref, b_ref, o_ref, *, relu):
    x = x_ref[...]  # [BM, K]
    w = w_ref[...]  # [K, BN]
    b = b_ref[...]  # [BN]
    y = jax.lax.dot_general(
        x, w, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y = y + b[None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y.astype(o_ref.dtype)


def _mlp_layer_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    relu: bool,
    block_m: int,
    block_n: int,
) -> jnp.ndarray:
    """Fused ``act(x @ w + b)`` via Pallas. x: [M, K], w: [K, N], b: [N]."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm = min(block_m, m)
    bn = min(block_n, n)
    assert m % bm == 0 and n % bn == 0, f"({m},{n}) not tiled by ({bm},{bn})"

    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_mlp_kernel, relu=relu),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def mlp_layer(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    relu: bool = True,
    block_m: int = 128,
    block_n: int = 128,
) -> jnp.ndarray:
    """Fused dense layer with a Pallas forward pass; the backward pass uses
    the reference formulation via `jax.vjp` (see dot_interact.py)."""
    return _mlp_layer_pallas(x, w, b, relu, block_m, block_n)


def _mlp_fwd(x, w, b, relu, block_m, block_n):
    return _mlp_layer_pallas(x, w, b, relu, block_m, block_n), (x, w, b)


def _mlp_bwd(relu, _bm, _bn, res, g):
    from compile.kernels import ref

    x, w, b = res
    _, vjp = jax.vjp(lambda x, w, b: ref.mlp_layer_ref(x, w, b, relu), x, w, b)
    return vjp(g)


mlp_layer.defvjp(_mlp_fwd, _mlp_bwd)


def vmem_bytes(block_m: int, block_n: int, k: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM footprint per grid step (DESIGN.md §Perf)."""
    return (block_m * k + k * block_n + block_n + block_m * block_n) * dtype_bytes


def mxu_utilization(block_m: int, block_n: int, k: int) -> float:
    """Fraction of 128×128 MXU tiles doing useful work for one step."""
    pad = lambda v: -(-v // 128) * 128
    useful = block_m * block_n * k
    padded = pad(block_m) * pad(block_n) * pad(k)
    return useful / padded
