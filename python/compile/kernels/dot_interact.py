"""Pallas kernel: DLRM pairwise dot-product feature interaction (L1).

Hardware adaptation (DESIGN.md §6): the paper's trainer is a GPU DLRM; on
TPU the interaction is one small-matrix Gram product per sample — ideal
MXU work. We tile over the batch: each grid step loads a [BT, F, D] block
of feature vectors into VMEM, computes the [F, F] Gram matrix per sample
on the MXU, and writes the upper-triangular entries using a precomputed
(static) index mask so no gather hits the hot loop.

VMEM footprint per grid step (defaults BT=32, F=27, D=16, f32):
  in 32·27·16·4 ≈ 55 KiB, gram 32·27·27·4 ≈ 93 KiB, out 32·351·4 ≈ 45 KiB
  → ≈ 193 KiB ≪ 16 MiB VMEM; MXU sees 27×16 @ 16×27 matmuls batched 32×.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is validated against ``ref.py`` and real-TPU
performance is estimated analytically (EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dot_interact_kernel(feats_ref, out_ref, *, iu, ju):
    """One batch tile: Gram matrix + static upper-triangle selection."""
    feats = feats_ref[...]  # [BT, F, D]
    # MXU: batched feats @ featsᵀ.
    gram = jax.lax.dot_general(
        feats,
        feats,
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )  # [BT, F, F]
    # Static index lists → compile-time slice selection, no runtime gather.
    cols = [gram[:, i, j] for i, j in zip(iu, ju)]
    out_ref[...] = jnp.stack(cols, axis=1)


def _dot_interaction_pallas(feats: jnp.ndarray, block_b: int) -> jnp.ndarray:
    """Pairwise interactions of [B, F, D] → [B, F(F-1)/2] via Pallas."""
    b, f, d = feats.shape
    npairs = (f * (f - 1)) // 2
    iu, ju = [], []
    for i in range(f):
        for j in range(i + 1, f):
            iu.append(i)
            ju.append(j)
    iu, ju = tuple(iu), tuple(ju)

    block_b = min(block_b, b)
    assert b % block_b == 0, f"batch {b} not divisible by tile {block_b}"
    grid = (b // block_b,)

    return pl.pallas_call(
        functools.partial(_dot_interact_kernel, iu=iu, ju=ju),
        out_shape=jax.ShapeDtypeStruct((b, npairs), feats.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, f, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((block_b, npairs), lambda i: (i, 0)),
        interpret=True,
    )(feats)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def dot_interaction(feats: jnp.ndarray, block_b: int = 32) -> jnp.ndarray:
    """Pairwise dot interactions with a Pallas forward pass.

    Pallas `interpret=True` calls do not support reverse-mode autodiff in
    this JAX version, so the backward pass uses the (mathematically
    identical) reference formulation via `jax.vjp` — the standard
    custom-VJP pattern for Pallas kernels.
    """
    return _dot_interaction_pallas(feats, block_b)


def _di_fwd(feats, block_b):
    return _dot_interaction_pallas(feats, block_b), feats


def _di_bwd(_block_b, feats, g):
    from compile.kernels import ref

    _, vjp = jax.vjp(ref.dot_interaction_ref, feats)
    return vjp(g)


dot_interaction.defvjp(_di_fwd, _di_bwd)


def vmem_bytes(block_b: int, f: int, d: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM footprint per grid step (DESIGN.md §Perf)."""
    feats = block_b * f * d * dtype_bytes
    gram = block_b * f * f * dtype_bytes
    out = block_b * ((f * (f - 1)) // 2) * dtype_bytes
    return feats + gram + out
