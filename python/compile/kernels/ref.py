"""Pure-jnp reference oracle for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has a reference implementation here;
pytest (and Hypothesis sweeps) assert elementwise closeness. These
references are also what the DLRM model uses when ``use_pallas=False``.
"""

import jax.numpy as jnp


def dot_interaction_ref(feats: jnp.ndarray) -> jnp.ndarray:
    """Pairwise dot-product feature interaction (DLRM's hot op).

    Args:
      feats: [B, F, D] — F feature vectors (bottom-MLP output + embeddings).

    Returns:
      [B, F*(F-1)//2] — the strictly-upper-triangular entries of the
      per-sample Gram matrix feats @ featsᵀ.
    """
    b, f, _ = feats.shape
    gram = jnp.einsum("bfd,bgd->bfg", feats, feats)
    iu, ju = jnp.triu_indices(f, k=1)
    return gram[:, iu, ju].reshape(b, (f * (f - 1)) // 2)


def mlp_layer_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, relu: bool = True) -> jnp.ndarray:
    """Fused dense layer: ``act(x @ w + b)``.

    Args:
      x: [B, I]; w: [I, O]; b: [O].
    """
    y = x @ w + b[None, :]
    return jnp.maximum(y, 0.0) if relu else y


def embedding_gather_ref(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Embedding lookup: table [V, D], idx [B, F] → [B, F, D]."""
    return table[idx]
