//! The streaming vFPGA dataflow engine (paper §3): compiled pipelines with
//! functional + cycle-approximate execution, an event-level simulator
//! validating the analytical timing model, and the virtualized device with
//! dynamic regions and partial reconfiguration.

pub mod eventsim;
pub mod pipeline;
pub mod vfpga;

pub use pipeline::{Pipeline, ShardTiming};
pub use vfpga::{RegionId, VFpga, MAX_REGIONS, RECONFIG_SECONDS};
