//! A compiled pipeline instance: functional execution (bit-exact operator
//! semantics via the shared kernels) plus the cycle-approximate timing
//! model from the hardware plan.

use crate::coordinator::packer::{pack, PackLayout, PackedBatch};
use crate::error::Result;
use crate::etl::column::Batch;
use crate::etl::dag::EtlState;
use crate::etl::exec::{ExecConfig, FusedEngine};
use crate::memsys::IngestSource;
use crate::planner::{HardwarePlan, StreamProfile};

/// Timing breakdown of one shard pass through the pipeline.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ShardTiming {
    /// Raw bytes ingested.
    pub ingest_bytes: u64,
    /// Packed bytes egressed toward the GPU.
    pub egress_bytes: u64,
    /// Simulated seconds on the ingest channel.
    pub ingest_s: f64,
    /// Simulated seconds in the streaming dataflow.
    pub compute_s: f64,
    /// Simulated wall time (ingest/compute overlap: max, §3.5).
    pub elapsed_s: f64,
    /// Host wall-clock seconds spent on the functional emulation (not part
    /// of the simulated time; reported for profiling).
    pub host_s: f64,
}

impl ShardTiming {
    pub fn accumulate(&mut self, o: &ShardTiming) {
        self.ingest_bytes += o.ingest_bytes;
        self.egress_bytes += o.egress_bytes;
        self.ingest_s += o.ingest_s;
        self.compute_s += o.compute_s;
        self.elapsed_s += o.elapsed_s;
        self.host_s += o.host_s;
    }

    /// Simulated ETL throughput (bytes/s of raw input).
    pub fn throughput(&self) -> f64 {
        if self.elapsed_s == 0.0 {
            0.0
        } else {
            self.ingest_bytes as f64 / self.elapsed_s
        }
    }
}

/// A deployed pipeline: plan + fitted state + the compiled fused engine
/// (the host-side analogue of the bitstream's fused op-chains).
#[derive(Debug)]
pub struct Pipeline {
    pub plan: HardwarePlan,
    pub state: EtlState,
    fitted: bool,
    engine: Option<FusedEngine>,
}

impl Pipeline {
    pub fn new(plan: HardwarePlan) -> Pipeline {
        Pipeline::with_exec_config(plan, ExecConfig::default())
    }

    /// Deploy with explicit fused-engine knobs (tile size / threads).
    pub fn with_exec_config(plan: HardwarePlan, cfg: ExecConfig) -> Pipeline {
        // DAGs without a label sink (no pack layout) fall back to the
        // reference executor in `process_packed`.
        let engine = FusedEngine::compile(&plan.dag, cfg).ok();
        Pipeline { plan, state: EtlState::default(), fitted: false, engine }
    }

    /// The compiled fused engine, if the plan's DAG admits a pack layout.
    pub fn engine(&self) -> Option<&FusedEngine> {
        self.engine.as_ref()
    }

    /// Fit phase (§3.1): stream a sample through the stateful operators to
    /// build vocabulary tables. Returns the simulated fit time.
    ///
    /// When the fused engine compiled, the fit runs through its tiled walk
    /// (VocabGen insertion fused into the stream — no separate reference-
    /// executor pass); `Dag::fit` remains the fallback and the semantic
    /// reference, pinned bit-identical by `prop_invariants`.
    pub fn fit(&mut self, sample: &Batch) -> Result<ShardTiming> {
        let t0 = std::time::Instant::now();
        self.state = match &self.engine {
            Some(engine) => engine.fit(sample)?,
            None => self.plan.dag.fit(sample)?,
        };
        self.fitted = true;
        // The fit pass streams only the sparse columns (§3.1 fit/apply).
        let profile = StreamProfile::from_batch(sample);
        let bytes = profile.sparse_bytes.max(1);
        let compute_s = self.plan.fit_seconds(profile);
        let ingest_s = bytes as f64 / self.plan.runtime.source.stream_bandwidth();
        Ok(ShardTiming {
            ingest_bytes: bytes,
            egress_bytes: 0,
            ingest_s,
            compute_s,
            elapsed_s: ingest_s.max(compute_s),
            host_s: t0.elapsed().as_secs_f64(),
        })
    }

    pub fn is_fitted(&self) -> bool {
        self.fitted
    }

    /// Apply phase: transform a raw shard into the training-ready batch,
    /// returning both the data and the simulated timing.
    pub fn process(&self, shard: &Batch) -> Result<(Batch, ShardTiming)> {
        let t0 = std::time::Instant::now();
        let out = self.plan.dag.apply(shard, &self.state)?;
        let host_s = t0.elapsed().as_secs_f64();

        let profile = StreamProfile::from_batch(shard);
        let ingest_bytes = profile.total();
        let egress_bytes = (out.rows() as u64) * self.plan.runtime.packed_row_bytes;
        let ingest_s = ingest_bytes as f64 / self.plan.runtime.source.stream_bandwidth();
        let compute_s = self.plan.apply_seconds(profile);
        Ok((
            out,
            ShardTiming {
                ingest_bytes,
                egress_bytes,
                ingest_s,
                compute_s,
                elapsed_s: ingest_s.max(compute_s),
                host_s,
            },
        ))
    }

    /// Apply + pack fused in one pass (tile-at-a-time, parallel across
    /// row ranges): transform a raw shard straight into the training-ready
    /// [`PackedBatch`], returning the data and the simulated timing. This
    /// is the producer hot path of the live train loop; `process` remains
    /// the reference (columnar) executor.
    pub fn process_packed(&self, shard: &Batch) -> Result<(PackedBatch, ShardTiming)> {
        let mut out = PackedBatch::default();
        let timing = self.process_packed_into(shard, &mut out)?;
        Ok((out, timing))
    }

    /// Like [`process_packed`](Self::process_packed), reusing `out`'s
    /// buffers (zero steady-state allocation with a
    /// [`crate::etl::exec::BufferPool`]).
    pub fn process_packed_into(&self, shard: &Batch, out: &mut PackedBatch) -> Result<ShardTiming> {
        let t0 = std::time::Instant::now();
        match &self.engine {
            Some(engine) => engine.execute_into(shard, &self.state, out)?,
            None => {
                // No pack layout compiled: reference executor + packer.
                let transformed = self.plan.dag.apply(shard, &self.state)?;
                let layout = PackLayout::of(&self.plan.dag)?;
                *out = pack(&transformed, &layout)?;
            }
        }
        let host_s = t0.elapsed().as_secs_f64();

        let profile = StreamProfile::from_batch(shard);
        let ingest_bytes = profile.total();
        let egress_bytes = (out.rows as u64) * self.plan.runtime.packed_row_bytes;
        let ingest_s = ingest_bytes as f64 / self.plan.runtime.source.stream_bandwidth();
        let compute_s = self.plan.apply_seconds(profile);
        Ok(ShardTiming {
            ingest_bytes,
            egress_bytes,
            ingest_s,
            compute_s,
            elapsed_s: ingest_s.max(compute_s),
            host_s,
        })
    }

    /// Apply + pack fused in one pass **into an arena staging slot** —
    /// the zero-copy producer hot path ([`crate::devmem`]): the fused
    /// engine writes each tile once, directly into arena-backed device
    /// staging memory, and the slot's byte reservation and allocation
    /// counters are enforced on the way. Falls back to the reference
    /// executor + packer (which allocates) when no engine compiled.
    pub fn process_into_slot(
        &self,
        shard: &Batch,
        slot: &mut crate::devmem::StagingSlot,
    ) -> Result<ShardTiming> {
        match &self.engine {
            Some(engine) => {
                let t0 = std::time::Instant::now();
                engine.execute_into_slot(shard, &self.state, slot)?;
                let host_s = t0.elapsed().as_secs_f64();

                let profile = StreamProfile::from_batch(shard);
                let ingest_bytes = profile.total();
                let egress_bytes = (slot.batch().rows as u64) * self.plan.runtime.packed_row_bytes;
                let ingest_s = ingest_bytes as f64 / self.plan.runtime.source.stream_bandwidth();
                let compute_s = self.plan.apply_seconds(profile);
                Ok(ShardTiming {
                    ingest_bytes,
                    egress_bytes,
                    ingest_s,
                    compute_s,
                    elapsed_s: ingest_s.max(compute_s),
                    host_s,
                })
            }
            None => {
                // Reference fallback: pack on the heap, then account the
                // move into the slot (not zero-copy — engines without a
                // pack layout cannot pin the in-place path).
                let mut timing = ShardTiming::default();
                let capacity = slot.capacity_bytes();
                slot.pack_into(capacity, |out| {
                    timing = self.process_packed_into(shard, out)?;
                    Ok(())
                })?;
                Ok(timing)
            }
        }
    }

    /// Simulated seconds to ETL an entire dataset of `bytes` raw input
    /// from `source` (conservative unprofiled bound).
    pub fn projected_seconds(&self, bytes: u64, source: IngestSource) -> f64 {
        let ingest = bytes as f64 / source.stream_bandwidth();
        ingest.max(self.plan.compute_seconds(bytes))
    }

    /// Paper-accurate projection with a schema profile: fit + apply
    /// passes, per-column II weighting (see `HardwarePlan`).
    pub fn projected_seconds_profiled(&self, profile: StreamProfile, source: IngestSource) -> f64 {
        self.plan.etl_seconds_profiled(profile, source)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataio::dataset::DatasetSpec;
    use crate::etl::pipelines::{build, PipelineKind};
    use crate::planner::{compile, PlannerConfig};

    fn deployed(kind: PipelineKind) -> (Pipeline, DatasetSpec) {
        let mut spec = DatasetSpec::dataset_i(0.002);
        spec.shards = 2;
        let dag = build(kind, &spec.schema);
        let plan = compile(&dag, &spec.schema, &PlannerConfig::default()).unwrap();
        (Pipeline::new(plan), spec)
    }

    #[test]
    fn fit_then_process_produces_training_batch() {
        let (mut p, spec) = deployed(PipelineKind::II);
        let shard = spec.shard(0, 42);
        p.fit(&shard).unwrap();
        assert!(p.is_fitted());
        let (out, t) = p.process(&shard).unwrap();
        assert_eq!(out.rows(), shard.rows());
        // 13 dense + 26 sparse + label sinks.
        assert_eq!(out.columns.len(), 40);
        assert!(t.elapsed_s > 0.0 && t.egress_bytes > 0);
        // Sparse outputs are in-vocabulary indices.
        let sparse = out.get("sparse0").unwrap().as_i64().unwrap();
        let vocab_len = p.state.vocabs["vocab_criteo_c0"].len() as i64;
        assert!(sparse.iter().all(|&v| v >= 0 && v <= vocab_len));
    }

    #[test]
    fn stateless_pipeline_near_datapath_rate() {
        let (p, spec) = deployed(PipelineKind::I);
        let shard = spec.shard(0, 42);
        let (_, t) = p.process(&shard).unwrap();
        // II=1 everywhere: compute rate equals the datapath rate.
        let rate = t.ingest_bytes as f64 / t.compute_s;
        assert!((rate / p.plan.datapath_rate() - 1.0).abs() < 0.05, "{t:?}");
        assert_eq!(t.elapsed_s, t.ingest_s.max(t.compute_s));
    }

    #[test]
    fn large_vocab_pipeline_is_compute_bound() {
        let (mut p, spec) = deployed(PipelineKind::III);
        let shard = spec.shard(0, 42);
        p.fit(&shard).unwrap();
        let (_, t) = p.process(&shard).unwrap();
        assert!(t.compute_s > t.ingest_s, "{t:?}");
    }

    #[test]
    fn throughput_matches_line_rate_when_compute_bound() {
        let (p, _) = deployed(PipelineKind::III);
        let bytes = 1u64 << 28;
        let secs = p.plan.compute_seconds(bytes);
        let rate = bytes as f64 / secs;
        let line = p.plan.line_rate();
        assert!((rate - line).abs() / line < 0.05, "rate={rate} line={line}");
    }

    #[test]
    fn fused_fit_in_pipeline_matches_reference_fit() {
        let (mut p, spec) = deployed(PipelineKind::III);
        let shard = spec.shard(0, 42);
        assert!(p.engine().is_some());
        p.fit(&shard).unwrap();
        // The tiled fused fit produced exactly the reference tables.
        assert_eq!(p.state, p.plan.dag.fit(&shard).unwrap());
    }

    #[test]
    fn process_packed_matches_reference_apply_then_pack() {
        let (mut p, spec) = deployed(PipelineKind::II);
        let shard = spec.shard(0, 42);
        p.fit(&shard).unwrap();
        assert!(p.engine().is_some());
        let (out, _) = p.process(&shard).unwrap();
        let layout = crate::coordinator::packer::PackLayout::of(&p.plan.dag).unwrap();
        let want = crate::coordinator::packer::pack(&out, &layout).unwrap();
        let (got, t) = p.process_packed(&shard).unwrap();
        assert_eq!(want, got);
        assert!(t.egress_bytes > 0 && t.host_s >= 0.0);
    }

    #[test]
    fn process_into_slot_matches_process_packed() {
        let (mut p, spec) = deployed(PipelineKind::II);
        let shard = spec.shard(0, 42);
        p.fit(&shard).unwrap();
        let (want, want_t) = p.process_packed(&shard).unwrap();

        let arena = crate::devmem::DeviceArena::with_slots(2);
        let mut slot = arena.acquire().unwrap();
        let t = p.process_into_slot(&shard, &mut slot).unwrap();
        assert_eq!(&want, slot.batch());
        assert_eq!(t.egress_bytes, want_t.egress_bytes);
        assert_eq!(t.ingest_bytes, want_t.ingest_bytes);
        assert_eq!(slot.packed_bytes(), want.bytes());
        arena.release(slot).unwrap();
    }

    #[test]
    fn timing_accumulates() {
        let mut acc = ShardTiming::default();
        let t = ShardTiming {
            ingest_bytes: 10,
            egress_bytes: 5,
            ingest_s: 1.0,
            compute_s: 2.0,
            elapsed_s: 2.0,
            host_s: 0.1,
        };
        acc.accumulate(&t);
        acc.accumulate(&t);
        assert_eq!(acc.ingest_bytes, 20);
        assert_eq!(acc.elapsed_s, 4.0);
        assert!((acc.throughput() - 5.0).abs() < 1e-9);
    }
}
