//! Cycle-approximate event simulation of a streaming stage chain with
//! bounded FIFOs and backpressure (paper §3.1: fused modules M-1, M-2, …
//! connected through on-chip FIFOs).
//!
//! The analytical throughput model (`HardwarePlan::line_rate`) claims the
//! dataflow sustains one word per `max(II)` cycles in steady state. This
//! module *checks* that claim: it simulates token-by-token timing through
//! the chain, including FIFO-full stalls, and the tests assert the two
//! models agree — keeping the fast analytical model honest.

/// One pipeline stage: initiation interval (cycles/token) and pipeline
/// depth (latency in cycles from input to output).
#[derive(Debug, Clone, Copy)]
pub struct SimStage {
    pub ii: u64,
    pub depth: u64,
}

/// Result of simulating a token stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimResult {
    /// Cycle at which the last token left the chain.
    pub total_cycles: u64,
    /// Steady-state cycles per token.
    pub cycles_per_token: f64,
    /// Stall cycles caused by downstream FIFO backpressure at stage 0.
    pub input_stall_cycles: u64,
}

/// Simulate `tokens` flowing through `stages` with FIFOs of `fifo_depth`
/// tokens between consecutive stages.
pub fn simulate(stages: &[SimStage], fifo_depth: usize, tokens: u64) -> SimResult {
    assert!(!stages.is_empty() && fifo_depth >= 1 && tokens >= 1);
    let s = stages.len();
    // fire[j] = cycle at which stage j *started* its most recent token.
    // ring[j] holds the start cycles of the last `fifo_depth` tokens at
    // stage j+1, to model "stage j may not emit token i until stage j+1
    // has accepted token i - fifo_depth".
    let mut last_start = vec![0i64; s];
    let mut first = vec![true; s];
    // history[j][k] = start cycle of token (i - fifo_depth + k) at stage j.
    let mut history: Vec<Vec<i64>> = vec![Vec::with_capacity(fifo_depth); s];
    let mut input_stall = 0u64;
    let mut finish_last = 0i64;

    for i in 0..tokens {
        let mut arrival = 0i64; // cycle the token is available to stage 0
        for j in 0..s {
            let st = stages[j];
            // Earliest start: after arrival, and II after our own last start.
            let mut start = if first[j] {
                arrival
            } else {
                arrival.max(last_start[j] + st.ii as i64)
            };
            // Backpressure: the FIFO between j and j+1 holds `fifo_depth`
            // tokens; we may start token i only once stage j+1 started
            // token i - fifo_depth.
            if j + 1 < s {
                if let Some(&gate) = history[j + 1]
                    .len()
                    .checked_sub(fifo_depth)
                    .and_then(|idx| history[j + 1].get(idx))
                {
                    start = start.max(gate);
                }
            }
            if j == 0 {
                input_stall += (start - arrival).max(0) as u64;
            }
            first[j] = false;
            last_start[j] = start;
            history[j].push(start);
            arrival = start + st.depth as i64; // available to next stage
            let _ = i;
        }
        finish_last = arrival;
    }

    let total = finish_last.max(0) as u64;
    SimResult {
        total_cycles: total,
        cycles_per_token: total as f64 / tokens as f64,
        input_stall_cycles: input_stall,
    }
}

/// Analytical prediction for the same chain: steady-state cycles/token is
/// the max II; total = tokens × maxII + fill latency.
pub fn analytical_cycles(stages: &[SimStage], tokens: u64) -> f64 {
    let max_ii = stages.iter().map(|s| s.ii).max().unwrap_or(1);
    let fill: u64 = stages.iter().map(|s| s.depth).sum();
    (tokens.saturating_sub(1) * max_ii + fill) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_ii1_streams_at_line_rate() {
        let stages = vec![SimStage { ii: 1, depth: 3 }; 4];
        let r = simulate(&stages, 4, 10_000);
        assert!((r.cycles_per_token - 1.0).abs() < 0.01, "{r:?}");
    }

    #[test]
    fn slowest_stage_sets_throughput() {
        // Mirrors Pipeline III: stateless II=1 stages + one II=6 stage.
        let stages = vec![
            SimStage { ii: 1, depth: 2 },
            SimStage { ii: 6, depth: 8 },
            SimStage { ii: 1, depth: 2 },
        ];
        let r = simulate(&stages, 4, 5_000);
        assert!((r.cycles_per_token - 6.0).abs() < 0.05, "{r:?}");
    }

    #[test]
    fn matches_analytical_model_within_2pct() {
        for iis in [[1u64, 1, 1], [2, 1, 1], [1, 6, 1], [2, 2, 6]] {
            let stages: Vec<SimStage> =
                iis.iter().map(|&ii| SimStage { ii, depth: 4 }).collect();
            let tokens = 20_000;
            let sim = simulate(&stages, 8, tokens).total_cycles as f64;
            let ana = analytical_cycles(&stages, tokens);
            let err = (sim - ana).abs() / ana;
            assert!(err < 0.02, "iis={iis:?} sim={sim} ana={ana} err={err}");
        }
    }

    #[test]
    fn backpressure_stalls_input_when_fifo_small() {
        let stages = vec![
            SimStage { ii: 1, depth: 1 },
            SimStage { ii: 8, depth: 1 }, // slow consumer
        ];
        let tight = simulate(&stages, 1, 1_000);
        assert!(tight.input_stall_cycles > 0, "{tight:?}");
        // Throughput still governed by the slow stage, not deadlocked.
        assert!((tight.cycles_per_token - 8.0).abs() < 0.1);
    }

    #[test]
    fn deeper_fifos_do_not_change_steady_state() {
        let stages = vec![
            SimStage { ii: 1, depth: 2 },
            SimStage { ii: 3, depth: 2 },
        ];
        let shallow = simulate(&stages, 1, 4_000);
        let deep = simulate(&stages, 64, 4_000);
        assert!((shallow.cycles_per_token - deep.cycles_per_token).abs() < 0.05);
        // But deeper FIFOs absorb the burst at the input.
        assert!(deep.input_stall_cycles < shallow.input_stall_cycles);
    }
}
