//! The vFPGA device: dynamic regions hosting reconfigurable pipelines
//! (paper §3.4/§4.8). Partial reconfiguration swaps pipelines in
//! milliseconds without a full bitstream recompile (Q1: multi-tenancy);
//! replicating pipelines across regions scales throughput (Q2: elasticity)
//! until the fabric clock derates (7 regions run at 150 MHz) or the shared
//! ingest channels saturate.

use crate::error::{EtlError, Result};
use crate::etl::column::Batch;
use crate::fpga::pipeline::{Pipeline, ShardTiming};
use crate::memsys::{IngestSource, Mmu};
use crate::planner::resources::{Device, ResourceReport};
use crate::planner::HardwarePlan;

/// Handle to a loaded dynamic region.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RegionId(pub usize);

/// Maximum dynamic regions in the U55c floorplan (paper §4.8).
pub const MAX_REGIONS: usize = 7;

/// Partial-reconfiguration latency (paper: "within milliseconds").
pub const RECONFIG_SECONDS: f64 = 4.0e-3;

/// The virtualized FPGA device.
pub struct VFpga {
    pub device: Device,
    regions: Vec<Option<Pipeline>>,
    pub mmu: Mmu,
    /// Simulated seconds spent on partial reconfiguration.
    pub reconfig_s: f64,
    /// Whether the RDMA stack is resident (consumes shell resources).
    pub with_rdma: bool,
}

impl VFpga {
    pub fn new(device: Device) -> VFpga {
        VFpga {
            device,
            regions: (0..MAX_REGIONS).map(|_| None).collect(),
            mmu: Mmu::default(),
            reconfig_s: 0.0,
            with_rdma: false,
        }
    }

    /// Number of loaded pipelines.
    pub fn active(&self) -> usize {
        self.regions.iter().filter(|r| r.is_some()).count()
    }

    /// Aggregate resource usage (shell + RDMA + all loaded pipelines).
    pub fn utilization(&self) -> ResourceReport {
        let mut r = ResourceReport {
            clb_frac: crate::planner::resources::Calib::SHELL_CLB_FRAC,
            bram_frac: crate::planner::resources::Calib::SHELL_BRAM_FRAC,
            dsp_frac: 0.0,
        };
        if self.with_rdma {
            r.clb_frac += crate::planner::resources::Calib::RDMA_CLB_FRAC;
            r.bram_frac += crate::planner::resources::Calib::RDMA_BRAM_FRAC;
        }
        for p in self.regions.iter().flatten() {
            r = r.add(&p.plan.resources);
        }
        r
    }

    /// Effective fabric clock: full speed up to 4 regions, derated beyond
    /// (paper: 7 concurrent pipelines at 150 MHz).
    pub fn effective_clock(&self) -> f64 {
        match self.active() {
            0..=4 => self.device.f_clk,
            5 | 6 => self.device.f_clk * 0.9,
            _ => 150.0e6,
        }
    }

    /// Load a compiled plan into a free dynamic region via partial
    /// reconfiguration. Fails when no region is free or resources would
    /// not fit.
    pub fn load(&mut self, plan: HardwarePlan) -> Result<RegionId> {
        let slot = self
            .regions
            .iter()
            .position(|r| r.is_none())
            .ok_or_else(|| EtlError::Mem("no free dynamic region".into()))?;
        let mut candidate = self.utilization();
        candidate = candidate.add(&plan.resources);
        if !candidate.fits() {
            return Err(EtlError::Plan(format!(
                "loading {} would exceed device resources: {candidate:?}",
                plan.name
            )));
        }
        if plan.with_rdma {
            self.with_rdma = true;
        }
        // Register the staging buffers with the MMU.
        for buf in &plan.runtime.buffers {
            let _ = self.mmu.map(crate::memsys::MemClass::Gpu, buf.bytes, 0);
        }
        self.regions[slot] = Some(Pipeline::new(plan));
        self.reconfig_s += RECONFIG_SECONDS;
        Ok(RegionId(slot))
    }

    /// Unload a region (partial reconfiguration back to empty).
    pub fn unload(&mut self, id: RegionId) -> Result<()> {
        if self.regions.get(id.0).map(|r| r.is_none()).unwrap_or(true) {
            return Err(EtlError::Mem(format!("region {} not loaded", id.0)));
        }
        self.regions[id.0] = None;
        self.reconfig_s += RECONFIG_SECONDS;
        Ok(())
    }

    pub fn pipeline(&self, id: RegionId) -> Result<&Pipeline> {
        self.regions
            .get(id.0)
            .and_then(|r| r.as_ref())
            .ok_or_else(|| EtlError::Mem(format!("region {} not loaded", id.0)))
    }

    pub fn pipeline_mut(&mut self, id: RegionId) -> Result<&mut Pipeline> {
        self.regions
            .get_mut(id.0)
            .and_then(|r| r.as_mut())
            .ok_or_else(|| EtlError::Mem(format!("region {} not loaded", id.0)))
    }

    /// Fit the pipeline in `id` on a sample shard.
    pub fn fit(&mut self, id: RegionId, sample: &Batch) -> Result<ShardTiming> {
        self.pipeline_mut(id)?.fit(sample)
    }

    /// Process one shard on one region, derating for the current clock.
    pub fn process(&self, id: RegionId, shard: &Batch) -> Result<(Batch, ShardTiming)> {
        let clk_scale = self.effective_clock() / self.device.f_clk;
        let p = self.pipeline(id)?;
        let (out, mut t) = p.process(shard)?;
        t.compute_s /= clk_scale;
        t.elapsed_s = t.ingest_s.max(t.compute_s);
        Ok((out, t))
    }

    /// Steady-state aggregate throughput (bytes/s) with `n` identical
    /// pipelines ingesting from `source`: per-pipeline compute at the
    /// derated clock, ingest shared fairly across pipelines (Fig. 17).
    pub fn concurrent_throughput(
        &self,
        plan: &HardwarePlan,
        n: usize,
        source: IngestSource,
    ) -> f64 {
        assert!(n >= 1 && n <= MAX_REGIONS);
        let clk_scale = match n {
            0..=4 => 1.0,
            5 | 6 => 0.9,
            _ => 150.0e6 / self.device.f_clk,
        };
        let per_pipe_compute = plan.line_rate() * clk_scale;
        let ingest_share = source.stream_bandwidth() / n as f64;
        n as f64 * per_pipe_compute.min(ingest_share)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataio::dataset::DatasetSpec;
    use crate::etl::pipelines::{build, PipelineKind};
    use crate::planner::{compile, PlannerConfig};

    fn plan(kind: PipelineKind) -> HardwarePlan {
        let spec = DatasetSpec::dataset_i(0.001);
        let dag = build(kind, &spec.schema);
        compile(&dag, &spec.schema, &PlannerConfig::default()).unwrap()
    }

    #[test]
    fn load_unload_cycle() {
        let mut fpga = VFpga::new(Device::alveo_u55c());
        let id = fpga.load(plan(PipelineKind::I)).unwrap();
        assert_eq!(fpga.active(), 1);
        assert!(fpga.reconfig_s > 0.0);
        fpga.unload(id).unwrap();
        assert_eq!(fpga.active(), 0);
        assert!(fpga.unload(id).is_err());
    }

    #[test]
    fn heterogeneous_pipelines_coexist() {
        // Q1 multi-tenancy: different pipelines in different regions.
        let mut fpga = VFpga::new(Device::alveo_u55c());
        let a = fpga.load(plan(PipelineKind::I)).unwrap();
        let b = fpga.load(plan(PipelineKind::III)).unwrap();
        assert_ne!(a, b);
        assert_eq!(fpga.active(), 2);
        let util = fpga.utilization();
        assert!(util.fits());
        assert!(util.clb_frac > 0.2);
    }

    #[test]
    fn clock_derates_beyond_four_regions() {
        let mut fpga = VFpga::new(Device::alveo_u55c());
        for _ in 0..4 {
            fpga.load(plan(PipelineKind::I)).unwrap();
        }
        assert_eq!(fpga.effective_clock(), 200.0e6);
        for _ in 0..3 {
            fpga.load(plan(PipelineKind::I)).unwrap();
        }
        assert_eq!(fpga.active(), 7);
        assert_eq!(fpga.effective_clock(), 150.0e6);
        // Eighth load fails: no free region.
        assert!(fpga.load(plan(PipelineKind::I)).is_err());
    }

    #[test]
    fn concurrent_throughput_scales_linearly_then_derates() {
        let fpga = VFpga::new(Device::alveo_u55c());
        let p = plan(PipelineKind::I);
        let t1 = fpga.concurrent_throughput(&p, 1, IngestSource::OnBoard);
        let t2 = fpga.concurrent_throughput(&p, 2, IngestSource::OnBoard);
        let t4 = fpga.concurrent_throughput(&p, 4, IngestSource::OnBoard);
        let t7 = fpga.concurrent_throughput(&p, 7, IngestSource::OnBoard);
        assert!((t2 / t1 - 2.0).abs() < 0.05, "t2/t1={}", t2 / t1);
        assert!((t4 / t1 - 4.0).abs() < 0.05);
        // 7 regions: sublinear because of the 150 MHz clock.
        assert!(t7 / t1 > 4.5 && t7 / t1 < 6.0, "t7/t1={}", t7 / t1);
    }

    #[test]
    fn ingest_bound_when_source_is_slow() {
        let fpga = VFpga::new(Device::alveo_u55c());
        let p = plan(PipelineKind::I);
        let t4 = fpga.concurrent_throughput(&p, 4, IngestSource::Ssd);
        // SSD at 1.2 GB/s caps the aggregate regardless of pipeline count.
        assert!((t4 / 1.2e9 - 1.0).abs() < 0.05);
    }

    #[test]
    fn process_on_loaded_region_runs_functionally() {
        let mut spec = DatasetSpec::dataset_i(0.001);
        spec.shards = 1;
        let mut fpga = VFpga::new(Device::alveo_u55c());
        let id = fpga.load(plan(PipelineKind::II)).unwrap();
        let shard = spec.shard(0, 9);
        fpga.fit(id, &shard).unwrap();
        let (out, t) = fpga.process(id, &shard).unwrap();
        assert_eq!(out.rows(), shard.rows());
        assert!(t.elapsed_s > 0.0);
    }
}
