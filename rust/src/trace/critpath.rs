//! Stall-attribution critical-path analysis.
//!
//! For each device lane, walk the consumer's span chain backwards over
//! the traced wall time and attribute **every second to exactly one
//! cause**:
//!
//! * `train_s` — the lane's replica was stepping ([`kind::TRAIN_STEP`]).
//! * `reduce_s` — posting to or waiting on the reduce bus
//!   ([`kind::REDUCE_POST`] / [`kind::REDUCE_APPLY`]).
//! * `backpressure_s` — idle while the lane's producer was blocked on an
//!   arena credit ([`kind::SLOT_ACQUIRE`]): the consumer starved because
//!   staging had nowhere to put the next shard.
//! * `etl_s` — idle while the lane's ETL stage was packing
//!   ([`kind::PACK`], with its nested [`kind::FUSED_EXEC`]): compute-
//!   bound ETL on the critical path.
//! * `ingest_s` — idle while some ingest worker was reading
//!   ([`kind::INGEST_READ`]) and neither of the above: I/O-bound.
//! * `other_s` — idle with no traced cause in flight (startup ramp,
//!   scheduler latency, drain).
//!
//! The busy classes come from the consumer thread itself (sequential, so
//! the intervals are disjoint); its idle gaps are attributed by interval
//! intersection against the cause classes in the priority order above —
//! the same backwards walk as the paper's utilization argument, but as a
//! checked invariant: per lane, the six classes **sum to the traced wall
//! time** ([`LaneAttribution::closes`], default tolerance 1%).
//! `prop_trace.rs` pins closure under fuzzed schedules; ROADMAP item 3's
//! feedback controller reads this breakdown as its observation signal.

use super::{kind, Trace, LANE_NONE};

/// One lane's closed stall ledger (all fields in host seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LaneAttribution {
    pub lane: u32,
    /// The wall time this ledger partitions.
    pub wall_s: f64,
    pub train_s: f64,
    pub reduce_s: f64,
    pub etl_s: f64,
    pub ingest_s: f64,
    pub backpressure_s: f64,
    pub other_s: f64,
}

impl LaneAttribution {
    /// Sum of all attributed classes.
    pub fn attributed_s(&self) -> f64 {
        self.train_s + self.reduce_s + self.etl_s + self.ingest_s + self.backpressure_s
            + self.other_s
    }

    /// Does the ledger close: attributed ≡ wall within `tol` (relative)?
    pub fn closes(&self, tol: f64) -> bool {
        let wall = self.wall_s.max(1e-12);
        ((self.attributed_s() - self.wall_s) / wall).abs() <= tol
    }
}

/// Per-lane stall attribution for a finished [`Trace`]
/// (`TrainReport::stall_attribution` when tracing is enabled).
#[derive(Debug, Clone, PartialEq)]
pub struct StallAttribution {
    pub per_lane: Vec<LaneAttribution>,
}

impl StallAttribution {
    /// Every lane's ledger closes within `tol`.
    pub fn closes(&self, tol: f64) -> bool {
        self.per_lane.iter().all(|l| l.closes(tol))
    }

    /// The attribution for one lane, if traced.
    pub fn lane(&self, lane: u32) -> Option<&LaneAttribution> {
        self.per_lane.iter().find(|l| l.lane == lane)
    }

    /// Human-readable table.
    pub fn render(&self) -> String {
        let mut s = String::from(
            "lane     wall_s    train    reduce      etl   ingest  backpr.    other\n",
        );
        for l in &self.per_lane {
            s.push_str(&format!(
                "{:<4} {:>9.4} {:>8.4} {:>9.4} {:>8.4} {:>8.4} {:>8.4} {:>8.4}\n",
                l.lane, l.wall_s, l.train_s, l.reduce_s, l.etl_s, l.ingest_s, l.backpressure_s,
                l.other_s
            ));
        }
        s
    }
}

/// Half-open interval set helpers (inputs need not be sorted).
fn normalize(mut v: Vec<(f64, f64)>) -> Vec<(f64, f64)> {
    v.retain(|(b, e)| e > b && b.is_finite() && e.is_finite());
    v.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
    let mut out: Vec<(f64, f64)> = Vec::with_capacity(v.len());
    for (b, e) in v {
        match out.last_mut() {
            Some(last) if b <= last.1 => last.1 = last.1.max(e),
            _ => out.push((b, e)),
        }
    }
    out
}

fn total(v: &[(f64, f64)]) -> f64 {
    v.iter().map(|(b, e)| e - b).sum()
}

/// `a \ b`; both normalized.
fn subtract(a: &[(f64, f64)], b: &[(f64, f64)]) -> Vec<(f64, f64)> {
    let mut out = Vec::new();
    for &(ab, ae) in a {
        let mut cur = ab;
        for &(bb, be) in b {
            if be <= cur {
                continue;
            }
            if bb >= ae {
                break;
            }
            if bb > cur {
                out.push((cur, bb.min(ae)));
            }
            cur = cur.max(be);
            if cur >= ae {
                break;
            }
        }
        if cur < ae {
            out.push((cur, ae));
        }
    }
    out
}

/// `a ∩ b`; both normalized.
fn intersect(a: &[(f64, f64)], b: &[(f64, f64)]) -> Vec<(f64, f64)> {
    let mut out = Vec::new();
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        let lo = a[i].0.max(b[j].0);
        let hi = a[i].1.min(b[j].1);
        if hi > lo {
            out.push((lo, hi));
        }
        if a[i].1 <= b[j].1 {
            i += 1;
        } else {
            j += 1;
        }
    }
    out
}

/// Clip every interval to `[0, wall]`.
fn clip(v: Vec<(f64, f64)>, wall: f64) -> Vec<(f64, f64)> {
    v.into_iter()
        .map(|(b, e)| (b.max(0.0), e.min(wall)))
        .filter(|(b, e)| e > b)
        .collect()
}

/// Compute the per-lane stall attribution for a trace (see module docs).
pub fn attribute(trace: &Trace) -> StallAttribution {
    let wall = trace.wall_s.max(0.0);
    let host = |s: &super::Span| (s.host_start_s, s.host_end_s);

    // Lanes = lanes that stepped (or applied a reduce epoch).
    let mut lanes: Vec<u32> = trace
        .spans()
        .filter(|s| {
            s.lane != LANE_NONE
                && matches!(s.kind, kind::TRAIN_STEP | kind::REDUCE_APPLY | kind::REDUCE_POST)
        })
        .map(|s| s.lane)
        .collect();
    lanes.sort_unstable();
    lanes.dedup();

    // Cause classes shared across lanes.
    let ingest_all = normalize(
        trace.spans_of_kind(kind::INGEST_READ).map(host).collect(),
    );

    let per_lane = lanes
        .into_iter()
        .map(|lane| {
            let of = |k: u16| -> Vec<(f64, f64)> {
                trace
                    .spans_of_kind(k)
                    .filter(|s| s.lane == lane)
                    .map(host)
                    .collect()
            };

            // Busy classes from the lane's (sequential) consumer thread.
            let train = clip(normalize(of(kind::TRAIN_STEP)), wall);
            let reduce = clip(
                normalize(
                    of(kind::REDUCE_POST).into_iter().chain(of(kind::REDUCE_APPLY)).collect(),
                ),
                wall,
            );
            // REDUCE spans may nest around/within step boundaries on the
            // consumer thread; give TRAIN_STEP priority so busy classes
            // stay disjoint.
            let reduce = subtract(&reduce, &train);

            // Idle = wall minus busy.
            let busy = normalize(train.iter().chain(reduce.iter()).copied().collect());
            let idle = subtract(&[(0.0, wall)], &busy);

            // Attribute idle by cause, in priority order; each cause
            // consumes its overlap and passes the remainder on.
            let backpr = clip(normalize(of(kind::SLOT_ACQUIRE)), wall);
            let idle_backpr = intersect(&idle, &backpr);
            let idle = subtract(&idle, &idle_backpr);

            let etl = clip(normalize(of(kind::PACK)), wall);
            let idle_etl = intersect(&idle, &etl);
            let idle = subtract(&idle, &idle_etl);

            let idle_ingest = intersect(&idle, &clip(ingest_all.clone(), wall));
            let idle = subtract(&idle, &idle_ingest);

            LaneAttribution {
                lane,
                wall_s: wall,
                train_s: total(&train),
                reduce_s: total(&reduce),
                etl_s: total(&idle_etl),
                ingest_s: total(&idle_ingest),
                backpressure_s: total(&idle_backpr),
                other_s: total(&idle),
            }
        })
        .collect();

    StallAttribution { per_lane }
}

#[cfg(test)]
mod tests {
    use super::super::{Span, ThreadTrack};
    use super::*;

    fn span(kind: u16, lane: u32, b: f64, e: f64) -> Span {
        Span {
            kind,
            lane,
            key: 0,
            host_start_s: b,
            host_end_s: e,
            sim_start_s: f64::NAN,
            sim_end_s: f64::NAN,
            bytes: 0,
            retries: 0,
        }
    }

    fn trace_of(spans: Vec<Span>, wall_s: f64) -> Trace {
        Trace { tracks: vec![ThreadTrack { label: "t".into(), spans }], wall_s }
    }

    #[test]
    fn interval_algebra() {
        let a = normalize(vec![(3.0, 4.0), (0.0, 2.0), (1.0, 2.5)]);
        assert_eq!(a, vec![(0.0, 2.5), (3.0, 4.0)]);
        assert_eq!(subtract(&a, &[(1.0, 3.5)]), vec![(0.0, 1.0), (3.5, 4.0)]);
        assert_eq!(intersect(&a, &[(2.0, 3.5)]), vec![(2.0, 2.5), (3.0, 3.5)]);
        assert!(subtract(&a, &a).is_empty());
        assert!((total(&a) - 3.5).abs() < 1e-12);
    }

    #[test]
    fn ledger_partitions_wall_time_by_priority() {
        // wall [0,10): train [2,4), reduce [4,5);
        // idle [0,2) ∪ [5,10). Causes: slot_acquire [5,6),
        // pack [0,1) ∪ [5.5,8) (pack ∩ remaining idle = [0,1) ∪ [6,8)),
        // ingest [0,9) picks up [1,2) ∪ [8,9); other = [9,10).
        let t = trace_of(
            vec![
                span(kind::TRAIN_STEP, 0, 2.0, 4.0),
                span(kind::REDUCE_APPLY, 0, 4.0, 5.0),
                span(kind::SLOT_ACQUIRE, 0, 5.0, 6.0),
                span(kind::PACK, 0, 0.0, 1.0),
                span(kind::PACK, 0, 5.5, 8.0),
                span(kind::INGEST_READ, LANE_NONE, 0.0, 9.0),
            ],
            10.0,
        );
        let att = attribute(&t);
        let l = att.lane(0).unwrap();
        assert!((l.train_s - 2.0).abs() < 1e-9);
        assert!((l.reduce_s - 1.0).abs() < 1e-9);
        assert!((l.backpressure_s - 1.0).abs() < 1e-9);
        assert!((l.etl_s - 3.0).abs() < 1e-9);
        assert!((l.ingest_s - 2.0).abs() < 1e-9);
        assert!((l.other_s - 1.0).abs() < 1e-9);
        assert!(att.closes(1e-9));
        assert!(att.render().contains("lane"));
    }

    #[test]
    fn overlapping_busy_spans_still_close() {
        // Reduce span enclosing a train span must not double-count.
        let t = trace_of(
            vec![
                span(kind::TRAIN_STEP, 0, 1.0, 3.0),
                span(kind::REDUCE_POST, 0, 0.5, 3.5),
            ],
            4.0,
        );
        let att = attribute(&t);
        let l = att.lane(0).unwrap();
        assert!((l.train_s - 2.0).abs() < 1e-9);
        assert!((l.reduce_s - 1.0).abs() < 1e-9);
        assert!((l.other_s - 1.0).abs() < 1e-9);
        assert!(att.closes(1e-9));
    }

    #[test]
    fn lanes_are_attributed_independently() {
        let t = trace_of(
            vec![
                span(kind::TRAIN_STEP, 0, 0.0, 1.0),
                span(kind::TRAIN_STEP, 1, 0.0, 2.0),
                span(kind::PACK, 1, 2.0, 3.0),
            ],
            3.0,
        );
        let att = attribute(&t);
        assert_eq!(att.per_lane.len(), 2);
        assert!((att.lane(0).unwrap().train_s - 1.0).abs() < 1e-9);
        assert!((att.lane(0).unwrap().other_s - 2.0).abs() < 1e-9);
        assert!((att.lane(1).unwrap().etl_s - 1.0).abs() < 1e-9);
        assert!(att.closes(1e-9));
    }

    #[test]
    fn empty_trace_yields_no_lanes() {
        let att = attribute(&trace_of(vec![], 1.0));
        assert!(att.per_lane.is_empty());
        assert!(att.closes(0.01));
    }
}
