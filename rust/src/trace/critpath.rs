//! Stall-attribution critical-path analysis.
//!
//! For each device lane, walk the consumer's span chain backwards over
//! the traced wall time and attribute **every second to exactly one
//! cause**:
//!
//! * `train_s` — the lane's replica was stepping ([`kind::TRAIN_STEP`]).
//! * `reduce_s` — posting to or waiting on the reduce bus
//!   ([`kind::REDUCE_POST`] / [`kind::REDUCE_APPLY`]).
//! * `backpressure_s` — idle while the lane's producer was blocked on an
//!   arena credit ([`kind::SLOT_ACQUIRE`]): the consumer starved because
//!   staging had nowhere to put the next shard.
//! * `etl_s` — idle while the lane's ETL stage was packing
//!   ([`kind::PACK`], with its nested [`kind::FUSED_EXEC`]): compute-
//!   bound ETL on the critical path.
//! * `ingest_s` — idle while some ingest worker was reading
//!   ([`kind::INGEST_READ`]) and neither of the above: I/O-bound.
//! * `other_s` — idle with no traced cause in flight (startup ramp,
//!   scheduler latency, drain).
//!
//! The busy classes come from the consumer thread itself (sequential, so
//! the intervals are disjoint); its idle gaps are attributed by interval
//! intersection against the cause classes in the priority order above —
//! the same backwards walk as the paper's utilization argument, but as a
//! checked invariant: per lane, the six classes **sum to the traced wall
//! time** ([`LaneAttribution::closes`], default tolerance 1%).
//! `prop_trace.rs` pins closure under fuzzed schedules; ROADMAP item 3's
//! feedback controller reads this breakdown as its observation signal.

use super::{kind, Trace, LANE_NONE};

/// One lane's closed stall ledger (all fields in host seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LaneAttribution {
    pub lane: u32,
    /// The wall time this ledger partitions.
    pub wall_s: f64,
    pub train_s: f64,
    pub reduce_s: f64,
    pub etl_s: f64,
    pub ingest_s: f64,
    pub backpressure_s: f64,
    pub other_s: f64,
}

impl LaneAttribution {
    /// Sum of all attributed classes.
    pub fn attributed_s(&self) -> f64 {
        self.train_s + self.reduce_s + self.etl_s + self.ingest_s + self.backpressure_s
            + self.other_s
    }

    /// Does the ledger close: attributed ≡ wall within `tol` (relative)?
    ///
    /// A zero-wall-time lane (joined late, or drained before the window
    /// opened) has nothing to partition and closes **trivially** — the
    /// old formula divided the residual by a `1e-12` floor, so a lane
    /// with 0 wall but a nanosecond of clock-skewed attributed time
    /// failed its ledger by six orders of magnitude.
    pub fn closes(&self, tol: f64) -> bool {
        if !(self.wall_s > 1e-12) {
            return true;
        }
        ((self.attributed_s() - self.wall_s) / self.wall_s).abs() <= tol
    }
}

/// Per-lane stall attribution for a finished [`Trace`]
/// (`TrainReport::stall_attribution` when tracing is enabled).
#[derive(Debug, Clone, PartialEq)]
pub struct StallAttribution {
    pub per_lane: Vec<LaneAttribution>,
}

impl StallAttribution {
    /// Every lane's ledger closes within `tol`.
    pub fn closes(&self, tol: f64) -> bool {
        self.per_lane.iter().all(|l| l.closes(tol))
    }

    /// The attribution for one lane, if traced.
    pub fn lane(&self, lane: u32) -> Option<&LaneAttribution> {
        self.per_lane.iter().find(|l| l.lane == lane)
    }

    /// Human-readable table.
    pub fn render(&self) -> String {
        let mut s = String::from(
            "lane     wall_s    train    reduce      etl   ingest  backpr.    other\n",
        );
        for l in &self.per_lane {
            s.push_str(&format!(
                "{:<4} {:>9.4} {:>8.4} {:>9.4} {:>8.4} {:>8.4} {:>8.4} {:>8.4}\n",
                l.lane, l.wall_s, l.train_s, l.reduce_s, l.etl_s, l.ingest_s, l.backpressure_s,
                l.other_s
            ));
        }
        s
    }
}

/// Half-open interval set helpers (inputs need not be sorted).
fn normalize(mut v: Vec<(f64, f64)>) -> Vec<(f64, f64)> {
    v.retain(|(b, e)| e > b && b.is_finite() && e.is_finite());
    v.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
    let mut out: Vec<(f64, f64)> = Vec::with_capacity(v.len());
    for (b, e) in v {
        match out.last_mut() {
            Some(last) if b <= last.1 => last.1 = last.1.max(e),
            _ => out.push((b, e)),
        }
    }
    out
}

fn total(v: &[(f64, f64)]) -> f64 {
    v.iter().map(|(b, e)| e - b).sum()
}

/// `a \ b`; both normalized.
fn subtract(a: &[(f64, f64)], b: &[(f64, f64)]) -> Vec<(f64, f64)> {
    let mut out = Vec::new();
    for &(ab, ae) in a {
        let mut cur = ab;
        for &(bb, be) in b {
            if be <= cur {
                continue;
            }
            if bb >= ae {
                break;
            }
            if bb > cur {
                out.push((cur, bb.min(ae)));
            }
            cur = cur.max(be);
            if cur >= ae {
                break;
            }
        }
        if cur < ae {
            out.push((cur, ae));
        }
    }
    out
}

/// `a ∩ b`; both normalized.
fn intersect(a: &[(f64, f64)], b: &[(f64, f64)]) -> Vec<(f64, f64)> {
    let mut out = Vec::new();
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        let lo = a[i].0.max(b[j].0);
        let hi = a[i].1.min(b[j].1);
        if hi > lo {
            out.push((lo, hi));
        }
        if a[i].1 <= b[j].1 {
            i += 1;
        } else {
            j += 1;
        }
    }
    out
}

/// Clip every interval to `[lo, hi]`.
fn clip(v: Vec<(f64, f64)>, lo: f64, hi: f64) -> Vec<(f64, f64)> {
    v.into_iter()
        .map(|(b, e)| (b.max(lo), e.min(hi)))
        .filter(|(b, e)| e > b)
        .collect()
}

/// Incremental stall attributor: feed spans as they happen (any single
/// clock — host seconds for a finished [`Trace`], sim seconds for the
/// auto-tuner's pipeline model), then attribute any `[t0, t1)` window
/// with the same interval algebra as the post-run [`attribute`]. This is
/// the windowed form ROADMAP item 3's controller consumes: one call per
/// W-step window instead of one pass over the whole run.
///
/// Spans may arrive in any order and may straddle window boundaries —
/// each [`window`](Self::window) call clips to its bounds, so adjacent
/// windows partition a span's time exactly. Call
/// [`prune_before`](Self::prune_before) after evaluating a window to
/// bound memory over a long run (spans wholly before the cutoff can
/// never intersect a later window).
#[derive(Debug, Clone, Default)]
pub struct WindowAttributor {
    /// `(kind, lane, start_s, end_s)` on the caller's clock.
    spans: Vec<(u16, u32, f64, f64)>,
}

impl WindowAttributor {
    pub fn new() -> WindowAttributor {
        WindowAttributor { spans: Vec::new() }
    }

    /// Feed one span. `lane` may be [`LANE_NONE`] for shared causes
    /// (ingest workers serve every lane).
    pub fn add(&mut self, kind: u16, lane: u32, start_s: f64, end_s: f64) {
        self.spans.push((kind, lane, start_s, end_s));
    }

    /// Drop spans that end at or before `t_s` — they cannot intersect
    /// any window starting at or after it. Lanes whose every span is
    /// pruned disappear from subsequent windows.
    pub fn prune_before(&mut self, t_s: f64) {
        self.spans.retain(|&(_, _, _, e)| e > t_s);
    }

    /// Attribute the window `[t0, t1)` (see module docs): per lane the
    /// six classes partition `t1 - t0` and the ledger closes.
    pub fn window(&self, t0: f64, t1: f64) -> StallAttribution {
        let wall = (t1 - t0).max(0.0);
        let t1 = t0 + wall;

        // Lanes = lanes that stepped (or applied a reduce epoch).
        let mut lanes: Vec<u32> = self
            .spans
            .iter()
            .filter(|(k, l, _, _)| {
                *l != LANE_NONE
                    && matches!(*k, kind::TRAIN_STEP | kind::REDUCE_APPLY | kind::REDUCE_POST)
            })
            .map(|(_, l, _, _)| *l)
            .collect();
        lanes.sort_unstable();
        lanes.dedup();

        // Cause classes shared across lanes.
        let ingest_all = normalize(
            self.spans
                .iter()
                .filter(|(k, _, _, _)| *k == kind::INGEST_READ)
                .map(|&(_, _, b, e)| (b, e))
                .collect(),
        );

        let per_lane = lanes
            .into_iter()
            .map(|lane| {
                let of = |k: u16| -> Vec<(f64, f64)> {
                    self.spans
                        .iter()
                        .filter(|(sk, sl, _, _)| *sk == k && *sl == lane)
                        .map(|&(_, _, b, e)| (b, e))
                        .collect()
                };

                // Busy classes from the lane's (sequential) consumer thread.
                let train = clip(normalize(of(kind::TRAIN_STEP)), t0, t1);
                let reduce = clip(
                    normalize(
                        of(kind::REDUCE_POST)
                            .into_iter()
                            .chain(of(kind::REDUCE_APPLY))
                            .collect(),
                    ),
                    t0,
                    t1,
                );
                // REDUCE spans may nest around/within step boundaries on the
                // consumer thread; give TRAIN_STEP priority so busy classes
                // stay disjoint.
                let reduce = subtract(&reduce, &train);

                // Idle = window minus busy.
                let busy = normalize(train.iter().chain(reduce.iter()).copied().collect());
                let idle = subtract(&[(t0, t1)], &busy);

                // Attribute idle by cause, in priority order; each cause
                // consumes its overlap and passes the remainder on.
                let backpr = clip(normalize(of(kind::SLOT_ACQUIRE)), t0, t1);
                let idle_backpr = intersect(&idle, &backpr);
                let idle = subtract(&idle, &idle_backpr);

                let etl = clip(normalize(of(kind::PACK)), t0, t1);
                let idle_etl = intersect(&idle, &etl);
                let idle = subtract(&idle, &idle_etl);

                let idle_ingest = intersect(&idle, &clip(ingest_all.clone(), t0, t1));
                let idle = subtract(&idle, &idle_ingest);

                LaneAttribution {
                    lane,
                    wall_s: wall,
                    train_s: total(&train),
                    reduce_s: total(&reduce),
                    etl_s: total(&idle_etl),
                    ingest_s: total(&idle_ingest),
                    backpressure_s: total(&idle_backpr),
                    other_s: total(&idle),
                }
            })
            .collect();

        StallAttribution { per_lane }
    }
}

/// Compute the per-lane stall attribution for a trace (see module docs):
/// the whole-run window `[0, wall]` of a [`WindowAttributor`] fed every
/// traced span on the host clock.
pub fn attribute(trace: &Trace) -> StallAttribution {
    let wall = trace.wall_s.max(0.0);
    let mut w = WindowAttributor::new();
    for s in trace.spans() {
        w.add(s.kind, s.lane, s.host_start_s, s.host_end_s);
    }
    w.window(0.0, wall)
}

#[cfg(test)]
mod tests {
    use super::super::{Span, ThreadTrack};
    use super::*;

    fn span(kind: u16, lane: u32, b: f64, e: f64) -> Span {
        Span {
            kind,
            lane,
            key: 0,
            host_start_s: b,
            host_end_s: e,
            sim_start_s: f64::NAN,
            sim_end_s: f64::NAN,
            bytes: 0,
            retries: 0,
        }
    }

    fn trace_of(spans: Vec<Span>, wall_s: f64) -> Trace {
        Trace { tracks: vec![ThreadTrack { label: "t".into(), spans }], wall_s }
    }

    #[test]
    fn interval_algebra() {
        let a = normalize(vec![(3.0, 4.0), (0.0, 2.0), (1.0, 2.5)]);
        assert_eq!(a, vec![(0.0, 2.5), (3.0, 4.0)]);
        assert_eq!(subtract(&a, &[(1.0, 3.5)]), vec![(0.0, 1.0), (3.5, 4.0)]);
        assert_eq!(intersect(&a, &[(2.0, 3.5)]), vec![(2.0, 2.5), (3.0, 3.5)]);
        assert!(subtract(&a, &a).is_empty());
        assert!((total(&a) - 3.5).abs() < 1e-12);
    }

    #[test]
    fn ledger_partitions_wall_time_by_priority() {
        // wall [0,10): train [2,4), reduce [4,5);
        // idle [0,2) ∪ [5,10). Causes: slot_acquire [5,6),
        // pack [0,1) ∪ [5.5,8) (pack ∩ remaining idle = [0,1) ∪ [6,8)),
        // ingest [0,9) picks up [1,2) ∪ [8,9); other = [9,10).
        let t = trace_of(
            vec![
                span(kind::TRAIN_STEP, 0, 2.0, 4.0),
                span(kind::REDUCE_APPLY, 0, 4.0, 5.0),
                span(kind::SLOT_ACQUIRE, 0, 5.0, 6.0),
                span(kind::PACK, 0, 0.0, 1.0),
                span(kind::PACK, 0, 5.5, 8.0),
                span(kind::INGEST_READ, LANE_NONE, 0.0, 9.0),
            ],
            10.0,
        );
        let att = attribute(&t);
        let l = att.lane(0).unwrap();
        assert!((l.train_s - 2.0).abs() < 1e-9);
        assert!((l.reduce_s - 1.0).abs() < 1e-9);
        assert!((l.backpressure_s - 1.0).abs() < 1e-9);
        assert!((l.etl_s - 3.0).abs() < 1e-9);
        assert!((l.ingest_s - 2.0).abs() < 1e-9);
        assert!((l.other_s - 1.0).abs() < 1e-9);
        assert!(att.closes(1e-9));
        assert!(att.render().contains("lane"));
    }

    #[test]
    fn overlapping_busy_spans_still_close() {
        // Reduce span enclosing a train span must not double-count.
        let t = trace_of(
            vec![
                span(kind::TRAIN_STEP, 0, 1.0, 3.0),
                span(kind::REDUCE_POST, 0, 0.5, 3.5),
            ],
            4.0,
        );
        let att = attribute(&t);
        let l = att.lane(0).unwrap();
        assert!((l.train_s - 2.0).abs() < 1e-9);
        assert!((l.reduce_s - 1.0).abs() < 1e-9);
        assert!((l.other_s - 1.0).abs() < 1e-9);
        assert!(att.closes(1e-9));
    }

    #[test]
    fn lanes_are_attributed_independently() {
        let t = trace_of(
            vec![
                span(kind::TRAIN_STEP, 0, 0.0, 1.0),
                span(kind::TRAIN_STEP, 1, 0.0, 2.0),
                span(kind::PACK, 1, 2.0, 3.0),
            ],
            3.0,
        );
        let att = attribute(&t);
        assert_eq!(att.per_lane.len(), 2);
        assert!((att.lane(0).unwrap().train_s - 1.0).abs() < 1e-9);
        assert!((att.lane(0).unwrap().other_s - 2.0).abs() < 1e-9);
        assert!((att.lane(1).unwrap().etl_s - 1.0).abs() < 1e-9);
        assert!(att.closes(1e-9));
    }

    #[test]
    fn empty_trace_yields_no_lanes() {
        let att = attribute(&trace_of(vec![], 1.0));
        assert!(att.per_lane.is_empty());
        assert!(att.closes(0.01));
    }

    #[test]
    fn zero_wall_lane_closes_trivially() {
        // A lane that joined late or drained before the window opened
        // has zero wall time; a nanosecond of clock-skewed attributed
        // time must not fail the ledger (the old relative check divided
        // by a 1e-12 floor, blowing the residual up by ~1e3).
        let empty = LaneAttribution {
            lane: 3,
            wall_s: 0.0,
            train_s: 0.0,
            reduce_s: 0.0,
            etl_s: 0.0,
            ingest_s: 0.0,
            backpressure_s: 0.0,
            other_s: 0.0,
        };
        assert!(empty.closes(0.01), "empty lane must close trivially");
        let skewed = LaneAttribution { train_s: 1e-9, ..empty };
        assert!(skewed.closes(0.01), "zero-wall lane with skewed residual");

        // End-to-end: a degenerate window over a lane whose spans lie
        // entirely outside it yields wall 0 and still closes.
        let mut w = WindowAttributor::new();
        w.add(kind::TRAIN_STEP, 0, 1.0, 2.0);
        let att = w.window(5.0, 5.0);
        let l = att.lane(0).unwrap();
        assert_eq!(l.wall_s, 0.0);
        assert!(l.closes(0.01), "zero-wall window must close");
        assert!(att.closes(0.01));
    }

    #[test]
    fn whole_run_window_matches_post_run_attribution() {
        let spans = vec![
            span(kind::TRAIN_STEP, 0, 2.0, 4.0),
            span(kind::REDUCE_APPLY, 0, 4.0, 5.0),
            span(kind::SLOT_ACQUIRE, 0, 5.0, 6.0),
            span(kind::PACK, 0, 0.0, 1.0),
            span(kind::PACK, 0, 5.5, 8.0),
            span(kind::INGEST_READ, LANE_NONE, 0.0, 9.0),
        ];
        let post = attribute(&trace_of(spans.clone(), 10.0));
        let mut w = WindowAttributor::new();
        for s in &spans {
            w.add(s.kind, s.lane, s.host_start_s, s.host_end_s);
        }
        assert_eq!(w.window(0.0, 10.0), post, "window(0, wall) ≡ attribute()");
    }

    #[test]
    fn adjacent_windows_partition_a_straddling_run() {
        // Each class, summed over the two half-windows, equals its
        // whole-run value — spans straddling the boundary (the train
        // span [2,4) vs boundary 3) are split exactly, never dropped or
        // double-counted.
        let mut w = WindowAttributor::new();
        w.add(kind::TRAIN_STEP, 0, 2.0, 4.0);
        w.add(kind::PACK, 0, 0.0, 1.5);
        w.add(kind::SLOT_ACQUIRE, 0, 4.5, 5.5);
        w.add(kind::INGEST_READ, LANE_NONE, 0.0, 6.0);
        let whole = w.window(0.0, 6.0);
        let (a, b) = (w.window(0.0, 3.0), w.window(3.0, 6.0));
        let (wl, al, bl) = (whole.lane(0).unwrap(), a.lane(0).unwrap(), b.lane(0).unwrap());
        for (w_v, a_v, b_v, name) in [
            (wl.train_s, al.train_s, bl.train_s, "train"),
            (wl.etl_s, al.etl_s, bl.etl_s, "etl"),
            (wl.backpressure_s, al.backpressure_s, bl.backpressure_s, "backpr"),
            (wl.ingest_s, al.ingest_s, bl.ingest_s, "ingest"),
            (wl.other_s, al.other_s, bl.other_s, "other"),
        ] {
            assert!((a_v + b_v - w_v).abs() < 1e-9, "{name}: {a_v} + {b_v} != {w_v}");
        }
        assert!(a.closes(1e-9) && b.closes(1e-9) && whole.closes(1e-9));
    }

    #[test]
    fn prune_drops_only_spans_before_the_cutoff() {
        let mut w = WindowAttributor::new();
        w.add(kind::TRAIN_STEP, 0, 0.0, 1.0);
        w.add(kind::TRAIN_STEP, 0, 2.0, 4.0);
        let before = w.window(2.0, 4.0);
        w.prune_before(2.0);
        assert_eq!(w.window(2.0, 4.0), before, "later windows unaffected");
        // The lane's only remaining span gone → lane disappears.
        w.prune_before(4.0);
        assert!(w.window(4.0, 5.0).per_lane.is_empty());
    }
}
