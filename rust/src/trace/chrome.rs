//! Chrome `trace_event` JSON export and validation.
//!
//! [`to_chrome_json`] renders a [`Trace`] in the Chrome trace-event
//! format (the JSON-object flavor: `{"traceEvents": [...]}`) loadable in
//! `chrome://tracing` and <https://ui.perfetto.dev>. Layout:
//!
//! * **pid 1, "host"** — one tid per recorded thread track, named by its
//!   [`set_thread_label`](super::set_thread_label) label; spans are B/E
//!   duration pairs on the host clock (µs since install).
//! * **pid 2, "sim"** — one tid per (lane, kind) pair of sim-stamped
//!   spans (`lane0/pack`, `lane0/dma_transfer`, …). Sim clocks of
//!   different kinds on a lane are independent (ETL clock vs DMA engine
//!   clock), so giving each its own track keeps every track's B/E pairs
//!   properly nested.
//!
//! Event `args` carry the span identity (`lane`, `key`) and annotations
//! (`bytes`, `retries`). The crate is dependency-free, so both the
//! writer and the validating reader ([`validate_chrome_trace`]) are
//! hand-rolled; the validator checks exactly what CI's `trace-validate`
//! step needs — well-formed JSON, required event fields, monotone
//! per-track timestamps, and balanced name-matched B/E pairs.

use super::{kind, Span, Trace, LANE_NONE};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Render a trace as Chrome trace-event JSON (see module docs).
pub fn to_chrome_json(trace: &Trace) -> String {
    let mut out = String::with_capacity(256 + trace.span_count() * 160);
    out.push_str("{\"traceEvents\":[");
    let mut first = true;
    let mut meta = |out: &mut String, first: &mut bool, name: &str, pid: u32, tid: u32, arg: &str| {
        sep(out, first);
        let _ = write!(
            out,
            "{{\"name\":\"{name}\",\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\"ts\":0,\"args\":{{\"name\":\"{}\"}}}}",
            escape(arg)
        );
    };

    meta(&mut out, &mut first, "process_name", 1, 0, "host");
    meta(&mut out, &mut first, "process_name", 2, 0, "sim");

    // Host tracks: one tid per thread.
    for (i, track) in trace.tracks.iter().enumerate() {
        let tid = i as u32 + 1;
        meta(&mut out, &mut first, "thread_name", 1, tid, &track.label);
        emit_track(&mut out, &mut first, 1, tid, &track.spans, |s| {
            (s.host_start_s, s.host_end_s)
        });
    }

    // Sim tracks: one tid per (lane, kind), deterministic order.
    let mut sim: BTreeMap<(u32, u16), Vec<Span>> = BTreeMap::new();
    for s in trace.spans() {
        if s.has_sim() {
            sim.entry((s.lane, s.kind)).or_default().push(*s);
        }
    }
    for (i, ((lane, k), spans)) in sim.into_iter().enumerate() {
        let tid = i as u32 + 1;
        let label = if lane == LANE_NONE {
            format!("sim/{}", kind::name(k))
        } else {
            format!("lane{lane}/{}", kind::name(k))
        };
        meta(&mut out, &mut first, "thread_name", 2, tid, &label);
        emit_track(&mut out, &mut first, 2, tid, &spans, |s| {
            (s.sim_start_s, s.sim_end_s)
        });
    }

    out.push_str("],\"displayTimeUnit\":\"ms\"}");
    out
}

fn sep(out: &mut String, first: &mut bool) {
    if *first {
        *first = false;
    } else {
        out.push(',');
    }
}

/// Emit one track's spans as properly nested B/E duration pairs with
/// non-decreasing timestamps.
///
/// Spans on a track are either disjoint or nested (they come from
/// sequential stage code, or from a monotone sim clock), but they arrive
/// in end-time order. Sort by (start asc, end desc) so parents precede
/// children, then walk with an explicit stack: before opening the next
/// span, close every stacked span that ends at or before its start.
fn emit_track<F>(out: &mut String, first: &mut bool, pid: u32, tid: u32, spans: &[Span], clock: F)
where
    F: Fn(&Span) -> (f64, f64),
{
    let mut ordered: Vec<(f64, f64, &Span)> = spans
        .iter()
        .map(|s| {
            let (b, e) = clock(s);
            (b, e, s)
        })
        .collect();
    ordered.sort_by(|a, b| {
        a.0.partial_cmp(&b.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal))
    });
    // (end_s, name) of currently open spans.
    let mut stack: Vec<(f64, &'static str)> = Vec::new();
    for (b, e, s) in ordered {
        while let Some(&(open_end, name)) = stack.last() {
            if open_end <= b {
                emit_end(out, first, pid, tid, name, open_end);
                stack.pop();
            } else {
                break;
            }
        }
        // Overlap without nesting can't come from well-formed stage code,
        // but clamp defensively so the output still validates: treat the
        // enclosing open span's end as this span's cap.
        let e = match stack.last() {
            Some(&(open_end, _)) => e.min(open_end),
            None => e,
        };
        let name = kind::name(s.kind);
        sep(out, first);
        let lane = if s.lane == LANE_NONE { -1i64 } else { s.lane as i64 };
        let _ = write!(
            out,
            "{{\"name\":\"{name}\",\"ph\":\"B\",\"pid\":{pid},\"tid\":{tid},\"ts\":{:.3},\"args\":{{\"lane\":{lane},\"key\":{},\"bytes\":{},\"retries\":{}}}}}",
            b * 1e6,
            s.key,
            s.bytes,
            s.retries
        );
        stack.push((e.max(b), name));
    }
    while let Some((open_end, name)) = stack.pop() {
        emit_end(out, first, pid, tid, name, open_end);
    }
}

fn emit_end(out: &mut String, first: &mut bool, pid: u32, tid: u32, name: &str, end_s: f64) {
    sep(out, first);
    let _ = write!(
        out,
        "{{\"name\":\"{name}\",\"ph\":\"E\",\"pid\":{pid},\"tid\":{tid},\"ts\":{:.3}}}",
        end_s * 1e6
    );
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

// ---------------------------------------------------------------------
// Minimal JSON reader (the crate is dependency-free) + trace validator.

/// A parsed JSON value — just enough for validating exported traces.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_num(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse a JSON document. Strict enough for round-tripping our own
/// exports and the bench files; not a general-purpose parser.
pub fn parse_json(text: &str) -> Result<JsonValue, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> Result<u8, String> {
        self.skip_ws();
        self.bytes.get(self.pos).copied().ok_or_else(|| "unexpected end of input".into())
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek()? != b {
            return Err(format!("expected '{}' at byte {}", b as char, self.pos));
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<JsonValue, String> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(JsonValue::Str(self.string()?)),
            b't' => self.lit("true", JsonValue::Bool(true)),
            b'f' => self.lit("false", JsonValue::Bool(false)),
            b'n' => self.lit("null", JsonValue::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: JsonValue) -> Result<JsonValue, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<JsonValue, String> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(JsonValue::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = *self
                .bytes
                .get(self.pos)
                .ok_or_else(|| "unterminated string".to_string())?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let esc = *self
                        .bytes
                        .get(self.pos)
                        .ok_or_else(|| "unterminated escape".to_string())?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .ok_or_else(|| "bad \\u escape".to_string())?;
                            self.pos += 4;
                            out.push(char::from_u32(hex).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos - 1)),
                    }
                }
                _ => {
                    // Re-sync to char boundary for multi-byte UTF-8.
                    let rest = &self.bytes[self.pos - 1..];
                    let s = std::str::from_utf8(rest).map_err(|_| "invalid utf8".to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8() - 1;
                }
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(JsonValue::Arr(out));
        }
        loop {
            out.push(self.value()?);
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(JsonValue::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<JsonValue, String> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(JsonValue::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.expect(b':')?;
            out.push((key, self.value()?));
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(JsonValue::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

/// Summary of a validated trace document.
#[derive(Debug, Clone, PartialEq)]
pub struct ChromeStats {
    /// Total events, including metadata.
    pub events: usize,
    /// Completed B/E duration pairs.
    pub duration_pairs: usize,
    /// Distinct (pid, tid) tracks carrying duration events.
    pub tracks: usize,
}

/// Validate a Chrome trace-event JSON document against the invariants
/// the format requires to load cleanly: a `traceEvents` array, each
/// event carrying `name`/`ph`/`pid`/`tid` (+ numeric `ts` for B/E),
/// non-decreasing timestamps per (pid, tid) track, and balanced B/E
/// pairs whose names match LIFO.
pub fn validate_chrome_trace(text: &str) -> Result<ChromeStats, String> {
    let doc = parse_json(text)?;
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| "missing traceEvents array".to_string())?;

    // per-track: (last ts, open B-name stack)
    let mut tracks: BTreeMap<(i64, i64), (f64, Vec<String>)> = BTreeMap::new();
    let mut pairs = 0usize;
    for (i, ev) in events.iter().enumerate() {
        let name = ev
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("event {i}: missing name"))?;
        let ph = ev
            .get("ph")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("event {i}: missing ph"))?;
        let pid = ev
            .get("pid")
            .and_then(|v| v.as_num())
            .ok_or_else(|| format!("event {i}: missing pid"))? as i64;
        let tid = ev
            .get("tid")
            .and_then(|v| v.as_num())
            .ok_or_else(|| format!("event {i}: missing tid"))? as i64;
        if ph == "M" {
            continue;
        }
        if ph != "B" && ph != "E" {
            return Err(format!("event {i}: unsupported ph {ph:?}"));
        }
        let ts = ev
            .get("ts")
            .and_then(|v| v.as_num())
            .ok_or_else(|| format!("event {i}: missing ts"))?;
        if !ts.is_finite() {
            return Err(format!("event {i}: non-finite ts"));
        }
        let entry = tracks.entry((pid, tid)).or_insert((f64::NEG_INFINITY, Vec::new()));
        if ts < entry.0 {
            return Err(format!(
                "event {i}: ts {ts} < previous {} on track ({pid},{tid})",
                entry.0
            ));
        }
        entry.0 = ts;
        match ph {
            "B" => entry.1.push(name.to_string()),
            _ => {
                let open = entry
                    .1
                    .pop()
                    .ok_or_else(|| format!("event {i}: E without open B on ({pid},{tid})"))?;
                if open != name {
                    return Err(format!(
                        "event {i}: E name {name:?} does not match open B {open:?}"
                    ));
                }
                pairs += 1;
            }
        }
    }
    for ((pid, tid), (_, stack)) in &tracks {
        if !stack.is_empty() {
            return Err(format!(
                "track ({pid},{tid}): {} unclosed B event(s): {stack:?}",
                stack.len()
            ));
        }
    }
    Ok(ChromeStats { events: events.len(), duration_pairs: pairs, tracks: tracks.len() })
}

#[cfg(test)]
mod tests {
    use super::super::{ThreadTrack, Trace};
    use super::*;

    fn span(kind: u16, lane: u32, b: f64, e: f64, sim: Option<(f64, f64)>) -> Span {
        Span {
            kind,
            lane,
            key: 0,
            host_start_s: b,
            host_end_s: e,
            sim_start_s: sim.map_or(f64::NAN, |s| s.0),
            sim_end_s: sim.map_or(f64::NAN, |s| s.1),
            bytes: 0,
            retries: 0,
        }
    }

    fn sample_trace() -> Trace {
        Trace {
            tracks: vec![
                ThreadTrack {
                    label: "pack-0".into(),
                    spans: vec![
                        // fused_exec nested inside pack
                        span(kind::FUSED_EXEC, LANE_NONE, 0.11, 0.18, None),
                        span(kind::PACK, 0, 0.1, 0.2, Some((0.0, 0.4))),
                        span(kind::DMA_TRANSFER, 0, 0.2, 0.25, Some((0.4, 0.9))),
                    ],
                },
                ThreadTrack {
                    label: "consumer-0".into(),
                    spans: vec![
                        span(kind::TRAIN_STEP, 0, 0.3, 0.5, None),
                        span(kind::TRAIN_STEP, 0, 0.5, 0.7, None),
                    ],
                },
            ],
            wall_s: 1.0,
        }
    }

    #[test]
    fn export_round_trips_through_validator() {
        let json = to_chrome_json(&sample_trace());
        let stats = validate_chrome_trace(&json).expect("export must validate");
        // 5 spans → 5 duration pairs across host + sim tracks:
        // host pack-0 (3), host consumer-0 (2), sim lane0/pack (1),
        // sim lane0/dma_transfer (1) → 7 pairs total.
        assert_eq!(stats.duration_pairs, 7);
        assert_eq!(stats.tracks, 4);
        // Thread names present for Perfetto.
        assert!(json.contains("\"thread_name\""));
        assert!(json.contains("lane0/pack"));
        assert!(json.contains("lane0/dma_transfer"));
    }

    #[test]
    fn validator_rejects_malformed_traces() {
        assert!(validate_chrome_trace("not json").is_err());
        assert!(validate_chrome_trace("{\"noTraceEvents\":1}").is_err());
        // E without B
        let bad = "{\"traceEvents\":[{\"name\":\"x\",\"ph\":\"E\",\"pid\":1,\"tid\":1,\"ts\":1}]}";
        assert!(validate_chrome_trace(bad).is_err());
        // non-monotone ts on one track
        let bad = "{\"traceEvents\":[\
            {\"name\":\"x\",\"ph\":\"B\",\"pid\":1,\"tid\":1,\"ts\":5},\
            {\"name\":\"x\",\"ph\":\"E\",\"pid\":1,\"tid\":1,\"ts\":4}]}";
        assert!(validate_chrome_trace(bad).is_err());
        // mismatched B/E names
        let bad = "{\"traceEvents\":[\
            {\"name\":\"x\",\"ph\":\"B\",\"pid\":1,\"tid\":1,\"ts\":1},\
            {\"name\":\"y\",\"ph\":\"E\",\"pid\":1,\"tid\":1,\"ts\":2}]}";
        assert!(validate_chrome_trace(bad).is_err());
        // unclosed B
        let bad = "{\"traceEvents\":[{\"name\":\"x\",\"ph\":\"B\",\"pid\":1,\"tid\":1,\"ts\":1}]}";
        assert!(validate_chrome_trace(bad).is_err());
    }

    #[test]
    fn parser_handles_escapes_and_numbers() {
        let v = parse_json("{\"a\\n\":[1,-2.5e3,true,null,\"\\u0041\"]}").unwrap();
        let arr = v.get("a\n").and_then(|x| x.as_arr()).unwrap();
        assert_eq!(arr[0].as_num(), Some(1.0));
        assert_eq!(arr[1].as_num(), Some(-2500.0));
        assert_eq!(arr[4].as_str(), Some("A"));
        assert!(parse_json("[1,]").is_err());
        assert!(parse_json("{\"a\":1} trailing").is_err());
    }
}
