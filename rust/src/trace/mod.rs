//! End-to-end pipeline tracing: deterministic dual-clock spans over every
//! stage of the ingest → exec → pack → slot → DMA → train → reduce chain.
//!
//! The recorder is install-guarded in the style of [`crate::util::sched`]
//! and [`crate::util::fault`]: a probe ([`begin`]) costs **one relaxed
//! atomic load** when no trace is installed (pinned by the
//! `trace-overhead` section of the hotpath bench), and recording is
//! **enrollment-scoped** — each [`install`] opens a fresh epoch, enrolls
//! the installing thread, and only threads carrying that epoch's token
//! ([`enroll_token`]/[`enroll`]) record spans, so concurrently running
//! untraced tests stay invisible to an installed trace and vice versa.
//! Spans land in **lock-free per-thread buffers** (a plain thread-local
//! `Vec` — no synchronization on the record path) that flush into the
//! global sink when the thread exits or the trace is finished.
//!
//! # Span taxonomy
//!
//! | kind | stage | sim clock | key |
//! |------|-------|-----------|-----|
//! | `IngestRead` | ingest worker producing one shard | — | shard index |
//! | `FusedExec` | fused engine apply+pack execution | — | rows |
//! | `Pack` | lane stage: shard → staged arena slot | lane ETL clock | lane shard ordinal |
//! | `SlotAcquire` | producer blocked on an arena credit | — | lane shard ordinal |
//! | `DmaTransfer` | chunked P2P write on the lane engine | lane DMA clock | transfer ordinal |
//! | `PrefetchCommit` | embedding hot-set commit for a slot | lane DMA clock | slot ordinal |
//! | `TrainStep` | one trainer step on a device replica | — | global step |
//! | `ReducePost` | posting a gradient contribution | — | run-relative step |
//! | `ReduceApply` | waiting for + folding a reduce epoch | — | epoch index |
//!
//! # The dual-clock convention
//!
//! Every span is stamped on the **host wall clock** (seconds since the
//! trace was installed; `host_start_s`/`host_end_s`). Spans whose stage
//! runs on a simulated clock — the paper's FPGA ETL clock ([`kind::PACK`],
//! cumulative per lane) and the per-device DMA engine clock
//! ([`kind::DMA_TRANSFER`], [`kind::PREFETCH_COMMIT`]) — additionally
//! carry a **sim interval** (`sim_start_s`/`sim_end_s`); host-native
//! stages carry `NaN` there. Host stamps vary run to run; the sim
//! timeline ([`Trace::sim_timeline`]) is a pure function of the config
//! for deterministic setups (round-robin routing, in-order ingest), so
//! `rust/tests/prop_trace.rs` replays it bitwise under fuzzed schedules.
//! Spans also carry fault/retry annotations: `retries` counts re-issued
//! attempts (DMA re-submits, ingest read retries) behind the span.
//!
//! # Reading a 2-lane Chrome trace (worked example)
//!
//! Run `cargo run --release --example end_to_end_training -- --devices 2
//! --trace trace.json` (or pass `--trace` to the `e2e_training` bench)
//! and load the file in `chrome://tracing` or <https://ui.perfetto.dev>.
//! Two process groups appear:
//!
//! * **host** — one track per thread: `router`, `ingest-w0/1`, `pack-0`,
//!   `pack-1`, `consumer-0`, `consumer-1`. On `pack-0` each shard shows
//!   `slot_acquire` (credit wait) → `pack` (with the nested `fused_exec`
//!   engine span) → `dma_transfer` (submit). On `consumer-0`, rows of
//!   `train_step` alternate with `reduce_post`/`reduce_apply`; a gap
//!   between two `train_step`s that lines up with a `pack` on `pack-0` is
//!   ETL starvation, one that lines up with nothing is ingest/startup.
//! * **sim** — per-lane simulated-clock tracks (`lane0/pack`,
//!   `lane0/dma_transfer`, …): the paper's overlap picture. When the DMA
//!   spans on `lane0/dma_transfer` start later than their `pack` spans
//!   end, the engine clock (not the ETL clock) is the bottleneck.
//!
//! The same gap analysis, automated and summed per lane, is
//! [`Trace::stall_attribution`] — its ledger **closes**: per lane, the
//! attributed causes sum to the traced wall time (a checked invariant,
//! tolerance 1%), which is what turns the report's disjoint wait counters
//! into an auditable breakdown. `TrainReport::stall_attribution` carries
//! it when [`crate::coordinator::TrainConfig::trace`] is set, and ROADMAP
//! item 3's feedback controller consumes it as the observation signal.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

pub mod chrome;
pub mod critpath;

pub use critpath::{LaneAttribution, StallAttribution, WindowAttributor};

/// Typed span kinds (`Span::kind`). Stable small integers so per-kind
/// live counters are a flat array.
pub mod kind {
    /// Ingest worker producing one shard (key = shard index).
    pub const INGEST_READ: u16 = 1;
    /// Fused engine apply+pack execution (host-side, key = rows).
    pub const FUSED_EXEC: u16 = 2;
    /// Stage-level shard → staged slot on a lane, sim-stamped on the
    /// lane's cumulative ETL clock (key = lane shard ordinal).
    pub const PACK: u16 = 3;
    /// Producer blocked acquiring an arena slot credit.
    pub const SLOT_ACQUIRE: u16 = 4;
    /// Chunked P2P DMA, sim-stamped on the device engine clock (key =
    /// engine transfer ordinal; `retries` = re-issued attempts).
    pub const DMA_TRANSFER: u16 = 5;
    /// Embedding hot-set promotion/commit for one staged slot.
    pub const PREFETCH_COMMIT: u16 = 6;
    /// One trainer step on a device replica (key = absolute global step).
    pub const TRAIN_STEP: u16 = 7;
    /// Posting a gradient contribution to the reduce bus.
    pub const REDUCE_POST: u16 = 8;
    /// Waiting for and folding a resolved reduce epoch (key = epoch).
    pub const REDUCE_APPLY: u16 = 9;
    /// A joining lane admitted to the live fleet at a quiesce point
    /// (key = routed-chunk frontier at admission).
    pub const LANE_JOIN: u16 = 10;
    /// A live lane scripted out of the fleet: its shard channel closes
    /// and it drains in-flight slots (key = routed-chunk frontier).
    pub const LANE_DRAIN: u16 = 11;

    pub(crate) const MAX: usize = 12;

    /// Human-readable kind name (Chrome event names, snapshot rows).
    pub fn name(k: u16) -> &'static str {
        match k {
            INGEST_READ => "ingest_read",
            FUSED_EXEC => "fused_exec",
            PACK => "pack",
            SLOT_ACQUIRE => "slot_acquire",
            DMA_TRANSFER => "dma_transfer",
            PREFETCH_COMMIT => "prefetch_commit",
            TRAIN_STEP => "train_step",
            REDUCE_POST => "reduce_post",
            REDUCE_APPLY => "reduce_apply",
            LANE_JOIN => "lane_join",
            LANE_DRAIN => "lane_drain",
            _ => "unknown",
        }
    }
}

/// `Span::lane` value for spans not owned by a device lane (ingest
/// workers, the fused engine).
pub const LANE_NONE: u32 = u32::MAX;

/// One recorded span: a typed stage interval on the host clock, with an
/// optional simulated-clock interval and I/O annotations (see the module
/// docs for the taxonomy and the dual-clock convention).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    /// One of the [`kind`] constants.
    pub kind: u16,
    /// Device lane the stage ran for, or [`LANE_NONE`].
    pub lane: u32,
    /// Stable identity within (lane, kind): shard index, step, ordinal.
    pub key: u64,
    /// Host seconds since the trace was installed.
    pub host_start_s: f64,
    pub host_end_s: f64,
    /// Simulated-clock interval; `NaN` for host-native stages.
    pub sim_start_s: f64,
    pub sim_end_s: f64,
    /// Payload bytes behind the span (0 when not applicable).
    pub bytes: u64,
    /// Fault/retry annotation: re-issued attempts folded into this span.
    pub retries: u32,
}

impl Span {
    /// Host duration in seconds.
    pub fn host_dur_s(&self) -> f64 {
        (self.host_end_s - self.host_start_s).max(0.0)
    }

    /// Does this span carry a simulated-clock interval?
    pub fn has_sim(&self) -> bool {
        self.sim_start_s.is_finite() && self.sim_end_s.is_finite()
    }
}

/// All spans recorded by one thread, in record (end-time) order.
#[derive(Debug, Clone)]
pub struct ThreadTrack {
    /// Label set by [`set_thread_label`], or the thread id's debug form.
    pub label: String,
    pub spans: Vec<Span>,
}

// ---------------------------------------------------------------------
// Global recorder state (install-guarded, mirror of util::fault).

static ACTIVE: AtomicBool = AtomicBool::new(false);
/// Epoch token of the installed trace (0 = none).
static CURRENT: AtomicU64 = AtomicU64::new(0);
static NEXT_EPOCH: AtomicU64 = AtomicU64::new(0);
static INSTALL_LOCK: Mutex<()> = Mutex::new(());
static STATE: Mutex<Option<TraceState>> = Mutex::new(None);
static SINK: Mutex<Sink> = Mutex::new(Sink { epoch: 0, tracks: Vec::new() });

/// Live per-kind counters for [`snapshot`]: span counts and host ns.
static LIVE_COUNT: [AtomicU64; kind::MAX] = [const { AtomicU64::new(0) }; kind::MAX];
static LIVE_NS: [AtomicU64; kind::MAX] = [const { AtomicU64::new(0) }; kind::MAX];

struct TraceState {
    epoch: u64,
    t0: Instant,
}

struct Sink {
    epoch: u64,
    tracks: Vec<ThreadTrack>,
}

thread_local! {
    /// Epoch token this thread is enrolled under (0 = never enrolled).
    static ENROLLED: Cell<u64> = const { Cell::new(0) };
    static LOCAL: RefCell<LocalBuf> =
        RefCell::new(LocalBuf { epoch: 0, t0: None, label: None, spans: Vec::new() });
}

/// Per-thread span buffer. Dropping it (thread exit) flushes whatever the
/// trace hasn't collected yet into the global sink.
struct LocalBuf {
    epoch: u64,
    t0: Option<Instant>,
    label: Option<String>,
    spans: Vec<Span>,
}

impl LocalBuf {
    fn flush(&mut self) {
        if self.spans.is_empty() {
            return;
        }
        let spans = std::mem::take(&mut self.spans);
        let mut sink = SINK.lock().unwrap_or_else(|p| p.into_inner());
        // Stale buffers (their trace already finished) are discarded.
        if sink.epoch != 0 && sink.epoch == self.epoch {
            let label = self
                .label
                .clone()
                .unwrap_or_else(|| format!("{:?}", std::thread::current().id()));
            sink.tracks.push(ThreadTrack { label, spans });
        }
    }
}

impl Drop for LocalBuf {
    fn drop(&mut self) {
        self.flush();
    }
}

/// The calling thread's enrollment token — capture before spawning a
/// worker and hand to [`enroll`] inside, so the trace covering the
/// spawner covers its fleet (same protocol as `util::fault`).
pub fn enroll_token() -> u64 {
    ENROLLED.with(|c| c.get())
}

/// Adopt a spawner's enrollment token on this thread (0 un-enrolls).
pub fn enroll(token: u64) {
    ENROLLED.with(|c| c.set(token));
}

/// Name this thread's track in the exported trace ("pack-0", "router").
/// Cheap and unconditional — call once per thread.
pub fn set_thread_label(label: &str) {
    LOCAL.with(|l| l.borrow_mut().label = Some(label.to_string()));
}

/// Is a trace currently installed?
pub fn is_active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Install a trace until [`TraceGuard::finish`] (or drop). Serializes on
/// a process-global lock — concurrently running traced tests queue here
/// instead of mixing spans. The installing thread is enrolled; threads it
/// spawns through the library's spawn points inherit enrollment.
pub fn install() -> TraceGuard {
    let serial = INSTALL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let epoch = NEXT_EPOCH.fetch_add(1, Ordering::SeqCst) + 1;
    let t0 = Instant::now();
    {
        let mut st = STATE.lock().unwrap_or_else(|p| p.into_inner());
        *st = Some(TraceState { epoch, t0 });
    }
    {
        let mut sink = SINK.lock().unwrap_or_else(|p| p.into_inner());
        sink.epoch = epoch;
        sink.tracks.clear();
    }
    for k in 0..kind::MAX {
        LIVE_COUNT[k].store(0, Ordering::Relaxed);
        LIVE_NS[k].store(0, Ordering::Relaxed);
    }
    ENROLLED.with(|c| c.set(epoch));
    CURRENT.store(epoch, Ordering::SeqCst);
    ACTIVE.store(true, Ordering::SeqCst);
    TraceGuard { serial: Some(serial), epoch, t0 }
}

/// RAII handle for an installed trace: [`finish`](Self::finish) collects
/// the recorded tracks; dropping without finishing discards them.
pub struct TraceGuard {
    serial: Option<MutexGuard<'static, ()>>,
    epoch: u64,
    t0: Instant,
}

impl TraceGuard {
    /// Stop recording and collect every enrolled thread's spans. Threads
    /// that exited already flushed through their buffer's destructor;
    /// the calling thread flushes here.
    pub fn finish(mut self) -> Trace {
        let wall_s = self.t0.elapsed().as_secs_f64();
        self.deactivate();
        LOCAL.with(|l| l.borrow_mut().flush());
        let tracks = {
            let mut sink = SINK.lock().unwrap_or_else(|p| p.into_inner());
            let tracks = std::mem::take(&mut sink.tracks);
            sink.epoch = 0;
            tracks
        };
        self.serial = None;
        Trace { tracks, wall_s }
    }

    fn deactivate(&mut self) {
        ACTIVE.store(false, Ordering::SeqCst);
        CURRENT.store(0, Ordering::SeqCst);
        let mut st = STATE.lock().unwrap_or_else(|p| p.into_inner());
        *st = None;
    }
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        if self.serial.is_some() {
            // finish() was never called: discard instead of leaking into
            // the next install's sink.
            self.deactivate();
            let mut sink = SINK.lock().unwrap_or_else(|p| p.into_inner());
            if sink.epoch == self.epoch {
                sink.epoch = 0;
                sink.tracks.clear();
            }
        }
    }
}

// ---------------------------------------------------------------------
// Probe API.

/// An open span. Obtained from [`begin`]; closed by one of the `end*`
/// methods (or by drop, which records a host-only span) — so every probe
/// records exactly one balanced interval even on error paths.
pub struct SpanGuard {
    state: Option<Open>,
}

struct Open {
    kind: u16,
    lane: u32,
    key: u64,
    t0: Instant,
    start_s: f64,
}

/// Open a span of `kind` for `lane`/`key`. One relaxed atomic load when
/// no trace is installed; when installed, records only on enrolled
/// threads.
#[inline]
pub fn begin(kind: u16, lane: u32, key: u64) -> SpanGuard {
    if !ACTIVE.load(Ordering::Relaxed) {
        return SpanGuard { state: None };
    }
    begin_slow(kind, lane, key)
}

#[cold]
fn begin_slow(kind: u16, lane: u32, key: u64) -> SpanGuard {
    let token = ENROLLED.with(|c| c.get());
    if token == 0 || token != CURRENT.load(Ordering::Relaxed) {
        return SpanGuard { state: None };
    }
    // Sync this thread's buffer to the installed epoch (fetches the
    // trace's time base once per thread per install).
    let t0 = LOCAL.with(|l| {
        let mut buf = l.borrow_mut();
        if buf.epoch != token {
            buf.flush();
            let st = STATE.lock().unwrap_or_else(|p| p.into_inner());
            let Some(st) = st.as_ref() else { return None };
            if st.epoch != token {
                return None;
            }
            buf.epoch = token;
            buf.t0 = Some(st.t0);
        }
        buf.t0
    });
    let Some(t0) = t0 else { return SpanGuard { state: None } };
    SpanGuard {
        state: Some(Open { kind, lane, key, t0, start_s: t0.elapsed().as_secs_f64() }),
    }
}

impl SpanGuard {
    /// Is this guard recording (trace installed + thread enrolled)?
    pub fn is_armed(&self) -> bool {
        self.state.is_some()
    }

    /// Close as a host-only span.
    #[inline]
    pub fn end(mut self) {
        self.close(f64::NAN, f64::NAN, 0, 0);
    }

    /// Close as a host-only span with a byte annotation.
    #[inline]
    pub fn end_bytes(mut self, bytes: u64) {
        self.close(f64::NAN, f64::NAN, bytes, 0);
    }

    /// Close with a simulated-clock interval.
    #[inline]
    pub fn end_sim(mut self, sim_start_s: f64, sim_end_s: f64) {
        self.close(sim_start_s, sim_end_s, 0, 0);
    }

    /// Close with a sim interval plus I/O and retry annotations.
    #[inline]
    pub fn end_io(mut self, sim_start_s: f64, sim_end_s: f64, bytes: u64, retries: u32) {
        self.close(sim_start_s, sim_end_s, bytes, retries);
    }

    /// Close as host-only with a retry annotation (failed attempts).
    #[inline]
    pub fn end_retries(mut self, retries: u32) {
        self.close(f64::NAN, f64::NAN, 0, retries);
    }

    fn close(&mut self, sim_start_s: f64, sim_end_s: f64, bytes: u64, retries: u32) {
        let Some(open) = self.state.take() else { return };
        let end_s = open.t0.elapsed().as_secs_f64();
        let span = Span {
            kind: open.kind,
            lane: open.lane,
            key: open.key,
            host_start_s: open.start_s,
            host_end_s: end_s,
            sim_start_s,
            sim_end_s,
            bytes,
            retries,
        };
        let k = (open.kind as usize).min(kind::MAX - 1);
        LIVE_COUNT[k].fetch_add(1, Ordering::Relaxed);
        LIVE_NS[k].fetch_add(((end_s - open.start_s) * 1e9) as u64, Ordering::Relaxed);
        LOCAL.with(|l| l.borrow_mut().spans.push(span));
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.state.is_some() {
            // Guard dropped on an error/early-return path: still record a
            // balanced host-only span.
            self.close(f64::NAN, f64::NAN, 0, 0);
        }
    }
}

// ---------------------------------------------------------------------
// Live exposition.

/// Point-in-time exposition of the live per-kind counters — readable
/// mid-run (the long-lived online loop's text endpoint), no allocation on
/// the record path.
#[derive(Debug, Clone)]
pub struct PipelineSnapshot {
    /// Is a trace currently recording?
    pub active: bool,
    /// Per-kind `(name, span count, host seconds)` rows, zero rows
    /// elided.
    pub rows: Vec<(&'static str, u64, f64)>,
}

impl PipelineSnapshot {
    /// Total spans across all kinds.
    pub fn total_spans(&self) -> u64 {
        self.rows.iter().map(|(_, c, _)| c).sum()
    }

    /// Prometheus-style text rendering.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("piperec_trace_active {}\n", self.active as u8));
        for (name, count, secs) in &self.rows {
            s.push_str(&format!("piperec_trace_spans{{kind=\"{name}\"}} {count}\n"));
            s.push_str(&format!(
                "piperec_trace_host_seconds{{kind=\"{name}\"}} {secs:.6}\n"
            ));
        }
        s
    }
}

/// Read the live counters of the currently (or most recently) installed
/// trace.
pub fn snapshot() -> PipelineSnapshot {
    let rows = (1..kind::MAX as u16)
        .filter_map(|k| {
            let count = LIVE_COUNT[k as usize].load(Ordering::Relaxed);
            if count == 0 {
                return None;
            }
            let secs = LIVE_NS[k as usize].load(Ordering::Relaxed) as f64 / 1e9;
            Some((kind::name(k), count, secs))
        })
        .collect();
    PipelineSnapshot { active: is_active(), rows }
}

// ---------------------------------------------------------------------
// The collected trace.

/// One simulated-clock event of [`Trace::sim_timeline`]: bit-exact
/// comparable across runs (the schedule-independence invariant).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct SimEvent {
    pub lane: u32,
    pub kind: u16,
    pub key: u64,
    pub sim_start_bits: u64,
    pub sim_end_bits: u64,
    pub bytes: u64,
}

/// A finished trace: every enrolled thread's span track plus the traced
/// wall time.
#[derive(Debug, Clone)]
pub struct Trace {
    pub tracks: Vec<ThreadTrack>,
    /// Host seconds from install to finish — the wall the stall ledger
    /// closes against.
    pub wall_s: f64,
}

impl Trace {
    /// Total recorded spans.
    pub fn span_count(&self) -> usize {
        self.tracks.iter().map(|t| t.spans.len()).sum()
    }

    /// Iterate every span across tracks.
    pub fn spans(&self) -> impl Iterator<Item = &Span> {
        self.tracks.iter().flat_map(|t| t.spans.iter())
    }

    /// Spans of one kind, across tracks.
    pub fn spans_of_kind(&self, k: u16) -> impl Iterator<Item = &Span> {
        self.spans().filter(move |s| s.kind == k)
    }

    /// The simulated-clock timeline: every sim-stamped span as a
    /// [`SimEvent`], sorted by (lane, kind, key). For deterministic
    /// configs (round-robin routing, in-order ingest, fixed seeds) this
    /// is a pure function of the config — identical bitwise across
    /// thread schedules (pinned by `prop_trace.rs`) — because every sim
    /// clock (lane ETL clock, per-device DMA engine clock) advances only
    /// by modeled costs, never by host timing.
    pub fn sim_timeline(&self) -> Vec<SimEvent> {
        let mut v: Vec<SimEvent> = self
            .spans()
            .filter(|s| s.has_sim())
            .map(|s| SimEvent {
                lane: s.lane,
                kind: s.kind,
                key: s.key,
                sim_start_bits: s.sim_start_s.to_bits(),
                sim_end_bits: s.sim_end_s.to_bits(),
                bytes: s.bytes,
            })
            .collect();
        v.sort_unstable();
        v
    }

    /// Export as Chrome `trace_event` JSON (see [`chrome`]).
    pub fn to_chrome_json(&self) -> String {
        chrome::to_chrome_json(self)
    }

    /// Walk the span chains backwards and attribute every second of wall
    /// time per lane to exactly one cause (see [`critpath`]).
    pub fn stall_attribution(&self) -> StallAttribution {
        critpath::attribute(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_probe_records_nothing_and_is_unarmed() {
        let _serial = INSTALL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        assert!(!is_active());
        let g = begin(kind::PACK, 0, 0);
        assert!(!g.is_armed());
        g.end();
    }

    #[test]
    fn install_records_spans_on_enrolled_threads_only() {
        let guard = install();
        let g = begin(kind::TRAIN_STEP, 0, 7);
        assert!(g.is_armed());
        g.end_bytes(64);
        let token = enroll_token();
        std::thread::scope(|scope| {
            // Enrolled child records; unenrolled child does not.
            scope.spawn(move || {
                enroll(token);
                set_thread_label("child");
                begin(kind::PACK, 1, 0).end_sim(0.5, 1.5);
            });
            scope.spawn(|| {
                let g = begin(kind::PACK, 9, 9);
                assert!(!g.is_armed());
                g.end();
            });
        });
        let trace = guard.finish();
        assert_eq!(trace.span_count(), 2);
        assert!(trace.tracks.iter().any(|t| t.label == "child"));
        let step = trace.spans_of_kind(kind::TRAIN_STEP).next().unwrap();
        assert_eq!((step.lane, step.key, step.bytes), (0, 7, 64));
        assert!(!step.has_sim());
        let pack = trace.spans_of_kind(kind::PACK).next().unwrap();
        assert!(pack.has_sim());
        assert_eq!((pack.sim_start_s, pack.sim_end_s), (0.5, 1.5));
        // Sim timeline carries exactly the sim-stamped span.
        let tl = trace.sim_timeline();
        assert_eq!(tl.len(), 1);
        assert_eq!(tl[0].lane, 1);
    }

    #[test]
    fn guard_drop_records_balanced_host_span() {
        let guard = install();
        {
            let _g = begin(kind::SLOT_ACQUIRE, 0, 3);
            // dropped without an explicit end (error path)
        }
        let trace = guard.finish();
        assert_eq!(trace.span_count(), 1);
        let s = trace.spans().next().unwrap();
        assert_eq!(s.kind, kind::SLOT_ACQUIRE);
        assert!(s.host_end_s >= s.host_start_s);
    }

    #[test]
    fn finish_without_spans_is_empty_and_guard_drop_discards() {
        {
            let guard = install();
            let trace = guard.finish();
            assert_eq!(trace.span_count(), 0);
            assert!(trace.wall_s >= 0.0);
        }
        {
            let guard = install();
            begin(kind::PACK, 0, 0).end();
            drop(guard); // not finished: spans discarded
        }
        let guard = install();
        let trace = guard.finish();
        assert_eq!(trace.span_count(), 0, "stale spans leaked across installs");
    }

    #[test]
    fn snapshot_counts_live_spans_and_renders() {
        let guard = install();
        begin(kind::DMA_TRANSFER, 0, 0).end_io(0.0, 1.0, 1024, 2);
        begin(kind::DMA_TRANSFER, 1, 0).end_io(0.0, 2.0, 2048, 0);
        let snap = snapshot();
        assert!(snap.active);
        assert_eq!(snap.total_spans(), 2);
        let row = snap.rows.iter().find(|(n, _, _)| *n == "dma_transfer").unwrap();
        assert_eq!(row.1, 2);
        let text = snap.render();
        assert!(text.contains("piperec_trace_active 1"));
        assert!(text.contains("piperec_trace_spans{kind=\"dma_transfer\"} 2"));
        let trace = guard.finish();
        let dma: Vec<_> = trace.spans_of_kind(kind::DMA_TRANSFER).collect();
        assert_eq!(dma.len(), 2);
        assert_eq!(dma[0].retries, 2);
    }

    #[test]
    fn stale_tokens_from_prior_installs_never_record() {
        let stale = {
            let _g = install();
            enroll_token()
        };
        let guard = install();
        std::thread::scope(|scope| {
            scope.spawn(move || {
                enroll(stale);
                let g = begin(kind::PACK, 0, 0);
                assert!(!g.is_armed());
                g.end();
            });
        });
        assert_eq!(guard.finish().span_count(), 0);
    }
}
