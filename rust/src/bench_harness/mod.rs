//! Bench harness substrate (the offline registry has no criterion): table
//! rendering, measurement loops, and paper-vs-measured comparison rows
//! shared by every `cargo bench` target.

pub mod experiments;

use crate::util::stats::Summary;
use crate::util::timer::measure_n;

/// An aligned ASCII table for bench output.
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n=== {} ===\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Bench context: honors `PIPEREC_BENCH_QUICK=1` to shrink workloads in CI.
pub struct BenchCtx {
    pub quick: bool,
}

impl BenchCtx {
    pub fn from_env() -> BenchCtx {
        BenchCtx {
            quick: std::env::var("PIPEREC_BENCH_QUICK").map(|v| v == "1").unwrap_or(false),
        }
    }

    /// Scale a workload knob down in quick mode.
    pub fn scale(&self, full: f64, quick: f64) -> f64 {
        if self.quick {
            quick
        } else {
            full
        }
    }

    pub fn iters(&self, full: usize) -> usize {
        if self.quick {
            1
        } else {
            full
        }
    }
}

/// Measure a closure with warmup and return a summary of seconds/iter.
pub fn bench(warmup: usize, iters: usize, f: impl FnMut()) -> Summary {
    Summary::of(&measure_n(warmup, iters, f))
}

/// Format a paper-vs-measured comparison cell: `measured (paper ×r)`.
pub fn vs_paper(measured: f64, paper: f64) -> String {
    if paper == 0.0 {
        return format!("{measured:.3}");
    }
    format!("{:.3} (paper {:.3}, ×{:.2})", measured, paper, measured / paper)
}

/// Format seconds compactly.
pub fn secs(s: f64) -> String {
    crate::util::fmt_secs(s)
}

/// Format a rate compactly.
pub fn rate(bytes_per_sec: f64) -> String {
    crate::util::fmt_rate(bytes_per_sec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("test", &["a", "column_b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["long_value".into(), "x".into()]);
        let s = t.render();
        assert!(s.contains("=== test ==="));
        assert!(s.contains("long_value"));
        // All data lines have the same visual width for col 1.
        let lines: Vec<&str> = s.lines().filter(|l| l.contains("  ")).collect();
        assert!(lines.len() >= 3);
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn bench_returns_summary() {
        let s = bench(1, 3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.n, 3);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn vs_paper_formats_ratio() {
        let s = vs_paper(2.0, 1.0);
        assert!(s.contains("×2.00"), "{s}");
    }
}
