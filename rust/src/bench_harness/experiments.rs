//! Shared experiment harness for the pipeline-latency comparisons
//! (Figs. 13/15/16 and Table 3): computes per-platform latencies for a
//! (dataset, pipeline) configuration at paper scale, including the
//! Beam cluster sweep and the SSD-bound PR-R / theoretical PR-T points
//! for Dataset-III.

use crate::baselines::{BeamModel, GpuKind, GpuModel, PandasModel, Platform};
use crate::dataio::dataset::DatasetSpec;
use crate::etl::pipelines::{build, PipelineKind};
use crate::memsys::IngestSource;
use crate::planner::{compile, PlannerConfig, StreamProfile};

/// All latencies for one (dataset, pipeline) configuration, paper scale.
#[derive(Debug, Clone)]
pub struct LatencyRow {
    pub pandas: f64,
    /// (vCPUs, seconds) Beam sweep.
    pub beam: Vec<(usize, f64)>,
    pub rtx3090: f64,
    pub a100: f64,
    /// PipeRec, realistic ingest (SSD-bound for D-III) — "PR-R".
    pub piperec: f64,
    /// PipeRec theoretical lower bound without the I/O limit — "PR-T".
    pub piperec_theoretical: f64,
}

impl LatencyRow {
    /// Latency for the platforms of Table 3.
    pub fn of(&self, p: Platform) -> f64 {
        match p {
            Platform::CpuPandas => self.pandas,
            Platform::CpuBeam => self.beam.last().map(|(_, s)| *s).unwrap_or(f64::NAN),
            Platform::Rtx3090 => self.rtx3090,
            Platform::A100 => self.a100,
            Platform::PipeRec => self.piperec,
        }
    }
}

/// Compute the full latency row for `kind` over `spec` at paper scale.
pub fn latencies(kind: PipelineKind, spec: &DatasetSpec) -> LatencyRow {
    let dag = build(kind, &spec.schema);
    let plan = compile(&dag, &spec.schema, &PlannerConfig::default())
        .expect("canned pipelines always compile");
    let profile = StreamProfile::from_schema(&spec.schema, spec.paper_rows);
    let source = if spec.ssd_bound { IngestSource::Ssd } else { IngestSource::Host };
    LatencyRow {
        pandas: PandasModel::default().pipeline_seconds(kind, spec),
        beam: BeamModel::sweep(kind, spec),
        rtx3090: GpuModel::new(GpuKind::Rtx3090).pipeline_seconds(kind, spec),
        a100: GpuModel::new(GpuKind::A100).pipeline_seconds(kind, spec),
        piperec: plan.etl_seconds_profiled(profile, source),
        piperec_theoretical: plan.fit_seconds(profile) + plan.apply_seconds(profile),
    }
}

/// Paper Table 3 latency anchors (s), for the vs-paper columns.
pub fn paper_latency(kind: PipelineKind, spec: &DatasetSpec) -> Option<[f64; 4]> {
    use crate::dataio::dataset::DatasetKind;
    // [pandas, 3090, a100, piperec]
    match (spec.kind, kind) {
        (DatasetKind::I, PipelineKind::I) => Some([78.0, 4.2, 2.8, 1.1]),
        (DatasetKind::I, PipelineKind::II) => Some([94.0, 12.8, 11.9, 3.0]),
        (DatasetKind::I, PipelineKind::III) => Some([218.0, 66.7, 77.2, 5.1]),
        (DatasetKind::II, PipelineKind::I) => Some([57.0, 8.3, 9.7, 0.8]),
        (DatasetKind::II, PipelineKind::II) => Some([61.0, 15.4, 16.7, 1.5]),
        (DatasetKind::II, PipelineKind::III) => Some([72.0, 25.8, 24.4, 1.5]),
        _ => None,
    }
}

/// Render one figure's comparison table for a pipeline over all datasets.
pub fn render_pipeline_figure(title: &str, kind: PipelineKind) -> super::Table {
    let mut t = super::Table::new(
        title,
        &["dataset", "pandas", "Beam-128", "RTX 3090", "A100", "PipeRec", "PR-T", "PipeRec vs pandas"],
    );
    for spec in [
        DatasetSpec::dataset_i(1.0),
        DatasetSpec::dataset_ii(1.0),
        DatasetSpec::dataset_iii(1.0),
    ] {
        let r = latencies(kind, &spec);
        t.row(vec![
            spec.name.to_string(),
            super::secs(r.pandas),
            super::secs(r.beam.last().unwrap().1),
            super::secs(r.rtx3090),
            super::secs(r.a100),
            super::secs(r.piperec),
            super::secs(r.piperec_theoretical),
            format!("{:.0}×", r.pandas / r.piperec),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset1_latencies_track_paper_anchors() {
        let spec = DatasetSpec::dataset_i(1.0);
        for kind in PipelineKind::all() {
            let got = latencies(kind, &spec);
            let paper = paper_latency(kind, &spec).unwrap();
            // Within 2× on every platform (the *shape* constraint; most
            // are much closer — see EXPERIMENTS.md).
            for (g, p) in [
                (got.pandas, paper[0]),
                (got.rtx3090, paper[1]),
                (got.a100, paper[2]),
                (got.piperec, paper[3]),
            ] {
                let ratio = g / p;
                assert!(
                    ratio > 0.4 && ratio < 2.5,
                    "{}: got {g:.1}s vs paper {p}s",
                    kind.label()
                );
            }
            // Ordering: pandas > GPUs > PipeRec.
            assert!(got.pandas > got.a100 && got.a100 > got.piperec);
        }
    }

    #[test]
    fn dataset3_is_ssd_bound_with_theoretical_point_below() {
        let spec = DatasetSpec::dataset_iii(1.0);
        let r = latencies(PipelineKind::I, &spec);
        let ssd_floor = spec.paper_bytes() as f64 / 1.2e9;
        assert!((r.piperec / ssd_floor - 1.0).abs() < 0.02);
        assert!(r.piperec_theoretical < r.piperec);
    }

    #[test]
    fn speedups_match_paper_magnitudes() {
        // §4.4: 85×/87× (P-I, D-I/D-II); §4.5: 32×/43× (D-I P-II/P-III).
        let d1 = DatasetSpec::dataset_i(1.0);
        let d2 = DatasetSpec::dataset_ii(1.0);
        let s_p1_d1 = {
            let r = latencies(PipelineKind::I, &d1);
            r.pandas / r.piperec
        };
        let s_p1_d2 = {
            let r = latencies(PipelineKind::I, &d2);
            r.pandas / r.piperec
        };
        let r2 = latencies(PipelineKind::II, &d1);
        let r3 = latencies(PipelineKind::III, &d1);
        assert!(s_p1_d1 > 30.0 && s_p1_d1 < 250.0, "{s_p1_d1}");
        assert!(s_p1_d2 > 30.0 && s_p1_d2 < 250.0, "{s_p1_d2}");
        assert!(r2.pandas / r2.piperec > 15.0, "{}", r2.pandas / r2.piperec);
        assert!(r3.pandas / r3.piperec > 20.0, "{}", r3.pandas / r3.piperec);
        // GPU speedup band: 2.4–17× (abstract).
        let gpu_speedup = r3.a100 / r3.piperec;
        assert!(gpu_speedup > 2.0 && gpu_speedup < 30.0, "{gpu_speedup}");
    }
}
