//! `piperec` — the launcher CLI.
//!
//! Subcommands:
//! * `compile`  — plan a pipeline and print the hardware plan + resources
//! * `etl`      — run an ETL pass (simulated FPGA vs baselines)
//! * `train`    — end-to-end: ETL → staging → PJRT DLRM training
//! * `inspect`  — dataset / artifact information
//!
//! Run `piperec <cmd> --help-args` for each command's options.

use piperec::baselines::{GpuKind, GpuModel, PandasModel};
use piperec::coordinator::{train, TrainConfig};
use piperec::dataio::dataset::{DatasetKind, DatasetSpec};
use piperec::etl::pipelines::{self, PipelineKind};
use piperec::fpga::Pipeline;
use piperec::planner::{compile, PlannerConfig};
use piperec::runtime::artifacts::ArtifactPaths;
use piperec::runtime::Trainer;
use piperec::util::cli::Args;
use piperec::util::{fmt_bytes, fmt_rate, fmt_secs};

fn parse_pipeline(s: &str) -> PipelineKind {
    match s {
        "1" | "p1" | "I" => PipelineKind::I,
        "2" | "p2" | "II" => PipelineKind::II,
        "3" | "p3" | "III" => PipelineKind::III,
        other => panic!("unknown pipeline {other:?} (use 1|2|3)"),
    }
}

fn parse_dataset(s: &str) -> DatasetKind {
    match s {
        "1" | "d1" | "I" => DatasetKind::I,
        "2" | "d2" | "II" => DatasetKind::II,
        "3" | "d3" | "III" => DatasetKind::III,
        other => panic!("unknown dataset {other:?} (use 1|2|3)"),
    }
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    match args.subcommand() {
        Some("compile") => cmd_compile(&args)?,
        Some("etl") => cmd_etl(&args)?,
        Some("train") => cmd_train(&args)?,
        Some("inspect") => cmd_inspect(&args)?,
        _ => {
            eprintln!(
                "usage: piperec <compile|etl|train|inspect> \
                 [--pipeline 1|2|3] [--dataset 1|2|3] [--scale F] [--steps N]"
            );
            std::process::exit(2);
        }
    }
    Ok(())
}

fn cmd_compile(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let kind = parse_pipeline(&args.get_str("pipeline", "1"));
    let spec = DatasetSpec::by_kind(parse_dataset(&args.get_str("dataset", "1")), 1.0);
    let dag = pipelines::build(kind, &spec.schema);
    let mut cfg = PlannerConfig::default();
    cfg.with_rdma = args.flag("rdma");
    cfg.lanes = args.get("lanes", cfg.lanes);
    let plan = compile(&dag, &spec.schema, &cfg)?;
    println!("plan {} over {}:", plan.name, spec.name);
    println!("  stages        : {}", plan.stages.len());
    println!("  lanes × width : {} × {} B", plan.lanes, plan.width_bytes);
    println!("  dataflow II   : {}", plan.dataflow_ii);
    println!("  line rate     : {}", fmt_rate(plan.line_rate()));
    println!("  HBM tables    : {}", plan.hbm_tables());
    let r = plan.device_report;
    println!(
        "  device        : CLB {:.1}%  BRAM {:.1}%  DSP {:.2}%",
        r.clb_frac * 100.0,
        r.bram_frac * 100.0,
        r.dsp_frac * 100.0
    );
    println!(
        "  paper-scale ETL time ({}): {}",
        spec.name,
        fmt_secs(plan.etl_seconds(spec.paper_bytes()))
    );
    Ok(())
}

fn cmd_etl(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let kind = parse_pipeline(&args.get_str("pipeline", "2"));
    let scale = args.get("scale", 0.1);
    let spec = DatasetSpec::by_kind(parse_dataset(&args.get_str("dataset", "1")), scale);
    let dag = pipelines::build(kind, &spec.schema);
    let plan = compile(&dag, &spec.schema, &PlannerConfig::default())?;
    let mut pipe = Pipeline::new(plan);

    println!(
        "ETL {} on {} ({} rows, {})",
        kind.label(),
        spec.name,
        spec.rows,
        fmt_bytes(spec.total_bytes())
    );
    let sample = spec.shard(0, 42);
    pipe.fit(&sample)?;
    let mut acc = piperec::fpga::ShardTiming::default();
    for i in 0..spec.shards {
        let shard = spec.shard(i, 42);
        if shard.rows() == 0 {
            break;
        }
        let (_, t) = pipe.process(&shard)?;
        acc.accumulate(&t);
    }
    println!("  simulated FPGA time : {}", fmt_secs(acc.elapsed_s));
    println!("  simulated throughput: {}", fmt_rate(acc.throughput()));
    println!("  host (functional)   : {}", fmt_secs(acc.host_s));
    let pandas = PandasModel::default().pipeline_seconds(kind, &spec)
        / spec.paper_scale_factor();
    let gpu = GpuModel::new(GpuKind::A100).pipeline_seconds(kind, &spec)
        / spec.paper_scale_factor();
    println!("  pandas model (same scale): {}", fmt_secs(pandas));
    println!("  A100 NVTabular model     : {}", fmt_secs(gpu));
    Ok(())
}

fn cmd_train(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let kind = parse_pipeline(&args.get_str("pipeline", "2"));
    let scale = args.get("scale", 0.05);
    let mut spec = DatasetSpec::by_kind(parse_dataset(&args.get_str("dataset", "1")), scale);
    spec.shards = args.get("shards", 4usize);
    let dag = pipelines::build(kind, &spec.schema);
    let plan = compile(&dag, &spec.schema, &PlannerConfig::default())?;
    let mut pipe = Pipeline::new(plan);
    pipe.fit(&spec.shard(0, 42))?;

    let paths = ArtifactPaths::default_dir();
    let mut trainer = Trainer::load(&paths, 7)?;
    println!(
        "training DLRM ({} params) on {} via {}",
        trainer.param_count(),
        spec.name,
        kind.label()
    );
    let cfg = TrainConfig {
        max_steps: args.get("steps", 100usize),
        loss_every: args.get("loss-every", 10usize),
        ..Default::default()
    };
    let report = train(&pipe, &spec, &mut trainer, &cfg)?;
    for (s, l) in &report.losses {
        println!("  step {s:>5}  loss {l:.5}");
    }
    println!(
        "steps={} wall={} util={:.1}% stalls={}",
        report.steps,
        fmt_secs(report.wall_s),
        report.util * 100.0,
        report.producer_stalls
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    for kind in [DatasetKind::I, DatasetKind::II, DatasetKind::III] {
        let spec = DatasetSpec::by_kind(kind, args.get("scale", 1.0));
        println!(
            "{:<12} rows={:>10} (paper {:>11})  row={}B  total={}  shards={}",
            spec.name,
            spec.rows,
            spec.paper_rows,
            spec.row_bytes(),
            fmt_bytes(spec.total_bytes()),
            spec.shards
        );
    }
    let paths = ArtifactPaths::default_dir();
    if paths.exist() {
        let meta = piperec::runtime::artifacts::ModelMeta::load(&paths.meta)?;
        println!(
            "artifacts: batch={} dense={} sparse={} vocab={} dim={} params={}",
            meta.batch,
            meta.n_dense,
            meta.n_sparse,
            meta.vocab,
            meta.embed_dim,
            meta.param_count()
        );
    } else {
        println!("artifacts: not built (run `make artifacts`)");
    }
    Ok(())
}
