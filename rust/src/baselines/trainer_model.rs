//! GPU trainer consumption model (paper Fig. 1/8): the rate at which DLRM
//! training consumes packed batches, used to size backpressure and to
//! reproduce the end-to-end imbalance figures. Calibrated to the paper's
//! production pipeline: a 12-core CPU sustains ~10 MB/s of preprocessing
//! while the GPU can consume ~100 MB/s, making CPU ETL 11.4–13× slower
//! than training (Fig. 1b).

/// DLRM training-step time model for an accelerator.
#[derive(Debug, Clone, Copy)]
pub struct TrainerModel {
    /// Fixed per-step overhead: kernel launches, optimizer, allreduce (s).
    pub step_overhead_s: f64,
    /// Per-row forward+backward time (s/row).
    pub per_row_s: f64,
    /// Packed bytes per row (schema-dependent).
    pub row_bytes: u64,
}

impl TrainerModel {
    /// A100-class trainer on the Criteo DLRM (packed row = 160 B):
    /// consumes ≈100 MB/s at large batch sizes (Fig. 8).
    pub fn a100_dlrm(row_bytes: u64) -> TrainerModel {
        TrainerModel {
            step_overhead_s: 5.0e-3,
            per_row_s: 1.35e-6,
            row_bytes,
        }
    }

    /// Step latency for a batch of `rows`.
    pub fn step_seconds(&self, rows: usize) -> f64 {
        self.step_overhead_s + rows as f64 * self.per_row_s
    }

    /// Sustained consumption bandwidth at a given batch size (bytes/s).
    pub fn consume_bw(&self, batch_rows: usize) -> f64 {
        (batch_rows as u64 * self.row_bytes) as f64 / self.step_seconds(batch_rows)
    }

    /// Seconds to train one epoch of `total_rows` at `batch_rows`.
    pub fn epoch_seconds(&self, total_rows: u64, batch_rows: usize) -> f64 {
        let steps = total_rows.div_ceil(batch_rows as u64);
        steps as f64 * self.step_seconds(batch_rows)
    }
}

/// The production 12-core CPU ETL rate from Fig. 1/8 (~10 MB/s).
pub const CPU_ETL_BW_12CORE: f64 = 10.0e6;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn consumption_near_100mbps_at_large_batches() {
        let t = TrainerModel::a100_dlrm(160);
        let bw = t.consume_bw(1 << 21); // 2M rows
        assert!(bw > 90.0e6 && bw < 130.0e6, "bw={bw}");
    }

    #[test]
    fn etl_training_imbalance_matches_fig1() {
        // CPU ETL 11.4–13.0× slower than training across 64K–2M batches.
        let t = TrainerModel::a100_dlrm(160);
        let total_rows = 45_000_000u64;
        let total_bytes = total_rows * 160;
        let etl_s = total_bytes as f64 / CPU_ETL_BW_12CORE;
        for batch in [64 * 1024, 256 * 1024, 1 << 20, 2 << 20] {
            let train_s = t.epoch_seconds(total_rows, batch);
            let ratio = etl_s / train_s;
            assert!(
                (10.0..14.0).contains(&ratio),
                "batch={batch} ratio={ratio:.1}"
            );
        }
    }

    #[test]
    fn larger_batches_amortize_overhead() {
        let t = TrainerModel::a100_dlrm(160);
        assert!(t.consume_bw(1 << 21) > t.consume_bw(64 * 1024));
    }

    #[test]
    fn epoch_time_counts_partial_step() {
        let t = TrainerModel::a100_dlrm(160);
        let a = t.epoch_seconds(100, 64);
        assert!((a - 2.0 * t.step_seconds(64)).abs() < 1e-9);
    }
}
