//! GPU ETL baseline: NVTabular / RAPIDS dask-cudf model (paper §4.2.3).
//!
//! No GPU exists in this environment, so per the substitution rule the
//! baseline is an analytic model calibrated to the paper's own
//! measurements: Table 2 per-operator times, Table 3 pipeline latencies on
//! RTX 3090 and A100, and the Fig. 10 RMM-pool-fraction curve. The model
//! runs the same *functional* operators (via the shared kernels) when data
//! is needed; only the clock is synthetic.
//!
//! Calibration (derived in DESIGN.md §1):
//! * stateless pipeline time = bytes / io_bw + n_cols × col_task_s
//!   (dask-cudf per-column task overhead dominates wide schemas — this is
//!   why Dataset-II is *slower* than Dataset-I on GPUs despite being
//!   smaller);
//! * vocabulary fit+map per feature = c0 + rows × r(card), with r a power
//!   law through the paper's 8K and 512K anchors (the card term scales
//!   with rows — groupby cost — matching D-I vs D-II deltas).

use crate::dataio::dataset::DatasetSpec;
use crate::etl::pipelines::PipelineKind;

/// Which GPU the model represents.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GpuKind {
    Rtx3090,
    A100,
}

impl GpuKind {
    pub fn label(&self) -> &'static str {
        match self {
            GpuKind::Rtx3090 => "RTX 3090",
            GpuKind::A100 => "A100",
        }
    }
}

/// Calibrated NVTabular model.
#[derive(Debug, Clone, Copy)]
pub struct GpuModel {
    pub kind: GpuKind,
    /// Effective decompression+transfer+kernel bandwidth for the stateless
    /// columnar scan (bytes/s).
    pub io_bw: f64,
    /// Per-column dask task overhead (s).
    pub col_task_s: f64,
    /// Vocabulary per-feature fixed cost (s).
    pub vocab_c0: f64,
    /// Vocabulary per-row cost at the 8K anchor (s/row).
    pub vocab_r8k: f64,
    /// Power-law exponent of the per-row cost in cardinality.
    pub vocab_alpha: f64,
    /// RMM pool fraction of GPU memory (Fig. 10 knob).
    pub rmm_fraction: f64,
}

impl GpuModel {
    pub fn new(kind: GpuKind) -> GpuModel {
        match kind {
            // Fit to Table 3 anchors (see module docs).
            GpuKind::Rtx3090 => GpuModel {
                kind,
                io_bw: 4.0e9,
                col_task_s: 10.0e-3,
                vocab_c0: 0.13,
                vocab_r8k: 4.6e-9,
                vocab_alpha: 0.62,
                rmm_fraction: 0.5,
            },
            GpuKind::A100 => GpuModel {
                kind,
                io_bw: 8.0e9,
                col_task_s: 16.0e-3,
                vocab_c0: 0.15,
                vocab_r8k: 4.5e-9,
                vocab_alpha: 0.62,
                rmm_fraction: 0.5,
            },
        }
    }

    pub fn with_rmm_fraction(mut self, frac: f64) -> GpuModel {
        self.rmm_fraction = frac.clamp(0.05, 1.0);
        self
    }

    /// Fig. 10 multiplier: runtimes improve steeply until the pool reaches
    /// ~0.3 of GPU memory (fewer spills/re-allocations), then only
    /// modestly.
    pub fn rmm_multiplier(&self) -> f64 {
        let f = self.rmm_fraction;
        if f < 0.3 {
            1.0 + 1.1 * (0.3 - f) / f // steep penalty below the knee
        } else {
            1.0 - 0.08 * (f - 0.3) / 0.2 // modest gains after
        }
    }

    /// Per-row vocabulary cost for a table of `card` entries.
    fn vocab_per_row(&self, card: usize) -> f64 {
        self.vocab_r8k * (card as f64 / 8192.0).powf(self.vocab_alpha)
    }

    /// Stateless scan time for a dataset at paper scale.
    fn stateless_seconds(&self, spec: &DatasetSpec) -> f64 {
        let cols = spec.schema.fields.len() as f64;
        spec.paper_bytes() as f64 / self.io_bw + cols * self.col_task_s
    }

    /// Vocabulary fit+apply time for all sparse features.
    fn vocab_seconds(&self, card: usize, spec: &DatasetSpec) -> f64 {
        let feats = spec.schema.sparse_count() as f64;
        feats * (self.vocab_c0 + spec.paper_rows as f64 * self.vocab_per_row(card))
    }

    /// End-to-end pipeline latency (paper Fig. 13/15/16, Table 3).
    pub fn pipeline_seconds(&self, pipeline: PipelineKind, spec: &DatasetSpec) -> f64 {
        let base = self.stateless_seconds(spec);
        let vocab = match pipeline.vocab_size() {
            None => 0.0,
            Some(card) => self.vocab_seconds(card, spec),
        };
        (base + vocab) * self.rmm_multiplier()
    }

    /// Per-operator time (Table 2 regeneration). Stateless kernels are
    /// launch-bound; vocab ops use the calibrated groupby model.
    pub fn op_seconds(&self, op: &str, rows: u64) -> f64 {
        let (launch, per_row): (f64, f64) = match (self.kind, op) {
            (GpuKind::Rtx3090, "Clamp") => (0.025, 1e-10),
            (GpuKind::Rtx3090, "Logarithm") => (0.008, 5e-11),
            (GpuKind::Rtx3090, "Hex2Int") => (0.045, 1.3e-10),
            (GpuKind::Rtx3090, "Modulus") => (0.014, 7e-11),
            (GpuKind::A100, "Clamp") => (0.038, 1e-10),
            (GpuKind::A100, "Logarithm") => (0.013, 5e-11),
            (GpuKind::A100, "Hex2Int") => (0.053, 1.3e-10),
            (GpuKind::A100, "Modulus") => (0.023, 7e-11),
            (_, "VocabMap-8K") => (0.02, 1e-10),
            (_, "VocabMap-512K") => (0.015, 1e-10),
            (_, "VocabGen-8K") => {
                return 26.0 * (self.vocab_c0 + rows as f64 * self.vocab_per_row(8192))
            }
            (_, "VocabGen-512K") => {
                return 26.0 * (self.vocab_c0 + rows as f64 * self.vocab_per_row(512 * 1024))
            }
            _ => (0.02, 1e-10),
        };
        launch + rows as f64 * per_row
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pct_err(got: f64, want: f64) -> f64 {
        (got / want - 1.0).abs()
    }

    #[test]
    fn a100_reproduces_table3_dataset1() {
        // Paper: 2.8 / 11.9 / 77.2 s.
        let m = GpuModel::new(GpuKind::A100);
        let spec = DatasetSpec::dataset_i(1.0);
        assert!(pct_err(m.pipeline_seconds(PipelineKind::I, &spec), 2.8) < 0.35);
        assert!(pct_err(m.pipeline_seconds(PipelineKind::II, &spec), 11.9) < 0.35);
        assert!(pct_err(m.pipeline_seconds(PipelineKind::III, &spec), 77.2) < 0.35);
    }

    #[test]
    fn rtx3090_reproduces_table3_dataset2() {
        // Paper: 8.3 / 15.4 / 25.8 s.
        let m = GpuModel::new(GpuKind::Rtx3090);
        let spec = DatasetSpec::dataset_ii(1.0);
        assert!(pct_err(m.pipeline_seconds(PipelineKind::I, &spec), 8.3) < 0.40);
        assert!(pct_err(m.pipeline_seconds(PipelineKind::II, &spec), 15.4) < 0.40);
        assert!(pct_err(m.pipeline_seconds(PipelineKind::III, &spec), 25.8) < 0.40);
    }

    #[test]
    fn wide_schema_is_slower_despite_fewer_bytes() {
        // The paper's D-II (11 GB) is slower than D-I (17 GB) on GPUs.
        let m = GpuModel::new(GpuKind::A100);
        let d1 = DatasetSpec::dataset_i(1.0);
        let d2 = DatasetSpec::dataset_ii(1.0);
        assert!(d2.paper_bytes() < d1.paper_bytes());
        assert!(
            m.pipeline_seconds(PipelineKind::I, &d2)
                > m.pipeline_seconds(PipelineKind::I, &d1)
        );
    }

    #[test]
    fn rmm_knee_at_0_3() {
        let base = GpuModel::new(GpuKind::A100);
        let t01 = base.with_rmm_fraction(0.1).rmm_multiplier();
        let t03 = base.with_rmm_fraction(0.3).rmm_multiplier();
        let t05 = base.with_rmm_fraction(0.5).rmm_multiplier();
        // Steep gain up to 0.3, modest after (paper Fig. 10).
        assert!(t01 > 1.5 * t03);
        assert!((t03 - t05) < 0.15 * t03);
    }

    #[test]
    fn table2_vocabgen_anchors() {
        // Paper: VocabGen-512K ≈ 64.1 s (3090) / 69.0 s (A100) at 45 M rows.
        let r = GpuModel::new(GpuKind::Rtx3090).op_seconds("VocabGen-512K", 45_000_000);
        let a = GpuModel::new(GpuKind::A100).op_seconds("VocabGen-512K", 45_000_000);
        assert!(pct_err(r, 64.1) < 0.3, "3090 {r}");
        assert!(pct_err(a, 69.0) < 0.3, "a100 {a}");
    }

    #[test]
    fn stateless_ops_are_launch_bound() {
        let m = GpuModel::new(GpuKind::A100);
        let small = m.op_seconds("Logarithm", 1_000);
        let large = m.op_seconds("Logarithm", 45_000_000);
        // Less than 5× growth over 45000× more rows.
        assert!(large < small * 5.0);
    }
}
