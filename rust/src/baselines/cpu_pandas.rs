//! CPU baselines (paper §4.2.2).
//!
//! Two layers, per the DESIGN.md substitution table:
//!
//! * [`RustCpuEtl`] — a *real* multithreaded columnar ETL engine in Rust
//!   (what a well-tuned single-node CPU baseline looks like on this
//!   machine). Used for measured wall-clock numbers and for the Fig. 12
//!   single-thread decomposition, whose *shape* (LoadOnly ≪ Stateless ≪
//!   VocabGen < VocabMap-large) is the paper's observable.
//! * [`PandasModel`] — a cost model calibrated to the paper's own pandas
//!   measurements (Table 2 per-operator costs, Table 3 pipeline
//!   latencies on the 128-core EPYC 7V13), used to report paper-scale
//!   numbers for the comparison tables.

use crate::dataio::dataset::DatasetSpec;
use crate::error::Result;
use crate::etl::column::Batch;
use crate::etl::dag::{Dag, EtlState};
use crate::etl::pipelines::PipelineKind;
use crate::util::pool::parallel_chunks;

/// Real multithreaded CPU execution of an ETL DAG: columns are partitioned
/// across worker threads (the natural pandas/numpy parallelisation axis
/// for columnar workloads).
pub struct RustCpuEtl {
    pub threads: usize,
}

impl RustCpuEtl {
    pub fn new(threads: usize) -> RustCpuEtl {
        RustCpuEtl { threads: threads.max(1) }
    }

    /// Fit + apply, returning the output batch and measured seconds.
    pub fn run(&self, dag: &Dag, input: &Batch) -> Result<(Batch, f64)> {
        let t0 = std::time::Instant::now();
        let state = dag.fit(input)?;
        let out = self.apply(dag, input, &state)?;
        Ok((out, t0.elapsed().as_secs_f64()))
    }

    /// Fit + fused apply+pack: the comparison point for the fused tiled
    /// engine (`etl::exec`) against this columnar baseline — same DAG,
    /// same thread budget, but one pass straight into trainer layout.
    pub fn run_fused(
        &self,
        dag: &Dag,
        input: &Batch,
    ) -> Result<(crate::coordinator::packer::PackedBatch, f64)> {
        use crate::etl::exec::{ExecConfig, FusedEngine};
        let t0 = std::time::Instant::now();
        let state = dag.fit(input)?;
        let cfg = ExecConfig { threads: self.threads, ..ExecConfig::default() };
        let engine = FusedEngine::compile(dag, cfg)?;
        let packed = engine.execute(input, &state)?;
        Ok((packed, t0.elapsed().as_secs_f64()))
    }

    /// Apply with frozen state, parallelised across row ranges.
    pub fn apply(&self, dag: &Dag, input: &Batch, state: &EtlState) -> Result<Batch> {
        if self.threads == 1 || input.rows() < 2 * self.threads {
            return dag.apply(input, state);
        }
        // Row-range parallelism: each worker transforms a horizontal slice.
        let rows = input.rows();
        let slices = parallel_chunks(rows, self.threads, |_, range| {
            let sub = slice_batch(input, range.clone());
            dag.apply(&sub, state)
        });
        // Stitch slices back together column-wise.
        let mut parts = Vec::new();
        for s in slices {
            parts.push(s?);
        }
        concat_batches(&parts)
    }
}

/// Extract rows `range` of every column (thin alias of
/// [`Batch::slice_rows`], kept for API stability).
pub fn slice_batch(b: &Batch, range: std::ops::Range<usize>) -> Batch {
    b.slice_rows(range)
}

/// Concatenate batches with identical schemas row-wise.
pub fn concat_batches(parts: &[Batch]) -> Result<Batch> {
    use crate::etl::column::Column;
    let mut out = Batch::new();
    if parts.is_empty() {
        return Ok(out);
    }
    for (ci, (name, first)) in parts[0].columns.iter().enumerate() {
        let col = match first {
            Column::F32 { width, .. } => {
                let mut data = Vec::new();
                for p in parts {
                    data.extend_from_slice(p.columns[ci].1.as_f32()?);
                }
                Column::F32 { data, width: *width }
            }
            Column::Hex8 { .. } => {
                let mut data = Vec::new();
                for p in parts {
                    data.extend_from_slice(p.columns[ci].1.as_hex8()?);
                }
                Column::Hex8 { data }
            }
            Column::I64 { width, .. } => {
                let mut data = Vec::new();
                for p in parts {
                    data.extend_from_slice(p.columns[ci].1.as_i64()?);
                }
                Column::I64 { data, width: *width }
            }
        };
        out.push(name.clone(), col)?;
    }
    Ok(out)
}

/// Cost model calibrated to the paper's pandas measurements.
///
/// Table 2 anchors (Dataset-I, 45 M rows, whole dataset, single thread):
/// Clamp 4.2 s, Logarithm 475 s, Hex2Int 411 s, Modulus 354 s,
/// VocabGen-8K 4.97 s, VocabMap-8K 21.9 s, VocabGen-512K 550 s,
/// VocabMap-512K 2390 s.
#[derive(Debug, Clone, Copy)]
pub struct PandasModel {
    /// Worker threads (paper: best run used 64 threads on 128 cores).
    pub threads: usize,
    /// Parallel efficiency of pandas/joblib column-parallel execution.
    pub efficiency: f64,
}

impl Default for PandasModel {
    fn default() -> Self {
        PandasModel { threads: 64, efficiency: 0.40 }
    }
}

/// Per-row single-thread costs (seconds), derived from Table 2 at 45 M rows.
pub mod costs {
    pub const LOAD_ONLY: f64 = 2.2e-9; // negligible (Fig. 12)
    pub const CLAMP: f64 = 4.20 / 45.0e6;
    pub const LOGARITHM: f64 = 475.28 / 45.0e6;
    pub const HEX2INT: f64 = 410.59 / 45.0e6;
    pub const MODULUS: f64 = 354.25 / 45.0e6;
    pub const VOCAB_GEN_8K: f64 = 4.97 / 45.0e6;
    pub const VOCAB_MAP_8K: f64 = 21.94 / 45.0e6;
    pub const VOCAB_GEN_512K: f64 = 549.79 / 45.0e6;
    pub const VOCAB_MAP_512K: f64 = 2390.26 / 45.0e6;

    /// Interpolate vocabulary op cost for arbitrary cardinality via a
    /// power law through the 8K and 512K anchors.
    pub fn vocab_gen(card: usize) -> f64 {
        powerlaw(card, VOCAB_GEN_8K, VOCAB_GEN_512K)
    }

    pub fn vocab_map(card: usize) -> f64 {
        powerlaw(card, VOCAB_MAP_8K, VOCAB_MAP_512K)
    }

    fn powerlaw(card: usize, at_8k: f64, at_512k: f64) -> f64 {
        let alpha = (at_512k / at_8k).ln() / 64f64.ln(); // 512K/8K = 64×
        at_8k * (card as f64 / 8192.0).powf(alpha).max(1.0 / 64.0)
    }
}

impl PandasModel {
    /// Single-thread seconds for the full dense+sparse op chain of
    /// `pipeline` over `spec` (whole dataset, paper scale).
    pub fn single_thread_seconds(&self, pipeline: PipelineKind, spec: &DatasetSpec) -> f64 {
        let rows = spec.paper_rows as f64;
        let dense = spec.schema.dense_count() as f64;
        let sparse = spec.schema.sparse_count() as f64;
        // Reference schema for the anchors is Dataset-I (13 dense, 26
        // sparse): per-feature cost = anchor / feature-count.
        let dense_chain = (costs::CLAMP + costs::LOGARITHM) / 13.0 * dense;
        let sparse_chain = (costs::HEX2INT + costs::MODULUS) / 26.0 * sparse;
        let vocab = match pipeline.vocab_size() {
            None => 0.0,
            Some(card) => {
                (costs::vocab_gen(card) + costs::vocab_map(card)) / 26.0 * sparse
            }
        };
        (dense_chain + sparse_chain + vocab) * rows
    }

    /// Parallel pipeline latency (the paper's Pandas rows in Fig. 13/15/16
    /// and Table 3): column-parallel speedup capped by the column count.
    pub fn pipeline_seconds(&self, pipeline: PipelineKind, spec: &DatasetSpec) -> f64 {
        let cols = spec.schema.fields.len() as f64;
        let parallel = (self.threads as f64).min(cols) * self.efficiency;
        self.single_thread_seconds(pipeline, spec) / parallel.max(1.0)
    }

    /// Per-operator cost on a dataset (Table 2 regeneration).
    pub fn op_seconds(&self, op: &str, rows: u64) -> f64 {
        let per_row = match op {
            "Clamp" => costs::CLAMP,
            "Logarithm" => costs::LOGARITHM,
            "Hex2Int" => costs::HEX2INT,
            "Modulus" => costs::MODULUS,
            "VocabGen-8K" => costs::VOCAB_GEN_8K,
            "VocabMap-8K" => costs::VOCAB_MAP_8K,
            "VocabGen-512K" => costs::VOCAB_GEN_512K,
            "VocabMap-512K" => costs::VOCAB_MAP_512K,
            _ => costs::LOAD_ONLY,
        };
        per_row * rows as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::etl::pipelines::build;

    #[test]
    fn rust_cpu_matches_reference_executor() {
        let mut spec = DatasetSpec::dataset_i(0.001);
        spec.shards = 1;
        let shard = spec.shard(0, 7);
        let dag = build(PipelineKind::II, &spec.schema);
        let state = dag.fit(&shard).unwrap();
        let reference = dag.apply(&shard, &state).unwrap();
        let parallel = RustCpuEtl::new(4).apply(&dag, &shard, &state).unwrap();
        assert_eq!(reference.rows(), parallel.rows());
        for ((n1, c1), (n2, c2)) in reference.columns.iter().zip(&parallel.columns) {
            assert_eq!(n1, n2);
            assert_eq!(c1, c2, "column {n1} diverged");
        }
    }

    #[test]
    fn fused_run_matches_reference_apply_plus_pack() {
        use crate::coordinator::packer::{pack, PackLayout};
        let mut spec = DatasetSpec::dataset_i(0.001);
        spec.shards = 1;
        let shard = spec.shard(0, 11);
        let dag = build(PipelineKind::II, &spec.schema);
        let state = dag.fit(&shard).unwrap();
        let reference = dag.apply(&shard, &state).unwrap();
        let layout = PackLayout::of(&dag).unwrap();
        let want = pack(&reference, &layout).unwrap();
        let (got, secs) = RustCpuEtl::new(4).run_fused(&dag, &shard).unwrap();
        assert_eq!(want, got);
        assert!(secs >= 0.0);
    }

    #[test]
    fn slice_and_concat_roundtrip() {
        let spec = DatasetSpec::dataset_i(0.0005);
        let shard = spec.shard(0, 3);
        let rows = shard.rows();
        let a = slice_batch(&shard, 0..rows / 2);
        let b = slice_batch(&shard, rows / 2..rows);
        let back = concat_batches(&[a, b]).unwrap();
        assert_eq!(back.rows(), rows);
        assert_eq!(
            back.get("criteo_c0").unwrap().as_hex8().unwrap(),
            shard.get("criteo_c0").unwrap().as_hex8().unwrap()
        );
    }

    #[test]
    fn pandas_model_reproduces_table3_dataset1() {
        // Paper Table 3, CPU column, Dataset-I: 78 s / 94 s / 218 s.
        let m = PandasModel::default();
        let spec = DatasetSpec::dataset_i(1.0);
        let p1 = m.pipeline_seconds(PipelineKind::I, &spec);
        let p2 = m.pipeline_seconds(PipelineKind::II, &spec);
        let p3 = m.pipeline_seconds(PipelineKind::III, &spec);
        assert!((p1 / 78.0 - 1.0).abs() < 0.35, "P-I {p1}");
        assert!((p2 / 94.0 - 1.0).abs() < 0.35, "P-II {p2}");
        assert!((p3 / 218.0 - 1.0).abs() < 0.35, "P-III {p3}");
        // Ordering is strict.
        assert!(p1 < p2 && p2 < p3);
    }

    #[test]
    fn pandas_model_table2_anchors_exact() {
        let m = PandasModel::default();
        assert!((m.op_seconds("Logarithm", 45_000_000) - 475.28).abs() < 0.1);
        assert!((m.op_seconds("VocabMap-512K", 45_000_000) - 2390.26).abs() < 0.5);
    }

    #[test]
    fn vocab_cost_interpolation_monotonic() {
        let c64k = costs::vocab_map(64 * 1024);
        assert!(c64k > costs::VOCAB_MAP_8K && c64k < costs::VOCAB_MAP_512K);
    }

    #[test]
    fn more_threads_is_faster_until_column_cap() {
        let spec = DatasetSpec::dataset_i(1.0);
        let t8 = PandasModel { threads: 8, efficiency: 0.4 }
            .pipeline_seconds(PipelineKind::I, &spec);
        let t32 = PandasModel { threads: 32, efficiency: 0.4 }
            .pipeline_seconds(PipelineKind::I, &spec);
        let t64 = PandasModel { threads: 64, efficiency: 0.4 }
            .pipeline_seconds(PipelineKind::I, &spec);
        let t128 = PandasModel { threads: 128, efficiency: 0.4 }
            .pipeline_seconds(PipelineKind::I, &spec);
        assert!(t8 > t32 && t32 > t64);
        // 40 columns cap the useful parallelism below 64 threads.
        assert_eq!(t64, t128);
    }
}
