//! Apache Beam / Google Cloud Dataflow baseline model (paper §4.2.2).
//!
//! The paper runs Beam on n2-standard-{16..128} clusters and observes that
//! "its benefit diminishes with larger cluster sizes due to coordination
//! overhead". This model reproduces that scaling law: per-element work
//! distributed across workers with a serial fraction (Amdahl), per-worker
//! shuffle/coordination overhead, plus job-startup and bucket-ingest costs
//! (~700 MB/s from the same region, §4.2.2).

use crate::baselines::cpu_pandas::PandasModel;
use crate::dataio::dataset::DatasetSpec;
use crate::etl::pipelines::PipelineKind;

/// Beam cluster scaling model.
#[derive(Debug, Clone, Copy)]
pub struct BeamModel {
    /// vCPUs in the cluster.
    pub vcpus: usize,
    /// Dataflow job startup + graph-optimization time (s).
    pub startup_s: f64,
    /// Serial fraction of the pipeline (fusion barriers, vocab merges).
    pub serial_frac: f64,
    /// Per-worker coordination cost per stage (s) — grows with the
    /// cluster and eventually dominates.
    pub coord_per_worker_s: f64,
    /// GCS ingest bandwidth (bytes/s) shared by the cluster.
    pub ingest_bw: f64,
}

impl BeamModel {
    pub fn new(vcpus: usize) -> BeamModel {
        BeamModel {
            vcpus: vcpus.max(1),
            startup_s: 45.0,
            serial_frac: 0.04,
            coord_per_worker_s: 0.9,
            ingest_bw: 700.0e6,
        }
    }

    /// A Beam worker's per-row throughput is pandas-like (same Python
    /// transform code); reuse the calibrated single-thread cost.
    fn single_thread_seconds(&self, pipeline: PipelineKind, spec: &DatasetSpec) -> f64 {
        PandasModel::default().single_thread_seconds(pipeline, spec)
    }

    /// End-to-end job latency at paper scale.
    pub fn pipeline_seconds(&self, pipeline: PipelineKind, spec: &DatasetSpec) -> f64 {
        let work = self.single_thread_seconds(pipeline, spec);
        let n = self.vcpus as f64;
        let compute = work * self.serial_frac + work * (1.0 - self.serial_frac) / n;
        let coordination = self.coord_per_worker_s * n.sqrt() * 4.0;
        let ingest = spec.paper_bytes() as f64 / self.ingest_bw;
        self.startup_s + coordination + compute.max(ingest)
    }

    /// The cluster size sweep the paper reports (n2-standard-16..128).
    pub fn sweep(pipeline: PipelineKind, spec: &DatasetSpec) -> Vec<(usize, f64)> {
        [16usize, 32, 64, 96, 128]
            .iter()
            .map(|&v| (v, BeamModel::new(v).pipeline_seconds(pipeline, spec)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaling_has_diminishing_returns() {
        let spec = DatasetSpec::dataset_i(1.0);
        let sweep = BeamModel::sweep(PipelineKind::III, &spec);
        let t16 = sweep[0].1;
        let t64 = sweep[2].1;
        let t128 = sweep[4].1;
        // Bigger clusters help…
        assert!(t64 < t16);
        // …but the 64→128 gain is much smaller than the 16→64 gain.
        let gain_16_64 = t16 - t64;
        let gain_64_128 = t64 - t128;
        assert!(
            gain_64_128 < gain_16_64 * 0.5,
            "gains {gain_16_64} vs {gain_64_128}"
        );
    }

    #[test]
    fn startup_floor_for_small_work() {
        let mut spec = DatasetSpec::dataset_i(1.0);
        spec.paper_rows = 100_000; // tiny job
        let t = BeamModel::new(128).pipeline_seconds(PipelineKind::I, &spec);
        assert!(t >= 45.0);
    }

    #[test]
    fn beam_slower_than_local_pandas_on_dataset1() {
        // The paper's Fig. 13a: distributed Beam does not beat the tuned
        // local baseline at this scale.
        let spec = DatasetSpec::dataset_i(1.0);
        let pandas = PandasModel::default().pipeline_seconds(PipelineKind::I, &spec);
        let beam = BeamModel::new(128).pipeline_seconds(PipelineKind::I, &spec);
        assert!(beam > pandas);
    }

    #[test]
    fn ingest_bound_at_scale() {
        // Dataset-III: 1.5 TB at 700 MB/s dominates any compute speedup.
        let spec = DatasetSpec::dataset_iii(1.0);
        let t = BeamModel::new(128).pipeline_seconds(PipelineKind::I, &spec);
        let ingest_floor = spec.paper_bytes() as f64 / 700.0e6;
        assert!(t >= ingest_floor);
    }
}
