//! Comparison baselines (paper §4.2): real multithreaded CPU ETL plus
//! calibrated models of pandas, Apache Beam/Dataflow, and NVTabular on
//! RTX 3090/A100, and the GPU trainer consumption model.

pub mod beam;
pub mod cpu_pandas;
pub mod gpu_nvtabular;
pub mod trainer_model;

pub use beam::BeamModel;
pub use cpu_pandas::{PandasModel, RustCpuEtl};
pub use gpu_nvtabular::{GpuKind, GpuModel};
pub use trainer_model::{TrainerModel, CPU_ETL_BW_12CORE};

/// All platforms the evaluation compares (Tables 2/3, Figs. 13–16).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Platform {
    CpuPandas,
    CpuBeam,
    Rtx3090,
    A100,
    PipeRec,
}

impl Platform {
    pub fn label(&self) -> &'static str {
        match self {
            Platform::CpuPandas => "CPU (pandas)",
            Platform::CpuBeam => "CPU (Beam)",
            Platform::Rtx3090 => "RTX 3090",
            Platform::A100 => "A100",
            Platform::PipeRec => "PipeRec",
        }
    }
}
