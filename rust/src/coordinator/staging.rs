//! P2P staging with double buffering and credit-based backpressure
//! (paper §3/Fig. 3): the FPGA writes a packed batch into a free GPU
//! staging buffer only when the trainer has returned a credit; batch *i*
//! trains while batch *i+1* is ingested.
//!
//! Two implementations share the semantics:
//! * [`StagingSim`] — simulated-time model used by the overlap scheduler;
//! * [`StagingQueue`] — a real bounded channel used by the live training
//!   loop (producer = ETL thread, consumer = PJRT trainer).

use std::collections::VecDeque;
use std::sync::atomic::AtomicU64;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;

use crate::coordinator::packer::PackedBatch;
use crate::memsys::channel::ChannelModel;
use crate::util::sched::{self, site};

/// Simulated-time staging: tracks *when* each buffer becomes free, not
/// just how many credits exist — a credit returned at `t` cannot start a
/// transfer before `t`.
#[derive(Debug)]
pub struct StagingSim {
    /// Earliest times each staging buffer is free (one entry per credit).
    free_at: VecDeque<f64>,
    channel: ChannelModel,
    /// Total bytes staged.
    pub bytes: u64,
    /// Time the producer spent blocked on credits.
    pub blocked_s: f64,
    /// Stall events (producer arrived before any buffer was free).
    stalls: u64,
}

impl StagingSim {
    pub fn new(buffers: u32, channel: ChannelModel) -> StagingSim {
        StagingSim {
            free_at: (0..buffers).map(|_| 0.0).collect(),
            channel,
            bytes: 0,
            blocked_s: 0.0,
            stalls: 0,
        }
    }

    /// Producer pushes a batch of `bytes` at simulated time `now`;
    /// returns the time the batch is fully resident in GPU memory.
    pub fn push(&mut self, now: f64, bytes: u64) -> f64 {
        self.push_timed(now, bytes).1
    }

    /// Like [`push`] but also returns the transfer *start* time, so the
    /// caller can stall the upstream ETL clock while the producer waits
    /// for a credit (backpressure propagates, §3).
    pub fn push_timed(&mut self, now: f64, bytes: u64) -> (f64, f64) {
        let free = self
            .free_at
            .pop_front()
            .expect("push without a matching credit (more pushes than buffers + releases)");
        let start = if free > now {
            self.blocked_s += free - now;
            self.stalls += 1;
            free
        } else {
            now
        };
        self.bytes += bytes;
        (start, start + self.channel.time(bytes))
    }

    /// Trainer finishes with a buffer at time `t`, returning its credit.
    pub fn release(&mut self, t: f64) {
        self.free_at.push_back(t);
    }

    pub fn stalls(&self) -> u64 {
        self.stalls
    }
}

/// Live bounded staging queue: capacity = number of staging buffers.
/// `try_push` mirrors the credit semantics (non-blocking producer side for
/// backpressure accounting); `push` blocks like a stalled DMA engine.
///
/// Generic over the staged unit: the heap channel path stages owned
/// [`PackedBatch`]es (the default), the zero-copy path stages
/// [`crate::devmem::StagingSlot`]s whose payload the trainer consumes in
/// place.
pub struct StagingQueue<T = PackedBatch> {
    tx: SyncSender<T>,
    stalls: Arc<AtomicU64>,
}

/// Producer handles clone (the multi-device loop gives each per-device
/// pack worker one); the consumer sees the channel closed only once
/// **every** clone is dropped. All clones share one stall counter.
impl<T> Clone for StagingQueue<T> {
    fn clone(&self) -> Self {
        StagingQueue { tx: self.tx.clone(), stalls: Arc::clone(&self.stalls) }
    }
}

/// Consumer half of the staging queue.
pub struct StagingConsumer<T = PackedBatch> {
    rx: Receiver<T>,
}

impl<T> StagingQueue<T> {
    pub fn with_buffers(buffers: usize) -> (StagingQueue<T>, StagingConsumer<T>) {
        let (tx, rx) = sync_channel(buffers.max(1));
        (
            StagingQueue { tx, stalls: Arc::new(AtomicU64::new(0)) },
            StagingConsumer { rx },
        )
    }

    /// Shared handle to the stall counter (survives moving the queue into
    /// the producer thread — the queue must be *moved* so dropping it
    /// closes the channel and unblocks the consumer).
    pub fn stall_counter(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.stalls)
    }

    /// Stall events so far.
    pub fn stalls(&self) -> u64 {
        self.stalls.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Non-blocking push; returns the batch back when all buffers are full.
    pub fn try_push(&self, batch: T) -> Option<T> {
        sched::point(site::STAGING_PUSH);
        match self.tx.try_send(batch) {
            Ok(()) => None,
            Err(TrySendError::Full(b)) => {
                self.stalls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                Some(b)
            }
            Err(TrySendError::Disconnected(_)) => None,
        }
    }

    /// Blocking push (the DMA engine waits for a credit).
    pub fn push(&self, batch: T) -> bool {
        if let Some(b) = self.try_push(batch) {
            return self.tx.send(b).is_ok();
        }
        true
    }
}

impl<T> StagingConsumer<T> {
    /// Blocking pop; `None` once the producer hung up and the queue drained.
    pub fn pop(&self) -> Option<T> {
        sched::point(site::STAGING_POP);
        self.rx.recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsys::channel::Path;

    fn chan() -> ChannelModel {
        ChannelModel::of(Path::P2pToGpu)
    }

    #[test]
    fn double_buffering_overlaps_two_pushes() {
        let mut s = StagingSim::new(2, chan());
        let d1 = s.push(0.0, 1 << 20);
        let d2 = s.push(0.0, 1 << 20);
        // Both transfers start immediately (two credits).
        assert!(d1 > 0.0 && (d2 - d1).abs() < 1e-9);
        assert_eq!(s.stalls(), 0);
    }

    #[test]
    fn third_push_blocks_until_release() {
        let mut s = StagingSim::new(2, chan());
        let _ = s.push(0.0, 1 << 20);
        let _ = s.push(0.0, 1 << 20);
        s.release(5.0); // trainer frees the first buffer at t=5
        let d3 = s.push(0.0, 1 << 20);
        assert!(d3 >= 5.0, "d3={d3}");
        assert_eq!(s.stalls(), 1);
        assert!(s.blocked_s >= 5.0 - 1e-9);
    }

    #[test]
    fn live_queue_backpressures() {
        let (q, c) = StagingQueue::with_buffers(1);
        let b = PackedBatch {
            rows: 1,
            n_dense: 1,
            n_sparse: 1,
            dense: vec![0.0],
            sparse: vec![0],
            labels: vec![0.0],
        };
        assert!(q.try_push(b.clone()).is_none()); // first fits
        assert!(q.try_push(b.clone()).is_some()); // second bounces
        assert_eq!(q.stalls(), 1);
        let got = c.pop().unwrap();
        assert_eq!(got.rows, 1);
        assert!(q.try_push(b).is_none()); // space again
    }

    #[test]
    fn queue_drains_after_producer_drop() {
        let (q, c) = StagingQueue::with_buffers(2);
        let b = PackedBatch {
            rows: 2,
            n_dense: 0,
            n_sparse: 0,
            dense: vec![],
            sparse: vec![],
            labels: vec![0.0, 1.0],
        };
        q.push(b);
        drop(q);
        assert!(c.pop().is_some());
        assert!(c.pop().is_none());
    }
}
