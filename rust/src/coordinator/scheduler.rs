//! Co-scheduling runtime (paper §3, Fig. 3/8, evaluated in Fig. 14):
//! overlaps ETL, P2P transfer and training with double buffering, tracks
//! per-window GPU utilization, and reproduces the end-to-end contrast
//! between the CPU–GPU pipeline (irregular delivery, fluctuating
//! utilization) and the FPGA–GPU pipeline (stable, near-saturated).

use crate::coordinator::staging::StagingSim;
use crate::memsys::channel::ChannelModel;
use crate::metrics::TimeSeries;
use crate::util::prng::Rng;

/// Configuration of one overlap simulation.
#[derive(Debug, Clone)]
pub struct OverlapConfig {
    /// Number of batches to run.
    pub batches: usize,
    /// ETL time per batch (s) — the producer's steady rate.
    pub etl_s: f64,
    /// Multiplicative jitter on ETL per batch (0 = deterministic; the
    /// CPU–GPU pipeline's delivery is highly irregular, §4.4).
    pub etl_jitter: f64,
    /// Training step time per batch (s).
    pub train_s: f64,
    /// Packed batch size (bytes) for the P2P transfer.
    pub batch_bytes: u64,
    /// Transfer channel (P2P for PipeRec; host-staged copy for CPU–GPU).
    pub channel: ChannelModel,
    /// Staging buffers / credits (2 = double buffering).
    pub staging_buffers: u32,
    /// RNG seed for jitter.
    pub seed: u64,
}

/// Result of an overlap simulation.
#[derive(Debug, Clone)]
pub struct OverlapResult {
    /// Wall-clock (simulated) end-to-end seconds.
    pub total_s: f64,
    /// Total GPU-busy seconds.
    pub busy_s: f64,
    /// Mean GPU utilization.
    pub mean_util: f64,
    /// Per-window utilization trace (Fig. 14).
    pub trace: TimeSeries,
    /// Producer seconds blocked on backpressure credits.
    pub producer_blocked_s: f64,
}

/// Simulate the pipelined execution and produce the utilization trace.
pub fn simulate_overlap(cfg: &OverlapConfig) -> OverlapResult {
    let mut rng = Rng::new(cfg.seed);
    let mut staging = StagingSim::new(cfg.staging_buffers, cfg.channel);

    let mut etl_free = 0.0f64; // when the ETL engine can start the next batch
    let mut gpu_free = 0.0f64; // when the GPU finishes its current step
    let mut busy_intervals: Vec<(f64, f64)> = Vec::with_capacity(cfg.batches);

    for _ in 0..cfg.batches {
        // ETL produces the batch (jittered for irregular CPU delivery).
        let jitter = if cfg.etl_jitter > 0.0 {
            // Log-normal-ish multiplicative noise, occasionally heavy:
            // stragglers in the preprocessing workers.
            let z = rng.normal();
            (1.0 + cfg.etl_jitter * z).max(0.2)
        } else {
            1.0
        };
        let etl_done = etl_free + cfg.etl_s * jitter;

        // Transfer into a staging buffer (credit-gated). Backpressure
        // stalls the ETL engine: the next batch cannot start until this
        // one has been handed off to a free buffer.
        let (handoff, arrived) = staging.push_timed(etl_done, cfg.batch_bytes);
        etl_free = handoff;

        // Train when both the data and the GPU are ready.
        let start = arrived.max(gpu_free);
        let end = start + cfg.train_s;
        busy_intervals.push((start, end));
        gpu_free = end;
        staging.release(end);
    }

    let total_s = gpu_free;
    let busy_s: f64 = busy_intervals.iter().map(|(s, e)| e - s).sum();

    // Utilization trace over fixed windows (~100 windows).
    let window = (total_s / 100.0).max(1e-9);
    let mut trace = TimeSeries::default();
    let mut w_start = 0.0;
    let mut i = 0usize;
    while w_start + window <= total_s + 1e-12 {
        let w_end = w_start + window;
        let mut busy = 0.0;
        // Sum overlap of busy intervals with this window.
        for (s, e) in busy_intervals[i..].iter() {
            if *s >= w_end {
                break;
            }
            busy += (e.min(w_end) - s.max(w_start)).max(0.0);
        }
        // Advance i past intervals fully before the next window.
        while i < busy_intervals.len() && busy_intervals[i].1 <= w_end {
            i += 1;
        }
        trace.push(w_start + window / 2.0, (busy / window).min(1.0));
        w_start = w_end;
    }

    OverlapResult {
        total_s,
        busy_s,
        mean_util: busy_s / total_s,
        trace,
        producer_blocked_s: staging.blocked_s,
    }
}

/// The two end-to-end systems the paper contrasts (Fig. 8/14).
pub fn piperec_config(batches: usize, etl_s: f64, train_s: f64, batch_bytes: u64) -> OverlapConfig {
    OverlapConfig {
        batches,
        etl_s,
        etl_jitter: 0.0,
        train_s,
        batch_bytes,
        channel: ChannelModel::of(crate::memsys::channel::Path::P2pToGpu),
        staging_buffers: 2,
        seed: 0x9e37,
    }
}

pub fn cpu_gpu_config(batches: usize, etl_s: f64, train_s: f64, batch_bytes: u64) -> OverlapConfig {
    OverlapConfig {
        batches,
        etl_s,
        etl_jitter: 0.8, // irregular delivery from CPU workers
        train_s,
        batch_bytes,
        // Staged copy through host DRAM (slower effective path).
        channel: ChannelModel::of(crate::memsys::channel::Path::CpuFpgaCpu),
        staging_buffers: 2,
        seed: 0x9e37,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_etl_keeps_gpu_saturated() {
        // PipeRec regime: ETL faster than training ⇒ util near 1.
        let cfg = piperec_config(500, 0.5e-3, 5e-3, 4 << 20);
        let r = simulate_overlap(&cfg);
        assert!(r.mean_util > 0.9, "util={}", r.mean_util);
        assert!(r.trace.cv() < 0.15, "cv={}", r.trace.cv());
    }

    #[test]
    fn slow_etl_leaves_gpu_idle() {
        // CPU regime: ETL ~12× slower than training ⇒ util ~1/12.
        let cfg = cpu_gpu_config(300, 60e-3, 5e-3, 4 << 20);
        let r = simulate_overlap(&cfg);
        assert!(r.mean_util < 0.15, "util={}", r.mean_util);
        // And the trace is unstable (fluctuating delivery).
        assert!(r.trace.cv() > 0.2, "cv={}", r.trace.cv());
    }

    #[test]
    fn end_to_end_speedup_matches_paper_order() {
        // Same 300 batches: CPU-bound pipeline vs PipeRec-fed pipeline.
        let train_s = 5e-3;
        let cpu = simulate_overlap(&cpu_gpu_config(300, 60e-3, train_s, 4 << 20));
        let pr = simulate_overlap(&piperec_config(300, 0.5e-3, train_s, 4 << 20));
        let speedup = cpu.total_s / pr.total_s;
        // Paper: end-to-end training time reduced ~10× (9.94%).
        assert!(speedup > 7.0 && speedup < 16.0, "speedup={speedup}");
    }

    #[test]
    fn backpressure_blocks_fast_producer() {
        // ETL much faster than training: producer must block on credits.
        let cfg = piperec_config(200, 0.1e-3, 10e-3, 4 << 20);
        let r = simulate_overlap(&cfg);
        assert!(r.producer_blocked_s > 0.0);
        // GPU never starves though.
        assert!(r.mean_util > 0.95);
    }

    #[test]
    fn busy_time_equals_batches_times_train() {
        let cfg = piperec_config(100, 1e-3, 2e-3, 1 << 20);
        let r = simulate_overlap(&cfg);
        assert!((r.busy_s - 100.0 * 2e-3).abs() < 1e-9);
        assert!(r.total_s >= r.busy_s);
    }
}
