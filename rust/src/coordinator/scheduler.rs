//! Co-scheduling runtime (paper §3, Fig. 3/8, evaluated in Fig. 14):
//! overlaps ETL, P2P transfer and training with double buffering, tracks
//! per-window GPU utilization, and reproduces the end-to-end contrast
//! between the CPU–GPU pipeline (irregular delivery, fluctuating
//! utilization) and the FPGA–GPU pipeline (stable, near-saturated).
//!
//! The scheduler also owns the fleet's **routing layer**
//! ([`DeviceRouter`]): when the staging dataflow feeds N simulated GPUs
//! (`devmem::ArenaSet`), every ingested shard is assigned a device lane
//! under a [`RoutePolicy`] — round-robin pins a bit-reproducible
//! assignment, least-loaded follows the per-device outstanding-byte
//! ledger ([`LoadTracker`]) for throughput under skewed shard costs.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::coordinator::staging::StagingSim;
use crate::memsys::channel::ChannelModel;
use crate::metrics::TimeSeries;
use crate::util::prng::Rng;

/// Configuration of one overlap simulation.
#[derive(Debug, Clone)]
pub struct OverlapConfig {
    /// Number of batches to run.
    pub batches: usize,
    /// ETL time per batch (s) — the producer's steady rate.
    pub etl_s: f64,
    /// Multiplicative jitter on ETL per batch (0 = deterministic; the
    /// CPU–GPU pipeline's delivery is highly irregular, §4.4).
    pub etl_jitter: f64,
    /// Training step time per batch (s).
    pub train_s: f64,
    /// Packed batch size (bytes) for the P2P transfer.
    pub batch_bytes: u64,
    /// Transfer channel (P2P for PipeRec; host-staged copy for CPU–GPU).
    pub channel: ChannelModel,
    /// Staging buffers / credits (2 = double buffering).
    pub staging_buffers: u32,
    /// RNG seed for jitter.
    pub seed: u64,
}

/// Result of an overlap simulation.
#[derive(Debug, Clone)]
pub struct OverlapResult {
    /// Wall-clock (simulated) end-to-end seconds.
    pub total_s: f64,
    /// Total GPU-busy seconds.
    pub busy_s: f64,
    /// Mean GPU utilization.
    pub mean_util: f64,
    /// Per-window utilization trace (Fig. 14).
    pub trace: TimeSeries,
    /// Producer seconds blocked on backpressure credits.
    pub producer_blocked_s: f64,
}

/// Simulate the pipelined execution and produce the utilization trace.
pub fn simulate_overlap(cfg: &OverlapConfig) -> OverlapResult {
    if cfg.batches == 0 {
        // Degenerate run: nothing executed, nothing traced. Guarding here
        // keeps `mean_util` finite (0/0 would be NaN, which poisons any
        // downstream Fig. 14 aggregation).
        return OverlapResult {
            total_s: 0.0,
            busy_s: 0.0,
            mean_util: 0.0,
            trace: TimeSeries::default(),
            producer_blocked_s: 0.0,
        };
    }
    let mut rng = Rng::new(cfg.seed);
    let mut staging = StagingSim::new(cfg.staging_buffers, cfg.channel);

    let mut etl_free = 0.0f64; // when the ETL engine can start the next batch
    let mut gpu_free = 0.0f64; // when the GPU finishes its current step
    let mut busy_intervals: Vec<(f64, f64)> = Vec::with_capacity(cfg.batches);

    for _ in 0..cfg.batches {
        // ETL produces the batch (jittered for irregular CPU delivery).
        let jitter = if cfg.etl_jitter > 0.0 {
            // Log-normal-ish multiplicative noise, occasionally heavy:
            // stragglers in the preprocessing workers.
            let z = rng.normal();
            (1.0 + cfg.etl_jitter * z).max(0.2)
        } else {
            1.0
        };
        let etl_done = etl_free + cfg.etl_s * jitter;

        // Transfer into a staging buffer (credit-gated). Backpressure
        // stalls the ETL engine: the next batch cannot start until this
        // one has been handed off to a free buffer.
        let (handoff, arrived) = staging.push_timed(etl_done, cfg.batch_bytes);
        etl_free = handoff;

        // Train when both the data and the GPU are ready.
        let start = arrived.max(gpu_free);
        let end = start + cfg.train_s;
        busy_intervals.push((start, end));
        gpu_free = end;
        staging.release(end);
    }

    let total_s = gpu_free;
    let busy_s: f64 = busy_intervals.iter().map(|(s, e)| e - s).sum();

    // Utilization trace over fixed windows (~100 windows).
    let window = (total_s / 100.0).max(1e-9);
    let trace = utilization_trace(&busy_intervals, total_s, window);

    OverlapResult {
        total_s,
        busy_s,
        mean_util: if total_s > 0.0 { busy_s / total_s } else { 0.0 },
        trace,
        producer_blocked_s: staging.blocked_s,
    }
}

/// Per-window utilization trace over `[0, total_s)` (Fig. 14): each point
/// is (window center, busy fraction). The trace covers **all** of
/// `total_s` — the trailing window may be shorter than `window` and is
/// normalized by its actual width, so busy time after the last full
/// window is never silently dropped (it always counted toward the mean;
/// now it shows in the trace too).
///
/// `busy_intervals` must be sorted by start time and non-overlapping (the
/// single-GPU step sequence of `simulate_overlap` satisfies both).
pub fn utilization_trace(
    busy_intervals: &[(f64, f64)],
    total_s: f64,
    window: f64,
) -> TimeSeries {
    let mut trace = TimeSeries::default();
    if total_s <= 0.0 || window <= 0.0 {
        return trace;
    }
    let mut w_start = 0.0f64;
    let mut i = 0usize;
    // The epsilon absorbs the float drift of repeated `w_start = w_end`
    // accumulation: a genuine partial window is emitted, a sliver of pure
    // rounding noise (≪ one window wide) is not.
    let eps = window * 1e-6;
    while w_start < total_s - eps {
        let w_end = (w_start + window).min(total_s);
        let width = w_end - w_start;
        let mut busy = 0.0;
        // Sum overlap of busy intervals with this window.
        for (s, e) in busy_intervals[i..].iter() {
            if *s >= w_end {
                break;
            }
            busy += (e.min(w_end) - s.max(w_start)).max(0.0);
        }
        // Advance i past intervals fully before the next window.
        while i < busy_intervals.len() && busy_intervals[i].1 <= w_end {
            i += 1;
        }
        trace.push(w_start + width / 2.0, (busy / width).min(1.0));
        w_start = w_end;
    }
    trace
}

/// How the fleet's routing layer assigns ingested shards to devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Device `k mod N` for the `k`-th routed shard — a bit-reproducible
    /// assignment (the differential-testing and replay mode).
    RoundRobin,
    /// The device with the fewest outstanding routed bytes (ties break to
    /// the lowest index) — throughput mode under skewed shard costs.
    LeastLoaded,
}

/// Shared per-device outstanding-byte ledger: the router charges a device
/// when a shard is routed to it, the consumer credits it back when the
/// device finishes the batch. Lock-free so the routing thread and the
/// consumer thread never contend.
#[derive(Debug)]
pub struct LoadTracker {
    loads: Vec<AtomicU64>,
}

impl LoadTracker {
    fn new(devices: usize) -> LoadTracker {
        LoadTracker { loads: (0..devices).map(|_| AtomicU64::new(0)).collect() }
    }

    /// Outstanding routed bytes on `device`.
    pub fn load(&self, device: usize) -> u64 {
        self.loads[device].load(Ordering::Relaxed)
    }

    fn charge(&self, device: usize, bytes: u64) {
        self.loads[device].fetch_add(bytes, Ordering::Relaxed);
    }

    /// Credit `bytes` back once `device` finished the routed work.
    pub fn complete(&self, device: usize, bytes: u64) {
        // Saturating: a double-complete must not wrap the ledger.
        let mut cur = self.loads[device].load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(bytes);
            match self.loads[device].compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Snapshot of every device's outstanding bytes.
    pub fn snapshot(&self) -> Vec<u64> {
        self.loads.iter().map(|l| l.load(Ordering::Relaxed)).collect()
    }
}

/// The shard→device routing layer of the multi-device train loop: the
/// producer asks `route(bytes)` for each ingested shard, the consumer
/// calls [`LoadTracker::complete`] when the device finishes it.
#[derive(Debug)]
pub struct DeviceRouter {
    policy: RoutePolicy,
    next: usize,
    routed: u64,
    tracker: Arc<LoadTracker>,
}

impl DeviceRouter {
    pub fn new(devices: usize, policy: RoutePolicy) -> DeviceRouter {
        assert!(devices >= 1, "router needs at least one device");
        DeviceRouter {
            policy,
            next: 0,
            routed: 0,
            tracker: Arc::new(LoadTracker::new(devices)),
        }
    }

    /// Number of device lanes.
    pub fn devices(&self) -> usize {
        self.tracker.loads.len()
    }

    /// Shards routed so far.
    pub fn routed(&self) -> u64 {
        self.routed
    }

    /// Shared handle to the outstanding-load ledger (hand it to the
    /// consumer side).
    pub fn tracker(&self) -> Arc<LoadTracker> {
        Arc::clone(&self.tracker)
    }

    /// Pick the device for the next shard of `bytes` and charge its lane.
    pub fn route(&mut self, bytes: u64) -> usize {
        let n = self.devices();
        let d = match self.policy {
            RoutePolicy::RoundRobin => {
                let d = self.next;
                self.next = (self.next + 1) % n;
                d
            }
            RoutePolicy::LeastLoaded => {
                let mut best = 0usize;
                let mut best_load = self.tracker.load(0);
                for d in 1..n {
                    let l = self.tracker.load(d);
                    if l < best_load {
                        best = d;
                        best_load = l;
                    }
                }
                best
            }
        };
        self.tracker.charge(d, bytes);
        self.routed += 1;
        d
    }
}

/// The two end-to-end systems the paper contrasts (Fig. 8/14).
pub fn piperec_config(batches: usize, etl_s: f64, train_s: f64, batch_bytes: u64) -> OverlapConfig {
    OverlapConfig {
        batches,
        etl_s,
        etl_jitter: 0.0,
        train_s,
        batch_bytes,
        channel: ChannelModel::of(crate::memsys::channel::Path::P2pToGpu),
        staging_buffers: 2,
        seed: 0x9e37,
    }
}

pub fn cpu_gpu_config(batches: usize, etl_s: f64, train_s: f64, batch_bytes: u64) -> OverlapConfig {
    OverlapConfig {
        batches,
        etl_s,
        etl_jitter: 0.8, // irregular delivery from CPU workers
        train_s,
        batch_bytes,
        // Staged copy through host DRAM (slower effective path).
        channel: ChannelModel::of(crate::memsys::channel::Path::CpuFpgaCpu),
        staging_buffers: 2,
        seed: 0x9e37,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_etl_keeps_gpu_saturated() {
        // PipeRec regime: ETL faster than training ⇒ util near 1.
        let cfg = piperec_config(500, 0.5e-3, 5e-3, 4 << 20);
        let r = simulate_overlap(&cfg);
        assert!(r.mean_util > 0.9, "util={}", r.mean_util);
        assert!(r.trace.cv() < 0.15, "cv={}", r.trace.cv());
    }

    #[test]
    fn slow_etl_leaves_gpu_idle() {
        // CPU regime: ETL ~12× slower than training ⇒ util ~1/12.
        let cfg = cpu_gpu_config(300, 60e-3, 5e-3, 4 << 20);
        let r = simulate_overlap(&cfg);
        assert!(r.mean_util < 0.15, "util={}", r.mean_util);
        // And the trace is unstable (fluctuating delivery).
        assert!(r.trace.cv() > 0.2, "cv={}", r.trace.cv());
    }

    #[test]
    fn end_to_end_speedup_matches_paper_order() {
        // Same 300 batches: CPU-bound pipeline vs PipeRec-fed pipeline.
        let train_s = 5e-3;
        let cpu = simulate_overlap(&cpu_gpu_config(300, 60e-3, train_s, 4 << 20));
        let pr = simulate_overlap(&piperec_config(300, 0.5e-3, train_s, 4 << 20));
        let speedup = cpu.total_s / pr.total_s;
        // Paper: end-to-end training time reduced ~10× (9.94%).
        assert!(speedup > 7.0 && speedup < 16.0, "speedup={speedup}");
    }

    #[test]
    fn backpressure_blocks_fast_producer() {
        // ETL much faster than training: producer must block on credits.
        let cfg = piperec_config(200, 0.1e-3, 10e-3, 4 << 20);
        let r = simulate_overlap(&cfg);
        assert!(r.producer_blocked_s > 0.0);
        // GPU never starves though.
        assert!(r.mean_util > 0.95);
    }

    #[test]
    fn busy_time_equals_batches_times_train() {
        let cfg = piperec_config(100, 1e-3, 2e-3, 1 << 20);
        let r = simulate_overlap(&cfg);
        assert!((r.busy_s - 100.0 * 2e-3).abs() < 1e-9);
        assert!(r.total_s >= r.busy_s);
    }

    #[test]
    fn zero_batches_returns_finite_zeroed_stats() {
        // batches == 0 used to produce mean_util = 0.0/0.0 = NaN, which
        // poisons any Fig. 14 aggregation it flows into.
        let cfg = piperec_config(0, 1e-3, 2e-3, 1 << 20);
        let r = simulate_overlap(&cfg);
        assert_eq!(r.total_s, 0.0);
        assert_eq!(r.busy_s, 0.0);
        assert!(r.mean_util.is_finite(), "util must not be NaN");
        assert_eq!(r.mean_util, 0.0);
        assert!(r.trace.points.is_empty());
        assert_eq!(r.producer_blocked_s, 0.0);
    }

    #[test]
    fn trace_emits_trailing_partial_window() {
        // One busy interval covering all of [0, 1.0); a 0.3 s window
        // leaves a 0.1 s tail that the old loop silently dropped.
        let intervals = [(0.0, 1.0)];
        let trace = utilization_trace(&intervals, 1.0, 0.3);
        assert_eq!(trace.points.len(), 4, "3 full windows + 1 partial");
        // The partial window is centered in its actual width …
        let (t_last, u_last) = *trace.points.last().unwrap();
        assert!((t_last - 0.95).abs() < 1e-12, "center {t_last}");
        // … and normalized by it: fully busy, not 1/3 busy.
        assert!((u_last - 1.0).abs() < 1e-12, "util {u_last}");
    }

    #[test]
    fn trace_covers_total_and_conserves_busy_time() {
        // Busy time after the last full window must appear in the trace:
        // Σ util_i × width_i == busy_s, and the windows tile [0, total).
        let intervals = [(0.1, 0.4), (0.75, 1.1), (1.15, 1.2)];
        let busy: f64 = intervals.iter().map(|(s, e)| e - s).sum();
        let total = 1.2;
        let window = 0.5; // 2 full windows + a 0.2 partial
        let trace = utilization_trace(&intervals, total, window);
        assert_eq!(trace.points.len(), 3);
        let mut covered = 0.0;
        let mut weighted = 0.0;
        for &(center, util) in &trace.points {
            let width = 2.0 * (center - covered);
            covered += width;
            weighted += util * width;
        }
        assert!((covered - total).abs() < 1e-9, "covered {covered} of {total}");
        assert!((weighted - busy).abs() < 1e-9, "trace busy {weighted} vs {busy}");
    }

    #[test]
    fn simulate_overlap_trace_covers_all_of_total() {
        // End-to-end: the last window's right edge reaches total_s.
        let r = simulate_overlap(&piperec_config(37, 1.3e-3, 2.1e-3, 1 << 20));
        assert!(!r.trace.points.is_empty());
        let mut covered = 0.0;
        for &(center, _) in &r.trace.points {
            covered += 2.0 * (center - covered);
        }
        assert!(
            (covered - r.total_s).abs() < 1e-9 * r.total_s.max(1.0),
            "trace covers {covered} of {}",
            r.total_s
        );
    }

    #[test]
    fn round_robin_routing_cycles_deterministically() {
        let mut r = DeviceRouter::new(3, RoutePolicy::RoundRobin);
        let picks: Vec<usize> = (0..7).map(|_| r.route(10)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
        assert_eq!(r.routed(), 7);
        assert_eq!(r.tracker().snapshot(), vec![30, 20, 20]);
    }

    #[test]
    fn least_loaded_routing_follows_the_ledger() {
        let mut r = DeviceRouter::new(3, RoutePolicy::LeastLoaded);
        let t = r.tracker();
        // Empty ledger: ties break to the lowest index.
        assert_eq!(r.route(100), 0);
        assert_eq!(r.route(10), 1);
        assert_eq!(r.route(10), 2);
        // Device 0 carries the most outstanding bytes → avoided.
        assert_eq!(r.route(10), 1);
        // Completing device 0's big shard makes it least loaded again.
        t.complete(0, 100);
        assert_eq!(r.route(10), 0);
        // Over-completion saturates at zero instead of wrapping.
        t.complete(2, 1 << 40);
        assert_eq!(t.load(2), 0);
    }
}
