//! Co-scheduling runtime (paper §3, Fig. 3/8, evaluated in Fig. 14):
//! overlaps ETL, P2P transfer and training with double buffering, tracks
//! per-window GPU utilization, and reproduces the end-to-end contrast
//! between the CPU–GPU pipeline (irregular delivery, fluctuating
//! utilization) and the FPGA–GPU pipeline (stable, near-saturated).
//!
//! The scheduler also owns the fleet's **routing layer**
//! ([`DeviceRouter`]): when the staging dataflow feeds N simulated GPUs
//! (`devmem::ArenaSet`), every ingested shard is assigned a device lane
//! under a [`RoutePolicy`] — round-robin pins a bit-reproducible
//! assignment, least-loaded follows the per-device outstanding-byte
//! ledger ([`LoadTracker`]) for throughput under skewed shard costs —
//! and the fleet's **barrier-free gradient all-reduce** ([`ReduceBus`]):
//! concurrent per-device trainer replicas post epoch-tagged f64
//! gradient-level contributions and block only on the resolution of the
//! epoch their next step depends on, never on a rendezvous barrier (see
//! the `ReduceBus` docs for the epoch protocol).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::coordinator::staging::StagingSim;
use crate::error::{EtlError, Result};
use crate::memsys::channel::ChannelModel;
use crate::metrics::TimeSeries;
use crate::runtime::GradStep;
use crate::util::prng::Rng;
use crate::util::sched::{self, site};

/// Configuration of one overlap simulation.
#[derive(Debug, Clone)]
pub struct OverlapConfig {
    /// Number of batches to run.
    pub batches: usize,
    /// ETL time per batch (s) — the producer's steady rate.
    pub etl_s: f64,
    /// Multiplicative jitter on ETL per batch (0 = deterministic; the
    /// CPU–GPU pipeline's delivery is highly irregular, §4.4).
    pub etl_jitter: f64,
    /// Training step time per batch (s).
    pub train_s: f64,
    /// Packed batch size (bytes) for the P2P transfer.
    pub batch_bytes: u64,
    /// Transfer channel (P2P for PipeRec; host-staged copy for CPU–GPU).
    pub channel: ChannelModel,
    /// Staging buffers / credits (2 = double buffering).
    pub staging_buffers: u32,
    /// RNG seed for jitter.
    pub seed: u64,
}

/// Result of an overlap simulation.
#[derive(Debug, Clone)]
pub struct OverlapResult {
    /// Wall-clock (simulated) end-to-end seconds.
    pub total_s: f64,
    /// Total GPU-busy seconds.
    pub busy_s: f64,
    /// Mean GPU utilization.
    pub mean_util: f64,
    /// Per-window utilization trace (Fig. 14).
    pub trace: TimeSeries,
    /// Producer seconds blocked on backpressure credits.
    pub producer_blocked_s: f64,
}

/// Simulate the pipelined execution and produce the utilization trace.
pub fn simulate_overlap(cfg: &OverlapConfig) -> OverlapResult {
    if cfg.batches == 0 {
        // Degenerate run: nothing executed, nothing traced. Guarding here
        // keeps `mean_util` finite (0/0 would be NaN, which poisons any
        // downstream Fig. 14 aggregation).
        return OverlapResult {
            total_s: 0.0,
            busy_s: 0.0,
            mean_util: 0.0,
            trace: TimeSeries::default(),
            producer_blocked_s: 0.0,
        };
    }
    let mut rng = Rng::new(cfg.seed);
    let mut staging = StagingSim::new(cfg.staging_buffers, cfg.channel);

    let mut etl_free = 0.0f64; // when the ETL engine can start the next batch
    let mut gpu_free = 0.0f64; // when the GPU finishes its current step
    let mut busy_intervals: Vec<(f64, f64)> = Vec::with_capacity(cfg.batches);

    for _ in 0..cfg.batches {
        // ETL produces the batch (jittered for irregular CPU delivery).
        let jitter = if cfg.etl_jitter > 0.0 {
            // Log-normal-ish multiplicative noise, occasionally heavy:
            // stragglers in the preprocessing workers.
            let z = rng.normal();
            (1.0 + cfg.etl_jitter * z).max(0.2)
        } else {
            1.0
        };
        let etl_done = etl_free + cfg.etl_s * jitter;

        // Transfer into a staging buffer (credit-gated). Backpressure
        // stalls the ETL engine: the next batch cannot start until this
        // one has been handed off to a free buffer.
        let (handoff, arrived) = staging.push_timed(etl_done, cfg.batch_bytes);
        etl_free = handoff;

        // Train when both the data and the GPU are ready.
        let start = arrived.max(gpu_free);
        let end = start + cfg.train_s;
        busy_intervals.push((start, end));
        gpu_free = end;
        staging.release(end);
    }

    let total_s = gpu_free;
    let busy_s: f64 = busy_intervals.iter().map(|(s, e)| e - s).sum();

    // Utilization trace over fixed windows (~100 windows).
    let window = (total_s / 100.0).max(1e-9);
    let trace = utilization_trace(&busy_intervals, total_s, window);

    OverlapResult {
        total_s,
        busy_s,
        mean_util: if total_s > 0.0 { busy_s / total_s } else { 0.0 },
        trace,
        producer_blocked_s: staging.blocked_s,
    }
}

/// Per-window utilization trace over `[0, total_s)` (Fig. 14): each point
/// is (window center, busy fraction). The trace covers **all** of
/// `total_s` — the trailing window may be shorter than `window` and is
/// normalized by its actual width, so busy time after the last full
/// window is never silently dropped (it always counted toward the mean;
/// now it shows in the trace too).
///
/// `busy_intervals` must be sorted by start time and non-overlapping (the
/// single-GPU step sequence of `simulate_overlap` satisfies both).
pub fn utilization_trace(
    busy_intervals: &[(f64, f64)],
    total_s: f64,
    window: f64,
) -> TimeSeries {
    let mut trace = TimeSeries::default();
    if total_s <= 0.0 || window <= 0.0 {
        return trace;
    }
    let mut w_start = 0.0f64;
    let mut i = 0usize;
    // The epsilon absorbs the float drift of repeated `w_start = w_end`
    // accumulation: a genuine partial window is emitted, a sliver of pure
    // rounding noise (≪ one window wide) is not.
    let eps = window * 1e-6;
    while w_start < total_s - eps {
        let w_end = (w_start + window).min(total_s);
        let width = w_end - w_start;
        let mut busy = 0.0;
        // Sum overlap of busy intervals with this window.
        for (s, e) in busy_intervals[i..].iter() {
            if *s >= w_end {
                break;
            }
            busy += (e.min(w_end) - s.max(w_start)).max(0.0);
        }
        // Advance i past intervals fully before the next window.
        while i < busy_intervals.len() && busy_intervals[i].1 <= w_end {
            i += 1;
        }
        trace.push(w_start + width / 2.0, (busy / width).min(1.0));
        w_start = w_end;
    }
    trace
}

/// How the fleet's routing layer assigns ingested shards to devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Device `k mod N` for the `k`-th routed shard — a bit-reproducible
    /// assignment (the differential-testing and replay mode).
    RoundRobin,
    /// The device with the fewest outstanding routed bytes (ties break to
    /// the lowest index) — throughput mode under skewed shard costs.
    LeastLoaded,
}

/// Shared per-device outstanding-byte ledger: the router charges a device
/// when a shard is routed to it, the consumer credits it back when the
/// device finishes the batch. Lock-free so the routing thread and the
/// consumer thread never contend.
#[derive(Debug)]
pub struct LoadTracker {
    loads: Vec<AtomicU64>,
}

impl LoadTracker {
    fn new(devices: usize) -> LoadTracker {
        LoadTracker { loads: (0..devices).map(|_| AtomicU64::new(0)).collect() }
    }

    /// Outstanding routed bytes on `device`.
    pub fn load(&self, device: usize) -> u64 {
        self.loads[device].load(Ordering::Relaxed)
    }

    fn charge(&self, device: usize, bytes: u64) {
        self.loads[device].fetch_add(bytes, Ordering::Relaxed);
    }

    /// Credit `bytes` back once `device` finished the routed work.
    pub fn complete(&self, device: usize, bytes: u64) {
        // Saturating: a double-complete must not wrap the ledger.
        let mut cur = self.loads[device].load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(bytes);
            match self.loads[device].compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Zero `device`'s ledger. Called on lane death and (re)admission: a
    /// retired lane's outstanding bytes must not linger and skew
    /// `LeastLoaded` against it when it later rejoins. In-flight
    /// `complete` calls for slots the lane still drains saturate at 0.
    pub fn clear(&self, device: usize) {
        self.loads[device].store(0, Ordering::Relaxed);
    }

    /// Snapshot of every device's outstanding bytes.
    pub fn snapshot(&self) -> Vec<u64> {
        self.loads.iter().map(|l| l.load(Ordering::Relaxed)).collect()
    }

    /// Snapshot with every non-routable lane masked out (`None`): the
    /// routing view. A `Draining` lane after a `RemoveLane` — or a dead
    /// one — must never appear in a `LeastLoaded` decision even though
    /// its ledger entry still moves while its queued slots finish
    /// (`clear` zeroes it, making it spuriously the *minimum*, not just
    /// stale). Masking here rather than at each call site makes the
    /// routing view the API; pinned by
    /// `draining_lane_is_masked_out_of_least_loaded`.
    pub fn snapshot_masked(&self, routable: &[bool]) -> Vec<Option<u64>> {
        self.loads
            .iter()
            .enumerate()
            .map(|(d, l)| {
                routable.get(d).copied().unwrap_or(false).then(|| l.load(Ordering::Relaxed))
            })
            .collect()
    }
}

/// The shard→device routing layer of the multi-device train loop: the
/// producer asks `route(bytes)` for each ingested shard, the consumer
/// calls [`LoadTracker::complete`] when the device finishes it.
#[derive(Debug)]
pub struct DeviceRouter {
    policy: RoutePolicy,
    next: usize,
    routed: u64,
    /// Lane liveness mask — [`mark_dead`](Self::mark_dead) retires a lane
    /// and the router stops assigning shards to it.
    alive: Vec<bool>,
    tracker: Arc<LoadTracker>,
}

impl DeviceRouter {
    pub fn new(devices: usize, policy: RoutePolicy) -> DeviceRouter {
        DeviceRouter::with_capacity(devices, devices, policy)
    }

    /// Router over `devices` live lanes with ledger capacity for `peak`
    /// lanes: scripted lane-adds ([`extend`](Self::extend) +
    /// [`mark_alive`](Self::mark_alive)) grow into the reserve without
    /// reallocating the shared lock-free [`LoadTracker`].
    pub fn with_capacity(devices: usize, peak: usize, policy: RoutePolicy) -> DeviceRouter {
        assert!(devices >= 1, "router needs at least one device");
        assert!(peak >= devices, "peak lane capacity below the initial fleet");
        DeviceRouter {
            policy,
            next: 0,
            routed: 0,
            alive: vec![true; devices],
            tracker: Arc::new(LoadTracker::new(peak)),
        }
    }

    /// Number of device lanes (live, dead, or still joining).
    pub fn devices(&self) -> usize {
        self.alive.len()
    }

    /// Add a lane slot in the joining state (not yet routable): returns
    /// its device index. The lane starts receiving shards only after
    /// [`mark_alive`](Self::mark_alive). Panics when extended past the
    /// ledger capacity given to [`with_capacity`](Self::with_capacity).
    pub fn extend(&mut self) -> usize {
        let device = self.alive.len();
        assert!(
            device < self.tracker.loads.len(),
            "router extended past its lane capacity"
        );
        self.alive.push(false);
        device
    }

    /// Admit lane `device` — a joiner going live, or a retired lane
    /// rejoining. Its ledger starts from a clean slate.
    pub fn mark_alive(&mut self, device: usize) {
        self.alive[device] = true;
        self.tracker.clear(device);
    }

    /// Swap the routing policy at a quiesce point (the control plane's
    /// route knob); the round-robin cursor and the ledger carry over.
    pub fn set_policy(&mut self, policy: RoutePolicy) {
        self.policy = policy;
    }

    /// Lanes still accepting work.
    pub fn alive_count(&self) -> usize {
        self.alive.iter().filter(|a| **a).count()
    }

    /// Retire a lost lane: subsequent [`route`](Self::route) calls never
    /// pick it (round-robin skips it, least-loaded masks its ledger
    /// entry). The lane-loss recovery of `train_loop::run_multi` calls
    /// this so a dead device's remaining shards re-route to survivors.
    /// The lane's outstanding-byte ledger is cleared — a dead lane's
    /// routed-but-unfinished bytes would otherwise linger forever and
    /// skew `LeastLoaded` against it if it later rejoins.
    pub fn mark_dead(&mut self, device: usize) {
        self.alive[device] = false;
        self.tracker.clear(device);
    }

    /// Is `device` still routable?
    pub fn is_alive(&self, device: usize) -> bool {
        self.alive[device]
    }

    /// Shards routed so far.
    pub fn routed(&self) -> u64 {
        self.routed
    }

    /// Shared handle to the outstanding-load ledger (hand it to the
    /// consumer side).
    pub fn tracker(&self) -> Arc<LoadTracker> {
        Arc::clone(&self.tracker)
    }

    /// Pick the device for the next shard of `bytes` and charge its lane.
    /// Panics if every lane has been marked dead (the caller must treat
    /// all-lanes-lost as a terminal [`EtlError::LaneLost`] before routing).
    pub fn route(&mut self, bytes: u64) -> usize {
        let n = self.devices();
        assert!(self.alive_count() > 0, "route with every lane dead");
        let d = match self.policy {
            RoutePolicy::RoundRobin => {
                // Skip retired lanes; survivors keep the cyclic order.
                loop {
                    let d = self.next;
                    self.next = (self.next + 1) % n;
                    if self.alive[d] {
                        break d;
                    }
                }
            }
            RoutePolicy::LeastLoaded => {
                // One coherent **masked** snapshot, then min by
                // (load, index): the decision is a pure function of the
                // snapshot, outstanding-byte ties break to the **lowest
                // device index** (pinned by
                // `least_loaded_ties_break_to_lowest_index`), and
                // draining/dead lanes never appear at all — their zeroed
                // ledgers would otherwise win every comparison (pinned
                // by `draining_lane_is_masked_out_of_least_loaded`).
                let snap = self.tracker.snapshot_masked(&self.alive);
                snap.iter()
                    .enumerate()
                    .filter_map(|(d, l)| l.map(|l| (d, l)))
                    .min_by_key(|&(d, l)| (l, d))
                    .map(|(d, _)| d)
                    .expect("router has >= 1 live device")
            }
        };
        self.tracker.charge(d, bytes);
        self.routed += 1;
        d
    }
}

/// Lookahead-driven embedding prefetcher of one device lane (BagPipe's
/// core idea on our topology): the router stamps and routes every shard
/// **before** its consumer runs, so by the time slot `k` is committed the
/// lane's pack worker has already staged — and prefetched for — slots
/// `k+1 … k+lookahead`. The pipeline is a sliding window of that depth:
///
/// * [`on_packed`](Self::on_packed) — called by the pack worker right
///   after staging a slot: extracts the embedding-row trace from the
///   packed sparse ids, issues the promotion batch at the slot's
///   stage-completion time (when `lookahead > 0`), and pushes the slot
///   into the window. Once the window exceeds the lookahead depth the
///   oldest slot is committed (hit/miss walk) with the *current* stage
///   clock as the consumer clock — the pipelined overlap that hides
///   promotion latency.
/// * [`flush`](Self::flush) — lane drain: commits whatever the window
///   still holds.
///
/// With `lookahead = 0` every slot commits immediately and all promotion
/// traffic is demand misses with fully exposed transfer time. Owned by a
/// single lane thread; all state advances in delivery order, so the
/// cache accounting is schedule-independent (see
/// `runtime::embedding`'s determinism notes).
#[derive(Debug)]
pub struct PrefetchPipeline {
    cache: crate::runtime::embedding::EmbShardCache,
    lookahead: usize,
    /// Staged-but-uncommitted slots: (row trace, prefetch done time).
    window: std::collections::VecDeque<(Vec<u32>, f64)>,
    /// Slots staged so far — the trace span key on this lane.
    staged: u64,
}

impl PrefetchPipeline {
    pub fn new(cache: crate::runtime::embedding::EmbShardCache, lookahead: usize) -> PrefetchPipeline {
        PrefetchPipeline { cache, lookahead, window: std::collections::VecDeque::new(), staged: 0 }
    }

    /// The shard cache being driven (tests / introspection).
    pub fn cache(&self) -> &crate::runtime::embedding::EmbShardCache {
        &self.cache
    }

    /// Retune the lookahead depth at a quiesce point (the control plane's
    /// `Lookahead` knob). Deepening takes effect as the window refills;
    /// shrinking drains the excess on the next staged slot (or the lane
    /// flush), so accounting stays in delivery order.
    pub fn set_lookahead(&mut self, lookahead: usize) {
        self.lookahead = lookahead;
    }

    /// Account a freshly staged slot: `sparse`/`rows` are the packed
    /// batch's sparse ids and the number of rows the consumer will
    /// actually step (full chunks within the step budget), `stage_done_s`
    /// the slot's DMA completion on this lane's engine clock.
    pub fn on_packed<F: Fn(usize) -> bool>(
        &mut self,
        sparse: &[i32],
        rows: usize,
        stage_done_s: f64,
        alive: &F,
    ) {
        let span = crate::trace::begin(
            crate::trace::kind::PREFETCH_COMMIT,
            self.cache.device() as u32,
            self.staged,
        );
        self.staged += 1;
        let trace = self.cache.table().trace(sparse, rows);
        let pf_done = if self.lookahead > 0 {
            self.cache.promote(&trace, stage_done_s, alive)
        } else {
            stage_done_s
        };
        span.end_sim(stage_done_s, pf_done.max(stage_done_s));
        self.window.push_back((trace, pf_done));
        while self.window.len() > self.lookahead {
            let (trace, pf_done) = self.window.pop_front().expect("window non-empty");
            self.cache.commit(&trace, pf_done, stage_done_s, alive);
        }
    }

    /// Drain the window at lane end (consumer clock `now_s`).
    pub fn flush<F: Fn(usize) -> bool>(&mut self, now_s: f64, alive: &F) {
        while let Some((trace, pf_done)) = self.window.pop_front() {
            self.cache.commit(&trace, pf_done, now_s, alive);
        }
    }

    /// Final per-lane cache stats.
    pub fn into_stats(self) -> crate::runtime::embedding::EmbCacheStats {
        self.cache.into_stats()
    }
}

/// One device's contribution to a resolved reduce epoch: the
/// gradient-level payloads of the local-SGD steps it executed inside the
/// epoch's window, in its local (ascending global step) order.
#[derive(Debug, Clone)]
pub struct EpochContrib {
    /// Contributing device index.
    pub device: usize,
    /// The device's steps in the window, local order.
    pub steps: Vec<GradStep>,
}

/// A resolved reduce epoch: every contribution of the epoch's global-step
/// window, **device-ascending** — the fixed association order that makes
/// the reduction bit-stable across runs and schedules. Replicas replay it
/// onto their last synced base via `Trainer::apply_reduced`; identical
/// `(base, epoch)` inputs land on bitwise identical parameters on every
/// replica, so no state broadcast is needed.
#[derive(Debug, Clone)]
pub struct ReducedEpoch {
    /// Epoch index (0-based within the run).
    pub epoch: u64,
    /// First run-relative global step of the window (inclusive).
    pub start: u64,
    /// One past the last run-relative global step of the window.
    pub end: u64,
    /// Per-device contributions, device-ascending; devices that took no
    /// step in the window are absent.
    pub contribs: Vec<EpochContrib>,
}

impl ReducedEpoch {
    /// Steps folded into this epoch.
    pub fn steps(&self) -> u64 {
        self.end - self.start
    }
}

/// Outcome of waiting on an epoch.
#[derive(Debug)]
pub enum EpochWait {
    /// The epoch resolved; replay it onto the synced base.
    Resolved(Arc<ReducedEpoch>),
    /// The stream ended and every epoch that will ever exist has already
    /// been handed out — the waiter is fully synced.
    Finished,
    /// The run aborted (a peer errored); stop stepping and unwind.
    Aborted,
}

/// One piece of the epoch-window schedule: from run-relative step
/// `from_rel` on, windows are `period` wide, the first of them ending at
/// `first_end` and carrying epoch index `from_epoch`. The launch segment
/// aligns to absolute step counts (a warm-started trainer keeps its sync
/// phase); control-plane retunes ([`ReduceBus::retune_every`]) push new
/// segments at epoch boundaries at or beyond the routing frontier, so the
/// step → epoch mapping stays a pure function of (config, script).
#[derive(Debug, Clone, Copy)]
struct Segment {
    from_rel: u64,
    from_epoch: u64,
    period: u64,
    first_end: u64,
}

struct BusInner {
    /// Current contributor count: [`ReduceBus::join`] grows it, and every
    /// serve/release threshold reads it live (a joiner raises the fetch
    /// count an epoch needs before its memory is dropped).
    members: usize,
    /// Epoch-window schedule, append-only (see [`Segment`]).
    segments: Vec<Segment>,
    /// Posted steps not yet folded into an epoch, keyed by run-relative
    /// global step index.
    pending: BTreeMap<u64, (usize, GradStep)>,
    /// Steps forfeited by a lost lane: they count toward window
    /// completeness but contribute no gradient (tombstones, not data).
    forfeited: BTreeSet<u64>,
    /// Steps forfeited so far (accounting; tombstones are consumed as
    /// their windows fold).
    forfeited_total: u64,
    /// Replicas that left the bus ([`ReduceBus::leave`]); every epoch they
    /// will never fetch counts them as implicitly served.
    leavers: usize,
    /// Lowest run-relative step index not yet seen contiguously from 0
    /// (epochs fold only over gap-free windows).
    contig: u64,
    /// Resolved epochs, in order. A slot is dropped (`None`) once every
    /// replica has fetched it, so bus memory is bounded by the epochs
    /// still in flight, not the whole run's gradient history.
    resolved: Vec<Option<Arc<ReducedEpoch>>>,
    /// Fetches served per resolved epoch (an epoch is fully served after
    /// `devices` fetches — each replica applies it exactly once, and a
    /// departed replica counts as served from the moment it left).
    served: Vec<usize>,
    /// One past the last folded run-relative step.
    resolved_end: u64,
    /// Total run-relative steps, once the stream end is known; resolves
    /// the trailing partial epoch.
    total: Option<u64>,
    aborted: bool,
}

/// The **barrier-free gradient all-reduce bus** of the concurrent
/// multi-device train loop (paper §3's overlap discipline applied to the
/// consumption side; BagPipe-style lookahead consumer independence).
///
/// # Epoch protocol
///
/// Global steps are numbered in **delivery order** (the router stamps
/// every staged slot with the global index of its first trainer step, so
/// the numbering is schedule-independent). With an all-reduce period of
/// `K = allreduce_every`, epoch `e` covers the global steps whose
/// absolute index lies in window `e` of width `K` (windows are aligned to
/// absolute step counts, so a warm-started trainer keeps its sync phase);
/// `allreduce_every = 0` makes the whole run one epoch (sync only at
/// stream end).
///
/// Each consumer thread steps its own replica through its routed chunks
/// **locally** (local SGD inside the window) and [`post`](Self::post)s
/// one f64 gradient-level [`GradStep`] per step. An epoch **resolves**
/// when every step of its window has been posted — there is no barrier:
/// nobody waits for *threads*, only for the *data* of the window, and a
/// device with many chunks in the window keeps stepping while others are
/// already blocked on [`wait_epoch`](Self::wait_epoch) for it. Before
/// stepping a chunk of the next window, a replica must have applied every
/// earlier epoch (`Trainer::apply_reduced` onto its synced base) — with
/// `K = 1` that serializes steps into exactly the single-device
/// trajectory (bitwise, since a one-contributor epoch replays the very
/// f32 update the single device would apply); larger `K` buys real
/// consumer concurrency at the price of bounded, deterministic local-SGD
/// divergence between syncs.
///
/// Note the memory bound: contributions buffer in the bus until their
/// window completes — so `allreduce_every = 0` holds every step's
/// gradients until stream end — and a resolved epoch is dropped as soon
/// as every replica has fetched it, so steady-state bus memory is the
/// epochs still in flight, not the run's gradient history. Because the
/// `allreduce_every = 0` mode buffers without bound, [`post`](Self::post)
/// enforces a hard pending-step cap ([`Self::with_pending_cap`], default
/// [`DEFAULT_PENDING_CAP`]) and surfaces a typed error instead of letting
/// the footgun OOM the process.
///
/// # Failure domain: membership shrink
///
/// A lost lane must not wedge its peers. The recovery protocol is:
/// the dying consumer [`forfeit`](Self::forfeit)s the steps it will never
/// post (tombstones that complete windows without contributing data) and
/// then [`leave`](Self::leave)s, telling the bus how many epochs it
/// already applied — every later epoch counts the leaver as implicitly
/// served, so survivors' fetches still release epoch memory and no waiter
/// deadlocks on a fetch that will never come.
///
/// # Elastic membership and retuning
///
/// The membership is dynamic in both directions: [`join`](Self::join)
/// (the counterpart of `leave`) admits a new contributor whose replica
/// synced through `applied` epochs — earlier epochs count it as
/// implicitly served, later ones it fetches like any member — and
/// [`retune_every`](Self::retune_every) changes the window period from
/// the next epoch boundary at or beyond the routing frontier on, leaving
/// every already-stamped step's epoch assignment untouched (the schedule
/// is a list of [`Segment`]s, each a pure function of config + control
/// script, so scripted retunes replay bitwise).
pub struct ReduceBus {
    /// Absolute steps already taken before this run (warm-start phase).
    start: u64,
    /// Hard bound on buffered (posted, unresolved) steps.
    pending_cap: usize,
    inner: Mutex<BusInner>,
    cv: Condvar,
}

/// Default hard bound on buffered pending steps — generous enough for any
/// realistic window, small enough to fail loudly long before the
/// `allreduce_every = 0` gradient history exhausts memory.
pub const DEFAULT_PENDING_CAP: usize = 1 << 20;

impl ReduceBus {
    /// Bus for `devices` replicas syncing every `allreduce_every` global
    /// steps (0 = only at stream end), with `steps_at_start` absolute
    /// steps already on the trainer's counter (epoch windows align to
    /// absolute counts).
    pub fn new(devices: usize, allreduce_every: usize, steps_at_start: u64) -> ReduceBus {
        assert!(devices >= 1, "reduce bus needs at least one device");
        let every = if allreduce_every == 0 { u64::MAX } else { allreduce_every as u64 };
        let first_end = (steps_at_start / every + 1)
            .saturating_mul(every)
            .saturating_sub(steps_at_start);
        ReduceBus {
            start: steps_at_start,
            pending_cap: DEFAULT_PENDING_CAP,
            inner: Mutex::new(BusInner {
                members: devices,
                segments: vec![Segment {
                    from_rel: 0,
                    from_epoch: 0,
                    period: every,
                    first_end,
                }],
                pending: BTreeMap::new(),
                forfeited: BTreeSet::new(),
                forfeited_total: 0,
                leavers: 0,
                contig: 0,
                resolved: Vec::new(),
                served: Vec::new(),
                resolved_end: 0,
                total: None,
                aborted: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Override the hard bound on buffered pending steps (see
    /// [`DEFAULT_PENDING_CAP`]).
    pub fn with_pending_cap(mut self, cap: usize) -> ReduceBus {
        assert!(cap >= 1, "pending cap must admit at least one step");
        self.pending_cap = cap;
        self
    }

    /// Replica count the bus currently serves ([`join`](Self::join) grows
    /// it mid-run).
    pub fn devices(&self) -> usize {
        self.inner.lock().expect("reduce bus poisoned").members
    }

    /// Number of epochs a replica must have applied before executing the
    /// step with **absolute** index `step_abs` (= the index of the epoch
    /// that step belongs to).
    pub fn epochs_before(&self, step_abs: u64) -> u64 {
        debug_assert!(step_abs >= self.start);
        let inner = self.inner.lock().expect("reduce bus poisoned");
        Self::epoch_of(&inner.segments, step_abs - self.start)
    }

    /// Epoch index of run-relative step `rel` under the segment schedule.
    fn epoch_of(segments: &[Segment], rel: u64) -> u64 {
        let seg = segments
            .iter()
            .rev()
            .find(|s| s.from_rel <= rel)
            .expect("segment 0 covers rel 0");
        if rel < seg.first_end {
            seg.from_epoch
        } else {
            seg.from_epoch + 1 + (rel - seg.first_end) / seg.period
        }
    }

    /// One past the last run-relative step of epoch `e` (unclamped by the
    /// stream total).
    fn end_rel(segments: &[Segment], e: u64) -> u64 {
        let seg = segments
            .iter()
            .rev()
            .find(|s| s.from_epoch <= e)
            .expect("segment 0 starts at epoch 0");
        seg.first_end
            .saturating_add((e - seg.from_epoch).saturating_mul(seg.period))
    }

    /// Admit a new contributor (the counterpart of [`leave`](Self::leave))
    /// and return its device index. The joiner's replica has already
    /// applied `applied` epochs (synced from the last resolved base), so
    /// every earlier epoch counts it as implicitly served; from `applied`
    /// on it fetches like any member — which is why admission fails if
    /// any such epoch was already fully served and released (the data the
    /// joiner needs is gone; it must re-sync and retry).
    pub fn join(&self, applied: u64) -> Result<usize> {
        sched::point(site::LANE_JOIN);
        let mut inner = self.inner.lock().expect("reduce bus poisoned");
        for idx in (applied as usize)..inner.resolved.len() {
            if inner.resolved[idx].is_none() {
                return Err(EtlError::Coord(format!(
                    "reduce-bus join too late: epoch {idx} was already released, \
                     but the joiner only synced through epoch {applied}"
                )));
            }
        }
        let device = inner.members;
        inner.members += 1;
        let members = inner.members;
        let upto = (applied as usize).min(inner.resolved.len());
        for idx in 0..upto {
            if inner.resolved[idx].is_some() {
                inner.served[idx] += 1;
                if inner.served[idx] >= members {
                    inner.resolved[idx] = None;
                }
            }
        }
        Ok(device)
    }

    /// Retune the all-reduce period at the routing frontier (the control
    /// plane's `AllreduceEvery` knob): the window in progress finishes
    /// under the old period, and the new one applies from the next epoch
    /// boundary at or beyond run-relative step `frontier_rel` — every
    /// already-stamped step keeps its epoch assignment. A no-op when the
    /// period is unchanged; a re-retune before the previous boundary took
    /// effect overrides it in place.
    pub fn retune_every(&self, frontier_rel: u64, allreduce_every: usize) {
        let period = if allreduce_every == 0 { u64::MAX } else { allreduce_every as u64 };
        let mut inner = self.inner.lock().expect("reduce bus poisoned");
        let last = *inner.segments.last().expect("segment 0 always present");
        if last.period == period {
            return;
        }
        if inner.segments.len() > 1 && frontier_rel <= last.from_rel {
            *inner.segments.last_mut().expect("non-empty") = Segment {
                from_rel: last.from_rel,
                from_epoch: last.from_epoch,
                period,
                first_end: last.from_rel.saturating_add(period),
            };
        } else {
            let (boundary, from_epoch) = if frontier_rel <= last.first_end {
                (last.first_end, last.from_epoch + 1)
            } else {
                let k = (frontier_rel - last.first_end).div_ceil(last.period);
                (
                    last.first_end.saturating_add(k.saturating_mul(last.period)),
                    last.from_epoch + 1 + k,
                )
            };
            inner.segments.push(Segment {
                from_rel: boundary,
                from_epoch,
                period,
                first_end: boundary.saturating_add(period),
            });
        }
        self.try_resolve(&mut inner);
        drop(inner);
        self.cv.notify_all();
    }

    /// Post the gradient contribution of run-relative global step `step`
    /// executed on `device`. Each step is posted exactly once; windows
    /// fold as soon as they are gap-free. Errors (typed, before buffering)
    /// once the pending buffer hits the hard cap — the
    /// `allreduce_every = 0` mode buffers every gradient until stream
    /// end, and the cap turns that silent OOM footgun into a diagnosis.
    pub fn post(&self, step: u64, device: usize, grad: GradStep) -> Result<()> {
        sched::point(site::REDUCE_POST);
        let mut inner = self.inner.lock().expect("reduce bus poisoned");
        assert!(device < inner.members, "device {device} out of range");
        if inner.pending.len() >= self.pending_cap {
            return Err(EtlError::Mem(format!(
                "reduce bus pending buffer hit its cap ({} steps) at step {step}: \
                 allreduce_every=0 buffers every gradient until stream end — \
                 use a nonzero allreduce_every or raise the cap",
                self.pending_cap
            )));
        }
        let prev = inner.pending.insert(step, (device, grad));
        assert!(prev.is_none(), "global step {step} posted twice");
        self.advance_contig(&mut inner);
        self.try_resolve(&mut inner);
        Ok(())
    }

    /// Forfeit run-relative steps a lost lane will never execute: they
    /// count toward window completeness (so peers' epochs still resolve)
    /// but contribute no gradient. Idempotent per step.
    pub fn forfeit(&self, range: std::ops::Range<u64>) {
        sched::point(site::REDUCE_POST);
        let mut inner = self.inner.lock().expect("reduce bus poisoned");
        for r in range {
            debug_assert!(
                !inner.pending.contains_key(&r),
                "step {r} both posted and forfeited"
            );
            if inner.forfeited.insert(r) {
                inner.forfeited_total += 1;
            }
        }
        self.advance_contig(&mut inner);
        self.try_resolve(&mut inner);
    }

    /// A replica leaves the bus after having applied `applied` epochs:
    /// every resolved-or-future epoch from `applied` on counts it as
    /// implicitly served, so the survivors' fetches still release epoch
    /// memory and nothing waits on a fetch that will never come. The
    /// leaver must have forfeited (or posted) all steps it was routed.
    pub fn leave(&self, applied: u64) {
        let mut inner = self.inner.lock().expect("reduce bus poisoned");
        inner.leavers += 1;
        let members = inner.members;
        for idx in (applied as usize)..inner.resolved.len() {
            if inner.resolved[idx].is_some() {
                inner.served[idx] += 1;
                if inner.served[idx] >= members {
                    inner.resolved[idx] = None;
                }
            }
        }
        drop(inner);
        self.cv.notify_all();
    }

    /// Steps forfeited so far (lane-loss accounting).
    pub fn forfeited_count(&self) -> u64 {
        self.inner.lock().expect("reduce bus poisoned").forfeited_total
    }

    /// Replicas that have left the bus.
    pub fn leavers(&self) -> usize {
        self.inner.lock().expect("reduce bus poisoned").leavers
    }

    /// Advance the contiguity cursor over posted steps and forfeit
    /// tombstones alike.
    fn advance_contig(&self, inner: &mut BusInner) {
        while inner.pending.contains_key(&inner.contig)
            || inner.forfeited.contains(&inner.contig)
        {
            inner.contig += 1;
        }
    }

    /// Declare the stream's total run-relative step count: resolves the
    /// trailing partial epoch and lets fully-synced waiters observe
    /// [`EpochWait::Finished`].
    pub fn close(&self, total: u64) {
        let mut inner = self.inner.lock().expect("reduce bus poisoned");
        debug_assert!(
            inner.total.is_none() || inner.total == Some(total),
            "bus closed twice with different totals"
        );
        inner.total = Some(total);
        self.try_resolve(&mut inner);
        drop(inner);
        self.cv.notify_all();
    }

    /// Abort the run (a participant errored): every current and future
    /// waiter observes [`EpochWait::Aborted`] and unwinds.
    pub fn abort(&self) {
        let mut inner = self.inner.lock().expect("reduce bus poisoned");
        inner.aborted = true;
        drop(inner);
        self.cv.notify_all();
    }

    /// Has the bus been aborted?
    pub fn is_aborted(&self) -> bool {
        self.inner.lock().expect("reduce bus poisoned").aborted
    }

    /// Epochs resolved so far.
    pub fn resolved_count(&self) -> u64 {
        self.inner.lock().expect("reduce bus poisoned").resolved.len() as u64
    }

    /// Block until epoch `e` resolves (epochs resolve in ascending order,
    /// so waiting on `applied_so_far` walks the sequence without skips).
    /// Each replica fetches each epoch exactly once: after `devices`
    /// fetches the epoch's gradients are dropped from the bus, bounding
    /// its memory to the epochs still in flight.
    pub fn wait_epoch(&self, e: u64) -> EpochWait {
        sched::point(site::REDUCE_WAIT);
        let mut inner = self.inner.lock().expect("reduce bus poisoned");
        loop {
            if (e as usize) < inner.resolved.len() {
                let idx = e as usize;
                let ep = Arc::clone(
                    inner.resolved[idx]
                        .as_ref()
                        .expect("epoch fetched more than `devices` times"),
                );
                inner.served[idx] += 1;
                if inner.served[idx] >= inner.members {
                    inner.resolved[idx] = None;
                }
                return EpochWait::Resolved(ep);
            }
            if inner.aborted {
                return EpochWait::Aborted;
            }
            if let Some(total) = inner.total {
                if inner.resolved_end >= total {
                    return EpochWait::Finished;
                }
            }
            inner = self.cv.wait(inner).expect("reduce bus poisoned");
        }
    }

    /// Fold every gap-free, fully-posted window into a resolved epoch
    /// (ascending), waking waiters when anything resolved.
    fn try_resolve(&self, inner: &mut BusInner) {
        let mut resolved_any = false;
        loop {
            let e = inner.resolved.len() as u64;
            let prev_end = inner.resolved_end;
            let mut end = Self::end_rel(&inner.segments, e);
            if let Some(total) = inner.total {
                end = end.min(total);
            }
            if end <= prev_end {
                break; // stream ended exactly on the last boundary
            }
            if inner.contig < end {
                break; // window still has unposted steps
            }
            let mut per_dev: Vec<Vec<GradStep>> =
                (0..inner.members).map(|_| Vec::new()).collect();
            for r in prev_end..end {
                if inner.forfeited.remove(&r) {
                    continue; // tombstone: completes the window, no data
                }
                let (d, g) = inner
                    .pending
                    .remove(&r)
                    .expect("contiguous step missing from pending set");
                per_dev[d].push(g);
            }
            let contribs = per_dev
                .into_iter()
                .enumerate()
                .filter(|(_, steps)| !steps.is_empty())
                .map(|(device, steps)| EpochContrib { device, steps })
                .collect();
            // A departed replica never fetches: it is served from birth.
            let pre_served = inner.leavers;
            inner.resolved.push(if pre_served >= inner.members {
                None // everyone left; resolve for accounting, hold no data
            } else {
                Some(Arc::new(ReducedEpoch { epoch: e, start: prev_end, end, contribs }))
            });
            inner.served.push(pre_served);
            inner.resolved_end = end;
            resolved_any = true;
        }
        if resolved_any {
            self.cv.notify_all();
        }
    }
}

/// The two end-to-end systems the paper contrasts (Fig. 8/14).
pub fn piperec_config(batches: usize, etl_s: f64, train_s: f64, batch_bytes: u64) -> OverlapConfig {
    OverlapConfig {
        batches,
        etl_s,
        etl_jitter: 0.0,
        train_s,
        batch_bytes,
        channel: ChannelModel::of(crate::memsys::channel::Path::P2pToGpu),
        staging_buffers: 2,
        seed: 0x9e37,
    }
}

pub fn cpu_gpu_config(batches: usize, etl_s: f64, train_s: f64, batch_bytes: u64) -> OverlapConfig {
    OverlapConfig {
        batches,
        etl_s,
        etl_jitter: 0.8, // irregular delivery from CPU workers
        train_s,
        batch_bytes,
        // Staged copy through host DRAM (slower effective path).
        channel: ChannelModel::of(crate::memsys::channel::Path::CpuFpgaCpu),
        staging_buffers: 2,
        seed: 0x9e37,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_etl_keeps_gpu_saturated() {
        // PipeRec regime: ETL faster than training ⇒ util near 1.
        let cfg = piperec_config(500, 0.5e-3, 5e-3, 4 << 20);
        let r = simulate_overlap(&cfg);
        assert!(r.mean_util > 0.9, "util={}", r.mean_util);
        assert!(r.trace.cv() < 0.15, "cv={}", r.trace.cv());
    }

    #[test]
    fn slow_etl_leaves_gpu_idle() {
        // CPU regime: ETL ~12× slower than training ⇒ util ~1/12.
        let cfg = cpu_gpu_config(300, 60e-3, 5e-3, 4 << 20);
        let r = simulate_overlap(&cfg);
        assert!(r.mean_util < 0.15, "util={}", r.mean_util);
        // And the trace is unstable (fluctuating delivery).
        assert!(r.trace.cv() > 0.2, "cv={}", r.trace.cv());
    }

    #[test]
    fn end_to_end_speedup_matches_paper_order() {
        // Same 300 batches: CPU-bound pipeline vs PipeRec-fed pipeline.
        let train_s = 5e-3;
        let cpu = simulate_overlap(&cpu_gpu_config(300, 60e-3, train_s, 4 << 20));
        let pr = simulate_overlap(&piperec_config(300, 0.5e-3, train_s, 4 << 20));
        let speedup = cpu.total_s / pr.total_s;
        // Paper: end-to-end training time reduced ~10× (9.94%).
        assert!(speedup > 7.0 && speedup < 16.0, "speedup={speedup}");
    }

    #[test]
    fn backpressure_blocks_fast_producer() {
        // ETL much faster than training: producer must block on credits.
        let cfg = piperec_config(200, 0.1e-3, 10e-3, 4 << 20);
        let r = simulate_overlap(&cfg);
        assert!(r.producer_blocked_s > 0.0);
        // GPU never starves though.
        assert!(r.mean_util > 0.95);
    }

    #[test]
    fn busy_time_equals_batches_times_train() {
        let cfg = piperec_config(100, 1e-3, 2e-3, 1 << 20);
        let r = simulate_overlap(&cfg);
        assert!((r.busy_s - 100.0 * 2e-3).abs() < 1e-9);
        assert!(r.total_s >= r.busy_s);
    }

    #[test]
    fn zero_batches_returns_finite_zeroed_stats() {
        // batches == 0 used to produce mean_util = 0.0/0.0 = NaN, which
        // poisons any Fig. 14 aggregation it flows into.
        let cfg = piperec_config(0, 1e-3, 2e-3, 1 << 20);
        let r = simulate_overlap(&cfg);
        assert_eq!(r.total_s, 0.0);
        assert_eq!(r.busy_s, 0.0);
        assert!(r.mean_util.is_finite(), "util must not be NaN");
        assert_eq!(r.mean_util, 0.0);
        assert!(r.trace.points.is_empty());
        assert_eq!(r.producer_blocked_s, 0.0);
    }

    #[test]
    fn trace_emits_trailing_partial_window() {
        // One busy interval covering all of [0, 1.0); a 0.3 s window
        // leaves a 0.1 s tail that the old loop silently dropped.
        let intervals = [(0.0, 1.0)];
        let trace = utilization_trace(&intervals, 1.0, 0.3);
        assert_eq!(trace.points.len(), 4, "3 full windows + 1 partial");
        // The partial window is centered in its actual width …
        let (t_last, u_last) = *trace.points.last().unwrap();
        assert!((t_last - 0.95).abs() < 1e-12, "center {t_last}");
        // … and normalized by it: fully busy, not 1/3 busy.
        assert!((u_last - 1.0).abs() < 1e-12, "util {u_last}");
    }

    #[test]
    fn trace_covers_total_and_conserves_busy_time() {
        // Busy time after the last full window must appear in the trace:
        // Σ util_i × width_i == busy_s, and the windows tile [0, total).
        let intervals = [(0.1, 0.4), (0.75, 1.1), (1.15, 1.2)];
        let busy: f64 = intervals.iter().map(|(s, e)| e - s).sum();
        let total = 1.2;
        let window = 0.5; // 2 full windows + a 0.2 partial
        let trace = utilization_trace(&intervals, total, window);
        assert_eq!(trace.points.len(), 3);
        let mut covered = 0.0;
        let mut weighted = 0.0;
        for &(center, util) in &trace.points {
            let width = 2.0 * (center - covered);
            covered += width;
            weighted += util * width;
        }
        assert!((covered - total).abs() < 1e-9, "covered {covered} of {total}");
        assert!((weighted - busy).abs() < 1e-9, "trace busy {weighted} vs {busy}");
    }

    #[test]
    fn simulate_overlap_trace_covers_all_of_total() {
        // End-to-end: the last window's right edge reaches total_s.
        let r = simulate_overlap(&piperec_config(37, 1.3e-3, 2.1e-3, 1 << 20));
        assert!(!r.trace.points.is_empty());
        let mut covered = 0.0;
        for &(center, _) in &r.trace.points {
            covered += 2.0 * (center - covered);
        }
        assert!(
            (covered - r.total_s).abs() < 1e-9 * r.total_s.max(1.0),
            "trace covers {covered} of {}",
            r.total_s
        );
    }

    #[test]
    fn round_robin_routing_cycles_deterministically() {
        let mut r = DeviceRouter::new(3, RoutePolicy::RoundRobin);
        let picks: Vec<usize> = (0..7).map(|_| r.route(10)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
        assert_eq!(r.routed(), 7);
        assert_eq!(r.tracker().snapshot(), vec![30, 20, 20]);
    }

    #[test]
    fn least_loaded_routing_follows_the_ledger() {
        let mut r = DeviceRouter::new(3, RoutePolicy::LeastLoaded);
        let t = r.tracker();
        // Empty ledger: ties break to the lowest index.
        assert_eq!(r.route(100), 0);
        assert_eq!(r.route(10), 1);
        assert_eq!(r.route(10), 2);
        // Device 0 carries the most outstanding bytes → avoided.
        assert_eq!(r.route(10), 1);
        // Completing device 0's big shard makes it least loaded again.
        t.complete(0, 100);
        assert_eq!(r.route(10), 0);
        // Over-completion saturates at zero instead of wrapping.
        t.complete(2, 1 << 40);
        assert_eq!(t.load(2), 0);
    }

    #[test]
    fn least_loaded_ties_break_to_lowest_index() {
        // Exact-assignment pin: with equal-byte shards the ledger passes
        // through repeated all-equal states, and every tie must go to the
        // lowest device index — the full pick sequence is deterministic.
        let mut r = DeviceRouter::new(4, RoutePolicy::LeastLoaded);
        let picks: Vec<usize> = (0..9).map(|_| r.route(10)).collect();
        // Loads cycle 0→1→2→3 (each pick charges 10, re-tying every 4).
        assert_eq!(picks, vec![0, 1, 2, 3, 0, 1, 2, 3, 0]);

        // Engineered partial tie: loads now [30, 20, 20, 20]; complete
        // work so devices 1 and 3 tie at the minimum — lowest wins.
        let t = r.tracker();
        t.complete(1, 20);
        t.complete(3, 20);
        assert_eq!(t.snapshot(), vec![30, 0, 20, 0]);
        assert_eq!(r.route(5), 1, "tie {{1, 3}} must break to device 1");
        assert_eq!(r.route(1), 3, "device 3 is now the unique minimum");
    }

    #[test]
    fn draining_lane_is_masked_out_of_least_loaded() {
        // Drain-then-route: after a RemoveLane-style mark_dead the
        // retired lane's ledger is cleared — making it the *numerical*
        // minimum — yet it must never win a LeastLoaded pick, and the
        // masked snapshot must not expose it at all. Its queued slots
        // still completing must not resurrect it either.
        let mut r = DeviceRouter::new(3, RoutePolicy::LeastLoaded);
        // Load the fleet unevenly: lane 0 heaviest, lane 1 lightest.
        let t = r.tracker();
        t.charge(0, 300);
        t.charge(1, 100);
        t.charge(2, 200);
        // Lane 1 (the would-be winner) starts draining.
        r.mark_dead(1);
        assert_eq!(t.snapshot_masked(&[true, false, true]), vec![Some(300), None, Some(200)]);
        // Every subsequent pick lands on a live lane — never the
        // zero-load draining one.
        for _ in 0..6 {
            let d = r.route(10);
            assert_ne!(d, 1, "routed to a draining lane");
        }
        // The draining lane's queued slots completing (saturating at 0)
        // keeps it masked, not re-admitted.
        t.complete(1, 50);
        assert_eq!(t.load(1), 0);
        assert_ne!(r.route(10), 1);
    }

    fn pipeline(lookahead: usize, cache_rows: usize) -> PrefetchPipeline {
        use crate::devmem::{ArenaConfig, DeviceArena};
        use crate::runtime::artifacts::{ModelMeta, ParamSpec};
        use crate::runtime::embedding::{EmbShardCache, EmbeddingTable, ShardPolicy};
        let meta = ModelMeta {
            batch: 2,
            n_dense: 1,
            n_sparse: 1,
            vocab: 8,
            embed_dim: 1,
            params: vec![
                ParamSpec { name: "emb".into(), dims: vec![8] },
                ParamSpec { name: "w1".into(), dims: vec![1] },
                ParamSpec { name: "b1".into(), dims: vec![1] },
            ],
            extra: Default::default(),
        };
        let table = EmbeddingTable::from_meta(&meta, 1, ShardPolicy::HashMod).unwrap();
        let arena = DeviceArena::new(ArenaConfig { slots: 2, slot_bytes: 1 << 16 });
        let region = arena.reserve_cache(cache_rows as u64 * table.row_bytes()).unwrap();
        PrefetchPipeline::new(EmbShardCache::new(table, cache_rows, region).unwrap(), lookahead)
    }

    #[test]
    fn prefetch_pipeline_hides_promotion_behind_lookahead() {
        // Full-size cache, lookahead 1: slot k's rows are promoted when
        // slot k is staged but committed one slot later — zero misses
        // after the pipeline fills, zero exposed wait once the stage
        // clock outruns the promotion clock.
        let alive = |_: usize| true;
        let mut pf = pipeline(1, 8);
        for k in 0..6i32 {
            let sparse = vec![k % 4, (k + 1) % 4];
            pf.on_packed(&sparse, 2, 1.0 + k as f64, &alive);
        }
        pf.flush(10.0, &alive);
        let st = pf.into_stats();
        assert_eq!(st.lookups, 12);
        assert_eq!(st.misses, 0, "{st:?}");
        assert_eq!(st.hits, 12);
        assert_eq!(st.prefetch_wait_s, 0.0, "lookahead must hide the transfers");
    }

    #[test]
    fn prefetch_pipeline_lookahead_zero_exposes_demand_misses() {
        let alive = |_: usize| true;
        let mut pf = pipeline(0, 8);
        pf.on_packed(&[0, 1], 2, 1.0, &alive);
        pf.on_packed(&[0, 1], 2, 2.0, &alive);
        pf.flush(3.0, &alive);
        let st = pf.into_stats();
        assert_eq!(st.lookups, 4);
        assert_eq!(st.misses, 2, "first touches demand-miss at lookahead 0");
        assert_eq!(st.hits, 2, "second slot hits the warmed rows");
        assert!(st.prefetch_wait_s > 0.0, "demand transfer time is exposed");
    }

    #[test]
    fn prefetch_pipeline_flush_commits_every_staged_slot() {
        // Exactly-once accounting survives a drain with a deep window.
        let alive = |_: usize| true;
        let mut pf = pipeline(8, 4);
        for k in 0..5i32 {
            pf.on_packed(&[k % 8, (k + 2) % 8], 2, k as f64, &alive);
        }
        // Nothing committed yet: window (5) never exceeded lookahead (8),
        // but prefetches landed (bounded by the 4-row capacity).
        assert!(pf.cache().resident_rows() > 0 && pf.cache().resident_rows() <= 4);
        pf.flush(5.0, &alive);
        let st = pf.into_stats();
        assert_eq!(st.lookups, 10);
        assert_eq!(st.hits + st.misses, st.lookups);
        assert_eq!(st.promoted_bytes, st.demoted_bytes + st.resident_bytes);
    }

    fn grad(loss: f64) -> crate::runtime::GradStep {
        crate::runtime::GradStep { loss, ..Default::default() }
    }

    #[test]
    fn reduce_bus_resolves_per_step_epochs_in_order() {
        // K = 1: every step is its own epoch with exactly one contributor.
        let bus = ReduceBus::new(2, 1, 0);
        assert_eq!(bus.epochs_before(0), 0);
        assert_eq!(bus.epochs_before(3), 3);
        for g in 0..4u64 {
            bus.post(g, (g % 2) as usize, grad(g as f64)).unwrap();
            assert_eq!(bus.resolved_count(), g + 1);
        }
        for e in 0..4u64 {
            match bus.wait_epoch(e) {
                EpochWait::Resolved(ep) => {
                    assert_eq!(ep.epoch, e);
                    assert_eq!((ep.start, ep.end), (e, e + 1));
                    assert_eq!(ep.contribs.len(), 1);
                    assert_eq!(ep.contribs[0].device, (e % 2) as usize);
                    assert_eq!(ep.contribs[0].steps[0].loss, e as f64);
                }
                other => panic!("epoch {e}: {other:?}"),
            }
        }
        bus.close(4);
        assert!(matches!(bus.wait_epoch(4), EpochWait::Finished));
    }

    #[test]
    fn reduce_bus_folds_windows_device_ascending_with_partial_tail() {
        // K = 3 over 2 devices, steps posted out of order: the window
        // folds only when gap-free, contributions sort device-ascending,
        // and close() resolves the trailing partial window.
        let bus = ReduceBus::new(2, 3, 0);
        bus.post(1, 1, grad(1.0)).unwrap();
        bus.post(2, 0, grad(2.0)).unwrap();
        assert_eq!(bus.resolved_count(), 0, "window [0,3) still has a gap");
        bus.post(0, 0, grad(0.0)).unwrap();
        assert_eq!(bus.resolved_count(), 1);
        let EpochWait::Resolved(ep) = bus.wait_epoch(0) else { panic!() };
        assert_eq!((ep.start, ep.end, ep.steps()), (0, 3, 3));
        assert_eq!(ep.contribs.len(), 2);
        assert_eq!(ep.contribs[0].device, 0);
        // Device 0's steps stay in its local (ascending step) order.
        let l0: Vec<f64> = ep.contribs[0].steps.iter().map(|s| s.loss).collect();
        assert_eq!(l0, vec![0.0, 2.0]);
        assert_eq!(ep.contribs[1].device, 1);

        // Steps 3..5 then stream end at 5: a 2-step partial epoch.
        bus.post(4, 1, grad(4.0)).unwrap();
        bus.post(3, 1, grad(3.0)).unwrap();
        assert_eq!(bus.resolved_count(), 1, "partial window waits for close");
        bus.close(5);
        assert_eq!(bus.resolved_count(), 2);
        let EpochWait::Resolved(ep) = bus.wait_epoch(1) else { panic!() };
        assert_eq!((ep.start, ep.end), (3, 5));
        assert_eq!(ep.contribs.len(), 1, "only device 1 stepped");
        assert!(matches!(bus.wait_epoch(2), EpochWait::Finished));
    }

    #[test]
    fn reduce_bus_stream_end_only_period_makes_one_epoch() {
        // allreduce_every = 0: nothing resolves until close, then the
        // whole run is one epoch.
        let bus = ReduceBus::new(3, 0, 0);
        for g in 0..7u64 {
            bus.post(g, (g % 3) as usize, grad(g as f64)).unwrap();
            assert_eq!(bus.epochs_before(g), 0, "no step depends on a sync");
        }
        assert_eq!(bus.resolved_count(), 0);
        bus.close(7);
        assert_eq!(bus.resolved_count(), 1);
        let EpochWait::Resolved(ep) = bus.wait_epoch(0) else { panic!() };
        assert_eq!((ep.start, ep.end), (0, 7));
        assert_eq!(ep.contribs.len(), 3);
        // Empty stream: close(0) resolves nothing and finishes everyone.
        let empty = ReduceBus::new(2, 0, 0);
        empty.close(0);
        assert_eq!(empty.resolved_count(), 0);
        assert!(matches!(empty.wait_epoch(0), EpochWait::Finished));
    }

    #[test]
    fn reduce_bus_warm_start_aligns_windows_to_absolute_counts() {
        // A trainer resuming at absolute step 5 with K = 4 must sync at
        // absolute boundaries 8, 12, … — the first epoch window is the
        // 3-step remainder [5, 8).
        let bus = ReduceBus::new(2, 4, 5);
        assert_eq!(bus.epochs_before(5), 0);
        assert_eq!(bus.epochs_before(7), 0);
        assert_eq!(bus.epochs_before(8), 1);
        assert_eq!(bus.epochs_before(12), 2);
        for r in 0..3u64 {
            bus.post(r, 0, grad(r as f64)).unwrap();
        }
        assert_eq!(bus.resolved_count(), 1, "partial first window [5, 8)");
        let EpochWait::Resolved(ep) = bus.wait_epoch(0) else { panic!() };
        assert_eq!((ep.start, ep.end), (0, 3));
        bus.post(3, 1, grad(3.0)).unwrap();
        assert_eq!(bus.resolved_count(), 1, "window [8, 12) incomplete");
        bus.close(4);
        assert_eq!(bus.resolved_count(), 2);
    }

    #[test]
    fn reduce_bus_abort_wakes_blocked_waiters() {
        let bus = ReduceBus::new(2, 1, 0);
        std::thread::scope(|scope| {
            let waiter = scope.spawn(|| bus.wait_epoch(0));
            std::thread::sleep(std::time::Duration::from_millis(5));
            bus.abort();
            assert!(matches!(waiter.join().unwrap(), EpochWait::Aborted));
        });
        assert!(bus.is_aborted());
    }

    #[test]
    fn reduce_bus_concurrent_posters_resolve_deterministically() {
        // 4 threads post their round-robin share of 64 steps in parallel;
        // the resolved epoch sequence must be the same every time.
        for _ in 0..8 {
            let bus = ReduceBus::new(4, 8, 0);
            std::thread::scope(|scope| {
                for d in 0..4usize {
                    let bus = &bus;
                    scope.spawn(move || {
                        for g in (d as u64..64).step_by(4) {
                            bus.post(g, d, grad(g as f64)).unwrap();
                        }
                    });
                }
            });
            bus.close(64);
            assert_eq!(bus.resolved_count(), 8);
            for e in 0..8u64 {
                let EpochWait::Resolved(ep) = bus.wait_epoch(e) else { panic!() };
                assert_eq!((ep.start, ep.end), (e * 8, (e + 1) * 8));
                assert_eq!(ep.contribs.len(), 4);
                for (d, c) in ep.contribs.iter().enumerate() {
                    assert_eq!(c.device, d);
                    assert_eq!(c.steps.len(), 2, "each device owns 2 of 8 steps");
                    let losses: Vec<f64> = c.steps.iter().map(|s| s.loss).collect();
                    assert_eq!(losses, vec![(e * 8 + d as u64) as f64, (e * 8 + 4 + d as u64) as f64]);
                }
            }
        }
    }

    #[test]
    fn router_skips_dead_lanes_under_both_policies() {
        let mut r = DeviceRouter::new(3, RoutePolicy::RoundRobin);
        r.mark_dead(1);
        assert_eq!(r.alive_count(), 2);
        assert!(!r.is_alive(1));
        let picks: Vec<usize> = (0..5).map(|_| r.route(10)).collect();
        assert_eq!(picks, vec![0, 2, 0, 2, 0], "round-robin skips the dead lane");

        let mut ll = DeviceRouter::new(3, RoutePolicy::LeastLoaded);
        // Lane 0 would win every empty-ledger tie; kill it.
        ll.mark_dead(0);
        assert_eq!(ll.route(10), 1);
        assert_eq!(ll.route(10), 2);
        assert_eq!(ll.route(10), 1);
        assert_eq!(ll.tracker().load(0), 0, "dead lane never charged");
    }

    #[test]
    fn forfeited_steps_complete_windows_without_contributing() {
        // K = 3 over 2 devices; device 1 dies owning steps 1 and 2.
        let bus = ReduceBus::new(2, 3, 0);
        bus.post(0, 0, grad(0.0)).unwrap();
        assert_eq!(bus.resolved_count(), 0);
        bus.forfeit(1..3);
        assert_eq!(bus.resolved_count(), 1, "tombstones complete the window");
        assert_eq!(bus.forfeited_count(), 2);
        let EpochWait::Resolved(ep) = bus.wait_epoch(0) else { panic!() };
        assert_eq!((ep.start, ep.end), (0, 3));
        assert_eq!(ep.contribs.len(), 1, "only the survivor contributed");
        assert_eq!(ep.contribs[0].device, 0);
        // Forfeiting is idempotent.
        bus.forfeit(1..3);
        assert_eq!(bus.forfeited_count(), 2);
    }

    #[test]
    fn leaver_counts_as_served_so_survivors_release_epochs() {
        // 2 devices, K = 1. Epoch 0 resolves; the doomed device applied it
        // (fetched once), then leaves. The survivor's fetch must still
        // drop the epoch, and later epochs need only the survivor.
        let bus = ReduceBus::new(2, 1, 0);
        bus.post(0, 0, grad(0.0)).unwrap();
        let EpochWait::Resolved(_) = bus.wait_epoch(0) else { panic!() };
        bus.leave(1); // applied epoch 0 already — do not double-serve it
        assert_eq!(bus.leavers(), 1);
        let EpochWait::Resolved(_) = bus.wait_epoch(0) else { panic!() };
        // Epoch 1 resolves after the departure: pre-served by the leaver,
        // a single survivor fetch must release it (no deadlocked waiter).
        bus.post(1, 0, grad(1.0)).unwrap();
        let EpochWait::Resolved(ep) = bus.wait_epoch(1) else { panic!() };
        assert_eq!(ep.epoch, 1);
        bus.close(2);
        assert!(matches!(bus.wait_epoch(2), EpochWait::Finished));
    }

    #[test]
    fn leave_before_survivor_fetch_does_not_drop_the_epoch() {
        // The regression the membership math must avoid: an epoch the
        // leaver never applied is pre-served by its departure, but the
        // survivor's copy must stay alive until the survivor fetches it.
        let bus = ReduceBus::new(2, 1, 0);
        bus.post(0, 1, grad(0.5)).unwrap();
        bus.leave(0); // died before applying epoch 0
        let EpochWait::Resolved(ep) = bus.wait_epoch(0) else {
            panic!("survivor must still get epoch 0")
        };
        assert_eq!(ep.contribs[0].device, 1);
    }

    #[test]
    fn pending_cap_errors_instead_of_buffering_forever() {
        // allreduce_every = 0 buffers every step until close; a tight cap
        // must surface a typed error, not grow without bound.
        let bus = ReduceBus::new(1, 0, 0).with_pending_cap(4);
        for g in 0..4u64 {
            bus.post(g, 0, grad(g as f64)).unwrap();
        }
        let err = bus.post(4, 0, grad(4.0)).unwrap_err();
        assert!(matches!(err, EtlError::Mem(_)), "got: {err}");
        assert!(err.to_string().contains("allreduce_every"));
        // A folding window never hits the cap: same cap, K = 2.
        let windowed = ReduceBus::new(1, 2, 0).with_pending_cap(4);
        for g in 0..32u64 {
            windowed.post(g, 0, grad(g as f64)).unwrap();
        }
        assert_eq!(windowed.resolved_count(), 16);
    }

    #[test]
    fn mark_dead_clears_the_outstanding_byte_ledger() {
        // The rejoin-skew bug: a lane dying with outstanding routed bytes
        // used to keep them on the ledger forever, so LeastLoaded would
        // shun the lane after it rejoined. Death must clear the ledger.
        let mut r = DeviceRouter::new(2, RoutePolicy::LeastLoaded);
        assert_eq!(r.route(1000), 0);
        assert_eq!(r.route(10), 1);
        assert_eq!(r.tracker().load(0), 1000);
        r.mark_dead(0);
        assert_eq!(r.tracker().load(0), 0, "death clears the ledger");
        // Rejoin: the lane competes on equal footing again (it wins the
        // 0-byte tie against lane 1's 10 outstanding bytes).
        r.mark_alive(0);
        assert_eq!(r.route(10), 0, "rejoined lane is not shunned");
        // A straggling completion for work drained before death saturates
        // against the cleared ledger instead of wrapping.
        r.tracker().complete(0, 1000);
        assert_eq!(r.tracker().load(0), 0);
    }

    #[test]
    fn router_extend_admits_a_joiner_only_after_mark_alive() {
        let mut r = DeviceRouter::with_capacity(2, 4, RoutePolicy::RoundRobin);
        assert_eq!(r.devices(), 2);
        let d = r.extend();
        assert_eq!((d, r.devices()), (2, 3));
        assert!(!r.is_alive(2), "a joiner starts out of rotation");
        let before: Vec<usize> = (0..4).map(|_| r.route(10)).collect();
        assert_eq!(before, vec![0, 1, 0, 1]);
        r.mark_alive(2);
        let after: Vec<usize> = (0..6).map(|_| r.route(10)).collect();
        assert_eq!(after, vec![0, 1, 2, 0, 1, 2], "joiner enters the cycle");
        // LeastLoaded sees the joiner's clean ledger too.
        r.set_policy(RoutePolicy::LeastLoaded);
        r.tracker().complete(2, 20); // clear the joiner's two charges
        assert_eq!(r.route(10), 2);
    }

    #[test]
    fn reduce_bus_join_raises_the_release_threshold() {
        // 1 member, K = 1. Epoch 0 resolves and is fetched once (old
        // threshold) — then a joiner synced through epoch 0 arrives:
        // epoch 0 counts it as served, epoch 1 needs both fetches.
        let bus = ReduceBus::new(1, 1, 0);
        bus.post(0, 0, grad(0.0)).unwrap();
        let EpochWait::Resolved(_) = bus.wait_epoch(0) else { panic!() };
        let d = bus.join(1).unwrap();
        assert_eq!((d, bus.devices()), (1, 2));
        bus.post(1, 0, grad(1.0)).unwrap();
        let EpochWait::Resolved(_) = bus.wait_epoch(1) else { panic!() };
        // Not released yet: the joiner still owes its fetch.
        let EpochWait::Resolved(ep) = bus.wait_epoch(1) else {
            panic!("epoch 1 must survive until the joiner fetches it")
        };
        assert_eq!(ep.epoch, 1);
        bus.close(2);
        assert!(matches!(bus.wait_epoch(2), EpochWait::Finished));
    }

    #[test]
    fn reduce_bus_join_past_a_released_epoch_is_rejected() {
        let bus = ReduceBus::new(1, 1, 0);
        bus.post(0, 0, grad(0.0)).unwrap();
        let EpochWait::Resolved(_) = bus.wait_epoch(0) else { panic!() };
        // Epoch 0 is fully served and dropped; a joiner synced through
        // nothing (applied = 0) can never fetch it.
        let err = bus.join(0).unwrap_err();
        assert!(err.to_string().contains("join too late"), "{err}");
        // Synced through epoch 0, the same joiner is admissible.
        assert_eq!(bus.join(1).unwrap(), 1);
    }

    #[test]
    fn retune_every_applies_at_the_next_epoch_boundary() {
        // K = 4 → retune to K = 2 at frontier 5: window [4, 8) finishes
        // under the old period, then [8, 10), [10, 12).
        let bus = ReduceBus::new(1, 4, 0);
        bus.retune_every(5, 2);
        for g in 0..12u64 {
            bus.post(g, 0, grad(g as f64)).unwrap();
        }
        let mut ends = Vec::new();
        for e in 0..bus.resolved_count() {
            let EpochWait::Resolved(ep) = bus.wait_epoch(e) else { panic!() };
            ends.push((ep.start, ep.end));
        }
        assert_eq!(ends, vec![(0, 4), (4, 8), (8, 10), (10, 12)]);
        // Steps query their epoch through the same segment schedule.
        assert_eq!(bus.epochs_before(7), 1);
        assert_eq!(bus.epochs_before(8), 2);
        assert_eq!(bus.epochs_before(10), 3);
    }

    #[test]
    fn retune_every_same_period_and_reretune_are_stable() {
        let bus = ReduceBus::new(1, 4, 0);
        bus.retune_every(0, 4); // no-op: unchanged period
        // Two retunes before the first boundary: the second overrides the
        // first at the same boundary (step 4), so K = 3 wins.
        bus.retune_every(1, 2);
        bus.retune_every(2, 3);
        for g in 0..10u64 {
            bus.post(g, 0, grad(g as f64)).unwrap();
        }
        let mut ends = Vec::new();
        for e in 0..bus.resolved_count() {
            let EpochWait::Resolved(ep) = bus.wait_epoch(e) else { panic!() };
            ends.push((ep.start, ep.end));
        }
        assert_eq!(ends, vec![(0, 4), (4, 7), (7, 10)]);
    }
}
