//! Multi-FPGA ETL sharding (paper §3.5): "because ETL demand scales with
//! data volume rather than model size, ETL can be sharded across FPGAs
//! independently of the number of trainers." This module plans and
//! simulates that scale-out: a fleet of vFPGA devices, a shard router
//! assigning dataset shards to devices, and aggregate-throughput
//! provisioning against a target trainer consumption rate.

use crate::memsys::IngestSource;
use crate::planner::HardwarePlan;

/// One ETL device in the fleet.
#[derive(Debug, Clone)]
pub struct EtlShard {
    pub device_id: usize,
    /// Pipelines instantiated on this device.
    pub pipelines: usize,
    /// Ingest source for this device.
    pub source: IngestSource,
}

/// A provisioning plan for a trainer fleet.
#[derive(Debug, Clone)]
pub struct ShardingPlan {
    pub shards: Vec<EtlShard>,
    /// Aggregate ETL bandwidth (bytes/s).
    pub aggregate_bw: f64,
    /// Target trainer consumption (bytes/s).
    pub target_bw: f64,
}

impl ShardingPlan {
    /// Headroom ratio (≥ 1.0 means the trainers stay fed).
    pub fn headroom(&self) -> f64 {
        self.aggregate_bw / self.target_bw
    }
}

/// Per-device throughput with `pipelines` instances (clock derating per
/// §4.8) ingesting from `source`.
pub fn device_bw(plan: &HardwarePlan, pipelines: usize, source: IngestSource) -> f64 {
    let clk_scale = match pipelines {
        0..=4 => 1.0,
        5 | 6 => 0.9,
        _ => 0.75,
    };
    let per_pipe = plan.line_rate() * clk_scale;
    let ingest_share = source.stream_bandwidth() / pipelines.max(1) as f64;
    pipelines as f64 * per_pipe.min(ingest_share)
}

/// Provision the minimum fleet that sustains `target_bw` of trainer
/// consumption with `headroom` (>1 keeps backpressure credits from
/// exhausting during vocab-heavy phases). Fills devices up to 4 pipelines
/// (the linear-scaling region) before adding a device.
pub fn provision(
    plan: &HardwarePlan,
    target_bw: f64,
    headroom: f64,
    source: IngestSource,
) -> ShardingPlan {
    assert!(target_bw > 0.0 && headroom >= 1.0);
    let need = target_bw * headroom;
    let per_device = device_bw(plan, 4, source);
    let mut shards = Vec::new();
    let mut agg = 0.0;
    let mut device_id = 0;
    while agg < need {
        // Last device may need fewer pipelines.
        let remaining = need - agg;
        let mut pipelines = 4;
        for p in 1..=4usize {
            if device_bw(plan, p, source) >= remaining {
                pipelines = p;
                break;
            }
        }
        let bw = device_bw(plan, pipelines, source);
        shards.push(EtlShard { device_id, pipelines, source });
        agg += bw;
        device_id += 1;
        if device_id > 1024 {
            break; // provisioning guard
        }
        let _ = per_device;
    }
    ShardingPlan { shards, aggregate_bw: agg, target_bw }
}

/// Route dataset shard `shard_idx` to a device round-robin — stateless
/// operators permit arbitrary routing; stateful pipelines use a stable
/// hash so each device's vocabulary sees a consistent key partition.
pub fn route(plan: &ShardingPlan, shard_idx: usize, stateful: bool) -> usize {
    let n = plan.shards.len().max(1);
    if stateful {
        (crate::etl::ops::kernels::mix64(shard_idx as u64) % n as u64) as usize
    } else {
        shard_idx % n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::etl::pipelines::{build, PipelineKind};
    use crate::etl::schema::Schema;
    use crate::planner::{compile, PlannerConfig};

    fn plan() -> HardwarePlan {
        let schema = Schema::criteo_kaggle();
        let dag = build(PipelineKind::I, &schema);
        compile(&dag, &schema, &PlannerConfig::default()).unwrap()
    }

    #[test]
    fn provision_meets_target_with_headroom() {
        let p = plan();
        // Feed 8 trainers at 100 MB/s each with 1.5× headroom.
        let sharding = provision(&p, 8.0 * 100.0e6, 1.5, IngestSource::OnBoard);
        assert!(sharding.headroom() >= 1.5);
        // One device at 11.5 GB/s line rate is plenty.
        assert_eq!(sharding.shards.len(), 1);
    }

    #[test]
    fn provision_scales_out_for_big_fleets() {
        let p = plan();
        // A trainer fleet consuming 100 GB/s needs multiple devices.
        let sharding = provision(&p, 100.0e9, 1.0, IngestSource::OnBoard);
        assert!(sharding.shards.len() > 1, "{:?}", sharding.shards.len());
        assert!(sharding.aggregate_bw >= 100.0e9);
        // Devices fill to 4 pipelines (linear region) before adding more.
        assert!(sharding.shards[0].pipelines == 4);
    }

    #[test]
    fn routing_is_stable_and_in_range() {
        let p = plan();
        let sharding = provision(&p, 100.0e9, 1.0, IngestSource::OnBoard);
        let n = sharding.shards.len();
        for idx in 0..100 {
            let a = route(&sharding, idx, true);
            let b = route(&sharding, idx, true);
            assert_eq!(a, b);
            assert!(a < n);
            assert_eq!(route(&sharding, idx, false), idx % n);
        }
    }

    #[test]
    fn stateful_routing_balances() {
        let p = plan();
        let sharding = provision(&p, 100.0e9, 1.0, IngestSource::OnBoard);
        let n = sharding.shards.len();
        let mut counts = vec![0usize; n];
        for idx in 0..10_000 {
            counts[route(&sharding, idx, true)] += 1;
        }
        let expect = 10_000 / n;
        for c in counts {
            assert!(c > expect / 2 && c < expect * 2, "c={c} expect={expect}");
        }
    }
}
