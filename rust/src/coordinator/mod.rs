//! L3 co-scheduling runtime (the paper's system contribution, §3): the
//! format-aware packer, credit-gated P2P staging with double buffering,
//! the ETL/training overlap scheduler with its multi-device routing layer
//! ([`RoutePolicy`]: round-robin for bit-reproducibility, least-loaded
//! for throughput, byte ties to the lowest device index) and barrier-free
//! gradient all-reduce bus ([`ReduceBus`]: epoch-tagged f64 gradient
//! contributions, replicas block only on the epoch their next step
//! depends on), and the live training loop that composes the FPGA data
//! plane with the trainer — across one simulated GPU or a routed fleet of
//! **truly concurrent** per-device consumer threads
//! ([`TrainConfig::devices`], per-device breakdowns in
//! [`TrainReport::per_device`]; see `train_loop`'s module docs for the
//! concurrency model and the reproducibility matrix of knob
//! combinations).
//!
//! # Failure domains
//!
//! Every stage of the ingest→pack→DMA→train pipeline has a bounded
//! failure domain with a typed error, a recovery action, and an exact
//! accounting counter. Faults are injected deterministically by
//! [`crate::util::fault`] (a pure function of plan seed × site × stable
//! key, so tests predict the afflicted set in advance) and every
//! recovery path below is exercised by `rust/tests/prop_faults.rs`
//! under fuzzed thread schedules:
//!
//! | site (`util::fault::site`) | where it strikes | recovery | accounting |
//! |---|---|---|---|
//! | `SHARD_READ` | shard production I/O ([`crate::dataio::ingest`]) | bounded per-shard retry with exponential backoff ([`crate::dataio::ingest::IngestConfig::max_retries`] / `backoff`), resume from the last delivered chunk | [`crate::dataio::ingest::IngestReport::retries`] |
//! | `ROW_DECODE` | per-chunk decode after read | same retry ladder; a shard that exhausts it is quarantined (skipped, stream continues) when `quarantine` is set, else a typed error | [`crate::dataio::ingest::IngestReport::quarantined`] |
//! | `SLOW_SHARD` | straggling producer | none needed — stalls are benign; delivery policy masks or exposes reordering | latency only |
//! | `WORKER_DEATH` | ingest worker thread panic | positive death signal (`catch_unwind` → `Died` token, never a hang), bounded respawn, then quarantine or [`crate::error::EtlError::WorkerDied`] | [`crate::dataio::ingest::IngestReport::worker_deaths`] |
//! | `DMA` | a device transfer attempt ([`crate::devmem::TransferEngine`]) | per-transfer re-issue on the same engine clock (failed attempts still occupy the wire), per-transfer timeout cut, up to [`crate::devmem::TransferConfig::max_retries`]; past budget → [`crate::error::EtlError::Fault`], which on a multi-device fleet demotes to a lane loss | [`TrainReport::retried_transfers`] / [`TrainReport::failed_transfers`] |
//! | `LANE_LOSS` | a device consumer mid-run | lane drains: consumer leaves the reduce group ([`ReduceBus::leave`]), queued step ranges are tombstoned ([`ReduceBus::forfeit`]) so epochs still resolve, the router re-routes remaining shards to survivors; no survivor → [`crate::error::EtlError::LaneLost`] | [`TrainReport::lanes_lost`] / [`TrainReport::forfeited_steps`] |
//! | `PREFETCH` | an embedding-cache promotion transfer ([`crate::runtime::embedding::EmbShardCache::promote`]) | bounded re-issue on the lane's promotion clock (each failed attempt burns the wire time); past budget the batch is abandoned — rows stay cold and surface as later demand misses, never as corrupt lookups; a dead *owner* lane re-homes its rows from the host cold tier | [`crate::runtime::embedding::EmbCacheStats::retried_prefetches`] / `failed_prefetches` / `rehomed_rows` |
//!
//! Cross-cutting guarantees: a fault-free run is bit-identical with the
//! fault layer compiled in (injection disabled is a branch on a relaxed
//! atomic — see the `fault_overhead` hotpath bench section); retried-
//! but-delivered runs reproduce the fault-free trajectory bitwise
//! (in-order, sync-every-step); and `delivered + quarantined = total`
//! holds exactly. [`crate::error::EtlError::is_fault`] classifies which errors the
//! recovery ladder may absorb; everything else aborts loudly.
//!
//! # Elastic fleet: lane lifecycle and the live control plane
//!
//! Every arena-path run is driven by the [`fleet`] runtime: per-device
//! **lanes** (pack worker + arena region + DMA clock + consumer thread)
//! assembled up front at the fleet's peak width, with a scripted
//! [`ControlScript`] of `(global_step, KnobChange)` events the router
//! applies mid-run. A lane walks one lifecycle:
//!
//! ```text
//!            AddLane applied                 RemoveLane applied
//!  Joining ────────────────────▶ Live ────────────────────────▶ Draining
//!     │                            │                               │
//!     │                            │ fault (DMA hard-fail /        │ queued slots
//!     │                            │ LANE_LOSS injection)          │ still train
//!     └────────── fleet ends ──────┴───────────────────────▶    Dead
//! ```
//!
//! Scripted changes land only at **quiesce points** — on the router
//! thread, between two shard routings, at the first routing frontier
//! `cum >= at_step`:
//!
//! ```text
//!   route(shard k) ─▶ [apply events with at_step <= cum] ─▶ route(shard k+1)
//!       Route / AllreduceEvery / Lookahead        retune in place
//!       AddLane / RemoveLane                      mask flip / sender taken
//!       IngestWorkers / ChunkRows                 restart at next shard boundary
//! ```
//!
//! Because no shard spans an application, a script is a pure function of
//! the delivery-order step numbering: scripted runs are **bitwise
//! identical under schedule fuzzing** (`rust/tests/prop_elastic.rs`).
//! [`KnobRegistry`] logs each application;
//! [`TrainReport::reconfigs`] counts them. Full details (deferred ingest
//! restarts, joiner epoch sync, graceful-drain accounting) in the
//! [`fleet`] module docs.
//!
//! # Online auto-tuner: closing the loop
//!
//! [`TrainConfig::autotune`] arms the [`autotune`] controller — the
//! closed feedback loop ROADMAP item 3 called for, with the scripted
//! control plane as its actuator:
//!
//! ```text
//!   SIGNAL                DECISION                ACTUATION
//!   windowed per-lane ──▶ dominant stall cause ─▶ one KnobChange at the
//!   StallAttribution      (greedy coordinate      next quiesce point
//!   (last W steps,         descent)               (same path as a script,
//!    sim-clock model)          │                   logged with its cause)
//!        ▲                     ▼
//!        │                HYSTERESIS: hold `cooldown` windows, judge
//!        │                windowed steps/s vs the pre-change baseline
//!        └──────────────  keep (≥ min_gain) or revert + retire the cause
//! ```
//!
//! | window signal | cause | knob ladder |
//! |---|---|---|
//! | per-lane modeled work max/mean over threshold | skew | `Route(LeastLoaded)` |
//! | idle time under ingest-read spans | ingest | `IngestWorkers` ×2, then `ChunkRows` ×4 → whole shards |
//! | idle time under slot-credit waits | backpressure | `Lookahead` +2 (embedding), else an `ArenaConfig::slots` hint |
//! | reduce-epoch busy time | reduce | `AllreduceEvery` ×2 |
//!
//! Observations are **simulated-clock only** (the router/worker
//! observation ledger plus a deterministic pipeline model), so
//! controller decisions are a pure function of (config, delivery
//! order) and replay bitwise under the schedule fuzzer
//! (`rust/tests/prop_autotune.rs`); the adversarial scenario matrix and
//! its ≥ 0.9× hand-tuned success bar live in [`crate::scenarios`].

pub mod autotune;
pub mod fleet;
pub mod online;
pub mod packer;
pub mod scheduler;
pub mod sharding;
pub mod staging;
pub mod train_loop;

pub use autotune::{
    AppliedKnob, AutotuneConfig, AutotuneReport, HillClimber, StallCause, WindowSummary,
};
pub use fleet::{ControlEvent, ControlScript, KnobChange, KnobRegistry, LaneState};
pub use packer::{pack, PackLayout, PackedBatch, PackedBatchView};
pub use scheduler::{
    cpu_gpu_config, piperec_config, simulate_overlap, utilization_trace, DeviceRouter,
    EpochContrib, EpochWait, LoadTracker, OverlapConfig, OverlapResult, PrefetchPipeline,
    ReduceBus, ReducedEpoch, RoutePolicy,
};
pub use online::{classify_psi, DriftDetector, DriftVerdict, FreshnessTracker, OnlineVocab};
pub use sharding::{provision, route, ShardingPlan};
pub use staging::{StagingConsumer, StagingQueue, StagingSim};
pub use train_loop::{run as train, DataPath, DeviceReport, TrainConfig, TrainReport};
