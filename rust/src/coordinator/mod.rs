//! L3 co-scheduling runtime (the paper's system contribution, §3): the
//! format-aware packer, credit-gated P2P staging with double buffering,
//! the ETL/training overlap scheduler with its multi-device routing layer
//! ([`RoutePolicy`]: round-robin for bit-reproducibility, least-loaded
//! for throughput, byte ties to the lowest device index) and barrier-free
//! gradient all-reduce bus ([`ReduceBus`]: epoch-tagged f64 gradient
//! contributions, replicas block only on the epoch their next step
//! depends on), and the live training loop that composes the FPGA data
//! plane with the trainer — across one simulated GPU or a routed fleet of
//! **truly concurrent** per-device consumer threads
//! ([`TrainConfig::devices`], per-device breakdowns in
//! [`TrainReport::per_device`]; see `train_loop`'s module docs for the
//! concurrency model and the reproducibility matrix of knob
//! combinations).

pub mod online;
pub mod packer;
pub mod scheduler;
pub mod sharding;
pub mod staging;
pub mod train_loop;

pub use packer::{pack, PackLayout, PackedBatch, PackedBatchView};
pub use scheduler::{
    cpu_gpu_config, piperec_config, simulate_overlap, utilization_trace, DeviceRouter,
    EpochContrib, EpochWait, LoadTracker, OverlapConfig, OverlapResult, ReduceBus, ReducedEpoch,
    RoutePolicy,
};
pub use online::{classify_psi, DriftDetector, DriftVerdict, FreshnessTracker, OnlineVocab};
pub use sharding::{provision, route, ShardingPlan};
pub use staging::{StagingConsumer, StagingQueue, StagingSim};
pub use train_loop::{run as train, DataPath, DeviceReport, TrainConfig, TrainReport};
