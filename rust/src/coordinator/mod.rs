//! L3 co-scheduling runtime (the paper's system contribution, §3): the
//! format-aware packer, credit-gated P2P staging with double buffering,
//! the ETL/training overlap scheduler, and the live training loop that
//! composes the FPGA data plane with the PJRT trainer.

pub mod online;
pub mod packer;
pub mod scheduler;
pub mod sharding;
pub mod staging;
pub mod train_loop;

pub use packer::{pack, PackLayout, PackedBatch, PackedBatchView};
pub use scheduler::{cpu_gpu_config, piperec_config, simulate_overlap, OverlapConfig, OverlapResult};
pub use online::{classify_psi, DriftDetector, DriftVerdict, FreshnessTracker, OnlineVocab};
pub use sharding::{provision, route, ShardingPlan};
pub use staging::{StagingConsumer, StagingQueue, StagingSim};
pub use train_loop::{run as train, DataPath, TrainConfig, TrainReport};
