//! The live training loop: ETL (simulated FPGA data plane, real
//! functional transforms) feeding the trainer through credit-gated
//! device staging — the end-to-end composition of all layers.
//!
//! The producer side plays the FPGA role (§3.5) as a fully overlapped
//! streaming dataflow: N async ingest workers generate shards into
//! pool-recycled buffers ([`crate::dataio::ingest`]), the fused engine
//! transforms+packs each shard, and the staging queue hands it to the
//! consumer — so shard I/O, fused apply+pack, P2P transfer and trainer
//! steps all overlap. The consumer is the GPU stand-in: pop, train,
//! return the credit. GPU utilization is measured as train-busy time over
//! wall time per window, exactly as Fig. 14 reports.
//!
//! Two data paths share the protocol ([`DataPath`]):
//!
//! * [`DataPath::Arena`] (default) — the **zero-copy** path of
//!   [`crate::devmem`]: the fused engine packs each shard once, directly
//!   into a [`crate::devmem::StagingSlot`] of the pinned device arena;
//!   the [`crate::devmem::TransferEngine`] accounts the chunked P2P DMA
//!   that makes the slot resident; the trainer steps **in place** on
//!   [`crate::devmem::DeviceBatchView`]s and releases the slot's credit.
//!   Zero per-shard `PackedBatch` heap allocations in the steady state,
//!   zero host-side copies between pack and training.
//! * [`DataPath::Channel`] — the legacy heap path: pool-recycled owned
//!   [`crate::coordinator::packer::PackedBatch`]es travel the staging
//!   queue by value (one logical host copy per packed byte). Kept as the
//!   differential baseline (`rust/tests/prop_devmem.rs` pins the two
//!   paths bit-identical) and for the `zero-copy` hotpath bench section.
//!
//! Ingest-wait, fused-exec and transfer-wait time are attributed
//! separately in the report so stage imbalance is visible (ROADMAP:
//! pipeline-stage attribution).
//!
//! # Multi-device (N simulated GPUs, truly concurrent consumers)
//!
//! With [`TrainConfig::devices`] > 1 the arena path becomes a routed
//! fleet with **one consumer thread per device**: a
//! [`crate::devmem::ArenaSet`] holds one staging region per device in a
//! shared MMU address space, each device lane has its own pack worker,
//! DMA clock, staged-slot queue and trainer replica, and the scheduler's
//! [`crate::coordinator::scheduler::DeviceRouter`] assigns every ingested
//! shard to a lane ([`crate::coordinator::scheduler::RoutePolicy`]:
//! round-robin pins a bit-reproducible schedule, least-loaded follows the
//! outstanding-byte ledger with byte ties broken to the lowest device
//! index).
//!
//! ```text
//!             router (delivery order, stamps global step ranges)
//!                │ shard+start_g        │                 │
//!         ┌──────▼──────┐       ┌───────▼─────┐    ┌──────▼──────┐
//!  lane 0 │ pack worker │       │ pack worker │ …  │ pack worker │ lane N-1
//!         │ arena 0+DMA0│       │ arena 1+DMA1│    │ arena N-1   │
//!         └──────┬──────┘       └───────┬─────┘    └──────┬──────┘
//!          slot queue 0           slot queue 1       slot queue N-1
//!         ┌──────▼──────┐       ┌───────▼─────┐    ┌──────▼──────┐
//!         │ consumer 0  │       │ consumer 1  │ …  │ consumer N-1│   one thread
//!         │ replica 0   │       │ replica 1   │    │ replica N-1 │   per device
//!         └──────┬──────┘       └───────┬─────┘    └──────┬──────┘
//!                └── grad posts ─┴─ ReduceBus ─┴─ epoch waits ──┘
//!                    (barrier-free epoch-tagged all-reduce)
//! ```
//!
//! Replicas are kept consistent by the **barrier-free gradient
//! all-reduce** of [`crate::coordinator::scheduler::ReduceBus`]: each
//! consumer steps its replica locally (`Trainer::grad_step`) and posts an
//! f64 gradient-level contribution per step; an epoch (a window of
//! [`TrainConfig::allreduce_every`] global steps in delivery order)
//! resolves as soon as all of its steps are posted, and each replica
//! independently replays the resolved epoch's contributions —
//! device-ascending — onto its last synced base
//! (`Trainer::apply_reduced`), landing every replica on bitwise identical
//! parameters with no rendezvous barrier and no state broadcast. The
//! reduction is costed per epoch against the calibrated P2P channel as a
//! deterministic tree ([`TrainReport::allreduce_sim_s`]); consumer time
//! blocked on epoch resolution is attributed to
//! [`TrainReport::reduce_wait_s`].
//!
//! **Reproducibility matrix** (pinned by `rust/tests/prop_devmem.rs` and
//! the schedule-fuzzing harness `rust/tests/prop_concurrent.rs`):
//!
//! * round-robin + `allreduce_every = 1` + in-order ingest — **bitwise
//!   identical** to the single-device trajectory (losses and final
//!   parameters), under every schedule: each epoch has exactly one
//!   contributed step, so the replay is the exact single-device f32
//!   update, serialized by the epoch dependency chain.
//! * round-robin + `allreduce_every > 1` (or `= 0`, sync at stream end
//!   only) — **deterministic** (schedule-independent losses and
//!   parameters) but not single-device-identical: replicas run local SGD
//!   inside each window and the window reduction replays contributions
//!   from the shared base. This is the throughput mode: consumers overlap
//!   within each window.
//! * least-loaded — exactly-once, not deterministic (routing follows the
//!   live byte ledger).
//!
//! [`TrainReport::per_device`] breaks transfer-wait, DMA, staged bytes,
//! steps, train-busy and reduce-wait down per device.
//!
//! # Sharded embedding tables (model parallelism)
//!
//! [`TrainConfig::embedding`] layers the sharded embedding cache of
//! [`crate::runtime::embedding`] over the routed fleet: the trainer's
//! embedding pool is hash-sharded across the devices, each lane pins a
//! bounded hot set in its arena ([`crate::devmem::DeviceArena::reserve_cache`])
//! and spills the rest to the simulated host cold tier. The lane's pack
//! worker drives a [`crate::coordinator::scheduler::PrefetchPipeline`]:
//! right after staging a slot it promotes that slot's embedding rows, and
//! commits the hit/miss walk `lookahead` slots later — the router's
//! head-start is what hides the promotion latency. Sparse embedding
//! gradients ride the existing [`ReduceBus`] epochs (every step's f64
//! gradient image already carries the touched embedding slots); rows owned
//! by peer shards charge [`TrainReport::exchange_bytes`] both for the row
//! fetch and the gradient routed back. Because the authoritative values
//! stay in each replica's flat state, enabling the cache **never changes
//! the training arithmetic** — `rust/tests/prop_embedding.rs` pins the
//! cached run bitwise identical to the uncached reference across device
//! counts × cache sizes × lookahead depths, including tables that exceed
//! any single arena's budget (the memory wall the layer exists for).
//!
//! # Failure domains (lane loss)
//!
//! On the multi-device path a device lane can be **lost mid-run** — an
//! injected [`crate::util::fault::site::LANE_LOSS`] at the consumer, or
//! this lane's DMA engine hard-failing past its retry budget
//! ([`TransferConfig::max_retries`]) at the pack worker — without taking
//! down the fleet. The dying side marks the lane dead (the router stops
//! assigning it shards and re-routes the remainder to survivors), the
//! consumer leaves the reduce group ([`ReduceBus::leave`]) so peers stop
//! waiting on its fetches, and every step range still queued on the dead
//! lane is forfeited ([`ReduceBus::forfeit`]) so reduce epochs keep
//! resolving — survivors converge on the reduced state of the steps that
//! actually ran. Only when **no** lane survives does the run fail, with
//! [`EtlError::LaneLost`]. [`TrainReport::lanes_lost`],
//! [`TrainReport::forfeited_steps`], [`TrainReport::retried_transfers`]
//! and [`TrainReport::failed_transfers`] account the damage; the full
//! site-by-site fault matrix lives in [`crate::coordinator`]'s module
//! docs.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::coordinator::scheduler::{DeviceRouter, EpochWait, ReduceBus, RoutePolicy};
use crate::coordinator::staging::StagingQueue;
use crate::dataio::dataset::DatasetSpec;
use crate::dataio::ingest::{AsyncIngest, IngestConfig, ShardInput};
use crate::devmem::{
    ArenaConfig, ArenaSet, DeviceArena, StagingSlot, TransferConfig, TransferEngine, TransferSet,
};
use crate::error::{EtlError, Result};
use crate::etl::column::Batch;
use crate::etl::exec::BufferPool;
use crate::fpga::Pipeline;
use crate::memsys::{ChannelModel, Path};
use crate::metrics::TimeSeries;
use crate::runtime::Trainer;
use crate::trace::{self, kind as tkind};
use crate::util::fault::{self, site as fsite};
use crate::util::sched::{self, site};

/// Which staging dataflow the loop runs (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataPath {
    /// Zero-copy device staging: pack into pinned arena slots, simulated
    /// P2P DMA, in-place training, credit return.
    Arena,
    /// Heap `PackedBatch`es over the staging channel (legacy baseline).
    Channel,
}

/// Configuration of a live training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Maximum training steps (stop even if data remains).
    pub max_steps: usize,
    /// Read the loss every `loss_every` steps.
    pub loss_every: usize,
    /// Staging buffers (2 = double buffering).
    pub staging_buffers: usize,
    /// Dataset seed.
    pub seed: u64,
    /// Async shard-ingest knobs (workers / channel depth / delivery
    /// policy). The default (2 workers, depth 2, in-order) reproduces the
    /// synchronous producer's batch sequence bit-for-bit while overlapping
    /// shard generation with fused execution.
    pub ingest: IngestConfig,
    /// Staging dataflow (default: the zero-copy arena path).
    pub path: DataPath,
    /// Device-arena sizing for [`DataPath::Arena`] (per device when
    /// `devices` > 1).
    pub arena: ArenaConfig,
    /// P2P DMA engine knobs for [`DataPath::Arena`] (one engine clock per
    /// device when `devices` > 1).
    pub transfer: TransferConfig,
    /// Simulated GPUs fed by the staging dataflow. 1 = the single-device
    /// arena path; > 1 routes shards across an [`ArenaSet`] (arena path
    /// only).
    pub devices: usize,
    /// Shard→device routing policy for `devices` > 1.
    pub route: RoutePolicy,
    /// All-reduce period in global steps for `devices` > 1. 1 (default)
    /// syncs replicas after every step — the bit-reproducible schedule;
    /// larger periods run local SGD between syncs; 0 syncs only at stream
    /// end.
    pub allreduce_every: usize,
    /// Sharded embedding-table layer (model parallelism; arena path
    /// only). `Some` shards the trainer's embedding pool across the
    /// device fleet with a lookahead-prefetched hot/cold cache per lane
    /// (see [`crate::runtime::embedding`]); the cached execution stays
    /// bitwise identical to the uncached reference. `None` (default)
    /// keeps the whole pool implicit in each replica's flat state.
    pub embedding: Option<crate::runtime::embedding::EmbeddingConfig>,
    /// Record an end-to-end trace of the run (see [`crate::trace`]):
    /// dual-clock spans from every stage land in
    /// [`TrainReport::trace`], with the per-lane stall ledger in
    /// [`TrainReport::stall_attribution`]. Off (default), every probe
    /// costs one relaxed atomic load; tracing never changes the training
    /// arithmetic (pinned bitwise by `rust/tests/prop_trace.rs`).
    pub trace: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            max_steps: 200,
            loss_every: 10,
            staging_buffers: 2,
            seed: 42,
            ingest: IngestConfig::default(),
            path: DataPath::Arena,
            arena: ArenaConfig::default(),
            transfer: TransferConfig::default(),
            devices: 1,
            route: RoutePolicy::RoundRobin,
            allreduce_every: 1,
            embedding: None,
            trace: false,
        }
    }
}

/// Per-device breakdown of a training run (one entry per simulated GPU;
/// the single-device paths report exactly one).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DeviceReport {
    /// Device index.
    pub device: usize,
    /// Shards routed to and packed on this device's lane.
    pub shards: u64,
    /// Training steps this device's replica executed.
    pub steps: u64,
    /// Host seconds this lane's pack worker spent blocked on device
    /// staging (credit + queue waits).
    pub transfer_wait_s: f64,
    /// Simulated seconds this device's DMA engine spent on the wire.
    pub dma_sim_s: f64,
    /// Packed bytes staged into this device's arena.
    pub staged_bytes: u64,
    /// Host seconds spent stepping this device's replica.
    pub train_busy_s: f64,
    /// Host seconds this device's consumer thread spent blocked on
    /// reduce-epoch resolution (waiting for peers' contributions).
    pub reduce_wait_s: f64,
}

/// Result of a live training run.
#[derive(Debug)]
pub struct TrainReport {
    pub steps: u64,
    /// (step, loss) samples.
    pub losses: Vec<(u64, f32)>,
    /// Wall-clock seconds end to end.
    pub wall_s: f64,
    /// Seconds the trainer was executing steps.
    pub train_busy_s: f64,
    /// Measured GPU(-stand-in) utilization = busy / wall.
    pub util: f64,
    /// Utilization trace per ~20-step window.
    pub util_trace: TimeSeries,
    /// Producer-side backpressure stalls.
    pub producer_stalls: u64,
    /// Host seconds the producer spent in fused apply+pack (exec time,
    /// excluding ingest wait).
    pub etl_host_s: f64,
    /// Host seconds the producer spent blocked waiting on shard ingest
    /// (I/O-wait attribution, disjoint from `etl_host_s`).
    pub ingest_wait_s: f64,
    /// Host seconds the producer spent blocked on device staging —
    /// waiting for a free arena slot (credit) or for staging-queue space;
    /// disjoint from `etl_host_s` and `ingest_wait_s`. 0 on the channel
    /// path (its queue blocking folds into `producer_stalls` only).
    pub transfer_wait_s: f64,
    /// Shards transformed by the producer.
    pub shards: u64,
    /// Simulated FPGA ETL seconds for the same bytes (the paper's clock).
    pub etl_sim_s: f64,
    /// Simulated seconds the P2P DMA engine spent moving packed bytes
    /// (arena path; 0 on the channel path).
    pub dma_sim_s: f64,
    /// Packed bytes staged toward the trainer.
    pub staged_bytes: u64,
    /// Host-side bytes logically copied between pack and training: the
    /// channel path pays one copy per packed byte (batches travel by
    /// value); the arena path pins this to 0 — the zero-copy acceptance
    /// counter.
    pub host_copy_bytes: u64,
    /// Per-shard slot-buffer allocations after each slot's first pack
    /// (arena path; must be 0 in the steady state).
    pub steady_allocs: u64,
    /// Per-device breakdowns, in device order. Each entry covers **this
    /// run only**: the time/byte/shard aggregates above are the sums
    /// across these, and the per-device `steps` sum to the steps this
    /// run executed — `self.steps` is the trainer's *absolute* counter,
    /// so on a warm (resumed) trainer it exceeds that sum by the steps
    /// taken before the run. `util` is the fleet-aggregate figure.
    pub per_device: Vec<DeviceReport>,
    /// Simulated seconds spent in gradient all-reduces (deterministic
    /// tree reduction over the calibrated P2P channel; 0 when devices=1).
    pub allreduce_sim_s: f64,
    /// All-reduce rounds (resolved reduce epochs) performed.
    pub allreduces: u64,
    /// Host seconds consumer threads spent blocked on reduce-epoch
    /// resolution, summed across devices (0 on the single-device paths).
    pub reduce_wait_s: f64,
    /// Device lanes lost mid-run and recovered by the fleet (consumer
    /// lane-loss or a lane's DMA engine hard-failing); the run only
    /// errors when no lane survives.
    pub lanes_lost: u64,
    /// DMA transfer attempts that failed and were re-issued on the same
    /// engine clock (summed across devices).
    pub retried_transfers: u64,
    /// DMA transfers abandoned after exhausting
    /// [`TransferConfig::max_retries`] (each one costs its lane).
    pub failed_transfers: u64,
    /// Scheduled global steps forfeited by lost lanes (tombstoned in the
    /// reduce bus so epochs still resolved); 0 on a fault-free run.
    pub forfeited_steps: u64,
    /// Embedding lookups served from the hot caches (summed across
    /// lanes; 0 when [`TrainConfig::embedding`] is `None`).
    pub cache_hits: u64,
    /// Embedding lookups that demand-promoted from the cold tier.
    pub cache_misses: u64,
    /// Cross-device embedding traffic: peer-owned row fetches over the
    /// P2P fabric plus embedding-row gradients routed to their owning
    /// shard.
    pub exchange_bytes: u64,
    /// Simulated consumer seconds exposed waiting on embedding
    /// promotions (0 when every prefetch completed in time).
    pub prefetch_wait_s: f64,
    /// Per-lane embedding-cache breakdowns, in device order (empty when
    /// the embedding layer is disabled).
    pub emb: Vec<crate::runtime::embedding::EmbCacheStats>,
    /// The run's full span trace when [`TrainConfig::trace`] was set
    /// (`None` otherwise): export with
    /// [`Trace::to_chrome_json`](crate::trace::Trace::to_chrome_json),
    /// or inspect the raw tracks.
    pub trace: Option<crate::trace::Trace>,
    /// Per-lane stall attribution derived from the trace: every second
    /// of wall time assigned to exactly one cause, with a ledger that
    /// closes (attributed ≡ wall within tolerance). The observation
    /// signal for the self-tuning controller (ROADMAP item 3). `None`
    /// when tracing was off.
    pub stall_attribution: Option<crate::trace::StallAttribution>,
}

impl TrainReport {
    /// First and last observed loss, for convergence checks.
    pub fn loss_delta(&self) -> Option<(f32, f32)> {
        match (self.losses.first(), self.losses.last()) {
            (Some(&(_, a)), Some(&(_, b))) if self.losses.len() >= 2 => Some((a, b)),
            _ => None,
        }
    }
}

/// Run the full loop: `pipeline` transforms shards of `spec`, the packed
/// batches train `trainer`.
pub fn run(
    pipeline: &Pipeline,
    spec: &DatasetSpec,
    trainer: &mut Trainer,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    if !pipeline.is_fitted() && pipeline.plan.dag.stateful_count() > 0 {
        return Err(EtlError::Coord("pipeline must be fitted before training".into()));
    }
    match (cfg.path, cfg.devices) {
        (_, 0) => {
            return Err(EtlError::Coord(
                "TrainConfig::devices must be >= 1 (0 is a config bug, not single-device)"
                    .into(),
            ))
        }
        (DataPath::Channel, d) if d > 1 => {
            return Err(EtlError::Coord(
                "multi-device training requires DataPath::Arena (per-device staging regions)"
                    .into(),
            ))
        }
        (DataPath::Channel, _) if cfg.embedding.is_some() => {
            return Err(EtlError::Coord(
                "the sharded embedding layer requires DataPath::Arena (its hot tier is pinned \
                 in the device arena)"
                    .into(),
            ))
        }
        _ => {}
    }
    if !cfg.trace {
        return dispatch(pipeline, spec, trainer, cfg);
    }
    // Traced run: install the recorder around the whole loop (the
    // installing thread enrolls here; every thread the loop spawns
    // inherits enrollment at its spawn point), then attach the collected
    // trace and its closed stall ledger to the report.
    let guard = trace::install();
    let result = dispatch(pipeline, spec, trainer, cfg);
    let recorded = guard.finish();
    let mut report = result?;
    report.stall_attribution = Some(recorded.stall_attribution());
    report.trace = Some(recorded);
    Ok(report)
}

/// Route a validated config to its data path.
fn dispatch(
    pipeline: &Pipeline,
    spec: &DatasetSpec,
    trainer: &mut Trainer,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    match (cfg.path, cfg.devices) {
        // The embedding layer rides the routed-fleet topology even at
        // devices = 1 (one lane, one shard) — pinned bitwise identical to
        // the plain arena path by the reproducibility matrix.
        (DataPath::Arena, d) if d > 1 || cfg.embedding.is_some() => {
            run_multi(pipeline, spec, trainer, cfg)
        }
        (DataPath::Arena, _) => run_arena(pipeline, spec, trainer, cfg),
        (DataPath::Channel, _) => run_channel(pipeline, spec, trainer, cfg),
    }
}

/// Zero-copy path: ingest → fused pack into arena slots → simulated P2P
/// DMA → in-place training → credit return.
fn run_arena(
    pipeline: &Pipeline,
    spec: &DatasetSpec,
    trainer: &mut Trainer,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let step_rows = trainer.meta.batch;
    let steps_at_start = trainer.steps;
    let (queue, consumer) = StagingQueue::<StagingSlot>::with_buffers(cfg.staging_buffers);
    let stall_counter = queue.stall_counter();
    let arena = DeviceArena::new(cfg.arena.clone());

    let t0 = std::time::Instant::now();
    let mut etl_host_s = 0.0f64;
    let mut etl_sim_s = 0.0f64;
    let mut ingest_wait_s = 0.0f64;
    let mut transfer_wait_s = 0.0f64;
    let mut dma_sim_s = 0.0f64;
    let mut staged_bytes = 0u64;
    let mut shards_done = 0u64;
    let mut producer_stalls = 0u64;
    let mut losses = Vec::new();
    let mut train_busy_s = 0.0f64;
    let mut util_trace = TimeSeries::default();
    let mut dma_retried = 0u64;
    let mut dma_failed = 0u64;
    let fault_token = fault::enroll_token();
    let trace_token = trace::enroll_token();

    std::thread::scope(|scope| -> Result<()> {
        // Producer: the FPGA data plane. Each shard is packed once,
        // directly into an acquired arena slot, then the DMA engine
        // schedules its chunked P2P transfer and the slot rides the queue
        // to the consumer. The queue is moved in so dropping it at the end
        // closes the channel and wakes the consumer.
        let arena = &arena;
        let ingest_cfg = cfg.ingest.clone();
        let ingest_spec = spec.clone();
        let transfer_cfg = cfg.transfer.clone();
        let producer = scope.spawn(move || -> Result<(f64, f64, f64, f64, f64, u64, u64, u64, u64)> {
            fault::enroll(fault_token);
            trace::enroll(trace_token);
            trace::set_thread_label("producer");
            let queue = queue;
            let mut ingest = AsyncIngest::spawn(
                ShardInput::Synth { spec: ingest_spec, seed: cfg.seed },
                &ingest_cfg,
            );
            let mut dma = TransferEngine::new(transfer_cfg);
            let mut host_s = 0.0;
            let mut sim_s = 0.0;
            let mut wait_s = 0.0;
            let mut shards = 0u64;
            while let Some((_, shard)) = ingest.next()? {
                // Credit wait: a free slot is the DMA engine's permission
                // to start (§3 backpressure).
                let t_acq = std::time::Instant::now();
                let acq_span = trace::begin(tkind::SLOT_ACQUIRE, 0, shards);
                let Some(mut slot) = arena.acquire() else {
                    // Consumer closed the arena (reached max_steps).
                    break;
                };
                acq_span.end();
                wait_s += t_acq.elapsed().as_secs_f64();

                let pack_span = trace::begin(tkind::PACK, 0, shards);
                let timing = pipeline.process_into_slot(&shard, &mut slot)?;
                pack_span.end_io(sim_s, sim_s + timing.elapsed_s, slot.packed_bytes(), 0);
                ingest.recycle(shard);
                host_s += timing.host_s;
                sim_s += timing.elapsed_s;
                shards += 1;

                // Schedule the slot's chunked P2P write at the current
                // simulated ETL clock; it overlaps the next shard's exec.
                // A hard DMA failure (past the retry budget) with no
                // sibling lane to absorb the work fails the run.
                dma.submit(sim_s, slot.packed_bytes())?;

                let t_push = std::time::Instant::now();
                let pushed = queue.push(slot);
                wait_s += t_push.elapsed().as_secs_f64();
                if !pushed {
                    // Consumer hung up (reached max_steps).
                    break;
                }
            }
            Ok((
                host_s,
                sim_s,
                ingest.wait_seconds(),
                wait_s,
                dma.busy_s(),
                dma.total_bytes(),
                shards,
                dma.retried_transfers(),
                dma.failed_transfers(),
            ))
        });

        // Consumer: the trainer steps in place on device-addressed views
        // of each staged slot, then returns the slot's credit. Errors are
        // collected (not early-returned) so shutdown below always runs —
        // a producer blocked on a credit is only woken by `arena.close()`.
        let mut consume = || -> Result<()> {
            trace::set_thread_label("consumer-0");
            let mut window_busy = 0.0f64;
            let mut window_start = 0.0f64;
            const WINDOW_STEPS: u64 = 20;
            'consume: while trainer.steps < cfg.max_steps as u64 {
                let Some(slot) = consumer.pop() else { break };
                for view in slot.chunk_views(step_rows) {
                    if trainer.steps >= cfg.max_steps as u64 {
                        break;
                    }
                    let ts = std::time::Instant::now();
                    let step_span = trace::begin(tkind::TRAIN_STEP, 0, trainer.steps);
                    trainer.step_device(&view)?;
                    step_span.end();
                    let dt = ts.elapsed().as_secs_f64();
                    train_busy_s += dt;
                    window_busy += dt;
                    if trainer.steps % (cfg.loss_every as u64).max(1) == 0 {
                        losses.push((trainer.steps, trainer.loss()?));
                    }
                    if trainer.steps % WINDOW_STEPS == 0 {
                        let now = t0.elapsed().as_secs_f64();
                        let span = (now - window_start).max(1e-9);
                        util_trace.push(now, (window_busy / span).min(1.0));
                        window_busy = 0.0;
                        window_start = now;
                    }
                }
                // Credit return: the slot is reclaimable (epoch bump).
                arena.release(slot)?;
                if trainer.steps >= cfg.max_steps as u64 {
                    break 'consume;
                }
            }
            Ok(())
        };
        let consumed = consume();
        // Shutdown: close the arena first so a producer blocked on a
        // credit wakes, then drop the consumer so a blocked push fails.
        arena.close();
        drop(consumer);
        let joined = producer.join();
        consumed?;
        match joined {
            Ok(Ok((h, s, iw, tw, db, bytes, n, rt, fl))) => {
                etl_host_s = h;
                etl_sim_s = s;
                ingest_wait_s = iw;
                transfer_wait_s = tw;
                dma_sim_s = db;
                staged_bytes = bytes;
                shards_done = n;
                dma_retried = rt;
                dma_failed = fl;
            }
            Ok(Err(e)) => return Err(e),
            Err(_) => return Err(EtlError::Coord("producer panicked".into())),
        }
        producer_stalls = stall_counter.load(std::sync::atomic::Ordering::Relaxed)
            + arena.stats().stalls;
        Ok(())
    })?;

    let arena_stats = arena.stats();
    let wall_s = t0.elapsed().as_secs_f64();
    Ok(TrainReport {
        steps: trainer.steps,
        losses,
        wall_s,
        train_busy_s,
        util: train_busy_s / wall_s.max(1e-9),
        util_trace,
        producer_stalls,
        etl_host_s,
        ingest_wait_s,
        transfer_wait_s,
        shards: shards_done,
        etl_sim_s,
        dma_sim_s,
        staged_bytes,
        host_copy_bytes: 0,
        steady_allocs: arena_stats.steady_allocs,
        per_device: vec![DeviceReport {
            device: 0,
            shards: shards_done,
            steps: trainer.steps - steps_at_start,
            transfer_wait_s,
            dma_sim_s,
            staged_bytes,
            train_busy_s,
            reduce_wait_s: 0.0,
        }],
        allreduce_sim_s: 0.0,
        allreduces: 0,
        reduce_wait_s: 0.0,
        lanes_lost: 0,
        retried_transfers: dma_retried,
        failed_transfers: dma_failed,
        forfeited_steps: 0,
        cache_hits: 0,
        cache_misses: 0,
        exchange_bytes: 0,
        prefetch_wait_s: 0.0,
        emb: Vec::new(),
        trace: None,
        stall_attribution: None,
    })
}

/// A staged slot annotated with its schedule position: the raw shard
/// bytes charged to its lane's load ledger and the **run-relative global
/// step index of its first trainer chunk** (the router stamps every slot
/// in delivery order, so reduce epochs are schedule-independent — no
/// consumer-side reordering stash is needed; each lane's queue is already
/// FIFO in delivery order).
struct RoutedSlot {
    start_rel: u64,
    /// Trainer chunks the router predicted for this slot (from the raw
    /// shard's rows). The consumer verifies the packed batch yields
    /// exactly this many — a mismatch would corrupt the global step
    /// numbering and deadlock the bus, so it aborts loudly instead.
    chunks: u64,
    raw_bytes: u64,
    slot: StagingSlot,
}

/// Per-lane producer accounting returned by each pack worker.
#[derive(Default)]
struct LaneOut {
    host_s: f64,
    sim_s: f64,
    wait_s: f64,
    shards: u64,
    dma_busy_s: f64,
    dma_bytes: u64,
    dma_retried: u64,
    dma_failed: u64,
    /// This lane's embedding-cache observables (None when the embedding
    /// layer is disabled).
    emb: Option<crate::runtime::embedding::EmbCacheStats>,
}

/// One executed step's record kept by a consumer thread: merged across
/// devices (in global-step order) into the fleet's losses, utilization
/// trace and busy-time attribution.
struct StepRec {
    /// Absolute global step index (delivery order, warm-start offset).
    g_abs: u64,
    /// Wall-clock seconds since run start when the step finished.
    end_s: f64,
    /// Host seconds the step took.
    busy_s: f64,
    /// The step's batch loss (the loss-slot observable).
    loss: f32,
}

/// Per-device consumer accounting returned by each consumer thread.
#[derive(Default)]
struct ConsumerOut {
    recs: Vec<StepRec>,
    reduce_wait_s: f64,
    /// This lane was lost mid-run (its replica's state is stale — the
    /// fleet's final parameters come from a surviving lane).
    lost: bool,
}

/// Aborts the reduce bus if the owning thread unwinds by panic, so
/// sibling consumers blocked on an epoch observe the failure instead of
/// waiting forever.
struct BusAbortOnPanic<'a>(&'a ReduceBus);

impl Drop for BusAbortOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.abort();
        }
    }
}

/// Outcome of folding one reduce epoch into a replica.
enum Fold {
    /// An epoch was applied; the replica's synced base advanced.
    Applied,
    /// No further epochs will arrive (stream finished or run aborted).
    Done,
}

/// Wait for `device`'s next reduce epoch and replay it onto the synced
/// `base` (device-ascending contributions; see `Trainer::apply_reduced`).
/// Fast path: when this device was the epoch's **sole** contributor, its
/// replica already holds exactly `base` + its own steps — bitwise what
/// the replay would rebuild (pinned by the grad/apply differential
/// tests) — so only the base refresh is needed; the sync-every-step
/// default takes this path on every contributing device. Time blocked on
/// resolution is charged to `reduce_wait_s`. Shared by the consumer's
/// mid-step dependency fold and its end-of-lane drain.
fn fold_next_epoch(
    bus: &ReduceBus,
    device: usize,
    replica: &mut Trainer,
    base: &mut [f32],
    applied: &mut u64,
    reduce_wait_s: &mut f64,
) -> Result<Fold> {
    let t_wait = std::time::Instant::now();
    // Covers both the wait for resolution and the replay itself.
    let span = trace::begin(tkind::REDUCE_APPLY, device as u32, *applied);
    match bus.wait_epoch(*applied) {
        EpochWait::Resolved(ep) => {
            *reduce_wait_s += t_wait.elapsed().as_secs_f64();
            let self_only = ep.contribs.len() == 1 && ep.contribs[0].device == device;
            if !self_only {
                replica.apply_reduced(base, ep.contribs.iter().map(|c| c.steps.as_slice()))?;
            }
            base.copy_from_slice(replica.state());
            *applied += 1;
            span.end();
            Ok(Fold::Applied)
        }
        EpochWait::Finished | EpochWait::Aborted => {
            drop(span); // records the terminal wait too
            Ok(Fold::Done)
        }
    }
}

/// Multi-device arena path: one staging region, DMA clock, pack worker
/// **and consumer thread** per simulated GPU; the router assigns each
/// ingested shard a lane and stamps its global step range; replicas step
/// concurrently and stay consistent through the barrier-free
/// gradient-level [`ReduceBus`] (see module docs).
fn run_multi(
    pipeline: &Pipeline,
    spec: &DatasetSpec,
    trainer: &mut Trainer,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let devices = cfg.devices;
    let step_rows = trainer.meta.batch;
    let steps_at_start = trainer.steps;
    let max_steps = cfg.max_steps as u64;
    let loss_every = (cfg.loss_every as u64).max(1);

    let arenas = ArenaSet::new(devices, cfg.arena.clone());
    let router = DeviceRouter::new(devices, cfg.route);
    let tracker = router.tracker();
    let bus = ReduceBus::new(devices, cfg.allreduce_every, steps_at_start);

    // Sharded embedding layer: one shard cache per lane, its hot tier
    // pinned in that lane's arena (the reservation errors if the hot set
    // cannot fit the device's memory budget — shrink `cache_rows`), its
    // prefetcher driven by the lane's own delivery order. Built before
    // the fleet spawns so a sizing error fails the run cleanly.
    let prefetchers: Vec<Option<crate::coordinator::scheduler::PrefetchPipeline>> =
        match &cfg.embedding {
            Some(ecfg) => {
                use crate::runtime::embedding::{EmbShardCache, EmbeddingTable};
                let table = EmbeddingTable::from_meta(&trainer.meta, devices, ecfg.policy)?;
                let cache_rows = ecfg.cache_rows.min(table.rows()).max(1);
                (0..devices)
                    .map(|d| {
                        let region = arenas
                            .device(d)
                            .reserve_cache(cache_rows as u64 * table.row_bytes())?;
                        let mut cache = EmbShardCache::new(table.clone(), cache_rows, region)?;
                        cache.seed(&ecfg.hot_seed, &|_| true);
                        Ok(Some(crate::coordinator::scheduler::PrefetchPipeline::new(
                            cache,
                            ecfg.lookahead,
                        )))
                    })
                    .collect::<Result<Vec<_>>>()?
            }
            None => (0..devices).map(|_| None).collect(),
        };

    // Per-device raw-shard lanes into the pack workers (depth 1: the
    // router hands a lane its next shard while it packs the current one).
    let mut shard_txs = Vec::with_capacity(devices);
    let mut shard_rxs = Vec::with_capacity(devices);
    for _ in 0..devices {
        let (tx, rx) = std::sync::mpsc::sync_channel::<(u64, Batch)>(1);
        shard_txs.push(tx);
        shard_rxs.push(rx);
    }
    // Consumed shard buffers flow back to the router for pool recycling.
    let (recycle_tx, recycle_rx) = std::sync::mpsc::channel::<Batch>();

    // Per-device staged-slot queues: each lane's worker feeds its own
    // consumer thread in FIFO (= delivery) order, so no reorder stash is
    // needed and a slow device backpressures only its own lane.
    let mut slot_queues = Vec::with_capacity(devices);
    let mut slot_rxs = Vec::with_capacity(devices);
    let mut stall_counters = Vec::with_capacity(devices);
    for _ in 0..devices {
        let (q, c) = StagingQueue::<RoutedSlot>::with_buffers(cfg.staging_buffers);
        stall_counters.push(q.stall_counter());
        slot_queues.push(q);
        slot_rxs.push(c);
    }

    // One replica per device, forked from the caller's current params.
    let replicas: Vec<Trainer> = (0..devices).map(|_| trainer.replica()).collect();

    // All-reduce cost model: a deterministic tree needs ceil(log2 N)
    // rounds of reduce plus as many of broadcast, each moving the flat
    // state over the calibrated P2P channel, charged once per epoch.
    let allreduce_chan = ChannelModel::of(Path::P2pToGpu);
    let reduce_rounds = (usize::BITS - (devices - 1).leading_zeros()) as f64;
    let state_bytes = (trainer.meta.state_len() * std::mem::size_of::<f32>()) as u64;
    let allreduce_cost_s = 2.0 * reduce_rounds * allreduce_chan.time(state_bytes);

    let t0 = std::time::Instant::now();
    let mut lanes: Vec<LaneOut> = Vec::with_capacity(devices);
    let mut cons: Vec<(Trainer, ConsumerOut)> = Vec::with_capacity(devices);
    let mut ingest_wait_s = 0.0f64;

    // Lane liveness, shared across the router, pack workers and
    // consumers: a dying side flips its lane's flag (the swap makes the
    // loss counted exactly once even if both ends of a lane fail) and
    // the router re-routes every not-yet-assigned shard to survivors.
    let lane_alive: Vec<AtomicBool> = (0..devices).map(|_| AtomicBool::new(true)).collect();
    let lanes_lost = AtomicU64::new(0);
    // Run-relative step cap: forfeited ranges are clamped to it, exactly
    // as consumers skip chunks past it, so the bus's closed total is the
    // same set of steps whether a lane lived or died.
    let cap_rel = max_steps.saturating_sub(steps_at_start);
    let fault_token = fault::enroll_token();
    let trace_token = trace::enroll_token();

    std::thread::scope(|scope| -> Result<()> {
        let arenas = &arenas;
        let bus = &bus;
        let lane_alive = &lane_alive;
        let lanes_lost = &lanes_lost;
        let mut first_err: Option<EtlError> = None;

        // Pack workers: one per device lane, each owning its device's DMA
        // engine clock and blocking only on its own arena's credits.
        let dma_engines = TransferSet::new(devices, cfg.transfer.clone()).into_engines();
        let mut workers = Vec::with_capacity(devices);
        for (d, (((rx, queue), mut dma), mut prefetch)) in shard_rxs
            .into_iter()
            .zip(slot_queues)
            .zip(dma_engines)
            .zip(prefetchers)
            .enumerate()
        {
            let recycle_tx = recycle_tx.clone();
            let worker_tracker = Arc::clone(&tracker);
            workers.push(scope.spawn(move || -> Result<LaneOut> {
                fault::enroll(fault_token);
                trace::enroll(trace_token);
                trace::set_thread_label(&format!("pack-{d}"));
                let _abort_on_panic = BusAbortOnPanic(bus);
                let arena = arenas.device(d);
                let mut out = LaneOut::default();
                let mut failure: Option<EtlError> = None;
                let mut dead = false;
                let mut last_stage_s = 0.0f64;
                while let Ok((start_rel, shard)) = rx.recv() {
                    let raw_bytes = shard.total_bytes() as u64;
                    // Same formula the router stamped the schedule with;
                    // the consumer verifies the packed batch agrees.
                    let chunks = (shard.rows() / step_rows) as u64;
                    if dead {
                        // Lane lost: these shards can no longer reach a
                        // trainer. Forfeit their scheduled steps so reduce
                        // epochs still resolve, settle the load ledger,
                        // recycle the buffer, and keep draining until the
                        // router (which re-routes to survivors) stops.
                        let lo = start_rel.min(cap_rel);
                        let hi = (start_rel + chunks).min(cap_rel);
                        if lo < hi {
                            bus.forfeit(lo..hi);
                        }
                        worker_tracker.complete(d, raw_bytes);
                        let _ = recycle_tx.send(shard);
                        continue;
                    }
                    let t_acq = std::time::Instant::now();
                    let acq_span = trace::begin(tkind::SLOT_ACQUIRE, d as u32, out.shards);
                    let Some(mut slot) = arena.acquire() else {
                        break; // fleet shut down (arena closed)
                    };
                    acq_span.end();
                    out.wait_s += t_acq.elapsed().as_secs_f64();
                    let pack_span = trace::begin(tkind::PACK, d as u32, out.shards);
                    let timing = match pipeline.process_into_slot(&shard, &mut slot) {
                        Ok(t) => t,
                        Err(e) => {
                            failure = Some(e);
                            let _ = arena.release(slot);
                            break;
                        }
                    };
                    pack_span.end_io(
                        out.sim_s,
                        out.sim_s + timing.elapsed_s,
                        slot.packed_bytes(),
                        0,
                    );
                    let _ = recycle_tx.send(shard);
                    out.host_s += timing.host_s;
                    out.sim_s += timing.elapsed_s;
                    out.shards += 1;
                    // This lane's chunked P2P write, on this device's own
                    // engine clock. A hard failure (past the retry budget)
                    // costs the lane, not the fleet: forfeit this slot's
                    // steps, return its credit, and fall into drain mode.
                    match dma.submit(out.sim_s, slot.packed_bytes()) {
                        Ok(rec) => {
                            // Prefetch planning: the router saw this shard
                            // before its consumer will, so the lane can
                            // promote the slot's embedding rows `lookahead`
                            // slots ahead of its commit. Only the chunks
                            // the consumer will actually step are traced;
                            // a lane whose consumer died forfeits its
                            // slots, so planning stops with it.
                            if let Some(pf) = prefetch.as_mut() {
                                let stepped = chunks.min(cap_rel.saturating_sub(start_rel));
                                if stepped > 0 && lane_alive[d].load(Ordering::SeqCst) {
                                    pf.on_packed(
                                        &slot.batch().sparse,
                                        stepped as usize * step_rows,
                                        rec.done_s,
                                        &|o: usize| lane_alive[o].load(Ordering::SeqCst),
                                    );
                                }
                                last_stage_s = rec.done_s;
                            }
                        }
                        Err(e) if e.is_fault() => {
                            if lane_alive[d].swap(false, Ordering::SeqCst) {
                                lanes_lost.fetch_add(1, Ordering::SeqCst);
                            }
                            let lo = start_rel.min(cap_rel);
                            let hi = (start_rel + chunks).min(cap_rel);
                            if lo < hi {
                                bus.forfeit(lo..hi);
                            }
                            worker_tracker.complete(d, raw_bytes);
                            let _ = arena.release(slot);
                            dead = true;
                            continue;
                        }
                        Err(e) => {
                            failure = Some(e);
                            let _ = arena.release(slot);
                            break;
                        }
                    }
                    let t_push = std::time::Instant::now();
                    let pushed = queue.push(RoutedSlot { start_rel, chunks, raw_bytes, slot });
                    out.wait_s += t_push.elapsed().as_secs_f64();
                    if !pushed {
                        break; // consumer hung up
                    }
                }
                out.dma_busy_s = dma.busy_s();
                out.dma_bytes = dma.total_bytes();
                out.dma_retried = dma.retried_transfers();
                out.dma_failed = dma.failed_transfers();
                if let Some(mut pf) = prefetch.take() {
                    // Drain the lookahead window: every slot that was
                    // prefetch-planned commits exactly once, so the
                    // hit/miss ledger covers every lookup the consumer
                    // performed (exactly-once accounting).
                    pf.flush(last_stage_s, &|o: usize| lane_alive[o].load(Ordering::SeqCst));
                    out.emb = Some(pf.into_stats());
                }
                match failure {
                    Some(e) => {
                        // Unblock peers waiting on this lane's steps.
                        bus.abort();
                        Err(e)
                    }
                    None => Ok(out),
                }
            }));
        }
        // Workers now hold the only recycle producer handles.
        drop(recycle_tx);

        // Router: the producer front-end — ingest in delivery order,
        // assign each shard a device lane, stamp it with the global step
        // index of its first chunk (epochs are defined over this
        // delivery-order numbering, independent of thread schedules),
        // recycle consumed buffers, and close the bus with the stream's
        // total step count on the way out.
        let ingest_cfg = cfg.ingest.clone();
        let ingest_spec = spec.clone();
        let seed = cfg.seed;
        let router_thread = scope.spawn(move || -> Result<f64> {
            fault::enroll(fault_token);
            trace::enroll(trace_token);
            trace::set_thread_label("router");
            let _abort_on_panic = BusAbortOnPanic(bus);
            let shard_txs = shard_txs;
            let mut router = router;
            let mut ingest =
                AsyncIngest::spawn(ShardInput::Synth { spec: ingest_spec, seed }, &ingest_cfg);
            let mut cum = 0u64; // run-relative global steps scheduled so far
            let mut last_dead = 0usize;
            let routed = (|| -> Result<()> {
                while let Some((_, shard)) = ingest.next()? {
                    while let Ok(b) = recycle_rx.try_recv() {
                        ingest.recycle(b);
                    }
                    if steps_at_start + cum >= max_steps || bus.is_aborted() {
                        // Nothing past the cap (or past an abort) will
                        // ever be stepped; stop routing instead of
                        // packing dead shards.
                        ingest.recycle(shard);
                        break;
                    }
                    // Sync lane losses into the routing mask: the dead
                    // lane's remaining shards land on survivors instead.
                    for dd in 0..shard_txs.len() {
                        if router.is_alive(dd) && !lane_alive[dd].load(Ordering::SeqCst) {
                            router.mark_dead(dd);
                            last_dead = dd;
                        }
                    }
                    if router.alive_count() == 0 {
                        // No lane left to absorb the stream: this is the
                        // unrecoverable failure domain.
                        ingest.recycle(shard);
                        return Err(EtlError::LaneLost { device: last_dead, survivors: 0 });
                    }
                    let chunks = (shard.rows() / step_rows) as u64;
                    let d = router.route(shard.total_bytes() as u64);
                    if shard_txs[d].send((cum, shard)).is_err() {
                        break; // lane worker exited (fleet shut down)
                    }
                    cum += chunks;
                }
                Ok(())
            })();
            match routed {
                Ok(()) => {
                    // The last routed slot may cross the cap; consumers
                    // skip its excess chunks, so the stream total is the
                    // capped count.
                    bus.close(cum.min(max_steps.saturating_sub(steps_at_start)));
                    Ok(ingest.wait_seconds())
                }
                Err(e) => {
                    bus.abort();
                    Err(e)
                }
            }
        });

        // Consumer threads: one per device. Each steps its own replica in
        // place on its lane's staged slots (local SGD), posts one
        // gradient contribution per step, and applies resolved reduce
        // epochs onto its synced base before stepping into the next
        // window — the only cross-device synchronization is the bus.
        let mut consumers = Vec::with_capacity(devices);
        for (d, (rx, mut replica)) in slot_rxs.into_iter().zip(replicas).enumerate() {
            let tracker = Arc::clone(&tracker);
            consumers.push(scope.spawn(move || -> Result<(Trainer, ConsumerOut)> {
                fault::enroll(fault_token);
                trace::enroll(trace_token);
                trace::set_thread_label(&format!("consumer-{d}"));
                let _abort_on_panic = BusAbortOnPanic(bus);
                let mut out = ConsumerOut::default();
                let mut base = replica.state_to_vec()?;
                let mut applied = 0u64; // reduce epochs folded so far
                let mut stepping = true;
                let mut failure: Option<EtlError> = None;
                while let Some(RoutedSlot { start_rel, chunks, raw_bytes, slot }) = rx.pop() {
                    sched::point(site::LANE_HANDOFF);
                    if !out.lost && failure.is_none() && fault::inject(fsite::LANE_LOSS, d as u64)
                    {
                        // Injected lane loss: this device is gone. Leave
                        // the reduce group so peers stop waiting on this
                        // replica's fetches, mark the lane dead for the
                        // router, and fall into drain mode — every
                        // remaining slot's steps are forfeited below so
                        // reduce epochs still resolve for survivors.
                        out.lost = true;
                        if lane_alive[d].swap(false, Ordering::SeqCst) {
                            lanes_lost.fetch_add(1, Ordering::SeqCst);
                        }
                        bus.leave(applied);
                    }
                    if out.lost {
                        if failure.is_none() {
                            let lo = start_rel.min(cap_rel);
                            let hi = (start_rel + chunks).min(cap_rel);
                            if lo < hi {
                                bus.forfeit(lo..hi);
                            }
                        }
                    } else if stepping && failure.is_none() {
                        let views = slot.chunk_views(step_rows);
                        if views.len() as u64 != chunks {
                            // A row-dropping pipeline would corrupt the
                            // schedule's step numbering and deadlock the
                            // bus — fail loudly instead.
                            bus.abort();
                            failure = Some(EtlError::Coord(format!(
                                "packed slot yields {} chunks but the router scheduled {} \
                                 (pipeline did not preserve rows)",
                                views.len(),
                                chunks
                            )));
                        }
                        for (c, view) in views.iter().enumerate() {
                            if failure.is_some() {
                                break;
                            }
                            let rel = start_rel + c as u64;
                            let g_abs = steps_at_start + rel;
                            if g_abs >= max_steps {
                                break;
                            }
                            // Fold every epoch this step depends on.
                            let need = bus.epochs_before(g_abs);
                            while applied < need && failure.is_none() {
                                match fold_next_epoch(
                                    bus,
                                    d,
                                    &mut replica,
                                    &mut base,
                                    &mut applied,
                                    &mut out.reduce_wait_s,
                                ) {
                                    Ok(Fold::Applied) => {}
                                    Ok(Fold::Done) => {
                                        stepping = false;
                                        break;
                                    }
                                    Err(e) => {
                                        bus.abort();
                                        failure = Some(e);
                                    }
                                }
                            }
                            if !stepping || failure.is_some() {
                                break;
                            }
                            let ts = std::time::Instant::now();
                            let step_span = trace::begin(tkind::TRAIN_STEP, d as u32, g_abs);
                            match replica.grad_step(view) {
                                Ok(grad) => {
                                    step_span.end();
                                    out.recs.push(StepRec {
                                        g_abs,
                                        end_s: t0.elapsed().as_secs_f64(),
                                        busy_s: ts.elapsed().as_secs_f64(),
                                        loss: grad.loss as f32,
                                    });
                                    let post_span =
                                        trace::begin(tkind::REDUCE_POST, d as u32, rel);
                                    let posted = bus.post(rel, d, grad);
                                    post_span.end();
                                    if let Err(e) = posted {
                                        // Pending-window cap blown (the
                                        // allreduce_every=0 footgun):
                                        // abort rather than buffer
                                        // gradients without bound.
                                        bus.abort();
                                        failure = Some(e);
                                    }
                                }
                                Err(e) => {
                                    bus.abort();
                                    failure = Some(e);
                                }
                            }
                        }
                    }
                    // Credit + ledger return happen on the consumer
                    // thread even when the slot's chunks were skipped
                    // (max_steps cut or failure drain) — exactly once.
                    tracker.complete(d, raw_bytes);
                    if let Err(e) = arenas.device(d).release(slot) {
                        if failure.is_none() {
                            bus.abort();
                            failure = Some(e);
                        }
                    }
                }
                // Lane closed: fold the remaining epochs so this replica
                // lands on the final reduced state even though peers may
                // still be stepping. A lost lane already left the reduce
                // group — fetching again would double-count its serves —
                // so it skips the drain and exits with stale state.
                while !out.lost && failure.is_none() {
                    match fold_next_epoch(
                        bus,
                        d,
                        &mut replica,
                        &mut base,
                        &mut applied,
                        &mut out.reduce_wait_s,
                    ) {
                        Ok(Fold::Applied) => {}
                        Ok(Fold::Done) => break,
                        Err(e) => {
                            bus.abort();
                            failure = Some(e);
                        }
                    }
                }
                match failure {
                    Some(e) => Err(e),
                    None => Ok((replica, out)),
                }
            }));
        }

        // Join consumers first: they exit once the router closed the bus
        // and their lanes drained. Only then close the arenas (waking any
        // worker still blocked on a credit after an abnormal consumer
        // exit) and collect the producer side.
        for handle in consumers {
            match handle.join() {
                Ok(Ok(pair)) => cons.push(pair),
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => {
                    first_err =
                        first_err.or_else(|| Some(EtlError::Coord("consumer panicked".into())))
                }
            }
        }
        arenas.close_all();
        for handle in workers {
            match handle.join() {
                Ok(Ok(out)) => lanes.push(out),
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => {
                    first_err =
                        first_err.or_else(|| Some(EtlError::Coord("pack worker panicked".into())))
                }
            }
        }
        match router_thread.join() {
            Ok(Ok(w)) => ingest_wait_s = w,
            Ok(Err(e)) => first_err = first_err.or(Some(e)),
            Err(_) => {
                first_err = first_err.or_else(|| Some(EtlError::Coord("router panicked".into())))
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    })?;

    // Every surviving replica drained the bus to the last resolved
    // epoch, so the survivors are bitwise identical; the fleet
    // parameters land back in the caller's trainer from the first one.
    // Lost lanes' replicas are stale (they left the reduce group) and
    // never source the final state; a fleet with no survivor at all is
    // the unrecoverable outcome.
    let total_steps: u64 = cons.iter().map(|(_, o)| o.recs.len() as u64).sum();
    if lanes_lost.load(Ordering::SeqCst) >= devices as u64 {
        let device = (0..devices)
            .rev()
            .find(|&dd| !lane_alive[dd].load(Ordering::SeqCst))
            .unwrap_or(0);
        return Err(EtlError::LaneLost { device, survivors: 0 });
    }
    let survivor = cons
        .iter()
        .position(|(_, o)| !o.lost)
        .expect("a lane neither worker- nor consumer-lost has a live replica");
    trainer.load_state(cons[survivor].0.state())?;
    trainer.steps = steps_at_start + total_steps;
    let allreduces = bus.resolved_count();
    let allreduce_sim_s = allreduces as f64 * allreduce_cost_s;

    // Merge the per-consumer step records into the fleet's observables,
    // in global-step (delivery) order.
    let mut dev_busy = vec![0.0f64; devices];
    let mut merged: Vec<(u64, f64, f64, f32)> = Vec::with_capacity(total_steps as usize);
    for (d, (_, out)) in cons.iter().enumerate() {
        for r in &out.recs {
            dev_busy[d] += r.busy_s;
            merged.push((r.g_abs, r.end_s, r.busy_s, r.loss));
        }
    }
    merged.sort_unstable_by_key(|r| r.0);
    let mut losses = Vec::new();
    for &(g, _, _, loss) in &merged {
        if (g + 1) % loss_every == 0 {
            losses.push((g + 1, loss));
        }
    }
    // The trace wants execution (wall-clock completion) order — with
    // concurrent consumers that is not global-step order.
    let mut step_records: Vec<(f64, f64)> = merged.iter().map(|r| (r.1, r.2)).collect();
    step_records.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
    let util_trace = TimeSeries::from_step_records(&step_records, 20);
    let train_busy_s: f64 = dev_busy.iter().sum();
    let reduce_wait_s: f64 = cons.iter().map(|(_, o)| o.reduce_wait_s).sum();
    let producer_stalls = stall_counters
        .iter()
        .map(|c| c.load(std::sync::atomic::Ordering::Relaxed))
        .sum::<u64>()
        + arenas.total_stats().stalls;

    let per_device: Vec<DeviceReport> = (0..devices)
        .map(|d| DeviceReport {
            device: d,
            shards: lanes[d].shards,
            steps: cons[d].0.steps,
            transfer_wait_s: lanes[d].wait_s,
            dma_sim_s: lanes[d].dma_busy_s,
            staged_bytes: lanes[d].dma_bytes,
            train_busy_s: dev_busy[d],
            reduce_wait_s: cons[d].1.reduce_wait_s,
        })
        .collect();
    let wall_s = t0.elapsed().as_secs_f64();
    // Per-lane cache stats roll up into the fleet-level counters; the
    // per-shard vector keeps device attribution for the bench/report.
    let emb: Vec<crate::runtime::embedding::EmbCacheStats> =
        lanes.iter().filter_map(|l| l.emb).collect();
    Ok(TrainReport {
        steps: steps_at_start + total_steps,
        losses,
        wall_s,
        train_busy_s,
        util: (train_busy_s / wall_s.max(1e-9)).min(1.0),
        util_trace,
        producer_stalls,
        etl_host_s: lanes.iter().map(|l| l.host_s).sum(),
        ingest_wait_s,
        transfer_wait_s: lanes.iter().map(|l| l.wait_s).sum(),
        shards: lanes.iter().map(|l| l.shards).sum(),
        etl_sim_s: lanes.iter().map(|l| l.sim_s).sum(),
        dma_sim_s: lanes.iter().map(|l| l.dma_busy_s).sum(),
        staged_bytes: lanes.iter().map(|l| l.dma_bytes).sum(),
        host_copy_bytes: 0,
        steady_allocs: arenas.total_stats().steady_allocs,
        per_device,
        allreduce_sim_s,
        allreduces,
        reduce_wait_s,
        lanes_lost: lanes_lost.load(Ordering::SeqCst),
        retried_transfers: lanes.iter().map(|l| l.dma_retried).sum(),
        failed_transfers: lanes.iter().map(|l| l.dma_failed).sum(),
        forfeited_steps: bus.forfeited_count(),
        cache_hits: emb.iter().map(|e| e.hits).sum(),
        cache_misses: emb.iter().map(|e| e.misses).sum(),
        exchange_bytes: emb.iter().map(|e| e.exchange_bytes).sum(),
        prefetch_wait_s: emb.iter().map(|e| e.prefetch_wait_s).sum(),
        emb,
        trace: None,
        stall_attribution: None,
    })
}

/// Legacy heap path: pool-recycled `PackedBatch`es travel the staging
/// queue by value (the differential baseline for the zero-copy path).
fn run_channel(
    pipeline: &Pipeline,
    spec: &DatasetSpec,
    trainer: &mut Trainer,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let step_rows = trainer.meta.batch;
    let steps_at_start = trainer.steps;
    let (queue, consumer) = StagingQueue::with_buffers(cfg.staging_buffers);
    let stall_counter = queue.stall_counter();
    // Packed-batch buffers cycle producer → staging → trainer → pool, so
    // the steady state allocates nothing per shard — but each batch still
    // crosses the queue by value (one logical host copy per byte).
    let pool = BufferPool::new();

    let t0 = std::time::Instant::now();
    let mut etl_host_s = 0.0f64;
    let mut etl_sim_s = 0.0f64;
    let mut ingest_wait_s = 0.0f64;
    let mut staged_bytes = 0u64;
    let mut shards_done = 0u64;
    let mut producer_stalls = 0u64;
    let mut losses = Vec::new();
    let mut train_busy_s = 0.0f64;
    let mut host_copy_bytes = 0u64;
    let mut util_trace = TimeSeries::default();

    let fault_token = fault::enroll_token();
    let trace_token = trace::enroll_token();
    std::thread::scope(|scope| -> Result<()> {
        let pool = &pool;
        let ingest_cfg = cfg.ingest.clone();
        let ingest_spec = spec.clone();
        let producer = scope.spawn(move || -> Result<(f64, f64, f64, u64, u64)> {
            fault::enroll(fault_token);
            trace::enroll(trace_token);
            trace::set_thread_label("producer");
            let queue = queue;
            let mut ingest = AsyncIngest::spawn(
                ShardInput::Synth { spec: ingest_spec, seed: cfg.seed },
                &ingest_cfg,
            );
            let mut host_s = 0.0;
            let mut sim_s = 0.0;
            let mut bytes = 0u64;
            let mut shards = 0u64;
            while let Some((_, shard)) = ingest.next()? {
                let mut packed = pool.take();
                let pack_span = trace::begin(tkind::PACK, 0, shards);
                let timing = pipeline.process_packed_into(&shard, &mut packed)?;
                pack_span.end_io(sim_s, sim_s + timing.elapsed_s, packed.bytes(), 0);
                ingest.recycle(shard);
                host_s += timing.host_s;
                sim_s += timing.elapsed_s;
                bytes += packed.bytes();
                shards += 1;
                if !queue.push(packed) {
                    // Consumer hung up (reached max_steps).
                    break;
                }
            }
            Ok((host_s, sim_s, ingest.wait_seconds(), bytes, shards))
        });

        // Consumer: the trainer steps on borrowed chunk views (the
        // incomplete tail of each staged batch is dropped, matching
        // DLRM's fixed batch shapes).
        trace::set_thread_label("consumer-0");
        let mut window_busy = 0.0f64;
        let mut window_start = 0.0f64;
        const WINDOW_STEPS: u64 = 20;
        'consume: while trainer.steps < cfg.max_steps as u64 {
            let Some(batch) = consumer.pop() else { break };
            host_copy_bytes += batch.bytes();
            for view in batch.chunk_views(step_rows) {
                if trainer.steps >= cfg.max_steps as u64 {
                    break;
                }
                let ts = std::time::Instant::now();
                let step_span = trace::begin(tkind::TRAIN_STEP, 0, trainer.steps);
                trainer.step_view(&view)?;
                step_span.end();
                let dt = ts.elapsed().as_secs_f64();
                train_busy_s += dt;
                window_busy += dt;
                if trainer.steps % (cfg.loss_every as u64).max(1) == 0 {
                    losses.push((trainer.steps, trainer.loss()?));
                }
                if trainer.steps % WINDOW_STEPS == 0 {
                    let now = t0.elapsed().as_secs_f64();
                    let span = (now - window_start).max(1e-9);
                    util_trace.push(now, (window_busy / span).min(1.0));
                    window_busy = 0.0;
                    window_start = now;
                }
            }
            // Return the drained buffer for reuse.
            pool.put(batch);
            if trainer.steps >= cfg.max_steps as u64 {
                break 'consume;
            }
        }
        // Drain/close: dropping the consumer unblocks a blocked producer.
        drop(consumer);
        match producer.join() {
            Ok(Ok((h, s, w, bytes, n))) => {
                etl_host_s = h;
                etl_sim_s = s;
                ingest_wait_s = w;
                staged_bytes = bytes;
                shards_done = n;
            }
            Ok(Err(e)) => return Err(e),
            Err(_) => return Err(EtlError::Coord("producer panicked".into())),
        }
        producer_stalls = stall_counter.load(std::sync::atomic::Ordering::Relaxed);
        Ok(())
    })?;

    let wall_s = t0.elapsed().as_secs_f64();
    Ok(TrainReport {
        steps: trainer.steps,
        losses,
        wall_s,
        train_busy_s,
        util: train_busy_s / wall_s.max(1e-9),
        util_trace,
        producer_stalls,
        etl_host_s,
        ingest_wait_s,
        transfer_wait_s: 0.0,
        shards: shards_done,
        etl_sim_s,
        dma_sim_s: 0.0,
        staged_bytes,
        host_copy_bytes,
        steady_allocs: 0,
        per_device: vec![DeviceReport {
            device: 0,
            shards: shards_done,
            steps: trainer.steps - steps_at_start,
            transfer_wait_s: 0.0,
            dma_sim_s: 0.0,
            staged_bytes,
            train_busy_s,
            reduce_wait_s: 0.0,
        }],
        allreduce_sim_s: 0.0,
        allreduces: 0,
        reduce_wait_s: 0.0,
        lanes_lost: 0,
        retried_transfers: 0,
        failed_transfers: 0,
        forfeited_steps: 0,
        cache_hits: 0,
        cache_misses: 0,
        exchange_bytes: 0,
        prefetch_wait_s: 0.0,
        emb: Vec::new(),
        trace: None,
        stall_attribution: None,
    })
}

#[cfg(test)]
mod tests {
    // Live-loop tests require compiled artifacts; they run in the
    // integration suite (rust/tests/integration_runtime.rs). The
    // ingest/exec/transfer time-attribution split and the arena-vs-
    // channel bit-identity are asserted in
    // rust/tests/integration_coordinator.rs against the artifact-free
    // reference trainer.

    #[test]
    fn default_config_is_sane() {
        let cfg = super::TrainConfig::default();
        assert!(cfg.max_steps > 0 && cfg.staging_buffers >= 2);
        assert!(cfg.ingest.workers >= 1 && cfg.ingest.channel_depth >= 1);
        // The zero-copy arena path is the shipping default, with enough
        // slots for double buffering on both sides of the queue.
        assert_eq!(cfg.path, super::DataPath::Arena);
        assert!(cfg.arena.slots >= cfg.staging_buffers + 2);
        assert!(cfg.transfer.chunk_bytes >= 1 << 20, "MiB-scale DMA chunks");
        // Multi-device defaults: single GPU, bit-reproducible routing,
        // sync-every-step all-reduce.
        assert_eq!(cfg.devices, 1);
        assert_eq!(cfg.route, crate::coordinator::scheduler::RoutePolicy::RoundRobin);
        assert_eq!(cfg.allreduce_every, 1);
    }

    #[test]
    fn multi_device_rejects_channel_path() {
        use crate::dataio::dataset::DatasetSpec;
        use crate::etl::pipelines::{build, PipelineKind};
        use crate::planner::{compile, PlannerConfig};
        use crate::runtime::artifacts::{ModelMeta, ParamSpec};

        let spec = DatasetSpec::dataset_i(0.001);
        let dag = build(PipelineKind::I, &spec.schema);
        let plan = compile(&dag, &spec.schema, &PlannerConfig::default()).unwrap();
        let mut pipe = crate::fpga::Pipeline::new(plan);
        pipe.fit(&spec.shard(0, 1)).unwrap();
        let meta = ModelMeta {
            batch: 64,
            n_dense: 13,
            n_sparse: 26,
            vocab: 64,
            embed_dim: 1,
            params: vec![
                ParamSpec { name: "w_dense".into(), dims: vec![13] },
                ParamSpec { name: "b".into(), dims: vec![1] },
                ParamSpec { name: "emb".into(), dims: vec![26 * 8] },
            ],
            extra: Default::default(),
        };
        let mut trainer = crate::runtime::Trainer::from_meta(meta, 1);
        let cfg = super::TrainConfig {
            devices: 2,
            path: super::DataPath::Channel,
            ..Default::default()
        };
        let err = super::run(&pipe, &spec, &mut trainer, &cfg).unwrap_err();
        assert!(err.to_string().contains("DataPath::Arena"), "{err}");

        // devices == 0 is a config bug, not an implicit single device.
        let cfg = super::TrainConfig { devices: 0, ..Default::default() };
        let err = super::run(&pipe, &spec, &mut trainer, &cfg).unwrap_err();
        assert!(err.to_string().contains("devices must be >= 1"), "{err}");
    }
}
