//! The live training loop: ETL (simulated FPGA data plane, real
//! functional transforms) feeding the trainer through credit-gated
//! device staging — the end-to-end composition of all layers.
//!
//! The producer side plays the FPGA role (§3.5) as a fully overlapped
//! streaming dataflow: N async ingest workers generate shards into
//! pool-recycled buffers ([`crate::dataio::ingest`]), the fused engine
//! transforms+packs each shard, and the staging queue hands it to the
//! consumer — so shard I/O, fused apply+pack, P2P transfer and trainer
//! steps all overlap. The consumer is the GPU stand-in: pop, train,
//! return the credit. GPU utilization is measured as train-busy time over
//! wall time per window, exactly as Fig. 14 reports.
//!
//! Two data paths share the protocol ([`DataPath`]):
//!
//! * [`DataPath::Arena`] (default) — the **zero-copy** path of
//!   [`crate::devmem`]: the fused engine packs each shard once, directly
//!   into a [`crate::devmem::StagingSlot`] of the pinned device arena;
//!   the [`crate::devmem::TransferEngine`] accounts the chunked P2P DMA
//!   that makes the slot resident; the trainer steps **in place** on
//!   [`crate::devmem::DeviceBatchView`]s and releases the slot's credit.
//!   Zero per-shard `PackedBatch` heap allocations in the steady state,
//!   zero host-side copies between pack and training.
//! * [`DataPath::Channel`] — the legacy heap path: pool-recycled owned
//!   [`crate::coordinator::packer::PackedBatch`]es travel the staging
//!   queue by value (one logical host copy per packed byte). Kept as the
//!   differential baseline (`rust/tests/prop_devmem.rs` pins the two
//!   paths bit-identical) and for the `zero-copy` hotpath bench section.
//!
//! Ingest-wait, fused-exec and transfer-wait time are attributed
//! separately in the report so stage imbalance is visible (ROADMAP:
//! pipeline-stage attribution).
//!
//! # Multi-device (N simulated GPUs)
//!
//! With [`TrainConfig::devices`] > 1 the arena path becomes a routed
//! fleet: a [`crate::devmem::ArenaSet`] holds one staging region per
//! device in a shared MMU address space, each device lane has its own
//! pack worker and DMA clock, and the scheduler's
//! [`crate::coordinator::scheduler::DeviceRouter`] assigns every ingested
//! shard to a lane ([`crate::coordinator::scheduler::RoutePolicy`]:
//! round-robin pins a bit-reproducible schedule, least-loaded follows the
//! outstanding-byte ledger). One [`Trainer`] replica steps per device;
//! every [`TrainConfig::allreduce_every`] global steps the replicas'
//! parameters are combined by a deterministic tree reduction (per-device
//! deltas summed in f64 in device order) and broadcast, with the
//! reduction costed against the calibrated P2P channel
//! ([`TrainReport::allreduce_sim_s`]). The default period of 1 syncs
//! after every step, so a round-robin fleet replays the single-device
//! trajectory **bitwise** (pinned by `rust/tests/prop_devmem.rs`);
//! larger periods trade that exactness for local-SGD-style divergence
//! between syncs. [`TrainReport::per_device`] breaks transfer-wait, DMA,
//! staged bytes and steps down per device.

use std::collections::BTreeMap;

use crate::coordinator::scheduler::{DeviceRouter, RoutePolicy};
use crate::coordinator::staging::StagingQueue;
use crate::dataio::dataset::DatasetSpec;
use crate::dataio::ingest::{AsyncIngest, IngestConfig, ShardInput};
use crate::devmem::{
    ArenaConfig, ArenaSet, DeviceArena, StagingSlot, TransferConfig, TransferEngine, TransferSet,
};
use crate::error::{EtlError, Result};
use crate::etl::column::Batch;
use crate::etl::exec::BufferPool;
use crate::fpga::Pipeline;
use crate::memsys::{ChannelModel, Path};
use crate::metrics::TimeSeries;
use crate::runtime::Trainer;

/// Which staging dataflow the loop runs (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataPath {
    /// Zero-copy device staging: pack into pinned arena slots, simulated
    /// P2P DMA, in-place training, credit return.
    Arena,
    /// Heap `PackedBatch`es over the staging channel (legacy baseline).
    Channel,
}

/// Configuration of a live training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Maximum training steps (stop even if data remains).
    pub max_steps: usize,
    /// Read the loss every `loss_every` steps.
    pub loss_every: usize,
    /// Staging buffers (2 = double buffering).
    pub staging_buffers: usize,
    /// Dataset seed.
    pub seed: u64,
    /// Async shard-ingest knobs (workers / channel depth / delivery
    /// policy). The default (2 workers, depth 2, in-order) reproduces the
    /// synchronous producer's batch sequence bit-for-bit while overlapping
    /// shard generation with fused execution.
    pub ingest: IngestConfig,
    /// Staging dataflow (default: the zero-copy arena path).
    pub path: DataPath,
    /// Device-arena sizing for [`DataPath::Arena`] (per device when
    /// `devices` > 1).
    pub arena: ArenaConfig,
    /// P2P DMA engine knobs for [`DataPath::Arena`] (one engine clock per
    /// device when `devices` > 1).
    pub transfer: TransferConfig,
    /// Simulated GPUs fed by the staging dataflow. 1 = the single-device
    /// arena path; > 1 routes shards across an [`ArenaSet`] (arena path
    /// only).
    pub devices: usize,
    /// Shard→device routing policy for `devices` > 1.
    pub route: RoutePolicy,
    /// All-reduce period in global steps for `devices` > 1. 1 (default)
    /// syncs replicas after every step — the bit-reproducible schedule;
    /// larger periods run local SGD between syncs; 0 syncs only at stream
    /// end.
    pub allreduce_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            max_steps: 200,
            loss_every: 10,
            staging_buffers: 2,
            seed: 42,
            ingest: IngestConfig::default(),
            path: DataPath::Arena,
            arena: ArenaConfig::default(),
            transfer: TransferConfig::default(),
            devices: 1,
            route: RoutePolicy::RoundRobin,
            allreduce_every: 1,
        }
    }
}

/// Per-device breakdown of a training run (one entry per simulated GPU;
/// the single-device paths report exactly one).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DeviceReport {
    /// Device index.
    pub device: usize,
    /// Shards routed to and packed on this device's lane.
    pub shards: u64,
    /// Training steps this device's replica executed.
    pub steps: u64,
    /// Host seconds this lane's pack worker spent blocked on device
    /// staging (credit + queue waits).
    pub transfer_wait_s: f64,
    /// Simulated seconds this device's DMA engine spent on the wire.
    pub dma_sim_s: f64,
    /// Packed bytes staged into this device's arena.
    pub staged_bytes: u64,
    /// Host seconds spent stepping this device's replica.
    pub train_busy_s: f64,
}

/// Result of a live training run.
#[derive(Debug)]
pub struct TrainReport {
    pub steps: u64,
    /// (step, loss) samples.
    pub losses: Vec<(u64, f32)>,
    /// Wall-clock seconds end to end.
    pub wall_s: f64,
    /// Seconds the trainer was executing steps.
    pub train_busy_s: f64,
    /// Measured GPU(-stand-in) utilization = busy / wall.
    pub util: f64,
    /// Utilization trace per ~20-step window.
    pub util_trace: TimeSeries,
    /// Producer-side backpressure stalls.
    pub producer_stalls: u64,
    /// Host seconds the producer spent in fused apply+pack (exec time,
    /// excluding ingest wait).
    pub etl_host_s: f64,
    /// Host seconds the producer spent blocked waiting on shard ingest
    /// (I/O-wait attribution, disjoint from `etl_host_s`).
    pub ingest_wait_s: f64,
    /// Host seconds the producer spent blocked on device staging —
    /// waiting for a free arena slot (credit) or for staging-queue space;
    /// disjoint from `etl_host_s` and `ingest_wait_s`. 0 on the channel
    /// path (its queue blocking folds into `producer_stalls` only).
    pub transfer_wait_s: f64,
    /// Shards transformed by the producer.
    pub shards: u64,
    /// Simulated FPGA ETL seconds for the same bytes (the paper's clock).
    pub etl_sim_s: f64,
    /// Simulated seconds the P2P DMA engine spent moving packed bytes
    /// (arena path; 0 on the channel path).
    pub dma_sim_s: f64,
    /// Packed bytes staged toward the trainer.
    pub staged_bytes: u64,
    /// Host-side bytes logically copied between pack and training: the
    /// channel path pays one copy per packed byte (batches travel by
    /// value); the arena path pins this to 0 — the zero-copy acceptance
    /// counter.
    pub host_copy_bytes: u64,
    /// Per-shard slot-buffer allocations after each slot's first pack
    /// (arena path; must be 0 in the steady state).
    pub steady_allocs: u64,
    /// Per-device breakdowns, in device order. Each entry covers **this
    /// run only**: the time/byte/shard aggregates above are the sums
    /// across these, and the per-device `steps` sum to the steps this
    /// run executed — `self.steps` is the trainer's *absolute* counter,
    /// so on a warm (resumed) trainer it exceeds that sum by the steps
    /// taken before the run. `util` is the fleet-aggregate figure.
    pub per_device: Vec<DeviceReport>,
    /// Simulated seconds spent in parameter all-reduces (deterministic
    /// tree reduction over the calibrated P2P channel; 0 when devices=1).
    pub allreduce_sim_s: f64,
    /// All-reduce rounds performed.
    pub allreduces: u64,
}

impl TrainReport {
    /// First and last observed loss, for convergence checks.
    pub fn loss_delta(&self) -> Option<(f32, f32)> {
        match (self.losses.first(), self.losses.last()) {
            (Some(&(_, a)), Some(&(_, b))) if self.losses.len() >= 2 => Some((a, b)),
            _ => None,
        }
    }
}

/// Run the full loop: `pipeline` transforms shards of `spec`, the packed
/// batches train `trainer`.
pub fn run(
    pipeline: &Pipeline,
    spec: &DatasetSpec,
    trainer: &mut Trainer,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    if !pipeline.is_fitted() && pipeline.plan.dag.stateful_count() > 0 {
        return Err(EtlError::Coord("pipeline must be fitted before training".into()));
    }
    match (cfg.path, cfg.devices) {
        (_, 0) => Err(EtlError::Coord(
            "TrainConfig::devices must be >= 1 (0 is a config bug, not single-device)".into(),
        )),
        (DataPath::Channel, d) if d > 1 => Err(EtlError::Coord(
            "multi-device training requires DataPath::Arena (per-device staging regions)"
                .into(),
        )),
        (DataPath::Arena, d) if d > 1 => run_multi(pipeline, spec, trainer, cfg),
        (DataPath::Arena, _) => run_arena(pipeline, spec, trainer, cfg),
        (DataPath::Channel, _) => run_channel(pipeline, spec, trainer, cfg),
    }
}

/// Zero-copy path: ingest → fused pack into arena slots → simulated P2P
/// DMA → in-place training → credit return.
fn run_arena(
    pipeline: &Pipeline,
    spec: &DatasetSpec,
    trainer: &mut Trainer,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let step_rows = trainer.meta.batch;
    let steps_at_start = trainer.steps;
    let (queue, consumer) = StagingQueue::<StagingSlot>::with_buffers(cfg.staging_buffers);
    let stall_counter = queue.stall_counter();
    let arena = DeviceArena::new(cfg.arena.clone());

    let t0 = std::time::Instant::now();
    let mut etl_host_s = 0.0f64;
    let mut etl_sim_s = 0.0f64;
    let mut ingest_wait_s = 0.0f64;
    let mut transfer_wait_s = 0.0f64;
    let mut dma_sim_s = 0.0f64;
    let mut staged_bytes = 0u64;
    let mut shards_done = 0u64;
    let mut producer_stalls = 0u64;
    let mut losses = Vec::new();
    let mut train_busy_s = 0.0f64;
    let mut util_trace = TimeSeries::default();

    std::thread::scope(|scope| -> Result<()> {
        // Producer: the FPGA data plane. Each shard is packed once,
        // directly into an acquired arena slot, then the DMA engine
        // schedules its chunked P2P transfer and the slot rides the queue
        // to the consumer. The queue is moved in so dropping it at the end
        // closes the channel and wakes the consumer.
        let arena = &arena;
        let ingest_cfg = cfg.ingest.clone();
        let ingest_spec = spec.clone();
        let transfer_cfg = cfg.transfer.clone();
        let producer = scope.spawn(move || -> Result<(f64, f64, f64, f64, f64, u64, u64)> {
            let queue = queue;
            let mut ingest = AsyncIngest::spawn(
                ShardInput::Synth { spec: ingest_spec, seed: cfg.seed },
                &ingest_cfg,
            );
            let mut dma = TransferEngine::new(transfer_cfg);
            let mut host_s = 0.0;
            let mut sim_s = 0.0;
            let mut wait_s = 0.0;
            let mut shards = 0u64;
            while let Some((_, shard)) = ingest.next()? {
                // Credit wait: a free slot is the DMA engine's permission
                // to start (§3 backpressure).
                let t_acq = std::time::Instant::now();
                let Some(mut slot) = arena.acquire() else {
                    // Consumer closed the arena (reached max_steps).
                    break;
                };
                wait_s += t_acq.elapsed().as_secs_f64();

                let timing = pipeline.process_into_slot(&shard, &mut slot)?;
                ingest.recycle(shard);
                host_s += timing.host_s;
                sim_s += timing.elapsed_s;
                shards += 1;

                // Schedule the slot's chunked P2P write at the current
                // simulated ETL clock; it overlaps the next shard's exec.
                dma.submit(sim_s, slot.packed_bytes());

                let t_push = std::time::Instant::now();
                let pushed = queue.push(slot);
                wait_s += t_push.elapsed().as_secs_f64();
                if !pushed {
                    // Consumer hung up (reached max_steps).
                    break;
                }
            }
            Ok((
                host_s,
                sim_s,
                ingest.wait_seconds(),
                wait_s,
                dma.busy_s(),
                dma.total_bytes(),
                shards,
            ))
        });

        // Consumer: the trainer steps in place on device-addressed views
        // of each staged slot, then returns the slot's credit. Errors are
        // collected (not early-returned) so shutdown below always runs —
        // a producer blocked on a credit is only woken by `arena.close()`.
        let mut consume = || -> Result<()> {
            let mut window_busy = 0.0f64;
            let mut window_start = 0.0f64;
            const WINDOW_STEPS: u64 = 20;
            'consume: while trainer.steps < cfg.max_steps as u64 {
                let Some(slot) = consumer.pop() else { break };
                for view in slot.chunk_views(step_rows) {
                    if trainer.steps >= cfg.max_steps as u64 {
                        break;
                    }
                    let ts = std::time::Instant::now();
                    trainer.step_device(&view)?;
                    let dt = ts.elapsed().as_secs_f64();
                    train_busy_s += dt;
                    window_busy += dt;
                    if trainer.steps % (cfg.loss_every as u64).max(1) == 0 {
                        losses.push((trainer.steps, trainer.loss()?));
                    }
                    if trainer.steps % WINDOW_STEPS == 0 {
                        let now = t0.elapsed().as_secs_f64();
                        let span = (now - window_start).max(1e-9);
                        util_trace.push(now, (window_busy / span).min(1.0));
                        window_busy = 0.0;
                        window_start = now;
                    }
                }
                // Credit return: the slot is reclaimable (epoch bump).
                arena.release(slot)?;
                if trainer.steps >= cfg.max_steps as u64 {
                    break 'consume;
                }
            }
            Ok(())
        };
        let consumed = consume();
        // Shutdown: close the arena first so a producer blocked on a
        // credit wakes, then drop the consumer so a blocked push fails.
        arena.close();
        drop(consumer);
        let joined = producer.join();
        consumed?;
        match joined {
            Ok(Ok((h, s, iw, tw, db, bytes, n))) => {
                etl_host_s = h;
                etl_sim_s = s;
                ingest_wait_s = iw;
                transfer_wait_s = tw;
                dma_sim_s = db;
                staged_bytes = bytes;
                shards_done = n;
            }
            Ok(Err(e)) => return Err(e),
            Err(_) => return Err(EtlError::Coord("producer panicked".into())),
        }
        producer_stalls = stall_counter.load(std::sync::atomic::Ordering::Relaxed)
            + arena.stats().stalls;
        Ok(())
    })?;

    let arena_stats = arena.stats();
    let wall_s = t0.elapsed().as_secs_f64();
    Ok(TrainReport {
        steps: trainer.steps,
        losses,
        wall_s,
        train_busy_s,
        util: train_busy_s / wall_s.max(1e-9),
        util_trace,
        producer_stalls,
        etl_host_s,
        ingest_wait_s,
        transfer_wait_s,
        shards: shards_done,
        etl_sim_s,
        dma_sim_s,
        staged_bytes,
        host_copy_bytes: 0,
        steady_allocs: arena_stats.steady_allocs,
        per_device: vec![DeviceReport {
            device: 0,
            shards: shards_done,
            steps: trainer.steps - steps_at_start,
            transfer_wait_s,
            dma_sim_s,
            staged_bytes,
            train_busy_s,
        }],
        allreduce_sim_s: 0.0,
        allreduces: 0,
    })
}

/// A staged slot annotated with its routing decision: the device lane it
/// rode, the raw shard bytes charged to that lane's load ledger, and its
/// global routing sequence number (round-robin consumption reorders on
/// `seq` so pack-worker races cannot perturb the schedule).
struct RoutedSlot {
    seq: u64,
    device: usize,
    raw_bytes: u64,
    slot: StagingSlot,
}

/// Per-lane producer accounting returned by each pack worker.
#[derive(Default)]
struct LaneOut {
    host_s: f64,
    sim_s: f64,
    wait_s: f64,
    shards: u64,
    dma_busy_s: f64,
    dma_bytes: u64,
}

/// Combine the replicas' parameters since the last sync and broadcast the
/// result: per-device deltas are summed onto the synced base in f64 with
/// a fixed device-ascending association (deterministic tree), so the
/// reduction is bit-stable across runs. The trailing loss slot is not a
/// parameter — the reduction covers only the parameter prefix and sets
/// the slot to the contributors' mean batch loss. When exactly one
/// replica stepped since the last sync the reduction degenerates to
/// broadcasting that replica's state verbatim (loss slot included) — the
/// fast path that makes round-robin with `allreduce_every = 1` replay the
/// single-device trajectory bitwise. Returns false (and does nothing)
/// when no replica stepped.
fn allreduce_params(
    replicas: &mut [Trainer],
    synced: &mut Vec<f32>,
    steps_at_sync: &mut [u64],
) -> Result<bool> {
    let stepped: Vec<usize> = replicas
        .iter()
        .enumerate()
        .filter(|(d, r)| r.steps > steps_at_sync[*d])
        .map(|(d, _)| d)
        .collect();
    if stepped.is_empty() {
        return Ok(false);
    }
    if stepped.len() == 1 {
        // Single contributor: broadcast verbatim, reusing the synced
        // buffer as scratch and skipping the contributor's self-load —
        // the sync-every-step default stays allocation-free per step.
        let src = stepped[0];
        synced.copy_from_slice(replicas[src].state());
        for (d, r) in replicas.iter_mut().enumerate() {
            if d != src {
                r.load_state(synced)?;
            }
            steps_at_sync[d] = r.steps;
        }
        return Ok(true);
    }
    // Reduce only the parameter prefix: the trailing loss slot is a
    // per-step observable, not a parameter — delta-summing it would
    // broadcast a meaningless value into every replica (and into the
    // caller's trainer at the final sync).
    let p = synced.len() - 1;
    let mut acc: Vec<f64> = synced[..p].iter().map(|&v| v as f64).collect();
    for &d in &stepped {
        let sd = &replicas[d].state()[..p];
        for (a, (s, base)) in acc.iter_mut().zip(sd.iter().zip(synced[..p].iter())) {
            *a += (*s as f64) - (*base as f64);
        }
    }
    let mut next: Vec<f32> = acc.into_iter().map(|v| v as f32).collect();
    // Loss slot: the deterministic mean of the contributors' batch
    // losses (device-ascending order) — what the fleet reports.
    let mean_loss = stepped
        .iter()
        .map(|&d| replicas[d].state()[p] as f64)
        .sum::<f64>()
        / stepped.len() as f64;
    next.push(mean_loss as f32);
    for (d, r) in replicas.iter_mut().enumerate() {
        r.load_state(&next)?;
        steps_at_sync[d] = r.steps;
    }
    *synced = next;
    Ok(true)
}

/// Multi-device arena path: one staging region, DMA clock and pack worker
/// per simulated GPU; the router assigns each ingested shard a lane; one
/// trainer replica steps per device with periodic all-reduce (see module
/// docs).
fn run_multi(
    pipeline: &Pipeline,
    spec: &DatasetSpec,
    trainer: &mut Trainer,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let devices = cfg.devices;
    let step_rows = trainer.meta.batch;
    let steps_at_start = trainer.steps;
    let max_steps = cfg.max_steps as u64;
    let loss_every = (cfg.loss_every as u64).max(1);

    let arenas = ArenaSet::new(devices, cfg.arena.clone());
    // The fleet queue carries routed slots from every lane; size it so
    // each device keeps a slot in flight toward the consumer.
    let (queue, consumer) =
        StagingQueue::<RoutedSlot>::with_buffers(cfg.staging_buffers.max(devices));
    let stall_counter = queue.stall_counter();
    let router = DeviceRouter::new(devices, cfg.route);
    let tracker = router.tracker();

    // Per-device raw-shard lanes into the pack workers (depth 1: the
    // router hands a lane its next shard while it packs the current one).
    let mut shard_txs = Vec::with_capacity(devices);
    let mut shard_rxs = Vec::with_capacity(devices);
    for _ in 0..devices {
        let (tx, rx) = std::sync::mpsc::sync_channel::<(u64, Batch)>(1);
        shard_txs.push(tx);
        shard_rxs.push(rx);
    }
    // Consumed shard buffers flow back to the router for pool recycling.
    let (recycle_tx, recycle_rx) = std::sync::mpsc::channel::<Batch>();

    // One replica per device, forked from the caller's current params.
    let mut replicas: Vec<Trainer> = (0..devices).map(|_| trainer.replica()).collect();
    let mut synced: Vec<f32> = trainer.state_to_vec()?;
    let mut steps_at_sync: Vec<u64> = vec![0; devices];
    // All-reduce cost model: a deterministic tree needs ceil(log2 N)
    // rounds of reduce plus as many of broadcast, each moving the flat
    // state over the calibrated P2P channel.
    let allreduce_chan = ChannelModel::of(Path::P2pToGpu);
    let reduce_rounds = (usize::BITS - (devices - 1).leading_zeros()) as f64;
    let state_bytes = (trainer.meta.state_len() * std::mem::size_of::<f32>()) as u64;
    let allreduce_cost_s = 2.0 * reduce_rounds * allreduce_chan.time(state_bytes);
    let mut allreduces = 0u64;
    let mut allreduce_sim_s = 0.0f64;

    let t0 = std::time::Instant::now();
    let mut global_steps = steps_at_start;
    let mut losses = Vec::new();
    let mut train_busy_s = 0.0f64;
    let mut util_trace = TimeSeries::default();
    let mut dev_busy = vec![0.0f64; devices];
    let mut lanes: Vec<LaneOut> = Vec::with_capacity(devices);
    let mut ingest_wait_s = 0.0f64;
    let mut producer_stalls = 0u64;

    std::thread::scope(|scope| -> Result<()> {
        // Pack workers: one per device lane, each owning its device's DMA
        // engine clock (split off the TransferSet) and blocking only on
        // its own arena's credits.
        let arenas = &arenas;
        let dma_engines = TransferSet::new(devices, cfg.transfer.clone()).into_engines();
        let mut workers = Vec::with_capacity(devices);
        for ((d, rx), mut dma) in shard_rxs.into_iter().enumerate().zip(dma_engines) {
            let queue = queue.clone();
            let recycle_tx = recycle_tx.clone();
            workers.push(scope.spawn(move || -> Result<LaneOut> {
                let arena = arenas.device(d);
                let mut out = LaneOut::default();
                while let Ok((seq, shard)) = rx.recv() {
                    let raw_bytes = shard.total_bytes() as u64;
                    let t_acq = std::time::Instant::now();
                    let Some(mut slot) = arena.acquire() else {
                        break; // consumer closed the fleet (max_steps)
                    };
                    out.wait_s += t_acq.elapsed().as_secs_f64();
                    let timing = pipeline.process_into_slot(&shard, &mut slot)?;
                    let _ = recycle_tx.send(shard);
                    out.host_s += timing.host_s;
                    out.sim_s += timing.elapsed_s;
                    out.shards += 1;
                    // This lane's chunked P2P write, on this device's own
                    // engine clock.
                    dma.submit(out.sim_s, slot.packed_bytes());
                    let t_push = std::time::Instant::now();
                    let pushed = queue.push(RoutedSlot { seq, device: d, raw_bytes, slot });
                    out.wait_s += t_push.elapsed().as_secs_f64();
                    if !pushed {
                        break; // consumer hung up
                    }
                }
                out.dma_busy_s = dma.busy_s();
                out.dma_bytes = dma.total_bytes();
                Ok(out)
            }));
        }
        // Workers now hold the only queue/recycle producer handles.
        drop(queue);
        drop(recycle_tx);

        // Router: the producer front-end — ingest in delivery order,
        // assign each shard a device lane, recycle consumed buffers.
        let ingest_cfg = cfg.ingest.clone();
        let ingest_spec = spec.clone();
        let router_thread = scope.spawn(move || -> Result<f64> {
            let shard_txs = shard_txs;
            let mut router = router;
            let mut ingest = AsyncIngest::spawn(
                ShardInput::Synth { spec: ingest_spec, seed: cfg.seed },
                &ingest_cfg,
            );
            let mut seq = 0u64;
            while let Some((_, shard)) = ingest.next()? {
                while let Ok(b) = recycle_rx.try_recv() {
                    ingest.recycle(b);
                }
                let d = router.route(shard.total_bytes() as u64);
                if shard_txs[d].send((seq, shard)).is_err() {
                    break; // lane worker exited (fleet shut down)
                }
                seq += 1;
            }
            Ok(ingest.wait_seconds())
        });

        // Consumer: steps the routed device's replica in place on each
        // staged slot, returns the credit, and keeps the replicas
        // consistent via the periodic all-reduce. Errors are collected so
        // the shutdown below always runs.
        let mut consume = |replicas: &mut [Trainer]| -> Result<()> {
            let mut window_busy = 0.0f64;
            let mut window_start = 0.0f64;
            const WINDOW_STEPS: u64 = 20;
            let mut expected = 0u64;
            let mut stash: BTreeMap<u64, RoutedSlot> = BTreeMap::new();
            'consume: while global_steps < max_steps {
                // Next slot: arrival order for least-loaded, global
                // routing order for round-robin (the stash reorders
                // pack-worker races back into the pinned schedule).
                let routed = if cfg.route == RoutePolicy::RoundRobin {
                    loop {
                        if let Some(r) = stash.remove(&expected) {
                            break Some(r);
                        }
                        match consumer.pop() {
                            Some(r) => {
                                if r.seq == expected {
                                    break Some(r);
                                }
                                stash.insert(r.seq, r);
                            }
                            None => {
                                // Queue closed: drain stragglers in
                                // ascending order.
                                let k = stash.keys().next().copied();
                                break k.and_then(|k| stash.remove(&k));
                            }
                        }
                    }
                } else {
                    consumer.pop()
                };
                let Some(RoutedSlot { seq, device: d, raw_bytes, slot }) = routed else {
                    break;
                };
                expected = seq + 1;
                for view in slot.chunk_views(step_rows) {
                    if global_steps >= max_steps {
                        break;
                    }
                    let ts = std::time::Instant::now();
                    replicas[d].step_device(&view)?;
                    let dt = ts.elapsed().as_secs_f64();
                    train_busy_s += dt;
                    dev_busy[d] += dt;
                    window_busy += dt;
                    global_steps += 1;
                    if global_steps % loss_every == 0 {
                        losses.push((global_steps, replicas[d].loss()?));
                    }
                    if cfg.allreduce_every > 0
                        && global_steps % cfg.allreduce_every as u64 == 0
                        && allreduce_params(replicas, &mut synced, &mut steps_at_sync)?
                    {
                        allreduces += 1;
                        allreduce_sim_s += allreduce_cost_s;
                    }
                    if global_steps % WINDOW_STEPS == 0 {
                        let now = t0.elapsed().as_secs_f64();
                        let span = (now - window_start).max(1e-9);
                        util_trace.push(now, (window_busy / span).min(1.0));
                        window_busy = 0.0;
                        window_start = now;
                    }
                }
                tracker.complete(d, raw_bytes);
                arenas.device(d).release(slot)?;
                if global_steps >= max_steps {
                    break 'consume;
                }
            }
            // Return any stashed credits so the arena accounting stays
            // exactly-once even on an early max_steps cutoff.
            for (_, r) in std::mem::take(&mut stash) {
                tracker.complete(r.device, r.raw_bytes);
                arenas.device(r.device).release(r.slot)?;
            }
            Ok(())
        };
        let consumed = consume(&mut replicas);
        // Shutdown: close every arena first so lane workers blocked on a
        // credit wake, then drop the consumer so blocked pushes fail; the
        // router unwinds once its lane sends start failing.
        arenas.close_all();
        drop(consumer);
        for handle in workers {
            match handle.join() {
                Ok(Ok(out)) => lanes.push(out),
                Ok(Err(e)) => return Err(e),
                Err(_) => return Err(EtlError::Coord("pack worker panicked".into())),
            }
        }
        match router_thread.join() {
            Ok(Ok(w)) => ingest_wait_s = w,
            Ok(Err(e)) => return Err(e),
            Err(_) => return Err(EtlError::Coord("router panicked".into())),
        }
        consumed?;
        producer_stalls = stall_counter.load(std::sync::atomic::Ordering::Relaxed)
            + arenas.total_stats().stalls;
        Ok(())
    })?;

    // Final sync folds any steps since the last periodic all-reduce, then
    // the fleet parameters land back in the caller's trainer.
    if allreduce_params(&mut replicas, &mut synced, &mut steps_at_sync)? {
        allreduces += 1;
        allreduce_sim_s += allreduce_cost_s;
    }
    trainer.load_state(&synced)?;
    trainer.steps = global_steps;

    let per_device: Vec<DeviceReport> = (0..devices)
        .map(|d| DeviceReport {
            device: d,
            shards: lanes[d].shards,
            steps: replicas[d].steps,
            transfer_wait_s: lanes[d].wait_s,
            dma_sim_s: lanes[d].dma_busy_s,
            staged_bytes: lanes[d].dma_bytes,
            train_busy_s: dev_busy[d],
        })
        .collect();
    let wall_s = t0.elapsed().as_secs_f64();
    Ok(TrainReport {
        steps: global_steps,
        losses,
        wall_s,
        train_busy_s,
        util: train_busy_s / wall_s.max(1e-9),
        util_trace,
        producer_stalls,
        etl_host_s: lanes.iter().map(|l| l.host_s).sum(),
        ingest_wait_s,
        transfer_wait_s: lanes.iter().map(|l| l.wait_s).sum(),
        shards: lanes.iter().map(|l| l.shards).sum(),
        etl_sim_s: lanes.iter().map(|l| l.sim_s).sum(),
        dma_sim_s: lanes.iter().map(|l| l.dma_busy_s).sum(),
        staged_bytes: lanes.iter().map(|l| l.dma_bytes).sum(),
        host_copy_bytes: 0,
        steady_allocs: arenas.total_stats().steady_allocs,
        per_device,
        allreduce_sim_s,
        allreduces,
    })
}

/// Legacy heap path: pool-recycled `PackedBatch`es travel the staging
/// queue by value (the differential baseline for the zero-copy path).
fn run_channel(
    pipeline: &Pipeline,
    spec: &DatasetSpec,
    trainer: &mut Trainer,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let step_rows = trainer.meta.batch;
    let steps_at_start = trainer.steps;
    let (queue, consumer) = StagingQueue::with_buffers(cfg.staging_buffers);
    let stall_counter = queue.stall_counter();
    // Packed-batch buffers cycle producer → staging → trainer → pool, so
    // the steady state allocates nothing per shard — but each batch still
    // crosses the queue by value (one logical host copy per byte).
    let pool = BufferPool::new();

    let t0 = std::time::Instant::now();
    let mut etl_host_s = 0.0f64;
    let mut etl_sim_s = 0.0f64;
    let mut ingest_wait_s = 0.0f64;
    let mut staged_bytes = 0u64;
    let mut shards_done = 0u64;
    let mut producer_stalls = 0u64;
    let mut losses = Vec::new();
    let mut train_busy_s = 0.0f64;
    let mut host_copy_bytes = 0u64;
    let mut util_trace = TimeSeries::default();

    std::thread::scope(|scope| -> Result<()> {
        let pool = &pool;
        let ingest_cfg = cfg.ingest.clone();
        let ingest_spec = spec.clone();
        let producer = scope.spawn(move || -> Result<(f64, f64, f64, u64, u64)> {
            let queue = queue;
            let mut ingest = AsyncIngest::spawn(
                ShardInput::Synth { spec: ingest_spec, seed: cfg.seed },
                &ingest_cfg,
            );
            let mut host_s = 0.0;
            let mut sim_s = 0.0;
            let mut bytes = 0u64;
            let mut shards = 0u64;
            while let Some((_, shard)) = ingest.next()? {
                let mut packed = pool.take();
                let timing = pipeline.process_packed_into(&shard, &mut packed)?;
                ingest.recycle(shard);
                host_s += timing.host_s;
                sim_s += timing.elapsed_s;
                bytes += packed.bytes();
                shards += 1;
                if !queue.push(packed) {
                    // Consumer hung up (reached max_steps).
                    break;
                }
            }
            Ok((host_s, sim_s, ingest.wait_seconds(), bytes, shards))
        });

        // Consumer: the trainer steps on borrowed chunk views (the
        // incomplete tail of each staged batch is dropped, matching
        // DLRM's fixed batch shapes).
        let mut window_busy = 0.0f64;
        let mut window_start = 0.0f64;
        const WINDOW_STEPS: u64 = 20;
        'consume: while trainer.steps < cfg.max_steps as u64 {
            let Some(batch) = consumer.pop() else { break };
            host_copy_bytes += batch.bytes();
            for view in batch.chunk_views(step_rows) {
                if trainer.steps >= cfg.max_steps as u64 {
                    break;
                }
                let ts = std::time::Instant::now();
                trainer.step_view(&view)?;
                let dt = ts.elapsed().as_secs_f64();
                train_busy_s += dt;
                window_busy += dt;
                if trainer.steps % (cfg.loss_every as u64).max(1) == 0 {
                    losses.push((trainer.steps, trainer.loss()?));
                }
                if trainer.steps % WINDOW_STEPS == 0 {
                    let now = t0.elapsed().as_secs_f64();
                    let span = (now - window_start).max(1e-9);
                    util_trace.push(now, (window_busy / span).min(1.0));
                    window_busy = 0.0;
                    window_start = now;
                }
            }
            // Return the drained buffer for reuse.
            pool.put(batch);
            if trainer.steps >= cfg.max_steps as u64 {
                break 'consume;
            }
        }
        // Drain/close: dropping the consumer unblocks a blocked producer.
        drop(consumer);
        match producer.join() {
            Ok(Ok((h, s, w, bytes, n))) => {
                etl_host_s = h;
                etl_sim_s = s;
                ingest_wait_s = w;
                staged_bytes = bytes;
                shards_done = n;
            }
            Ok(Err(e)) => return Err(e),
            Err(_) => return Err(EtlError::Coord("producer panicked".into())),
        }
        producer_stalls = stall_counter.load(std::sync::atomic::Ordering::Relaxed);
        Ok(())
    })?;

    let wall_s = t0.elapsed().as_secs_f64();
    Ok(TrainReport {
        steps: trainer.steps,
        losses,
        wall_s,
        train_busy_s,
        util: train_busy_s / wall_s.max(1e-9),
        util_trace,
        producer_stalls,
        etl_host_s,
        ingest_wait_s,
        transfer_wait_s: 0.0,
        shards: shards_done,
        etl_sim_s,
        dma_sim_s: 0.0,
        staged_bytes,
        host_copy_bytes,
        steady_allocs: 0,
        per_device: vec![DeviceReport {
            device: 0,
            shards: shards_done,
            steps: trainer.steps - steps_at_start,
            transfer_wait_s: 0.0,
            dma_sim_s: 0.0,
            staged_bytes,
            train_busy_s,
        }],
        allreduce_sim_s: 0.0,
        allreduces: 0,
    })
}

#[cfg(test)]
mod tests {
    // Live-loop tests require compiled artifacts; they run in the
    // integration suite (rust/tests/integration_runtime.rs). The
    // ingest/exec/transfer time-attribution split and the arena-vs-
    // channel bit-identity are asserted in
    // rust/tests/integration_coordinator.rs against the artifact-free
    // reference trainer.

    #[test]
    fn default_config_is_sane() {
        let cfg = super::TrainConfig::default();
        assert!(cfg.max_steps > 0 && cfg.staging_buffers >= 2);
        assert!(cfg.ingest.workers >= 1 && cfg.ingest.channel_depth >= 1);
        // The zero-copy arena path is the shipping default, with enough
        // slots for double buffering on both sides of the queue.
        assert_eq!(cfg.path, super::DataPath::Arena);
        assert!(cfg.arena.slots >= cfg.staging_buffers + 2);
        assert!(cfg.transfer.chunk_bytes >= 1 << 20, "MiB-scale DMA chunks");
        // Multi-device defaults: single GPU, bit-reproducible routing,
        // sync-every-step all-reduce.
        assert_eq!(cfg.devices, 1);
        assert_eq!(cfg.route, crate::coordinator::scheduler::RoutePolicy::RoundRobin);
        assert_eq!(cfg.allreduce_every, 1);
    }

    #[test]
    fn multi_device_rejects_channel_path() {
        use crate::dataio::dataset::DatasetSpec;
        use crate::etl::pipelines::{build, PipelineKind};
        use crate::planner::{compile, PlannerConfig};
        use crate::runtime::artifacts::{ModelMeta, ParamSpec};

        let spec = DatasetSpec::dataset_i(0.001);
        let dag = build(PipelineKind::I, &spec.schema);
        let plan = compile(&dag, &spec.schema, &PlannerConfig::default()).unwrap();
        let mut pipe = crate::fpga::Pipeline::new(plan);
        pipe.fit(&spec.shard(0, 1)).unwrap();
        let meta = ModelMeta {
            batch: 64,
            n_dense: 13,
            n_sparse: 26,
            vocab: 64,
            embed_dim: 1,
            params: vec![
                ParamSpec { name: "w_dense".into(), dims: vec![13] },
                ParamSpec { name: "b".into(), dims: vec![1] },
                ParamSpec { name: "emb".into(), dims: vec![26 * 8] },
            ],
            extra: Default::default(),
        };
        let mut trainer = crate::runtime::Trainer::from_meta(meta, 1);
        let cfg = super::TrainConfig {
            devices: 2,
            path: super::DataPath::Channel,
            ..Default::default()
        };
        let err = super::run(&pipe, &spec, &mut trainer, &cfg).unwrap_err();
        assert!(err.to_string().contains("DataPath::Arena"), "{err}");

        // devices == 0 is a config bug, not an implicit single device.
        let cfg = super::TrainConfig { devices: 0, ..Default::default() };
        let err = super::run(&pipe, &spec, &mut trainer, &cfg).unwrap_err();
        assert!(err.to_string().contains("devices must be >= 1"), "{err}");
    }
}
