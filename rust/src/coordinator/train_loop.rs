//! The live training loop: ETL (simulated FPGA data plane, real
//! functional transforms) feeding the PJRT trainer through the credit-
//! gated staging queue — the end-to-end composition of all three layers.
//!
//! The producer side plays the FPGA role (§3.5) as a fully overlapped
//! streaming dataflow: N async ingest workers generate shards into
//! pool-recycled buffers ([`crate::dataio::ingest`]), the fused engine
//! transforms+packs each shard straight into a recycled trainer-layout
//! buffer, and the staging queue hands it to the consumer — so shard I/O,
//! fused apply+pack, and trainer steps all overlap. The consumer is the
//! GPU stand-in: pop, train, release the buffer. GPU utilization is
//! measured as train-busy time over wall time per window, exactly as
//! Fig. 14 reports. Ingest-wait and fused-exec time are attributed
//! separately in the report so stage imbalance is visible (ROADMAP:
//! pipeline-stage attribution).

use crate::coordinator::staging::StagingQueue;
use crate::dataio::dataset::DatasetSpec;
use crate::dataio::ingest::{AsyncIngest, IngestConfig, ShardInput};
use crate::error::{EtlError, Result};
use crate::etl::exec::BufferPool;
use crate::fpga::Pipeline;
use crate::metrics::TimeSeries;
use crate::runtime::Trainer;

/// Configuration of a live training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Maximum training steps (stop even if data remains).
    pub max_steps: usize,
    /// Read the loss every `loss_every` steps.
    pub loss_every: usize,
    /// Staging buffers (2 = double buffering).
    pub staging_buffers: usize,
    /// Dataset seed.
    pub seed: u64,
    /// Async shard-ingest knobs (workers / channel depth / delivery
    /// policy). The default (2 workers, depth 2, in-order) reproduces the
    /// synchronous producer's batch sequence bit-for-bit while overlapping
    /// shard generation with fused execution.
    pub ingest: IngestConfig,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            max_steps: 200,
            loss_every: 10,
            staging_buffers: 2,
            seed: 42,
            ingest: IngestConfig::default(),
        }
    }
}

/// Result of a live training run.
#[derive(Debug)]
pub struct TrainReport {
    pub steps: u64,
    /// (step, loss) samples.
    pub losses: Vec<(u64, f32)>,
    /// Wall-clock seconds end to end.
    pub wall_s: f64,
    /// Seconds the trainer was executing steps.
    pub train_busy_s: f64,
    /// Measured GPU(-stand-in) utilization = busy / wall.
    pub util: f64,
    /// Utilization trace per ~20-step window.
    pub util_trace: TimeSeries,
    /// Producer-side backpressure stalls.
    pub producer_stalls: u64,
    /// Host seconds the producer spent in fused apply+pack (exec time,
    /// excluding ingest wait).
    pub etl_host_s: f64,
    /// Host seconds the producer spent blocked waiting on shard ingest
    /// (I/O-wait attribution, disjoint from `etl_host_s`).
    pub ingest_wait_s: f64,
    /// Shards transformed by the producer.
    pub shards: u64,
    /// Simulated FPGA ETL seconds for the same bytes (the paper's clock).
    pub etl_sim_s: f64,
}

impl TrainReport {
    /// First and last observed loss, for convergence checks.
    pub fn loss_delta(&self) -> Option<(f32, f32)> {
        match (self.losses.first(), self.losses.last()) {
            (Some(&(_, a)), Some(&(_, b))) if self.losses.len() >= 2 => Some((a, b)),
            _ => None,
        }
    }
}

/// Run the full loop: `pipeline` transforms shards of `spec`, the packed
/// batches train `trainer`.
pub fn run(
    pipeline: &Pipeline,
    spec: &DatasetSpec,
    trainer: &mut Trainer,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    if !pipeline.is_fitted() && pipeline.plan.dag.stateful_count() > 0 {
        return Err(EtlError::Coord("pipeline must be fitted before training".into()));
    }
    let step_rows = trainer.meta.batch;
    let (queue, consumer) = StagingQueue::with_buffers(cfg.staging_buffers);
    let stall_counter = queue.stall_counter();
    // Packed-batch buffers cycle producer → staging → trainer → pool, so
    // the steady state allocates nothing per shard.
    let pool = BufferPool::new();

    let t0 = std::time::Instant::now();
    let mut etl_host_s = 0.0f64;
    let mut etl_sim_s = 0.0f64;
    let mut ingest_wait_s = 0.0f64;
    let mut shards_done = 0u64;
    let mut producer_stalls = 0u64;
    let mut losses = Vec::new();
    let mut train_busy_s = 0.0f64;
    let mut util_trace = TimeSeries::default();

    std::thread::scope(|scope| -> Result<()> {
        // Producer: the FPGA data plane. Async ingest workers stream
        // shards into recycled buffers while the fused engine transforms
        // each one straight into a recycled trainer-layout buffer; the
        // queue is moved in so dropping it at the end closes the channel
        // and wakes the consumer.
        let pool = &pool;
        let ingest_cfg = cfg.ingest.clone();
        let ingest_spec = spec.clone();
        let producer = scope.spawn(move || -> Result<(f64, f64, f64, u64)> {
            let queue = queue;
            let mut ingest = AsyncIngest::spawn(
                ShardInput::Synth { spec: ingest_spec, seed: cfg.seed },
                &ingest_cfg,
            );
            let mut host_s = 0.0;
            let mut sim_s = 0.0;
            let mut shards = 0u64;
            while let Some((_, shard)) = ingest.next()? {
                let mut packed = pool.take();
                let timing = pipeline.process_packed_into(&shard, &mut packed)?;
                ingest.recycle(shard);
                host_s += timing.host_s;
                sim_s += timing.elapsed_s;
                shards += 1;
                if !queue.push(packed) {
                    // Consumer hung up (reached max_steps).
                    break;
                }
            }
            Ok((host_s, sim_s, ingest.wait_seconds(), shards))
        });

        // Consumer: the trainer steps on borrowed chunk views (zero-copy;
        // the incomplete tail of each staged batch is dropped, matching
        // DLRM's fixed batch shapes).
        let mut window_busy = 0.0f64;
        let mut window_start = 0.0f64;
        const WINDOW_STEPS: u64 = 20;
        'consume: while trainer.steps < cfg.max_steps as u64 {
            let Some(batch) = consumer.pop() else { break };
            for view in batch.chunk_views(step_rows) {
                if trainer.steps >= cfg.max_steps as u64 {
                    break;
                }
                let ts = std::time::Instant::now();
                trainer.step_view(&view)?;
                let dt = ts.elapsed().as_secs_f64();
                train_busy_s += dt;
                window_busy += dt;
                if trainer.steps % (cfg.loss_every as u64).max(1) == 0 {
                    losses.push((trainer.steps, trainer.loss()?));
                }
                if trainer.steps % WINDOW_STEPS == 0 {
                    let now = t0.elapsed().as_secs_f64();
                    let span = (now - window_start).max(1e-9);
                    util_trace.push(now, (window_busy / span).min(1.0));
                    window_busy = 0.0;
                    window_start = now;
                }
            }
            // Return the drained buffer for reuse.
            pool.put(batch);
            if trainer.steps >= cfg.max_steps as u64 {
                break 'consume;
            }
        }
        // Drain/close: dropping the consumer unblocks a blocked producer.
        drop(consumer);
        match producer.join() {
            Ok(Ok((h, s, w, n))) => {
                etl_host_s = h;
                etl_sim_s = s;
                ingest_wait_s = w;
                shards_done = n;
            }
            Ok(Err(e)) => return Err(e),
            Err(_) => return Err(EtlError::Coord("producer panicked".into())),
        }
        producer_stalls = stall_counter.load(std::sync::atomic::Ordering::Relaxed);
        Ok(())
    })?;

    let wall_s = t0.elapsed().as_secs_f64();
    Ok(TrainReport {
        steps: trainer.steps,
        losses,
        wall_s,
        train_busy_s,
        util: train_busy_s / wall_s.max(1e-9),
        util_trace,
        producer_stalls,
        etl_host_s,
        ingest_wait_s,
        shards: shards_done,
        etl_sim_s,
    })
}

#[cfg(test)]
mod tests {
    // Live-loop tests require compiled artifacts; they run in the
    // integration suite (rust/tests/integration_runtime.rs). The
    // ingest/exec time-attribution split is asserted in
    // rust/tests/integration_coordinator.rs against the artifact-free
    // reference trainer.

    #[test]
    fn default_config_is_sane() {
        let cfg = super::TrainConfig::default();
        assert!(cfg.max_steps > 0 && cfg.staging_buffers >= 2);
        assert!(cfg.ingest.workers >= 1 && cfg.ingest.channel_depth >= 1);
    }
}
