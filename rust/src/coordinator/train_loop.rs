//! The live training loop: ETL (simulated FPGA data plane, real
//! functional transforms) feeding the trainer through credit-gated
//! device staging — the end-to-end composition of all layers.
//!
//! The producer side plays the FPGA role (§3.5) as a fully overlapped
//! streaming dataflow: N async ingest workers generate shards into
//! pool-recycled buffers ([`crate::dataio::ingest`]), the fused engine
//! transforms+packs each shard, and the staging queue hands it to the
//! consumer — so shard I/O, fused apply+pack, P2P transfer and trainer
//! steps all overlap. The consumer is the GPU stand-in: pop, train,
//! return the credit. GPU utilization is measured as train-busy time over
//! wall time per window, exactly as Fig. 14 reports.
//!
//! Two data paths share the protocol ([`DataPath`]):
//!
//! * [`DataPath::Arena`] (default) — the **zero-copy** path of
//!   [`crate::devmem`]: the fused engine packs each shard once, directly
//!   into a [`crate::devmem::StagingSlot`] of the pinned device arena;
//!   the [`crate::devmem::TransferEngine`] accounts the chunked P2P DMA
//!   that makes the slot resident; the trainer steps **in place** on
//!   [`crate::devmem::DeviceBatchView`]s and releases the slot's credit.
//!   Zero per-shard `PackedBatch` heap allocations in the steady state,
//!   zero host-side copies between pack and training.
//! * [`DataPath::Channel`] — the legacy heap path: pool-recycled owned
//!   [`crate::coordinator::packer::PackedBatch`]es travel the staging
//!   queue by value (one logical host copy per packed byte). Kept as the
//!   differential baseline (`rust/tests/prop_devmem.rs` pins the two
//!   paths bit-identical) and for the `zero-copy` hotpath bench section.
//!
//! Ingest-wait, fused-exec and transfer-wait time are attributed
//! separately in the report so stage imbalance is visible (ROADMAP:
//! pipeline-stage attribution).

use crate::coordinator::staging::StagingQueue;
use crate::dataio::dataset::DatasetSpec;
use crate::dataio::ingest::{AsyncIngest, IngestConfig, ShardInput};
use crate::devmem::{ArenaConfig, DeviceArena, StagingSlot, TransferConfig, TransferEngine};
use crate::error::{EtlError, Result};
use crate::etl::exec::BufferPool;
use crate::fpga::Pipeline;
use crate::metrics::TimeSeries;
use crate::runtime::Trainer;

/// Which staging dataflow the loop runs (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataPath {
    /// Zero-copy device staging: pack into pinned arena slots, simulated
    /// P2P DMA, in-place training, credit return.
    Arena,
    /// Heap `PackedBatch`es over the staging channel (legacy baseline).
    Channel,
}

/// Configuration of a live training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Maximum training steps (stop even if data remains).
    pub max_steps: usize,
    /// Read the loss every `loss_every` steps.
    pub loss_every: usize,
    /// Staging buffers (2 = double buffering).
    pub staging_buffers: usize,
    /// Dataset seed.
    pub seed: u64,
    /// Async shard-ingest knobs (workers / channel depth / delivery
    /// policy). The default (2 workers, depth 2, in-order) reproduces the
    /// synchronous producer's batch sequence bit-for-bit while overlapping
    /// shard generation with fused execution.
    pub ingest: IngestConfig,
    /// Staging dataflow (default: the zero-copy arena path).
    pub path: DataPath,
    /// Device-arena sizing for [`DataPath::Arena`].
    pub arena: ArenaConfig,
    /// P2P DMA engine knobs for [`DataPath::Arena`].
    pub transfer: TransferConfig,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            max_steps: 200,
            loss_every: 10,
            staging_buffers: 2,
            seed: 42,
            ingest: IngestConfig::default(),
            path: DataPath::Arena,
            arena: ArenaConfig::default(),
            transfer: TransferConfig::default(),
        }
    }
}

/// Result of a live training run.
#[derive(Debug)]
pub struct TrainReport {
    pub steps: u64,
    /// (step, loss) samples.
    pub losses: Vec<(u64, f32)>,
    /// Wall-clock seconds end to end.
    pub wall_s: f64,
    /// Seconds the trainer was executing steps.
    pub train_busy_s: f64,
    /// Measured GPU(-stand-in) utilization = busy / wall.
    pub util: f64,
    /// Utilization trace per ~20-step window.
    pub util_trace: TimeSeries,
    /// Producer-side backpressure stalls.
    pub producer_stalls: u64,
    /// Host seconds the producer spent in fused apply+pack (exec time,
    /// excluding ingest wait).
    pub etl_host_s: f64,
    /// Host seconds the producer spent blocked waiting on shard ingest
    /// (I/O-wait attribution, disjoint from `etl_host_s`).
    pub ingest_wait_s: f64,
    /// Host seconds the producer spent blocked on device staging —
    /// waiting for a free arena slot (credit) or for staging-queue space;
    /// disjoint from `etl_host_s` and `ingest_wait_s`. 0 on the channel
    /// path (its queue blocking folds into `producer_stalls` only).
    pub transfer_wait_s: f64,
    /// Shards transformed by the producer.
    pub shards: u64,
    /// Simulated FPGA ETL seconds for the same bytes (the paper's clock).
    pub etl_sim_s: f64,
    /// Simulated seconds the P2P DMA engine spent moving packed bytes
    /// (arena path; 0 on the channel path).
    pub dma_sim_s: f64,
    /// Packed bytes staged toward the trainer.
    pub staged_bytes: u64,
    /// Host-side bytes logically copied between pack and training: the
    /// channel path pays one copy per packed byte (batches travel by
    /// value); the arena path pins this to 0 — the zero-copy acceptance
    /// counter.
    pub host_copy_bytes: u64,
    /// Per-shard slot-buffer allocations after each slot's first pack
    /// (arena path; must be 0 in the steady state).
    pub steady_allocs: u64,
}

impl TrainReport {
    /// First and last observed loss, for convergence checks.
    pub fn loss_delta(&self) -> Option<(f32, f32)> {
        match (self.losses.first(), self.losses.last()) {
            (Some(&(_, a)), Some(&(_, b))) if self.losses.len() >= 2 => Some((a, b)),
            _ => None,
        }
    }
}

/// Run the full loop: `pipeline` transforms shards of `spec`, the packed
/// batches train `trainer`.
pub fn run(
    pipeline: &Pipeline,
    spec: &DatasetSpec,
    trainer: &mut Trainer,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    if !pipeline.is_fitted() && pipeline.plan.dag.stateful_count() > 0 {
        return Err(EtlError::Coord("pipeline must be fitted before training".into()));
    }
    match cfg.path {
        DataPath::Arena => run_arena(pipeline, spec, trainer, cfg),
        DataPath::Channel => run_channel(pipeline, spec, trainer, cfg),
    }
}

/// Zero-copy path: ingest → fused pack into arena slots → simulated P2P
/// DMA → in-place training → credit return.
fn run_arena(
    pipeline: &Pipeline,
    spec: &DatasetSpec,
    trainer: &mut Trainer,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let step_rows = trainer.meta.batch;
    let (queue, consumer) = StagingQueue::<StagingSlot>::with_buffers(cfg.staging_buffers);
    let stall_counter = queue.stall_counter();
    let arena = DeviceArena::new(cfg.arena.clone());

    let t0 = std::time::Instant::now();
    let mut etl_host_s = 0.0f64;
    let mut etl_sim_s = 0.0f64;
    let mut ingest_wait_s = 0.0f64;
    let mut transfer_wait_s = 0.0f64;
    let mut dma_sim_s = 0.0f64;
    let mut staged_bytes = 0u64;
    let mut shards_done = 0u64;
    let mut producer_stalls = 0u64;
    let mut losses = Vec::new();
    let mut train_busy_s = 0.0f64;
    let mut util_trace = TimeSeries::default();

    std::thread::scope(|scope| -> Result<()> {
        // Producer: the FPGA data plane. Each shard is packed once,
        // directly into an acquired arena slot, then the DMA engine
        // schedules its chunked P2P transfer and the slot rides the queue
        // to the consumer. The queue is moved in so dropping it at the end
        // closes the channel and wakes the consumer.
        let arena = &arena;
        let ingest_cfg = cfg.ingest.clone();
        let ingest_spec = spec.clone();
        let transfer_cfg = cfg.transfer.clone();
        let producer = scope.spawn(move || -> Result<(f64, f64, f64, f64, f64, u64, u64)> {
            let queue = queue;
            let mut ingest = AsyncIngest::spawn(
                ShardInput::Synth { spec: ingest_spec, seed: cfg.seed },
                &ingest_cfg,
            );
            let mut dma = TransferEngine::new(transfer_cfg);
            let mut host_s = 0.0;
            let mut sim_s = 0.0;
            let mut wait_s = 0.0;
            let mut shards = 0u64;
            while let Some((_, shard)) = ingest.next()? {
                // Credit wait: a free slot is the DMA engine's permission
                // to start (§3 backpressure).
                let t_acq = std::time::Instant::now();
                let Some(mut slot) = arena.acquire() else {
                    // Consumer closed the arena (reached max_steps).
                    break;
                };
                wait_s += t_acq.elapsed().as_secs_f64();

                let timing = pipeline.process_into_slot(&shard, &mut slot)?;
                ingest.recycle(shard);
                host_s += timing.host_s;
                sim_s += timing.elapsed_s;
                shards += 1;

                // Schedule the slot's chunked P2P write at the current
                // simulated ETL clock; it overlaps the next shard's exec.
                dma.submit(sim_s, slot.packed_bytes());

                let t_push = std::time::Instant::now();
                let pushed = queue.push(slot);
                wait_s += t_push.elapsed().as_secs_f64();
                if !pushed {
                    // Consumer hung up (reached max_steps).
                    break;
                }
            }
            Ok((
                host_s,
                sim_s,
                ingest.wait_seconds(),
                wait_s,
                dma.busy_s(),
                dma.total_bytes(),
                shards,
            ))
        });

        // Consumer: the trainer steps in place on device-addressed views
        // of each staged slot, then returns the slot's credit. Errors are
        // collected (not early-returned) so shutdown below always runs —
        // a producer blocked on a credit is only woken by `arena.close()`.
        let mut consume = || -> Result<()> {
            let mut window_busy = 0.0f64;
            let mut window_start = 0.0f64;
            const WINDOW_STEPS: u64 = 20;
            'consume: while trainer.steps < cfg.max_steps as u64 {
                let Some(slot) = consumer.pop() else { break };
                for view in slot.chunk_views(step_rows) {
                    if trainer.steps >= cfg.max_steps as u64 {
                        break;
                    }
                    let ts = std::time::Instant::now();
                    trainer.step_device(&view)?;
                    let dt = ts.elapsed().as_secs_f64();
                    train_busy_s += dt;
                    window_busy += dt;
                    if trainer.steps % (cfg.loss_every as u64).max(1) == 0 {
                        losses.push((trainer.steps, trainer.loss()?));
                    }
                    if trainer.steps % WINDOW_STEPS == 0 {
                        let now = t0.elapsed().as_secs_f64();
                        let span = (now - window_start).max(1e-9);
                        util_trace.push(now, (window_busy / span).min(1.0));
                        window_busy = 0.0;
                        window_start = now;
                    }
                }
                // Credit return: the slot is reclaimable (epoch bump).
                arena.release(slot)?;
                if trainer.steps >= cfg.max_steps as u64 {
                    break 'consume;
                }
            }
            Ok(())
        };
        let consumed = consume();
        // Shutdown: close the arena first so a producer blocked on a
        // credit wakes, then drop the consumer so a blocked push fails.
        arena.close();
        drop(consumer);
        let joined = producer.join();
        consumed?;
        match joined {
            Ok(Ok((h, s, iw, tw, db, bytes, n))) => {
                etl_host_s = h;
                etl_sim_s = s;
                ingest_wait_s = iw;
                transfer_wait_s = tw;
                dma_sim_s = db;
                staged_bytes = bytes;
                shards_done = n;
            }
            Ok(Err(e)) => return Err(e),
            Err(_) => return Err(EtlError::Coord("producer panicked".into())),
        }
        producer_stalls = stall_counter.load(std::sync::atomic::Ordering::Relaxed)
            + arena.stats().stalls;
        Ok(())
    })?;

    let arena_stats = arena.stats();
    let wall_s = t0.elapsed().as_secs_f64();
    Ok(TrainReport {
        steps: trainer.steps,
        losses,
        wall_s,
        train_busy_s,
        util: train_busy_s / wall_s.max(1e-9),
        util_trace,
        producer_stalls,
        etl_host_s,
        ingest_wait_s,
        transfer_wait_s,
        shards: shards_done,
        etl_sim_s,
        dma_sim_s,
        staged_bytes,
        host_copy_bytes: 0,
        steady_allocs: arena_stats.steady_allocs,
    })
}

/// Legacy heap path: pool-recycled `PackedBatch`es travel the staging
/// queue by value (the differential baseline for the zero-copy path).
fn run_channel(
    pipeline: &Pipeline,
    spec: &DatasetSpec,
    trainer: &mut Trainer,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let step_rows = trainer.meta.batch;
    let (queue, consumer) = StagingQueue::with_buffers(cfg.staging_buffers);
    let stall_counter = queue.stall_counter();
    // Packed-batch buffers cycle producer → staging → trainer → pool, so
    // the steady state allocates nothing per shard — but each batch still
    // crosses the queue by value (one logical host copy per byte).
    let pool = BufferPool::new();

    let t0 = std::time::Instant::now();
    let mut etl_host_s = 0.0f64;
    let mut etl_sim_s = 0.0f64;
    let mut ingest_wait_s = 0.0f64;
    let mut staged_bytes = 0u64;
    let mut shards_done = 0u64;
    let mut producer_stalls = 0u64;
    let mut losses = Vec::new();
    let mut train_busy_s = 0.0f64;
    let mut host_copy_bytes = 0u64;
    let mut util_trace = TimeSeries::default();

    std::thread::scope(|scope| -> Result<()> {
        let pool = &pool;
        let ingest_cfg = cfg.ingest.clone();
        let ingest_spec = spec.clone();
        let producer = scope.spawn(move || -> Result<(f64, f64, f64, u64, u64)> {
            let queue = queue;
            let mut ingest = AsyncIngest::spawn(
                ShardInput::Synth { spec: ingest_spec, seed: cfg.seed },
                &ingest_cfg,
            );
            let mut host_s = 0.0;
            let mut sim_s = 0.0;
            let mut bytes = 0u64;
            let mut shards = 0u64;
            while let Some((_, shard)) = ingest.next()? {
                let mut packed = pool.take();
                let timing = pipeline.process_packed_into(&shard, &mut packed)?;
                ingest.recycle(shard);
                host_s += timing.host_s;
                sim_s += timing.elapsed_s;
                bytes += packed.bytes();
                shards += 1;
                if !queue.push(packed) {
                    // Consumer hung up (reached max_steps).
                    break;
                }
            }
            Ok((host_s, sim_s, ingest.wait_seconds(), bytes, shards))
        });

        // Consumer: the trainer steps on borrowed chunk views (the
        // incomplete tail of each staged batch is dropped, matching
        // DLRM's fixed batch shapes).
        let mut window_busy = 0.0f64;
        let mut window_start = 0.0f64;
        const WINDOW_STEPS: u64 = 20;
        'consume: while trainer.steps < cfg.max_steps as u64 {
            let Some(batch) = consumer.pop() else { break };
            host_copy_bytes += batch.bytes();
            for view in batch.chunk_views(step_rows) {
                if trainer.steps >= cfg.max_steps as u64 {
                    break;
                }
                let ts = std::time::Instant::now();
                trainer.step_view(&view)?;
                let dt = ts.elapsed().as_secs_f64();
                train_busy_s += dt;
                window_busy += dt;
                if trainer.steps % (cfg.loss_every as u64).max(1) == 0 {
                    losses.push((trainer.steps, trainer.loss()?));
                }
                if trainer.steps % WINDOW_STEPS == 0 {
                    let now = t0.elapsed().as_secs_f64();
                    let span = (now - window_start).max(1e-9);
                    util_trace.push(now, (window_busy / span).min(1.0));
                    window_busy = 0.0;
                    window_start = now;
                }
            }
            // Return the drained buffer for reuse.
            pool.put(batch);
            if trainer.steps >= cfg.max_steps as u64 {
                break 'consume;
            }
        }
        // Drain/close: dropping the consumer unblocks a blocked producer.
        drop(consumer);
        match producer.join() {
            Ok(Ok((h, s, w, bytes, n))) => {
                etl_host_s = h;
                etl_sim_s = s;
                ingest_wait_s = w;
                staged_bytes = bytes;
                shards_done = n;
            }
            Ok(Err(e)) => return Err(e),
            Err(_) => return Err(EtlError::Coord("producer panicked".into())),
        }
        producer_stalls = stall_counter.load(std::sync::atomic::Ordering::Relaxed);
        Ok(())
    })?;

    let wall_s = t0.elapsed().as_secs_f64();
    Ok(TrainReport {
        steps: trainer.steps,
        losses,
        wall_s,
        train_busy_s,
        util: train_busy_s / wall_s.max(1e-9),
        util_trace,
        producer_stalls,
        etl_host_s,
        ingest_wait_s,
        transfer_wait_s: 0.0,
        shards: shards_done,
        etl_sim_s,
        dma_sim_s: 0.0,
        staged_bytes,
        host_copy_bytes,
        steady_allocs: 0,
    })
}

#[cfg(test)]
mod tests {
    // Live-loop tests require compiled artifacts; they run in the
    // integration suite (rust/tests/integration_runtime.rs). The
    // ingest/exec/transfer time-attribution split and the arena-vs-
    // channel bit-identity are asserted in
    // rust/tests/integration_coordinator.rs against the artifact-free
    // reference trainer.

    #[test]
    fn default_config_is_sane() {
        let cfg = super::TrainConfig::default();
        assert!(cfg.max_steps > 0 && cfg.staging_buffers >= 2);
        assert!(cfg.ingest.workers >= 1 && cfg.ingest.channel_depth >= 1);
        // The zero-copy arena path is the shipping default, with enough
        // slots for double buffering on both sides of the queue.
        assert_eq!(cfg.path, super::DataPath::Arena);
        assert!(cfg.arena.slots >= cfg.staging_buffers + 2);
        assert!(cfg.transfer.chunk_bytes >= 1 << 20, "MiB-scale DMA chunks");
    }
}
