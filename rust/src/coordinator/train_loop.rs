//! The live training loop: ETL (simulated FPGA data plane, real
//! functional transforms) feeding the trainer through credit-gated
//! device staging — the end-to-end composition of all layers.
//!
//! The producer side plays the FPGA role (§3.5) as a fully overlapped
//! streaming dataflow: N async ingest workers generate shards into
//! pool-recycled buffers ([`crate::dataio::ingest`]), the fused engine
//! transforms+packs each shard, and the staging queue hands it to the
//! consumer — so shard I/O, fused apply+pack, P2P transfer and trainer
//! steps all overlap. The consumer is the GPU stand-in: pop, train,
//! return the credit. GPU utilization is measured as train-busy time over
//! wall time per window, exactly as Fig. 14 reports.
//!
//! Two data paths share the protocol ([`DataPath`]):
//!
//! * [`DataPath::Arena`] (default) — the **zero-copy** path of
//!   [`crate::devmem`]: the fused engine packs each shard once, directly
//!   into a [`crate::devmem::StagingSlot`] of the pinned device arena;
//!   the [`crate::devmem::TransferEngine`] accounts the chunked P2P DMA
//!   that makes the slot resident; the trainer steps **in place** on
//!   [`crate::devmem::DeviceBatchView`]s and releases the slot's credit.
//!   Zero per-shard `PackedBatch` heap allocations in the steady state,
//!   zero host-side copies between pack and training.
//! * [`DataPath::Channel`] — the legacy heap path: pool-recycled owned
//!   [`crate::coordinator::packer::PackedBatch`]es travel the staging
//!   queue by value (one logical host copy per packed byte). Kept as the
//!   differential baseline (`rust/tests/prop_devmem.rs` pins the two
//!   paths bit-identical) and for the `zero-copy` hotpath bench section.
//!
//! Ingest-wait, fused-exec and transfer-wait time are attributed
//! separately in the report so stage imbalance is visible (ROADMAP:
//! pipeline-stage attribution).
//!
//! # Multi-device (N simulated GPUs, truly concurrent consumers)
//!
//! With [`TrainConfig::devices`] > 1 the arena path becomes a routed
//! fleet with **one consumer thread per device**: a
//! [`crate::devmem::ArenaSet`] holds one staging region per device in a
//! shared MMU address space, each device lane has its own pack worker,
//! DMA clock, staged-slot queue and trainer replica, and the scheduler's
//! [`crate::coordinator::scheduler::DeviceRouter`] assigns every ingested
//! shard to a lane ([`crate::coordinator::scheduler::RoutePolicy`]:
//! round-robin pins a bit-reproducible schedule, least-loaded follows the
//! outstanding-byte ledger with byte ties broken to the lowest device
//! index).
//!
//! ```text
//!             router (delivery order, stamps global step ranges)
//!                │ shard+start_g        │                 │
//!         ┌──────▼──────┐       ┌───────▼─────┐    ┌──────▼──────┐
//!  lane 0 │ pack worker │       │ pack worker │ …  │ pack worker │ lane N-1
//!         │ arena 0+DMA0│       │ arena 1+DMA1│    │ arena N-1   │
//!         └──────┬──────┘       └───────┬─────┘    └──────┬──────┘
//!          slot queue 0           slot queue 1       slot queue N-1
//!         ┌──────▼──────┐       ┌───────▼─────┐    ┌──────▼──────┐
//!         │ consumer 0  │       │ consumer 1  │ …  │ consumer N-1│   one thread
//!         │ replica 0   │       │ replica 1   │    │ replica N-1 │   per device
//!         └──────┬──────┘       └───────┬─────┘    └──────┬──────┘
//!                └── grad posts ─┴─ ReduceBus ─┴─ epoch waits ──┘
//!                    (barrier-free epoch-tagged all-reduce)
//! ```
//!
//! Replicas are kept consistent by the **barrier-free gradient
//! all-reduce** of [`crate::coordinator::scheduler::ReduceBus`]: each
//! consumer steps its replica locally (`Trainer::grad_step`) and posts an
//! f64 gradient-level contribution per step; an epoch (a window of
//! [`TrainConfig::allreduce_every`] global steps in delivery order)
//! resolves as soon as all of its steps are posted, and each replica
//! independently replays the resolved epoch's contributions —
//! device-ascending — onto its last synced base
//! (`Trainer::apply_reduced`), landing every replica on bitwise identical
//! parameters with no rendezvous barrier and no state broadcast. The
//! reduction is costed per epoch against the calibrated P2P channel as a
//! deterministic tree ([`TrainReport::allreduce_sim_s`]); consumer time
//! blocked on epoch resolution is attributed to
//! [`TrainReport::reduce_wait_s`].
//!
//! **Reproducibility matrix** (pinned by `rust/tests/prop_devmem.rs` and
//! the schedule-fuzzing harness `rust/tests/prop_concurrent.rs`):
//!
//! * round-robin + `allreduce_every = 1` + in-order ingest — **bitwise
//!   identical** to the single-device trajectory (losses and final
//!   parameters), under every schedule: each epoch has exactly one
//!   contributed step, so the replay is the exact single-device f32
//!   update, serialized by the epoch dependency chain.
//! * round-robin + `allreduce_every > 1` (or `= 0`, sync at stream end
//!   only) — **deterministic** (schedule-independent losses and
//!   parameters) but not single-device-identical: replicas run local SGD
//!   inside each window and the window reduction replays contributions
//!   from the shared base. This is the throughput mode: consumers overlap
//!   within each window.
//! * least-loaded — exactly-once, not deterministic (routing follows the
//!   live byte ledger).
//!
//! [`TrainReport::per_device`] breaks transfer-wait, DMA, staged bytes,
//! steps, train-busy and reduce-wait down per device.
//!
//! # Sharded embedding tables (model parallelism)
//!
//! [`TrainConfig::embedding`] layers the sharded embedding cache of
//! [`crate::runtime::embedding`] over the routed fleet: the trainer's
//! embedding pool is hash-sharded across the devices, each lane pins a
//! bounded hot set in its arena ([`crate::devmem::DeviceArena::reserve_cache`])
//! and spills the rest to the simulated host cold tier. The lane's pack
//! worker drives a [`crate::coordinator::scheduler::PrefetchPipeline`]:
//! right after staging a slot it promotes that slot's embedding rows, and
//! commits the hit/miss walk `lookahead` slots later — the router's
//! head-start is what hides the promotion latency. Sparse embedding
//! gradients ride the existing [`crate::coordinator::scheduler::ReduceBus`] epochs (every step's f64
//! gradient image already carries the touched embedding slots); rows owned
//! by peer shards charge [`TrainReport::exchange_bytes`] both for the row
//! fetch and the gradient routed back. Because the authoritative values
//! stay in each replica's flat state, enabling the cache **never changes
//! the training arithmetic** — `rust/tests/prop_embedding.rs` pins the
//! cached run bitwise identical to the uncached reference across device
//! counts × cache sizes × lookahead depths, including tables that exceed
//! any single arena's budget (the memory wall the layer exists for).
//!
//! # Failure domains (lane loss)
//!
//! On the multi-device path a device lane can be **lost mid-run** — an
//! injected [`crate::util::fault::site::LANE_LOSS`] at the consumer, or
//! this lane's DMA engine hard-failing past its retry budget
//! ([`TransferConfig::max_retries`]) at the pack worker — without taking
//! down the fleet. The dying side marks the lane dead (the router stops
//! assigning it shards and re-routes the remainder to survivors), the
//! consumer leaves the reduce group ([`crate::coordinator::scheduler::ReduceBus::leave`]) so peers stop
//! waiting on its fetches, and every step range still queued on the dead
//! lane is forfeited ([`crate::coordinator::scheduler::ReduceBus::forfeit`]) so reduce epochs keep
//! resolving — survivors converge on the reduced state of the steps that
//! actually ran. Only when **no** lane survives does the run fail, with
//! [`EtlError::LaneLost`]. [`TrainReport::lanes_lost`],
//! [`TrainReport::forfeited_steps`], [`TrainReport::retried_transfers`]
//! and [`TrainReport::failed_transfers`] account the damage; the full
//! site-by-site fault matrix lives in [`crate::coordinator`]'s module
//! docs.

use crate::coordinator::autotune::{AppliedKnob, AutotuneConfig, AutotuneReport};
use crate::coordinator::fleet::{self, ControlScript};
use crate::coordinator::scheduler::RoutePolicy;
use crate::coordinator::staging::StagingQueue;
use crate::dataio::dataset::DatasetSpec;
use crate::dataio::ingest::{AsyncIngest, DeliveryPolicy, IngestConfig, ShardInput};
use crate::devmem::{ArenaConfig, TransferConfig};
use crate::error::{EtlError, Result};
use crate::etl::exec::BufferPool;
use crate::fpga::Pipeline;
use crate::metrics::TimeSeries;
use crate::runtime::Trainer;
use crate::trace::{self, kind as tkind};
use crate::util::fault;

/// Which staging dataflow the loop runs (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataPath {
    /// Zero-copy device staging: pack into pinned arena slots, simulated
    /// P2P DMA, in-place training, credit return.
    Arena,
    /// Heap `PackedBatch`es over the staging channel (legacy baseline).
    Channel,
}

/// Configuration of a live training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Maximum training steps (stop even if data remains).
    pub max_steps: usize,
    /// Read the loss every `loss_every` steps.
    pub loss_every: usize,
    /// Staging buffers (2 = double buffering).
    pub staging_buffers: usize,
    /// Dataset seed.
    pub seed: u64,
    /// Async shard-ingest knobs (workers / channel depth / delivery
    /// policy). The default (2 workers, depth 2, in-order) reproduces the
    /// synchronous producer's batch sequence bit-for-bit while overlapping
    /// shard generation with fused execution.
    pub ingest: IngestConfig,
    /// Staging dataflow (default: the zero-copy arena path).
    pub path: DataPath,
    /// Device-arena sizing for [`DataPath::Arena`] (per device when
    /// `devices` > 1).
    pub arena: ArenaConfig,
    /// P2P DMA engine knobs for [`DataPath::Arena`] (one engine clock per
    /// device when `devices` > 1).
    pub transfer: TransferConfig,
    /// Simulated GPUs fed by the staging dataflow. 1 = the single-device
    /// arena path; > 1 routes shards across an [`crate::devmem::ArenaSet`] (arena path
    /// only).
    pub devices: usize,
    /// Shard→device routing policy for `devices` > 1.
    pub route: RoutePolicy,
    /// All-reduce period in global steps for `devices` > 1. 1 (default)
    /// syncs replicas after every step — the bit-reproducible schedule;
    /// larger periods run local SGD between syncs; 0 syncs only at stream
    /// end.
    pub allreduce_every: usize,
    /// Sharded embedding-table layer (model parallelism; arena path
    /// only). `Some` shards the trainer's embedding pool across the
    /// device fleet with a lookahead-prefetched hot/cold cache per lane
    /// (see [`crate::runtime::embedding`]); the cached execution stays
    /// bitwise identical to the uncached reference. `None` (default)
    /// keeps the whole pool implicit in each replica's flat state.
    pub embedding: Option<crate::runtime::embedding::EmbeddingConfig>,
    /// Record an end-to-end trace of the run (see [`crate::trace`]):
    /// dual-clock spans from every stage land in
    /// [`TrainReport::trace`], with the per-lane stall ledger in
    /// [`TrainReport::stall_attribution`]. Off (default), every probe
    /// costs one relaxed atomic load; tracing never changes the training
    /// arithmetic (pinned bitwise by `rust/tests/prop_trace.rs`).
    pub trace: bool,
    /// Scripted mid-run control-plane changes — lane add/remove and live
    /// knob retunes, applied deterministically at routing-frontier
    /// quiesce points (see [`crate::coordinator::fleet`]; arena path
    /// only). Empty (default) = a static fleet with zero overhead.
    pub control: ControlScript,
    /// Online hill-climbing auto-tuner (see
    /// [`crate::coordinator::autotune`]; arena path + in-order ingest
    /// only). `Some` closes the loop from windowed stall attribution to
    /// live [`KnobChange`](crate::coordinator::fleet::KnobChange)
    /// emissions at quiesce points; mutually exclusive with a
    /// non-empty [`TrainConfig::control`] script (two writers to the
    /// same knobs would race by construction). `None` (default) keeps
    /// every knob static — pinned bitwise identical to pre-controller
    /// behavior by `rust/tests/prop_autotune.rs`.
    pub autotune: Option<AutotuneConfig>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            max_steps: 200,
            loss_every: 10,
            staging_buffers: 2,
            seed: 42,
            ingest: IngestConfig::default(),
            path: DataPath::Arena,
            arena: ArenaConfig::default(),
            transfer: TransferConfig::default(),
            devices: 1,
            route: RoutePolicy::RoundRobin,
            allreduce_every: 1,
            embedding: None,
            trace: false,
            control: ControlScript::default(),
            autotune: None,
        }
    }
}

impl TrainConfig {
    /// Typed shape validation ([`EtlError::Config`]), called at the
    /// entry of every training loop before anything spawns. Catches the
    /// configs that would otherwise fail obscurely mid-run: a zero-wide
    /// fleet, the channel path under multi-device/embedding features, a
    /// credit pool too small to double-buffer, an embedding prefetcher
    /// with no hot tier to promote into, and a malformed
    /// [`ControlScript`].
    pub fn validate(&self) -> Result<()> {
        if self.devices == 0 {
            return Err(EtlError::Config(
                "TrainConfig::devices must be >= 1 (0 is a config bug, not single-device)"
                    .into(),
            ));
        }
        if self.devices > 1 && self.path != DataPath::Arena {
            return Err(EtlError::Config(
                "multi-device training requires DataPath::Arena (per-device staging regions)"
                    .into(),
            ));
        }
        if self.embedding.is_some() && self.path != DataPath::Arena {
            return Err(EtlError::Config(
                "the sharded embedding layer requires DataPath::Arena (its hot tier is pinned \
                 in the device arena)"
                    .into(),
            ));
        }
        if self.path == DataPath::Arena && self.arena.slots < 2 {
            return Err(EtlError::Config(format!(
                "ArenaConfig::slots must be >= 2 for credit-gated double buffering (got {})",
                self.arena.slots
            )));
        }
        if let Some(e) = &self.embedding {
            if e.cache_rows == 0 && e.lookahead > 0 {
                return Err(EtlError::Config(format!(
                    "EmbeddingConfig::cache_rows = 0 cannot host a lookahead of {} (nothing \
                     to prefetch into)",
                    e.lookahead
                )));
            }
        }
        if !self.control.is_empty() && self.path != DataPath::Arena {
            return Err(EtlError::Config(
                "a ControlScript requires DataPath::Arena (the control plane lives in the \
                 fleet router)"
                    .into(),
            ));
        }
        if let Some(at) = &self.autotune {
            at.validate()?;
            if self.path != DataPath::Arena {
                return Err(EtlError::Config(
                    "the auto-tuner requires DataPath::Arena (the controller lives in the \
                     fleet router)"
                        .into(),
                ));
            }
            if self.ingest.policy != DeliveryPolicy::InOrder {
                return Err(EtlError::Config(
                    "the auto-tuner requires DeliveryPolicy::InOrder (its ingest knobs \
                     restart at shard boundaries, and its observation windows are defined \
                     over the in-order step numbering)"
                        .into(),
                ));
            }
            if !self.control.is_empty() {
                return Err(EtlError::Config(
                    "TrainConfig::autotune and a non-empty ControlScript are mutually \
                     exclusive (two writers to the same knobs would race; script the run \
                     or tune it, not both)"
                        .into(),
                ));
            }
        }
        self.control.validate(self.devices, &self.ingest)
    }
}

/// Per-device breakdown of a training run (one entry per simulated GPU;
/// the single-device paths report exactly one).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DeviceReport {
    /// Device index.
    pub device: usize,
    /// Shards routed to and packed on this device's lane.
    pub shards: u64,
    /// Training steps this device's replica executed.
    pub steps: u64,
    /// Host seconds this lane's pack worker spent blocked on device
    /// staging (credit + queue waits).
    pub transfer_wait_s: f64,
    /// Simulated seconds this device's DMA engine spent on the wire.
    pub dma_sim_s: f64,
    /// Packed bytes staged into this device's arena.
    pub staged_bytes: u64,
    /// Host seconds spent stepping this device's replica.
    pub train_busy_s: f64,
    /// Host seconds this device's consumer thread spent blocked on
    /// reduce-epoch resolution (waiting for peers' contributions).
    pub reduce_wait_s: f64,
}

/// Result of a live training run.
#[derive(Debug)]
pub struct TrainReport {
    pub steps: u64,
    /// (step, loss) samples.
    pub losses: Vec<(u64, f32)>,
    /// Wall-clock seconds end to end.
    pub wall_s: f64,
    /// Seconds the trainer was executing steps.
    pub train_busy_s: f64,
    /// Measured GPU(-stand-in) utilization = busy / wall.
    pub util: f64,
    /// Utilization trace per ~20-step window.
    pub util_trace: TimeSeries,
    /// Producer-side backpressure stalls.
    pub producer_stalls: u64,
    /// Host seconds the producer spent in fused apply+pack (exec time,
    /// excluding ingest wait).
    pub etl_host_s: f64,
    /// Host seconds the producer spent blocked waiting on shard ingest
    /// (I/O-wait attribution, disjoint from `etl_host_s`).
    pub ingest_wait_s: f64,
    /// Host seconds the producer spent blocked on device staging —
    /// waiting for a free arena slot (credit) or for staging-queue space;
    /// disjoint from `etl_host_s` and `ingest_wait_s`. 0 on the channel
    /// path (its queue blocking folds into `producer_stalls` only).
    pub transfer_wait_s: f64,
    /// Shards transformed by the producer.
    pub shards: u64,
    /// Simulated FPGA ETL seconds for the same bytes (the paper's clock).
    pub etl_sim_s: f64,
    /// Simulated seconds the P2P DMA engine spent moving packed bytes
    /// (arena path; 0 on the channel path).
    pub dma_sim_s: f64,
    /// Packed bytes staged toward the trainer.
    pub staged_bytes: u64,
    /// Host-side bytes logically copied between pack and training: the
    /// channel path pays one copy per packed byte (batches travel by
    /// value); the arena path pins this to 0 — the zero-copy acceptance
    /// counter.
    pub host_copy_bytes: u64,
    /// Per-shard slot-buffer allocations after each slot's first pack
    /// (arena path; must be 0 in the steady state).
    pub steady_allocs: u64,
    /// Per-device breakdowns, in device order. Each entry covers **this
    /// run only**: the time/byte/shard aggregates above are the sums
    /// across these, and the per-device `steps` sum to the steps this
    /// run executed — `self.steps` is the trainer's *absolute* counter,
    /// so on a warm (resumed) trainer it exceeds that sum by the steps
    /// taken before the run. `util` is the fleet-aggregate figure.
    pub per_device: Vec<DeviceReport>,
    /// Simulated seconds spent in gradient all-reduces (deterministic
    /// tree reduction over the calibrated P2P channel; 0 when devices=1).
    pub allreduce_sim_s: f64,
    /// All-reduce rounds (resolved reduce epochs) performed.
    pub allreduces: u64,
    /// Host seconds consumer threads spent blocked on reduce-epoch
    /// resolution, summed across devices (0 on the single-device paths).
    pub reduce_wait_s: f64,
    /// Device lanes lost mid-run and recovered by the fleet (consumer
    /// lane-loss or a lane's DMA engine hard-failing); the run only
    /// errors when no lane survives.
    pub lanes_lost: u64,
    /// DMA transfer attempts that failed and were re-issued on the same
    /// engine clock (summed across devices).
    pub retried_transfers: u64,
    /// DMA transfers abandoned after exhausting
    /// [`TransferConfig::max_retries`] (each one costs its lane).
    pub failed_transfers: u64,
    /// Scheduled global steps forfeited by lost lanes (tombstoned in the
    /// reduce bus so epochs still resolved); 0 on a fault-free run.
    pub forfeited_steps: u64,
    /// Control-plane changes the router applied mid-run (scripted
    /// [`ControlScript`] events and auto-tuner emissions executed at
    /// quiesce points; 0 for a static fleet or the channel path).
    pub reconfigs: u64,
    /// The full typed control-plane log: every applied change with its
    /// routing frontier and provenance — `cause: None` for scripted
    /// events, the trigger
    /// [`StallCause`](crate::coordinator::autotune::StallCause) for
    /// auto-tuner emissions. `reconfigs` is its length.
    pub knob_log: Vec<AppliedKnob>,
    /// The auto-tuner's windowed report (observation windows, modeled
    /// throughput series, steady-state metric, applied/reverted counts)
    /// when [`TrainConfig::autotune`] was set; `None` otherwise.
    pub autotune: Option<AutotuneReport>,
    /// Embedding lookups served from the hot caches (summed across
    /// lanes; 0 when [`TrainConfig::embedding`] is `None`).
    pub cache_hits: u64,
    /// Embedding lookups that demand-promoted from the cold tier.
    pub cache_misses: u64,
    /// Cross-device embedding traffic: peer-owned row fetches over the
    /// P2P fabric plus embedding-row gradients routed to their owning
    /// shard.
    pub exchange_bytes: u64,
    /// Simulated consumer seconds exposed waiting on embedding
    /// promotions (0 when every prefetch completed in time).
    pub prefetch_wait_s: f64,
    /// Per-lane embedding-cache breakdowns, in device order (empty when
    /// the embedding layer is disabled).
    pub emb: Vec<crate::runtime::embedding::EmbCacheStats>,
    /// The run's full span trace when [`TrainConfig::trace`] was set
    /// (`None` otherwise): export with
    /// [`Trace::to_chrome_json`](crate::trace::Trace::to_chrome_json),
    /// or inspect the raw tracks.
    pub trace: Option<crate::trace::Trace>,
    /// Per-lane stall attribution derived from the trace: every second
    /// of wall time assigned to exactly one cause, with a ledger that
    /// closes (attributed ≡ wall within tolerance). The observation
    /// signal for the self-tuning controller (ROADMAP item 3). `None`
    /// when tracing was off.
    pub stall_attribution: Option<crate::trace::StallAttribution>,
}

impl TrainReport {
    /// First and last observed loss, for convergence checks.
    pub fn loss_delta(&self) -> Option<(f32, f32)> {
        match (self.losses.first(), self.losses.last()) {
            (Some(&(_, a)), Some(&(_, b))) if self.losses.len() >= 2 => Some((a, b)),
            _ => None,
        }
    }
}

/// Run the full loop: `pipeline` transforms shards of `spec`, the packed
/// batches train `trainer`.
pub fn run(
    pipeline: &Pipeline,
    spec: &DatasetSpec,
    trainer: &mut Trainer,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    if !pipeline.is_fitted() && pipeline.plan.dag.stateful_count() > 0 {
        return Err(EtlError::Coord("pipeline must be fitted before training".into()));
    }
    cfg.validate()?;
    if !cfg.trace {
        return dispatch(pipeline, spec, trainer, cfg);
    }
    // Traced run: install the recorder around the whole loop (the
    // installing thread enrolls here; every thread the loop spawns
    // inherits enrollment at its spawn point), then attach the collected
    // trace and its closed stall ledger to the report.
    let guard = trace::install();
    let result = dispatch(pipeline, spec, trainer, cfg);
    let recorded = guard.finish();
    let mut report = result?;
    report.stall_attribution = Some(recorded.stall_attribution());
    report.trace = Some(recorded);
    Ok(report)
}

/// Route a validated config to its data path.
fn dispatch(
    pipeline: &Pipeline,
    spec: &DatasetSpec,
    trainer: &mut Trainer,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    match cfg.path {
        // Every arena run rides the routed-fleet topology — devices = 1
        // is a one-lane fleet (pinned bitwise identical to the legacy
        // single-device path by the reproducibility matrix), and the
        // control plane only exists on this path.
        DataPath::Arena => fleet::run(pipeline, spec, trainer, cfg),
        DataPath::Channel => run_channel(pipeline, spec, trainer, cfg),
    }
}

/// Legacy heap path: pool-recycled `PackedBatch`es travel the staging
/// queue by value (the differential baseline for the zero-copy path).
fn run_channel(
    pipeline: &Pipeline,
    spec: &DatasetSpec,
    trainer: &mut Trainer,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let step_rows = trainer.meta.batch;
    let steps_at_start = trainer.steps;
    let (queue, consumer) = StagingQueue::with_buffers(cfg.staging_buffers);
    let stall_counter = queue.stall_counter();
    // Packed-batch buffers cycle producer → staging → trainer → pool, so
    // the steady state allocates nothing per shard — but each batch still
    // crosses the queue by value (one logical host copy per byte).
    let pool = BufferPool::new();

    let t0 = std::time::Instant::now();
    let mut etl_host_s = 0.0f64;
    let mut etl_sim_s = 0.0f64;
    let mut ingest_wait_s = 0.0f64;
    let mut staged_bytes = 0u64;
    let mut shards_done = 0u64;
    let mut producer_stalls = 0u64;
    let mut losses = Vec::new();
    let mut train_busy_s = 0.0f64;
    let mut host_copy_bytes = 0u64;
    let mut util_trace = TimeSeries::default();

    let fault_token = fault::enroll_token();
    let trace_token = trace::enroll_token();
    std::thread::scope(|scope| -> Result<()> {
        let pool = &pool;
        let ingest_cfg = cfg.ingest.clone();
        let ingest_spec = spec.clone();
        let producer = scope.spawn(move || -> Result<(f64, f64, f64, u64, u64)> {
            fault::enroll(fault_token);
            trace::enroll(trace_token);
            trace::set_thread_label("producer");
            let queue = queue;
            let mut ingest = AsyncIngest::spawn(
                ShardInput::Synth { spec: ingest_spec, seed: cfg.seed },
                &ingest_cfg,
            );
            let mut host_s = 0.0;
            let mut sim_s = 0.0;
            let mut bytes = 0u64;
            let mut shards = 0u64;
            while let Some((_, shard)) = ingest.next()? {
                let mut packed = pool.take();
                let pack_span = trace::begin(tkind::PACK, 0, shards);
                let timing = pipeline.process_packed_into(&shard, &mut packed)?;
                pack_span.end_io(sim_s, sim_s + timing.elapsed_s, packed.bytes(), 0);
                ingest.recycle(shard);
                host_s += timing.host_s;
                sim_s += timing.elapsed_s;
                bytes += packed.bytes();
                shards += 1;
                if !queue.push(packed) {
                    // Consumer hung up (reached max_steps).
                    break;
                }
            }
            Ok((host_s, sim_s, ingest.wait_seconds(), bytes, shards))
        });

        // Consumer: the trainer steps on borrowed chunk views (the
        // incomplete tail of each staged batch is dropped, matching
        // DLRM's fixed batch shapes).
        trace::set_thread_label("consumer-0");
        let mut window_busy = 0.0f64;
        let mut window_start = 0.0f64;
        const WINDOW_STEPS: u64 = 20;
        'consume: while trainer.steps < cfg.max_steps as u64 {
            let Some(batch) = consumer.pop() else { break };
            host_copy_bytes += batch.bytes();
            for view in batch.chunk_views(step_rows) {
                if trainer.steps >= cfg.max_steps as u64 {
                    break;
                }
                let ts = std::time::Instant::now();
                let step_span = trace::begin(tkind::TRAIN_STEP, 0, trainer.steps);
                trainer.step_view(&view)?;
                step_span.end();
                let dt = ts.elapsed().as_secs_f64();
                train_busy_s += dt;
                window_busy += dt;
                if trainer.steps % (cfg.loss_every as u64).max(1) == 0 {
                    losses.push((trainer.steps, trainer.loss()?));
                }
                if trainer.steps % WINDOW_STEPS == 0 {
                    let now = t0.elapsed().as_secs_f64();
                    let span = (now - window_start).max(1e-9);
                    util_trace.push(now, (window_busy / span).min(1.0));
                    window_busy = 0.0;
                    window_start = now;
                }
            }
            // Return the drained buffer for reuse.
            pool.put(batch);
            if trainer.steps >= cfg.max_steps as u64 {
                break 'consume;
            }
        }
        // Drain/close: dropping the consumer unblocks a blocked producer.
        drop(consumer);
        match producer.join() {
            Ok(Ok((h, s, w, bytes, n))) => {
                etl_host_s = h;
                etl_sim_s = s;
                ingest_wait_s = w;
                staged_bytes = bytes;
                shards_done = n;
            }
            Ok(Err(e)) => return Err(e),
            Err(_) => return Err(EtlError::Coord("producer panicked".into())),
        }
        producer_stalls = stall_counter.load(std::sync::atomic::Ordering::Relaxed);
        Ok(())
    })?;

    let wall_s = t0.elapsed().as_secs_f64();
    Ok(TrainReport {
        steps: trainer.steps,
        losses,
        wall_s,
        train_busy_s,
        util: train_busy_s / wall_s.max(1e-9),
        util_trace,
        producer_stalls,
        etl_host_s,
        ingest_wait_s,
        transfer_wait_s: 0.0,
        shards: shards_done,
        etl_sim_s,
        dma_sim_s: 0.0,
        staged_bytes,
        host_copy_bytes,
        steady_allocs: 0,
        per_device: vec![DeviceReport {
            device: 0,
            shards: shards_done,
            steps: trainer.steps - steps_at_start,
            transfer_wait_s: 0.0,
            dma_sim_s: 0.0,
            staged_bytes,
            train_busy_s,
            reduce_wait_s: 0.0,
        }],
        allreduce_sim_s: 0.0,
        allreduces: 0,
        reduce_wait_s: 0.0,
        lanes_lost: 0,
        retried_transfers: 0,
        failed_transfers: 0,
        forfeited_steps: 0,
        reconfigs: 0,
        knob_log: Vec::new(),
        autotune: None,
        cache_hits: 0,
        cache_misses: 0,
        exchange_bytes: 0,
        prefetch_wait_s: 0.0,
        emb: Vec::new(),
        trace: None,
        stall_attribution: None,
    })
}

#[cfg(test)]
mod tests {
    // Live-loop tests require compiled artifacts; they run in the
    // integration suite (rust/tests/integration_runtime.rs). The
    // ingest/exec/transfer time-attribution split and the arena-vs-
    // channel bit-identity are asserted in
    // rust/tests/integration_coordinator.rs against the artifact-free
    // reference trainer.

    #[test]
    fn default_config_is_sane() {
        let cfg = super::TrainConfig::default();
        assert!(cfg.max_steps > 0 && cfg.staging_buffers >= 2);
        assert!(cfg.ingest.workers >= 1 && cfg.ingest.channel_depth >= 1);
        // The zero-copy arena path is the shipping default, with enough
        // slots for double buffering on both sides of the queue.
        assert_eq!(cfg.path, super::DataPath::Arena);
        assert!(cfg.arena.slots >= cfg.staging_buffers + 2);
        assert!(cfg.transfer.chunk_bytes >= 1 << 20, "MiB-scale DMA chunks");
        // Multi-device defaults: single GPU, bit-reproducible routing,
        // sync-every-step all-reduce.
        assert_eq!(cfg.devices, 1);
        assert_eq!(cfg.route, crate::coordinator::scheduler::RoutePolicy::RoundRobin);
        assert_eq!(cfg.allreduce_every, 1);
    }

    #[test]
    fn multi_device_rejects_channel_path() {
        use crate::dataio::dataset::DatasetSpec;
        use crate::etl::pipelines::{build, PipelineKind};
        use crate::planner::{compile, PlannerConfig};
        use crate::runtime::artifacts::{ModelMeta, ParamSpec};

        let spec = DatasetSpec::dataset_i(0.001);
        let dag = build(PipelineKind::I, &spec.schema);
        let plan = compile(&dag, &spec.schema, &PlannerConfig::default()).unwrap();
        let mut pipe = crate::fpga::Pipeline::new(plan);
        pipe.fit(&spec.shard(0, 1)).unwrap();
        let meta = ModelMeta {
            batch: 64,
            n_dense: 13,
            n_sparse: 26,
            vocab: 64,
            embed_dim: 1,
            params: vec![
                ParamSpec { name: "w_dense".into(), dims: vec![13] },
                ParamSpec { name: "b".into(), dims: vec![1] },
                ParamSpec { name: "emb".into(), dims: vec![26 * 8] },
            ],
            extra: Default::default(),
        };
        let mut trainer = crate::runtime::Trainer::from_meta(meta, 1);
        let cfg = super::TrainConfig {
            devices: 2,
            path: super::DataPath::Channel,
            ..Default::default()
        };
        let err = super::run(&pipe, &spec, &mut trainer, &cfg).unwrap_err();
        assert!(err.to_string().contains("DataPath::Arena"), "{err}");

        // devices == 0 is a config bug, not an implicit single device.
        let cfg = super::TrainConfig { devices: 0, ..Default::default() };
        let err = super::run(&pipe, &spec, &mut trainer, &cfg).unwrap_err();
        assert!(err.to_string().contains("devices must be >= 1"), "{err}");
    }

    #[test]
    fn validate_returns_typed_config_errors() {
        use crate::error::EtlError;

        // The happy default passes.
        assert!(super::TrainConfig::default().validate().is_ok());

        let cfg = super::TrainConfig { devices: 0, ..Default::default() };
        match cfg.validate().unwrap_err() {
            EtlError::Config(msg) => assert!(msg.contains("devices must be >= 1"), "{msg}"),
            other => panic!("expected EtlError::Config, got {other:?}"),
        }

        let mut cfg = super::TrainConfig::default();
        cfg.arena.slots = 1;
        match cfg.validate().unwrap_err() {
            EtlError::Config(msg) => assert!(msg.contains("slots"), "{msg}"),
            other => panic!("expected EtlError::Config, got {other:?}"),
        }

        let mut cfg = super::TrainConfig::default();
        cfg.embedding = Some(crate::runtime::embedding::EmbeddingConfig {
            cache_rows: 0,
            lookahead: 2,
            ..Default::default()
        });
        match cfg.validate().unwrap_err() {
            EtlError::Config(msg) => assert!(msg.contains("cache_rows"), "{msg}"),
            other => panic!("expected EtlError::Config, got {other:?}"),
        }

        // Malformed control scripts are config errors too.
        let mut cfg = super::TrainConfig::default();
        cfg.control = crate::coordinator::fleet::ControlScript {
            events: vec![
                crate::coordinator::fleet::ControlEvent {
                    at_step: 9,
                    change: crate::coordinator::fleet::KnobChange::AddLane,
                },
                crate::coordinator::fleet::ControlEvent {
                    at_step: 3,
                    change: crate::coordinator::fleet::KnobChange::AddLane,
                },
            ],
        };
        match cfg.validate().unwrap_err() {
            EtlError::Config(msg) => assert!(msg.contains("sorted"), "{msg}"),
            other => panic!("expected EtlError::Config, got {other:?}"),
        }

        // The auto-tuner composes with the arena path + in-order ingest
        // only, and never alongside a user script.
        let cfg = super::TrainConfig {
            autotune: Some(crate::coordinator::autotune::AutotuneConfig::default()),
            ..Default::default()
        };
        assert!(cfg.validate().is_ok());

        let mut cfg = super::TrainConfig {
            autotune: Some(crate::coordinator::autotune::AutotuneConfig::default()),
            ..Default::default()
        };
        cfg.ingest.policy = crate::dataio::ingest::DeliveryPolicy::FreshestFirst;
        match cfg.validate().unwrap_err() {
            EtlError::Config(msg) => assert!(msg.contains("InOrder"), "{msg}"),
            other => panic!("expected EtlError::Config, got {other:?}"),
        }

        let mut cfg = super::TrainConfig {
            autotune: Some(crate::coordinator::autotune::AutotuneConfig::default()),
            ..Default::default()
        };
        cfg.control = crate::coordinator::fleet::ControlScript {
            events: vec![crate::coordinator::fleet::ControlEvent {
                at_step: 3,
                change: crate::coordinator::fleet::KnobChange::Lookahead(2),
            }],
        };
        match cfg.validate().unwrap_err() {
            EtlError::Config(msg) => assert!(msg.contains("mutually"), "{msg}"),
            other => panic!("expected EtlError::Config, got {other:?}"),
        }

        let bad_window = super::TrainConfig {
            autotune: Some(crate::coordinator::autotune::AutotuneConfig {
                window: 0,
                ..Default::default()
            }),
            ..Default::default()
        };
        assert!(bad_window.validate().is_err());
    }
}
