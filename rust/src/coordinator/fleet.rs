//! Elastic fleet runtime: lane lifecycle plus a live control plane over
//! the multi-device arena dataflow.
//!
//! This module is the **fleet driver** behind every
//! [`DataPath::Arena`](crate::coordinator::train_loop::DataPath) run —
//! `devices = 1` is simply a one-lane fleet (pinned bitwise identical to
//! the legacy single-device path by the reproducibility matrix in
//! `train_loop`'s docs). It decomposes the old monolithic `run_multi`
//! into three pieces:
//!
//! 1. **[`Lane`]** — the per-device bundle: a raw-shard channel into a
//!    pack worker, that worker's [`DeviceArena`](crate::devmem::DeviceArena)
//!    region and private DMA engine clock, the staged-slot queue into the
//!    lane's consumer thread, and the consumer's trainer replica.
//! 2. **[`FleetRuntime`]** — assembly: it sizes every shared structure
//!    (the [`ArenaSet`], [`DeviceRouter`], [`ReduceBus`],
//!    [`TransferSet`]) to the fleet's **peak** width (initial `devices`
//!    plus every scripted [`KnobChange::AddLane`]) so a joining lane
//!    never reallocates shared state mid-run, then hands `run` the lane
//!    bundles to spawn.
//! 3. **The control plane** — the router thread doubles as the live
//!    controller: it applies a deterministic [`ControlScript`] of
//!    `(global_step, KnobChange)` events at **quiesce points** and logs
//!    each application in a [`KnobRegistry`].
//!
//! # Lane lifecycle
//!
//! ```text
//!            AddLane applied                 RemoveLane applied
//!  Joining ────────────────────▶ Live ────────────────────────▶ Draining
//!     │   (router.mark_alive,      │    (sender taken, queued       │
//!     │    LANE_JOIN span)         │     slots still train)         │
//!     │                            │ fault (DMA hard-fail /         │
//!     │                            │ LANE_LOSS injection)           ▼
//!     └────────── fleet ends ──────┴──────────────────────────▶  Dead
//! ```
//!
//! * **Joining**: assembled but masked from routing. Its worker blocks on
//!   its shard channel; its consumer blocks on its slot queue. Its
//!   reduce-bus membership is registered at assembly
//!   ([`ReduceBus::join`]) so release thresholds are stable for the
//!   whole run.
//! * **Live**: routed shards, training, posting gradient contributions.
//! * **Draining**: gracefully removed — the router took its shard
//!   sender, so no new work arrives; already-queued slots still train
//!   (their steps were stamped before the quiesce point), then the
//!   consumer folds the remaining epochs and exits as a valid survivor.
//!   Unlike a fault death, nothing is forfeited and `lanes_lost` does
//!   not move.
//! * **Dead**: a fault took the lane (its remaining steps were
//!   forfeited, the router re-routes to survivors) or the run ended.
//!
//! # Quiesce points
//!
//! Every scripted change applies on the **router thread**, between two
//! shard routings, at the first routing frontier `cum >= at_step`
//! (`cum` = run-relative global steps stamped so far):
//!
//! ```text
//!   route(shard k)   ──▶  [apply events with at_step <= cum]  ──▶  route(shard k+1)
//!                            │
//!                            ├─ Route(p)          router.set_policy     (next shard on)
//!                            ├─ AllreduceEvery(n) bus.retune_every      (next epoch boundary on)
//!                            ├─ AddLane           router.mark_alive     (joiner eligible now)
//!                            ├─ RemoveLane(d)     sender taken          (lane drains)
//!                            ├─ Lookahead(n)      queued to every lane  (shards with start_rel >= frontier)
//!                            └─ IngestWorkers/ChunkRows                 (restart at next shard boundary)
//! ```
//!
//! Events at the **same `at_step`** apply in stable event-index order
//! (the order they appear in [`ControlScript::events`]); two events at
//! the same step targeting the *same knob* are rejected by validation,
//! so a script's effect at any frontier is unambiguous.
//!
//! No shard spans an application, so a script is a pure function of the
//! delivery-order step numbering — scripted runs stay **bitwise
//! identical under schedule fuzzing** (`rust/tests/prop_elastic.rs`).
//! The two ingest knobs are the only deferred ones: the old pipeline
//! finishes its current shard, its first delivery past that boundary is
//! discarded (chunk-stable synth regenerates it identically), and a
//! replacement spawns via [`AsyncIngest::spawn_from`].
//!
//! The same quiesce machinery serves the **online auto-tuner**
//! ([`crate::coordinator::autotune`], `TrainConfig::autotune`): the
//! router closes an observation window every W routed steps, hands it to
//! the hill-climbing controller, and applies whatever [`KnobChange`] it
//! emits through [`apply_knob_change`] — the same code path a scripted
//! event takes, logged in the same [`KnobRegistry`] (with its trigger
//! [`StallCause`](crate::coordinator::autotune::StallCause)).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::{Arc, Mutex};

use crate::coordinator::autotune::{
    AppliedKnob, AutotuneReport, ClimberInit, HillClimber, ObsLedger, SlotObs, StallCause,
};
use crate::coordinator::scheduler::{
    DeviceRouter, EpochWait, PrefetchPipeline, ReduceBus, RoutePolicy,
};
use crate::coordinator::staging::{StagingConsumer, StagingQueue};
use crate::coordinator::train_loop::{DeviceReport, TrainConfig, TrainReport};
use crate::dataio::dataset::DatasetSpec;
use crate::dataio::ingest::{AsyncIngest, DeliveryPolicy, IngestConfig, ShardInput};
use crate::devmem::{ArenaSet, StagingSlot, TransferEngine, TransferSet};
use crate::error::{EtlError, Result};
use crate::etl::column::Batch;
use crate::fpga::Pipeline;
use crate::memsys::{ChannelModel, Path};
use crate::metrics::TimeSeries;
use crate::runtime::Trainer;
use crate::trace::{self, kind as tkind};
use crate::util::fault::{self, site as fsite};
use crate::util::sched::{self, site};

/// One mid-run control-plane change.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KnobChange {
    /// Switch the shard→device routing policy.
    Route(RoutePolicy),
    /// Retune the all-reduce period ([`ReduceBus::retune_every`]); takes
    /// effect at the next epoch boundary at or past the frontier.
    AllreduceEvery(usize),
    /// Restart the ingest pipeline with this many workers (in-order
    /// delivery only; applied at the next shard boundary).
    IngestWorkers(usize),
    /// Restart the ingest pipeline with this chunking granularity
    /// (rows per delivered chunk, 0 = whole shards; in-order only).
    ChunkRows(usize),
    /// Retune every lane's embedding-prefetch lookahead window
    /// (no-op when the embedding layer is disabled).
    Lookahead(usize),
    /// Admit the next pre-assembled joiner lane to the fleet.
    AddLane,
    /// Gracefully drain lane `d` (an initial-fleet lane index).
    RemoveLane(usize),
}

impl KnobChange {
    /// Stable short name (registry/debug output).
    pub fn name(&self) -> &'static str {
        match self {
            KnobChange::Route(_) => "route",
            KnobChange::AllreduceEvery(_) => "allreduce_every",
            KnobChange::IngestWorkers(_) => "ingest_workers",
            KnobChange::ChunkRows(_) => "chunk_rows",
            KnobChange::Lookahead(_) => "lookahead",
            KnobChange::AddLane => "add_lane",
            KnobChange::RemoveLane(_) => "remove_lane",
        }
    }
}

/// A scripted change: applied at the first quiesce point where the
/// routing frontier has reached `at_step` (run-relative global steps).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ControlEvent {
    /// Run-relative global step threshold.
    pub at_step: u64,
    /// The change to apply.
    pub change: KnobChange,
}

/// A deterministic schedule of control-plane changes, sorted by
/// `at_step`. Empty (the default) means a static fleet — the script adds
/// zero overhead to an unscripted run.
///
/// **Tie-break**: events sharing an `at_step` apply in **stable
/// event-index order** — the order they appear in `events`. That makes
/// the applied sequence a pure function of the script. Two same-step
/// events that touch the *same knob* would make the winner an authoring
/// accident rather than a decision, so validation rejects them
/// ([`EtlError::Config`]); the two exceptions follow the knobs'
/// semantics — repeated `AddLane` events admit distinct joiners (never
/// duplicates), and `RemoveLane` only conflicts with a removal of the
/// *same* lane.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ControlScript {
    /// The events, sorted ascending by [`ControlEvent::at_step`]
    /// (ties apply in stable event-index order; duplicate same-step
    /// same-knob pairs are rejected by [`ControlScript::validate`]).
    pub events: Vec<ControlEvent>,
}

impl ControlScript {
    /// No scripted changes?
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Scripted lane additions (the fleet's peak width is
    /// `devices + add_lanes()`).
    pub fn add_lanes(&self) -> usize {
        self.events.iter().filter(|e| matches!(e.change, KnobChange::AddLane)).count()
    }

    /// Two same-step events conflict when applying both cannot be
    /// order-independent: same knob, except that `AddLane`s admit
    /// distinct joiners and `RemoveLane`s only clash on the same lane.
    fn conflicts(a: KnobChange, b: KnobChange) -> bool {
        match (a, b) {
            (KnobChange::AddLane, KnobChange::AddLane) => false,
            (KnobChange::RemoveLane(x), KnobChange::RemoveLane(y)) => x == y,
            _ => a.name() == b.name(),
        }
    }

    /// Typed validation against the run's shape: events must be sorted,
    /// same-step events must not touch the same knob twice (the
    /// tie-break is stable event-index order — see the struct docs),
    /// ingest restarts need in-order delivery, lane removals must target
    /// the initial fleet.
    pub fn validate(&self, devices: usize, ingest: &IngestConfig) -> Result<()> {
        let mut last = 0u64;
        for (i, ev) in self.events.iter().enumerate() {
            if ev.at_step < last {
                return Err(EtlError::Config(format!(
                    "ControlScript events must be sorted by at_step \
                     (event {i} at step {} follows step {last})",
                    ev.at_step
                )));
            }
            last = ev.at_step;
            for (j, prev) in self.events[..i].iter().enumerate() {
                if prev.at_step == ev.at_step && Self::conflicts(prev.change, ev.change) {
                    return Err(EtlError::Config(format!(
                        "ControlScript: events {j} and {i} both touch knob \
                         '{}' at step {} — same-step events apply in event-index \
                         order, so a same-knob pair is ambiguous by construction",
                        ev.change.name(),
                        ev.at_step
                    )));
                }
            }
            match ev.change {
                KnobChange::IngestWorkers(0) => {
                    return Err(EtlError::Config(
                        "ControlScript: IngestWorkers(0) — the ingest pipeline needs at \
                         least one worker"
                            .into(),
                    ))
                }
                KnobChange::IngestWorkers(_) | KnobChange::ChunkRows(_)
                    if ingest.policy != DeliveryPolicy::InOrder =>
                {
                    return Err(EtlError::Config(
                        "ControlScript ingest knobs (IngestWorkers/ChunkRows) require \
                         DeliveryPolicy::InOrder (the restart cursor is a shard boundary)"
                            .into(),
                    ))
                }
                KnobChange::RemoveLane(d) if d >= devices => {
                    return Err(EtlError::Config(format!(
                        "ControlScript: RemoveLane({d}) targets a lane outside the initial \
                         fleet (devices = {devices}; scripted joiners cannot be removed)"
                    )))
                }
                _ => {}
            }
        }
        Ok(())
    }
}

/// Log of the control-plane changes a run actually applied, in
/// application order; [`TrainReport::reconfigs`] is its length. Scripted
/// and controller-emitted changes land in the same registry — the cause
/// column (`None` for scripted events, the trigger
/// [`StallCause`] for auto-tuner emissions) is the only difference.
#[derive(Debug, Default)]
pub struct KnobRegistry {
    applied: Vec<(u64, KnobChange)>,
    causes: Vec<Option<StallCause>>,
}

impl KnobRegistry {
    fn record(&mut self, frontier: u64, change: KnobChange) {
        self.record_caused(frontier, change, None);
    }

    fn record_caused(&mut self, frontier: u64, change: KnobChange, cause: Option<StallCause>) {
        self.applied.push((frontier, change));
        self.causes.push(cause);
    }

    /// Applied changes as `(routing frontier at application, change)`.
    pub fn applied(&self) -> &[(u64, KnobChange)] {
        &self.applied
    }

    /// The full typed log, each change with its provenance.
    pub fn log(&self) -> Vec<AppliedKnob> {
        self.applied
            .iter()
            .zip(&self.causes)
            .map(|(&(at_step, change), &cause)| AppliedKnob { at_step, change, cause })
            .collect()
    }

    /// Number of applied changes.
    pub fn reconfigs(&self) -> u64 {
        self.applied.len() as u64
    }
}

/// Lifecycle of a fleet lane (see the module-level state machine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum LaneState {
    /// Assembled, masked from routing, awaiting a scripted `AddLane`.
    Joining = 0,
    /// Routed shards, training.
    Live = 1,
    /// Gracefully removed: no new shards, queued work still trains.
    Draining = 2,
    /// Lost to a fault, or finished draining.
    Dead = 3,
}

/// Shared, atomically-updated lane state (router, workers and consumers
/// all transition it).
struct LaneStateCell(AtomicU8);

impl LaneStateCell {
    fn new(s: LaneState) -> LaneStateCell {
        LaneStateCell(AtomicU8::new(s as u8))
    }

    fn set(&self, s: LaneState) {
        self.0.store(s as u8, Ordering::SeqCst);
    }

    fn get(&self) -> LaneState {
        match self.0.load(Ordering::SeqCst) {
            0 => LaneState::Joining,
            1 => LaneState::Live,
            2 => LaneState::Draining,
            _ => LaneState::Dead,
        }
    }
}

/// A staged slot annotated with its schedule position: the raw shard
/// bytes charged to its lane's load ledger and the **run-relative global
/// step index of its first trainer chunk** (the router stamps every slot
/// in delivery order, so reduce epochs are schedule-independent — no
/// consumer-side reordering stash is needed; each lane's queue is already
/// FIFO in delivery order).
struct RoutedSlot {
    start_rel: u64,
    /// Trainer chunks the router predicted for this slot (from the raw
    /// shard's rows). The consumer verifies the packed batch yields
    /// exactly this many — a mismatch would corrupt the global step
    /// numbering and deadlock the bus, so it aborts loudly instead.
    chunks: u64,
    raw_bytes: u64,
    slot: StagingSlot,
}

/// Per-lane producer accounting returned by each pack worker.
#[derive(Default)]
struct LaneOut {
    host_s: f64,
    sim_s: f64,
    wait_s: f64,
    shards: u64,
    dma_busy_s: f64,
    dma_bytes: u64,
    dma_retried: u64,
    dma_failed: u64,
    /// This lane's embedding-cache observables (None when the embedding
    /// layer is disabled).
    emb: Option<crate::runtime::embedding::EmbCacheStats>,
}

/// One executed step's record kept by a consumer thread: merged across
/// devices (in global-step order) into the fleet's losses, utilization
/// trace and busy-time attribution.
struct StepRec {
    /// Absolute global step index (delivery order, warm-start offset).
    g_abs: u64,
    /// Wall-clock seconds since run start when the step finished.
    end_s: f64,
    /// Host seconds the step took.
    busy_s: f64,
    /// The step's batch loss (the loss-slot observable).
    loss: f32,
}

/// Per-device consumer accounting returned by each consumer thread.
#[derive(Default)]
struct ConsumerOut {
    recs: Vec<StepRec>,
    reduce_wait_s: f64,
    /// This lane was lost mid-run (its replica's state is stale — the
    /// fleet's final parameters come from a surviving lane).
    lost: bool,
}

/// Aborts the reduce bus if the owning thread unwinds by panic, so
/// sibling consumers blocked on an epoch observe the failure instead of
/// waiting forever.
struct BusAbortOnPanic<'a>(&'a ReduceBus);

impl Drop for BusAbortOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.abort();
        }
    }
}

/// Outcome of folding one reduce epoch into a replica.
enum Fold {
    /// An epoch was applied; the replica's synced base advanced.
    Applied,
    /// No further epochs will arrive (stream finished or run aborted).
    Done,
}

/// Wait for `device`'s next reduce epoch and replay it onto the synced
/// `base` (device-ascending contributions; see `Trainer::apply_reduced`).
/// Fast path: when this device was the epoch's **sole** contributor, its
/// replica already holds exactly `base` + its own steps — bitwise what
/// the replay would rebuild (pinned by the grad/apply differential
/// tests) — so only the base refresh is needed; the sync-every-step
/// default takes this path on every contributing device. Time blocked on
/// resolution is charged to `reduce_wait_s`. Shared by the consumer's
/// mid-step dependency fold and its end-of-lane drain.
fn fold_next_epoch(
    bus: &ReduceBus,
    device: usize,
    replica: &mut Trainer,
    base: &mut [f32],
    applied: &mut u64,
    reduce_wait_s: &mut f64,
) -> Result<Fold> {
    let t_wait = std::time::Instant::now();
    // Covers both the wait for resolution and the replay itself.
    let span = trace::begin(tkind::REDUCE_APPLY, device as u32, *applied);
    match bus.wait_epoch(*applied) {
        EpochWait::Resolved(ep) => {
            *reduce_wait_s += t_wait.elapsed().as_secs_f64();
            let self_only = ep.contribs.len() == 1 && ep.contribs[0].device == device;
            if !self_only {
                replica.apply_reduced(base, ep.contribs.iter().map(|c| c.steps.as_slice()))?;
            }
            base.copy_from_slice(replica.state());
            *applied += 1;
            span.end();
            Ok(Fold::Applied)
        }
        EpochWait::Finished | EpochWait::Aborted => {
            drop(span); // records the terminal wait too
            Ok(Fold::Done)
        }
    }
}

/// Pending `(frontier, lookahead)` retunes queued to a lane by the
/// control plane (scripted or auto-tuned); the lane's pack worker pops
/// entries whose frontier its slot stream has reached.
type LookaheadQueue = Arc<Mutex<VecDeque<(u64, usize)>>>;

/// The per-device bundle [`FleetRuntime::assemble`] builds and `run`
/// splits across the lane's pack-worker and consumer threads.
struct Lane {
    device: usize,
    /// Router → pack worker raw-shard channel (depth 1: the router hands
    /// a lane its next shard while it packs the current one).
    shard_rx: Receiver<(u64, Batch)>,
    /// Pack worker's producer end of the staged-slot queue.
    slot_queue: StagingQueue<RoutedSlot>,
    /// Consumer's end of the staged-slot queue.
    slot_rx: StagingConsumer<RoutedSlot>,
    stall_counter: Arc<AtomicU64>,
    /// This lane's private DMA engine clock.
    dma: TransferEngine,
    /// This lane's embedding prefetcher (None when disabled).
    prefetch: Option<PrefetchPipeline>,
    /// This lane's trainer replica.
    replica: Trainer,
    /// Control-plane `(frontier, lookahead)` retunes, applied by the
    /// worker to shards with `start_rel >= frontier`. The router pushes
    /// at quiesce points (see [`apply_knob_change`]); the worker pops.
    lookahead: LookaheadQueue,
}

/// Everything the fleet driver owns before threads spawn: shared
/// structures sized to the peak lane count, plus the per-lane bundles.
struct FleetRuntime {
    peak: usize,
    arenas: ArenaSet,
    router: DeviceRouter,
    bus: ReduceBus,
    lanes: Vec<Lane>,
    /// Router → lane senders; `RemoveLane` takes one to drain the lane.
    shard_txs: Vec<Option<SyncSender<(u64, Batch)>>>,
    states: Vec<LaneStateCell>,
    /// Pre-assembled joiner device indices, in `AddLane` event order.
    joiners: VecDeque<usize>,
    /// Router-side handles to every lane's lookahead retune queue.
    lookaheads: Vec<LookaheadQueue>,
    /// Simulated cost of one all-reduce epoch at peak width.
    allreduce_cost_s: f64,
}

impl FleetRuntime {
    /// Build every shared structure and lane bundle at the fleet's peak
    /// width. Joiner lanes are fully assembled here — arena region, DMA
    /// clock, queues, replica, reduce-bus membership — and only their
    /// routing admission is deferred to the scripted quiesce point, so
    /// lane-add is a pure mask flip with nothing left to allocate.
    fn assemble(trainer: &Trainer, cfg: &TrainConfig) -> Result<FleetRuntime> {
        let devices = cfg.devices;
        let peak = devices + cfg.control.add_lanes();

        let mut arenas = ArenaSet::new(devices, cfg.arena.clone());
        for _ in devices..peak {
            arenas.grow(cfg.arena.clone());
        }

        let mut router = DeviceRouter::with_capacity(devices, peak, cfg.route);
        let mut joiners = VecDeque::with_capacity(peak - devices);
        for _ in devices..peak {
            joiners.push_back(router.extend());
        }

        // Reduce-bus membership is peak-wide from step 0: joiners
        // register at assembly (nothing has resolved yet, so `join(0)`
        // cannot race a released epoch) and serve their epochs when they
        // fold — at admission, or in their end-of-lane drain.
        let bus = ReduceBus::new(devices, cfg.allreduce_every, trainer.steps);
        for d in devices..peak {
            let joined = bus.join(0)?;
            debug_assert_eq!(joined, d);
        }

        let mut transfers = TransferSet::new(devices, cfg.transfer.clone());
        for _ in devices..peak {
            transfers.grow(cfg.transfer.clone());
        }
        let engines = transfers.into_engines();

        // Sharded embedding layer: one shard cache per lane (joiners
        // included — their hot tiers are seeded now and serve peer
        // fetches from assembly on), its hot tier pinned in that lane's
        // arena, its prefetcher driven by the lane's own delivery order.
        // Built before the fleet spawns so a sizing error fails cleanly.
        let prefetchers: Vec<Option<PrefetchPipeline>> = match &cfg.embedding {
            Some(ecfg) => {
                use crate::runtime::embedding::{EmbShardCache, EmbeddingTable};
                let table = EmbeddingTable::from_meta(&trainer.meta, peak, ecfg.policy)?;
                let cache_rows = ecfg.cache_rows.min(table.rows()).max(1);
                (0..peak)
                    .map(|d| {
                        let region = arenas
                            .device(d)
                            .reserve_cache(cache_rows as u64 * table.row_bytes())?;
                        let mut cache = EmbShardCache::new(table.clone(), cache_rows, region)?;
                        cache.seed(&ecfg.hot_seed, &|_| true);
                        Ok(Some(PrefetchPipeline::new(cache, ecfg.lookahead)))
                    })
                    .collect::<Result<Vec<_>>>()?
            }
            None => (0..peak).map(|_| None).collect(),
        };

        // All-reduce cost model: a deterministic tree needs ceil(log2 N)
        // rounds of reduce plus as many of broadcast, each moving the
        // flat state over the calibrated P2P channel, once per epoch.
        let allreduce_chan = ChannelModel::of(Path::P2pToGpu);
        let reduce_rounds = (usize::BITS - (peak - 1).leading_zeros()) as f64;
        let state_bytes = (trainer.meta.state_len() * std::mem::size_of::<f32>()) as u64;
        let allreduce_cost_s = 2.0 * reduce_rounds * allreduce_chan.time(state_bytes);

        let mut shard_txs = Vec::with_capacity(peak);
        let mut lanes = Vec::with_capacity(peak);
        let mut lookaheads = Vec::with_capacity(peak);
        for (d, (dma, prefetch)) in engines.into_iter().zip(prefetchers).enumerate() {
            let (tx, shard_rx) = std::sync::mpsc::sync_channel::<(u64, Batch)>(1);
            shard_txs.push(Some(tx));
            let (slot_queue, slot_rx) = StagingQueue::<RoutedSlot>::with_buffers(cfg.staging_buffers);
            let stall_counter = slot_queue.stall_counter();
            let lookahead: LookaheadQueue = Arc::default();
            lookaheads.push(Arc::clone(&lookahead));
            lanes.push(Lane {
                device: d,
                shard_rx,
                slot_queue,
                slot_rx,
                stall_counter,
                dma,
                prefetch,
                replica: trainer.replica(),
                lookahead,
            });
        }

        let states = (0..peak)
            .map(|d| {
                LaneStateCell::new(if d < devices { LaneState::Live } else { LaneState::Joining })
            })
            .collect();

        Ok(FleetRuntime {
            peak,
            arenas,
            router,
            bus,
            lanes,
            shard_txs,
            states,
            joiners,
            lookaheads,
            allreduce_cost_s,
        })
    }
}

/// Apply one control-plane change at a quiesce point, on the router
/// thread. This is the **single actuation path**: scripted
/// [`ControlEvent`]s and auto-tuner emissions both land here, so a
/// controller decision is byte-for-byte the change a hand-written
/// script would have made. `cum` is the routing frontier (run-relative
/// steps stamped so far); `idx` is the shard index currently in hand
/// (the ingest-restart boundary).
#[allow(clippy::too_many_arguments)]
fn apply_knob_change(
    change: KnobChange,
    cum: u64,
    idx: usize,
    router: &mut DeviceRouter,
    bus: &ReduceBus,
    states: &[LaneStateCell],
    shard_txs: &mut [Option<SyncSender<(u64, Batch)>>],
    joiners: &mut VecDeque<usize>,
    eff_ingest: &mut IngestConfig,
    restart_after: &mut Option<usize>,
    lookaheads: &[LookaheadQueue],
) {
    match change {
        KnobChange::Route(p) => router.set_policy(p),
        KnobChange::AllreduceEvery(v) => bus.retune_every(cum, v),
        KnobChange::Lookahead(n) => {
            // Queue to every lane: each slot stream is in start_rel
            // order per lane, so the worker applying at its first shard
            // at/past the frontier is that lane's quiesce point. Every
            // shard routed before this call has start_rel < cum, so the
            // retune touches exactly the shards a pre-dealt
            // `(at_step, n)` event would have (the frontier is the
            // first at/past the scripted step).
            for q in lookaheads {
                q.lock().unwrap_or_else(|p| p.into_inner()).push_back((cum, n));
            }
        }
        KnobChange::IngestWorkers(n) => {
            eff_ingest.workers = n;
            *restart_after = Some(idx);
        }
        KnobChange::ChunkRows(n) => {
            eff_ingest.chunk_rows = n;
            *restart_after = Some(idx);
        }
        KnobChange::AddLane => {
            let d = joiners
                .pop_front()
                .expect("validated: one joiner per AddLane event");
            debug_assert_eq!(states[d].get(), LaneState::Joining);
            sched::point(site::LANE_JOIN);
            let span = trace::begin(tkind::LANE_JOIN, d as u32, cum);
            router.mark_alive(d);
            states[d].set(LaneState::Live);
            span.end();
        }
        KnobChange::RemoveLane(d) => {
            // Taking the sender is the drain trigger: the lane's worker
            // exits once its queued shards are packed, its consumer
            // trains them (all stamped pre-quiesce), then folds to the
            // end as a valid survivor.
            if shard_txs[d].take().is_some() {
                let span = trace::begin(tkind::LANE_DRAIN, d as u32, cum);
                router.mark_dead(d);
                states[d].set(LaneState::Draining);
                span.end();
            }
        }
    }
}

/// Fleet driver for every arena-path run: one staging region, DMA clock,
/// pack worker **and consumer thread** per lane; the router assigns each
/// ingested shard a lane and stamps its global step range; replicas step
/// concurrently and stay consistent through the barrier-free
/// gradient-level [`ReduceBus`]; the scripted control plane reconfigures
/// the fleet at quiesce points (see module docs).
pub(crate) fn run(
    pipeline: &Pipeline,
    spec: &DatasetSpec,
    trainer: &mut Trainer,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    cfg.validate()?;
    let step_rows = trainer.meta.batch;
    let steps_at_start = trainer.steps;
    let max_steps = cfg.max_steps as u64;
    let loss_every = (cfg.loss_every as u64).max(1);

    let FleetRuntime { peak, arenas, router, bus, lanes: lane_bundles, shard_txs, states, joiners, lookaheads, allreduce_cost_s } =
        FleetRuntime::assemble(trainer, cfg)?;
    let tracker = router.tracker();

    // Online auto-tuner: a shared router↔worker observation ledger plus
    // the hill-climbing controller the router thread will drive at its
    // window boundaries. Every observation is sim-clock, so the
    // controller's decisions replay bitwise (see `autotune` module docs).
    let tuner: Option<(Arc<ObsLedger>, HillClimber)> = cfg.autotune.map(|at| {
        let init = ClimberInit {
            route_round_robin: cfg.route == RoutePolicy::RoundRobin,
            workers: cfg.ingest.workers,
            chunk_rows: cfg.ingest.chunk_rows,
            rows_per_shard: spec.rows_per_shard(),
            lookahead: cfg.embedding.as_ref().map(|e| e.lookahead).unwrap_or(0),
            embedding: cfg.embedding.is_some(),
            allreduce_every: cfg.allreduce_every,
            arena_slots: cfg.arena.slots,
            ssd_bound: spec.ssd_bound,
            allreduce_cost_s,
            step_rows,
            n_dense: trainer.meta.n_dense,
            n_sparse: trainer.meta.n_sparse,
            embed_dim: trainer.meta.embed_dim,
        };
        (Arc::new(ObsLedger::new()), HillClimber::new(at, init))
    });
    let obs_handle: Option<Arc<ObsLedger>> = tuner.as_ref().map(|(o, _)| Arc::clone(o));
    let mut autotune_report: Option<AutotuneReport> = None;

    // Consumed shard buffers flow back to the router for pool recycling.
    let (recycle_tx, recycle_rx) = std::sync::mpsc::channel::<Batch>();

    let t0 = std::time::Instant::now();
    let mut lanes: Vec<LaneOut> = Vec::with_capacity(peak);
    let mut cons: Vec<(Trainer, ConsumerOut)> = Vec::with_capacity(peak);
    let mut ingest_wait_s = 0.0f64;
    let mut registry = KnobRegistry::default();
    let mut stall_counters = Vec::with_capacity(peak);

    // Lane liveness, shared across the router, pack workers and
    // consumers: a dying side flips its lane's flag (the swap makes the
    // loss counted exactly once even if both ends of a lane fail) and
    // the router re-routes every not-yet-assigned shard to survivors.
    // Joiners start alive here — the *routing* mask, not this flag, is
    // what holds them back until admission.
    let lane_alive: Vec<AtomicBool> = (0..peak).map(|_| AtomicBool::new(true)).collect();
    let lanes_lost = AtomicU64::new(0);
    // Run-relative step cap: forfeited ranges are clamped to it, exactly
    // as consumers skip chunks past it, so the bus's closed total is the
    // same set of steps whether a lane lived or died.
    let cap_rel = max_steps.saturating_sub(steps_at_start);
    let fault_token = fault::enroll_token();
    let trace_token = trace::enroll_token();

    std::thread::scope(|scope| -> Result<()> {
        let arenas = &arenas;
        let bus = &bus;
        let lane_alive = &lane_alive;
        let lanes_lost = &lanes_lost;
        let states = &states;
        let mut first_err: Option<EtlError> = None;

        // Split each lane bundle into its worker half and consumer half.
        let mut worker_parts = Vec::with_capacity(peak);
        let mut consumer_parts = Vec::with_capacity(peak);
        for lane in lane_bundles {
            let Lane {
                device,
                shard_rx,
                slot_queue,
                slot_rx,
                stall_counter,
                dma,
                prefetch,
                replica,
                lookahead,
            } = lane;
            stall_counters.push(stall_counter);
            worker_parts.push((device, shard_rx, slot_queue, dma, prefetch, lookahead));
            consumer_parts.push((device, slot_rx, replica));
        }

        // Pack workers: one per lane, each owning its device's DMA
        // engine clock and blocking only on its own arena's credits.
        let mut workers = Vec::with_capacity(peak);
        for (d, rx, queue, mut dma, mut prefetch, la_queue) in worker_parts {
            let recycle_tx = recycle_tx.clone();
            let worker_tracker = Arc::clone(&tracker);
            let obs = obs_handle.clone();
            workers.push(scope.spawn(move || -> Result<LaneOut> {
                fault::enroll(fault_token);
                trace::enroll(trace_token);
                trace::set_thread_label(&format!("pack-{d}"));
                let _abort_on_panic = BusAbortOnPanic(bus);
                let arena = arenas.device(d);
                let mut out = LaneOut::default();
                let mut failure: Option<EtlError> = None;
                let mut dead = false;
                let mut last_stage_s = 0.0f64;
                while let Ok((start_rel, shard)) = rx.recv() {
                    // Control-plane lookahead retunes: the slot stream
                    // is in start_rel order per lane, so applying at the
                    // first shard at/past the queued frontier is this
                    // lane's quiesce point.
                    {
                        let mut q = la_queue.lock().unwrap_or_else(|p| p.into_inner());
                        while q.front().is_some_and(|&(at, _)| start_rel >= at) {
                            let (_, n) = q.pop_front().expect("front checked");
                            if let Some(pf) = prefetch.as_mut() {
                                pf.set_lookahead(n);
                            }
                        }
                    }
                    let raw_bytes = shard.total_bytes() as u64;
                    // Same formula the router stamped the schedule with;
                    // the consumer verifies the packed batch agrees.
                    let chunks = (shard.rows() / step_rows) as u64;
                    if dead {
                        // Lane lost: these shards can no longer reach a
                        // trainer. Forfeit their scheduled steps so reduce
                        // epochs still resolve, settle the load ledger,
                        // recycle the buffer, and keep draining until the
                        // router (which re-routes to survivors) stops.
                        let lo = start_rel.min(cap_rel);
                        let hi = (start_rel + chunks).min(cap_rel);
                        if lo < hi {
                            bus.forfeit(lo..hi);
                        }
                        if chunks > 0 {
                            if let Some(o) = obs.as_deref() {
                                o.forfeit_slot(start_rel);
                            }
                        }
                        worker_tracker.complete(d, raw_bytes);
                        let _ = recycle_tx.send(shard);
                        continue;
                    }
                    let t_acq = std::time::Instant::now();
                    let acq_span = trace::begin(tkind::SLOT_ACQUIRE, d as u32, out.shards);
                    let Some(mut slot) = arena.acquire() else {
                        break; // fleet shut down (arena closed)
                    };
                    acq_span.end();
                    out.wait_s += t_acq.elapsed().as_secs_f64();
                    let pack_span = trace::begin(tkind::PACK, d as u32, out.shards);
                    let timing = match pipeline.process_into_slot(&shard, &mut slot) {
                        Ok(t) => t,
                        Err(e) => {
                            failure = Some(e);
                            let _ = arena.release(slot);
                            break;
                        }
                    };
                    pack_span.end_io(
                        out.sim_s,
                        out.sim_s + timing.elapsed_s,
                        slot.packed_bytes(),
                        0,
                    );
                    let _ = recycle_tx.send(shard);
                    out.host_s += timing.host_s;
                    out.sim_s += timing.elapsed_s;
                    out.shards += 1;
                    // This lane's chunked P2P write, on this device's own
                    // engine clock. A hard failure (past the retry budget)
                    // costs the lane, not the fleet: forfeit this slot's
                    // steps, return its credit, and fall into drain mode.
                    match dma.submit(out.sim_s, slot.packed_bytes()) {
                        Ok(rec) => {
                            // Auto-tuner observation: the slot's sim-clock
                            // pack time and DMA wire time (queueing
                            // excluded — the controller's model rebuilds
                            // queueing from its own clocks).
                            if chunks > 0 {
                                if let Some(o) = obs.as_deref() {
                                    o.complete_slot(
                                        start_rel,
                                        timing.elapsed_s,
                                        rec.done_s - rec.start_s,
                                    );
                                }
                            }
                            // Prefetch planning: the router saw this shard
                            // before its consumer will, so the lane can
                            // promote the slot's embedding rows `lookahead`
                            // slots ahead of its commit. Only the chunks
                            // the consumer will actually step are traced;
                            // a lane whose consumer died forfeits its
                            // slots, so planning stops with it.
                            if let Some(pf) = prefetch.as_mut() {
                                let stepped = chunks.min(cap_rel.saturating_sub(start_rel));
                                if stepped > 0 && lane_alive[d].load(Ordering::SeqCst) {
                                    pf.on_packed(
                                        &slot.batch().sparse,
                                        stepped as usize * step_rows,
                                        rec.done_s,
                                        &|o: usize| lane_alive[o].load(Ordering::SeqCst),
                                    );
                                }
                                last_stage_s = rec.done_s;
                            }
                        }
                        Err(e) if e.is_fault() => {
                            if lane_alive[d].swap(false, Ordering::SeqCst) {
                                lanes_lost.fetch_add(1, Ordering::SeqCst);
                            }
                            states[d].set(LaneState::Dead);
                            let lo = start_rel.min(cap_rel);
                            let hi = (start_rel + chunks).min(cap_rel);
                            if lo < hi {
                                bus.forfeit(lo..hi);
                            }
                            if chunks > 0 {
                                if let Some(o) = obs.as_deref() {
                                    o.forfeit_slot(start_rel);
                                }
                            }
                            worker_tracker.complete(d, raw_bytes);
                            let _ = arena.release(slot);
                            dead = true;
                            continue;
                        }
                        Err(e) => {
                            failure = Some(e);
                            let _ = arena.release(slot);
                            break;
                        }
                    }
                    let t_push = std::time::Instant::now();
                    let pushed = queue.push(RoutedSlot { start_rel, chunks, raw_bytes, slot });
                    out.wait_s += t_push.elapsed().as_secs_f64();
                    if !pushed {
                        break; // consumer hung up
                    }
                }
                out.dma_busy_s = dma.busy_s();
                out.dma_bytes = dma.total_bytes();
                out.dma_retried = dma.retried_transfers();
                out.dma_failed = dma.failed_transfers();
                if let Some(mut pf) = prefetch.take() {
                    // Drain the lookahead window: every slot that was
                    // prefetch-planned commits exactly once, so the
                    // hit/miss ledger covers every lookup the consumer
                    // performed (exactly-once accounting).
                    pf.flush(last_stage_s, &|o: usize| lane_alive[o].load(Ordering::SeqCst));
                    out.emb = Some(pf.into_stats());
                }
                match failure {
                    Some(e) => {
                        // Unblock peers waiting on this lane's steps.
                        bus.abort();
                        Err(e)
                    }
                    None => Ok(out),
                }
            }));
        }
        // Workers now hold the only recycle producer handles.
        drop(recycle_tx);

        // Router + control plane: the producer front-end — ingest in
        // delivery order, apply scripted knob changes whose step the
        // routing frontier has reached, assign each shard a device lane,
        // stamp it with the global step index of its first chunk (epochs
        // are defined over this delivery-order numbering, independent of
        // thread schedules), recycle consumed buffers, and close the bus
        // with the stream's total step count on the way out.
        let ingest_cfg = cfg.ingest.clone();
        let ingest_spec = spec.clone();
        let seed = cfg.seed;
        let script = cfg.control.events.clone();
        let router_thread = scope.spawn(move || -> Result<(f64, KnobRegistry, Option<AutotuneReport>)> {
            fault::enroll(fault_token);
            trace::enroll(trace_token);
            trace::set_thread_label("router");
            let _abort_on_panic = BusAbortOnPanic(bus);
            let mut shard_txs = shard_txs;
            let mut router = router;
            let mut joiners = joiners;
            let lookaheads = lookaheads;
            let mut registry = KnobRegistry::default();
            let mut tuner = tuner;
            // Next observation-window index the tuner will close.
            let mut win_idx = 0u64;
            let mut eff_ingest = ingest_cfg;
            let mut ingest = AsyncIngest::spawn(
                ShardInput::Synth { spec: ingest_spec.clone(), seed },
                &eff_ingest,
            );
            let mut wait_s = 0.0f64;
            let mut cum = 0u64; // run-relative global steps scheduled so far
            let mut last_dead = 0usize;
            let mut next_ev = 0usize;
            // Pending ingest restart: shard index the old pipeline must
            // finish before the retuned replacement takes over.
            let mut restart_after: Option<usize> = None;
            let routed = (|| -> Result<()> {
                loop {
                    let Some((idx, shard)) = ingest.next()? else { break };
                    while let Ok(b) = recycle_rx.try_recv() {
                        ingest.recycle(b);
                    }
                    if let Some(boundary) = restart_after {
                        if idx > boundary {
                            // Quiesce point reached: shard `boundary`
                            // routed fully. This delivery is the retuned
                            // pipeline's first (chunk-stable synth
                            // regenerates it bitwise), so discard it and
                            // swap pipelines.
                            ingest.recycle(shard);
                            wait_s += ingest.wait_seconds();
                            ingest = AsyncIngest::spawn_from(
                                ShardInput::Synth { spec: ingest_spec.clone(), seed },
                                &eff_ingest,
                                idx,
                            );
                            restart_after = None;
                            continue;
                        }
                    }
                    if steps_at_start + cum >= max_steps || bus.is_aborted() {
                        // Nothing past the cap (or past an abort) will
                        // ever be stepped; stop routing instead of
                        // packing dead shards.
                        ingest.recycle(shard);
                        break;
                    }
                    // Control plane: apply every scripted change whose
                    // step the routing frontier has reached, between two
                    // shard routings (the quiesce point). Same-step
                    // events apply in stable event-index order.
                    while next_ev < script.len() && script[next_ev].at_step <= cum {
                        let ev = script[next_ev];
                        next_ev += 1;
                        sched::point(site::KNOB_APPLY);
                        apply_knob_change(
                            ev.change,
                            cum,
                            idx,
                            &mut router,
                            bus,
                            states,
                            &mut shard_txs,
                            &mut joiners,
                            &mut eff_ingest,
                            &mut restart_after,
                            &lookaheads,
                        );
                        registry.record(cum, ev.change);
                    }
                    // Auto-tuner: close every observation window the
                    // frontier has fully routed, fold it into the
                    // controller, and actuate its decision through the
                    // exact path a scripted event takes. The wait is
                    // deadlock-free — every step of the window is
                    // already routed and lanes drain independently of
                    // the router — and bounded by the abort probe.
                    if let Some((obs, climber)) = tuner.as_mut() {
                        let w = climber.window_steps();
                        while cum >= (win_idx + 1) * w {
                            let hi = (win_idx + 1) * w;
                            if !obs.wait_through(hi, || bus.is_aborted()) {
                                break;
                            }
                            let slots = obs.take_below(hi);
                            if let Some((change, cause)) =
                                climber.observe_window(win_idx, &slots, true)
                            {
                                sched::point(site::KNOB_APPLY);
                                apply_knob_change(
                                    change,
                                    cum,
                                    idx,
                                    &mut router,
                                    bus,
                                    states,
                                    &mut shard_txs,
                                    &mut joiners,
                                    &mut eff_ingest,
                                    &mut restart_after,
                                    &lookaheads,
                                );
                                registry.record_caused(cum, change, Some(cause));
                            }
                            win_idx += 1;
                        }
                    }
                    // Sync lane losses into the routing mask: the dead
                    // lane's remaining shards land on survivors instead.
                    for dd in 0..shard_txs.len() {
                        if router.is_alive(dd) && !lane_alive[dd].load(Ordering::SeqCst) {
                            router.mark_dead(dd);
                            states[dd].set(LaneState::Dead);
                            last_dead = dd;
                        }
                    }
                    if router.alive_count() == 0 {
                        // No lane left to absorb the stream: this is the
                        // unrecoverable failure domain.
                        ingest.recycle(shard);
                        return Err(EtlError::LaneLost { device: last_dead, survivors: 0 });
                    }
                    let chunks = (shard.rows() / step_rows) as u64;
                    let raw_bytes = shard.total_bytes() as u64;
                    let d = router.route(raw_bytes);
                    // Post the slot's schedule identity before the send
                    // so the worker's completion always finds it. The
                    // straggler flag is a pure plan query — it consumes
                    // no fault attempts. Zero-chunk slots advance no
                    // step and are never posted.
                    if chunks > 0 {
                        if let Some((obs, _)) = tuner.as_ref() {
                            obs.note_route(SlotObs {
                                start_rel: cum,
                                chunks,
                                lane: d as u32,
                                raw_bytes,
                                straggler: fault::afflicted(fsite::SLOW_SHARD, idx as u64),
                                pack_sim_s: 0.0,
                                dma_sim_s: 0.0,
                                forfeited: false,
                            });
                        }
                    }
                    let tx = shard_txs[d]
                        .as_ref()
                        .expect("router only routes to lanes whose sender it still holds");
                    if tx.send((cum, shard)).is_err() {
                        break; // lane worker exited (fleet shut down)
                    }
                    cum += chunks;
                }
                Ok(())
            })();
            match routed {
                Ok(()) => {
                    // The last routed slot may cross the cap; consumers
                    // skip its excess chunks, so the stream total is the
                    // capped count.
                    bus.close(cum.min(max_steps.saturating_sub(steps_at_start)));
                    wait_s += ingest.wait_seconds();
                    // Passively fold the tail windows (the last may be
                    // partial) into the controller's report: routing is
                    // over, so nothing is actuated, but the report
                    // covers the whole run and the steady-state metric
                    // reflects the converged configuration.
                    let report = tuner.map(|(obs, mut climber)| {
                        let w = climber.window_steps();
                        while win_idx * w < cum {
                            let hi = ((win_idx + 1) * w).min(cum);
                            if !obs.wait_through(hi, || bus.is_aborted()) {
                                break;
                            }
                            let slots = obs.take_below(hi);
                            climber.observe_window(win_idx, &slots, false);
                            win_idx += 1;
                        }
                        climber.finish()
                    });
                    Ok((wait_s, registry, report))
                }
                Err(e) => {
                    bus.abort();
                    Err(e)
                }
            }
        });

        // Consumer threads: one per lane. Each steps its own replica in
        // place on its lane's staged slots (local SGD), posts one
        // gradient contribution per step, and applies resolved reduce
        // epochs onto its synced base before stepping into the next
        // window — the only cross-device synchronization is the bus. A
        // joiner's consumer simply blocks on its (empty) queue until the
        // lane is admitted; its first fold syncs the replica through
        // every epoch its first step depends on.
        let mut consumers = Vec::with_capacity(peak);
        for (d, rx, mut replica) in consumer_parts {
            let tracker = Arc::clone(&tracker);
            consumers.push(scope.spawn(move || -> Result<(Trainer, ConsumerOut)> {
                fault::enroll(fault_token);
                trace::enroll(trace_token);
                trace::set_thread_label(&format!("consumer-{d}"));
                let _abort_on_panic = BusAbortOnPanic(bus);
                let mut out = ConsumerOut::default();
                let mut base = replica.state_to_vec()?;
                let mut applied = 0u64; // reduce epochs folded so far
                let mut stepping = true;
                let mut failure: Option<EtlError> = None;
                while let Some(RoutedSlot { start_rel, chunks, raw_bytes, slot }) = rx.pop() {
                    sched::point(site::LANE_HANDOFF);
                    if !out.lost && failure.is_none() && fault::inject(fsite::LANE_LOSS, d as u64)
                    {
                        // Injected lane loss: this device is gone. Leave
                        // the reduce group so peers stop waiting on this
                        // replica's fetches, mark the lane dead for the
                        // router, and fall into drain mode — every
                        // remaining slot's steps are forfeited below so
                        // reduce epochs still resolve for survivors.
                        out.lost = true;
                        if lane_alive[d].swap(false, Ordering::SeqCst) {
                            lanes_lost.fetch_add(1, Ordering::SeqCst);
                        }
                        states[d].set(LaneState::Dead);
                        bus.leave(applied);
                    }
                    if out.lost {
                        if failure.is_none() {
                            let lo = start_rel.min(cap_rel);
                            let hi = (start_rel + chunks).min(cap_rel);
                            if lo < hi {
                                bus.forfeit(lo..hi);
                            }
                        }
                    } else if stepping && failure.is_none() {
                        let views = slot.chunk_views(step_rows);
                        if views.len() as u64 != chunks {
                            // A row-dropping pipeline would corrupt the
                            // schedule's step numbering and deadlock the
                            // bus — fail loudly instead.
                            bus.abort();
                            failure = Some(EtlError::Coord(format!(
                                "packed slot yields {} chunks but the router scheduled {} \
                                 (pipeline did not preserve rows)",
                                views.len(),
                                chunks
                            )));
                        }
                        for (c, view) in views.iter().enumerate() {
                            if failure.is_some() {
                                break;
                            }
                            let rel = start_rel + c as u64;
                            let g_abs = steps_at_start + rel;
                            if g_abs >= max_steps {
                                break;
                            }
                            // Fold every epoch this step depends on.
                            let need = bus.epochs_before(g_abs);
                            while applied < need && failure.is_none() {
                                match fold_next_epoch(
                                    bus,
                                    d,
                                    &mut replica,
                                    &mut base,
                                    &mut applied,
                                    &mut out.reduce_wait_s,
                                ) {
                                    Ok(Fold::Applied) => {}
                                    Ok(Fold::Done) => {
                                        stepping = false;
                                        break;
                                    }
                                    Err(e) => {
                                        bus.abort();
                                        failure = Some(e);
                                    }
                                }
                            }
                            if !stepping || failure.is_some() {
                                break;
                            }
                            let ts = std::time::Instant::now();
                            let step_span = trace::begin(tkind::TRAIN_STEP, d as u32, g_abs);
                            match replica.grad_step(view) {
                                Ok(grad) => {
                                    step_span.end();
                                    out.recs.push(StepRec {
                                        g_abs,
                                        end_s: t0.elapsed().as_secs_f64(),
                                        busy_s: ts.elapsed().as_secs_f64(),
                                        loss: grad.loss as f32,
                                    });
                                    let post_span =
                                        trace::begin(tkind::REDUCE_POST, d as u32, rel);
                                    let posted = bus.post(rel, d, grad);
                                    post_span.end();
                                    if let Err(e) = posted {
                                        // Pending-window cap blown (the
                                        // allreduce_every=0 footgun):
                                        // abort rather than buffer
                                        // gradients without bound.
                                        bus.abort();
                                        failure = Some(e);
                                    }
                                }
                                Err(e) => {
                                    bus.abort();
                                    failure = Some(e);
                                }
                            }
                        }
                    }
                    // Credit + ledger return happen on the consumer
                    // thread even when the slot's chunks were skipped
                    // (max_steps cut or failure drain) — exactly once.
                    tracker.complete(d, raw_bytes);
                    if let Err(e) = arenas.device(d).release(slot) {
                        if failure.is_none() {
                            bus.abort();
                            failure = Some(e);
                        }
                    }
                }
                // Lane closed: fold the remaining epochs so this replica
                // lands on the final reduced state even though peers may
                // still be stepping — this is what makes a drained
                // (gracefully removed) lane and a never-admitted joiner
                // valid survivors. A lost lane already left the reduce
                // group — fetching again would double-count its serves —
                // so it skips the drain and exits with stale state.
                while !out.lost && failure.is_none() {
                    match fold_next_epoch(
                        bus,
                        d,
                        &mut replica,
                        &mut base,
                        &mut applied,
                        &mut out.reduce_wait_s,
                    ) {
                        Ok(Fold::Applied) => {}
                        Ok(Fold::Done) => break,
                        Err(e) => {
                            bus.abort();
                            failure = Some(e);
                        }
                    }
                }
                if states[d].get() == LaneState::Draining {
                    states[d].set(LaneState::Dead);
                }
                match failure {
                    Some(e) => Err(e),
                    None => Ok((replica, out)),
                }
            }));
        }

        // Join consumers first: they exit once the router closed the bus
        // and their lanes drained. Only then close the arenas (waking any
        // worker still blocked on a credit after an abnormal consumer
        // exit) and collect the producer side.
        for handle in consumers {
            match handle.join() {
                Ok(Ok(pair)) => cons.push(pair),
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => {
                    first_err =
                        first_err.or_else(|| Some(EtlError::Coord("consumer panicked".into())))
                }
            }
        }
        arenas.close_all();
        for handle in workers {
            match handle.join() {
                Ok(Ok(out)) => lanes.push(out),
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => {
                    first_err =
                        first_err.or_else(|| Some(EtlError::Coord("pack worker panicked".into())))
                }
            }
        }
        match router_thread.join() {
            Ok(Ok((w, reg, rep))) => {
                ingest_wait_s = w;
                registry = reg;
                autotune_report = rep;
            }
            Ok(Err(e)) => first_err = first_err.or(Some(e)),
            Err(_) => {
                first_err = first_err.or_else(|| Some(EtlError::Coord("router panicked".into())))
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    })?;

    // Every surviving replica drained the bus to the last resolved
    // epoch, so the survivors are bitwise identical; the fleet
    // parameters land back in the caller's trainer from the first one.
    // Lost lanes' replicas are stale (they left the reduce group) and
    // never source the final state; a fleet with no survivor at all is
    // the unrecoverable outcome.
    let total_steps: u64 = cons.iter().map(|(_, o)| o.recs.len() as u64).sum();
    if lanes_lost.load(Ordering::SeqCst) >= peak as u64 {
        let device = (0..peak)
            .rev()
            .find(|&dd| !lane_alive[dd].load(Ordering::SeqCst))
            .unwrap_or(0);
        return Err(EtlError::LaneLost { device, survivors: 0 });
    }
    let survivor = cons
        .iter()
        .position(|(_, o)| !o.lost)
        .expect("a lane neither worker- nor consumer-lost has a live replica");
    trainer.load_state(cons[survivor].0.state())?;
    trainer.steps = steps_at_start + total_steps;
    let allreduces = bus.resolved_count();
    let allreduce_sim_s = allreduces as f64 * allreduce_cost_s;

    // Merge the per-consumer step records into the fleet's observables,
    // in global-step (delivery) order.
    let mut dev_busy = vec![0.0f64; peak];
    let mut merged: Vec<(u64, f64, f64, f32)> = Vec::with_capacity(total_steps as usize);
    for (d, (_, out)) in cons.iter().enumerate() {
        for r in &out.recs {
            dev_busy[d] += r.busy_s;
            merged.push((r.g_abs, r.end_s, r.busy_s, r.loss));
        }
    }
    merged.sort_unstable_by_key(|r| r.0);
    let mut losses = Vec::new();
    for &(g, _, _, loss) in &merged {
        if (g + 1) % loss_every == 0 {
            losses.push((g + 1, loss));
        }
    }
    // The trace wants execution (wall-clock completion) order — with
    // concurrent consumers that is not global-step order.
    let mut step_records: Vec<(f64, f64)> = merged.iter().map(|r| (r.1, r.2)).collect();
    step_records.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
    let util_trace = TimeSeries::from_step_records(&step_records, 20);
    let train_busy_s: f64 = dev_busy.iter().sum();
    let reduce_wait_s: f64 = cons.iter().map(|(_, o)| o.reduce_wait_s).sum();
    let producer_stalls = stall_counters
        .iter()
        .map(|c| c.load(Ordering::Relaxed))
        .sum::<u64>()
        + arenas.total_stats().stalls;

    let per_device: Vec<DeviceReport> = (0..peak)
        .map(|d| DeviceReport {
            device: d,
            shards: lanes[d].shards,
            steps: cons[d].0.steps,
            transfer_wait_s: lanes[d].wait_s,
            dma_sim_s: lanes[d].dma_busy_s,
            staged_bytes: lanes[d].dma_bytes,
            train_busy_s: dev_busy[d],
            reduce_wait_s: cons[d].1.reduce_wait_s,
        })
        .collect();
    let wall_s = t0.elapsed().as_secs_f64();
    // Per-lane cache stats roll up into the fleet-level counters; the
    // per-shard vector keeps device attribution for the bench/report.
    let emb: Vec<crate::runtime::embedding::EmbCacheStats> =
        lanes.iter().filter_map(|l| l.emb).collect();
    Ok(TrainReport {
        steps: steps_at_start + total_steps,
        losses,
        wall_s,
        train_busy_s,
        util: (train_busy_s / wall_s.max(1e-9)).min(1.0),
        util_trace,
        producer_stalls,
        etl_host_s: lanes.iter().map(|l| l.host_s).sum(),
        ingest_wait_s,
        transfer_wait_s: lanes.iter().map(|l| l.wait_s).sum(),
        shards: lanes.iter().map(|l| l.shards).sum(),
        etl_sim_s: lanes.iter().map(|l| l.sim_s).sum(),
        dma_sim_s: lanes.iter().map(|l| l.dma_busy_s).sum(),
        staged_bytes: lanes.iter().map(|l| l.dma_bytes).sum(),
        host_copy_bytes: 0,
        steady_allocs: arenas.total_stats().steady_allocs,
        per_device,
        allreduce_sim_s,
        allreduces,
        reduce_wait_s,
        lanes_lost: lanes_lost.load(Ordering::SeqCst),
        retried_transfers: lanes.iter().map(|l| l.dma_retried).sum(),
        failed_transfers: lanes.iter().map(|l| l.dma_failed).sum(),
        forfeited_steps: bus.forfeited_count(),
        reconfigs: registry.reconfigs(),
        knob_log: registry.log(),
        autotune: autotune_report,
        cache_hits: emb.iter().map(|e| e.hits).sum(),
        cache_misses: emb.iter().map(|e| e.misses).sum(),
        exchange_bytes: emb.iter().map(|e| e.exchange_bytes).sum(),
        prefetch_wait_s: emb.iter().map(|e| e.prefetch_wait_s).sum(),
        emb,
        trace: None,
        stall_attribution: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn in_order() -> IngestConfig {
        IngestConfig::default()
    }

    #[test]
    fn control_script_validation_catches_shape_bugs() {
        let ok = ControlScript {
            events: vec![
                ControlEvent { at_step: 2, change: KnobChange::AddLane },
                ControlEvent { at_step: 2, change: KnobChange::Route(RoutePolicy::LeastLoaded) },
                ControlEvent { at_step: 5, change: KnobChange::RemoveLane(0) },
            ],
        };
        assert!(ok.validate(2, &in_order()).is_ok());
        assert_eq!(ok.add_lanes(), 1);

        let unsorted = ControlScript {
            events: vec![
                ControlEvent { at_step: 5, change: KnobChange::AddLane },
                ControlEvent { at_step: 2, change: KnobChange::AddLane },
            ],
        };
        let err = unsorted.validate(2, &in_order()).unwrap_err();
        assert!(err.to_string().contains("sorted"), "{err}");

        let zero_workers = ControlScript {
            events: vec![ControlEvent { at_step: 1, change: KnobChange::IngestWorkers(0) }],
        };
        assert!(zero_workers.validate(2, &in_order()).is_err());

        let mut fresh = in_order();
        fresh.policy = DeliveryPolicy::FreshestFirst;
        let ingest_knob = ControlScript {
            events: vec![ControlEvent { at_step: 1, change: KnobChange::ChunkRows(32) }],
        };
        let err = ingest_knob.validate(2, &fresh).unwrap_err();
        assert!(err.to_string().contains("InOrder"), "{err}");

        let bad_remove = ControlScript {
            events: vec![ControlEvent { at_step: 1, change: KnobChange::RemoveLane(2) }],
        };
        let err = bad_remove.validate(2, &in_order()).unwrap_err();
        assert!(err.to_string().contains("RemoveLane(2)"), "{err}");
    }

    #[test]
    fn control_script_rejects_same_step_same_knob_pairs() {
        // Same step, same knob: ambiguous under the event-index
        // tie-break, so validation rejects with a typed Config error.
        let dup = ControlScript {
            events: vec![
                ControlEvent { at_step: 4, change: KnobChange::Lookahead(2) },
                ControlEvent { at_step: 4, change: KnobChange::Lookahead(6) },
            ],
        };
        let err = dup.validate(2, &in_order()).unwrap_err();
        assert!(matches!(err, EtlError::Config(_)));
        assert!(err.to_string().contains("lookahead"), "{err}");

        // Same step, different knobs: fine (applies in event order).
        let mixed = ControlScript {
            events: vec![
                ControlEvent { at_step: 4, change: KnobChange::Lookahead(2) },
                ControlEvent { at_step: 4, change: KnobChange::IngestWorkers(2) },
            ],
        };
        assert!(mixed.validate(2, &in_order()).is_ok());

        // Repeated AddLane at one step admits distinct joiners: allowed.
        let grow2 = ControlScript {
            events: vec![
                ControlEvent { at_step: 4, change: KnobChange::AddLane },
                ControlEvent { at_step: 4, change: KnobChange::AddLane },
            ],
        };
        assert!(grow2.validate(2, &in_order()).is_ok());

        // RemoveLane clashes only on the same lane index.
        let shrink2 = ControlScript {
            events: vec![
                ControlEvent { at_step: 4, change: KnobChange::RemoveLane(0) },
                ControlEvent { at_step: 4, change: KnobChange::RemoveLane(1) },
            ],
        };
        assert!(shrink2.validate(3, &in_order()).is_ok());
        let shrink_dup = ControlScript {
            events: vec![
                ControlEvent { at_step: 4, change: KnobChange::RemoveLane(1) },
                ControlEvent { at_step: 4, change: KnobChange::RemoveLane(1) },
            ],
        };
        assert!(shrink_dup.validate(3, &in_order()).is_err());
    }

    #[test]
    fn knob_registry_counts_applications_in_order() {
        let mut reg = KnobRegistry::default();
        assert_eq!(reg.reconfigs(), 0);
        reg.record(3, KnobChange::AddLane);
        reg.record(7, KnobChange::Route(RoutePolicy::RoundRobin));
        assert_eq!(reg.reconfigs(), 2);
        assert_eq!(reg.applied()[0], (3, KnobChange::AddLane));
        assert_eq!(reg.applied()[1].1.name(), "route");
        // Controller-emitted changes carry their trigger cause through
        // the same registry; scripted ones stay cause-less.
        reg.record_caused(9, KnobChange::IngestWorkers(4), Some(StallCause::Ingest));
        let log = reg.log();
        assert_eq!(log.len(), 3);
        assert_eq!(log[0].cause, None);
        assert_eq!(
            log[2],
            AppliedKnob {
                at_step: 9,
                change: KnobChange::IngestWorkers(4),
                cause: Some(StallCause::Ingest),
            }
        );
    }

    #[test]
    fn lane_state_cell_round_trips_every_state() {
        let cell = LaneStateCell::new(LaneState::Joining);
        assert_eq!(cell.get(), LaneState::Joining);
        for s in [LaneState::Live, LaneState::Draining, LaneState::Dead] {
            cell.set(s);
            assert_eq!(cell.get(), s);
        }
    }
}
