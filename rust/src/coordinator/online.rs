//! Online / continuous-training support (paper §2.1): recommender
//! datasets grow continuously and drift; PipeRec's fit/apply split must
//! therefore handle *dynamic vocabularies* ("dynamic vocabulary tables are
//! frequently updated with new data", §3.2.2) and surface *data drift* so
//! the control plane can trigger refits or model refreshes.
//!
//! This module provides the L3 pieces the paper's online deployment needs:
//!
//! * [`OnlineVocab`] — a bounded, continuously-updated vocabulary: new
//!   tokens are admitted in first-appearance order until `capacity`, then
//!   mapped to the shared OOV index; tracks admission/OOV rates so the
//!   control plane can size tables (and decide BRAM↔HBM promotion).
//! * [`DriftDetector`] — streaming population-stability monitoring over
//!   sparse-feature histograms (PSI), flagging distribution shift.
//! * [`FreshnessTracker`] — time-to-freshness accounting: the latency
//!   between an event's ingest and the training step that consumed it
//!   (the paper's "time-to-freshness for online models").

use crate::etl::ops::vocab::VocabTable;

/// A continuously-updated, capacity-bounded vocabulary.
#[derive(Debug)]
pub struct OnlineVocab {
    table: VocabTable,
    capacity: usize,
    /// Tokens admitted since the last [`reset_stats`](Self::reset_stats).
    pub admitted: u64,
    /// Lookups that hit an existing entry since the last reset.
    pub hits: u64,
    /// Lookups rejected to OOV (table full) since the last reset.
    pub oov: u64,
}

impl OnlineVocab {
    pub fn new(capacity: usize) -> OnlineVocab {
        OnlineVocab {
            table: VocabTable::with_capacity(capacity),
            capacity,
            admitted: 0,
            hits: 0,
            oov: 0,
        }
    }

    /// Index for the out-of-vocabulary bucket (one past the last slot).
    pub fn oov_index(&self) -> i64 {
        self.capacity as i64
    }

    /// Map a token, admitting it if the table still has room.
    pub fn map(&mut self, token: i64) -> i64 {
        if let Some(idx) = self.table.get(token) {
            self.hits += 1;
            return idx as i64;
        }
        if self.table.len() < self.capacity {
            self.admitted += 1;
            self.table.get_or_insert(token) as i64
        } else {
            self.oov += 1;
            self.oov_index()
        }
    }

    /// Map a whole column in place.
    pub fn map_slice(&mut self, tokens: &mut [i64]) {
        for t in tokens.iter_mut() {
            *t = self.map(*t);
        }
    }

    pub fn len(&self) -> usize {
        self.table.len()
    }

    pub fn is_empty(&self) -> bool {
        self.table.is_empty()
    }

    /// Zero the admission/hit/OOV counters without touching the table
    /// contents. Call this at each fit-round boundary: the counters are a
    /// *windowed* hotness signal (rates since the last reset), not lifetime
    /// totals — the embedding prefetcher and the table-sizing control plane
    /// both read per-round rates, and lifetime counters would dilute a hot
    /// recent window under a long cold history.
    pub fn reset_stats(&mut self) {
        self.admitted = 0;
        self.hits = 0;
        self.oov = 0;
    }

    /// Tokens currently admitted, in first-appearance order — the hotness
    /// ranking used to seed the embedding hot cache (earliest-admitted
    /// tokens are the head of the popularity distribution under the
    /// first-appearance admission policy).
    pub fn hot_tokens(&self) -> &[i64] {
        self.table.keys_in_order()
    }

    /// Fraction of recent lookups that fell to OOV — the control-plane
    /// signal for growing the table (or promoting it to HBM).
    pub fn oov_rate(&self) -> f64 {
        let total = self.hits + self.admitted + self.oov;
        if total == 0 {
            0.0
        } else {
            self.oov as f64 / total as f64
        }
    }

    /// Freeze into an immutable table (checkpoint / plan redeployment).
    pub fn freeze(self) -> VocabTable {
        self.table
    }
}

/// Population-stability-index drift detector over bucketized token
/// frequencies. PSI < 0.1: stable; 0.1–0.25: moderate shift; > 0.25:
/// significant drift (the classical credit-scoring thresholds).
#[derive(Debug, Clone)]
pub struct DriftDetector {
    buckets: usize,
    reference: Vec<f64>,
    current: Vec<u64>,
    current_n: u64,
}

impl DriftDetector {
    /// `buckets` histogram bins over the hashed token space.
    pub fn new(buckets: usize) -> DriftDetector {
        assert!(buckets >= 2);
        DriftDetector {
            buckets,
            reference: Vec::new(),
            current: vec![0; buckets],
            current_n: 0,
        }
    }

    #[inline]
    fn bucket(&self, token: i64) -> usize {
        (crate::etl::ops::kernels::mix64(token as u64) % self.buckets as u64) as usize
    }

    /// Record a batch of tokens into the current window.
    pub fn observe(&mut self, tokens: &[i64]) {
        for &t in tokens {
            let b = self.bucket(t);
            self.current[b] += 1;
        }
        self.current_n += tokens.len() as u64;
    }

    /// Close the window: returns the PSI vs the reference distribution
    /// (None for the first window, which becomes the reference).
    pub fn rotate(&mut self) -> Option<f64> {
        if self.current_n == 0 {
            return None;
        }
        let dist: Vec<f64> = self
            .current
            .iter()
            .map(|&c| (c as f64 / self.current_n as f64).max(1e-9))
            .collect();
        let psi = if self.reference.is_empty() {
            None
        } else {
            Some(
                dist.iter()
                    .zip(&self.reference)
                    .map(|(c, r)| (c - r) * (c / r).ln())
                    .sum(),
            )
        };
        self.reference = dist;
        self.current = vec![0; self.buckets];
        self.current_n = 0;
        psi
    }
}

/// Drift verdicts at the classical PSI thresholds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DriftVerdict {
    Stable,
    Moderate,
    Significant,
}

pub fn classify_psi(psi: f64) -> DriftVerdict {
    if psi < 0.1 {
        DriftVerdict::Stable
    } else if psi < 0.25 {
        DriftVerdict::Moderate
    } else {
        DriftVerdict::Significant
    }
}

/// Time-to-freshness accounting: event ingest time → training time.
#[derive(Debug, Default)]
pub struct FreshnessTracker {
    /// (ingest_time, trained_time) per batch.
    samples: Vec<(f64, f64)>,
}

impl FreshnessTracker {
    /// Record that a batch ingested at `ingest_t` was trained at `train_t`.
    pub fn record(&mut self, ingest_t: f64, train_t: f64) {
        assert!(train_t >= ingest_t, "training cannot precede ingest");
        self.samples.push((ingest_t, train_t));
    }

    /// Mean time-to-freshness (s).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().map(|(i, t)| t - i).sum::<f64>() / self.samples.len() as f64
    }

    /// Worst-case time-to-freshness (s).
    pub fn max(&self) -> f64 {
        self.samples.iter().map(|(i, t)| t - i).fold(0.0, f64::max)
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn online_vocab_admits_then_oovs() {
        let mut v = OnlineVocab::new(4);
        for t in [10, 20, 30, 40] {
            assert!(v.map(t) < 4);
        }
        assert_eq!(v.len(), 4);
        // Known tokens still map; new ones go to OOV.
        assert_eq!(v.map(10), 0);
        assert_eq!(v.map(99), v.oov_index());
        assert_eq!(v.oov, 1);
        assert!(v.oov_rate() > 0.0);
    }

    #[test]
    fn online_vocab_is_first_appearance_ordered() {
        let mut v = OnlineVocab::new(16);
        assert_eq!(v.map(77), 0);
        assert_eq!(v.map(33), 1);
        assert_eq!(v.map(77), 0);
        let frozen = v.freeze();
        assert_eq!(frozen.keys_in_order(), &[77, 33]);
    }

    #[test]
    fn reset_stats_pins_windowed_hotness_semantics() {
        let mut v = OnlineVocab::new(2);
        // Round 1: two admissions, one hit, two OOVs → oov_rate 2/5.
        for t in [1, 2, 1, 3, 4] {
            v.map(t);
        }
        assert_eq!((v.admitted, v.hits, v.oov), (2, 1, 2));
        assert!((v.oov_rate() - 0.4).abs() < 1e-12);

        // Round boundary: the stats window closes, the table survives.
        v.reset_stats();
        assert_eq!((v.admitted, v.hits, v.oov), (0, 0, 0));
        assert_eq!(v.oov_rate(), 0.0);
        assert_eq!(v.len(), 2, "reset must not evict admitted tokens");
        assert_eq!(v.hot_tokens(), &[1, 2]);

        // Round 2: all in-vocab traffic → windowed oov_rate is 0, not the
        // lifetime 2/9 a non-reset counter would report.
        for t in [1, 2, 1, 2] {
            v.map(t);
        }
        assert_eq!((v.admitted, v.hits, v.oov), (0, 4, 0));
        assert_eq!(v.oov_rate(), 0.0);

        // Round 3: pure-OOV traffic is visible at full strength in its own
        // window (lifetime counters would report 3/12 instead of 1.0).
        v.reset_stats();
        for t in [7, 8, 9] {
            v.map(t);
        }
        assert_eq!(v.oov_rate(), 1.0);
    }

    #[test]
    fn map_slice_updates_in_place() {
        let mut v = OnlineVocab::new(8);
        let mut xs = vec![5, 6, 5, 7];
        v.map_slice(&mut xs);
        assert_eq!(xs, vec![0, 1, 0, 2]);
    }

    #[test]
    fn drift_detector_flags_distribution_change() {
        let mut d = DriftDetector::new(32);
        let mut rng = Rng::new(1);
        // Window 1: tokens 0..100 (reference).
        let w1: Vec<i64> = (0..20_000).map(|_| rng.below(100) as i64).collect();
        d.observe(&w1);
        assert!(d.rotate().is_none());
        // Window 2: same distribution → stable.
        let w2: Vec<i64> = (0..20_000).map(|_| rng.below(100) as i64).collect();
        d.observe(&w2);
        let psi = d.rotate().unwrap();
        assert_eq!(classify_psi(psi), DriftVerdict::Stable, "psi={psi}");
        // Window 3: disjoint token range → significant drift.
        let w3: Vec<i64> = (0..20_000).map(|_| 10_000 + rng.below(100) as i64).collect();
        d.observe(&w3);
        let psi = d.rotate().unwrap();
        assert_eq!(classify_psi(psi), DriftVerdict::Significant, "psi={psi}");
    }

    #[test]
    fn freshness_tracks_mean_and_max() {
        let mut f = FreshnessTracker::default();
        f.record(0.0, 0.5);
        f.record(1.0, 2.5);
        assert_eq!(f.count(), 2);
        assert!((f.mean() - 1.0).abs() < 1e-12);
        assert_eq!(f.max(), 1.5);
    }

    #[test]
    #[should_panic(expected = "training cannot precede ingest")]
    fn freshness_rejects_time_travel() {
        let mut f = FreshnessTracker::default();
        f.record(2.0, 1.0);
    }
}
