//! Online hill-climbing auto-tuner: the feedback loop of ROADMAP item 3
//! (InTune-style), closing observation → decision → actuation over the
//! elastic fleet's control plane.
//!
//! The controller runs **inside the router thread** of
//! [`crate::coordinator::fleet`]: the router is the only place where the
//! delivery-order step numbering, the knob surface and the quiesce
//! points all meet, so a controller living there can observe a window,
//! decide, and actuate without any new synchronization domain. It emits
//! exactly the events a hand-written
//! [`ControlScript`](crate::coordinator::fleet::ControlScript) would
//! contain — the same [`KnobChange`] enum, applied by the same quiesce
//! machinery, logged in the same
//! [`KnobRegistry`](crate::coordinator::fleet::KnobRegistry) (each with
//! its trigger [`StallCause`]).
//!
//! # Determinism
//!
//! The tuner must keep the fleet's headline property: **a run is a pure
//! function of its config**, bitwise replayable under the schedule
//! fuzzer. Wall-clock observations would break that, so every signal the
//! controller consumes lives on the *simulated* clock:
//!
//! * the router posts each routed slot's schedule identity (step range,
//!   lane, raw bytes, straggler affliction — via the pure
//!   [`fault::afflicted`](crate::util::fault::afflicted) query) into an
//!   [`ObsLedger`] **before** sending it to the lane;
//! * the lane's pack worker completes the record with the slot's
//!   deterministic FPGA pack time and DMA wire time (both sim-clock);
//! * at each window boundary (`cum >= (k+1)·W`) the router blocks until
//!   the window's slots are complete — deadlock-free, because every
//!   step of the window has already been routed and lanes drain
//!   independently — and replays them through a deterministic
//!   **pipeline model** ([`PipelineModel`]): persistent per-worker
//!   ingest clocks, per-lane pack/credit/train clocks and reduce-epoch
//!   costs, emitting synthetic spans into a
//!   [`WindowAttributor`](crate::trace::WindowAttributor).
//!
//! The windowed [`StallAttribution`] over those modeled spans is the
//! observation; modeled windowed steps/s is the objective. Both are
//! pure functions of (config, delivery order), so controller decisions
//! replay bitwise (`rust/tests/prop_autotune.rs`). The one exception is
//! documented: a `Route(LeastLoaded)` flip makes *subsequent routing*
//! follow the live byte ledger — exactly-once but schedule-dependent,
//! same as configuring `LeastLoaded` statically.
//!
//! # Policy: greedy coordinate descent with hysteresis
//!
//! ```text
//!   window k closes ──▶ dominant stall cause ──▶ one KnobChange ──▶ hold
//!        ▲                                                           │
//!        │    keep (tp improved ≥ min_gain)   ◀── judge window ◀─────┘
//!        └── revert + mark cause exhausted    (after cooldown)
//! ```
//!
//! | cause            | signal                                | knob ladder                          |
//! |------------------|---------------------------------------|--------------------------------------|
//! | `Skew`           | per-lane modeled work max/mean         | `Route(LeastLoaded)` (once)          |
//! | `Ingest`         | idle ∩ ingest-read spans               | `IngestWorkers ×2`, then `ChunkRows ×4 → 0` |
//! | `Backpressure`   | idle ∩ slot-credit waits               | `Lookahead +2` (embedding), else slots hint |
//! | `Reduce`         | reduce-epoch busy time                 | `AllreduceEvery ×2`                  |
//!
//! One change at a time; after applying, the controller holds for
//! [`AutotuneConfig::cooldown`] windows, then keeps the change only if
//! the judge window's modeled throughput improved by at least
//! [`AutotuneConfig::min_gain`], else emits the inverse change and marks
//! the cause exhausted. `max_changes = 0` is observe-only mode: windows
//! and throughput are reported, nothing is emitted — the scenario
//! harness uses it to score hand-tuned and deliberately-bad configs on
//! the same modeled objective (`rust/src/scenarios`).

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Condvar, Mutex};

use crate::coordinator::fleet::KnobChange;
use crate::coordinator::scheduler::RoutePolicy;
use crate::error::{EtlError, Result};
use crate::memsys::{ChannelModel, Path};
use crate::metrics::TimeSeries;
use crate::trace::{kind as tkind, StallAttribution, WindowAttributor, LANE_NONE};

/// Knobs of the online controller (`TrainConfig::autotune`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutotuneConfig {
    /// Observation window in global steps (the W of "the last W steps").
    pub window: u64,
    /// Windows to hold after a change before judging it (the transition
    /// window right after an application is never the judge).
    pub cooldown: u64,
    /// Relative modeled-throughput improvement required to keep a change.
    pub min_gain: f64,
    /// Total changes the controller may apply (reverts not counted);
    /// 0 = observe-only (report windows, emit nothing).
    pub max_changes: usize,
    /// Ceiling for the `IngestWorkers` ladder.
    pub max_ingest_workers: usize,
    /// Ceiling for the embedding `Lookahead` ladder.
    pub max_lookahead: usize,
    /// Ceiling for the `AllreduceEvery` ladder.
    pub max_allreduce_every: usize,
    /// Per-lane modeled-work max/mean ratio above which the fleet counts
    /// as skewed (triggers the one-shot `Route(LeastLoaded)` flip).
    pub imbalance_threshold: f64,
}

impl Default for AutotuneConfig {
    fn default() -> Self {
        AutotuneConfig {
            window: 8,
            cooldown: 1,
            min_gain: 0.02,
            max_changes: 8,
            max_ingest_workers: 8,
            max_lookahead: 8,
            max_allreduce_every: 8,
            imbalance_threshold: 1.5,
        }
    }
}

impl AutotuneConfig {
    /// Shape validation ([`EtlError::Config`]).
    pub fn validate(&self) -> Result<()> {
        if self.window == 0 {
            return Err(EtlError::Config(
                "AutotuneConfig::window must be >= 1 step".into(),
            ));
        }
        if !(self.min_gain >= 0.0 && self.min_gain.is_finite()) {
            return Err(EtlError::Config(format!(
                "AutotuneConfig::min_gain must be finite and >= 0 (got {})",
                self.min_gain
            )));
        }
        if !(self.imbalance_threshold >= 1.0) {
            return Err(EtlError::Config(format!(
                "AutotuneConfig::imbalance_threshold must be >= 1 (got {})",
                self.imbalance_threshold
            )));
        }
        Ok(())
    }
}

/// Why the controller touched a knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StallCause {
    /// Lanes idled on shard ingest (I/O-bound window).
    Ingest,
    /// Lanes idled on arena slot credits (staging backpressure).
    Backpressure,
    /// Reduce epochs dominated the window.
    Reduce,
    /// Per-lane load imbalance (skewed shard sizes under round-robin).
    Skew,
}

impl StallCause {
    /// Stable short name (reports/debug output).
    pub fn name(&self) -> &'static str {
        match self {
            StallCause::Ingest => "ingest",
            StallCause::Backpressure => "backpressure",
            StallCause::Reduce => "reduce",
            StallCause::Skew => "skew",
        }
    }

    fn idx(&self) -> usize {
        match self {
            StallCause::Ingest => 0,
            StallCause::Backpressure => 1,
            StallCause::Reduce => 2,
            StallCause::Skew => 3,
        }
    }
}

/// One applied control-plane change with its provenance: scripted
/// (`cause: None`) or controller-emitted (`cause: Some`). The typed form
/// of the `KnobRegistry` log, surfaced as `TrainReport::knob_log`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AppliedKnob {
    /// Routing frontier (run-relative global steps) at application.
    pub at_step: u64,
    /// The change applied.
    pub change: KnobChange,
    /// The stall cause that triggered it (None for scripted events).
    pub cause: Option<StallCause>,
}

/// One routed slot's observation record: schedule identity stamped by
/// the router, measured sim-clock costs filled in by the pack worker.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlotObs {
    /// Run-relative global step of the slot's first chunk.
    pub start_rel: u64,
    /// Trainer chunks (steps) the slot carries; always > 0 (zero-chunk
    /// slots advance no step and are never posted).
    pub chunks: u64,
    /// Lane the router assigned.
    pub lane: u32,
    /// Raw (pre-pack) shard bytes — the ingest cost driver.
    pub raw_bytes: u64,
    /// The slot's source shard is straggler-afflicted
    /// ([`crate::util::fault::site::SLOW_SHARD`], pure query).
    pub straggler: bool,
    /// Simulated FPGA pack seconds (deterministic per bytes).
    pub pack_sim_s: f64,
    /// Simulated DMA wire seconds (engine queueing excluded — the model
    /// rebuilds queueing from its own clocks).
    pub dma_sim_s: f64,
    /// The slot's steps were forfeited (lane died); it carries no cost.
    pub forfeited: bool,
}

#[derive(Debug)]
struct ObsEntry {
    obs: SlotObs,
    complete: bool,
}

#[derive(Debug, Default)]
struct ObsState {
    slots: BTreeMap<u64, ObsEntry>,
    /// Every slot covering steps `< contig` is complete.
    contig: u64,
}

/// Shared router ↔ pack-worker observation ledger: the router posts each
/// slot's schedule identity before sending it, the owning worker
/// completes it with the slot's sim-clock costs (or forfeits it when the
/// lane dies), and the router blocks on whole-window completion at its
/// decision points. Contiguity is tracked over the run-relative step
/// numbering, which the routed slots tile exactly.
#[derive(Debug, Default)]
pub struct ObsLedger {
    state: Mutex<ObsState>,
    cv: Condvar,
}

impl ObsLedger {
    pub fn new() -> ObsLedger {
        ObsLedger::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, ObsState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Router: record a routed slot's schedule identity (before the send,
    /// so the worker's completion always finds the entry).
    pub fn note_route(&self, obs: SlotObs) {
        debug_assert!(obs.chunks > 0, "zero-chunk slots are never posted");
        let mut st = self.lock();
        st.slots.insert(obs.start_rel, ObsEntry { obs, complete: false });
    }

    /// Pack worker: complete a slot with its measured sim-clock costs.
    pub fn complete_slot(&self, start_rel: u64, pack_sim_s: f64, dma_sim_s: f64) {
        let mut st = self.lock();
        if let Some(e) = st.slots.get_mut(&start_rel) {
            e.obs.pack_sim_s = pack_sim_s;
            e.obs.dma_sim_s = dma_sim_s;
            e.complete = true;
        }
        Self::advance(&mut st);
        self.cv.notify_all();
    }

    /// Pack worker: the slot's lane died; its steps were forfeited on the
    /// reduce bus, so the window must not wait for costs that will never
    /// be measured.
    pub fn forfeit_slot(&self, start_rel: u64) {
        let mut st = self.lock();
        if let Some(e) = st.slots.get_mut(&start_rel) {
            e.obs.forfeited = true;
            e.complete = true;
        }
        Self::advance(&mut st);
        self.cv.notify_all();
    }

    fn advance(st: &mut ObsState) {
        while let Some(e) = st.slots.get(&st.contig) {
            if !e.complete {
                break;
            }
            st.contig += e.obs.chunks;
        }
    }

    /// Steps contiguously complete from 0.
    pub fn contig(&self) -> u64 {
        self.lock().contig
    }

    /// Block until every step below `step` is complete, or `abort()`
    /// returns true (checked on a bounded poll, so an aborting run never
    /// wedges the router). Returns whether the target was reached.
    pub fn wait_through(&self, step: u64, abort: impl Fn() -> bool) -> bool {
        let mut st = self.lock();
        loop {
            if st.contig >= step {
                return true;
            }
            if abort() {
                return false;
            }
            let (next, _) = self
                .cv
                .wait_timeout(st, std::time::Duration::from_millis(10))
                .unwrap_or_else(|p| p.into_inner());
            st = next;
        }
    }

    /// Drain every slot with `start_rel < hi`, in step order. Call only
    /// after [`wait_through`](Self::wait_through)`(hi)` succeeded.
    pub fn take_below(&self, hi: u64) -> Vec<SlotObs> {
        let mut st = self.lock();
        let rest = st.slots.split_off(&hi);
        let taken = std::mem::replace(&mut st.slots, rest);
        taken.into_values().map(|e| e.obs).collect()
    }
}

/// Straggler ingest-cost multiplier: an afflicted shard's read is modeled
/// as this many times slower (the real `fault::stall` is a bounded
/// wall-clock sleep; the model needs a sim-clock analogue that makes the
/// straggling lane visibly ingest-bound).
const STRAGGLER_FACTOR: f64 = 8.0;

/// Per-lane clocks of the pipeline model.
#[derive(Debug, Clone, Default)]
struct LaneClock {
    pack_free: f64,
    train_free: f64,
    /// Train-end times of modeled in-flight slots (slot credits).
    credits: VecDeque<f64>,
    /// Modeled busy seconds this window (pack + dma + train) — the skew
    /// signal.
    work: f64,
}

/// Deterministic replay of a window's routed slots through the pipeline's
/// stage topology: per-worker ingest servers → per-lane pack+DMA engine →
/// slot-credit ring → per-lane trainer with reduce-epoch costs. Clocks
/// persist across windows (the steady state carries over); each window
/// emits synthetic spans into a [`WindowAttributor`] whose windowed
/// [`StallAttribution`] is the controller's observation signal.
#[derive(Debug)]
pub struct PipelineModel {
    ingest_free: Vec<f64>,
    lanes: BTreeMap<u32, LaneClock>,
    slots_per_lane: usize,
    now: f64,
    ingest_setup_s: f64,
    ingest_bw: f64,
    step_cost_s: f64,
    /// Exposed embedding-promotion wait per step at lookahead 0; decays
    /// as `emb_unit_s / (1 + lookahead)`.
    emb_unit_s: f64,
    allreduce_cost_s: f64,
    lookahead: usize,
    allreduce_every: usize,
    /// Trainer rows per step — converts a slot's chunk count back to rows
    /// so chunked ingest can be charged one setup per delivery.
    step_rows: usize,
    /// Live `IngestConfig::chunk_rows` mirror (0 = whole-shard reads).
    chunk_rows: usize,
    attr: WindowAttributor,
}

impl PipelineModel {
    fn new(init: &ClimberInit) -> PipelineModel {
        // Ingest channel: the SSD model for SSD-bound datasets (the D-III
        // cliff), otherwise a host-generation cost of the same shape.
        let (setup_s, bw) = if init.ssd_bound {
            let c = ChannelModel::of(Path::SsdRead);
            (c.setup_s, c.bandwidth)
        } else {
            (20.0e-6, 8.0e9)
        };
        // Per-step trainer cost: linear in the batch's feature volume —
        // an arbitrary but deterministic scale shared by every arm the
        // controller compares, so only ratios matter.
        let step_cost_s = (init.step_rows * (init.n_dense + init.n_sparse * (init.embed_dim + 4)))
            as f64
            * 1e-9
            + 2e-6;
        let emb_unit_s = if init.embedding {
            ChannelModel::of(Path::P2pToGpu)
                .time((init.step_rows * init.n_sparse * init.embed_dim * 4) as u64)
        } else {
            0.0
        };
        PipelineModel {
            ingest_free: vec![0.0; init.workers.max(1)],
            lanes: BTreeMap::new(),
            slots_per_lane: init.arena_slots.max(2),
            now: 0.0,
            ingest_setup_s: setup_s,
            ingest_bw: bw,
            step_cost_s,
            emb_unit_s,
            allreduce_cost_s: init.allreduce_cost_s,
            lookahead: init.lookahead,
            allreduce_every: init.allreduce_every,
            step_rows: init.step_rows.max(1),
            chunk_rows: init.chunk_rows,
            attr: WindowAttributor::new(),
        }
    }

    fn set_workers(&mut self, n: usize) {
        let n = n.max(1);
        let now = self.now;
        self.ingest_free.resize(n, now);
    }

    /// Replay one window's slots; returns (window start, window end,
    /// windowed attribution, per-lane work max/mean).
    fn advance(&mut self, slots: &[SlotObs]) -> (f64, f64, StallAttribution, f64) {
        let t0 = self.now;
        for lane in self.lanes.values_mut() {
            lane.work = 0.0;
        }
        for obs in slots.iter().filter(|o| !o.forfeited) {
            // Ingest: earliest-free server (ties to the lowest index).
            let w = (0..self.ingest_free.len())
                .min_by(|&a, &b| self.ingest_free[a].total_cmp(&self.ingest_free[b]))
                .expect("model has >= 1 ingest worker");
            // One setup per chunked delivery: tiny `chunk_rows` against a
            // high-setup channel (the SSD cliff) multiplies the fixed
            // cost, which is exactly what the `ChunkRows` rung amortizes.
            let deliveries = if self.chunk_rows == 0 {
                1
            } else {
                (obs.chunks as usize * self.step_rows).div_ceil(self.chunk_rows).max(1)
            };
            let mut cost =
                deliveries as f64 * self.ingest_setup_s + obs.raw_bytes as f64 / self.ingest_bw;
            if obs.straggler {
                cost *= STRAGGLER_FACTOR;
            }
            let ready = self.ingest_free[w] + cost;
            self.ingest_free[w] = ready;
            self.attr.add(tkind::INGEST_READ, LANE_NONE, ready - cost, ready);

            let lane = self.lanes.entry(obs.lane).or_default();
            // Pack start: data ready, engine free, and a slot credit.
            let data_at = ready.max(lane.pack_free);
            let credit_at = if lane.credits.len() >= self.slots_per_lane {
                lane.credits.pop_front().expect("ring non-empty at capacity")
            } else {
                0.0
            };
            let start = data_at.max(credit_at);
            if start > data_at {
                self.attr.add(tkind::SLOT_ACQUIRE, obs.lane, data_at, start);
            }
            let pack_end = start + obs.pack_sim_s + obs.dma_sim_s;
            self.attr.add(tkind::PACK, obs.lane, start, pack_end);
            lane.pack_free = pack_end;

            // Train: the consumer steps the slot's chunks back to back,
            // then pays any reduce epochs whose boundary the slot's step
            // range crossed.
            let per_step = self.step_cost_s + self.emb_unit_s / (1.0 + self.lookahead as f64);
            let steps_s = obs.chunks as f64 * per_step;
            let t_start = pack_end.max(lane.train_free);
            let t_end = t_start + steps_s;
            self.attr.add(tkind::TRAIN_STEP, obs.lane, t_start, t_end);
            let epochs = if self.allreduce_every > 0 {
                let ae = self.allreduce_every as u64;
                (obs.start_rel + obs.chunks) / ae - obs.start_rel / ae
            } else {
                0
            };
            let r_end = t_end + epochs as f64 * self.allreduce_cost_s;
            if r_end > t_end {
                self.attr.add(tkind::REDUCE_APPLY, obs.lane, t_end, r_end);
            }
            lane.train_free = r_end;
            lane.credits.push_back(r_end);
            lane.work += obs.pack_sim_s + obs.dma_sim_s + steps_s;
        }

        let t1 = self
            .lanes
            .values()
            .map(|l| l.train_free)
            .fold(t0, f64::max);
        let att = self.attr.window(t0, t1);
        self.attr.prune_before(t1);
        self.now = t1;

        let works: Vec<f64> = self.lanes.values().map(|l| l.work).collect();
        let imbalance = if works.len() >= 2 {
            let sum: f64 = works.iter().sum();
            let mean = sum / works.len() as f64;
            if mean > 1e-12 {
                works.iter().cloned().fold(0.0, f64::max) / mean
            } else {
                1.0
            }
        } else {
            1.0
        };
        (t0, t1, att, imbalance)
    }
}

/// Everything the controller needs to know about the run it is tuning:
/// the starting knob values it will climb from and the cost-model scale
/// parameters. Built by the fleet driver from (config, spec, trainer
/// meta); constructed directly in tests.
#[derive(Debug, Clone, Copy)]
pub struct ClimberInit {
    /// Starting policy is round-robin (the only state a route flip can
    /// improve from).
    pub route_round_robin: bool,
    /// Initial ingest workers.
    pub workers: usize,
    /// Initial ingest chunk rows (0 = whole shards).
    pub chunk_rows: usize,
    /// Rows per shard (the ceiling of the `ChunkRows` ladder: at or past
    /// it, chunking is already whole-shard).
    pub rows_per_shard: usize,
    /// Initial embedding-prefetch lookahead.
    pub lookahead: usize,
    /// Embedding layer enabled (the `Lookahead` knob exists).
    pub embedding: bool,
    /// Initial all-reduce period.
    pub allreduce_every: usize,
    /// Arena slots per lane (the model's credit-ring depth).
    pub arena_slots: usize,
    /// Dataset is SSD-bound (ingest modeled on the SSD channel).
    pub ssd_bound: bool,
    /// Simulated cost of one all-reduce epoch.
    pub allreduce_cost_s: f64,
    /// Trainer batch rows per step.
    pub step_rows: usize,
    /// Dense features per row.
    pub n_dense: usize,
    /// Sparse features per row.
    pub n_sparse: usize,
    /// Embedding dimension.
    pub embed_dim: usize,
}

/// One observation window's outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WindowSummary {
    /// Window index (window k covers steps `[k·W, (k+1)·W)`; the final
    /// window may be shorter).
    pub index: u64,
    /// Steps executed in the window (forfeited slots excluded).
    pub steps: u64,
    /// Slots (shards/chunks) the window covered.
    pub shards: u64,
    /// Modeled window duration (sim seconds).
    pub sim_s: f64,
    /// Modeled windowed throughput (the objective).
    pub steps_per_s: f64,
    /// Dominant stall cause, if any idle class cleared the floor.
    pub dominant: Option<StallCause>,
    /// The change the controller emitted at this window's close.
    pub action: Option<KnobChange>,
    /// The action was a hysteresis revert (not a fresh climb).
    pub reverted: bool,
}

/// Final controller report (`TrainReport::autotune`).
#[derive(Debug, Clone, Default)]
pub struct AutotuneReport {
    /// Every evaluated window, in order.
    pub windows: Vec<WindowSummary>,
    /// Modeled windowed throughput as a time series (sim-clock window
    /// ends vs steps/s).
    pub throughput: TimeSeries,
    /// Whole-run modeled throughput (total steps / total modeled time).
    pub modeled_steps_per_s: f64,
    /// Steady-state modeled throughput: the last ≤ 3 windows, weighted
    /// by steps — the scenario success metric (the climb's early bad
    /// windows don't drown the converged tail).
    pub steady_steps_per_s: f64,
    /// Changes applied (fresh climbs; reverts tracked separately).
    pub applied: u64,
    /// Hysteresis reverts emitted.
    pub reverts: u64,
    /// The controller saw backpressure it had no live knob for — raising
    /// `ArenaConfig::slots` (a pre-run knob) is the suggested fix.
    pub slots_hint: bool,
}

/// An applied-but-unjudged change.
#[derive(Debug, Clone, Copy)]
struct Holding {
    judge_at: u64,
    baseline_tp: f64,
    revert: KnobChange,
    cause: StallCause,
}

/// The greedy coordinate-descent controller (see module docs). Owned by
/// the fleet's router thread; every method is deterministic in its
/// arguments.
#[derive(Debug)]
pub struct HillClimber {
    cfg: AutotuneConfig,
    model: PipelineModel,
    // Live knob mirror (climbed from `ClimberInit`).
    route_round_robin: bool,
    workers: usize,
    chunk_rows: usize,
    rows_per_shard: usize,
    lookahead: usize,
    embedding: bool,
    allreduce_every: usize,
    // Hysteresis state.
    holding: Option<Holding>,
    quiet_until: u64,
    exhausted: [bool; 4],
    applied: u64,
    reverts: u64,
    slots_hint: bool,
    windows: Vec<WindowSummary>,
    throughput: TimeSeries,
}

impl HillClimber {
    pub fn new(cfg: AutotuneConfig, init: ClimberInit) -> HillClimber {
        HillClimber {
            model: PipelineModel::new(&init),
            route_round_robin: init.route_round_robin,
            workers: init.workers.max(1),
            chunk_rows: init.chunk_rows,
            rows_per_shard: init.rows_per_shard.max(1),
            lookahead: init.lookahead,
            embedding: init.embedding,
            allreduce_every: init.allreduce_every,
            holding: None,
            quiet_until: 0,
            exhausted: [false; 4],
            applied: 0,
            reverts: 0,
            slots_hint: false,
            windows: Vec::new(),
            throughput: TimeSeries::default(),
            cfg,
        }
    }

    /// The observation window size in steps.
    pub fn window_steps(&self) -> u64 {
        self.cfg.window
    }

    /// Fold one closed window of observations and decide. Returns the
    /// change to apply at the quiesce point, if any. `actuate = false`
    /// evaluates the window for the report but never emits (observe-only
    /// mode, and the post-routing drain of the final windows).
    pub fn observe_window(
        &mut self,
        index: u64,
        slots: &[SlotObs],
        actuate: bool,
    ) -> Option<(KnobChange, StallCause)> {
        let steps: u64 = slots.iter().filter(|s| !s.forfeited).map(|s| s.chunks).sum();
        let shards = slots.iter().filter(|s| !s.forfeited).count() as u64;
        let (t0, t1, att, imbalance) = self.model.advance(slots);
        let dur = (t1 - t0).max(1e-12);
        let tp = steps as f64 / dur;
        self.throughput.push(t1, tp);

        let dominant = Self::dominant(&att);
        let actuate = actuate && self.cfg.max_changes > 0;
        let mut action: Option<(KnobChange, StallCause)> = None;
        let mut reverted = false;

        if let Some(h) = self.holding.take() {
            if index < h.judge_at {
                self.holding = Some(h); // still cooling down
            } else if tp >= h.baseline_tp * (1.0 + self.cfg.min_gain) {
                // Keep: the climb paid off; the cause stays eligible.
            } else if actuate {
                self.apply_mirror(h.revert);
                self.exhausted[h.cause.idx()] = true;
                self.reverts += 1;
                self.quiet_until = index + 1 + self.cfg.cooldown;
                action = Some((h.revert, h.cause));
                reverted = true;
            }
        }

        if action.is_none()
            && self.holding.is_none()
            && actuate
            && index >= self.quiet_until
            && self.applied < self.cfg.max_changes as u64
        {
            if let Some((cause, change, revert)) = self.pick(dominant, imbalance) {
                self.apply_mirror(change);
                self.applied += 1;
                self.holding = Some(Holding {
                    judge_at: index + 1 + self.cfg.cooldown,
                    baseline_tp: tp,
                    revert,
                    cause,
                });
                action = Some((change, cause));
            }
        }

        self.windows.push(WindowSummary {
            index,
            steps,
            shards,
            sim_s: dur,
            steps_per_s: tp,
            dominant,
            action: action.map(|(c, _)| c),
            reverted,
        });
        action
    }

    /// Dominant stall cause of a window's attribution: the largest of
    /// the three actionable idle classes, if it clears 10% of the
    /// window's total lane-seconds.
    fn dominant(att: &StallAttribution) -> Option<StallCause> {
        let ingest: f64 = att.per_lane.iter().map(|l| l.ingest_s).sum();
        let backpr: f64 = att.per_lane.iter().map(|l| l.backpressure_s).sum();
        let reduce: f64 = att.per_lane.iter().map(|l| l.reduce_s).sum();
        let wall: f64 = att.per_lane.iter().map(|l| l.wall_s).sum();
        let floor = 0.10 * wall.max(1e-12);
        let (cause, top) = [
            (StallCause::Ingest, ingest),
            (StallCause::Backpressure, backpr),
            (StallCause::Reduce, reduce),
        ]
        .into_iter()
        .fold((StallCause::Ingest, f64::MIN), |acc, c| if c.1 > acc.1 { c } else { acc });
        (top > floor).then_some(cause)
    }

    /// Coordinate choice: (cause, change, inverse). Skew outranks the
    /// idle classes — an imbalanced fleet starves its fast lanes no
    /// matter what the per-stage ledgers say.
    fn pick(
        &mut self,
        dominant: Option<StallCause>,
        imbalance: f64,
    ) -> Option<(StallCause, KnobChange, KnobChange)> {
        if imbalance > self.cfg.imbalance_threshold
            && self.route_round_robin
            && !self.exhausted[StallCause::Skew.idx()]
        {
            return Some((
                StallCause::Skew,
                KnobChange::Route(RoutePolicy::LeastLoaded),
                KnobChange::Route(RoutePolicy::RoundRobin),
            ));
        }
        let cause = dominant?;
        if self.exhausted[cause.idx()] {
            return None;
        }
        match cause {
            StallCause::Ingest => {
                if self.workers < self.cfg.max_ingest_workers {
                    let n = (self.workers * 2).min(self.cfg.max_ingest_workers);
                    return Some((
                        cause,
                        KnobChange::IngestWorkers(n),
                        KnobChange::IngestWorkers(self.workers),
                    ));
                }
                if self.chunk_rows > 0 {
                    // Coarser chunks amortize the per-delivery setup; at
                    // or past the shard size, go whole-shard (0).
                    let grown = self.chunk_rows.saturating_mul(4);
                    let next = if grown >= self.rows_per_shard { 0 } else { grown };
                    return Some((
                        cause,
                        KnobChange::ChunkRows(next),
                        KnobChange::ChunkRows(self.chunk_rows),
                    ));
                }
                self.exhausted[cause.idx()] = true;
                None
            }
            StallCause::Backpressure => {
                if self.embedding && self.lookahead < self.cfg.max_lookahead {
                    let n = (self.lookahead + 2).min(self.cfg.max_lookahead);
                    return Some((
                        cause,
                        KnobChange::Lookahead(n),
                        KnobChange::Lookahead(self.lookahead),
                    ));
                }
                // Arena slots are a pre-run knob; surface the hint.
                self.slots_hint = true;
                self.exhausted[cause.idx()] = true;
                None
            }
            StallCause::Reduce => {
                if self.allreduce_every > 0 && self.allreduce_every < self.cfg.max_allreduce_every
                {
                    let n = (self.allreduce_every * 2).min(self.cfg.max_allreduce_every);
                    return Some((
                        cause,
                        KnobChange::AllreduceEvery(n),
                        KnobChange::AllreduceEvery(self.allreduce_every),
                    ));
                }
                self.exhausted[cause.idx()] = true;
                None
            }
            // Skew is only ever selected through the imbalance gate.
            StallCause::Skew => None,
        }
    }

    /// Mirror an applied change into the knob state and the model.
    fn apply_mirror(&mut self, change: KnobChange) {
        match change {
            KnobChange::Route(p) => self.route_round_robin = p == RoutePolicy::RoundRobin,
            KnobChange::IngestWorkers(n) => {
                self.workers = n.max(1);
                self.model.set_workers(n);
            }
            KnobChange::ChunkRows(n) => {
                self.chunk_rows = n;
                self.model.chunk_rows = n;
            }
            KnobChange::Lookahead(n) => {
                self.lookahead = n;
                self.model.lookahead = n;
            }
            KnobChange::AllreduceEvery(n) => {
                self.allreduce_every = n;
                self.model.allreduce_every = n;
            }
            KnobChange::AddLane | KnobChange::RemoveLane(_) => {}
        }
    }

    /// Seal the run into its report.
    pub fn finish(self) -> AutotuneReport {
        let total_steps: u64 = self.windows.iter().map(|w| w.steps).sum();
        let total_s: f64 = self.windows.iter().map(|w| w.sim_s).sum();
        let tail = self.windows.len().saturating_sub(3);
        let tail_steps: u64 = self.windows[tail..].iter().map(|w| w.steps).sum();
        let tail_s: f64 = self.windows[tail..].iter().map(|w| w.sim_s).sum();
        AutotuneReport {
            modeled_steps_per_s: total_steps as f64 / total_s.max(1e-12),
            steady_steps_per_s: tail_steps as f64 / tail_s.max(1e-12),
            applied: self.applied,
            reverts: self.reverts,
            slots_hint: self.slots_hint,
            windows: self.windows,
            throughput: self.throughput,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn init() -> ClimberInit {
        ClimberInit {
            route_round_robin: true,
            workers: 1,
            chunk_rows: 0,
            rows_per_shard: 64,
            lookahead: 0,
            embedding: false,
            allreduce_every: 1,
            arena_slots: 3,
            ssd_bound: true,
            allreduce_cost_s: 1e-6,
            step_rows: 16,
            n_dense: 4,
            n_sparse: 4,
            embed_dim: 4,
        }
    }

    fn slot(start_rel: u64, chunks: u64, lane: u32, raw_bytes: u64) -> SlotObs {
        SlotObs {
            start_rel,
            chunks,
            lane,
            raw_bytes,
            straggler: false,
            pack_sim_s: 10e-6,
            dma_sim_s: 5e-6,
            forfeited: false,
        }
    }

    #[test]
    fn ledger_tracks_contiguity_and_windows() {
        let led = ObsLedger::new();
        led.note_route(slot(0, 4, 0, 100));
        led.note_route(slot(4, 4, 1, 100));
        led.note_route(slot(8, 4, 0, 100));
        assert_eq!(led.contig(), 0);
        // Completing out of order holds the cursor at the gap.
        led.complete_slot(4, 1e-6, 1e-6);
        assert_eq!(led.contig(), 0);
        led.complete_slot(0, 1e-6, 1e-6);
        assert_eq!(led.contig(), 8);
        assert!(led.wait_through(8, || false));
        // Forfeits complete a slot too (a dead lane must not wedge the
        // controller).
        led.forfeit_slot(8);
        assert!(led.wait_through(12, || false));
        let w = led.take_below(8);
        assert_eq!(w.len(), 2);
        assert_eq!((w[0].start_rel, w[1].start_rel), (0, 4));
        let rest = led.take_below(u64::MAX);
        assert_eq!(rest.len(), 1);
        assert!(rest[0].forfeited);
        // An aborted run returns instead of blocking forever.
        assert!(!led.wait_through(100, || true));
    }

    #[test]
    fn ingest_bound_window_raises_workers_then_chunks() {
        // SSD-bound 1-worker start: big raw shards make every lane wait
        // on ingest, so the first climbs walk the ingest ladder.
        let mut hc = HillClimber::new(
            AutotuneConfig { cooldown: 0, ..Default::default() },
            ClimberInit { chunk_rows: 8, ..init() },
        );
        let win: Vec<SlotObs> =
            (0..4).map(|i| slot(i * 2, 2, (i % 2) as u32, 4 << 20)).collect();
        let first = hc.observe_window(0, &win, true);
        assert_eq!(
            first,
            Some((KnobChange::IngestWorkers(2), StallCause::Ingest)),
            "windows: {:?}",
            hc.windows
        );
        assert_eq!(hc.windows[0].dominant, Some(StallCause::Ingest));
        // Parallel modeled servers improve the judge window → keep, and
        // the ladder continues upward while ingest still dominates.
        let shift = |w: &[SlotObs], k: u64| -> Vec<SlotObs> {
            w.iter().map(|s| SlotObs { start_rel: s.start_rel + 8 * k, ..*s }).collect()
        };
        let second = hc.observe_window(1, &shift(&win, 1), true);
        assert_eq!(second, Some((KnobChange::IngestWorkers(4), StallCause::Ingest)));
        assert_eq!(hc.reverts, 0);
        let mut k = 2;
        let mut saw_chunk_knob = false;
        while k < 12 {
            if let Some((KnobChange::ChunkRows(n), StallCause::Ingest)) =
                hc.observe_window(k, &shift(&win, k), true)
            {
                // 8 ×4 = 32 < 64 rows/shard: still chunked, coarser.
                assert_eq!(n, 32);
                saw_chunk_knob = true;
                break;
            }
            k += 1;
        }
        assert!(saw_chunk_knob, "ingest ladder never reached ChunkRows: {:?}", hc.windows);
    }

    #[test]
    fn route_flip_without_gain_reverts_and_exhausts() {
        // Two lanes with 3:1 modeled work split trip the skew gate; the
        // synthetic windows keep the identical split afterwards, so the
        // judge sees no gain, reverts, and never flips again.
        let mut hc = HillClimber::new(
            AutotuneConfig { cooldown: 0, min_gain: 0.02, ..Default::default() },
            init(),
        );
        let win = |k: u64| -> Vec<SlotObs> {
            vec![
                slot(8 * k, 6, 0, 6 << 10),
                slot(8 * k + 6, 2, 1, 2 << 10),
            ]
        };
        let first = hc.observe_window(0, &win(0), true);
        assert_eq!(
            first,
            Some((KnobChange::Route(RoutePolicy::LeastLoaded), StallCause::Skew))
        );
        let second = hc.observe_window(1, &win(1), true);
        assert_eq!(
            second,
            Some((KnobChange::Route(RoutePolicy::RoundRobin), StallCause::Skew)),
            "no modeled gain must revert"
        );
        assert!(hc.windows[1].reverted);
        assert_eq!(hc.reverts, 1);
        for k in 2..5 {
            assert_eq!(hc.observe_window(k, &win(k), true), None, "skew cause exhausted");
        }
    }

    #[test]
    fn observe_only_mode_reports_but_never_emits() {
        let mut hc = HillClimber::new(
            AutotuneConfig { max_changes: 0, ..Default::default() },
            init(),
        );
        for k in 0..4u64 {
            let win: Vec<SlotObs> =
                (0..4).map(|i| slot(8 * k + i * 2, 2, (i % 2) as u32, 4 << 20)).collect();
            assert_eq!(hc.observe_window(k, &win, true), None);
        }
        let rep = hc.finish();
        assert_eq!(rep.applied, 0);
        assert_eq!(rep.windows.len(), 4);
        assert!(rep.modeled_steps_per_s > 0.0);
        assert!(rep.steady_steps_per_s > 0.0);
        assert_eq!(rep.throughput.points.len(), 4);
    }

    #[test]
    fn decisions_are_a_pure_function_of_observations() {
        let cfg = AutotuneConfig { cooldown: 0, ..Default::default() };
        let mut a = HillClimber::new(cfg, ClimberInit { chunk_rows: 8, ..init() });
        let mut b = HillClimber::new(cfg, ClimberInit { chunk_rows: 8, ..init() });
        for k in 0..10u64 {
            let win: Vec<SlotObs> = (0..4)
                .map(|i| {
                    let mut s = slot(8 * k + i * 2, 2, (i % 2) as u32, (1 + i) << 18);
                    s.straggler = (k + i) % 3 == 0;
                    s
                })
                .collect();
            assert_eq!(a.observe_window(k, &win, true), b.observe_window(k, &win, true));
        }
        let (ra, rb) = (a.finish(), b.finish());
        assert_eq!(ra.windows, rb.windows);
        assert_eq!(ra.applied, rb.applied);
        assert_eq!(ra.throughput.points, rb.throughput.points);
    }

    #[test]
    fn autotune_config_validation() {
        assert!(AutotuneConfig::default().validate().is_ok());
        let bad = AutotuneConfig { window: 0, ..Default::default() };
        assert!(matches!(bad.validate(), Err(EtlError::Config(_))));
        let bad = AutotuneConfig { min_gain: f64::NAN, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = AutotuneConfig { imbalance_threshold: 0.5, ..Default::default() };
        assert!(bad.validate().is_err());
    }
}
