//! Format-aware packer (paper §1/§3): converts the transformed columnar
//! batch into the exact memory layout the trainer consumes — one
//! contiguous buffer per framework tensor (dense f32 [B, D_d], sparse i32
//! indices [B, D_s], labels f32 [B]) — so the P2P stream lands in GPU
//! memory training-ready, with no host-side reshaping.
//!
//! This is the L3 hot path: every training byte flows through `pack`.

use crate::error::{EtlError, Result};
use crate::etl::column::{Batch, Column};
use crate::etl::dag::{Dag, Node, SinkRole};
use crate::etl::ops::OpSpec;

/// A training-ready packed batch (the unit streamed over P2P DMA).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PackedBatch {
    pub rows: usize,
    pub n_dense: usize,
    pub n_sparse: usize,
    /// Row-major `[rows, n_dense]` normalized dense features.
    pub dense: Vec<f32>,
    /// Row-major `[rows, n_sparse]` embedding indices.
    pub sparse: Vec<i32>,
    /// `[rows]` labels.
    pub labels: Vec<f32>,
}

impl PackedBatch {
    /// Total payload bytes (what the DMA engine moves).
    pub fn bytes(&self) -> u64 {
        (self.dense.len() * 4 + self.sparse.len() * 4 + self.labels.len() * 4) as u64
    }

    /// Split into per-training-step slices of `step_rows` (the last slice
    /// is dropped if incomplete — DLRM training uses fixed batch shapes).
    pub fn chunks(&self, step_rows: usize) -> Vec<PackedBatch> {
        self.chunk_views(step_rows).iter().map(PackedBatchView::to_batch).collect()
    }

    /// Borrowed equivalent of [`chunks`](Self::chunks): zero-copy views
    /// over the packed buffers. The train loop steps directly on these so
    /// steady-state stepping never re-copies the batch payload.
    pub fn chunk_views(&self, step_rows: usize) -> Vec<PackedBatchView<'_>> {
        assert!(step_rows > 0);
        let full = self.rows / step_rows;
        (0..full)
            .map(|i| {
                let r = i * step_rows..(i + 1) * step_rows;
                PackedBatchView {
                    rows: step_rows,
                    n_dense: self.n_dense,
                    n_sparse: self.n_sparse,
                    dense: &self.dense[r.start * self.n_dense..r.end * self.n_dense],
                    sparse: &self.sparse[r.start * self.n_sparse..r.end * self.n_sparse],
                    labels: &self.labels[r],
                }
            })
            .collect()
    }

    /// A borrowed view of the whole batch.
    pub fn view(&self) -> PackedBatchView<'_> {
        PackedBatchView {
            rows: self.rows,
            n_dense: self.n_dense,
            n_sparse: self.n_sparse,
            dense: &self.dense,
            sparse: &self.sparse,
            labels: &self.labels,
        }
    }
}

/// A borrowed slice of a [`PackedBatch`] — same shape metadata, zero-copy
/// payload. What the trainer consumes in the steady state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PackedBatchView<'a> {
    pub rows: usize,
    pub n_dense: usize,
    pub n_sparse: usize,
    pub dense: &'a [f32],
    pub sparse: &'a [i32],
    pub labels: &'a [f32],
}

impl PackedBatchView<'_> {
    /// Total payload bytes of this view.
    pub fn bytes(&self) -> u64 {
        (self.dense.len() * 4 + self.sparse.len() * 4 + self.labels.len() * 4) as u64
    }

    /// Materialize an owned copy.
    pub fn to_batch(&self) -> PackedBatch {
        PackedBatch {
            rows: self.rows,
            n_dense: self.n_dense,
            n_sparse: self.n_sparse,
            dense: self.dense.to_vec(),
            sparse: self.sparse.to_vec(),
            labels: self.labels.to_vec(),
        }
    }
}

/// Sink layout extracted from a DAG: which output columns feed which
/// tensor, in declaration order. Dense sinks may be wider than one slot
/// (OneHot widening); `dense_widths` records the slots per dense sink and
/// the packed dense tensor is `[rows, n_dense_slots]`.
#[derive(Debug, Clone)]
pub struct PackLayout {
    pub dense_cols: Vec<String>,
    /// Slots per dense sink, parallel to `dense_cols` (1 unless widened).
    pub dense_widths: Vec<usize>,
    pub sparse_cols: Vec<String>,
    pub label_col: String,
}

impl PackLayout {
    pub fn of(dag: &Dag) -> Result<PackLayout> {
        let widths = node_widths(dag);
        let mut dense_cols = Vec::new();
        let mut dense_widths = Vec::new();
        let mut sparse_cols = Vec::new();
        let mut label_col = None;
        for (name, input, role) in dag.sinks() {
            match role {
                SinkRole::Dense => {
                    dense_cols.push(name.to_string());
                    dense_widths.push(widths[input.0]);
                }
                SinkRole::SparseIndex => sparse_cols.push(name.to_string()),
                SinkRole::Label => label_col = Some(name.to_string()),
            }
        }
        Ok(PackLayout {
            dense_cols,
            dense_widths,
            sparse_cols,
            label_col: label_col
                .ok_or_else(|| EtlError::Coord("DAG has no label sink".into()))?,
        })
    }

    /// Total f32 slots per packed dense row (= sum of dense sink widths).
    pub fn n_dense_slots(&self) -> usize {
        self.dense_widths.iter().sum()
    }
}

/// Per-node output widths, mirroring the reference executor's `Column`
/// constructors: OneHot widens to `k`; the f32 elementwise operators
/// preserve their input width; every integer-producing operator re-emits
/// width 1.
fn node_widths(dag: &Dag) -> Vec<usize> {
    let mut widths = vec![1usize; dag.nodes.len()];
    for (i, node) in dag.nodes.iter().enumerate() {
        widths[i] = match node {
            Node::Source { .. } => 1,
            Node::Op { spec, inputs, .. } => match spec {
                OpSpec::OneHot { k } => *k,
                OpSpec::FillMissing { .. } | OpSpec::Clamp { .. } | OpSpec::Logarithm => {
                    inputs.first().map(|n| widths[n.0]).unwrap_or(1)
                }
                _ => 1,
            },
            Node::Sink { input, .. } => widths[input.0],
        };
    }
    widths
}

/// Pack a transformed batch into the trainer layout.
///
/// Transposes column-major ETL output into row-major tensors; sparse
/// indices are range-checked into `i32` (embedding rows fit 2^31).
pub fn pack(batch: &Batch, layout: &PackLayout) -> Result<PackedBatch> {
    let rows = batch.rows();
    let n_dense = layout.n_dense_slots();
    let n_sparse = layout.sparse_cols.len();

    let mut dense = vec![0f32; rows * n_dense];
    let mut off = 0usize;
    for (name, &w) in layout.dense_cols.iter().zip(&layout.dense_widths) {
        let col = expect_col(batch, name)?;
        let data = col.as_f32()?;
        if col.width() != w {
            return Err(EtlError::Coord(format!(
                "dense sink {name} has width {} (expected {w})",
                col.width()
            )));
        }
        // Column-major → row-major scatter; the stride-friendly loop is
        // over rows so the destination writes are sequential per column.
        for r in 0..rows {
            dense[r * n_dense + off..r * n_dense + off + w]
                .copy_from_slice(&data[r * w..(r + 1) * w]);
        }
        off += w;
    }

    let mut sparse = vec![0i32; rows * n_sparse];
    for (ci, name) in layout.sparse_cols.iter().enumerate() {
        let data = expect_col(batch, name)?.as_i64()?;
        for (r, &v) in data.iter().enumerate() {
            if v < 0 || v > i32::MAX as i64 {
                return Err(EtlError::Coord(format!(
                    "sparse index {v} out of i32 range in {name}"
                )));
            }
            sparse[r * n_sparse + ci] = v as i32;
        }
    }

    let labels = expect_col(batch, &layout.label_col)?.as_f32()?.to_vec();

    Ok(PackedBatch { rows, n_dense, n_sparse, dense, sparse, labels })
}

fn expect_col<'a>(batch: &'a Batch, name: &str) -> Result<&'a Column> {
    batch
        .get(name)
        .ok_or_else(|| EtlError::Coord(format!("transformed batch missing column {name:?}")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::etl::column::Column;
    use crate::etl::dag::Dag;
    use crate::etl::ops::OpSpec;
    use crate::etl::schema::Schema;

    fn layout_and_batch() -> (PackLayout, Batch) {
        let _schema = Schema::tabular("t", 2, 2, 100);
        let mut dag = Dag::new("p");
        let l = dag.source("t_label", crate::etl::column::ColType::F32);
        dag.sink("label", l, SinkRole::Label);
        for i in 0..2 {
            let s = dag.source(format!("t_i{i}"), crate::etl::column::ColType::F32);
            let o = dag.op(OpSpec::Clamp { lo: 0.0, hi: 1.0 }, &[s]);
            dag.sink(format!("dense{i}"), o, SinkRole::Dense);
        }
        for i in 0..2 {
            let s = dag.source(format!("t_c{i}"), crate::etl::column::ColType::Hex8);
            let h = dag.op(OpSpec::Hex2Int, &[s]);
            dag.sink(format!("sparse{i}"), h, SinkRole::SparseIndex);
        }
        let layout = PackLayout::of(&dag).unwrap();

        let mut b = Batch::new();
        b.push("label", Column::f32(vec![1.0, 0.0, 1.0])).unwrap();
        b.push("dense0", Column::f32(vec![0.1, 0.2, 0.3])).unwrap();
        b.push("dense1", Column::f32(vec![1.1, 1.2, 1.3])).unwrap();
        b.push("sparse0", Column::i64(vec![7, 8, 9])).unwrap();
        b.push("sparse1", Column::i64(vec![70, 80, 90])).unwrap();
        (layout, b)
    }

    #[test]
    fn packs_row_major() {
        let (layout, b) = layout_and_batch();
        let p = pack(&b, &layout).unwrap();
        assert_eq!(p.rows, 3);
        assert_eq!(p.dense, vec![0.1, 1.1, 0.2, 1.2, 0.3, 1.3]);
        assert_eq!(p.sparse, vec![7, 70, 8, 80, 9, 90]);
        assert_eq!(p.labels, vec![1.0, 0.0, 1.0]);
        assert_eq!(p.bytes(), (6 * 4 + 6 * 4 + 3 * 4) as u64);
    }

    #[test]
    fn missing_column_is_an_error() {
        let (layout, mut b) = layout_and_batch();
        b.columns.retain(|(n, _)| n != "sparse1");
        assert!(pack(&b, &layout).is_err());
    }

    #[test]
    fn negative_index_rejected() {
        let (layout, mut b) = layout_and_batch();
        for (n, c) in b.columns.iter_mut() {
            if n == "sparse0" {
                *c = Column::i64(vec![-1, 0, 1]);
            }
        }
        assert!(pack(&b, &layout).is_err());
    }

    #[test]
    fn chunks_split_evenly_and_drop_tail() {
        let (layout, b) = layout_and_batch();
        let p = pack(&b, &layout).unwrap();
        let chunks = p.chunks(2);
        assert_eq!(chunks.len(), 1); // 3 rows → one chunk of 2, tail dropped
        assert_eq!(chunks[0].rows, 2);
        assert_eq!(chunks[0].dense, vec![0.1, 1.1, 0.2, 1.2]);
        assert_eq!(chunks[0].labels, vec![1.0, 0.0]);
    }

    #[test]
    fn chunk_views_alias_the_owned_chunks() {
        let (layout, b) = layout_and_batch();
        let p = pack(&b, &layout).unwrap();
        let views = p.chunk_views(2);
        let owned = p.chunks(2);
        assert_eq!(views.len(), owned.len());
        for (v, o) in views.iter().zip(&owned) {
            assert_eq!(v.rows, o.rows);
            assert_eq!(v.dense, &o.dense[..]);
            assert_eq!(v.sparse, &o.sparse[..]);
            assert_eq!(v.labels, &o.labels[..]);
            assert_eq!(v.bytes(), o.bytes());
            assert_eq!(&v.to_batch(), o);
        }
        // Borrowed slices point into the parent's buffers (no copy).
        assert!(std::ptr::eq(views[0].dense.as_ptr(), p.dense.as_ptr()));
    }

    #[test]
    fn whole_batch_view_roundtrips() {
        let (layout, b) = layout_and_batch();
        let p = pack(&b, &layout).unwrap();
        let v = p.view();
        assert_eq!(v.rows, p.rows);
        assert_eq!(v.to_batch(), p);
        assert_eq!(p.chunk_views(1).len(), 3);
    }

    #[test]
    fn layout_orders_match_declaration() {
        let (layout, _) = layout_and_batch();
        assert_eq!(layout.dense_cols, vec!["dense0", "dense1"]);
        assert_eq!(layout.dense_widths, vec![1, 1]);
        assert_eq!(layout.n_dense_slots(), 2);
        assert_eq!(layout.sparse_cols, vec!["sparse0", "sparse1"]);
        assert_eq!(layout.label_col, "label");
    }

    #[test]
    fn widened_onehot_sink_packs_interleaved() {
        // label + width-1 dense + OneHot(3) dense: 4 slots per row.
        let mut dag = Dag::new("wide");
        let l = dag.source("label", crate::etl::column::ColType::F32);
        dag.sink("label", l, SinkRole::Label);
        let d = dag.source("x", crate::etl::column::ColType::F32);
        dag.sink("dense0", d, SinkRole::Dense);
        let s = dag.source("b", crate::etl::column::ColType::I64);
        let oh = dag.op(OpSpec::OneHot { k: 3 }, &[s]);
        dag.sink("onehot", oh, SinkRole::Dense);
        let layout = PackLayout::of(&dag).unwrap();
        assert_eq!(layout.dense_widths, vec![1, 3]);
        assert_eq!(layout.n_dense_slots(), 4);

        let mut b = Batch::new();
        b.push("label", Column::f32(vec![1.0, 0.0])).unwrap();
        b.push("dense0", Column::f32(vec![0.5, 0.25])).unwrap();
        b.push(
            "onehot",
            Column::F32 { data: vec![0.0, 1.0, 0.0, 0.0, 0.0, 1.0], width: 3 },
        )
        .unwrap();
        let p = pack(&b, &layout).unwrap();
        assert_eq!(p.n_dense, 4);
        assert_eq!(p.dense, vec![0.5, 0.0, 1.0, 0.0, 0.25, 0.0, 0.0, 1.0]);
        // Wrong width is still rejected.
        let mut bad = Batch::new();
        bad.push("label", Column::f32(vec![1.0, 0.0])).unwrap();
        bad.push("dense0", Column::f32(vec![0.5, 0.25])).unwrap();
        bad.push("onehot", Column::f32(vec![1.0, 0.0])).unwrap();
        assert!(pack(&bad, &layout).is_err());
    }
}
