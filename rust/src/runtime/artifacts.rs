//! Artifact manifest: metadata emitted by `python/compile/aot.py`
//! alongside the HLO text files, describing model shapes and the train
//! step's argument order. Parsed at load time so the Rust runtime never
//! needs Python.
//!
//! Format (`artifacts/meta.txt`, `key=value` lines, `#` comments):
//! ```text
//! batch=256
//! n_dense=13
//! n_sparse=26
//! vocab=2000
//! embed_dim=16
//! param=emb:52000,16
//! param=w_bot1:13,64
//! ...
//! ```
//! `param=` lines appear in the exact positional-argument order of the
//! lowered train step (params first, then dense, sparse, labels).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{EtlError, Result};

/// One model parameter tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub dims: Vec<usize>,
}

impl ParamSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Parsed artifact metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelMeta {
    pub batch: usize,
    pub n_dense: usize,
    pub n_sparse: usize,
    pub vocab: usize,
    pub embed_dim: usize,
    pub params: Vec<ParamSpec>,
    pub extra: BTreeMap<String, String>,
}

impl ModelMeta {
    pub fn parse(text: &str) -> Result<ModelMeta> {
        let mut kv = BTreeMap::new();
        let mut params = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                EtlError::Runtime(format!("meta line {} not key=value: {line:?}", lineno + 1))
            })?;
            if k == "param" {
                let (name, dims) = v.split_once(':').ok_or_else(|| {
                    EtlError::Runtime(format!("bad param spec: {v:?}"))
                })?;
                let dims: Vec<usize> = dims
                    .split(',')
                    .map(|d| {
                        d.trim().parse().map_err(|e| {
                            EtlError::Runtime(format!("bad dim in {v:?}: {e}"))
                        })
                    })
                    .collect::<Result<_>>()?;
                params.push(ParamSpec { name: name.trim().to_string(), dims });
            } else {
                kv.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
        let get = |k: &str| -> Result<usize> {
            kv.get(k)
                .ok_or_else(|| EtlError::Runtime(format!("meta missing key {k:?}")))?
                .parse()
                .map_err(|e| EtlError::Runtime(format!("bad {k}: {e}")))
        };
        Ok(ModelMeta {
            batch: get("batch")?,
            n_dense: get("n_dense")?,
            n_sparse: get("n_sparse")?,
            vocab: get("vocab")?,
            embed_dim: get("embed_dim")?,
            params,
            extra: kv,
        })
    }

    pub fn load(path: &Path) -> Result<ModelMeta> {
        let text = std::fs::read_to_string(path)?;
        ModelMeta::parse(&text)
    }

    /// Total trainable parameters.
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.elements()).sum()
    }
}

/// Locations of the artifacts produced by `make artifacts`.
#[derive(Debug, Clone)]
pub struct ArtifactPaths {
    pub dir: PathBuf,
    pub train_hlo: PathBuf,
    pub loss_hlo: PathBuf,
    pub meta: PathBuf,
}

impl ArtifactPaths {
    pub fn in_dir(dir: impl Into<PathBuf>) -> ArtifactPaths {
        let dir = dir.into();
        ArtifactPaths {
            train_hlo: dir.join("train_step.hlo.txt"),
            loss_hlo: dir.join("read_loss.hlo.txt"),
            meta: dir.join("meta.txt"),
            dir,
        }
    }

    /// Default location relative to the repo root (or `PIPEREC_ARTIFACTS`).
    pub fn default_dir() -> ArtifactPaths {
        let dir = std::env::var("PIPEREC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        ArtifactPaths::in_dir(dir)
    }

    pub fn exist(&self) -> bool {
        self.train_hlo.exists() && self.loss_hlo.exists() && self.meta.exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# DLRM artifact metadata
batch=256
n_dense=13
n_sparse=26
vocab=2000
embed_dim=16
param=emb:52000,16
param=w_bot1:13,64
param=b_bot1:64
";

    #[test]
    fn parses_sample() {
        let m = ModelMeta::parse(SAMPLE).unwrap();
        assert_eq!(m.batch, 256);
        assert_eq!(m.n_sparse, 26);
        assert_eq!(m.params.len(), 3);
        assert_eq!(m.params[0].name, "emb");
        assert_eq!(m.params[0].dims, vec![52000, 16]);
        assert_eq!(m.params[0].elements(), 832_000);
        assert_eq!(m.param_count(), 832_000 + 13 * 64 + 64);
    }

    #[test]
    fn missing_key_is_error() {
        assert!(ModelMeta::parse("batch=1\n").is_err());
    }

    #[test]
    fn bad_dims_are_error() {
        let text = SAMPLE.replace("52000,16", "52000,x");
        assert!(ModelMeta::parse(&text).is_err());
    }

    #[test]
    fn paths_layout() {
        let p = ArtifactPaths::in_dir("/tmp/a");
        assert!(p.train_hlo.ends_with("train_step.hlo.txt"));
        assert!(p.meta.ends_with("meta.txt"));
    }
}
