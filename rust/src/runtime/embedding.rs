//! Sharded embedding tables with a hot/cold memory hierarchy — the DLRM
//! memory wall (ROADMAP item 1; BagPipe, and the heterogeneous
//! acceleration pipeline of Adnan et al. in PAPERS.md).
//!
//! Production recommender models are dominated by embedding tables that
//! exceed any single device's memory. This module adds **model
//! parallelism alongside the existing data parallelism**: the embedding
//! pool's rows are sharded across the device fleet by a [`ShardPolicy`],
//! and each lane keeps only a bounded **hot cache** of rows resident in
//! its [`DeviceArena`](crate::devmem::DeviceArena) (a pinned
//! [`CacheRegion`]), spilling everything else to a simulated **host cold
//! tier**. Promotion/demotion traffic is costed against the calibrated
//! channel models — first-touch promotions stream from SSD, re-promotions
//! come from host memory, peer-owned rows cross the P2P fabric, and
//! evictions write back to host.
//!
//! ```text
//!                 row ownership (ShardPolicy::HashMod, 3 devices)
//!   flat emb pool  [ r0 r1 r2 r3 r4 r5 r6 r7 ... ]
//!                     │  │  │  │  │  │  │  │
//!                    d2 d0 d1 d0 d2 d1 d0 d2      owner = mix64(row) % devices
//!
//!          device d's view of its shard
//!   ┌───────────────────────────── device d ────────────────────────────┐
//!   │  hot cache (CacheRegion in the DeviceArena, ≤ cache_rows rows)    │
//!   │  [ r3 r6 r1 ... ]   LRU; ByteLedger: promoted = demoted+resident  │
//!   └───────▲────────────────────────────┬────────────────────────────--┘
//!      promote (SsdRead first touch,     │ demote on eviction
//!      P2pToGpu re-promote / peer row)   ▼ (HostDmaWrite)
//!   ┌────────────────────── simulated host cold tier ──────────────────┐
//!   │            every row not currently resident on a device          │
//!   └──────────────────────────────────────────────────────────────────┘
//! ```
//!
//! # Prefetch timeline vs consumer timeline
//!
//! The router stamps and routes every shard **before** its consumer runs,
//! so the producer side of a lane sees each batch's categorical-id set
//! `lookahead` shards early (BagPipe's core observation). The lane's pack
//! worker extracts the id trace from the packed batch it just staged and
//! issues the promotion batch immediately; the commit (hit/miss walk) of
//! a slot happens `lookahead` slots later, by which time the prefetch has
//! usually completed and the consumer observes zero wait:
//!
//! ```text
//!   producer:  stage k     stage k+1    stage k+2    stage k+3
//!              prefetch k  prefetch k+1 prefetch k+2 prefetch k+3
//!   consumer:                           commit k     commit k+1   (lookahead=2)
//!                                       wait = max(0, pf_done(k) − now)
//! ```
//!
//! With `lookahead = 0` every miss is a demand fetch whose transfer time
//! is fully exposed to the consumer (`prefetch_wait_s`).
//!
//! # Determinism
//!
//! The authoritative embedding **values** stay in each replica's flat
//! `f32` state — the cache is a deterministic placement/cost simulation
//! (hit/miss counters, byte ledgers, simulated clocks) layered over the
//! unchanged training arithmetic. That is what makes the cached, sharded
//! execution **bitwise identical** to the uncached reference across every
//! device count × cache size × lookahead depth
//! (`rust/tests/prop_embedding.rs`), exactly like the rest of the
//! simulation (channel models cost the zero-copy path without perturbing
//! it). Cache state is per-lane and advanced only by that lane's pack
//! worker in delivery order, so hit/miss accounting is
//! schedule-independent too.

use std::collections::{BTreeMap, HashMap, HashSet};

use crate::coordinator::online::OnlineVocab;
use crate::devmem::CacheRegion;
use crate::error::{EtlError, Result};
use crate::etl::ops::kernels::mix64;
use crate::memsys::{ChannelModel, Path};
use crate::metrics::ByteLedger;
use crate::runtime::artifacts::ModelMeta;
use crate::util::fault::{self, site as fsite};

/// Wire bytes per embedding-row gradient shipped to the owning shard
/// (u32 row id + f64 gradient).
pub const GRAD_WIRE_BYTES: u64 = 12;

/// Bounded retry budget for a faulted prefetch transfer (mirrors the DMA
/// engine's transient-retry ladder).
const PREFETCH_MAX_ATTEMPTS: u32 = 4;

/// How embedding rows are assigned an owning device.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPolicy {
    /// `owner = mix64(row) % devices` — load-balanced, the default.
    HashMod,
    /// Contiguous row blocks: device `d` owns rows
    /// `[d*ceil(rows/devices), (d+1)*ceil(rows/devices))`.
    Block,
}

/// Knobs of the sharded embedding layer, carried on
/// [`TrainConfig`](crate::coordinator::train_loop::TrainConfig).
#[derive(Debug, Clone, PartialEq)]
pub struct EmbeddingConfig {
    /// Hot rows resident per device (clamped to `[1, table rows]`;
    /// the byte reservation must fit the arena's memory budget).
    pub cache_rows: usize,
    /// Shards of router lookahead between prefetch issue and commit.
    pub lookahead: usize,
    /// Row → owning-device assignment.
    pub policy: ShardPolicy,
    /// Rows to pre-promote before the first batch (typically from
    /// [`hot_rows_from_vocab`] — `OnlineVocab`'s admission order is the
    /// hotness signal).
    pub hot_seed: Vec<u32>,
}

impl Default for EmbeddingConfig {
    fn default() -> Self {
        EmbeddingConfig {
            cache_rows: 4096,
            lookahead: 2,
            policy: ShardPolicy::HashMod,
            hot_seed: Vec::new(),
        }
    }
}

/// The sharded embedding table's *geometry*: how many rows exist, how
/// wide each row is on the wire, and which device owns each row. The row
/// values themselves stay in the trainer's flat state (see module docs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EmbeddingTable {
    rows: usize,
    row_bytes: u64,
    devices: usize,
    policy: ShardPolicy,
    vocab: usize,
    n_sparse: usize,
}

impl EmbeddingTable {
    /// Derive the table from artifact metadata: one row per flat
    /// embedding-pool slot (`param_count - n_dense - 1`), each modeled at
    /// the artifact's `embed_dim × f32` wire width (what a production
    /// DLRM actually moves per lookup).
    pub fn from_meta(meta: &ModelMeta, devices: usize, policy: ShardPolicy) -> Result<EmbeddingTable> {
        if devices == 0 {
            return Err(EtlError::Runtime("embedding table needs at least one device".into()));
        }
        let p = meta.param_count();
        let nd = meta.n_dense;
        if p < nd + 2 {
            return Err(EtlError::Runtime(
                "artifact has no embedding pool: nothing to shard".into(),
            ));
        }
        Ok(EmbeddingTable {
            rows: p - nd - 1,
            row_bytes: 4 * meta.embed_dim.max(1) as u64,
            devices,
            policy,
            vocab: meta.vocab.max(1),
            n_sparse: meta.n_sparse,
        })
    }

    /// Total rows in the pool.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Modeled wire bytes per row.
    pub fn row_bytes(&self) -> u64 {
        self.row_bytes
    }

    /// Devices the rows are sharded over.
    pub fn devices(&self) -> usize {
        self.devices
    }

    /// Total modeled table footprint — compare against a single arena's
    /// budget to see the memory wall.
    pub fn total_bytes(&self) -> u64 {
        self.rows as u64 * self.row_bytes
    }

    /// Owning device of `row`.
    pub fn owner(&self, row: u32) -> usize {
        match self.policy {
            ShardPolicy::HashMod => (mix64(row as u64) % self.devices as u64) as usize,
            ShardPolicy::Block => {
                let per = self.rows.div_ceil(self.devices).max(1);
                ((row as usize) / per).min(self.devices - 1)
            }
        }
    }

    /// The embedding-row id trace of a packed batch's first `rows` rows,
    /// in lookup order — exactly the rows the trainer's forward pass will
    /// read, derived with the trainer's own index arithmetic
    /// (`(s·vocab + v mod vocab) mod pool`).
    pub fn trace(&self, sparse: &[i32], rows: usize) -> Vec<u32> {
        let ns = self.n_sparse;
        let mut out = Vec::with_capacity(rows * ns);
        for r in 0..rows {
            for s in 0..ns {
                let v = sparse[r * ns + s].rem_euclid(self.vocab as i32) as usize;
                out.push(((s * self.vocab + v) % self.rows) as u32);
            }
        }
        out
    }
}

/// Cache/exchange observables of one lane's embedding shard, rolled up
/// into [`TrainReport`](crate::coordinator::train_loop::TrainReport).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EmbCacheStats {
    /// Lane (device index) the shard cache belongs to.
    pub device: usize,
    /// Embedding-row lookups committed (`rows × n_sparse` per step).
    pub lookups: u64,
    /// Lookups served from the hot cache.
    pub hits: u64,
    /// Lookups that demand-promoted from the cold tier.
    pub misses: u64,
    /// Bytes promoted into the hot tier (seed + prefetch + demand).
    pub promoted_bytes: u64,
    /// Bytes demoted back to the cold tier on eviction.
    pub demoted_bytes: u64,
    /// Bytes still resident in the hot tier at drain.
    pub resident_bytes: u64,
    /// Cross-device traffic: peer-owned row fetches plus embedding-row
    /// gradients routed to their owning shard.
    pub exchange_bytes: u64,
    /// Consumer seconds exposed waiting on promotions (simulated).
    pub prefetch_wait_s: f64,
    /// Rows re-homed from the cold tier because their owner lane died.
    pub rehomed_rows: u64,
    /// Prefetch transfer attempts retried after an injected fault.
    pub retried_prefetches: u64,
    /// Promotion batches abandoned after the retry budget (rows stay
    /// cold and surface as later misses — graceful degradation).
    pub failed_prefetches: u64,
}

/// One device's shard of the embedding table: the LRU hot-row set pinned
/// in its [`CacheRegion`], the promotion/demotion cost model, and the
/// exactly-once byte ledger. Owned and advanced by a single lane thread
/// in delivery order (see module docs on determinism).
#[derive(Debug)]
pub struct EmbShardCache {
    table: EmbeddingTable,
    cap_rows: usize,
    region: CacheRegion,
    /// Resident row → LRU tick.
    resident: HashMap<u32, u64>,
    /// LRU tick → row (ordered eviction scan).
    lru: BTreeMap<u64, u32>,
    tick: u64,
    /// Rows ever promoted on this device: a first touch streams from SSD,
    /// a re-promotion comes from the host cold tier.
    touched: HashSet<u32>,
    ledger: ByteLedger,
    stats: EmbCacheStats,
    /// Simulated completion clock of this lane's promotion engine.
    pf_clock: f64,
    promo_ordinal: u64,
    chan_peer: ChannelModel,
    chan_ssd: ChannelModel,
    chan_host_rd: ChannelModel,
    chan_host_wr: ChannelModel,
}

impl EmbShardCache {
    /// Build device `region.device`'s shard cache holding at most
    /// `cache_rows` hot rows. The region must fit them.
    pub fn new(table: EmbeddingTable, cache_rows: usize, region: CacheRegion) -> Result<EmbShardCache> {
        let cap_rows = cache_rows.min(table.rows()).max(1);
        if cap_rows as u64 * table.row_bytes() > region.bytes {
            return Err(EtlError::Mem(format!(
                "cache region of {} B on device {} cannot hold {cap_rows} rows of {} B",
                region.bytes,
                region.device,
                table.row_bytes()
            )));
        }
        Ok(EmbShardCache {
            stats: EmbCacheStats { device: region.device, ..EmbCacheStats::default() },
            table,
            cap_rows,
            region,
            resident: HashMap::new(),
            lru: BTreeMap::new(),
            tick: 0,
            touched: HashSet::new(),
            ledger: ByteLedger::default(),
            pf_clock: 0.0,
            promo_ordinal: 0,
            chan_peer: ChannelModel::of(Path::P2pToGpu),
            chan_ssd: ChannelModel::of(Path::SsdRead),
            chan_host_rd: ChannelModel::of(Path::HostDmaRead),
            chan_host_wr: ChannelModel::of(Path::HostDmaWrite),
        })
    }

    /// Lane (device index) this shard belongs to.
    pub fn device(&self) -> usize {
        self.region.device
    }

    /// The table geometry this shard caches rows of.
    pub fn table(&self) -> &EmbeddingTable {
        &self.table
    }

    /// Hot-row capacity after clamping.
    pub fn cap_rows(&self) -> usize {
        self.cap_rows
    }

    /// Rows currently resident.
    pub fn resident_rows(&self) -> usize {
        self.resident.len()
    }

    /// The exactly-once promotion/demotion ledger.
    pub fn ledger(&self) -> ByteLedger {
        self.ledger
    }

    /// Pre-promote the seed hot set (truncated to capacity) at simulated
    /// time zero — warmup traffic, costed like any other promotion.
    pub fn seed<F: Fn(usize) -> bool>(&mut self, rows: &[u32], alive: &F) {
        let mut seen = HashSet::new();
        let uniq: Vec<u32> = rows
            .iter()
            .copied()
            .filter(|r| (*r as usize) < self.table.rows() && seen.insert(*r))
            .take(self.cap_rows)
            .collect();
        self.promote(&uniq, 0.0, alive);
    }

    /// Promote `rows` (deduplicated, possibly already-resident entries are
    /// skipped) as one batched transfer issued at `issue_s`. Returns the
    /// simulated completion time of the batch. `alive(owner)` gates which
    /// peer shards can serve their rows: a dead owner's rows are re-homed
    /// from the host cold tier instead of silently corrupting lookups.
    pub fn promote<F: Fn(usize) -> bool>(&mut self, rows: &[u32], issue_s: f64, alive: &F) -> f64 {
        let start = self.pf_clock.max(issue_s);
        // Classify the batch by transfer source and total the bytes.
        let rb = self.table.row_bytes();
        let mut ssd_bytes = 0u64;
        let mut host_bytes = 0u64;
        let mut peer_bytes = 0u64;
        let mut to_insert: Vec<u32> = Vec::new();
        let mut batch_seen = HashSet::new();
        let mut rehomed = 0u64;
        let mut exchange = 0u64;
        for &row in rows {
            if self.resident.contains_key(&row) || !batch_seen.insert(row) {
                continue;
            }
            let owner = self.table.owner(row);
            if owner != self.device() {
                if alive(owner) {
                    // Fetched across the P2P fabric from the owning shard.
                    peer_bytes += rb;
                    exchange += rb;
                } else {
                    // Owner lane is gone: re-home from the cold tier.
                    host_bytes += rb;
                    rehomed += 1;
                }
            } else if self.touched.contains(&row) {
                host_bytes += rb;
            } else {
                ssd_bytes += rb;
            }
            to_insert.push(row);
        }
        if to_insert.is_empty() {
            return start;
        }
        let cost = self.chan_ssd.time(ssd_bytes)
            + self.chan_host_rd.time(host_bytes)
            + self.chan_peer.time(peer_bytes);

        // Transient fault ladder on the prefetch transfer (site PREFETCH,
        // key = device<<48 | promotion ordinal): each failed attempt burns
        // the wire time; past the budget the batch is abandoned and the
        // rows stay cold (they surface as later misses).
        let key = ((self.device() as u64) << 48) | self.promo_ordinal;
        self.promo_ordinal += 1;
        let mut attempts = 0u32;
        let mut done = start;
        while fault::inject(fsite::PREFETCH, key) {
            attempts += 1;
            done += cost;
            self.stats.retried_prefetches += 1;
            if attempts >= PREFETCH_MAX_ATTEMPTS {
                self.stats.failed_prefetches += 1;
                self.pf_clock = done;
                return done;
            }
        }
        done += cost;
        self.pf_clock = done;

        self.stats.exchange_bytes += exchange;
        self.stats.rehomed_rows += rehomed;
        for row in to_insert {
            self.insert_resident(row);
        }
        done
    }

    /// Make `row` resident, evicting the LRU row (a demotion write-back
    /// to the host cold tier) when the cache is full.
    fn insert_resident(&mut self, row: u32) {
        let rb = self.table.row_bytes();
        if self.resident.len() >= self.cap_rows {
            if let Some((&old_tick, &victim)) = self.lru.iter().next() {
                self.lru.remove(&old_tick);
                self.resident.remove(&victim);
                self.ledger.demote(rb);
                self.stats.demoted_bytes += rb;
                // Demotion cost rides the host write channel on the same
                // promotion engine clock.
                self.pf_clock += self.chan_host_wr.time(rb);
            }
        }
        self.tick += 1;
        self.resident.insert(row, self.tick);
        self.lru.insert(self.tick, row);
        self.touched.insert(row);
        self.ledger.promote(rb);
        self.stats.promoted_bytes += rb;
    }

    /// Commit one staged slot's lookups at consumer time `now_s`:
    /// `pf_done_s` is the completion time of the prefetch issued for this
    /// slot (its exposure, if any, is charged to `prefetch_wait_s`), then
    /// the trace is walked in lookup order — hits touch the LRU, misses
    /// demand-promote with their transfer fully exposed. Embedding-row
    /// gradients for peer-owned rows are charged to `exchange_bytes`.
    pub fn commit<F: Fn(usize) -> bool>(
        &mut self,
        trace: &[u32],
        pf_done_s: f64,
        now_s: f64,
        alive: &F,
    ) {
        self.stats.prefetch_wait_s += (pf_done_s - now_s).max(0.0);
        let mut now = now_s.max(pf_done_s);
        for &row in trace {
            self.stats.lookups += 1;
            if let Some(tick) = self.resident.get(&row).copied() {
                self.stats.hits += 1;
                self.lru.remove(&tick);
                self.tick += 1;
                self.resident.insert(row, self.tick);
                self.lru.insert(self.tick, row);
            } else {
                self.stats.misses += 1;
                let done = self.promote(&[row], now, alive);
                self.stats.prefetch_wait_s += (done - now).max(0.0);
                now = now.max(done);
            }
            let owner = self.table.owner(row);
            if owner != self.device() && alive(owner) {
                self.stats.exchange_bytes += GRAD_WIRE_BYTES;
            }
        }
    }

    /// Drain into the final per-lane stats (resident bytes snapshotted;
    /// the ledger is guaranteed to balance against them).
    pub fn into_stats(mut self) -> EmbCacheStats {
        self.stats.resident_bytes = self.resident.len() as u64 * self.table.row_bytes();
        debug_assert!(self.ledger.balances(self.stats.resident_bytes));
        self.stats
    }
}

/// Derive the initial hot set from `OnlineVocab`'s admission stats: the
/// first-appearance admission order *is* the hotness ranking (head of the
/// popularity distribution), so the earliest-admitted vocabulary slots
/// map to the rows worth pre-promoting. Returns deduplicated rows in
/// hotness order, truncated to `limit`.
pub fn hot_rows_from_vocab(vocab: &OnlineVocab, table: &EmbeddingTable, limit: usize) -> Vec<u32> {
    let mut seen = HashSet::new();
    let mut out = Vec::new();
    'outer: for slot in 0..vocab.len() {
        for s in 0..table.n_sparse {
            let row = ((s * table.vocab + slot % table.vocab) % table.rows) as u32;
            if seen.insert(row) {
                out.push(row);
                if out.len() >= limit {
                    break 'outer;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devmem::{ArenaConfig, DeviceArena};
    use crate::runtime::artifacts::ParamSpec;

    fn meta(vocab: usize, n_sparse: usize, embed_dim: usize, pool: usize) -> ModelMeta {
        ModelMeta {
            batch: 4,
            n_dense: 2,
            n_sparse,
            vocab,
            embed_dim,
            params: vec![
                ParamSpec { name: "emb".into(), dims: vec![pool] },
                ParamSpec { name: "w1".into(), dims: vec![2] },
                ParamSpec { name: "b1".into(), dims: vec![1] },
            ],
            extra: Default::default(),
        }
    }

    fn table(devices: usize) -> EmbeddingTable {
        EmbeddingTable::from_meta(&meta(10, 2, 4, 40), devices, ShardPolicy::HashMod).unwrap()
    }

    fn region(rows: usize, t: &EmbeddingTable) -> CacheRegion {
        let arena = DeviceArena::new(ArenaConfig { slots: 2, slot_bytes: 1 << 20 });
        arena.reserve_cache(rows as u64 * t.row_bytes()).unwrap()
    }

    const ALL_ALIVE: fn(usize) -> bool = |_| true;

    #[test]
    fn table_geometry_matches_trainer_layout() {
        let t = table(2);
        assert_eq!(t.rows(), 40); // pool + w1 + b1 = 43 params, minus nd+1
        assert_eq!(t.row_bytes(), 16);
        assert_eq!(t.total_bytes(), 640);
        // Every row has exactly one owner in range.
        for r in 0..t.rows() as u32 {
            assert!(t.owner(r) < 2);
        }
        // Block policy assigns contiguous halves.
        let b = EmbeddingTable::from_meta(&meta(10, 2, 4, 40), 2, ShardPolicy::Block).unwrap();
        assert_eq!(b.owner(0), 0);
        assert_eq!(b.owner(19), 0);
        assert_eq!(b.owner(20), 1);
        assert_eq!(b.owner(39), 1);
        // Dense-only artifacts have nothing to shard.
        let dense_only = ModelMeta {
            batch: 1,
            n_dense: 2,
            n_sparse: 0,
            vocab: 1,
            embed_dim: 1,
            params: vec![
                ParamSpec { name: "w1".into(), dims: vec![2] },
                ParamSpec { name: "b1".into(), dims: vec![1] },
            ],
            extra: Default::default(),
        };
        assert!(EmbeddingTable::from_meta(&dense_only, 1, ShardPolicy::HashMod).is_err());
    }

    #[test]
    fn trace_mirrors_trainer_index_arithmetic() {
        let t = table(1);
        // vocab=10, ns=2, pool=40: row = (s*10 + v%10) % 40.
        let sparse = vec![3, 17, -1, 42];
        let trace = t.trace(&sparse, 2);
        assert_eq!(trace, vec![3, 17, 9, 12]);
        // Truncated row count limits the trace.
        assert_eq!(t.trace(&sparse, 1), vec![3, 17]);
    }

    #[test]
    fn cache_hits_after_promotion_and_counts_exactly_once() {
        let t = table(1);
        let mut c = EmbShardCache::new(t.clone(), 4, region(4, &t)).unwrap();
        let done = c.promote(&[1, 2, 3], 0.0, &ALL_ALIVE);
        assert!(done > 0.0, "promotion must cost simulated time");
        c.commit(&[1, 2, 3, 9, 1], done, done, &ALL_ALIVE);
        let st = c.into_stats();
        assert_eq!(st.lookups, 5);
        assert_eq!(st.hits, 4); // 1,2,3 prefetched; second 1 hits; 9 missed
        assert_eq!(st.misses, 1);
        assert_eq!(st.hits + st.misses, st.lookups);
        assert_eq!(st.promoted_bytes, 4 * 16);
        assert_eq!(st.resident_bytes, 4 * 16);
        assert_eq!(st.demoted_bytes, 0);
    }

    #[test]
    fn eviction_demotes_and_ledger_balances() {
        let t = table(1);
        let mut c = EmbShardCache::new(t.clone(), 2, region(2, &t)).unwrap();
        c.promote(&[1, 2], 0.0, &ALL_ALIVE);
        // LRU order: touch 1 so 2 is the victim.
        c.commit(&[1], 0.0, 0.0, &ALL_ALIVE);
        c.promote(&[3], 0.0, &ALL_ALIVE);
        assert_eq!(c.resident_rows(), 2);
        let ledger = c.ledger();
        assert!(ledger.balances(2 * 16));
        let st = c.into_stats();
        assert_eq!(st.promoted_bytes, 3 * 16);
        assert_eq!(st.demoted_bytes, 16);
        assert_eq!(st.resident_bytes, 2 * 16);
        assert_eq!(st.promoted_bytes, st.demoted_bytes + st.resident_bytes);
    }

    #[test]
    fn lru_touch_on_hit_protects_hot_rows() {
        let t = table(1);
        let mut c = EmbShardCache::new(t.clone(), 2, region(2, &t)).unwrap();
        c.promote(&[7, 8], 0.0, &ALL_ALIVE);
        c.commit(&[7], 1.0, 1.0, &ALL_ALIVE); // 7 is now MRU
        c.promote(&[9], 1.0, &ALL_ALIVE); // evicts 8, not 7
        c.commit(&[7, 9], 2.0, 2.0, &ALL_ALIVE);
        let st = c.into_stats();
        assert_eq!(st.misses, 0);
        assert_eq!(st.hits, 3);
    }

    #[test]
    fn demand_miss_exposes_wait_and_prefetch_hides_it() {
        let t = table(1);
        // Demand path: commit with nothing prefetched.
        let mut c = EmbShardCache::new(t.clone(), 4, region(4, &t)).unwrap();
        c.commit(&[1, 2], 0.0, 0.0, &ALL_ALIVE);
        let demand = c.into_stats();
        assert_eq!(demand.misses, 2);
        assert!(demand.prefetch_wait_s > 0.0, "demand misses must expose wait");

        // Prefetch path: same rows promoted long before the commit time.
        let mut c = EmbShardCache::new(t.clone(), 4, region(4, &t)).unwrap();
        let done = c.promote(&[1, 2], 0.0, &ALL_ALIVE);
        c.commit(&[1, 2], done, done + 1.0, &ALL_ALIVE);
        let pf = c.into_stats();
        assert_eq!(pf.misses, 0);
        assert_eq!(pf.prefetch_wait_s, 0.0, "completed prefetch hides the transfer");
    }

    #[test]
    fn peer_rows_cost_exchange_and_dead_owner_rehomes() {
        let t = table(4);
        let my = t
            .clone();
        // Build the cache on device 0 and promote rows owned elsewhere.
        let arena = DeviceArena::new(ArenaConfig { slots: 2, slot_bytes: 1 << 20 });
        let region = arena.reserve_cache(8 * my.row_bytes()).unwrap();
        let mut c = EmbShardCache::new(my.clone(), 8, region).unwrap();
        let peer_row = (0..my.rows() as u32).find(|r| my.owner(*r) == 1).unwrap();
        let dead_row = (0..my.rows() as u32).find(|r| my.owner(*r) == 2).unwrap();
        let alive = |o: usize| o != 2;
        c.promote(&[peer_row, dead_row], 0.0, &alive);
        c.commit(&[peer_row, dead_row], 1.0, 1.0, &alive);
        let st = c.into_stats();
        assert_eq!(st.rehomed_rows, 1);
        // Peer row: fetched over P2P + its gradient routed back.
        assert_eq!(st.exchange_bytes, my.row_bytes() + GRAD_WIRE_BYTES);
        assert_eq!(st.misses, 0);
    }

    #[test]
    fn prefetch_faults_retry_then_abandon() {
        let t = table(1);
        let mut c = EmbShardCache::new(t.clone(), 4, region(4, &t)).unwrap();
        // Transient: 2 failures then success — rows land, retries counted.
        let plan = crate::util::fault::FaultPlan::new(9).with(fsite::PREFETCH, crate::util::fault::RATE_FULL, 2);
        {
            let _g = plan.install();
            let done = c.promote(&[1, 2], 0.0, &ALL_ALIVE);
            assert!(done > 0.0);
        }
        assert_eq!(c.resident_rows(), 2);

        // Permanent: budget exhausts, batch abandoned, rows stay cold.
        let plan = crate::util::fault::FaultPlan::new(9)
            .with(fsite::PREFETCH, crate::util::fault::RATE_FULL, crate::util::fault::PERMANENT);
        {
            let _g = plan.install();
            c.promote(&[5, 6], 0.0, &ALL_ALIVE);
        }
        assert_eq!(c.resident_rows(), 2, "abandoned batch must not insert rows");
        let st = c.into_stats();
        assert_eq!(st.retried_prefetches as u32, 2 + PREFETCH_MAX_ATTEMPTS);
        assert_eq!(st.failed_prefetches, 1);
        assert!(st.promoted_bytes >= st.demoted_bytes + st.resident_bytes);
    }

    #[test]
    fn seed_truncates_to_capacity_and_dedups() {
        let t = table(1);
        let mut c = EmbShardCache::new(t.clone(), 2, region(2, &t)).unwrap();
        c.seed(&[4, 4, 5, 6, 7], &ALL_ALIVE);
        assert_eq!(c.resident_rows(), 2);
        let st = c.into_stats();
        // No churn: exactly capacity promoted, nothing demoted.
        assert_eq!(st.promoted_bytes, 2 * 16);
        assert_eq!(st.demoted_bytes, 0);
    }

    #[test]
    fn hot_rows_from_vocab_follow_admission_order() {
        let t = table(1);
        let mut v = OnlineVocab::new(8);
        for tok in [100, 200, 300] {
            v.map(tok);
        }
        // Slots 0,1,2 admitted; ns=2, vocab=10, pool=40:
        // rows (0,10), (1,11), (2,12) in hotness order.
        let rows = hot_rows_from_vocab(&v, &t, 16);
        assert_eq!(rows, vec![0, 10, 1, 11, 2, 12]);
        assert_eq!(hot_rows_from_vocab(&v, &t, 3), vec![0, 10, 1]);
    }

    #[test]
    fn cache_region_must_hold_capacity() {
        let t = table(1);
        let small = region(1, &t);
        assert!(EmbShardCache::new(t.clone(), 4, small).is_err());
    }
}
