//! PJRT runtime (the paper's GPU-trainer stand-in): loads the HLO-text
//! artifacts AOT-compiled by `python/compile/aot.py`, compiles them on the
//! PJRT CPU client, and drives training with a **device-resident flat
//! state buffer** — all parameters live in one `f32[state_len]` array with
//! a trailing loss slot; each step the host uploads only the packed batch
//! and re-feeds the previous output buffer (`execute_b`), mirroring the
//! paper's zero-copy ingest discipline. A second tiny executable slices
//! the loss slot out on-device (the CPU PJRT plugin lacks CopyRawToHost).
//!
//! Interchange is HLO *text* (xla_extension 0.5.1 rejects jax≥0.5's
//! 64-bit-id serialized protos; the text parser reassigns ids).

pub mod artifacts;
pub mod checkpoint;

use crate::coordinator::packer::PackedBatch;
use crate::error::{EtlError, Result};
use crate::util::prng::Rng;
use artifacts::{ArtifactPaths, ModelMeta};

/// Wrap an `xla::Error` into our error type.
fn xe(e: xla::Error) -> EtlError {
    EtlError::Runtime(e.to_string())
}

/// The PJRT engine: one CPU client shared by all executables.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        Ok(Engine { client: xla::PjRtClient::cpu().map_err(xe)? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file.
    pub fn compile_hlo(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path).map_err(xe)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(xe)
    }

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, dims, None).map_err(xe)
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, dims, None).map_err(xe)
    }
}

impl ModelMeta {
    /// Flat state length: all parameters + 1 loss slot.
    pub fn state_len(&self) -> usize {
        self.param_count() + 1
    }
}

/// A loaded DLRM train step with a device-resident flat state buffer.
pub struct Trainer {
    engine: Engine,
    step_exe: xla::PjRtLoadedExecutable,
    loss_exe: xla::PjRtLoadedExecutable,
    pub meta: ModelMeta,
    state: xla::PjRtBuffer,
    /// Steps executed.
    pub steps: u64,
}

impl Trainer {
    /// Load artifacts, compile both executables, and initialize the state
    /// buffer with a deterministic Glorot-ish scheme.
    pub fn load(paths: &ArtifactPaths, seed: u64) -> Result<Trainer> {
        if !paths.exist() {
            return Err(EtlError::Runtime(format!(
                "artifacts not found in {:?} — run `make artifacts`",
                paths.dir
            )));
        }
        let engine = Engine::cpu()?;
        let meta = ModelMeta::load(&paths.meta)?;
        let step_exe = engine.compile_hlo(&paths.train_hlo)?;
        let loss_exe = engine.compile_hlo(&paths.loss_hlo)?;
        let state = engine.upload_f32(&init_state(&meta, seed), &[meta.state_len()])?;
        Ok(Trainer { engine, step_exe, loss_exe, meta, state, steps: 0 })
    }

    /// Reset parameters.
    pub fn init_params(&mut self, seed: u64) -> Result<()> {
        self.state = self
            .engine
            .upload_f32(&init_state(&self.meta, seed), &[self.meta.state_len()])?;
        self.steps = 0;
        Ok(())
    }

    /// Run one training step on a packed batch; the state stays on device.
    pub fn step(&mut self, batch: &PackedBatch) -> Result<()> {
        let m = &self.meta;
        if batch.rows != m.batch || batch.n_dense != m.n_dense || batch.n_sparse != m.n_sparse {
            return Err(EtlError::Runtime(format!(
                "batch shape ({}, {}, {}) != artifact shape ({}, {}, {})",
                batch.rows, batch.n_dense, batch.n_sparse, m.batch, m.n_dense, m.n_sparse
            )));
        }
        // Fold indices into the (possibly smaller) artifact vocabulary.
        let vocab = m.vocab as i32;
        let sparse: Vec<i32> = batch.sparse.iter().map(|&v| v % vocab).collect();

        let dense_b = self.engine.upload_f32(&batch.dense, &[batch.rows, m.n_dense])?;
        let sparse_b = self.engine.upload_i32(&sparse, &[batch.rows, m.n_sparse])?;
        let labels_b = self.engine.upload_f32(&batch.labels, &[batch.rows])?;

        let mut outs = self
            .step_exe
            .execute_b(&[&self.state, &dense_b, &sparse_b, &labels_b])
            .map_err(xe)?;
        let mut replica = outs
            .drain(..)
            .next()
            .ok_or_else(|| EtlError::Runtime("no outputs".into()))?;
        if replica.len() != 1 {
            return Err(EtlError::Runtime(format!(
                "expected 1 state output, got {}",
                replica.len()
            )));
        }
        self.state = replica.remove(0);
        self.steps += 1;
        Ok(())
    }

    /// Read the loss slot of the current state (runs the on-device slice
    /// executable; downloads 4 bytes).
    pub fn loss(&self) -> Result<f32> {
        let mut outs = self.loss_exe.execute_b(&[&self.state]).map_err(xe)?;
        let buf = outs
            .drain(..)
            .next()
            .and_then(|mut r| if r.is_empty() { None } else { Some(r.remove(0)) })
            .ok_or_else(|| EtlError::Runtime("loss executable produced no output".into()))?;
        let lit = buf.to_literal_sync().map_err(xe)?;
        lit.get_first_element().map_err(xe)
    }

    /// Convenience: step then read loss.
    pub fn step_with_loss(&mut self, batch: &PackedBatch) -> Result<f32> {
        self.step(batch)?;
        self.loss()
    }

    /// Download the full state (tests / checkpoints).
    pub fn state_to_vec(&self) -> Result<Vec<f32>> {
        let lit = self.state.to_literal_sync().map_err(xe)?;
        lit.to_vec::<f32>().map_err(xe)
    }

    /// Download one named parameter tensor by slicing the host copy.
    pub fn param_to_vec(&self, name: &str) -> Result<Vec<f32>> {
        let state = self.state_to_vec()?;
        let mut off = 0usize;
        for p in &self.meta.params {
            let n = p.elements();
            if p.name == name {
                return Ok(state[off..off + n].to_vec());
            }
            off += n;
        }
        Err(EtlError::Runtime(format!("no parameter named {name:?}")))
    }

    pub fn param_count(&self) -> usize {
        self.meta.param_count()
    }

    /// Capture a checkpoint of the current device state (downloads the
    /// flat state once; §2's warm-start path).
    pub fn checkpoint(&self, etl: &crate::etl::dag::EtlState) -> Result<checkpoint::Checkpoint> {
        Ok(checkpoint::Checkpoint::capture(self.steps, self.state_to_vec()?, etl))
    }

    /// Restore from a checkpoint: uploads the state and resumes the step
    /// counter. Fails if the state length does not match the artifact.
    pub fn restore(&mut self, ck: &checkpoint::Checkpoint) -> Result<()> {
        if ck.state.len() != self.meta.state_len() {
            return Err(EtlError::Runtime(format!(
                "checkpoint state_len {} != artifact {}",
                ck.state.len(),
                self.meta.state_len()
            )));
        }
        self.state = self.engine.upload_f32(&ck.state, &[ck.state.len()])?;
        self.steps = ck.step;
        Ok(())
    }
}

/// Host-side initial state: per-parameter init + zeroed loss slot.
pub fn init_state(meta: &ModelMeta, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut state = Vec::with_capacity(meta.state_len());
    for p in &meta.params {
        let n = p.elements();
        if p.name.starts_with('b') {
            state.extend(std::iter::repeat(0f32).take(n));
        } else if p.name.starts_with("emb") {
            state.extend((0..n).map(|_| (rng.normal() as f32) * 0.05));
        } else {
            let fan_in = *p.dims.first().unwrap_or(&1) as f64;
            let fan_out = *p.dims.last().unwrap_or(&1) as f64;
            let scale = (2.0 / (fan_in + fan_out)).sqrt();
            state.extend((0..n).map(|_| (rng.normal() * scale) as f32));
        }
    }
    state.push(0.0); // loss slot
    state
}

#[cfg(test)]
mod tests {
    use super::*;
    use artifacts::ParamSpec;

    #[test]
    fn missing_artifacts_error_is_actionable() {
        let paths = ArtifactPaths::in_dir("/nonexistent");
        let msg = match Trainer::load(&paths, 0) {
            Err(e) => format!("{e}"),
            Ok(_) => panic!("expected an error"),
        };
        assert!(msg.contains("make artifacts"), "{msg}");
    }

    #[test]
    fn init_state_layout() {
        let meta = ModelMeta {
            batch: 4,
            n_dense: 2,
            n_sparse: 2,
            vocab: 10,
            embed_dim: 4,
            params: vec![
                ParamSpec { name: "emb".into(), dims: vec![20, 4] },
                ParamSpec { name: "w1".into(), dims: vec![2, 8] },
                ParamSpec { name: "b1".into(), dims: vec![8] },
            ],
            extra: Default::default(),
        };
        let s = init_state(&meta, 42);
        assert_eq!(s.len(), 80 + 16 + 8 + 1);
        // biases zero, loss slot zero
        assert!(s[96..104].iter().all(|&v| v == 0.0));
        assert_eq!(*s.last().unwrap(), 0.0);
        // deterministic
        assert_eq!(s, init_state(&meta, 42));
        assert_ne!(s, init_state(&meta, 43));
    }
}
