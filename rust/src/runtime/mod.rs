//! Trainer runtime (the paper's GPU-trainer stand-in).
//!
//! The default build uses a **pure-Rust reference trainer**: a
//! deterministic logistic-regression DLRM stand-in over the same flat
//! `f32[state_len]` device-state layout the PJRT path uses (all
//! parameters in one buffer with a trailing loss slot). It consumes
//! [`PackedBatch`]es straight from the packer — the coordinator, staging
//! and checkpoint layers are exercised end-to-end without any native
//! dependency.
//!
//! The original PJRT/XLA-backed trainer (AOT-compiled JAX/Pallas DLRM,
//! device-resident state, HLO-text interchange) is preserved in
//! [`pjrt`](self) behind the `pjrt` cargo feature; enabling it requires
//! vendoring the `xla` crate, which the offline build environment does
//! not ship.
//!
//! # Sharded embedding tables ([`embedding`])
//!
//! The model's embedding pool no longer has to fit one device: rows are
//! hash-sharded across the fleet (model parallelism alongside the data
//! parallelism of `train_loop::run_multi`), each lane holding a bounded
//! **hot cache** pinned in its `DeviceArena` and spilling cold rows to a
//! simulated host tier, with promotion/demotion costed on the P2P/SSD
//! channel models and prefetch driven by router lookahead. See
//! [`embedding`]'s module docs for the ownership and prefetch-timeline
//! diagrams. The cache layer is a placement/cost simulation over the
//! unchanged trainer arithmetic, so cached sharded execution stays
//! **bitwise identical** to the uncached reference
//! (`rust/tests/prop_embedding.rs`).

pub mod artifacts;
pub mod checkpoint;
pub mod embedding;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use crate::coordinator::packer::{PackedBatch, PackedBatchView};
use crate::devmem::DeviceBatchView;
use crate::error::{EtlError, Result};
use crate::util::prng::Rng;
use artifacts::{ArtifactPaths, ModelMeta};

impl ModelMeta {
    /// Flat state length: all parameters + 1 loss slot.
    pub fn state_len(&self) -> usize {
        self.param_count() + 1
    }
}

/// Default SGD learning rate of the reference trainer.
const DEFAULT_LR: f32 = 0.05;

/// One training step's **gradient-level contribution**: what a
/// data-parallel replica posts to the reduce bus
/// ([`crate::coordinator::scheduler::ReduceBus`]) instead of shipping
/// whole parameter states. Gradients are carried in f64 (exact images of
/// the f32 values the step computed, so a round-trip through the bus is
/// lossless) and applied back in f32 by [`Trainer::apply_grad`] with
/// exactly the arithmetic of a local SGD step — which is what makes a
/// single-contributor reduction bitwise identical to stepping in place.
///
/// `emb` keeps the per-row `(flat state index, grad)` pairs **in
/// application order**: the local step applies repeated indices
/// sequentially (not pre-summed), and bitwise replay must preserve that
/// f32 rounding order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GradStep {
    /// Dense-weight gradients, length `n_dense`.
    pub dense: Vec<f64>,
    /// Bias gradient.
    pub bias: f64,
    /// Embedding-pool gradients as (flat state index, grad), row order.
    pub emb: Vec<(usize, f64)>,
    /// Pre-update mean batch loss of the step (the loss-slot observable).
    pub loss: f64,
}

/// A loaded DLRM train step with a flat state buffer (reference
/// implementation: logistic regression over dense features plus one
/// embedded scalar per sparse feature, SGD, bit-deterministic).
///
/// State layout within the `param_count()` prefix of the flat buffer:
/// dense weights `[0, n_dense)`, bias at `n_dense`, embedding pool
/// `[n_dense+1, param_count)` indexed by `(feature, index)`; the loss
/// slot sits at `param_count()` exactly like the PJRT artifact.
pub struct Trainer {
    pub meta: ModelMeta,
    state: Vec<f32>,
    /// Steps executed.
    pub steps: u64,
    lr: f32,
}

impl Trainer {
    /// Load artifact metadata and initialize the state buffer with the
    /// same deterministic Glorot-ish scheme the PJRT path uses. Only
    /// `meta.txt` is required — the reference trainer never reads the HLO
    /// files, so training works without the Python AOT step.
    pub fn load(paths: &ArtifactPaths, seed: u64) -> Result<Trainer> {
        if !paths.meta.exists() {
            return Err(EtlError::Runtime(format!(
                "artifacts not found in {:?} — run `make artifacts`",
                paths.dir
            )));
        }
        let meta = ModelMeta::load(&paths.meta)?;
        let state = init_state(&meta, seed);
        Ok(Trainer { meta, state, steps: 0, lr: DEFAULT_LR })
    }

    /// Build a trainer directly from metadata (no artifact files needed) —
    /// used by tests and by deployments that only want the reference
    /// trainer semantics.
    pub fn from_meta(meta: ModelMeta, seed: u64) -> Trainer {
        let state = init_state(&meta, seed);
        Trainer { meta, state, steps: 0, lr: DEFAULT_LR }
    }

    /// Reset parameters.
    pub fn init_params(&mut self, seed: u64) -> Result<()> {
        self.state = init_state(&self.meta, seed);
        self.steps = 0;
        Ok(())
    }

    /// Fork a data-parallel replica: same artifact metadata, a bitwise
    /// copy of the **current** parameters, a fresh step counter. The
    /// multi-device train loop steps one replica per simulated GPU and
    /// keeps them consistent via all-reduce.
    pub fn replica(&self) -> Trainer {
        Trainer {
            meta: self.meta.clone(),
            state: self.state.clone(),
            steps: 0,
            lr: self.lr,
        }
    }

    /// Overwrite the flat state in place — the all-reduce broadcast path
    /// (and the write-back of the reduced fleet parameters into the
    /// caller's trainer). Fails if the length does not match the
    /// artifact's `state_len`.
    pub fn load_state(&mut self, state: &[f32]) -> Result<()> {
        if state.len() != self.meta.state_len() {
            return Err(EtlError::Runtime(format!(
                "state length {} != artifact state_len {}",
                state.len(),
                self.meta.state_len()
            )));
        }
        self.state.copy_from_slice(state);
        Ok(())
    }

    /// Run one training step on a packed batch.
    pub fn step(&mut self, batch: &PackedBatch) -> Result<()> {
        self.step_view(&batch.view())
    }

    /// Run one training step on a borrowed slice of a packed batch — the
    /// copy-free path the train loop uses with
    /// [`PackedBatch::chunk_views`].
    pub fn step_view(&mut self, batch: &PackedBatchView<'_>) -> Result<()> {
        let grad = self.forward_backward(batch)?;
        self.apply_grad(&grad)?;
        self.steps += 1;
        Ok(())
    }

    /// Forward + backward pass on the current parameters **without**
    /// applying the update: the gradient-computation half of a step. The
    /// accumulation arithmetic is exactly the local step's (f32 sums, row
    /// order), with the finished values widened to f64 for the bus — so
    /// `forward_backward` + [`apply_grad`](Self::apply_grad) is bitwise
    /// identical to [`step_view`](Self::step_view).
    fn forward_backward(&self, batch: &PackedBatchView<'_>) -> Result<GradStep> {
        let m = &self.meta;
        if batch.rows != m.batch || batch.n_dense != m.n_dense || batch.n_sparse != m.n_sparse {
            return Err(EtlError::Runtime(format!(
                "batch shape ({}, {}, {}) != artifact shape ({}, {}, {})",
                batch.rows, batch.n_dense, batch.n_sparse, m.batch, m.n_dense, m.n_sparse
            )));
        }
        let p = m.param_count();
        let nd = m.n_dense;
        let ns = m.n_sparse;
        if p < nd + 1 {
            return Err(EtlError::Runtime(format!(
                "artifact has {p} params; reference trainer needs at least {}",
                nd + 1
            )));
        }
        let vocab = m.vocab.max(1);
        let emb_len = p - nd - 1; // may be 0: dense-only model
        let rows = batch.rows;
        let inv_rows = 1.0f32 / rows.max(1) as f32;

        let mut gw = vec![0f32; nd];
        let mut gb = 0f32;
        let mut gemb: Vec<(usize, f64)> = Vec::with_capacity(rows * ns.min(8));
        let mut loss = 0f32;

        for r in 0..rows {
            // Forward: logit = b + w·dense + Σ emb[feature, idx].
            let mut z = self.state[nd];
            for d in 0..nd {
                z += self.state[d] * batch.dense[r * nd + d];
            }
            if emb_len > 0 {
                for s in 0..ns {
                    let v = batch.sparse[r * ns + s].rem_euclid(vocab as i32) as usize;
                    let e = nd + 1 + (s * vocab + v) % emb_len;
                    z += self.state[e];
                }
            }
            let pred = 1.0 / (1.0 + (-z).exp());
            let y = batch.labels[r];
            let eps = 1e-7f32;
            let pc = pred.clamp(eps, 1.0 - eps);
            loss += -(y * pc.ln() + (1.0 - y) * (1.0 - pc).ln());

            // Backward (mean BCE gradient).
            let g = (pred - y) * inv_rows;
            for d in 0..nd {
                gw[d] += g * batch.dense[r * nd + d];
            }
            gb += g;
            if emb_len > 0 {
                for s in 0..ns {
                    let v = batch.sparse[r * ns + s].rem_euclid(vocab as i32) as usize;
                    let e = nd + 1 + (s * vocab + v) % emb_len;
                    gemb.push((e, g as f64));
                }
            }
        }
        loss *= inv_rows;

        Ok(GradStep {
            dense: gw.into_iter().map(|g| g as f64).collect(),
            bias: gb as f64,
            emb: gemb,
            loss: loss as f64,
        })
    }

    /// Apply one step's gradients to the current parameters — the
    /// parameter-application half of a step, shared by the local step and
    /// the reduce-bus replay. Narrowing each f64 back to the f32 it was
    /// widened from is exact, and the update order (dense, bias, then the
    /// embedding pairs sequentially) matches the local step, so replay is
    /// bitwise. The loss slot is set to the payload's batch loss. Does
    /// **not** advance the step counter.
    pub fn apply_grad(&mut self, grad: &GradStep) -> Result<()> {
        let nd = self.meta.n_dense;
        let p = self.meta.param_count();
        if grad.dense.len() != nd {
            return Err(EtlError::Runtime(format!(
                "gradient has {} dense entries; artifact has {nd}",
                grad.dense.len()
            )));
        }
        for (d, g) in grad.dense.iter().enumerate() {
            self.state[d] -= self.lr * (*g as f32);
        }
        self.state[nd] -= self.lr * (grad.bias as f32);
        for &(e, g) in &grad.emb {
            if e < nd + 1 || e >= p {
                return Err(EtlError::Runtime(format!(
                    "embedding gradient index {e} outside pool [{}, {p})",
                    nd + 1
                )));
            }
            self.state[e] -= self.lr * (g as f32);
        }
        // Loss slot holds the (pre-update) batch loss, like the PJRT
        // train step's fused loss output.
        let last = self.state.len() - 1;
        self.state[last] = grad.loss as f32;
        Ok(())
    }

    /// Run one training step on a device-staged batch and return its
    /// gradient-level contribution for the reduce bus. The replica's own
    /// parameters advance exactly as [`step_device`](Self::step_device)
    /// would (the local-SGD leg of barrier-free data parallelism); the
    /// returned [`GradStep`] is the f64 image of the applied gradients.
    pub fn grad_step(&mut self, batch: &DeviceBatchView<'_>) -> Result<GradStep> {
        self.grad_step_view(&batch.data)
    }

    /// [`grad_step`](Self::grad_step) on a borrowed packed-batch view.
    pub fn grad_step_view(&mut self, batch: &PackedBatchView<'_>) -> Result<GradStep> {
        let grad = self.forward_backward(batch)?;
        self.apply_grad(&grad)?;
        self.steps += 1;
        Ok(grad)
    }

    /// Rebuild this replica's parameters from the last synced `base` by
    /// replaying a resolved reduce epoch's gradient contributions:
    /// contributions are applied **device-ascending** (the caller passes
    /// them in that order), each device's steps in its local order. Every
    /// replica replaying the same `(base, contribs)` lands on bitwise
    /// identical parameters — the broadcast of the barrier-free
    /// all-reduce without any state shipping. With a single contributed
    /// step this is exactly the single-device update applied to `base`.
    /// Does not advance the step counter (local steps were counted by
    /// [`grad_step`](Self::grad_step)).
    pub fn apply_reduced<'a>(
        &mut self,
        base: &[f32],
        contribs: impl IntoIterator<Item = &'a [GradStep]>,
    ) -> Result<()> {
        self.load_state(base)?;
        for steps in contribs {
            for grad in steps {
                self.apply_grad(grad)?;
            }
        }
        Ok(())
    }

    /// Run one training step **in place** on a batch staged in device
    /// memory ([`crate::devmem`]): the payload is borrowed straight from
    /// the arena slot the DMA engine made resident — the zero-copy
    /// consumption end of the paper's P2P ingest path (§3, Fig. 3).
    pub fn step_device(&mut self, batch: &DeviceBatchView<'_>) -> Result<()> {
        self.step_view(&batch.data)
    }

    /// Read the loss slot of the current state.
    pub fn loss(&self) -> Result<f32> {
        Ok(*self.state.last().expect("state always has a loss slot"))
    }

    /// Convenience: step then read loss.
    pub fn step_with_loss(&mut self, batch: &PackedBatch) -> Result<f32> {
        self.step(batch)?;
        self.loss()
    }

    /// Borrow the full flat state (the copy-free read the all-reduce
    /// fast path uses; [`state_to_vec`](Self::state_to_vec) clones).
    pub fn state(&self) -> &[f32] {
        &self.state
    }

    /// Download the full state (tests / checkpoints).
    pub fn state_to_vec(&self) -> Result<Vec<f32>> {
        Ok(self.state.clone())
    }

    /// Download one named parameter tensor by slicing the flat state.
    pub fn param_to_vec(&self, name: &str) -> Result<Vec<f32>> {
        let mut off = 0usize;
        for p in &self.meta.params {
            let n = p.elements();
            if p.name == name {
                return Ok(self.state[off..off + n].to_vec());
            }
            off += n;
        }
        Err(EtlError::Runtime(format!("no parameter named {name:?}")))
    }

    pub fn param_count(&self) -> usize {
        self.meta.param_count()
    }

    /// Capture a checkpoint of the current state (§2's warm-start path).
    pub fn checkpoint(&self, etl: &crate::etl::dag::EtlState) -> Result<checkpoint::Checkpoint> {
        Ok(checkpoint::Checkpoint::capture(self.steps, self.state_to_vec()?, etl))
    }

    /// Restore from a checkpoint: replaces the state and resumes the step
    /// counter. Fails if the state length does not match the artifact.
    pub fn restore(&mut self, ck: &checkpoint::Checkpoint) -> Result<()> {
        if ck.state.len() != self.meta.state_len() {
            return Err(EtlError::Runtime(format!(
                "checkpoint state_len {} != artifact {}",
                ck.state.len(),
                self.meta.state_len()
            )));
        }
        self.state = ck.state.clone();
        self.steps = ck.step;
        Ok(())
    }
}

/// Host-side initial state: per-parameter init + zeroed loss slot.
pub fn init_state(meta: &ModelMeta, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut state = Vec::with_capacity(meta.state_len());
    for p in &meta.params {
        let n = p.elements();
        if p.name.starts_with('b') {
            state.extend(std::iter::repeat(0f32).take(n));
        } else if p.name.starts_with("emb") {
            state.extend((0..n).map(|_| (rng.normal() as f32) * 0.05));
        } else {
            let fan_in = *p.dims.first().unwrap_or(&1) as f64;
            let fan_out = *p.dims.last().unwrap_or(&1) as f64;
            let scale = (2.0 / (fan_in + fan_out)).sqrt();
            state.extend((0..n).map(|_| (rng.normal() * scale) as f32));
        }
    }
    state.push(0.0); // loss slot
    state
}

#[cfg(test)]
mod tests {
    use super::*;
    use artifacts::ParamSpec;

    fn tiny_meta() -> ModelMeta {
        ModelMeta {
            batch: 4,
            n_dense: 2,
            n_sparse: 2,
            vocab: 10,
            embed_dim: 4,
            params: vec![
                ParamSpec { name: "emb".into(), dims: vec![20, 4] },
                ParamSpec { name: "w1".into(), dims: vec![2, 8] },
                ParamSpec { name: "b1".into(), dims: vec![8] },
            ],
            extra: Default::default(),
        }
    }

    fn tiny_batch() -> PackedBatch {
        PackedBatch {
            rows: 4,
            n_dense: 2,
            n_sparse: 2,
            dense: vec![0.5, 1.0, 0.0, 2.0, 1.5, 0.5, 0.2, 0.8],
            sparse: vec![1, 7, 2, 3, 1, 7, 9, 0],
            labels: vec![1.0, 0.0, 1.0, 0.0],
        }
    }

    #[test]
    fn missing_artifacts_error_is_actionable() {
        let paths = ArtifactPaths::in_dir("/nonexistent");
        let msg = match Trainer::load(&paths, 0) {
            Err(e) => format!("{e}"),
            Ok(_) => panic!("expected an error"),
        };
        assert!(msg.contains("make artifacts"), "{msg}");
    }

    #[test]
    fn init_state_layout() {
        let meta = tiny_meta();
        let s = init_state(&meta, 42);
        assert_eq!(s.len(), 80 + 16 + 8 + 1);
        // biases zero, loss slot zero
        assert!(s[96..104].iter().all(|&v| v == 0.0));
        assert_eq!(*s.last().unwrap(), 0.0);
        // deterministic
        assert_eq!(s, init_state(&meta, 42));
        assert_ne!(s, init_state(&meta, 43));
    }

    #[test]
    fn loss_decreases_on_fixed_batch() {
        let mut t = Trainer::from_meta(tiny_meta(), 7);
        let batch = tiny_batch();
        let first = t.step_with_loss(&batch).unwrap();
        assert!(first.is_finite() && first > 0.0);
        for _ in 0..50 {
            t.step(&batch).unwrap();
        }
        let last = t.loss().unwrap();
        assert!(last < first, "loss did not decrease: {first} → {last}");
        assert_eq!(t.steps, 51);
    }

    #[test]
    fn rejects_wrong_batch_shape() {
        let mut t = Trainer::from_meta(tiny_meta(), 1);
        let mut batch = tiny_batch();
        batch.rows -= 1;
        batch.labels.pop();
        batch.dense.truncate(batch.rows * batch.n_dense);
        batch.sparse.truncate(batch.rows * batch.n_sparse);
        assert!(t.step(&batch).is_err());
    }

    #[test]
    fn replica_forks_params_and_load_state_broadcasts() {
        let mut t = Trainer::from_meta(tiny_meta(), 3);
        let batch = tiny_batch();
        t.step(&batch).unwrap();
        let mut r = t.replica();
        assert_eq!(r.steps, 0, "replicas start their own step counter");
        assert_eq!(r.state_to_vec().unwrap(), t.state_to_vec().unwrap());
        // Stepping the replica matches stepping the original (bitwise).
        t.step(&batch).unwrap();
        r.step(&batch).unwrap();
        assert_eq!(r.state_to_vec().unwrap(), t.state_to_vec().unwrap());
        // Broadcast: load_state overwrites verbatim; bad lengths bounce.
        let s = t.state_to_vec().unwrap();
        let mut other = Trainer::from_meta(tiny_meta(), 99);
        other.load_state(&s).unwrap();
        assert_eq!(other.state_to_vec().unwrap(), s);
        assert!(other.load_state(&s[1..]).is_err());
    }

    #[test]
    fn step_and_step_view_are_identical() {
        let mut a = Trainer::from_meta(tiny_meta(), 3);
        let mut b = Trainer::from_meta(tiny_meta(), 3);
        let batch = tiny_batch();
        a.step(&batch).unwrap();
        b.step_view(&batch.view()).unwrap();
        assert_eq!(a.state_to_vec().unwrap(), b.state_to_vec().unwrap());
    }

    #[test]
    fn step_device_matches_step_on_arena_staged_batch() {
        let mut a = Trainer::from_meta(tiny_meta(), 5);
        let mut b = Trainer::from_meta(tiny_meta(), 5);
        let batch = tiny_batch();
        let arena = crate::devmem::DeviceArena::with_slots(1);
        let mut slot = arena.acquire().unwrap();
        slot.pack_into(batch.bytes(), |out| {
            *out = batch.clone();
            Ok(())
        })
        .unwrap();

        a.step(&batch).unwrap();
        for view in slot.chunk_views(4) {
            b.step_device(&view).unwrap();
        }
        assert_eq!(b.steps, 1);
        assert_eq!(a.state_to_vec().unwrap(), b.state_to_vec().unwrap());
        arena.release(slot).unwrap();
    }

    #[test]
    fn grad_step_view_matches_step_view_bitwise() {
        // The gradient-computation/application split must be a pure
        // refactor of the fused step: same params, same loss, same bits.
        let mut a = Trainer::from_meta(tiny_meta(), 13);
        let mut b = Trainer::from_meta(tiny_meta(), 13);
        let batch = tiny_batch();
        for _ in 0..7 {
            a.step_view(&batch.view()).unwrap();
            let grad = b.grad_step_view(&batch.view()).unwrap();
            assert_eq!(grad.dense.len(), 2);
            assert!(grad.loss.is_finite());
            assert_eq!(grad.loss as f32, b.loss().unwrap());
        }
        assert_eq!(a.steps, b.steps);
        let (sa, sb) = (a.state_to_vec().unwrap(), b.state_to_vec().unwrap());
        for (x, y) in sa.iter().zip(&sb) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn apply_reduced_single_contributor_replays_verbatim() {
        // One contributed step applied to the synced base must equal the
        // contributor's own local SGD result — the bitwise fast path of
        // the barrier-free all-reduce.
        let mut contributor = Trainer::from_meta(tiny_meta(), 21);
        let mut follower = contributor.replica();
        let base = contributor.state_to_vec().unwrap();
        let batch = tiny_batch();
        let grad = contributor.grad_step_view(&batch.view()).unwrap();

        let contrib = [grad.clone()];
        follower.apply_reduced(&base, [contrib.as_slice()]).unwrap();
        let (sc, sf) = (
            contributor.state_to_vec().unwrap(),
            follower.state_to_vec().unwrap(),
        );
        for (i, (x, y)) in sc.iter().zip(&sf).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "state[{i}]: {x} vs {y}");
        }
        // apply_reduced does not advance the follower's step counter.
        assert_eq!(contributor.steps, 1);
        assert_eq!(follower.steps, 0);

        // Multi-contribution replay is deterministic: two followers
        // replaying the same (base, contribs) agree bitwise.
        let grad2 = contributor.grad_step_view(&batch.view()).unwrap();
        let both = [grad, grad2];
        let mut f1 = Trainer::from_meta(tiny_meta(), 21);
        let mut f2 = Trainer::from_meta(tiny_meta(), 21);
        f1.apply_reduced(&base, [both.as_slice()]).unwrap();
        f2.apply_reduced(&base, [both.as_slice()]).unwrap();
        assert_eq!(f1.state_to_vec().unwrap(), f2.state_to_vec().unwrap());
    }

    #[test]
    fn apply_grad_rejects_malformed_payloads() {
        let mut t = Trainer::from_meta(tiny_meta(), 5);
        // Wrong dense arity.
        let bad = GradStep { dense: vec![0.0; 3], ..GradStep::default() };
        assert!(t.apply_grad(&bad).is_err());
        // Embedding index outside the pool (>= param_count).
        let bad = GradStep {
            dense: vec![0.0; 2],
            emb: vec![(t.param_count(), 0.1)],
            ..GradStep::default()
        };
        assert!(t.apply_grad(&bad).is_err());
        // Embedding index inside the dense/bias prefix.
        let bad = GradStep {
            dense: vec![0.0; 2],
            emb: vec![(0, 0.1)],
            ..GradStep::default()
        };
        assert!(t.apply_grad(&bad).is_err());
        // Well-formed payload lands.
        let ok = GradStep {
            dense: vec![0.0; 2],
            emb: vec![(t.meta.n_dense + 1, 0.1)],
            ..GradStep::default()
        };
        assert!(t.apply_grad(&ok).is_ok());
    }

    #[test]
    fn checkpoint_restore_replays_bit_identically() {
        let mut t = Trainer::from_meta(tiny_meta(), 9);
        let batch = tiny_batch();
        for _ in 0..5 {
            t.step(&batch).unwrap();
        }
        let etl = crate::etl::dag::EtlState::default();
        let ck = t.checkpoint(&etl).unwrap();
        for _ in 0..3 {
            t.step(&batch).unwrap();
        }
        let loss_at_8 = t.loss().unwrap();
        t.restore(&ck).unwrap();
        assert_eq!(t.steps, 5);
        for _ in 0..3 {
            t.step(&batch).unwrap();
        }
        assert_eq!(t.loss().unwrap(), loss_at_8);
    }

    #[test]
    fn param_to_vec_slices_by_name() {
        let t = Trainer::from_meta(tiny_meta(), 11);
        let emb = t.param_to_vec("emb").unwrap();
        assert_eq!(emb.len(), 80);
        let b1 = t.param_to_vec("b1").unwrap();
        assert!(b1.iter().all(|&v| v == 0.0));
        assert!(t.param_to_vec("nope").is_err());
    }
}
