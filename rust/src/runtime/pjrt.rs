//! PJRT-backed trainer (original implementation, `pjrt` feature only):
//! loads the HLO-text artifacts AOT-compiled by `python/compile/aot.py`,
//! compiles them on the PJRT CPU client, and drives training with a
//! **device-resident flat state buffer** — all parameters live in one
//! `f32[state_len]` array with a trailing loss slot; each step the host
//! uploads only the packed batch and re-feeds the previous output buffer
//! (`execute_b`), mirroring the paper's zero-copy ingest discipline. A
//! second tiny executable slices the loss slot out on-device (the CPU
//! PJRT plugin lacks CopyRawToHost).
//!
//! Interchange is HLO *text* (xla_extension 0.5.1 rejects jax≥0.5's
//! 64-bit-id serialized protos; the text parser reassigns ids).
//!
//! Building this module requires vendoring the `xla` crate, which the
//! offline environment does not ship — hence the feature gate. The
//! default build's [`super::Trainer`] reproduces the same public API in
//! pure Rust.

use crate::coordinator::packer::PackedBatch;
use crate::error::{EtlError, Result};
use super::artifacts::{ArtifactPaths, ModelMeta};
use super::init_state;

/// Wrap an `xla::Error` into our error type.
fn xe(e: xla::Error) -> EtlError {
    EtlError::Runtime(e.to_string())
}

/// The PJRT engine: one CPU client shared by all executables.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        Ok(Engine { client: xla::PjRtClient::cpu().map_err(xe)? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file.
    pub fn compile_hlo(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path).map_err(xe)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(xe)
    }

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, dims, None).map_err(xe)
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, dims, None).map_err(xe)
    }
}

/// A loaded DLRM train step with a device-resident flat state buffer.
pub struct Trainer {
    engine: Engine,
    step_exe: xla::PjRtLoadedExecutable,
    loss_exe: xla::PjRtLoadedExecutable,
    pub meta: ModelMeta,
    state: xla::PjRtBuffer,
    /// Steps executed.
    pub steps: u64,
}

impl Trainer {
    /// Load artifacts, compile both executables, and initialize the state
    /// buffer with a deterministic Glorot-ish scheme.
    pub fn load(paths: &ArtifactPaths, seed: u64) -> Result<Trainer> {
        if !paths.exist() {
            return Err(EtlError::Runtime(format!(
                "artifacts not found in {:?} — run `make artifacts`",
                paths.dir
            )));
        }
        let engine = Engine::cpu()?;
        let meta = ModelMeta::load(&paths.meta)?;
        let step_exe = engine.compile_hlo(&paths.train_hlo)?;
        let loss_exe = engine.compile_hlo(&paths.loss_hlo)?;
        let state = engine.upload_f32(&init_state(&meta, seed), &[meta.state_len()])?;
        Ok(Trainer { engine, step_exe, loss_exe, meta, state, steps: 0 })
    }

    /// Reset parameters.
    pub fn init_params(&mut self, seed: u64) -> Result<()> {
        self.state = self
            .engine
            .upload_f32(&init_state(&self.meta, seed), &[self.meta.state_len()])?;
        self.steps = 0;
        Ok(())
    }

    /// Run one training step on a packed batch; the state stays on device.
    pub fn step(&mut self, batch: &PackedBatch) -> Result<()> {
        let m = &self.meta;
        if batch.rows != m.batch || batch.n_dense != m.n_dense || batch.n_sparse != m.n_sparse {
            return Err(EtlError::Runtime(format!(
                "batch shape ({}, {}, {}) != artifact shape ({}, {}, {})",
                batch.rows, batch.n_dense, batch.n_sparse, m.batch, m.n_dense, m.n_sparse
            )));
        }
        // Fold indices into the (possibly smaller) artifact vocabulary.
        let vocab = m.vocab as i32;
        let sparse: Vec<i32> = batch.sparse.iter().map(|&v| v % vocab).collect();

        let dense_b = self.engine.upload_f32(&batch.dense, &[batch.rows, m.n_dense])?;
        let sparse_b = self.engine.upload_i32(&sparse, &[batch.rows, m.n_sparse])?;
        let labels_b = self.engine.upload_f32(&batch.labels, &[batch.rows])?;

        let mut outs = self
            .step_exe
            .execute_b(&[&self.state, &dense_b, &sparse_b, &labels_b])
            .map_err(xe)?;
        let mut replica = outs
            .drain(..)
            .next()
            .ok_or_else(|| EtlError::Runtime("no outputs".into()))?;
        if replica.len() != 1 {
            return Err(EtlError::Runtime(format!(
                "expected 1 state output, got {}",
                replica.len()
            )));
        }
        self.state = replica.remove(0);
        self.steps += 1;
        Ok(())
    }

    /// Read the loss slot of the current state (runs the on-device slice
    /// executable; downloads 4 bytes).
    pub fn loss(&self) -> Result<f32> {
        let mut outs = self.loss_exe.execute_b(&[&self.state]).map_err(xe)?;
        let buf = outs
            .drain(..)
            .next()
            .and_then(|mut r| if r.is_empty() { None } else { Some(r.remove(0)) })
            .ok_or_else(|| EtlError::Runtime("loss executable produced no output".into()))?;
        let lit = buf.to_literal_sync().map_err(xe)?;
        lit.get_first_element().map_err(xe)
    }

    /// Download the full state (tests / checkpoints).
    pub fn state_to_vec(&self) -> Result<Vec<f32>> {
        let lit = self.state.to_literal_sync().map_err(xe)?;
        lit.to_vec::<f32>().map_err(xe)
    }
}
