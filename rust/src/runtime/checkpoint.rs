//! Checkpointing for continuous training (paper §2: "warm-starting from
//! previous checkpoints" is how production recommender pipelines run).
//! Saves/restores the trainer's flat parameter state and the fitted ETL
//! vocabularies so a PipeRec deployment can restart without refitting or
//! reinitializing.
//!
//! Format (little-endian):
//! ```text
//! magic "PRCKPT1\0" | u64 step | u64 state_len | f32[state_len]
//! u32 n_vocabs | per vocab: u16 key_len | key | u64 n_keys | i64[n_keys]
//! ```
//! Vocabularies are stored as keys in first-appearance order — replaying
//! them through `VocabTable::get_or_insert` reconstructs identical
//! indices (the table's defining invariant).

use std::io::{Read, Write};
use std::path::Path;

use crate::error::{EtlError, Result};
use crate::etl::dag::EtlState;
use crate::etl::ops::vocab::VocabTable;

const MAGIC: &[u8; 8] = b"PRCKPT1\0";

/// A checkpoint: trainer step, flat model state, fitted vocabularies.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub state: Vec<f32>,
    /// (vocab key, table keys in first-appearance order).
    pub vocabs: Vec<(String, Vec<i64>)>,
}

impl Checkpoint {
    /// Capture from a trainer state vector and fitted ETL state.
    pub fn capture(step: u64, state: Vec<f32>, etl: &EtlState) -> Checkpoint {
        let mut vocabs: Vec<(String, Vec<i64>)> = etl
            .vocabs
            .iter()
            .map(|(k, t)| (k.clone(), t.keys_in_order().to_vec()))
            .collect();
        vocabs.sort_by(|a, b| a.0.cmp(&b.0)); // deterministic order
        Checkpoint { step, state, vocabs }
    }

    /// Reconstruct the ETL state (identical indices by replay).
    pub fn restore_etl(&self) -> EtlState {
        let mut etl = EtlState::default();
        for (key, keys) in &self.vocabs {
            let mut t = VocabTable::with_capacity(keys.len());
            for &k in keys {
                t.get_or_insert(k);
            }
            etl.vocabs.insert(key.clone(), t);
        }
        etl
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_all(MAGIC)?;
        w.write_all(&self.step.to_le_bytes())?;
        w.write_all(&(self.state.len() as u64).to_le_bytes())?;
        for v in &self.state {
            w.write_all(&v.to_le_bytes())?;
        }
        w.write_all(&(self.vocabs.len() as u32).to_le_bytes())?;
        for (key, keys) in &self.vocabs {
            let kb = key.as_bytes();
            if kb.len() > u16::MAX as usize {
                return Err(EtlError::Format("vocab key too long".into()));
            }
            w.write_all(&(kb.len() as u16).to_le_bytes())?;
            w.write_all(kb)?;
            w.write_all(&(keys.len() as u64).to_le_bytes())?;
            for &k in keys {
                w.write_all(&k.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<Checkpoint> {
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(EtlError::Format("bad checkpoint magic".into()));
        }
        let step = read_u64(r)?;
        let state_len = read_u64(r)? as usize;
        if state_len > (1 << 32) {
            return Err(EtlError::Format(format!("implausible state_len {state_len}")));
        }
        let mut state = vec![0f32; state_len];
        let mut buf = vec![0u8; state_len * 4];
        r.read_exact(&mut buf)?;
        for (i, c) in buf.chunks_exact(4).enumerate() {
            state[i] = f32::from_le_bytes(c.try_into().unwrap());
        }
        let n_vocabs = read_u32(r)? as usize;
        let mut vocabs = Vec::with_capacity(n_vocabs);
        for _ in 0..n_vocabs {
            let klen = read_u16(r)? as usize;
            let mut kb = vec![0u8; klen];
            r.read_exact(&mut kb)?;
            let key = String::from_utf8(kb)
                .map_err(|e| EtlError::Format(format!("bad vocab key: {e}")))?;
            let n = read_u64(r)? as usize;
            let mut keys = vec![0i64; n];
            let mut buf = vec![0u8; n * 8];
            r.read_exact(&mut buf)?;
            for (i, c) in buf.chunks_exact(8).enumerate() {
                keys[i] = i64::from_le_bytes(c.try_into().unwrap());
            }
            vocabs.push((key, keys));
        }
        Ok(Checkpoint { step, state, vocabs })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        self.write_to(&mut f)?;
        f.flush()?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        Checkpoint::read_from(&mut f)
    }
}

fn read_u16<R: Read>(r: &mut R) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::etl::ops::vocab::vocab_gen;

    fn sample() -> Checkpoint {
        let mut etl = EtlState::default();
        etl.vocabs.insert("a".into(), vocab_gen(&[30, 10, 30, 20], 8));
        etl.vocabs.insert("b".into(), vocab_gen(&[-5, 7], 8));
        Checkpoint::capture(123, vec![1.0, -2.5, f32::NAN, 0.0], &etl)
    }

    #[test]
    fn roundtrip_in_memory() {
        let ck = sample();
        let mut buf = Vec::new();
        ck.write_to(&mut buf).unwrap();
        let back = Checkpoint::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back.step, 123);
        assert_eq!(back.vocabs, ck.vocabs);
        // NaN-aware state compare.
        assert_eq!(back.state.len(), 4);
        for (a, b) in ck.state.iter().zip(&back.state) {
            assert!(a == b || (a.is_nan() && b.is_nan()));
        }
    }

    #[test]
    fn restore_replays_identical_indices() {
        let ck = sample();
        let etl = ck.restore_etl();
        let t = &etl.vocabs["a"];
        assert_eq!(t.get(30), Some(0));
        assert_eq!(t.get(10), Some(1));
        assert_eq!(t.get(20), Some(2));
        assert_eq!(etl.vocabs["b"].get(-5), Some(0));
    }

    #[test]
    fn roundtrip_on_disk() {
        let dir = std::env::temp_dir().join("piperec_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ck.bin");
        let ck = sample();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, ck.step);
        assert_eq!(back.vocabs, ck.vocabs);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        assert!(Checkpoint::read_from(&mut &b"NOTACKPT"[..]).is_err());
        let mut buf = Vec::new();
        sample().write_to(&mut buf).unwrap();
        buf.truncate(buf.len() / 2);
        assert!(Checkpoint::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn capture_orders_vocabs_deterministically() {
        let ck = sample();
        assert_eq!(ck.vocabs[0].0, "a");
        assert_eq!(ck.vocabs[1].0, "b");
    }
}
