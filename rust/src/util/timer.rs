//! Wall-clock timing helpers.

use std::time::Instant;

/// Time a closure, returning `(result, seconds)`.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Run `f` repeatedly: `warmup` unmeasured runs then `iters` measured runs,
/// returning per-iteration seconds.
pub fn measure_n(warmup: usize, iters: usize, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

/// A simple scope stopwatch accumulating named spans — used for coarse
/// profiling of the coordinator hot path.
#[derive(Debug, Default)]
pub struct Stopwatch {
    spans: Vec<(String, f64)>,
}

impl Stopwatch {
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let (out, secs) = time_it(f);
        self.spans.push((name.to_string(), secs));
        out
    }

    pub fn spans(&self) -> &[(String, f64)] {
        &self.spans
    }

    pub fn total(&self) -> f64 {
        self.spans.iter().map(|(_, s)| s).sum()
    }

    /// Merge spans with identical names (sums their times).
    pub fn rollup(&self) -> Vec<(String, f64)> {
        let mut out: Vec<(String, f64)> = Vec::new();
        for (name, secs) in &self.spans {
            match out.iter_mut().find(|(n, _)| n == name) {
                Some((_, acc)) => *acc += secs,
                None => out.push((name.clone(), *secs)),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_value_and_positive_time() {
        let (v, secs) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn measure_n_counts() {
        let mut calls = 0;
        let times = measure_n(2, 5, || calls += 1);
        assert_eq!(times.len(), 5);
        assert_eq!(calls, 7);
    }

    #[test]
    fn stopwatch_rollup_merges() {
        let mut sw = Stopwatch::default();
        sw.time("a", || {});
        sw.time("b", || {});
        sw.time("a", || {});
        let rolled = sw.rollup();
        assert_eq!(rolled.len(), 2);
        assert_eq!(rolled[0].0, "a");
        assert!(sw.total() >= 0.0);
    }
}
