//! Deterministic fault injection for the ingest→pack→DMA→train pipeline
//! (style of the `util/sched.rs` schedule fuzzer): a process-installable
//! [`FaultPlan`] that decides, as a pure function of **(seed, site, key)**,
//! whether a given operation attempt fails — shard read I/O errors, corrupt
//! rows in TSV/rcol decode, slow-shard stragglers, DMA transfer failures,
//! ingest-worker death, and whole-lane (device) loss.
//!
//! Keys are *stable identities* (shard index, transfer ordinal, device
//! index), **not** arrival order, so the set of afflicted keys is
//! schedule-independent: the fault suite (`rust/tests/prop_faults.rs`) can
//! replay the same plan under hundreds of fuzzed schedules and assert the
//! recovery outcome (bitwise-identical delivery, exact quarantine sets,
//! surviving-lane accounting) never varies.
//!
//! Each afflicted key fails a bounded number of *attempts* ([`SiteRule::
//! failures`]) and then succeeds — that is what makes retry paths testable:
//! `failures < max_retries` exercises retried-but-delivered, while
//! [`PERMANENT`] exercises quarantine / lane loss. When no plan is
//! installed, [`inject`] is a single relaxed atomic load — cheap enough to
//! leave in production paths permanently (pinned by the `fault_overhead`
//! section of the hotpath bench).
//!
//! Installation is process-global; [`FaultPlan::install`] serializes
//! installers on a mutex (held by the returned guard) so concurrently
//! running tests cannot interleave two different plans. Injection is
//! additionally **enrollment-scoped**: each install opens a fresh epoch,
//! enrolls the installing thread, and only afflicts threads carrying that
//! epoch's token — library thread-spawn points propagate the spawner's
//! token ([`enroll_token`]/[`enroll`]) so a plan reaches its own worker
//! fleet, while unrelated tests running in parallel on other threads stay
//! untouched.

use std::cell::Cell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

/// Well-known injection sites. Each has a stable key domain, documented
/// per constant, so plans and assertions agree on what an affliction hits.
pub mod site {
    /// Shard read I/O error in an ingest worker (key = shard index).
    pub const SHARD_READ: u64 = 1;
    /// Corrupt rows surfacing from TSV/rcol decode (key = shard index).
    pub const ROW_DECODE: u64 = 2;
    /// Slow-shard straggler: a bounded stall before the read (key = shard).
    pub const SLOW_SHARD: u64 = 3;
    /// DMA transfer failure in `TransferEngine::submit` (key = transfer
    /// ordinal within the engine).
    pub const DMA: u64 = 4;
    /// Ingest-worker death: the worker thread panics while producing the
    /// keyed shard (key = shard index).
    pub const WORKER_DEATH: u64 = 5;
    /// Whole-lane loss in the multi-device train loop (key = device index).
    pub const LANE_LOSS: u64 = 6;
    /// Embedding-cache prefetch transfer failure (key = `device << 48 |
    /// promotion ordinal within that lane's cache`).
    pub const PREFETCH: u64 = 7;

    /// Human-readable site name for error surfaces and reports.
    pub fn name(site: u64) -> &'static str {
        match site {
            SHARD_READ => "shard_read",
            ROW_DECODE => "row_decode",
            SLOW_SHARD => "slow_shard",
            DMA => "dma",
            WORKER_DEATH => "worker_death",
            LANE_LOSS => "lane_loss",
            PREFETCH => "prefetch",
            _ => "unknown",
        }
    }
}

/// Affliction rate denominator: rates are expressed per 65 536 keys.
pub const RATE_FULL: u32 = 1 << 16;

/// Marker prefix for panics raised *by injection* (worker-death faults).
/// [`quiet_injected_panics`] suppresses their default-hook noise so fault
/// campaigns don't spray hundreds of expected backtraces into test logs.
pub const INJECTED_PANIC: &str = "piperec-injected-fault";

/// Install (once per process) a panic hook that silences panics whose
/// payload carries [`INJECTED_PANIC`] and forwards everything else to the
/// previous hook. Real panics keep their diagnostics.
pub fn quiet_injected_panics() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let payload = info.payload();
            let injected = payload
                .downcast_ref::<&str>()
                .map(|s| s.contains(INJECTED_PANIC))
                .or_else(|| {
                    payload.downcast_ref::<String>().map(|s| s.contains(INJECTED_PANIC))
                })
                .unwrap_or(false);
            if !injected {
                prev(info);
            }
        }));
    });
}

/// `failures` value meaning "never succeeds" (poison / permanent loss).
pub const PERMANENT: u32 = u32::MAX;

const MAX_SITE: usize = 8;

/// Per-site injection rule: which fraction of the key space is afflicted,
/// and how many attempts each afflicted key fails before succeeding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SiteRule {
    /// Probability an individual key is afflicted, in units of
    /// 1/[`RATE_FULL`]. [`RATE_FULL`] afflicts every key.
    pub rate: u32,
    /// Number of attempts an afflicted key fails before it starts
    /// succeeding; [`PERMANENT`] never succeeds.
    pub failures: u32,
}

/// A deterministic fault schedule: seed plus per-site rules. Pure data —
/// build one with the fluent constructors, then [`install`](Self::install)
/// it to activate injection process-wide.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    seed: u64,
    rules: [Option<SiteRule>; MAX_SITE],
}

impl FaultPlan {
    /// An empty plan rooted at `seed` (injects nothing until rules are
    /// added).
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, rules: [None; MAX_SITE] }
    }

    /// The plan's seed (for failure reports).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Add a rule: afflict `rate`/65 536 of `site`'s keys, each failing
    /// `failures` attempts before succeeding.
    pub fn with(mut self, site: u64, rate: u32, failures: u32) -> FaultPlan {
        assert!((site as usize) < MAX_SITE, "unknown fault site {site}");
        self.rules[site as usize] = Some(SiteRule { rate: rate.min(RATE_FULL), failures });
        self
    }

    /// Add a rule afflicting **every** key of `site`.
    pub fn always(self, site: u64, failures: u32) -> FaultPlan {
        self.with(site, RATE_FULL, failures)
    }

    /// Pure affliction query: how many attempts does `key` fail at `site`
    /// under this plan? `None` if the key is healthy. Does **not** consume
    /// an attempt — tests use this to predict exact quarantine sets.
    pub fn afflicts(&self, site: u64, key: u64) -> Option<u32> {
        let rule = self.rules.get(site as usize).copied().flatten()?;
        if rule.rate == 0 {
            return None;
        }
        if (mix(self.seed, site, key) & (RATE_FULL as u64 - 1)) < rule.rate as u64 {
            Some(rule.failures)
        } else {
            None
        }
    }

    /// Activate this plan until the guard drops. Blocks while another plan
    /// is installed (tests running in parallel serialize here instead of
    /// mixing plans). The installing thread is enrolled in the plan's
    /// epoch; threads it spawns through the library's spawn points inherit
    /// enrollment, everything else stays unafflicted.
    pub fn install(self) -> FaultGuard {
        let serial = INSTALL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        let epoch = EPOCH.fetch_add(1, Ordering::SeqCst) + 1;
        {
            let mut st = STATE.lock().unwrap_or_else(|p| p.into_inner());
            *st = Some(PlanState { plan: self, epoch, attempts: HashMap::new() });
        }
        ENROLLED.with(|c| c.set(epoch));
        INJECTED.store(0, Ordering::SeqCst);
        ACTIVE.store(true, Ordering::SeqCst);
        FaultGuard { _serial: serial }
    }
}

/// The calling thread's enrollment token — capture it before spawning a
/// worker thread and hand it to [`enroll`] inside, so the fault plan that
/// covers the spawner also covers the fleet it spawns. Returns a dead
/// token when the thread is not enrolled (enrolling with it is a no-op
/// match, which is exactly right).
pub fn enroll_token() -> u64 {
    ENROLLED.with(|c| c.get())
}

/// Adopt a spawner's enrollment token on this thread. Tokens from an
/// earlier plan's epoch are stale and never match the active plan.
pub fn enroll(token: u64) {
    ENROLLED.with(|c| c.set(token));
}

/// Deterministic draw for (seed, site, key): splitmix64 finalizer over the
/// same mixing constants as `sched.rs`, so different sites/keys decorrelate.
fn mix(seed: u64, site: u64, key: u64) -> u64 {
    let mut x = seed
        ^ site.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ key.wrapping_mul(0xD1B5_4A32_D192_ED03);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    x
}

struct PlanState {
    plan: FaultPlan,
    /// Install epoch this plan opened; only threads enrolled with a
    /// matching token are afflicted.
    epoch: u64,
    /// Attempt counts per (site, key) — injection fails the first
    /// `failures` attempts of an afflicted key, then lets it through.
    attempts: HashMap<(u64, u64), u32>,
}

static ACTIVE: AtomicBool = AtomicBool::new(false);
static INJECTED: AtomicU64 = AtomicU64::new(0);
static EPOCH: AtomicU64 = AtomicU64::new(0);
static STATE: Mutex<Option<PlanState>> = Mutex::new(None);
static INSTALL_LOCK: Mutex<()> = Mutex::new(());

thread_local! {
    /// Epoch token this thread is enrolled under (0 = never enrolled).
    static ENROLLED: Cell<u64> = Cell::new(0);
}

/// RAII handle for an installed fault plan: dropping it deactivates
/// injection and releases the global installer lock.
pub struct FaultGuard {
    _serial: MutexGuard<'static, ()>,
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        ACTIVE.store(false, Ordering::SeqCst);
        let mut st = STATE.lock().unwrap_or_else(|p| p.into_inner());
        *st = None;
    }
}

/// Is a fault plan currently installed?
pub fn is_active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Should this attempt of (`site`, `key`) fail? One relaxed atomic load
/// when no plan is installed; under a plan, a deterministic draw plus an
/// attempt-count bump for afflicted keys.
#[inline]
pub fn inject(site: u64, key: u64) -> bool {
    if !ACTIVE.load(Ordering::Relaxed) {
        return false;
    }
    inject_slow(site, key)
}

#[cold]
fn inject_slow(site: u64, key: u64) -> bool {
    let token = ENROLLED.with(|c| c.get());
    if token == 0 {
        return false;
    }
    let mut st = STATE.lock().unwrap_or_else(|p| p.into_inner());
    let Some(st) = st.as_mut() else { return false };
    if st.epoch != token {
        return false;
    }
    let Some(failures) = st.plan.afflicts(site, key) else { return false };
    let a = st.attempts.entry((site, key)).or_insert(0);
    if *a < failures {
        *a = a.saturating_add(1);
        INJECTED.fetch_add(1, Ordering::Relaxed);
        true
    } else {
        false
    }
}

/// Straggler hook: if (`site`, `key`) is afflicted, stall this thread for
/// a deterministic bounded micro-sleep (≤ ~200 µs) — enough to invert
/// arrival orders without slowing a campaign down. Counts an attempt like
/// [`inject`], so `failures` bounds how often a key straggles.
pub fn stall(site: u64, key: u64) {
    if !inject(site, key) {
        return;
    }
    let seed = {
        let st = STATE.lock().unwrap_or_else(|p| p.into_inner());
        st.as_ref().map(|s| s.plan.seed).unwrap_or(0)
    };
    let micros = mix(seed, site ^ 0xACE, key) % 200;
    std::thread::sleep(std::time::Duration::from_micros(micros));
}

/// Pure, non-consuming affliction query: is (`site`, `key`) afflicted by
/// the installed plan, as seen from this (enrolled) thread? Unlike
/// [`inject`] this never burns an attempt and ignores the attempt budget
/// — it reports whether the *rule* hits the key, not whether the next
/// attempt would fail. The control plane uses it to stamp deterministic
/// straggler penalties into its observations ([`site::SLOW_SHARD`] keys)
/// without perturbing the fault schedule the workers will see. One
/// relaxed atomic load when no plan is installed.
#[inline]
pub fn afflicted(site: u64, key: u64) -> bool {
    if !ACTIVE.load(Ordering::Relaxed) {
        return false;
    }
    afflicted_slow(site, key)
}

#[cold]
fn afflicted_slow(site: u64, key: u64) -> bool {
    let token = ENROLLED.with(|c| c.get());
    if token == 0 {
        return false;
    }
    let st = STATE.lock().unwrap_or_else(|p| p.into_inner());
    match st.as_ref() {
        Some(s) if s.epoch == token => s.plan.afflicts(site, key).is_some(),
        _ => false,
    }
}

/// Total injections performed since the current plan was installed.
pub fn injected_count() -> u64 {
    INJECTED.load(Ordering::Relaxed)
}

/// Seed source for a fault-fuzzing campaign: hands out a deterministic
/// seed sequence (mirror of `sched::SchedFuzzer`), so CI can shard
/// campaigns by base seed (`PIPEREC_FAULT_SEED_BASE`).
pub struct FaultFuzzer {
    rng: super::prng::Rng,
}

impl FaultFuzzer {
    /// A campaign rooted at `base_seed`.
    pub fn new(base_seed: u64) -> FaultFuzzer {
        FaultFuzzer { rng: super::prng::Rng::new(base_seed) }
    }

    /// Next fault seed of the campaign.
    pub fn next_seed(&mut self) -> u64 {
        self.rng.next_u64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inactive_injects_nothing() {
        let _serial = INSTALL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        assert!(!is_active());
        for s in 0..MAX_SITE as u64 {
            assert!(!inject(s, 0));
        }
        assert!(!is_active());
    }

    #[test]
    fn afflicted_keys_fail_exactly_failures_attempts() {
        let plan = FaultPlan::new(11).always(site::SHARD_READ, 2);
        let _g = plan.install();
        // Every key afflicted; first two attempts fail, third succeeds.
        assert!(inject(site::SHARD_READ, 7));
        assert!(inject(site::SHARD_READ, 7));
        assert!(!inject(site::SHARD_READ, 7));
        assert!(!inject(site::SHARD_READ, 7));
        // Independent attempt counters per key.
        assert!(inject(site::SHARD_READ, 8));
        assert_eq!(injected_count(), 3);
    }

    #[test]
    fn affliction_is_a_pure_function_of_seed_site_key() {
        let a = FaultPlan::new(99).with(site::DMA, RATE_FULL / 2, 1);
        let b = FaultPlan::new(99).with(site::DMA, RATE_FULL / 2, 1);
        for k in 0..256 {
            assert_eq!(a.afflicts(site::DMA, k), b.afflicts(site::DMA, k));
        }
        // A half rate should hit a plausible fraction of 256 keys.
        let hits = (0..256).filter(|&k| a.afflicts(site::DMA, k).is_some()).count();
        assert!((64..=192).contains(&hits), "rate=1/2 hit {hits}/256 keys");
        // Different seeds pick different key sets (with overwhelming odds).
        let c = FaultPlan::new(100).with(site::DMA, RATE_FULL / 2, 1);
        assert!((0..256).any(|k| a.afflicts(site::DMA, k) != c.afflicts(site::DMA, k)));
    }

    #[test]
    fn sites_decorrelate_under_one_seed() {
        let p = FaultPlan::new(5)
            .with(site::SHARD_READ, RATE_FULL / 2, 1)
            .with(site::ROW_DECODE, RATE_FULL / 2, 1);
        let differs = (0..256).any(|k| {
            p.afflicts(site::SHARD_READ, k).is_some() != p.afflicts(site::ROW_DECODE, k).is_some()
        });
        assert!(differs);
    }

    #[test]
    fn permanent_faults_never_succeed() {
        let _g = FaultPlan::new(3).always(site::LANE_LOSS, PERMANENT).install();
        for _ in 0..64 {
            assert!(inject(site::LANE_LOSS, 1));
        }
    }

    #[test]
    fn guard_drop_deactivates_and_clears_state() {
        {
            let _g = FaultPlan::new(1).always(site::SHARD_READ, 1).install();
            assert!(is_active());
            assert!(inject(site::SHARD_READ, 0));
        }
        let _serial = INSTALL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        assert!(!is_active());
        assert!(STATE.lock().unwrap_or_else(|p| p.into_inner()).is_none());
    }

    #[test]
    fn empty_rule_or_zero_rate_injects_nothing() {
        let _g = FaultPlan::new(4).with(site::DMA, 0, 5).install();
        for k in 0..64 {
            assert!(!inject(site::DMA, k));
            assert!(!inject(site::SHARD_READ, k)); // no rule at all
        }
        assert_eq!(injected_count(), 0);
    }

    #[test]
    fn afflicted_is_pure_and_never_consumes_attempts() {
        let _g = FaultPlan::new(21).always(site::SLOW_SHARD, 1).install();
        // Querying any number of times leaves the attempt budget intact…
        for _ in 0..16 {
            assert!(afflicted(site::SLOW_SHARD, 5));
        }
        assert_eq!(injected_count(), 0);
        // …and ignores it: the key stays "afflicted by rule" even after
        // its single failing attempt has been consumed by `inject`.
        assert!(inject(site::SLOW_SHARD, 5));
        assert!(!inject(site::SLOW_SHARD, 5));
        assert!(afflicted(site::SLOW_SHARD, 5));
        // Unenrolled threads never see the plan.
        std::thread::scope(|scope| {
            let clean = scope.spawn(|| afflicted(site::SLOW_SHARD, 5)).join().unwrap();
            assert!(!clean);
        });
    }

    #[test]
    fn stall_is_bounded_and_counts_attempts() {
        let _g = FaultPlan::new(6).always(site::SLOW_SHARD, 1).install();
        let t0 = std::time::Instant::now();
        stall(site::SLOW_SHARD, 9);
        assert!(t0.elapsed() < std::time::Duration::from_millis(100));
        // Attempt consumed: the same key no longer straggles.
        assert!(!inject(site::SLOW_SHARD, 9));
    }

    #[test]
    fn fuzzer_seed_sequence_is_deterministic() {
        let mut a = FaultFuzzer::new(7);
        let mut b = FaultFuzzer::new(7);
        let sa: Vec<u64> = (0..5).map(|_| a.next_seed()).collect();
        let sb: Vec<u64> = (0..5).map(|_| b.next_seed()).collect();
        assert_eq!(sa, sb);
    }

    #[test]
    fn concurrent_injects_under_install_do_not_wedge() {
        let _g = FaultPlan::new(0xF001).always(site::SHARD_READ, 3).install();
        let tok = enroll_token();
        std::thread::scope(|scope| {
            for t in 0..4u64 {
                scope.spawn(move || {
                    enroll(tok);
                    for i in 0..200u64 {
                        inject(site::SHARD_READ, (t + i) & 15);
                    }
                });
            }
        });
    }

    #[test]
    fn unenrolled_threads_are_never_afflicted() {
        let _g = FaultPlan::new(0xF002).always(site::DMA, PERMANENT).install();
        // The installing thread is afflicted…
        assert!(inject(site::DMA, 0));
        // …but a thread that never enrolled (a parallel unrelated test)
        // sails through, and a stale token from a previous epoch is dead.
        std::thread::scope(|scope| {
            let clean = scope.spawn(|| inject(site::DMA, 0)).join().unwrap();
            assert!(!clean);
            let stale = scope
                .spawn(|| {
                    enroll(enroll_token().wrapping_sub(1));
                    inject(site::DMA, 0)
                })
                .join()
                .unwrap();
            assert!(!stale);
        });
    }
}
