//! Summary statistics for measurements (the offline registry has no
//! criterion; the bench harness builds on this module).

/// Summary of a sample of f64 measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns a zeroed summary for empty input.
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                stddev: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p95: 0.0,
                p99: 0.0,
            };
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n > 1 {
            self.m2 / (self.n - 1) as f64
        } else {
            0.0
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.25) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_summary() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin()).collect();
        let s = Summary::of(&xs);
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.stddev() - s.stddev).abs() < 1e-9);
    }

    #[test]
    fn empty_summary_is_zeroed() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }
}
