//! Infrastructure substrates built in-repo because the offline environment
//! lacks the usual crates (clap/rayon/criterion/proptest/loom): a
//! deterministic PRNG, a CLI argument parser, a scoped thread pool, timing
//! helpers, summary statistics, a property-testing mini-framework, a
//! schedule-fuzzing harness for the concurrent dataflow, and a
//! deterministic fault-injection harness for the recovery paths.

pub mod cli;
pub mod fault;
pub mod pool;
pub mod prng;
pub mod prop;
pub mod sched;
pub mod stats;
pub mod timer;

/// Format a byte count using binary units (KiB/MiB/GiB).
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a duration in seconds with an adaptive unit (ns/µs/ms/s).
pub fn fmt_secs(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.2} s")
    }
}

/// Format a throughput in bytes/second.
pub fn fmt_rate(bytes_per_sec: f64) -> String {
    if bytes_per_sec >= 1e9 {
        format!("{:.2} GB/s", bytes_per_sec / 1e9)
    } else if bytes_per_sec >= 1e6 {
        format!("{:.2} MB/s", bytes_per_sec / 1e6)
    } else if bytes_per_sec >= 1e3 {
        format!("{:.2} KB/s", bytes_per_sec / 1e3)
    } else {
        format!("{bytes_per_sec:.1} B/s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(2.0), "2.00 s");
        assert_eq!(fmt_secs(0.002), "2.00 ms");
        assert_eq!(fmt_secs(2e-6), "2.00 µs");
        assert_eq!(fmt_secs(2e-9), "2.0 ns");
    }

    #[test]
    fn rate_formatting() {
        assert_eq!(fmt_rate(12.5e9), "12.50 GB/s");
        assert_eq!(fmt_rate(10e6), "10.00 MB/s");
    }
}
