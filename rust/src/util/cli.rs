//! Minimal command-line argument parser (the offline registry has no clap).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments,
//! with typed accessors and a generated usage string.

use std::collections::BTreeMap;

/// Parsed command line: positionals plus `--key [value]` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process command line (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// True if `--name` was given as a bare flag.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// String option with default.
    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.options
            .get(name)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn opt_str(&self, name: &str) -> Option<String> {
        self.options.get(name).cloned()
    }

    /// Typed option with default; panics with a clear message on a bad value.
    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.options.get(name) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("invalid value for --{name}: {v:?} ({e})")),
        }
    }

    /// First positional argument, if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn parses_key_value_pairs() {
        let a = parse("train --steps 100 --lr=0.05 --verbose");
        assert_eq!(a.subcommand(), Some("train"));
        assert_eq!(a.get::<u32>("steps", 0), 100);
        assert_eq!(a.get::<f64>("lr", 0.0), 0.05);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn defaults_apply_when_missing() {
        let a = parse("etl");
        assert_eq!(a.get::<u32>("steps", 7), 7);
        assert_eq!(a.get_str("pipeline", "p1"), "p1");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn flag_followed_by_flag_is_not_a_value() {
        let a = parse("--fast --steps 5");
        assert!(a.flag("fast"));
        assert_eq!(a.get::<u32>("steps", 0), 5);
    }

    #[test]
    fn positionals_collected_in_order() {
        let a = parse("bench fig13 extra");
        assert_eq!(a.positional, vec!["bench", "fig13", "extra"]);
    }

    #[test]
    #[should_panic(expected = "invalid value for --steps")]
    fn bad_typed_value_panics() {
        let a = parse("--steps abc");
        let _ = a.get::<u32>("steps", 0);
    }
}
