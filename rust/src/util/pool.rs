//! Scoped worker pool over `std::thread` (the offline registry has no
//! rayon). Provides `parallel_chunks` — the only parallel idiom the CPU
//! baseline and data generators need: split a range into contiguous chunks
//! and run a closure per chunk on `n` threads.

/// Run `f(chunk_index, range)` for each of `chunks` contiguous sub-ranges of
/// `0..len` across up to `threads` OS threads, returning per-chunk results
/// in order.
pub fn parallel_chunks<T, F>(len: usize, chunks: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, std::ops::Range<usize>) -> T + Sync,
{
    assert!(chunks > 0, "chunks must be > 0");
    let chunks = chunks.min(len.max(1));
    let per = len.div_ceil(chunks);
    let ranges: Vec<std::ops::Range<usize>> = (0..chunks)
        .map(|i| (i * per).min(len)..((i + 1) * per).min(len))
        .collect();

    std::thread::scope(|scope| {
        let handles: Vec<_> = ranges
            .into_iter()
            .enumerate()
            .map(|(i, r)| scope.spawn({ let f = &f; move || f(i, r) }))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("worker panicked"))
            .collect()
    })
}

/// Map a slice in parallel, preserving order.
pub fn parallel_map<I, O, F>(items: &[I], threads: usize, f: F) -> Vec<O>
where
    I: Sync,
    O: Send,
    F: Fn(&I) -> O + Sync,
{
    let results = parallel_chunks(items.len(), threads, |_, range| {
        items[range].iter().map(&f).collect::<Vec<O>>()
    });
    results.into_iter().flatten().collect()
}

/// Number of worker threads to use by default (respects `PIPEREC_THREADS`).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PIPEREC_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_range_exactly() {
        let parts = parallel_chunks(103, 4, |_, r| r);
        let total: usize = parts.iter().map(|r| r.len()).sum();
        assert_eq!(total, 103);
        // Contiguous and ordered.
        let mut next = 0;
        for r in parts {
            assert_eq!(r.start, next);
            next = r.end;
        }
        assert_eq!(next, 103);
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let xs: Vec<u64> = (0..10_000).collect();
        let partials = parallel_chunks(xs.len(), 8, |_, r| xs[r].iter().sum::<u64>());
        let total: u64 = partials.iter().sum();
        assert_eq!(total, xs.iter().sum::<u64>());
    }

    #[test]
    fn parallel_map_preserves_order() {
        let xs: Vec<u32> = (0..1000).collect();
        let ys = parallel_map(&xs, 4, |x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input_is_fine() {
        let ys: Vec<u32> = parallel_map(&[] as &[u32], 4, |x| *x);
        assert!(ys.is_empty());
    }

    #[test]
    fn more_chunks_than_items_clamps() {
        let parts = parallel_chunks(3, 16, |_, r| r.len());
        assert_eq!(parts.iter().sum::<usize>(), 3);
    }
}
