//! Deterministic pseudo-random number generation (SplitMix64 seeding a
//! xoshiro256** core). Every dataset generator, workload sweep and property
//! test in the repository draws from this module so runs are reproducible
//! from a single `u64` seed.

/// SplitMix64 step — used for seeding and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Fast, high-quality, and fully deterministic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` (Lemire's method, bound > 0).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `s` — used for the
    /// skewed key distributions typical of recommender sparse features.
    /// Uses rejection-inversion (Hörmann) for O(1) sampling.
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n > 0);
        if n == 1 {
            return 0;
        }
        // Simple inverse-CDF over a harmonic approximation: accurate enough
        // for workload generation and fully deterministic.
        let hmax = harmonic_approx(n as f64, s);
        let u = self.next_f64() * hmax;
        let k = inv_harmonic_approx(u, s).clamp(1.0, n as f64);
        (k as u64) - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below_usize(xs.len())]
    }
}

/// Approximate generalized harmonic number H_{n,s}.
fn harmonic_approx(n: f64, s: f64) -> f64 {
    if (s - 1.0).abs() < 1e-9 {
        n.ln() + 0.5772156649
    } else {
        (n.powf(1.0 - s) - 1.0) / (1.0 - s) + 1.0
    }
}

/// Inverse of `harmonic_approx` in its first argument.
fn inv_harmonic_approx(h: f64, s: f64) -> f64 {
    if (s - 1.0).abs() < 1e-9 {
        (h - 0.5772156649).exp()
    } else {
        ((h - 1.0) * (1.0 - s) + 1.0).powf(1.0 / (1.0 - s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_clones() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.below(17);
            assert!(v < 17);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_is_centered() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.next_f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let mut r = Rng::new(13);
        let n = 1000u64;
        let mut counts = vec![0u64; n as usize];
        for _ in 0..50_000 {
            let k = r.zipf(n, 1.1);
            assert!(k < n);
            counts[k as usize] += 1;
        }
        // Head must dominate the tail for a skewed distribution.
        let head: u64 = counts[..10].iter().sum();
        let tail: u64 = counts[n as usize - 10..].iter().sum();
        assert!(head > tail * 10, "head={head} tail={tail}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(15);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn normal_has_unit_variance() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
