//! Property-testing mini-framework (the offline registry has no proptest).
//!
//! A property is a closure over a [`Gen`] handle that draws random inputs
//! and asserts invariants. `check` runs it for `cases` seeds; on failure it
//! re-runs with progressively smaller size budgets (a coarse shrinking
//! pass) and reports the failing seed so the case can be replayed
//! deterministically with `replay`.

use super::prng::Rng;

/// Random-input generation handle passed to properties. The `size` budget
/// bounds collection lengths so shrinking can retry smaller inputs.
pub struct Gen {
    rng: Rng,
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Gen {
            rng: Rng::new(seed),
            size,
        }
    }

    pub fn u64(&mut self, bound: u64) -> u64 {
        self.rng.below(bound.max(1))
    }

    pub fn usize(&mut self, bound: usize) -> usize {
        self.rng.below_usize(bound.max(1))
    }

    pub fn i64_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u128;
        let off = if span > u64::MAX as u128 {
            self.rng.next_u64() as u128
        } else {
            self.rng.below(span as u64) as u128
        };
        (lo as i128 + off as i128) as i64
    }

    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// A length in `[1, size]`.
    pub fn len(&mut self) -> usize {
        1 + self.usize(self.size.max(1))
    }

    /// A possibly-empty length in `[0, size]`.
    pub fn len0(&mut self) -> usize {
        self.usize(self.size + 1)
    }

    /// Vector of draws.
    pub fn vec<T>(&mut self, n: usize, mut f: impl FnMut(&mut Self) -> T) -> Vec<T> {
        (0..n).map(|_| f(self)).collect()
    }

    /// Raw RNG access for custom distributions.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Outcome of a property run.
#[derive(Debug)]
pub enum PropResult {
    Ok { cases: usize },
    Failed { seed: u64, size: usize, message: String },
}

/// Run `prop` for `cases` random cases. Panics with the failing seed on
/// failure (after a coarse shrink pass over smaller size budgets).
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    match check_quiet(name, cases, &prop) {
        PropResult::Ok { .. } => {}
        PropResult::Failed { seed, size, message } => panic!(
            "property '{name}' failed (replay seed={seed}, size={size}): {message}"
        ),
    }
}

/// Like [`check`] but returns the outcome instead of panicking.
pub fn check_quiet(
    name: &str,
    cases: usize,
    prop: &impl Fn(&mut Gen) -> Result<(), String>,
) -> PropResult {
    let base_seed = 0x5EED_0000u64 ^ fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let size = 2 + (case * 64 / cases.max(1));
        let mut g = Gen::new(seed, size);
        if let Err(msg) = prop(&mut g) {
            // Coarse shrink: retry the same seed with smaller size budgets
            // and report the smallest still-failing configuration.
            let mut best = (seed, size, msg);
            let mut s = size;
            while s > 1 {
                s /= 2;
                let mut g = Gen::new(seed, s);
                if let Err(m) = prop(&mut g) {
                    best = (seed, s, m);
                } else {
                    break;
                }
            }
            return PropResult::Failed {
                seed: best.0,
                size: best.1,
                message: best.2,
            };
        }
    }
    PropResult::Ok { cases }
}

/// Replay a specific failing case.
pub fn replay(
    seed: u64,
    size: usize,
    prop: impl Fn(&mut Gen) -> Result<(), String>,
) -> Result<(), String> {
    let mut g = Gen::new(seed, size);
    prop(&mut g)
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let close = (x - y).abs() <= tol + tol * x.abs().max(y.abs())
            || (x.is_nan() && y.is_nan());
        if !close {
            return Err(format!("mismatch at {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Assert two f32 slices are **bitwise** equal (NaNs compare by payload,
/// `0.0` ≠ `-0.0`) — the comparison the concurrency/differential suites
/// use for "replays the reference trajectory exactly".
pub fn assert_bits_equal(a: &[f32], b: &[f32]) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if x.to_bits() != y.to_bits() {
            return Err(format!(
                "bit mismatch at {i}: {x} ({:#010x}) vs {y} ({:#010x})",
                x.to_bits(),
                y.to_bits()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add_commutes", 50, |g| {
            let a = g.i64_range(-1000, 1000);
            let b = g.i64_range(-1000, 1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err("addition does not commute".into())
            }
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let res = check_quiet("always_fails", 10, &|_g: &mut Gen| Err("nope".to_string()));
        match res {
            PropResult::Failed { message, .. } => assert_eq!(message, "nope"),
            _ => panic!("expected failure"),
        }
    }

    #[test]
    fn shrink_reduces_size() {
        // Fails whenever size >= 4: shrinker should land below the original.
        let res = check_quiet("size_sensitive", 64, &|g: &mut Gen| {
            if g.size >= 4 {
                Err(format!("size {}", g.size))
            } else {
                Ok(())
            }
        });
        match res {
            PropResult::Failed { size, .. } => assert!(size >= 4 && size <= 7, "size={size}"),
            _ => panic!("expected failure"),
        }
    }

    #[test]
    fn replay_is_deterministic() {
        let prop = |g: &mut Gen| -> Result<(), String> {
            let v = g.u64(1000);
            Err(format!("{v}"))
        };
        let a = replay(42, 8, prop).unwrap_err();
        let b = replay(42, 8, prop).unwrap_err();
        assert_eq!(a, b);
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-6).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-6).is_err());
    }

    #[test]
    fn assert_bits_equal_is_exact() {
        assert!(assert_bits_equal(&[1.0, f32::NAN], &[1.0, f32::NAN]).is_ok());
        // Same value, different bits: -0.0 vs 0.0 must be caught.
        assert!(assert_bits_equal(&[0.0], &[-0.0]).is_err());
        assert!(assert_bits_equal(&[1.0], &[1.0 + f32::EPSILON]).is_err());
        assert!(assert_bits_equal(&[1.0], &[1.0, 2.0]).is_err());
    }
}
