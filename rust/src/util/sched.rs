//! Schedule-fuzzing hooks for the concurrent dataflow (the offline
//! registry has no loom): instrumented **yield points** on the hot
//! cross-thread operations — staging-queue push/pop, arena credit
//! acquire/release, reduce-bus post/wait — that, when a [`SchedFuzzer`]
//! seed is installed, inject seed-derived perturbations (yields, bounded
//! spins, micro-sleeps) to drive the thread scheduler through
//! interleavings it would rarely pick on its own.
//!
//! The concurrency suite (`rust/tests/prop_concurrent.rs`) replays the
//! multi-device train loop under hundreds of perturbed schedules and
//! asserts the results stay **bitwise identical** to the deterministic
//! reference — the claim is schedule-independence, so the harness only
//! needs interleaving *diversity*, not exact replay; the seed makes a
//! failing perturbation pattern approximately reproducible.
//!
//! When no fuzzer is installed, [`point`] is a single relaxed atomic
//! load — cheap enough to leave in production paths permanently.
//!
//! Installation is process-global; [`install`] serializes installers on a
//! mutex (held by the returned guard) so concurrently running tests
//! cannot interleave two different seeds.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

/// Well-known instrumentation sites, mixed into the perturbation draw so
/// the same seed perturbs different operations differently.
pub mod site {
    /// Staging-queue producer side (`StagingQueue::push`).
    pub const STAGING_PUSH: u64 = 1;
    /// Staging-queue consumer side (`StagingConsumer::pop`).
    pub const STAGING_POP: u64 = 2;
    /// Arena credit acquire (`DeviceArena::acquire`).
    pub const ARENA_ACQUIRE: u64 = 3;
    /// Arena credit return (`DeviceArena::release`).
    pub const ARENA_RELEASE: u64 = 4;
    /// Gradient contribution post (`ReduceBus::post`).
    pub const REDUCE_POST: u64 = 5;
    /// Epoch resolution wait (`ReduceBus::wait_epoch`).
    pub const REDUCE_WAIT: u64 = 6;
    /// Consumer-lane slot handoff in the multi-device train loop.
    pub const LANE_HANDOFF: u64 = 7;
    /// A joining lane admitted to the fleet at a quiesce point
    /// (`FleetRuntime` lane-add).
    pub const LANE_JOIN: u64 = 8;
    /// A scripted knob change applied at the routing frontier
    /// (`ControlScript` event in the fleet router).
    pub const KNOB_APPLY: u64 = 9;
}

static ACTIVE: AtomicBool = AtomicBool::new(false);
static SEED: AtomicU64 = AtomicU64::new(0);
static COUNTER: AtomicU64 = AtomicU64::new(0);
static INSTALL_LOCK: Mutex<()> = Mutex::new(());

/// RAII handle for an installed fuzz schedule: dropping it deactivates
/// the perturbations and releases the global installer lock.
pub struct FuzzGuard {
    _serial: MutexGuard<'static, ()>,
}

impl Drop for FuzzGuard {
    fn drop(&mut self) {
        ACTIVE.store(false, Ordering::SeqCst);
    }
}

/// Activate schedule perturbations derived from `seed` until the guard
/// drops. Blocks while another fuzz schedule is installed (tests running
/// in parallel serialize here instead of mixing seeds).
pub fn install(seed: u64) -> FuzzGuard {
    let serial = INSTALL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    SEED.store(seed, Ordering::SeqCst);
    COUNTER.store(0, Ordering::SeqCst);
    ACTIVE.store(true, Ordering::SeqCst);
    FuzzGuard { _serial: serial }
}

/// Is a fuzz schedule currently installed?
pub fn is_active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// A schedule-perturbation point. No-op (one relaxed load) unless a
/// fuzzer is installed; otherwise draws a deterministic function of
/// (seed, site, global arrival index) and maybe yields/spins/sleeps.
#[inline]
pub fn point(site: u64) {
    if !ACTIVE.load(Ordering::Relaxed) {
        return;
    }
    perturb(site);
}

#[cold]
fn perturb(site: u64) {
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    let mut x = SEED.load(Ordering::Relaxed)
        ^ site.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ n.wrapping_mul(0xD1B5_4A32_D192_ED03);
    // splitmix64 finalizer: decorrelate consecutive arrival indices.
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    match x & 7 {
        0 | 1 => std::thread::yield_now(),
        2 => {
            // Bounded spin: stretches the race window without descheduling.
            let spins = (x >> 8) & 127;
            for _ in 0..spins {
                std::hint::spin_loop();
            }
        }
        3 => {
            // Micro-sleep: forces a real deschedule (≤ ~40 µs).
            std::thread::sleep(std::time::Duration::from_micros((x >> 16) % 40));
        }
        _ => {} // run straight through
    }
}

/// Seed source for a fuzzing campaign: hands out a deterministic seed
/// sequence and runs closures under each installed schedule.
pub struct SchedFuzzer {
    rng: super::prng::Rng,
}

impl SchedFuzzer {
    /// A campaign rooted at `base_seed` (each campaign seed yields a
    /// deterministic sequence of schedule seeds).
    pub fn new(base_seed: u64) -> SchedFuzzer {
        SchedFuzzer { rng: super::prng::Rng::new(base_seed) }
    }

    /// Next schedule seed of the campaign.
    pub fn next_seed(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Run `f` under the campaign's next perturbed schedule; returns the
    /// schedule seed (for failure reports) alongside the result.
    pub fn with_schedule<T>(&mut self, f: impl FnOnce() -> T) -> (u64, T) {
        let seed = self.next_seed();
        let _guard = install(seed);
        (seed, f())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inactive_points_are_noops() {
        // Hold the installer lock so no parallel test can activate a
        // schedule while we assert the inactive fast path (a FuzzGuard
        // clears ACTIVE before it releases this lock).
        let _serial = INSTALL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        assert!(!is_active());
        // Must not panic, block, or activate anything.
        for s in 0..8 {
            point(s);
        }
        assert!(!is_active());
    }

    #[test]
    fn install_activates_and_guard_deactivates() {
        {
            let _g = install(42);
            assert!(is_active());
            for _ in 0..100 {
                point(site::STAGING_PUSH);
            }
            assert!(is_active());
        }
        // Re-acquiring the installer lock proves the guard cleared the
        // flag (no other installer can hold it while we check).
        let _serial = INSTALL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        assert!(!is_active());
    }

    #[test]
    fn fuzzer_seed_sequence_is_deterministic() {
        let mut a = SchedFuzzer::new(7);
        let mut b = SchedFuzzer::new(7);
        let sa: Vec<u64> = (0..5).map(|_| a.next_seed()).collect();
        let sb: Vec<u64> = (0..5).map(|_| b.next_seed()).collect();
        assert_eq!(sa, sb);
        let mut c = SchedFuzzer::new(8);
        assert_ne!(sa[0], c.next_seed());
    }

    #[test]
    fn with_schedule_installs_for_the_closure_only() {
        let mut f = SchedFuzzer::new(3);
        let (seed, was_active) = f.with_schedule(|| {
            point(site::REDUCE_POST);
            is_active()
        });
        assert!(was_active);
        let _serial = INSTALL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        assert!(!is_active());
        let _ = seed;
    }

    #[test]
    fn concurrent_points_under_install_do_not_wedge() {
        let _g = install(0xF00D);
        std::thread::scope(|scope| {
            for t in 0..4 {
                scope.spawn(move || {
                    for i in 0..200u64 {
                        point((t + i) & 7);
                    }
                });
            }
        });
    }
}
