//! P2P DMA transfer engine: schedules chunked device writes through the
//! calibrated channel models (paper Fig. 6/11) on a single simulated
//! engine clock, so a staged slot's transfer overlaps the next shard's
//! fused execution (§3.5) while per-transfer latency and effective
//! bandwidth stay observable — `fig11_transfers` drives this engine.
//!
//! A transfer submitted at simulated time `now` starts when the engine is
//! free (`max(now, previous done)`) and takes
//! [`ChannelModel::time_chunked`] for its byte count: the paper's
//! conclusion that MiB-scale chunks with depth-2 double buffering hide the
//! per-chunk setup cost is the default configuration.
//!
//! # Failure domain: DMA re-issue
//!
//! Submission is fallible. An attempt fails when the deterministic
//! fault plan afflicts site [`dma`](crate::util::fault::site::DMA) —
//! keyed by the engine's transfer ordinal, so the afflicted set is a
//! pure function of the fault seed, not of thread schedule — or when
//! its wire time exceeds [`TransferConfig::timeout_s`]. A failed
//! attempt still occupies the engine for the time it burned (the wire
//! was busy; the payload just never became resident), then the engine
//! re-issues up to [`TransferConfig::max_retries`] times before
//! surfacing [`EtlError::Fault`]. Successful-after-retry transfers
//! carry their attempt count in [`TransferRecord::retries`] and the
//! engine tallies [`retried_transfers`](TransferEngine::retried_transfers)
//! / [`failed_transfers`](TransferEngine::failed_transfers) so the
//! train loop's `TrainReport` can account for every re-issue exactly.

use std::collections::VecDeque;

use crate::error::{EtlError, Result};
use crate::memsys::{ChannelModel, Path};
use crate::trace::{self, kind as tkind};
use crate::util::fault::{self, site as fsite};

/// Knobs of the DMA engine.
#[derive(Debug, Clone)]
pub struct TransferConfig {
    /// Physical path transfers ride (default: FPGA → GPU one-way P2P).
    pub path: Path,
    /// DMA chunk size (paper: MiB-scale chunks plateau the channel).
    pub chunk_bytes: u64,
    /// Outstanding chunks (2 = double buffering).
    pub depth: u32,
    /// Retained per-transfer records (ring buffer; totals keep counting).
    pub record_cap: usize,
    /// Re-issues allowed per transfer before the engine gives up and
    /// surfaces [`EtlError::Fault`] (failed attempts still charge wire
    /// time).
    pub max_retries: u32,
    /// Per-attempt deadline in simulated seconds; an attempt whose wire
    /// time exceeds it is cut off at the deadline and re-issued.
    /// Default: infinite (no timeout).
    pub timeout_s: f64,
}

impl Default for TransferConfig {
    fn default() -> Self {
        TransferConfig {
            path: Path::P2pToGpu,
            chunk_bytes: 4 << 20,
            depth: 2,
            record_cap: 4096,
            max_retries: 3,
            timeout_s: f64::INFINITY,
        }
    }
}

/// Accounting of one scheduled transfer (simulated seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransferRecord {
    /// Payload bytes moved.
    pub bytes: u64,
    /// When the producer submitted the transfer.
    pub submit_s: f64,
    /// When the engine started it (submit, or later if the engine was
    /// busy with a previous slot).
    pub start_s: f64,
    /// When the last chunk landed in device memory.
    pub done_s: f64,
    /// Failed attempts this transfer survived before landing (0 = clean).
    pub retries: u32,
}

impl TransferRecord {
    /// Submit-to-resident latency (includes engine queueing and any
    /// re-issued attempts).
    pub fn latency_s(&self) -> f64 {
        self.done_s - self.submit_s
    }

    /// Wire time of this transfer, including failed attempts — a
    /// retried transfer's effective bandwidth degrades accordingly.
    pub fn transfer_s(&self) -> f64 {
        self.done_s - self.start_s
    }

    /// Effective bandwidth over the wire time (the ramp-then-plateau
    /// curve of Fig. 11).
    pub fn effective_bw(&self) -> f64 {
        if self.bytes == 0 {
            return 0.0;
        }
        self.bytes as f64 / self.transfer_s().max(1e-12)
    }
}

/// The DMA engine: one channel, one clock, chunked double-buffered
/// transfers, cumulative accounting.
#[derive(Debug)]
pub struct TransferEngine {
    channel: ChannelModel,
    cfg: TransferConfig,
    /// Simulated time the engine next becomes free.
    free_at_s: f64,
    records: VecDeque<TransferRecord>,
    transfers: u64,
    bytes: u64,
    busy_s: f64,
    /// Simulated seconds transfers waited behind the engine.
    queued_s: f64,
    /// Transfer ordinals handed out so far — the fault-injection key, so
    /// an afflicted transfer is the same one on every schedule.
    issued: u64,
    /// Failed attempts that were re-issued.
    retried: u64,
    /// Transfers abandoned after exhausting `max_retries`.
    failed: u64,
    /// Device lane this engine's clock belongs to (trace span lane;
    /// engines outside a [`TransferSet`] default to 0).
    device: u32,
}

impl TransferEngine {
    pub fn new(cfg: TransferConfig) -> TransferEngine {
        assert!(cfg.chunk_bytes > 0 && cfg.depth > 0, "bad transfer config");
        TransferEngine {
            channel: ChannelModel::of(cfg.path),
            cfg,
            free_at_s: 0.0,
            records: VecDeque::new(),
            transfers: 0,
            bytes: 0,
            busy_s: 0.0,
            queued_s: 0.0,
            issued: 0,
            retried: 0,
            failed: 0,
            device: 0,
        }
    }

    /// Tag this engine's clock with its device lane for trace spans.
    pub fn with_device(mut self, device: u32) -> TransferEngine {
        self.device = device;
        self
    }

    /// Engine on the training-ingest path (FPGA → GPU P2P) with the
    /// default chunking.
    pub fn p2p() -> TransferEngine {
        TransferEngine::new(TransferConfig::default())
    }

    /// The calibrated channel this engine drives.
    pub fn channel(&self) -> &ChannelModel {
        &self.channel
    }

    /// Schedule a transfer of `bytes` submitted at simulated time
    /// `now_s`; returns its timing record. The engine serializes
    /// transfers: this one starts when the previous one is done.
    ///
    /// Fallible: attempts afflicted by the installed fault plan (site
    /// `dma`, keyed by this engine's transfer ordinal) or cut off by
    /// [`TransferConfig::timeout_s`] are re-issued up to
    /// [`TransferConfig::max_retries`] times — each failed attempt
    /// still advances the engine clock for the wire time it burned —
    /// before surfacing [`EtlError::Fault`]. Without an installed plan
    /// and with the default infinite timeout this never errors.
    pub fn submit(&mut self, now_s: f64, bytes: u64) -> Result<TransferRecord> {
        let key = self.issued;
        self.issued += 1;
        let span = trace::begin(tkind::DMA_TRANSFER, self.device, key);
        let wire_s = self
            .channel
            .time_chunked(bytes, self.cfg.chunk_bytes, self.cfg.depth);
        let first_start_s = self.free_at_s.max(now_s);
        let mut start_s = first_start_s;
        let mut retries = 0u32;
        loop {
            let timed_out = wire_s > self.cfg.timeout_s;
            let attempt_s = if timed_out { self.cfg.timeout_s } else { wire_s };
            if timed_out || fault::inject(fsite::DMA, key) {
                // The attempt occupied the wire before dying; charge it.
                self.free_at_s = start_s + attempt_s;
                self.busy_s += attempt_s;
                if retries == self.cfg.max_retries {
                    self.failed += 1;
                    span.end_retries(retries + 1);
                    return Err(EtlError::Fault { site: fsite::name(fsite::DMA), key });
                }
                retries += 1;
                self.retried += 1;
                start_s = self.free_at_s;
                continue;
            }
            let rec = TransferRecord {
                bytes,
                submit_s: now_s,
                start_s: first_start_s,
                done_s: start_s + wire_s,
                retries,
            };
            self.free_at_s = rec.done_s;
            self.transfers += 1;
            self.bytes += bytes;
            self.busy_s += wire_s;
            self.queued_s += first_start_s - now_s;
            if self.records.len() == self.cfg.record_cap.max(1) {
                self.records.pop_front();
            }
            self.records.push_back(rec);
            span.end_io(rec.start_s, rec.done_s, bytes, retries);
            return Ok(rec);
        }
    }

    /// Transfers that landed so far (failed ones are not counted here).
    pub fn transfers(&self) -> u64 {
        self.transfers
    }

    /// Failed attempts the engine re-issued.
    pub fn retried_transfers(&self) -> u64 {
        self.retried
    }

    /// Transfers abandoned after exhausting the retry budget.
    pub fn failed_transfers(&self) -> u64 {
        self.failed
    }

    /// Total payload bytes moved.
    pub fn total_bytes(&self) -> u64 {
        self.bytes
    }

    /// Simulated seconds the engine spent on the wire.
    pub fn busy_s(&self) -> f64 {
        self.busy_s
    }

    /// Simulated seconds transfers spent queued behind the engine.
    pub fn queued_s(&self) -> f64 {
        self.queued_s
    }

    /// Simulated time the engine next becomes free.
    pub fn free_at_s(&self) -> f64 {
        self.free_at_s
    }

    /// Mean effective bandwidth across everything moved.
    pub fn mean_bw(&self) -> f64 {
        if self.busy_s <= 0.0 {
            0.0
        } else {
            self.bytes as f64 / self.busy_s
        }
    }

    /// Retained per-transfer records, oldest first.
    pub fn records(&self) -> &VecDeque<TransferRecord> {
        &self.records
    }
}

/// Per-device DMA queues: one [`TransferEngine`] per simulated GPU, each
/// on its **own engine clock** — device 0's transfer never queues behind
/// device 1's (the fleet analogue of the single-engine serialization
/// above). The multi-device train loop and the `multi-device` hotpath
/// bench section build their per-lane clocks here and split them across
/// the lane workers via [`into_engines`](Self::into_engines); shared-set
/// accounting stays available through the aggregate accessors.
#[derive(Debug)]
pub struct TransferSet {
    engines: Vec<TransferEngine>,
}

impl TransferSet {
    /// One engine per device, identical channel/chunking configuration.
    pub fn new(devices: usize, cfg: TransferConfig) -> TransferSet {
        assert!(devices >= 1, "transfer set needs at least one device");
        TransferSet {
            engines: (0..devices)
                .map(|d| TransferEngine::new(cfg.clone()).with_device(d as u32))
                .collect(),
        }
    }

    /// Number of per-device DMA queues.
    pub fn devices(&self) -> usize {
        self.engines.len()
    }

    /// Grow the set by one engine clock (a joining lane's DMA queue),
    /// starting at simulated time zero like its launch-time siblings.
    /// Returns the new device index.
    pub fn grow(&mut self, cfg: TransferConfig) -> usize {
        let device = self.engines.len();
        self.engines.push(TransferEngine::new(cfg).with_device(device as u32));
        device
    }

    /// The engine of simulated GPU `device`.
    pub fn engine(&self, device: usize) -> &TransferEngine {
        &self.engines[device]
    }

    /// Mutable engine access (a pack worker owns its device's clock).
    pub fn engine_mut(&mut self, device: usize) -> &mut TransferEngine {
        &mut self.engines[device]
    }

    /// Schedule a transfer on `device`'s queue at simulated time `now_s`.
    pub fn submit(&mut self, device: usize, now_s: f64, bytes: u64) -> Result<TransferRecord> {
        self.engines[device].submit(now_s, bytes)
    }

    /// Total payload bytes moved across every device.
    pub fn total_bytes(&self) -> u64 {
        self.engines.iter().map(|e| e.total_bytes()).sum()
    }

    /// Re-issued attempts summed across every device's engine.
    pub fn retried_total(&self) -> u64 {
        self.engines.iter().map(|e| e.retried_transfers()).sum()
    }

    /// Abandoned transfers summed across every device's engine.
    pub fn failed_total(&self) -> u64 {
        self.engines.iter().map(|e| e.failed_transfers()).sum()
    }

    /// Sum of per-device wire seconds (the engines run in parallel, so
    /// this is aggregate DMA work, not wall time).
    pub fn busy_s_total(&self) -> f64 {
        self.engines.iter().map(|e| e.busy_s()).sum()
    }

    /// Split into the per-device engines (each worker thread takes its
    /// own clock).
    pub fn into_engines(self) -> Vec<TransferEngine> {
        self.engines
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MIB: u64 = 1 << 20;

    fn engine(chunk: u64, depth: u32) -> TransferEngine {
        TransferEngine::new(TransferConfig {
            path: Path::P2pToGpu,
            chunk_bytes: chunk,
            depth,
            record_cap: 8,
            ..TransferConfig::default()
        })
    }

    #[test]
    fn single_chunk_transfer_matches_channel_time() {
        // chunk ≥ payload and depth 1 degenerate to the raw channel model.
        let mut e = engine(64 * MIB, 1);
        let rec = e.submit(0.0, MIB).unwrap();
        let want = ChannelModel::of(Path::P2pToGpu).time(MIB);
        assert!((rec.done_s - want).abs() < 1e-12, "{} vs {want}", rec.done_s);
        assert_eq!(rec.start_s, 0.0);
        assert_eq!(rec.bytes, MIB);
        assert_eq!(rec.retries, 0);
    }

    #[test]
    fn engine_serializes_back_to_back_submissions() {
        let mut e = engine(MIB, 2);
        let a = e.submit(0.0, 8 * MIB).unwrap();
        let b = e.submit(0.0, 8 * MIB).unwrap();
        assert_eq!(b.start_s, a.done_s, "second transfer queues behind the first");
        assert!(b.latency_s() > b.transfer_s());
        assert!(e.queued_s() > 0.0);
        assert_eq!(e.transfers(), 2);
        assert_eq!(e.total_bytes(), 16 * MIB);
    }

    #[test]
    fn idle_engine_starts_at_submit_time() {
        let mut e = engine(MIB, 2);
        let _ = e.submit(0.0, MIB).unwrap();
        // Submitted well after the first finished: no queueing.
        let rec = e.submit(1.0, MIB).unwrap();
        assert_eq!(rec.start_s, 1.0);
        assert!((rec.latency_s() - rec.transfer_s()).abs() < 1e-15);
    }

    #[test]
    fn chunked_double_buffering_approaches_plateau() {
        // 256 MiB in 4 MiB depth-2 chunks must be close to pure payload
        // time — the paper's "batch into MiB chunks" conclusion.
        let mut e = engine(4 * MIB, 2);
        let rec = e.submit(0.0, 256 * MIB).unwrap();
        let plateau = e.channel().bandwidth;
        assert!(rec.effective_bw() > 0.95 * plateau, "{}", rec.effective_bw());
        // And strictly worse with tiny serial chunks.
        let mut tiny = engine(64 * 1024, 1);
        let slow = tiny.submit(0.0, 256 * MIB).unwrap();
        assert!(slow.transfer_s() > rec.transfer_s());
    }

    #[test]
    fn empty_transfer_is_free() {
        let mut e = engine(MIB, 2);
        let rec = e.submit(3.5, 0).unwrap();
        assert_eq!(rec.start_s, 3.5);
        assert_eq!(rec.done_s, 3.5);
        assert_eq!(rec.effective_bw(), 0.0);
    }

    #[test]
    fn transfer_set_clocks_are_independent_per_device() {
        let mut set = TransferSet::new(2, TransferConfig {
            path: Path::P2pToGpu,
            chunk_bytes: MIB,
            depth: 2,
            record_cap: 8,
            ..TransferConfig::default()
        });
        // Load device 0's queue; device 1 must start at submit time.
        let a = set.submit(0, 0.0, 64 * MIB).unwrap();
        let b = set.submit(0, 0.0, 64 * MIB).unwrap();
        assert_eq!(b.start_s, a.done_s, "same device serializes");
        let c = set.submit(1, 0.0, 64 * MIB).unwrap();
        assert_eq!(c.start_s, 0.0, "sibling device has its own clock");
        assert_eq!(set.total_bytes(), 192 * MIB);
        assert!(set.busy_s_total() > set.engine(0).busy_s());
        assert_eq!(set.devices(), 2);
        assert_eq!(set.retried_total(), 0);
        assert_eq!(set.failed_total(), 0);
        let engines = set.into_engines();
        assert_eq!(engines.len(), 2);
        assert_eq!(engines[0].transfers(), 2);
        assert_eq!(engines[1].transfers(), 1);
    }

    #[test]
    fn transfer_set_grow_adds_a_fresh_engine_clock() {
        let cfg = TransferConfig {
            path: Path::P2pToGpu,
            chunk_bytes: MIB,
            depth: 2,
            record_cap: 8,
            ..TransferConfig::default()
        };
        let mut set = TransferSet::new(2, cfg.clone());
        set.submit(0, 0.0, 64 * MIB).unwrap();
        assert_eq!(set.grow(cfg), 2);
        assert_eq!(set.devices(), 3);
        // The grown engine starts at sim time zero on its own clock.
        let rec = set.submit(2, 0.0, 64 * MIB).unwrap();
        assert_eq!(rec.start_s, 0.0, "grown device has its own clock");
        assert_eq!(set.engine(2).transfers(), 1);
        assert_eq!(set.total_bytes(), 128 * MIB);
    }

    #[test]
    fn record_ring_is_bounded_but_totals_keep_counting() {
        let mut e = engine(MIB, 2);
        for _ in 0..20 {
            e.submit(0.0, MIB).unwrap();
        }
        assert_eq!(e.records().len(), 8);
        assert_eq!(e.transfers(), 20);
        assert_eq!(e.total_bytes(), 20 * MIB);
        assert!(e.mean_bw() > 0.0);
    }

    #[test]
    fn injected_dma_fault_is_retried_and_charged() {
        // Every transfer fails its first 2 attempts, then lands.
        let plan = fault::FaultPlan::new(9).always(fsite::DMA, 2);
        let _g = plan.install();
        let mut e = engine(MIB, 2);
        let rec = e.submit(0.0, 8 * MIB).unwrap();
        assert_eq!(rec.retries, 2);
        assert_eq!(e.retried_transfers(), 2);
        assert_eq!(e.failed_transfers(), 0);
        assert_eq!(e.transfers(), 1);
        // The two dead attempts burned wire time: latency is three
        // attempts long, and the clean wire time is one third of busy.
        let clean = e.channel().time_chunked(8 * MIB, MIB, 2);
        assert!((rec.latency_s() - 3.0 * clean).abs() < 1e-12);
        assert!((e.busy_s() - 3.0 * clean).abs() < 1e-12);
    }

    #[test]
    fn dma_fault_past_retry_budget_is_a_typed_error() {
        let plan = fault::FaultPlan::new(9).always(fsite::DMA, fault::PERMANENT);
        let _g = plan.install();
        let mut e = TransferEngine::new(TransferConfig {
            chunk_bytes: MIB,
            depth: 2,
            record_cap: 8,
            max_retries: 2,
            ..TransferConfig::default()
        });
        let before = e.free_at_s();
        let err = e.submit(0.0, 8 * MIB).unwrap_err();
        assert!(matches!(err, EtlError::Fault { site: "dma", key: 0 }));
        assert_eq!(e.failed_transfers(), 1);
        assert_eq!(e.retried_transfers(), 2);
        assert_eq!(e.transfers(), 0, "abandoned transfers never land");
        assert!(e.free_at_s() > before, "dead attempts still occupied the engine");
        // The next ordinal is still afflicted (always-plan), but the
        // engine keeps issuing fresh keys: ordinal 1, not a replay of 0.
        let err2 = e.submit(0.0, MIB).unwrap_err();
        assert!(matches!(err2, EtlError::Fault { key: 1, .. }));
    }

    #[test]
    fn per_transfer_timeout_cuts_off_and_reissues() {
        // No fault plan: the deadline alone kills every attempt of a
        // transfer whose wire time exceeds it.
        let wire = ChannelModel::of(Path::P2pToGpu).time_chunked(64 * MIB, MIB, 2);
        let mut e = TransferEngine::new(TransferConfig {
            chunk_bytes: MIB,
            depth: 2,
            record_cap: 8,
            max_retries: 1,
            timeout_s: wire / 2.0,
            ..TransferConfig::default()
        });
        let err = e.submit(0.0, 64 * MIB).unwrap_err();
        assert!(matches!(err, EtlError::Fault { site: "dma", .. }));
        // Two attempts, each cut at the deadline.
        assert!((e.busy_s() - wire).abs() < 1e-12);
        assert_eq!(e.retried_transfers(), 1);
        assert_eq!(e.failed_transfers(), 1);
        // A payload under the deadline still lands untouched.
        let ok = e.submit(0.0, MIB).unwrap();
        assert_eq!(ok.retries, 0);
    }

    #[test]
    fn fault_free_submission_is_byte_identical_to_preplan_behavior() {
        // With no installed plan the Result wrapper is the only change:
        // timings and accounting match the historical engine exactly.
        let mut e = engine(MIB, 2);
        let a = e.submit(0.0, 8 * MIB).unwrap();
        assert_eq!(a.retries, 0);
        assert_eq!(e.retried_transfers(), 0);
        assert_eq!(e.failed_transfers(), 0);
        assert!((e.busy_s() - a.transfer_s()).abs() < 1e-15);
    }
}
