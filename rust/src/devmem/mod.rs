//! Zero-copy device-memory subsystem (paper §3, Fig. 3): a pinned staging
//! arena over a simulated GPU memory region plus a P2P DMA transfer
//! engine, completing the producer→trainer path as a true zero-copy
//! dataflow.
//!
//! The paper's headline ingest claim is that the format-aware packer
//! "streams training-ready batches directly into GPU memory via P2P DMA
//! transfers, enabling zero-copy ingest". Before this subsystem the live
//! train loop handed heap-allocated `PackedBatch`es over a channel — every
//! shard was packed into fresh host memory and then logically copied to
//! the trainer. Now the fused engine packs each tile **once, directly into
//! an arena-backed staging slot**, the transfer engine accounts the
//! chunked P2P DMA that makes the slot resident in GPU memory, and the
//! trainer steps **in place** on borrowed [`DeviceBatchView`]s, returning
//! the slot's credit when done.
//!
//! # End-to-end data path
//!
//! ```text
//!   ingest workers          producer thread                consumer thread
//!  ┌──────────────┐   ┌──────────────────────────┐   ┌─────────────────────┐
//!  │ shard I/O    │   │  DeviceArena::acquire    │   │  pop DeviceBatch    │
//!  │ (synth/rcol/ │──▶│  fused exec ──▶ pack     │──▶│  trainer.step_device│
//!  │  tsv chunks) │   │  straight into the slot  │   │  (in-place views)   │
//!  └──────────────┘   │  TransferEngine::submit  │   │  arena.release      │
//!        ▲            │  (chunked P2P DMA sim)   │   │  (credit returns)   │
//!        │            └──────────────────────────┘   └─────────────────────┘
//!        │                        │                            │
//!        └── recycled Batch ──────┘        StagingSlot credits ◀┘
//! ```
//!
//! * [`DeviceArena`] — a slab allocator over a fixed simulated GPU region
//!   (registered in the [`crate::memsys::Mmu`] address space as
//!   [`crate::memsys::MemClass::Gpu`] pages) handing out [`StagingSlot`]s
//!   with epoch-based reclamation and credit-gated backpressure: `acquire`
//!   blocks while every slot is in flight, exactly like the DMA engine
//!   waiting for a staging credit (§3, Fig. 3).
//! * [`TransferEngine`] — schedules chunked P2P DMA writes through the
//!   calibrated [`crate::memsys::ChannelModel`] (Fig. 11), serializing
//!   transfers on one engine clock so a slot's transfer overlaps the next
//!   shard's fused exec, with per-transfer latency/bandwidth records.
//! * [`DeviceBatchView`] — a borrowed, device-addressed view of a staged
//!   batch; the trainer consumes it in place (no copy, no allocation).
//!
//! # Multi-device topology (N simulated GPUs)
//!
//! [`ArenaSet`] and [`TransferSet`] scale the same protocol to a fleet:
//! one arena region and one DMA queue **per device**, the arenas' regions
//! disjoint `MemClass::Gpu` ranges of one shared [`crate::memsys::Mmu`]
//! address space, the DMA queues on independent engine clocks. The
//! scheduler's routing layer
//! ([`crate::coordinator::scheduler::DeviceRouter`]) assigns each
//! ingested shard a device lane — round-robin for bit-reproducibility,
//! least-loaded for throughput — and the multi-device train loop steps
//! one `Trainer` replica per device, periodically all-reducing parameters
//! (deterministic tree reduction costed against the calibrated channels).
//!
//! ```text
//!                     ┌─ route ─▶ arena 0 ── DMA 0 ─▶ replica 0 ─┐
//!   ingest ─ shards ──┤          arena 1 ── DMA 1 ─▶ replica 1 ──┼─ all-reduce
//!                     └─ ... ──▶ arena N ── DMA N ─▶ replica N ──┘   (tree)
//! ```
//!
//! Credits, epochs and stats stay per-device: a stalled GPU
//! backpressures only its own lane — the per-device staging discipline
//! multi-device recommender training needs (BagPipe; the heterogeneous
//! acceleration pipeline of Adnan et al.).
//!
//! # Zero-copy invariants (pinned by `rust/tests/prop_devmem.rs`)
//!
//! * each packed byte is written exactly once, by the fused packer,
//!   directly into arena-backed slot memory ([`ArenaStats::packed_bytes`]
//!   equals the byte volume the trainer consumed);
//! * after each slot's first pack (warmup), the steady-state loop performs
//!   **zero** per-shard `PackedBatch` heap allocations
//!   ([`ArenaStats::steady_allocs`] stays 0);
//! * arena-backed delivery is bit-identical to the heap `PackedBatch`
//!   channel path across worker counts × slot counts × arena sizes.
//!
//! # Resident caches (embedding hot tier)
//!
//! Beyond the staging slots, an arena can pin an extra fixed
//! [`CacheRegion`] of its device's memory via
//! [`DeviceArena::reserve_cache`] — the hot tier of the sharded embedding
//! cache (`crate::runtime::embedding`). The reservation is bounded by the
//! device's staging budget, so a table that exceeds it **must**
//! oversubscribe into the simulated host cold tier, with
//! promotion/demotion traffic costed against the channel models.

pub mod arena;
pub mod transfer;

pub use arena::{
    ArenaConfig, ArenaSet, ArenaStats, CacheRegion, DeviceArena, DeviceBatchView, StagingSlot,
};
pub use transfer::{TransferConfig, TransferEngine, TransferRecord, TransferSet};
