//! Pinned staging arenas: slab allocators over fixed simulated GPU
//! memory regions (paper §3, Fig. 3 — the FPGA's P2P staging buffers live
//! in GPU memory and are recycled under trainer credits).
//!
//! Each [`DeviceArena`] carves its region into fixed-size
//! [`StagingSlot`]s. A slot is `acquire`d by the producer (blocking while
//! every slot is in flight — the credit-gated backpressure of the staging
//! protocol), packed **in place** by the fused engine, staged to the
//! trainer, and `release`d when the trainer finishes stepping on it. Each
//! release bumps the slot's epoch — the epoch-based reclamation that
//! invalidates stale handles and lets the simulation check that no view
//! outlives its credit.
//!
//! [`ArenaSet`] scales the same protocol to a fleet: one arena **per
//! simulated GPU**, every region registered as a disjoint
//! [`MemClass::Gpu`] range in one **shared** [`Mmu`] address space — the
//! unified virtual address space the FPGA dataflow engine routes buffer
//! descriptors through. Credits, epochs and stats stay strictly
//! per-device, so one stalled GPU backpressures only its own producer
//! lane (the scheduler's routing layer decides which lane each shard
//! takes; see `coordinator::scheduler`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::coordinator::packer::{PackedBatch, PackedBatchView};
use crate::error::{EtlError, Result};
use crate::memsys::{MemClass, Mmu};
use crate::util::sched::{self, site};

/// Next unique arena identity (catches cross-arena slot release).
static NEXT_ARENA_ID: AtomicU64 = AtomicU64::new(1);

/// Sizing of the staging arena.
#[derive(Debug, Clone)]
pub struct ArenaConfig {
    /// Number of staging slots (credits). 4 = double buffering on both the
    /// producer and consumer side of the staging queue.
    pub slots: usize,
    /// Bytes reserved per slot in the simulated GPU region; packing a
    /// batch larger than this is an arena-exhaustion error.
    pub slot_bytes: u64,
}

impl Default for ArenaConfig {
    fn default() -> Self {
        ArenaConfig { slots: 4, slot_bytes: 64 << 20 }
    }
}

/// Counters of the arena's zero-copy contract (see module docs).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ArenaStats {
    /// Device index the counters belong to (0 for a standalone arena).
    pub device: usize,
    /// Slots handed out.
    pub acquires: u64,
    /// Credits returned.
    pub releases: u64,
    /// Acquires that had to block on a credit (producer stalls).
    pub stalls: u64,
    /// Seconds spent blocked in `acquire`.
    pub acquire_wait_s: f64,
    /// Packed bytes that flowed through released slots (each written
    /// exactly once by the fused packer).
    pub packed_bytes: u64,
    /// Slot-buffer allocations on a slot's *first* pack (expected: the
    /// slots size themselves to the workload once).
    pub warmup_allocs: u64,
    /// Slot-buffer allocations on any later pack — must stay 0 in the
    /// steady state (the zero-copy acceptance counter).
    pub steady_allocs: u64,
}

/// One staging slot: a fixed region of simulated GPU memory holding a
/// training-ready [`PackedBatch`] packed in place by the fused engine.
///
/// Slots are linear handles: they cannot be cloned, so Rust ownership
/// already rules out use-after-release; the epoch stamp additionally lets
/// the arena detect a handle from a previous incarnation of the slot.
#[derive(Debug)]
pub struct StagingSlot {
    index: usize,
    epoch: u64,
    vaddr: u64,
    capacity_bytes: u64,
    arena_id: u64,
    /// Simulated GPU this slot's region belongs to.
    device: usize,
    /// Packs performed on this slot over its lifetime.
    packs: u64,
    /// Did the last pack grow the slot's buffers?
    grew: bool,
    /// Payload bytes of the last pack.
    packed_bytes: u64,
    batch: PackedBatch,
}

impl StagingSlot {
    /// Slot index within its arena.
    pub fn index(&self) -> usize {
        self.index
    }

    /// Simulated GPU this slot stages into.
    pub fn device(&self) -> usize {
        self.device
    }

    /// Reclamation epoch this handle belongs to.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Device virtual address of the slot's first byte.
    pub fn vaddr(&self) -> u64 {
        self.vaddr
    }

    /// Bytes reserved for this slot in the simulated GPU region.
    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Payload bytes of the batch currently packed into the slot.
    pub fn packed_bytes(&self) -> u64 {
        self.packed_bytes
    }

    /// The staged batch, in place.
    pub fn batch(&self) -> &PackedBatch {
        &self.batch
    }

    /// Mutable access for pack paths that track their own accounting.
    /// Prefer [`pack_into`](Self::pack_into), which maintains the arena's
    /// allocation/copy counters.
    pub fn batch_mut(&mut self) -> &mut PackedBatch {
        &mut self.batch
    }

    /// Pack into the slot through `f` (typically the fused engine's
    /// `execute_into`), enforcing the slot's byte reservation and
    /// recording whether the pack had to grow the slot's buffers —
    /// the counters behind [`ArenaStats::steady_allocs`].
    pub fn pack_into<F>(&mut self, required_bytes: u64, f: F) -> Result<()>
    where
        F: FnOnce(&mut PackedBatch) -> Result<()>,
    {
        if required_bytes > self.capacity_bytes {
            return Err(EtlError::Mem(format!(
                "staging slot {} overflow: batch needs {required_bytes} B but the slot \
                 reserves {} B (grow ArenaConfig::slot_bytes or shrink the shard)",
                self.index, self.capacity_bytes
            )));
        }
        self.grew = false;
        let before = (
            self.batch.dense.capacity(),
            self.batch.sparse.capacity(),
            self.batch.labels.capacity(),
        );
        f(&mut self.batch)?;
        let after = (
            self.batch.dense.capacity(),
            self.batch.sparse.capacity(),
            self.batch.labels.capacity(),
        );
        self.grew = after != before;
        self.packs += 1;
        self.packed_bytes = self.batch.bytes();
        // `required_bytes` may be a caller estimate (the no-engine
        // fallback passes the slot capacity); re-check the actual payload
        // so an oversized pack can never silently overlap the next slot.
        if self.packed_bytes > self.capacity_bytes {
            return Err(EtlError::Mem(format!(
                "staging slot {} overflow: packed {} B into a {} B reservation \
                 (grow ArenaConfig::slot_bytes or shrink the shard)",
                self.index, self.packed_bytes, self.capacity_bytes
            )));
        }
        Ok(())
    }

    /// Borrowed device-addressed view of the whole staged batch.
    pub fn view(&self) -> DeviceBatchView<'_> {
        DeviceBatchView {
            data: self.batch.view(),
            vaddr: self.vaddr,
            slot: self.index,
            epoch: self.epoch,
            device: self.device,
        }
    }

    /// Per-training-step views of `step_rows` rows each (the incomplete
    /// tail is dropped, matching DLRM's fixed batch shapes). The trainer
    /// steps on these in place — no copy leaves the slot.
    pub fn chunk_views(&self, step_rows: usize) -> Vec<DeviceBatchView<'_>> {
        self.batch
            .chunk_views(step_rows)
            .into_iter()
            .map(|data| DeviceBatchView {
                data,
                vaddr: self.vaddr,
                slot: self.index,
                epoch: self.epoch,
                device: self.device,
            })
            .collect()
    }
}

/// A borrowed view of a staged batch living in device memory: the payload
/// slices plus the device address it is resident at. What the trainer
/// consumes in place (see [`crate::runtime::Trainer::step_device`]).
#[derive(Debug, Clone, Copy)]
pub struct DeviceBatchView<'a> {
    /// The packed payload, borrowed straight from the slot.
    pub data: PackedBatchView<'a>,
    /// Device virtual address of the backing slot.
    pub vaddr: u64,
    /// Backing slot index.
    pub slot: usize,
    /// Slot epoch this view belongs to.
    pub epoch: u64,
    /// Simulated GPU the staged batch is resident on.
    pub device: usize,
}

impl DeviceBatchView<'_> {
    /// Payload bytes of this view.
    pub fn bytes(&self) -> u64 {
        self.data.bytes()
    }
}

struct ArenaInner {
    /// Slots currently owned by the arena (credits available).
    free: Vec<StagingSlot>,
    /// Current epoch per slot index; a released slot must match.
    epochs: Vec<u64>,
    /// No further acquires (consumer exited); wakes blocked producers.
    closed: bool,
    stats: ArenaStats,
}

/// The staging arena of one simulated GPU. See module docs for the
/// protocol; thread-safe — the producer and consumer sides share it by
/// reference across threads. Standalone arenas own their MMU address
/// space; arenas inside an [`ArenaSet`] share one (one disjoint
/// `MemClass::Gpu` range per device).
pub struct DeviceArena {
    inner: Mutex<ArenaInner>,
    avail: Condvar,
    cfg: ArenaConfig,
    base_vaddr: u64,
    id: u64,
    device: usize,
    /// The unified address space the region is registered in (shared
    /// across every arena of an [`ArenaSet`]).
    mmu: Arc<Mutex<Mmu>>,
}

impl DeviceArena {
    /// Build an arena of `cfg.slots` slots, registering the whole region
    /// as GPU pages in a fresh MMU address space (device index 0).
    pub fn new(cfg: ArenaConfig) -> DeviceArena {
        DeviceArena::with_mmu(cfg, 0, Arc::new(Mutex::new(Mmu::default())))
    }

    /// Build the arena of simulated GPU `device`, mapping its region as
    /// the next free `MemClass::Gpu` range of the shared address space —
    /// the [`ArenaSet`] constructor path.
    fn with_mmu(cfg: ArenaConfig, device: usize, mmu: Arc<Mutex<Mmu>>) -> DeviceArena {
        assert!(cfg.slots >= 1, "arena needs at least one slot");
        assert!(cfg.slot_bytes >= 1, "slot_bytes must be positive");
        let id = NEXT_ARENA_ID.fetch_add(1, Ordering::Relaxed);
        let base_vaddr = mmu
            .lock()
            .expect("mmu poisoned")
            .map(MemClass::Gpu, cfg.slots as u64 * cfg.slot_bytes, 0);
        // Reverse index order: `acquire` pops from the back, so the first
        // credits hand out slot 0, 1, … in address order.
        let free = (0..cfg.slots)
            .rev()
            .map(|i| StagingSlot {
                index: i,
                epoch: 0,
                vaddr: base_vaddr + i as u64 * cfg.slot_bytes,
                capacity_bytes: cfg.slot_bytes,
                arena_id: id,
                device,
                packs: 0,
                grew: false,
                packed_bytes: 0,
                batch: PackedBatch::default(),
            })
            .collect();
        DeviceArena {
            inner: Mutex::new(ArenaInner {
                free,
                epochs: vec![0; cfg.slots],
                closed: false,
                stats: ArenaStats { device, ..ArenaStats::default() },
            }),
            avail: Condvar::new(),
            cfg,
            base_vaddr,
            id,
            device,
            mmu,
        }
    }

    /// Convenience: `slots` slots at the default per-slot reservation.
    pub fn with_slots(slots: usize) -> DeviceArena {
        DeviceArena::new(ArenaConfig { slots, ..ArenaConfig::default() })
    }

    /// The arena's sizing.
    pub fn config(&self) -> &ArenaConfig {
        &self.cfg
    }

    /// Base virtual address of the region in the MMU address space.
    pub fn base_vaddr(&self) -> u64 {
        self.base_vaddr
    }

    /// Simulated GPU this arena stages into.
    pub fn device(&self) -> usize {
        self.device
    }

    /// Blocking acquire: waits for a credit (free slot). Returns `None`
    /// once the arena is [`close`](Self::close)d — the consumer exited, so
    /// producers must stop rather than wait for credits that will never
    /// return.
    pub fn acquire(&self) -> Option<StagingSlot> {
        sched::point(site::ARENA_ACQUIRE);
        let mut inner = self.inner.lock().expect("arena poisoned");
        let mut waited: Option<std::time::Instant> = None;
        loop {
            if inner.closed {
                return None;
            }
            if let Some(slot) = inner.free.pop() {
                inner.stats.acquires += 1;
                if let Some(t0) = waited {
                    inner.stats.acquire_wait_s += t0.elapsed().as_secs_f64();
                }
                return Some(slot);
            }
            if waited.is_none() {
                waited = Some(std::time::Instant::now());
                inner.stats.stalls += 1;
            }
            inner = self.avail.wait(inner).expect("arena poisoned");
        }
    }

    /// Non-blocking acquire: `None` when every slot is in flight (or the
    /// arena is closed) — the backpressure signal.
    pub fn try_acquire(&self) -> Option<StagingSlot> {
        let mut inner = self.inner.lock().expect("arena poisoned");
        if inner.closed {
            return None;
        }
        let slot = inner.free.pop();
        if slot.is_some() {
            inner.stats.acquires += 1;
        }
        slot
    }

    /// Return a slot's credit: validates the handle, bumps the slot epoch
    /// (reclamation), folds the slot's pack accounting into the arena
    /// stats, and wakes one blocked producer.
    pub fn release(&self, mut slot: StagingSlot) -> Result<()> {
        sched::point(site::ARENA_RELEASE);
        let mut inner = self.inner.lock().expect("arena poisoned");
        if slot.arena_id != self.id {
            return Err(EtlError::Mem(format!(
                "slot released to a foreign arena (slot arena {}, this arena {})",
                slot.arena_id, self.id
            )));
        }
        if slot.epoch != inner.epochs[slot.index] {
            return Err(EtlError::Mem(format!(
                "stale slot {}: handle epoch {} but the arena is at epoch {}",
                slot.index, slot.epoch, inner.epochs[slot.index]
            )));
        }
        inner.epochs[slot.index] += 1;
        inner.stats.releases += 1;
        inner.stats.packed_bytes += slot.packed_bytes;
        if slot.grew {
            if slot.packs > 1 {
                inner.stats.steady_allocs += 1;
            } else {
                inner.stats.warmup_allocs += 1;
            }
        }
        slot.epoch = inner.epochs[slot.index];
        slot.grew = false;
        slot.packed_bytes = 0;
        inner.free.push(slot);
        drop(inner);
        self.avail.notify_one();
        Ok(())
    }

    /// Close the arena: blocked and future `acquire`s return `None`.
    /// Credits may still be released afterwards.
    pub fn close(&self) {
        let mut inner = self.inner.lock().expect("arena poisoned");
        inner.closed = true;
        drop(inner);
        self.avail.notify_all();
    }

    /// Credits currently available.
    pub fn available(&self) -> usize {
        self.inner.lock().expect("arena poisoned").free.len()
    }

    /// Slots currently in flight (acquired, not yet released).
    pub fn outstanding(&self) -> usize {
        let inner = self.inner.lock().expect("arena poisoned");
        self.cfg.slots - inner.free.len()
    }

    /// Snapshot of the zero-copy counters.
    pub fn stats(&self) -> ArenaStats {
        self.inner.lock().expect("arena poisoned").stats
    }

    /// Translate a device virtual address through the (possibly shared)
    /// MMU (tests / buffer-descriptor plumbing).
    pub fn translate(&self, vaddr: u64) -> Result<(MemClass, u64)> {
        let mut mmu = self.mmu.lock().expect("mmu poisoned");
        let (class, paddr, _cycles) = mmu.translate(vaddr)?;
        Ok((class, paddr))
    }

    /// Carve an additional fixed [`MemClass::Gpu`] region out of this
    /// device's memory for a resident cache (the embedding hot tier). The
    /// region is mapped once, lives for the process, and is *not* part of
    /// the staging credit protocol — it models state pinned in device
    /// memory alongside the staging slots.
    ///
    /// The reservation is bounded by the arena's own footprint
    /// (`slots * slot_bytes`): the hot tier must not be allowed to grow
    /// past the device memory the simulation budgets per GPU — that is the
    /// memory wall the cold tier exists to absorb.
    pub fn reserve_cache(&self, bytes: u64) -> Result<CacheRegion> {
        if bytes == 0 {
            return Err(EtlError::Mem("cache reservation must be positive".into()));
        }
        let budget = self.cfg.slots as u64 * self.cfg.slot_bytes;
        if bytes > budget {
            return Err(EtlError::Mem(format!(
                "cache reservation of {bytes} B exceeds device {}'s memory budget \
                 ({budget} B): shrink cache_rows or oversubscribe into the cold tier",
                self.device
            )));
        }
        let vaddr = self.mmu.lock().expect("mmu poisoned").map(MemClass::Gpu, bytes, 0);
        Ok(CacheRegion { vaddr, bytes, device: self.device })
    }
}

/// A pinned device-memory region backing a resident cache (see
/// [`DeviceArena::reserve_cache`]). Plain data: the simulation addresses
/// cached rows relative to `vaddr` and sizes eviction off `bytes`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheRegion {
    /// Device virtual address of the region's first byte.
    pub vaddr: u64,
    /// Bytes pinned for the cache.
    pub bytes: u64,
    /// Simulated GPU the region is resident on.
    pub device: usize,
}

/// One staging arena **per simulated GPU**, all regions registered as
/// disjoint [`MemClass::Gpu`] ranges in one shared [`Mmu`] address space —
/// the multi-device topology the scheduler's routing layer feeds
/// (ROADMAP: "multi-device arenas, one region per GPU, scheduler-routed").
///
/// ```text
///          shared Mmu virtual address space
///   ┌────────────┬────────────┬────────────┬───────┐
///   │ GPU0 slots │ GPU1 slots │ GPU2 slots │  ...  │   (MemClass::Gpu)
///   └────────────┴────────────┴────────────┴───────┘
///      arena 0       arena 1      arena 2
///    credits/epochs/stats per device — a stalled GPU
///    backpressures only its own producer lane
/// ```
pub struct ArenaSet {
    arenas: Vec<DeviceArena>,
    mmu: Arc<Mutex<Mmu>>,
}

impl ArenaSet {
    /// Build `devices` arenas of identical sizing over one shared address
    /// space.
    pub fn new(devices: usize, cfg: ArenaConfig) -> ArenaSet {
        assert!(devices >= 1, "arena set needs at least one device");
        let mmu = Arc::new(Mutex::new(Mmu::default()));
        let arenas = (0..devices)
            .map(|d| DeviceArena::with_mmu(cfg.clone(), d, Arc::clone(&mmu)))
            .collect();
        ArenaSet { arenas, mmu }
    }

    /// Number of simulated GPUs.
    pub fn devices(&self) -> usize {
        self.arenas.len()
    }

    /// Grow the set by one arena region (a joining lane's staging space),
    /// mapped after the existing regions in the same shared address
    /// space. Returns the new device index.
    pub fn grow(&mut self, cfg: ArenaConfig) -> usize {
        let device = self.arenas.len();
        self.arenas.push(DeviceArena::with_mmu(cfg, device, Arc::clone(&self.mmu)));
        device
    }

    /// The arena of simulated GPU `device`.
    pub fn device(&self, device: usize) -> &DeviceArena {
        &self.arenas[device]
    }

    /// Iterate the per-device arenas in device order.
    pub fn iter(&self) -> impl Iterator<Item = &DeviceArena> {
        self.arenas.iter()
    }

    /// Close every arena (wakes all blocked producers, fleet shutdown).
    pub fn close_all(&self) {
        for a in &self.arenas {
            a.close();
        }
    }

    /// Per-device counter snapshots, in device order.
    pub fn per_device_stats(&self) -> Vec<ArenaStats> {
        self.arenas.iter().map(|a| a.stats()).collect()
    }

    /// Fleet-aggregate counters (the exactly-once accounting across every
    /// device; `device` is meaningless on the sum and reported as 0).
    pub fn total_stats(&self) -> ArenaStats {
        let mut total = ArenaStats::default();
        for s in self.per_device_stats() {
            total.acquires += s.acquires;
            total.releases += s.releases;
            total.stalls += s.stalls;
            total.acquire_wait_s += s.acquire_wait_s;
            total.packed_bytes += s.packed_bytes;
            total.warmup_allocs += s.warmup_allocs;
            total.steady_allocs += s.steady_allocs;
        }
        total
    }

    /// Translate a device virtual address through the shared MMU: any
    /// device's slot addresses resolve in the one unified address space.
    pub fn translate(&self, vaddr: u64) -> Result<(MemClass, u64)> {
        let mut mmu = self.mmu.lock().expect("mmu poisoned");
        let (class, paddr, _cycles) = mmu.translate(vaddr)?;
        Ok((class, paddr))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_arena(slots: usize, slot_bytes: u64) -> DeviceArena {
        DeviceArena::new(ArenaConfig { slots, slot_bytes })
    }

    fn pack_rows(slot: &mut StagingSlot, rows: usize) -> Result<()> {
        let need = (rows * 3 * 4) as u64; // 1 dense + 1 sparse + label
        slot.pack_into(need, |out| {
            out.rows = rows;
            out.n_dense = 1;
            out.n_sparse = 1;
            out.dense.clear();
            out.dense.resize(rows, 1.0);
            out.sparse.clear();
            out.sparse.resize(rows, 2);
            out.labels.clear();
            out.labels.resize(rows, 0.0);
            Ok(())
        })
    }

    #[test]
    fn arena_region_is_gpu_mapped() {
        let a = small_arena(3, 1 << 20);
        let s = a.try_acquire().unwrap();
        assert_eq!(s.vaddr(), a.base_vaddr());
        let (class, _) = a.translate(s.vaddr()).unwrap();
        assert_eq!(class, MemClass::Gpu);
        // Last byte of the last slot still translates.
        let last = a.base_vaddr() + 3 * (1 << 20) - 1;
        assert_eq!(a.translate(last).unwrap().0, MemClass::Gpu);
        a.release(s).unwrap();
    }

    #[test]
    fn exhaustion_backpressures_and_release_unblocks() {
        let a = small_arena(2, 1 << 16);
        let s1 = a.try_acquire().unwrap();
        let s2 = a.try_acquire().unwrap();
        assert!(a.try_acquire().is_none(), "third credit must bounce");
        assert_eq!(a.outstanding(), 2);

        // A blocked acquire resumes once another thread releases.
        std::thread::scope(|scope| {
            let waiter = scope.spawn(|| a.acquire());
            // The stall counter ticks exactly when the waiter blocks.
            while a.stats().stalls == 0 {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            a.release(s1).unwrap();
            let got = waiter.join().unwrap();
            assert!(got.is_some());
            a.release(got.unwrap()).unwrap();
        });
        a.release(s2).unwrap();
        let st = a.stats();
        assert_eq!(st.acquires, 3);
        assert_eq!(st.releases, 3);
        assert!(st.stalls >= 1);
        assert!(st.acquire_wait_s > 0.0);
    }

    #[test]
    fn close_wakes_blocked_acquire() {
        let a = small_arena(1, 1 << 16);
        let s = a.try_acquire().unwrap();
        std::thread::scope(|scope| {
            let waiter = scope.spawn(|| a.acquire());
            std::thread::sleep(std::time::Duration::from_millis(10));
            a.close();
            assert!(waiter.join().unwrap().is_none());
        });
        // Releasing after close is still legal (consumer drains last).
        a.release(s).unwrap();
        assert!(a.try_acquire().is_none(), "closed arena hands out nothing");
    }

    #[test]
    fn epoch_reclamation_rejects_stale_handles() {
        let a = small_arena(1, 1 << 16);
        let s = a.try_acquire().unwrap();
        assert_eq!(s.epoch(), 0);
        a.release(s).unwrap();
        let mut s = a.try_acquire().unwrap();
        assert_eq!(s.epoch(), 1);
        // Forge a stale handle (same-module test access).
        s.epoch = 0;
        let err = a.release(s).unwrap_err();
        assert!(err.to_string().contains("stale slot"), "{err}");
    }

    #[test]
    fn foreign_slot_is_rejected() {
        let a = small_arena(1, 1 << 16);
        let b = small_arena(1, 1 << 16);
        let s = a.try_acquire().unwrap();
        let err = b.release(s).unwrap_err();
        assert!(err.to_string().contains("foreign arena"), "{err}");
    }

    #[test]
    fn pack_into_tracks_warmup_then_steady_state() {
        let a = small_arena(1, 1 << 16);
        for _round in 0..4 {
            let mut s = a.acquire().unwrap();
            pack_rows(&mut s, 100).unwrap();
            assert_eq!(s.packed_bytes(), 100 * 3 * 4);
            a.release(s).unwrap();
        }
        let st = a.stats();
        // First pack allocates (warmup); reuse packs must not.
        assert_eq!(st.warmup_allocs, 1, "{st:?}");
        assert_eq!(st.steady_allocs, 0, "{st:?}");
        assert_eq!(st.packed_bytes, 4 * 100 * 3 * 4);
    }

    #[test]
    fn slot_overflow_is_an_arena_exhaustion_error() {
        let a = small_arena(1, 64); // 64-byte slot
        let mut s = a.acquire().unwrap();
        let err = pack_rows(&mut s, 1000).unwrap_err();
        assert!(err.to_string().contains("overflow"), "{err}");
        a.release(s).unwrap();

        // The post-pack check fires even when the caller's estimate was
        // too low (the no-engine fallback passes the slot capacity).
        let mut s = a.acquire().unwrap();
        let err = s
            .pack_into(0, |out| {
                out.rows = 100;
                out.n_dense = 0;
                out.n_sparse = 0;
                out.dense.clear();
                out.sparse.clear();
                out.labels.clear();
                out.labels.resize(100, 0.0);
                Ok(())
            })
            .unwrap_err();
        assert!(err.to_string().contains("overflow"), "{err}");
        a.release(s).unwrap();
    }

    #[test]
    fn arena_set_maps_disjoint_regions_in_one_address_space() {
        let set = ArenaSet::new(3, ArenaConfig { slots: 2, slot_bytes: 1 << 20 });
        assert_eq!(set.devices(), 3);
        // Regions are disjoint and every device's addresses translate as
        // GPU pages through the one shared MMU.
        let mut bases: Vec<u64> = set.iter().map(|a| a.base_vaddr()).collect();
        bases.sort_unstable();
        bases.dedup();
        assert_eq!(bases.len(), 3, "per-device regions must be disjoint");
        for d in 0..3 {
            let a = set.device(d);
            assert_eq!(a.device(), d);
            let s = a.try_acquire().unwrap();
            assert_eq!(s.device(), d);
            assert_eq!(set.translate(s.vaddr()).unwrap().0, MemClass::Gpu);
            assert_eq!(a.translate(s.vaddr()).unwrap().0, MemClass::Gpu);
            // Views are stamped with the device they are resident on.
            assert_eq!(s.view().device, d);
            a.release(s).unwrap();
        }
        // A slot released to a sibling device of the same set is foreign.
        let s0 = set.device(0).try_acquire().unwrap();
        let err = set.device(1).release(s0).unwrap_err();
        assert!(err.to_string().contains("foreign arena"), "{err}");
    }

    #[test]
    fn arena_set_credits_and_stats_stay_per_device() {
        let set = ArenaSet::new(2, ArenaConfig { slots: 1, slot_bytes: 1 << 16 });
        // Exhaust device 0 — device 1 is unaffected.
        let held = set.device(0).try_acquire().unwrap();
        assert!(set.device(0).try_acquire().is_none());
        let mut other = set.device(1).try_acquire().unwrap();
        pack_rows(&mut other, 16).unwrap();
        set.device(1).release(other).unwrap();
        set.device(0).release(held).unwrap();

        let per = set.per_device_stats();
        assert_eq!(per[0].device, 0);
        assert_eq!(per[1].device, 1);
        assert_eq!(per[0].packed_bytes, 0);
        assert_eq!(per[1].packed_bytes, 16 * 3 * 4);
        // A bounced try_acquire is not an acquire: one credit each.
        assert_eq!(per[0].acquires, 1);
        assert_eq!(per[1].acquires, 1);
        let total = set.total_stats();
        assert_eq!(total.acquires, 2);
        assert_eq!(total.packed_bytes, 16 * 3 * 4);
        // close_all wakes every device's producers.
        set.close_all();
        assert!(set.device(0).try_acquire().is_none());
        assert!(set.device(1).try_acquire().is_none());
    }

    #[test]
    fn arena_set_grow_maps_a_disjoint_region_in_the_shared_space() {
        let cfg = ArenaConfig { slots: 2, slot_bytes: 1 << 16 };
        let mut set = ArenaSet::new(2, cfg.clone());
        assert_eq!(set.grow(cfg.clone()), 2);
        assert_eq!(set.devices(), 3);
        let grown = set.device(2);
        assert_eq!(grown.device(), 2);
        // The new region lives after the launch-time regions and resolves
        // through the same shared MMU.
        assert!(grown.base_vaddr() > set.device(1).base_vaddr());
        let s = grown.try_acquire().unwrap();
        assert_eq!(set.translate(s.vaddr()).unwrap().0, MemClass::Gpu);
        assert_eq!(s.view().device, 2);
        grown.release(s).unwrap();
        // The siblings' credits are untouched by the grow.
        assert_eq!(set.device(0).stats().acquires, 0);
        assert_eq!(set.total_stats().acquires, 1);
    }

    #[test]
    fn reserve_cache_maps_gpu_region_within_budget() {
        let a = small_arena(2, 1 << 16);
        let region = a.reserve_cache(1 << 12).unwrap();
        assert_eq!(region.bytes, 1 << 12);
        assert_eq!(region.device, a.device());
        assert_eq!(a.translate(region.vaddr).unwrap().0, MemClass::Gpu);
        assert_eq!(a.translate(region.vaddr + region.bytes - 1).unwrap().0, MemClass::Gpu);
        // The cache region must not alias the staging slots.
        let slots_end = a.base_vaddr() + 2 * (1 << 16);
        assert!(region.vaddr >= slots_end || region.vaddr + region.bytes <= a.base_vaddr());

        // Zero-byte and over-budget reservations are rejected.
        assert!(a.reserve_cache(0).is_err());
        let err = a.reserve_cache((2 << 16) + 1).unwrap_err();
        assert!(err.to_string().contains("memory budget"), "{err}");
    }

    #[test]
    fn views_carry_device_addresses() {
        let a = small_arena(2, 1 << 16);
        let s0 = a.acquire().unwrap();
        let mut s1 = a.acquire().unwrap();
        pack_rows(&mut s1, 10).unwrap();
        assert_eq!(s1.vaddr(), a.base_vaddr() + (1 << 16));
        let v = s1.view();
        assert_eq!(v.vaddr, s1.vaddr());
        assert_eq!(v.data.rows, 10);
        assert_eq!(v.bytes(), s1.packed_bytes());
        let chunks = s1.chunk_views(4);
        assert_eq!(chunks.len(), 2); // 10 rows → two full 4-row steps
        assert!(chunks.iter().all(|c| c.slot == 1 && c.vaddr == s1.vaddr()));
        // Views borrow the slot payload in place (no copy).
        assert!(std::ptr::eq(v.data.dense.as_ptr(), s1.batch().dense.as_ptr()));
        a.release(s0).unwrap();
        a.release(s1).unwrap();
    }
}
