//! Adversarial scenario matrix for the online auto-tuner
//! ([`crate::coordinator::autotune`]): three deliberately hostile
//! pipeline shapes, each packaged with a **deliberately bad** starting
//! config and the **best hand-tuned** config for the same shape. The
//! success bar pinned by ROADMAP item 3 is [`SUCCESS_BAR`]: starting
//! from the bad config, the auto-tuned run's steady-state modeled
//! throughput must reach at least 0.9× the hand-tuned run's on every
//! scenario.
//!
//! | scenario | adversity | bad start | hand tuning | expected climb |
//! |---|---|---|---|---|
//! | [`Scenario::skewed_shards`] | pseudorandom shard sizes up to 6× ([`crate::dataio::synth::SynthConfig::shard_skew`]) | `RoundRobin` routing | `LeastLoaded` routing | `Route(LeastLoaded)` flip |
//! | [`Scenario::straggler_lane`] | one lane's shards straggle 8× (`SLOW_SHARD` fault plan, even shard indices only — round-robin pins them to lane 0) | 1 ingest worker | 4 ingest workers | `IngestWorkers` ×2 ladder |
//! | [`Scenario::ssd_cliff`] | SSD-bound ingest (80 µs setup per read) | 1 worker + 16-row chunks (one setup *per step*) | 4 workers + whole-shard reads | `IngestWorkers` ladder, then `ChunkRows → 0` |
//!
//! All three arms of a scenario — bad, hand-tuned, auto-tuned — are
//! scored by the **same deterministic pipeline model**: the bad and
//! hand arms run with the controller in observe-only mode
//! (`max_changes = 0`), the auto arm runs it live from the bad config,
//! and every arm reads
//! [`AutotuneReport::steady_steps_per_s`](crate::coordinator::AutotuneReport::steady_steps_per_s)
//! (the steps-weighted tail windows, so the auto arm's early bad
//! windows — the climb it was asked to make — don't drown its converged
//! state). Scenario runs assert the throughput *bar*, not bitwise
//! replay: a kept `Route(LeastLoaded)` flip intentionally hands routing
//! to the live byte ledger (see the autotune module docs); the bitwise
//! properties are pinned separately by `rust/tests/prop_autotune.rs`.

use crate::coordinator::{
    train, AutotuneConfig, DataPath, RoutePolicy, TrainConfig,
};
use crate::dataio::dataset::{DatasetKind, DatasetSpec};
use crate::dataio::ingest::{DeliveryPolicy, IngestConfig};
use crate::dataio::synth::SynthConfig;
use crate::devmem::ArenaConfig;
use crate::error::Result;
use crate::etl::column::ColType;
use crate::etl::dag::{Dag, SinkRole};
use crate::etl::ops::OpSpec;
use crate::etl::schema::Schema;
use crate::fpga::Pipeline;
use crate::planner::{compile, PlannerConfig};
use crate::runtime::artifacts::{ModelMeta, ParamSpec};
use crate::runtime::Trainer;
use crate::util::fault::{site as fsite, FaultPlan, PERMANENT, RATE_FULL};

/// The ROADMAP item-3 acceptance ratio: auto-tuned steady-state
/// throughput over hand-tuned, per scenario, from the bad start.
pub const SUCCESS_BAR: f64 = 0.9;

const ND: usize = 2;
const NS: usize = 2;
const STEP_ROWS: usize = 16;
const ROWS: usize = 1024;
const SHARDS: usize = 16;

/// Which adversity the scenario models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioKind {
    /// Pseudorandom shard sizes under round-robin routing.
    SkewedShards,
    /// Straggling shard reads pinned to one lane's round-robin slice.
    StragglerLane,
    /// High-setup SSD ingest shredded into per-step chunks.
    SsdCliff,
}

/// Modeled scores of one arm (all from the controller's report, so the
/// three arms share one objective).
#[derive(Debug, Clone, Copy)]
pub struct ArmScore {
    /// Steady-state modeled throughput (the scenario metric).
    pub steady_steps_per_s: f64,
    /// Whole-run modeled throughput.
    pub modeled_steps_per_s: f64,
    /// Controller changes applied (0 for observe-only arms).
    pub applied: u64,
    /// Hysteresis reverts emitted.
    pub reverts: u64,
}

/// The three arms of one evaluated scenario.
#[derive(Debug, Clone, Copy)]
pub struct ScenarioOutcome {
    /// The deliberately bad config, observe-only.
    pub bad: ArmScore,
    /// The hand-tuned config, observe-only.
    pub hand: ArmScore,
    /// The bad config with the controller live.
    pub auto: ArmScore,
}

impl ScenarioOutcome {
    /// Auto-tuned over hand-tuned steady-state throughput.
    pub fn auto_vs_hand(&self) -> f64 {
        self.auto.steady_steps_per_s / self.hand.steady_steps_per_s.max(1e-12)
    }

    /// Did the auto-tuned arm reach the [`SUCCESS_BAR`]?
    pub fn meets_bar(&self) -> bool {
        self.auto_vs_hand() >= SUCCESS_BAR
    }
}

/// One adversarial scenario: dataset shape, the two reference configs,
/// the controller knobs, and an optional fault plan the evaluation
/// installs around all three arms.
pub struct Scenario {
    pub kind: ScenarioKind,
    pub name: &'static str,
    pub spec: DatasetSpec,
    /// The deliberately bad starting config (the auto arm starts here).
    pub bad: TrainConfig,
    /// The best hand-tuned config for this shape.
    pub hand: TrainConfig,
    /// Controller knobs for the auto arm (observe-only arms reuse them
    /// with `max_changes = 0`).
    pub tuner: AutotuneConfig,
    /// Deterministic fault plan active for every arm (straggler only).
    pub fault: Option<FaultPlan>,
    pipeline: Pipeline,
    meta: ModelMeta,
}

impl Scenario {
    /// Skewed shard sizes (up to 6×) under round-robin routing: the
    /// per-lane modeled work trips the imbalance gate and the controller
    /// flips `Route(LeastLoaded)` — the hand-tuned config from the start.
    pub fn skewed_shards() -> Scenario {
        let mut spec = scenario_spec("skewed-shards");
        spec.synth.shard_skew = 6.0;
        let bad = base_cfg();
        let mut hand = base_cfg();
        hand.route = RoutePolicy::LeastLoaded;
        Scenario::assemble(
            ScenarioKind::SkewedShards,
            "skewed-shards",
            spec,
            bad,
            hand,
            AutotuneConfig {
                window: 8,
                cooldown: 0,
                min_gain: 0.01,
                imbalance_threshold: 1.3,
                ..AutotuneConfig::default()
            },
            None,
        )
    }

    /// One straggler lane: a `SLOW_SHARD` plan whose afflicted shards all
    /// sit at even indices, which round-robin over two lanes pins to lane
    /// 0 — those reads are modeled 8× slower (the controller's straggler
    /// factor), so the single bad ingest worker serializes behind them.
    /// The ladder climbs `IngestWorkers` to the hand-tuned 4.
    pub fn straggler_lane() -> Scenario {
        let spec = scenario_spec("straggler-lane");
        let mut bad = base_cfg();
        bad.ingest.workers = 1;
        let mut hand = base_cfg();
        hand.ingest.workers = 4;
        Scenario::assemble(
            ScenarioKind::StragglerLane,
            "straggler-lane",
            spec,
            bad,
            hand,
            ingest_tuner(),
            Some(straggler_plan()),
        )
    }

    /// The Dataset-III SSD-bandwidth cliff: every read pays the SSD
    /// channel's 80 µs setup, and the bad config shreds shards into
    /// 16-row chunks — one setup *per trainer step* — on a single worker.
    /// The ladder climbs workers, then coarsens `ChunkRows` to
    /// whole-shard reads.
    pub fn ssd_cliff() -> Scenario {
        let mut spec = scenario_spec("ssd-cliff");
        spec.ssd_bound = true;
        let mut bad = base_cfg();
        bad.ingest.workers = 1;
        bad.ingest.chunk_rows = STEP_ROWS;
        let mut hand = base_cfg();
        hand.ingest.workers = 4;
        hand.ingest.chunk_rows = 0;
        Scenario::assemble(
            ScenarioKind::SsdCliff,
            "ssd-cliff",
            spec,
            bad,
            hand,
            ingest_tuner(),
            None,
        )
    }

    /// The full matrix, in a stable order.
    pub fn all() -> Vec<Scenario> {
        vec![
            Scenario::skewed_shards(),
            Scenario::straggler_lane(),
            Scenario::ssd_cliff(),
        ]
    }

    fn assemble(
        kind: ScenarioKind,
        name: &'static str,
        spec: DatasetSpec,
        bad: TrainConfig,
        hand: TrainConfig,
        tuner: AutotuneConfig,
        fault: Option<FaultPlan>,
    ) -> Scenario {
        let schema = spec.schema.clone();
        let dag = passthrough_dag(ND, NS);
        dag.validate(&schema).expect("scenario dag matches its schema");
        let plan = compile(&dag, &schema, &PlannerConfig::default())
            .expect("scenario dag compiles");
        Scenario {
            kind,
            name,
            spec,
            bad,
            hand,
            tuner,
            fault,
            pipeline: Pipeline::new(plan),
            meta: trainer_meta(STEP_ROWS, ND, NS),
        }
    }

    /// Run the three arms — bad (observe-only), hand-tuned
    /// (observe-only), auto-tuned (live, from the bad config) — under
    /// the scenario's fault plan and score them on the shared modeled
    /// objective.
    pub fn evaluate(&self) -> Result<ScenarioOutcome> {
        let _fault_guard = self.fault.clone().map(|p| p.install());
        let bad = self.run_arm(&self.bad, 0)?;
        let hand = self.run_arm(&self.hand, 0)?;
        let auto = self.run_arm(&self.bad, self.tuner.max_changes)?;
        Ok(ScenarioOutcome { bad, hand, auto })
    }

    fn run_arm(&self, cfg: &TrainConfig, max_changes: usize) -> Result<ArmScore> {
        let mut cfg = cfg.clone();
        cfg.autotune = Some(AutotuneConfig { max_changes, ..self.tuner });
        let mut trainer = Trainer::from_meta(self.meta.clone(), 7);
        let report = train(&self.pipeline, &self.spec, &mut trainer, &cfg)?;
        let at = report
            .autotune
            .expect("an armed arena-path run always carries a controller report");
        Ok(ArmScore {
            steady_steps_per_s: at.steady_steps_per_s,
            modeled_steps_per_s: at.modeled_steps_per_s,
            applied: at.applied,
            reverts: at.reverts,
        })
    }
}

/// Controller knobs shared by the two ingest-bound scenarios: the skew
/// gate is disabled (their single-slot windows make per-window lane work
/// lumpy by construction, which is load *granularity*, not routing
/// skew), and the worker ladder tops out at the hand-tuned 4.
fn ingest_tuner() -> AutotuneConfig {
    AutotuneConfig {
        window: 8,
        cooldown: 0,
        max_ingest_workers: 4,
        imbalance_threshold: f64::INFINITY,
        ..AutotuneConfig::default()
    }
}

/// The `SLOW_SHARD` plan of the straggler scenario: the first seed whose
/// afflicted shard set is non-trivial (2–5 of the 16 shards) and sits
/// entirely at even indices, which round-robin over two lanes maps to
/// lane 0 — one straggler lane. Pure scan over [`FaultPlan::afflicts`]
/// (no plan is installed), so the choice is deterministic.
fn straggler_plan() -> FaultPlan {
    let seed = (0u64..1 << 20)
        .find(|&s| {
            let p = FaultPlan::new(s).with(fsite::SLOW_SHARD, RATE_FULL / 4, PERMANENT);
            let hit: Vec<usize> = (0..SHARDS)
                .filter(|&i| p.afflicts(fsite::SLOW_SHARD, i as u64).is_some())
                .collect();
            (2..=5).contains(&hit.len()) && hit.iter().all(|i| i % 2 == 0)
        })
        .expect("a one-lane straggler seed exists well below 2^20");
    FaultPlan::new(seed).with(fsite::SLOW_SHARD, RATE_FULL / 4, PERMANENT)
}

/// 1024 rows over 16 shards (64 rows / 4 trainer steps per shard at the
/// uniform split): 64 global steps, 8 windows of 8 — room for a few
/// climb/judge cycles *and* a converged 3-window tail.
fn scenario_spec(name: &'static str) -> DatasetSpec {
    DatasetSpec {
        kind: DatasetKind::I,
        name,
        schema: Schema::tabular("t", ND, NS, 64),
        rows: ROWS,
        paper_rows: ROWS as u64,
        shards: SHARDS,
        synth: SynthConfig::default(),
        ssd_bound: false,
    }
}

/// Two-lane arena fleet, in-order ingest, sync-every-step — the fixture
/// family of `rust/tests/prop_elastic.rs`.
fn base_cfg() -> TrainConfig {
    TrainConfig {
        max_steps: usize::MAX / 2,
        loss_every: 1,
        staging_buffers: 2,
        seed: 99,
        ingest: IngestConfig {
            workers: 2,
            channel_depth: 2,
            policy: DeliveryPolicy::InOrder,
            ..IngestConfig::default()
        },
        path: DataPath::Arena,
        arena: ArenaConfig { slots: 3, slot_bytes: 16 << 20 },
        devices: 2,
        route: RoutePolicy::RoundRobin,
        allreduce_every: 1,
        ..TrainConfig::default()
    }
}

/// Stateless packing dag matching the reference-trainer meta.
fn passthrough_dag(nd: usize, ns: usize) -> Dag {
    let mut dag = Dag::new("scenario");
    let l = dag.source("t_label", ColType::F32);
    dag.sink("label", l, SinkRole::Label);
    for i in 0..nd {
        let d = dag.source(format!("t_i{i}"), ColType::F32);
        let f = dag.op(
            OpSpec::FillMissing { dense_default: 0.0, sparse_default: 0 },
            &[d],
        );
        dag.sink(format!("dense{i}"), f, SinkRole::Dense);
    }
    for i in 0..ns {
        let s = dag.source(format!("t_c{i}"), ColType::Hex8);
        let h = dag.op(OpSpec::Hex2Int, &[s]);
        let m = dag.op(OpSpec::Modulus { m: 1 << 16 }, &[h]);
        dag.sink(format!("sparse{i}"), m, SinkRole::SparseIndex);
    }
    dag
}

fn trainer_meta(batch: usize, nd: usize, ns: usize) -> ModelMeta {
    ModelMeta {
        batch,
        n_dense: nd,
        n_sparse: ns,
        vocab: 128,
        embed_dim: 1,
        params: vec![
            ParamSpec { name: "w_dense".into(), dims: vec![nd] },
            ParamSpec { name: "b".into(), dims: vec![1] },
            ParamSpec { name: "emb".into(), dims: vec![ns * 32] },
        ],
        extra: Default::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_has_three_valid_scenarios() {
        let all = Scenario::all();
        assert_eq!(all.len(), 3);
        for sc in &all {
            // Both reference configs must survive the same validation the
            // auto arm runs under (autotune armed, observe-only).
            let mut bad = sc.bad.clone();
            bad.autotune = Some(AutotuneConfig { max_changes: 0, ..sc.tuner });
            bad.validate().unwrap_or_else(|e| {
                panic!("{}: bad config invalid: {e}", sc.name);
            });
            let mut hand = sc.hand.clone();
            hand.autotune = Some(AutotuneConfig { max_changes: 0, ..sc.tuner });
            hand.validate().unwrap_or_else(|e| {
                panic!("{}: hand config invalid: {e}", sc.name);
            });
            assert!(sc.tuner.validate().is_ok(), "{}", sc.name);
        }
    }

    #[test]
    fn straggler_plan_pins_one_round_robin_lane() {
        let plan = straggler_plan();
        let hit: Vec<usize> = (0..SHARDS)
            .filter(|&i| plan.afflicts(fsite::SLOW_SHARD, i as u64).is_some())
            .collect();
        assert!((2..=5).contains(&hit.len()), "afflicted {hit:?}");
        assert!(hit.iter().all(|i| i % 2 == 0), "stragglers span lanes: {hit:?}");
    }

    #[test]
    fn skewed_scenario_shards_are_actually_skewed() {
        let sc = Scenario::skewed_shards();
        let sizes: Vec<usize> =
            (0..sc.spec.shards).map(|i| sc.spec.rows_in_shard(i)).collect();
        assert_eq!(sizes.iter().sum::<usize>(), sc.spec.rows);
        let (min, max) = (*sizes.iter().min().unwrap(), *sizes.iter().max().unwrap());
        assert!(
            max as f64 >= 2.0 * min.max(1) as f64,
            "skew 6.0 produced near-uniform sizes: {sizes:?}"
        );
    }
}
