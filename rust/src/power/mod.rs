//! Platform power and energy-efficiency models (paper §4.6, Table 3).
//!
//! Static powers from the paper: CPU 150 W, RTX 3090 33 W, A100 43 W,
//! PipeRec 17 W. Dynamic power is modeled as static + activity terms
//! calibrated to Table 3's measured averages; Perf/W is the reciprocal of
//! latency × power, normalized to the CPU baseline.

use crate::baselines::Platform;
use crate::dataio::dataset::{DatasetKind, DatasetSpec};
use crate::etl::pipelines::PipelineKind;

/// Idle/static power (W), per the paper.
pub fn static_power(p: Platform) -> f64 {
    match p {
        Platform::CpuPandas | Platform::CpuBeam => 150.0,
        Platform::Rtx3090 => 33.0,
        Platform::A100 => 43.0,
        Platform::PipeRec => 17.0,
    }
}

/// Average power under load (W) for a configuration. Calibrated to
/// Table 3: CPU 294–379 W, 3090 92–143 W, A100 75–82 W, PipeRec 24–26 W.
pub fn dynamic_power(p: Platform, dataset: DatasetKind, pipeline: PipelineKind) -> f64 {
    let wide = dataset == DatasetKind::II;
    let vocab_activity = match pipeline {
        PipelineKind::I => 0.0,
        PipelineKind::II => 1.0,
        PipelineKind::III => 2.0,
    };
    match p {
        // All cores saturated; wide schemas push more memory traffic.
        Platform::CpuPandas | Platform::CpuBeam => {
            294.0 + if wide { 75.0 } else { 0.0 } + vocab_activity * 7.0
        }
        // GPU power rises with vocabulary work (groupby kernels).
        Platform::Rtx3090 => 92.0 + if wide { 9.0 } else { 0.0 } + vocab_activity * 17.0,
        Platform::A100 => 76.0 + if wide { -1.0 } else { 0.0 } + vocab_activity * 2.5,
        // The FPGA's draw is nearly flat (paper: 24–26 W).
        Platform::PipeRec => 24.0 + vocab_activity * 1.0,
    }
}

/// Energy for one pipeline execution (J).
pub fn energy_joules(power_w: f64, latency_s: f64) -> f64 {
    power_w * latency_s
}

/// Perf/W of a platform relative to the CPU baseline (Table 3's
/// "Eff. (CPU=1)" rows): `(lat_cpu × pwr_cpu) / (lat × pwr)`.
pub fn perf_per_watt_vs_cpu(
    cpu_latency_s: f64,
    cpu_power_w: f64,
    latency_s: f64,
    power_w: f64,
) -> f64 {
    (cpu_latency_s * cpu_power_w) / (latency_s * power_w)
}

/// One row of Table 3.
#[derive(Debug, Clone)]
pub struct PowerRow {
    pub platform: Platform,
    pub power_w: f64,
    pub latency_s: f64,
    pub eff_vs_cpu: f64,
}

/// Build the Table 3 rows for a configuration given per-platform latencies.
pub fn table3_rows(
    spec: &DatasetSpec,
    pipeline: PipelineKind,
    latencies: &[(Platform, f64)],
) -> Vec<PowerRow> {
    let cpu_lat = latencies
        .iter()
        .find(|(p, _)| *p == Platform::CpuPandas)
        .map(|(_, l)| *l)
        .expect("CPU latency required as the baseline");
    let cpu_pwr = dynamic_power(Platform::CpuPandas, spec.kind, pipeline);
    latencies
        .iter()
        .map(|&(platform, latency_s)| {
            let power_w = dynamic_power(platform, spec.kind, pipeline);
            PowerRow {
                platform,
                power_w,
                latency_s,
                eff_vs_cpu: perf_per_watt_vs_cpu(cpu_lat, cpu_pwr, latency_s, power_w),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_powers_match_paper() {
        assert_eq!(static_power(Platform::CpuPandas), 150.0);
        assert_eq!(static_power(Platform::Rtx3090), 33.0);
        assert_eq!(static_power(Platform::A100), 43.0);
        assert_eq!(static_power(Platform::PipeRec), 17.0);
    }

    #[test]
    fn dynamic_power_in_table3_ranges() {
        for ds in [DatasetKind::I, DatasetKind::II] {
            for pl in PipelineKind::all() {
                let cpu = dynamic_power(Platform::CpuPandas, ds, pl);
                assert!((290.0..385.0).contains(&cpu), "cpu {cpu}");
                let g = dynamic_power(Platform::Rtx3090, ds, pl);
                assert!((90.0..145.0).contains(&g), "3090 {g}");
                let a = dynamic_power(Platform::A100, ds, pl);
                assert!((70.0..85.0).contains(&a), "a100 {a}");
                let f = dynamic_power(Platform::PipeRec, ds, pl);
                assert!((23.0..27.0).contains(&f), "piperec {f}");
            }
        }
    }

    #[test]
    fn table3_anchor_d1_p1() {
        // Paper D-I + P-I: CPU 294 W/78 s, PipeRec 24 W/1.1 s ⇒ 868.6×.
        let eff = perf_per_watt_vs_cpu(78.0, 294.0, 1.1, 24.0);
        assert!((eff / 868.6 - 1.0).abs() < 0.01, "eff={eff}");
    }

    #[test]
    fn table3_rows_normalize_to_cpu() {
        let spec = DatasetSpec::dataset_i(1.0);
        let rows = table3_rows(
            &spec,
            PipelineKind::I,
            &[
                (Platform::CpuPandas, 78.0),
                (Platform::A100, 2.8),
                (Platform::PipeRec, 1.1),
            ],
        );
        assert!((rows[0].eff_vs_cpu - 1.0).abs() < 1e-12);
        assert!(rows[2].eff_vs_cpu > rows[1].eff_vs_cpu);
        assert!(rows[2].eff_vs_cpu > 500.0);
    }

    #[test]
    fn energy_is_power_times_time() {
        assert_eq!(energy_joules(25.0, 4.0), 100.0);
    }
}
