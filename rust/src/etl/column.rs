//! Typed columnar values flowing through ETL pipelines.
//!
//! Recommender ETL is columnar: every feature is a column, and operators
//! transform whole columns. Three physical representations cover the
//! paper's operator pool (Table 1):
//!
//! * `F32`  — dense numeric features (possibly multi-wide after OneHot),
//! * `Hex8` — raw categorical tokens: 8 ASCII hex chars packed in a `u64`
//!            (the Criteo on-disk encoding),
//! * `I64`  — integer categorical values / vocabulary indices.

use crate::error::{EtlError, Result};

/// A typed column of feature values.
#[derive(Debug, Clone, PartialEq)]
pub enum Column {
    /// Dense floats; `width` values per row (width > 1 after OneHot).
    F32 { data: Vec<f32>, width: usize },
    /// Raw categorical tokens as 8 packed ASCII hex characters.
    Hex8 { data: Vec<u64> },
    /// Integer categorical values or indices; `width` values per row.
    I64 { data: Vec<i64>, width: usize },
}

/// Logical type tags used by DAG validation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ColType {
    F32,
    Hex8,
    I64,
}

impl std::fmt::Display for ColType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ColType::F32 => write!(f, "f32"),
            ColType::Hex8 => write!(f, "hex8"),
            ColType::I64 => write!(f, "i64"),
        }
    }
}

impl Column {
    pub fn f32(data: Vec<f32>) -> Column {
        Column::F32 { data, width: 1 }
    }

    pub fn i64(data: Vec<i64>) -> Column {
        Column::I64 { data, width: 1 }
    }

    pub fn hex8(data: Vec<u64>) -> Column {
        Column::Hex8 { data }
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        match self {
            Column::F32 { data, width } => data.len() / width.max(&1),
            Column::Hex8 { data } => data.len(),
            Column::I64 { data, width } => data.len() / width.max(&1),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Values per row.
    pub fn width(&self) -> usize {
        match self {
            Column::F32 { width, .. } => *width,
            Column::Hex8 { .. } => 1,
            Column::I64 { width, .. } => *width,
        }
    }

    pub fn coltype(&self) -> ColType {
        match self {
            Column::F32 { .. } => ColType::F32,
            Column::Hex8 { .. } => ColType::Hex8,
            Column::I64 { .. } => ColType::I64,
        }
    }

    /// Bytes per row on the wire (64-bit words for hex/int, 4-byte floats).
    pub fn row_bytes(&self) -> usize {
        match self {
            Column::F32 { width, .. } => 4 * width,
            Column::Hex8 { .. } => 8,
            Column::I64 { width, .. } => 8 * width,
        }
    }

    /// Total payload bytes.
    pub fn total_bytes(&self) -> usize {
        self.len() * self.row_bytes()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Column::F32 { data, .. } => Ok(data),
            other => Err(EtlError::TypeMismatch {
                expected: ColType::F32,
                got: other.coltype(),
            }),
        }
    }

    pub fn as_hex8(&self) -> Result<&[u64]> {
        match self {
            Column::Hex8 { data } => Ok(data),
            other => Err(EtlError::TypeMismatch {
                expected: ColType::Hex8,
                got: other.coltype(),
            }),
        }
    }

    pub fn as_i64(&self) -> Result<&[i64]> {
        match self {
            Column::I64 { data, .. } => Ok(data),
            other => Err(EtlError::TypeMismatch {
                expected: ColType::I64,
                got: other.coltype(),
            }),
        }
    }
}

/// Pack an ASCII hex string (up to 8 chars) into the `Hex8` wire format.
/// Shorter strings are left-padded with '0'.
pub fn pack_hex(s: &str) -> Result<u64> {
    let bytes = s.as_bytes();
    if bytes.len() > 8 || bytes.is_empty() {
        return Err(EtlError::BadHex(s.to_string()));
    }
    let mut out = [b'0'; 8];
    out[8 - bytes.len()..].copy_from_slice(bytes);
    for &b in &out {
        if !b.is_ascii_hexdigit() {
            return Err(EtlError::BadHex(s.to_string()));
        }
    }
    Ok(u64::from_be_bytes(out))
}

/// Unpack the `Hex8` wire format back to an ASCII string.
pub fn unpack_hex(v: u64) -> String {
    String::from_utf8(v.to_be_bytes().to_vec()).expect("hex8 is always ASCII")
}

/// A batch: a set of named columns with equal row counts.
#[derive(Debug, Clone, Default)]
pub struct Batch {
    pub columns: Vec<(String, Column)>,
}

impl Batch {
    pub fn new() -> Batch {
        Batch::default()
    }

    /// Number of rows (0 for an empty batch). All columns must agree —
    /// enforced by `push`.
    pub fn rows(&self) -> usize {
        self.columns.first().map(|(_, c)| c.len()).unwrap_or(0)
    }

    pub fn push(&mut self, name: impl Into<String>, col: Column) -> Result<()> {
        if !self.columns.is_empty() && col.len() != self.rows() {
            return Err(EtlError::RowCountMismatch {
                expected: self.rows(),
                got: col.len(),
            });
        }
        self.columns.push((name.into(), col));
        Ok(())
    }

    pub fn get(&self, name: &str) -> Option<&Column> {
        self.columns
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, c)| c)
    }

    pub fn total_bytes(&self) -> usize {
        self.columns.iter().map(|(_, c)| c.total_bytes()).sum()
    }

    /// Extract rows `range` of every column (tile slicing for row-range
    /// parallel executors).
    pub fn slice_rows(&self, range: std::ops::Range<usize>) -> Batch {
        let mut out = Batch::new();
        for (name, col) in &self.columns {
            let c = match col {
                Column::F32 { data, width } => Column::F32 {
                    data: data[range.start * width..range.end * width].to_vec(),
                    width: *width,
                },
                Column::Hex8 { data } => Column::Hex8 { data: data[range.clone()].to_vec() },
                Column::I64 { data, width } => Column::I64 {
                    data: data[range.start * width..range.end * width].to_vec(),
                    width: *width,
                },
            };
            out.push(name.clone(), c).expect("slice preserves row counts");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex_roundtrip() {
        let v = pack_hex("0a1b2c3d").unwrap();
        assert_eq!(unpack_hex(v), "0a1b2c3d");
    }

    #[test]
    fn hex_pads_short_strings() {
        let v = pack_hex("1a3f").unwrap();
        assert_eq!(unpack_hex(v), "00001a3f");
    }

    #[test]
    fn hex_rejects_bad_input() {
        assert!(pack_hex("xyz").is_err());
        assert!(pack_hex("123456789").is_err());
        assert!(pack_hex("").is_err());
    }

    #[test]
    fn widths_and_lengths() {
        let c = Column::F32 {
            data: vec![0.0; 12],
            width: 4,
        };
        assert_eq!(c.len(), 3);
        assert_eq!(c.width(), 4);
        assert_eq!(c.row_bytes(), 16);
        assert_eq!(c.total_bytes(), 48);
    }

    #[test]
    fn batch_rejects_mismatched_rows() {
        let mut b = Batch::new();
        b.push("a", Column::f32(vec![1.0, 2.0])).unwrap();
        assert!(b.push("b", Column::f32(vec![1.0])).is_err());
        assert_eq!(b.rows(), 2);
    }

    #[test]
    fn typed_accessors_enforce_types() {
        let c = Column::f32(vec![1.0]);
        assert!(c.as_f32().is_ok());
        assert!(c.as_i64().is_err());
        assert!(c.as_hex8().is_err());
    }
}
