//! The three canned evaluation pipelines (§4.1.3, Fig. 9), constructed
//! over an arbitrary tabular schema:
//!
//! * **Pipeline I** — stateless: dense → FillMissing→Clamp→Logarithm,
//!   sparse → Hex2Int→Modulus.
//! * **Pipeline II** — Pipeline I + small (8K) vocabulary tables.
//! * **Pipeline III** — Pipeline I + large (512K) vocabulary tables.

use crate::etl::column::ColType;
use crate::etl::schema::FeatureKind;
use crate::etl::dag::{Dag, SinkRole};
use crate::etl::ops::OpSpec;
use crate::etl::schema::Schema;

/// Small-vocabulary size used by Pipeline II (BRAM-resident).
pub const SMALL_VOCAB: usize = 8 * 1024;
/// Large-vocabulary size used by Pipeline III (HBM-resident).
pub const LARGE_VOCAB: usize = 512 * 1024;

/// Which evaluation pipeline to build.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PipelineKind {
    /// Stateless only.
    I,
    /// Stateful, small vocab tables.
    II,
    /// Stateful, large vocab tables.
    III,
}

impl PipelineKind {
    pub fn label(&self) -> &'static str {
        match self {
            PipelineKind::I => "P-I",
            PipelineKind::II => "P-II",
            PipelineKind::III => "P-III",
        }
    }

    /// Modulus bound / expected vocabulary cardinality.
    pub fn vocab_size(&self) -> Option<usize> {
        match self {
            PipelineKind::I => None,
            PipelineKind::II => Some(SMALL_VOCAB),
            PipelineKind::III => Some(LARGE_VOCAB),
        }
    }

    pub fn all() -> [PipelineKind; 3] {
        [PipelineKind::I, PipelineKind::II, PipelineKind::III]
    }
}

/// Build the evaluation pipeline `kind` over `schema`.
///
/// Every dense field runs FillMissing→Clamp→Logarithm; every sparse field
/// runs Hex2Int→Modulus (bound = vocab size for stateful pipelines, 2^22
/// for Pipeline I) and, for Pipelines II/III, VocabGen. The label passes
/// through.
pub fn build(kind: PipelineKind, schema: &Schema) -> Dag {
    let mut dag = Dag::new(format!("{}", kind.label()));

    // Label passthrough.
    for f in &schema.fields {
        if f.kind == FeatureKind::Label {
            let s = dag.source(&f.name, ColType::F32);
            dag.sink("label", s, SinkRole::Label);
        }
    }

    // Dense chain.
    for (di, f) in schema.dense_fields().enumerate() {
        let s = dag.source(&f.name, ColType::F32);
        let fm = dag.op(
            OpSpec::FillMissing { dense_default: 0.0, sparse_default: 0 },
            &[s],
        );
        let cl = dag.op(OpSpec::Clamp { lo: 0.0, hi: f32::MAX }, &[fm]);
        let lg = dag.op(OpSpec::Logarithm, &[cl]);
        dag.sink(format!("dense{di}"), lg, SinkRole::Dense);
    }

    // Sparse chain.
    let modulus = kind.vocab_size().unwrap_or(1 << 22) as i64;
    for (si, f) in schema.sparse_fields().enumerate() {
        let s = dag.source(&f.name, ColType::Hex8);
        let h = dag.op(OpSpec::Hex2Int, &[s]);
        let m = dag.op(OpSpec::Modulus { m: modulus }, &[h]);
        let out = match kind.vocab_size() {
            None => m,
            Some(expected) => dag.vocab_op(
                OpSpec::VocabGen { expected },
                m,
                format!("vocab_{}", f.name),
            ),
        };
        dag.sink(format!("sparse{si}"), out, SinkRole::SparseIndex);
    }

    dag
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::etl::schema::Schema;

    #[test]
    fn all_pipelines_validate_on_criteo() {
        let schema = Schema::criteo_kaggle();
        for kind in PipelineKind::all() {
            let dag = build(kind, &schema);
            dag.validate(&schema).unwrap();
        }
    }

    #[test]
    fn pipeline1_is_stateless() {
        let schema = Schema::criteo_kaggle();
        let dag = build(PipelineKind::I, &schema);
        assert_eq!(dag.stateful_count(), 0);
    }

    #[test]
    fn pipeline2_has_one_vocab_per_sparse_feature() {
        let schema = Schema::criteo_kaggle();
        let dag = build(PipelineKind::II, &schema);
        assert_eq!(dag.stateful_count(), 26);
    }

    #[test]
    fn sink_counts_match_schema() {
        let schema = Schema::synthetic_wide();
        let dag = build(PipelineKind::III, &schema);
        let sinks: Vec<_> = dag.sinks().collect();
        // label + 504 dense + 42 sparse
        assert_eq!(sinks.len(), 1 + 504 + 42);
    }

    #[test]
    fn vocab_sizes_match_paper() {
        assert_eq!(PipelineKind::II.vocab_size(), Some(8192));
        assert_eq!(PipelineKind::III.vocab_size(), Some(524288));
        assert_eq!(PipelineKind::I.vocab_size(), None);
    }
}
