//! Fused tiled execution engine — the software analogue of the paper's
//! streaming FPGA dataflow (§3.2).
//!
//! The reference executor ([`Dag::apply`]) is an interpreter: every
//! operator materializes a fresh [`Column`], and the packer then pays a
//! second strided transpose pass to produce the trainer layout. The FPGA
//! never does this — operators are *fused* into streaming op-chains
//! connected by on-chip FIFOs, and each record crosses the datapath once,
//! landing directly in its training-ready position (§3.2, Fig. 4/5). This
//! module reproduces that execution model on the host:
//!
//! 1. **Compile** — [`FusedEngine::compile`] lowers a `Dag` + its
//!    [`PackLayout`] into per-sink fused chains: a linear sequence of
//!    [`Step`]s over the scalar kernels in [`crate::etl::ops::kernels`]
//!    (the single source of operator truth, so results stay bit-identical
//!    to the reference executor). Sinks whose subgraph is not a linear
//!    unary chain (Cartesian diamonds, OneHot widening, type errors)
//!    compile to a *general* plan that evaluates the subgraph per tile
//!    with the same semantics as `Dag::apply`.
//! 2. **Tile** — execution walks the input in row tiles (default 8 K
//!    rows, i.e. L1/L2-resident working sets, the software stand-in for
//!    the FPGA's FIFO depth). Each chain runs stage-at-a-time over a
//!    reused tile scratch buffer: no per-operator `Column` allocation,
//!    no reference counting, nothing shared — the engine is `Send + Sync`.
//! 3. **Pack** — the final stage of every chain writes the tile's values
//!    *directly into the row-major [`PackedBatch`] buffers* (dense f32
//!    `[B, D_d]`, sparse i32 `[B, D_s]`, labels `[B]`), fusing apply and
//!    pack into one pass exactly as the format-aware packer does in
//!    hardware (§3.2.3).
//!
//! Because tiles write disjoint row ranges, tiles are embarrassingly
//! parallel: [`ExecConfig::threads`] workers split the tile list and one
//! `process()` call saturates all cores. All apply-phase operators are
//! row-wise pure (vocabularies are frozen during apply — the fit/apply
//! split of §3.1), so the output is bit-identical for every tile size and
//! thread count; `rust/tests/prop_invariants.rs` proves this against the
//! reference executor across random pipelines.
//!
//! [`BufferPool`] recycles `PackedBatch` buffers so the steady-state
//! train loop allocates nothing per batch ([`FusedEngine::execute_into`]
//! reuses the destination's capacity).

use std::sync::Mutex;

use crate::coordinator::packer::{PackLayout, PackedBatch};
use crate::error::{EtlError, Result};
use crate::etl::column::{Batch, ColType, Column};
use crate::etl::dag::{Dag, EtlState, Node, NodeId, SinkRole};
use crate::etl::ops::kernels;
use crate::etl::ops::OpSpec;

/// Execution knobs.
#[derive(Debug, Clone)]
pub struct ExecConfig {
    /// Rows per tile (cache-resident working set).
    pub tile_rows: usize,
    /// Worker threads for row-range parallelism (1 = serial).
    pub threads: usize,
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig {
            tile_rows: 8192,
            threads: crate::util::pool::default_threads(),
        }
    }
}

/// One fused pipeline stage: a scalar kernel with frozen parameters.
/// Mirrors the operator pool (Table 1) minus the widening/binary
/// operators, which take the general per-tile path instead.
#[derive(Debug, Clone)]
enum Step {
    FillMissingF32(f32),
    Clamp { lo: f32, hi: f32 },
    Logarithm,
    Bucketize(Vec<f32>),
    Hex2Int,
    FillMissingI64(i64),
    Modulus(i64),
    SigridHash(i64),
    /// VocabGen replayed through the frozen table (apply-phase semantics:
    /// OOV maps to `table.len()`, matching `Dag::apply`).
    VocabReplay(String),
    VocabMap { key: String, oov: Option<i64> },
}

/// Where a chain's output lands in the packed batch.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Dest {
    Dense(usize),
    Sparse(usize),
    Label,
}

/// Compiled plan for one sink.
#[derive(Debug, Clone)]
enum SinkPlan {
    /// Linear unary chain fused end-to-end: source → steps → packed slot.
    Fused {
        name: String,
        source: String,
        src_type: ColType,
        steps: Vec<Step>,
        dest: Dest,
    },
    /// Non-linear / unsupported subgraph: evaluated per tile with
    /// reference semantics, then scattered into the packed slot.
    General { name: String, node: usize, dest: Dest },
}

/// A compiled DAG + layout, executable tile-at-a-time straight into
/// trainer-layout buffers. `Send + Sync`: plain owned data, no `Rc`.
#[derive(Debug, Clone)]
pub struct FusedEngine {
    dag: Dag,
    layout: PackLayout,
    sinks: Vec<SinkPlan>,
    pub cfg: ExecConfig,
    n_dense: usize,
    n_sparse: usize,
    fused: usize,
}

/// Reused per-worker tile scratch.
struct TileBufs {
    f: Vec<f32>,
    i: Vec<i64>,
}

impl TileBufs {
    fn new(tile: usize) -> TileBufs {
        TileBufs { f: Vec::with_capacity(tile), i: Vec::with_capacity(tile) }
    }
}

/// One tile's disjoint output region.
struct TileJob<'a> {
    start: usize,
    rows: usize,
    dense: &'a mut [f32],
    sparse: &'a mut [i32],
    labels: &'a mut [f32],
}

impl FusedEngine {
    /// Lower `dag` into fused per-sink chains packing into the layout
    /// derived from its sinks. Fails only if the DAG has no label sink
    /// (no [`PackLayout`]); every sink shape is executable — unsupported
    /// shapes fall back to the general per-tile evaluator.
    pub fn compile(dag: &Dag, cfg: ExecConfig) -> Result<FusedEngine> {
        let layout = PackLayout::of(dag)?;
        let n_dense = layout.dense_cols.len();
        let n_sparse = layout.sparse_cols.len();
        let mut sinks = Vec::new();
        let mut fused = 0usize;
        let (mut di, mut si) = (0usize, 0usize);
        for (name, input, role) in dag.sinks() {
            let dest = match role {
                SinkRole::Dense => {
                    let d = Dest::Dense(di);
                    di += 1;
                    d
                }
                SinkRole::SparseIndex => {
                    let d = Dest::Sparse(si);
                    si += 1;
                    d
                }
                SinkRole::Label => {
                    // The packer reads only `layout.label_col` (the last
                    // declared label sink); mirror that.
                    if name != layout.label_col {
                        continue;
                    }
                    Dest::Label
                }
            };
            match lower_chain(dag, input, dest) {
                Some((source, src_type, steps)) => {
                    fused += 1;
                    sinks.push(SinkPlan::Fused {
                        name: name.to_string(),
                        source,
                        src_type,
                        steps,
                        dest,
                    });
                }
                None => sinks.push(SinkPlan::General {
                    name: name.to_string(),
                    node: input.0,
                    dest,
                }),
            }
        }
        Ok(FusedEngine {
            dag: dag.clone(),
            layout,
            sinks,
            cfg,
            n_dense,
            n_sparse,
            fused,
        })
    }

    /// Number of sinks compiled to fully-fused chains (vs general).
    pub fn fused_sink_count(&self) -> usize {
        self.fused
    }

    /// Total sinks in the compiled plan.
    pub fn sink_count(&self) -> usize {
        self.sinks.len()
    }

    /// The pack layout this engine targets.
    pub fn layout(&self) -> &PackLayout {
        &self.layout
    }

    /// Apply + pack in one pass, allocating a fresh batch.
    pub fn execute(&self, input: &Batch, state: &EtlState) -> Result<PackedBatch> {
        let mut out = empty_batch();
        self.execute_into(input, state, &mut out)?;
        Ok(out)
    }

    /// Apply + pack in one pass into `out`, reusing its buffers (zero
    /// steady-state allocation when `out` comes from a [`BufferPool`]).
    pub fn execute_into(&self, input: &Batch, state: &EtlState, out: &mut PackedBatch) -> Result<()> {
        let rows = input.rows();
        out.rows = rows;
        out.n_dense = self.n_dense;
        out.n_sparse = self.n_sparse;
        out.dense.clear();
        out.dense.resize(rows * self.n_dense, 0.0);
        out.sparse.clear();
        out.sparse.resize(rows * self.n_sparse, 0);
        out.labels.clear();
        out.labels.resize(rows, 0.0);
        if rows == 0 {
            return Ok(());
        }

        let tile = self.cfg.tile_rows.max(1);
        let n_tiles = rows.div_ceil(tile);
        let threads = self.cfg.threads.max(1).min(n_tiles);

        // Carve the output into disjoint per-tile mutable regions.
        let mut jobs: Vec<TileJob<'_>> = Vec::with_capacity(n_tiles);
        {
            let mut d: &mut [f32] = &mut out.dense;
            let mut s: &mut [i32] = &mut out.sparse;
            let mut l: &mut [f32] = &mut out.labels;
            let mut start = 0usize;
            while start < rows {
                let n = tile.min(rows - start);
                let (dh, dt) = std::mem::take(&mut d).split_at_mut(n * self.n_dense);
                d = dt;
                let (sh, st) = std::mem::take(&mut s).split_at_mut(n * self.n_sparse);
                s = st;
                let (lh, lt) = std::mem::take(&mut l).split_at_mut(n);
                l = lt;
                jobs.push(TileJob { start, rows: n, dense: dh, sparse: sh, labels: lh });
                start += n;
            }
        }

        if threads <= 1 {
            let mut bufs = TileBufs::new(tile);
            for job in jobs {
                self.run_tile(input, state, job, &mut bufs)?;
            }
            return Ok(());
        }

        // Row-range data parallelism: round-robin tiles over a scoped
        // worker pool; disjoint output regions need no synchronization.
        let mut groups: Vec<Vec<TileJob<'_>>> = (0..threads).map(|_| Vec::new()).collect();
        for (i, job) in jobs.into_iter().enumerate() {
            groups[i % threads].push(job);
        }
        let results: Vec<Result<()>> = std::thread::scope(|scope| {
            let handles: Vec<_> = groups
                .into_iter()
                .map(|group| {
                    scope.spawn(move || -> Result<()> {
                        let mut bufs = TileBufs::new(tile);
                        for job in group {
                            self.run_tile(input, state, job, &mut bufs)?;
                        }
                        Ok(())
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("fused-exec worker panicked"))
                .collect()
        });
        for r in results {
            r?;
        }
        Ok(())
    }

    /// Execute with a recycled destination buffer from `pool`.
    pub fn execute_pooled(
        &self,
        input: &Batch,
        state: &EtlState,
        pool: &BufferPool,
    ) -> Result<PackedBatch> {
        let mut out = pool.take();
        self.execute_into(input, state, &mut out)?;
        Ok(out)
    }

    /// Run every sink chain over one tile.
    fn run_tile(
        &self,
        input: &Batch,
        state: &EtlState,
        mut job: TileJob<'_>,
        bufs: &mut TileBufs,
    ) -> Result<()> {
        let range = job.start..job.start + job.rows;
        // Lazily sliced tile sub-batch + memo, shared by general sinks.
        let mut sub: Option<Batch> = None;
        let mut memo: Vec<Option<Column>> = Vec::new();
        for sink in &self.sinks {
            match sink {
                SinkPlan::Fused { name, source, src_type, steps, dest } => self.run_fused(
                    input, state, &range, bufs, name, source, *src_type, steps, *dest, &mut job,
                )?,
                SinkPlan::General { name, node, dest } => {
                    if sub.is_none() {
                        sub = Some(input.slice_rows(range.clone()));
                        memo = vec![None; self.dag.nodes.len()];
                    }
                    let col = eval_owned(
                        &self.dag,
                        *node,
                        sub.as_ref().expect("just set"),
                        state,
                        &mut memo,
                    )?;
                    write_general(name, &col, *dest, &mut job, self.n_dense, self.n_sparse)?;
                }
            }
        }
        Ok(())
    }

    /// Run one fused chain over a tile and scatter into the packed slot.
    #[allow(clippy::too_many_arguments)]
    fn run_fused(
        &self,
        input: &Batch,
        state: &EtlState,
        range: &std::ops::Range<usize>,
        bufs: &mut TileBufs,
        name: &str,
        source: &str,
        src_type: ColType,
        steps: &[Step],
        dest: Dest,
        job: &mut TileJob<'_>,
    ) -> Result<()> {
        let col = input
            .get(source)
            .ok_or_else(|| EtlError::Dag(format!("input batch missing column {source:?}")))?;
        if col.coltype() != src_type {
            return Err(EtlError::TypeMismatch { expected: src_type, got: col.coltype() });
        }
        if col.width() != 1 {
            let role = match dest {
                Dest::Dense(_) => "dense",
                Dest::Sparse(_) => "sparse",
                Dest::Label => "label",
            };
            return Err(EtlError::Coord(format!(
                "{role} sink {name} has width {} (expected 1)",
                col.width()
            )));
        }

        // Load the source tile (hex sources fuse straight through the
        // leading Hex2Int — no staging copy of the raw tokens).
        let mut next_step = 0usize;
        let mut is_f32 = match col {
            Column::F32 { data, .. } => {
                bufs.f.clear();
                bufs.f.extend_from_slice(&data[range.clone()]);
                true
            }
            Column::I64 { data, .. } => {
                bufs.i.clear();
                bufs.i.extend_from_slice(&data[range.clone()]);
                false
            }
            Column::Hex8 { data } => {
                debug_assert!(matches!(steps.first(), Some(Step::Hex2Int)));
                bufs.i.clear();
                bufs.i.extend(data[range.clone()].iter().map(|&v| kernels::hex2int(v)));
                next_step = 1;
                false
            }
        };

        // Stage-at-a-time over the cache-resident tile buffer.
        for step in &steps[next_step..] {
            match step {
                Step::FillMissingF32(d) => {
                    for v in bufs.f.iter_mut() {
                        *v = kernels::fill_missing_f32(*v, *d);
                    }
                }
                Step::Clamp { lo, hi } => {
                    for v in bufs.f.iter_mut() {
                        *v = kernels::clamp(*v, *lo, *hi);
                    }
                }
                Step::Logarithm => {
                    for v in bufs.f.iter_mut() {
                        *v = kernels::logarithm(*v);
                    }
                }
                Step::Bucketize(borders) => {
                    bufs.i.clear();
                    bufs.i.extend(bufs.f.iter().map(|&x| kernels::bucketize(x, borders)));
                    is_f32 = false;
                }
                Step::Hex2Int => {
                    return Err(EtlError::Dag(
                        "fused Hex2Int on a non-source position (compiler bug)".into(),
                    ));
                }
                Step::FillMissingI64(d) => {
                    for v in bufs.i.iter_mut() {
                        *v = kernels::fill_missing_i64(*v, *d);
                    }
                }
                Step::Modulus(m) => {
                    for v in bufs.i.iter_mut() {
                        *v = kernels::modulus(*v, *m);
                    }
                }
                Step::SigridHash(m) => {
                    for v in bufs.i.iter_mut() {
                        *v = kernels::sigrid_hash(*v, *m);
                    }
                }
                Step::VocabReplay(key) => {
                    let table = state
                        .vocabs
                        .get(key)
                        .ok_or_else(|| EtlError::Vocab(format!("vocab {key:?} not fitted")))?;
                    let oov = table.len() as i64;
                    for v in bufs.i.iter_mut() {
                        *v = table.get(*v).map(|i| i as i64).unwrap_or(oov);
                    }
                }
                Step::VocabMap { key, oov } => {
                    let table = state.vocabs.get(key).ok_or_else(|| {
                        EtlError::op("VocabMap", "no fitted vocabulary table provided")
                    })?;
                    match oov {
                        Some(d) => {
                            for v in bufs.i.iter_mut() {
                                *v = table.get(*v).map(|i| i as i64).unwrap_or(*d);
                            }
                        }
                        None => {
                            for v in bufs.i.iter_mut() {
                                *v = table.get(*v).map(|i| i as i64).ok_or_else(|| {
                                    EtlError::Vocab(format!(
                                        "value {v} not present in fitted vocabulary (size {})",
                                        table.len()
                                    ))
                                })?;
                            }
                        }
                    }
                }
            }
        }

        // Pack: scatter the tile into its row-major destination.
        match dest {
            Dest::Dense(ci) => {
                debug_assert!(is_f32);
                let nd = self.n_dense;
                for (r, &v) in bufs.f.iter().enumerate() {
                    job.dense[r * nd + ci] = v;
                }
            }
            Dest::Label => {
                debug_assert!(is_f32);
                job.labels.copy_from_slice(&bufs.f);
            }
            Dest::Sparse(ci) => {
                let ns = self.n_sparse;
                for (r, &v) in bufs.i.iter().enumerate() {
                    if v < 0 || v > i32::MAX as i64 {
                        return Err(EtlError::Coord(format!(
                            "sparse index {v} out of i32 range in {name}"
                        )));
                    }
                    job.sparse[r * ns + ci] = v as i32;
                }
            }
        }
        Ok(())
    }
}

fn empty_batch() -> PackedBatch {
    PackedBatch {
        rows: 0,
        n_dense: 0,
        n_sparse: 0,
        dense: Vec::new(),
        sparse: Vec::new(),
        labels: Vec::new(),
    }
}

/// Walk back from a sink input to its source; `Some` iff the subgraph is
/// a linear unary chain of fusable operators whose types check out for
/// `dest` (the same checks `Dag::validate` performs, re-derived here so
/// compilation works without a schema).
fn lower_chain(dag: &Dag, from: NodeId, dest: Dest) -> Option<(String, ColType, Vec<Step>)> {
    // Collect (spec, vocab_key) back-to-front.
    let mut rev: Vec<(&OpSpec, Option<&String>)> = Vec::new();
    let mut cur = from;
    let (source, src_type) = loop {
        match dag.nodes.get(cur.0)? {
            Node::Source { field, coltype } => break (field.clone(), *coltype),
            Node::Sink { input, .. } => cur = *input,
            Node::Op { spec, inputs, vocab_key } => {
                if inputs.len() != 1 {
                    return None; // Cartesian et al. → general path
                }
                rev.push((spec, vocab_key.as_ref()));
                cur = inputs[0];
            }
        }
    };

    // Forward type-checked lowering.
    let mut ty = src_type;
    let mut steps = Vec::with_capacity(rev.len());
    for (spec, key) in rev.into_iter().rev() {
        let step = match (spec, ty) {
            (OpSpec::FillMissing { dense_default, .. }, ColType::F32) => {
                Step::FillMissingF32(*dense_default)
            }
            (OpSpec::FillMissing { sparse_default, .. }, ColType::I64) => {
                Step::FillMissingI64(*sparse_default)
            }
            (OpSpec::Clamp { lo, hi }, ColType::F32) => Step::Clamp { lo: *lo, hi: *hi },
            (OpSpec::Logarithm, ColType::F32) => Step::Logarithm,
            (OpSpec::Bucketize { borders }, ColType::F32) => {
                ty = ColType::I64;
                Step::Bucketize(borders.clone())
            }
            (OpSpec::Hex2Int, ColType::Hex8) => {
                ty = ColType::I64;
                Step::Hex2Int
            }
            (OpSpec::Modulus { m }, ColType::I64) => Step::Modulus(*m),
            (OpSpec::SigridHash { m }, ColType::I64) => Step::SigridHash(*m),
            (OpSpec::VocabGen { .. }, ColType::I64) => Step::VocabReplay(key?.clone()),
            (OpSpec::VocabMap { oov }, ColType::I64) => {
                Step::VocabMap { key: key?.clone(), oov: *oov }
            }
            // OneHot (widening), type mismatches → general path.
            _ => return None,
        };
        steps.push(step);
    }

    // Hex sources are only fusable through a leading Hex2Int.
    if src_type == ColType::Hex8 && !matches!(steps.first(), Some(Step::Hex2Int)) {
        return None;
    }
    // Final type must match the destination tensor.
    let ok = match dest {
        Dest::Dense(_) | Dest::Label => ty == ColType::F32,
        Dest::Sparse(_) => ty == ColType::I64,
    };
    if !ok {
        return None;
    }
    Some((source, src_type, steps))
}

/// Reference-semantics evaluation of one node over a (tile) batch, memoized
/// per tile. Mirrors `Dag::apply`'s `eval_node` (including the VocabGen
/// replay-through-frozen-table apply semantics) without `Rc`, so the
/// engine stays `Send`.
fn eval_owned(
    dag: &Dag,
    i: usize,
    batch: &Batch,
    state: &EtlState,
    memo: &mut Vec<Option<Column>>,
) -> Result<Column> {
    if let Some(col) = &memo[i] {
        return Ok(col.clone());
    }
    let col = match &dag.nodes[i] {
        Node::Source { field, .. } => batch
            .get(field)
            .cloned()
            .ok_or_else(|| EtlError::Dag(format!("input batch missing column {field:?}")))?,
        Node::Op { spec, inputs, vocab_key } => {
            let mut cols = Vec::with_capacity(inputs.len());
            for &NodeId(j) in inputs {
                cols.push(eval_owned(dag, j, batch, state, memo)?);
            }
            let refs: Vec<&Column> = cols.iter().collect();
            let vocab = vocab_key.as_ref().and_then(|k| state.vocabs.get(k));
            match spec {
                OpSpec::VocabGen { .. } => {
                    let key = vocab_key
                        .as_ref()
                        .ok_or_else(|| EtlError::Vocab("VocabGen has no vocab key".into()))?;
                    let table = state
                        .vocabs
                        .get(key)
                        .ok_or_else(|| EtlError::Vocab(format!("vocab {key:?} not fitted")))?;
                    let data = refs[0].as_i64()?;
                    Column::i64(crate::etl::ops::vocab::vocab_map_oov(
                        data,
                        table,
                        table.len() as i64,
                    ))
                }
                _ => spec.apply(&refs, vocab)?,
            }
        }
        Node::Sink { input: NodeId(j), .. } => eval_owned(dag, *j, batch, state, memo)?,
    };
    memo[i] = Some(col.clone());
    Ok(col)
}

/// Scatter a general sink's tile column into the packed destination, with
/// the packer's exact shape/range checks.
fn write_general(
    name: &str,
    col: &Column,
    dest: Dest,
    job: &mut TileJob<'_>,
    n_dense: usize,
    n_sparse: usize,
) -> Result<()> {
    match dest {
        Dest::Dense(ci) => {
            let data = col.as_f32()?;
            if col.width() != 1 {
                return Err(EtlError::Coord(format!(
                    "dense sink {name} has width {} (expected 1)",
                    col.width()
                )));
            }
            for (r, &v) in data.iter().enumerate() {
                job.dense[r * n_dense + ci] = v;
            }
        }
        Dest::Label => {
            let data = col.as_f32()?;
            if data.len() != job.rows {
                return Err(EtlError::Coord(format!(
                    "label sink {name} has width {} (expected 1)",
                    col.width()
                )));
            }
            job.labels.copy_from_slice(data);
        }
        Dest::Sparse(ci) => {
            let data = col.as_i64()?;
            if col.width() != 1 {
                return Err(EtlError::Coord(format!(
                    "sparse sink {name} has width {} (expected 1)",
                    col.width()
                )));
            }
            for (r, &v) in data.iter().enumerate() {
                if v < 0 || v > i32::MAX as i64 {
                    return Err(EtlError::Coord(format!(
                        "sparse index {v} out of i32 range in {name}"
                    )));
                }
                job.sparse[r * n_sparse + ci] = v as i32;
            }
        }
    }
    Ok(())
}

/// A recycling pool of [`PackedBatch`] buffers: `take` a buffer, fill it
/// with [`FusedEngine::execute_into`], and `put` it back once consumed —
/// the steady-state loop then allocates nothing per batch.
#[derive(Debug, Default)]
pub struct BufferPool {
    free: Mutex<Vec<PackedBatch>>,
}

impl BufferPool {
    pub fn new() -> BufferPool {
        BufferPool::default()
    }

    /// Pop a recycled buffer (or a fresh empty one).
    pub fn take(&self) -> PackedBatch {
        self.free
            .lock()
            .expect("buffer pool poisoned")
            .pop()
            .unwrap_or_else(empty_batch)
    }

    /// Return a buffer for reuse.
    pub fn put(&self, batch: PackedBatch) {
        self.free.lock().expect("buffer pool poisoned").push(batch);
    }

    /// Buffers currently available.
    pub fn available(&self) -> usize {
        self.free.lock().expect("buffer pool poisoned").len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::packer::pack;
    use crate::dataio::dataset::DatasetSpec;
    use crate::etl::pipelines::{build, PipelineKind};

    fn assert_packed_eq(a: &PackedBatch, b: &PackedBatch) {
        assert_eq!((a.rows, a.n_dense, a.n_sparse), (b.rows, b.n_dense, b.n_sparse));
        assert_eq!(a.sparse, b.sparse);
        assert_eq!(a.labels.len(), b.labels.len());
        for (x, y) in a.labels.iter().zip(&b.labels) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.dense.len(), b.dense.len());
        for (x, y) in a.dense.iter().zip(&b.dense) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    fn reference(dag: &Dag, batch: &Batch, state: &EtlState) -> PackedBatch {
        let out = dag.apply(batch, state).unwrap();
        let layout = PackLayout::of(dag).unwrap();
        pack(&out, &layout).unwrap()
    }

    #[test]
    fn engine_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<FusedEngine>();
        assert_send_sync::<BufferPool>();
    }

    #[test]
    fn fused_matches_reference_on_all_canned_pipelines() {
        let mut spec = DatasetSpec::dataset_i(0.002);
        spec.shards = 1;
        let shard = spec.shard(0, 7);
        for kind in PipelineKind::all() {
            let dag = build(kind, &spec.schema);
            let state = dag.fit(&shard).unwrap();
            let want = reference(&dag, &shard, &state);
            for (tile, threads) in [(shard.rows() + 1, 1), (1000, 1), (333, 4), (1, 2)] {
                let engine =
                    FusedEngine::compile(&dag, ExecConfig { tile_rows: tile, threads }).unwrap();
                // All canned-pipeline sinks are linear chains → fully fused.
                assert_eq!(engine.fused_sink_count(), engine.sink_count(), "{}", kind.label());
                let got = engine.execute(&shard, &state).unwrap();
                assert_packed_eq(&want, &got);
            }
        }
    }

    #[test]
    fn general_fallback_handles_cartesian_and_bucketize() {
        use crate::etl::column::pack_hex;
        let mut dag = Dag::new("diamond");
        let l = dag.source("label", ColType::F32);
        dag.sink("label", l, SinkRole::Label);
        let d = dag.source("x", ColType::F32);
        let bk = dag.op(OpSpec::Bucketize { borders: vec![0.5, 2.0] }, &[d]);
        dag.sink("bucket", bk, SinkRole::SparseIndex);
        let c0 = dag.source("c0", ColType::Hex8);
        let c1 = dag.source("c1", ColType::Hex8);
        let h0 = dag.op(OpSpec::Hex2Int, &[c0]);
        let h1 = dag.op(OpSpec::Hex2Int, &[c1]);
        let cross = dag.op(OpSpec::Cartesian { m: 5000 }, &[h0, h1]);
        dag.sink("cross", cross, SinkRole::SparseIndex);

        let mut batch = Batch::new();
        batch.push("label", Column::f32(vec![1.0, 0.0, 1.0])).unwrap();
        batch.push("x", Column::f32(vec![0.1, f32::NAN, 7.0])).unwrap();
        batch
            .push("c0", Column::hex8(vec![pack_hex("1a3f").unwrap(); 3]))
            .unwrap();
        batch
            .push("c1", Column::hex8(vec![pack_hex("00ff").unwrap(); 3]))
            .unwrap();

        let state = EtlState::default();
        let want = reference(&dag, &batch, &state);
        let engine = FusedEngine::compile(&dag, ExecConfig { tile_rows: 2, threads: 2 }).unwrap();
        // Bucketize chain fuses; the Cartesian diamond takes the general path.
        assert!(engine.fused_sink_count() >= 2);
        assert!(engine.fused_sink_count() < engine.sink_count());
        let got = engine.execute(&batch, &state).unwrap();
        assert_packed_eq(&want, &got);
    }

    #[test]
    fn empty_batch_executes() {
        let spec = DatasetSpec::dataset_i(0.001);
        let dag = build(PipelineKind::I, &spec.schema);
        let engine = FusedEngine::compile(&dag, ExecConfig::default()).unwrap();
        let got = engine.execute(&Batch::new(), &EtlState::default());
        // An empty batch has no columns at all — sources are missing.
        // A zero-row batch with the right columns works:
        let zero = spec.shard(9999, 42);
        if zero.rows() == 0 && !zero.columns.is_empty() {
            let p = engine.execute(&zero, &EtlState::default()).unwrap();
            assert_eq!(p.rows, 0);
        }
        assert!(got.is_err() || got.unwrap().rows == 0);
    }

    #[test]
    fn oov_replay_matches_reference_across_shards() {
        // Fit on shard 0, apply to shard 1 (unseen tokens → OOV index).
        let mut spec = DatasetSpec::dataset_i(0.002);
        spec.shards = 2;
        let dag = build(PipelineKind::II, &spec.schema);
        let state = dag.fit(&spec.shard(0, 42)).unwrap();
        let other = spec.shard(1, 42);
        let want = reference(&dag, &other, &state);
        let engine = FusedEngine::compile(&dag, ExecConfig { tile_rows: 777, threads: 3 }).unwrap();
        let got = engine.execute(&other, &state).unwrap();
        assert_packed_eq(&want, &got);
    }

    #[test]
    fn negative_sparse_index_is_rejected_like_pack() {
        let mut dag = Dag::new("neg");
        let l = dag.source("label", ColType::F32);
        dag.sink("label", l, SinkRole::Label);
        let s = dag.source("s", ColType::I64);
        dag.sink("sparse0", s, SinkRole::SparseIndex);
        let mut batch = Batch::new();
        batch.push("label", Column::f32(vec![0.0, 1.0])).unwrap();
        batch.push("s", Column::i64(vec![3, -1])).unwrap();
        let engine = FusedEngine::compile(&dag, ExecConfig::default()).unwrap();
        let err = engine.execute(&batch, &EtlState::default()).unwrap_err();
        assert!(err.to_string().contains("out of i32 range"), "{err}");
    }

    #[test]
    fn buffer_pool_recycles_capacity() {
        let mut spec = DatasetSpec::dataset_i(0.001);
        spec.shards = 1;
        let shard = spec.shard(0, 3);
        let dag = build(PipelineKind::I, &spec.schema);
        let engine = FusedEngine::compile(&dag, ExecConfig::default()).unwrap();
        let state = EtlState::default();
        let pool = BufferPool::new();
        let b1 = engine.execute_pooled(&shard, &state, &pool).unwrap();
        let ptr = b1.dense.as_ptr();
        let cap = b1.dense.capacity();
        pool.put(b1);
        assert_eq!(pool.available(), 1);
        let b2 = engine.execute_pooled(&shard, &state, &pool).unwrap();
        // Same allocation reused: no steady-state allocation.
        assert_eq!(b2.dense.as_ptr(), ptr);
        assert_eq!(b2.dense.capacity(), cap);
        assert_eq!(pool.available(), 0);
    }
}
