//! Fused tiled execution engine — the software analogue of the paper's
//! streaming FPGA dataflow (§3.2).
//!
//! The reference executor ([`Dag::apply`]) is an interpreter: every
//! operator materializes a fresh [`Column`], and the packer then pays a
//! second strided transpose pass to produce the trainer layout. The FPGA
//! never does this — operators are *fused* into streaming op-chains
//! connected by on-chip FIFOs, and each record crosses the datapath once,
//! landing directly in its training-ready position (§3.2, Fig. 4/5). This
//! module reproduces that execution model on the host:
//!
//! 1. **Compile** — [`FusedEngine::compile`] lowers a `Dag` + its
//!    [`PackLayout`] into per-sink fused chains: a linear sequence of
//!    [`Step`]s over the scalar kernels in [`crate::etl::ops::kernels`]
//!    (the single source of operator truth, so results stay bit-identical
//!    to the reference executor). Three chain shapes fuse end-to-end:
//!    * linear unary chains (source → ops → packed slot);
//!    * the same chain terminated by a widening **OneHot**, which scatters
//!      `k` indicator slots per row straight into the dense tensor;
//!    * two i64 chains crossed by one **Cartesian** with a unary i64 tail
//!      (the binary-operator dataflow of Table 1).
//!    Any other shape (nested Cartesians, OneHot over a binary op, type
//!    errors) compiles to a *general* plan that evaluates the subgraph per
//!    tile with the same semantics as `Dag::apply`.
//! 2. **Tile** — execution walks the input in row tiles (default 8 K
//!    rows, i.e. L1/L2-resident working sets, the software stand-in for
//!    the FPGA's FIFO depth). Each chain runs stage-at-a-time over a
//!    reused tile scratch buffer: no per-operator `Column` allocation,
//!    no reference counting, nothing shared — the engine is `Send + Sync`.
//! 3. **Pack** — the final stage of every chain writes the tile's values
//!    *directly into the row-major [`PackedBatch`] buffers* (dense f32
//!    `[B, D_d]` where `D_d` counts slots including OneHot widening,
//!    sparse i32 `[B, D_s]`, labels `[B]`), fusing apply and pack into one
//!    pass exactly as the format-aware packer does in hardware (§3.2.3).
//!
//! Because tiles write disjoint row ranges, tiles are embarrassingly
//! parallel: [`ExecConfig::threads`] workers split the tile list and one
//! `process()` call saturates all cores. All apply-phase operators are
//! row-wise pure (vocabularies are frozen during apply — the fit/apply
//! split of §3.1), so the output is bit-identical for every tile size and
//! thread count; `rust/tests/prop_invariants.rs` proves this against the
//! reference executor across random pipelines.
//!
//! **Fit is fused too** ([`FusedEngine::fit`]): instead of a separate
//! reference-executor pass, VocabGen tables are built *inside* the tiled
//! walk — each tile's values stream through the same fused chains and are
//! inserted in row order, so first-appearance indices are bit-identical to
//! [`Dag::fit`] (pinned by `prop_fused_fit_bit_identical_to_reference`).
//! [`FusedEngine::fit_accumulate`] extends the same walk across shards for
//! streaming/continuous fit, which is how the async ingest pipeline
//! ([`crate::dataio::ingest`]) keeps the fit phase overlapped with shard
//! I/O. A VocabGen upstream of another VocabGen is replayed through its
//! in-progress table; that is exact because indices are assigned once and
//! a tile's values are always inserted before any downstream VocabGen of
//! the same tile reads them. The one shape the tiled walk cannot pin — a
//! `VocabMap` inside another VocabGen's subgraph, whose lookups may go
//! out-of-vocabulary mid-stream — is detected at compile time and `fit`
//! falls back to the reference `Dag::fit` automatically (streaming
//! `fit_accumulate` refuses it with an error).
//!
//! [`BufferPool`] recycles `PackedBatch` buffers so the steady-state
//! train loop allocates nothing per batch ([`FusedEngine::execute_into`]
//! reuses the destination's capacity).

use std::sync::Mutex;

use crate::coordinator::packer::{PackLayout, PackedBatch};
use crate::error::{EtlError, Result};
use crate::etl::column::{Batch, ColType, Column};
use crate::etl::dag::{Dag, EtlState, Node, NodeId, SinkRole};
use crate::etl::ops::kernels;
use crate::etl::ops::vocab::VocabTable;
use crate::etl::ops::OpSpec;
use crate::trace::{self, kind as tkind};

/// Execution knobs.
#[derive(Debug, Clone)]
pub struct ExecConfig {
    /// Rows per tile (cache-resident working set).
    pub tile_rows: usize,
    /// Worker threads for row-range parallelism (1 = serial).
    pub threads: usize,
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig {
            tile_rows: 8192,
            threads: crate::util::pool::default_threads(),
        }
    }
}

/// One fused pipeline stage: a scalar kernel with frozen parameters.
/// Mirrors the operator pool (Table 1); the widening OneHot and the
/// binary Cartesian are represented at the [`SinkPlan`] level instead
/// (they change the dataflow shape, not just the value stream).
#[derive(Debug, Clone)]
enum Step {
    FillMissingF32(f32),
    Clamp { lo: f32, hi: f32 },
    Logarithm,
    Bucketize(Vec<f32>),
    Hex2Int,
    FillMissingI64(i64),
    Modulus(i64),
    SigridHash(i64),
    /// VocabGen replayed through the frozen table (apply-phase semantics:
    /// OOV maps to `table.len()`, matching `Dag::apply`).
    VocabReplay(String),
    VocabMap { key: String, oov: Option<i64> },
}

/// Where a chain's output lands in the packed batch.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Dest {
    /// Slot offset + slot width in the dense tensor (w > 1 for OneHot).
    Dense { off: usize, w: usize },
    Sparse(usize),
    Label,
}

fn role_of(dest: Dest) -> &'static str {
    match dest {
        Dest::Dense { .. } => "dense",
        Dest::Sparse(_) => "sparse",
        Dest::Label => "label",
    }
}

/// One lowered linear segment: a width-1 source column plus the unary
/// steps applied to it.
#[derive(Debug, Clone)]
struct Leaf {
    source: String,
    src_type: ColType,
    steps: Vec<Step>,
}

/// Compiled plan for one sink.
#[derive(Debug, Clone)]
enum SinkPlan {
    /// Linear unary chain fused end-to-end: source → steps → packed slot.
    Fused { name: String, leaf: Leaf, dest: Dest },
    /// Unary i64 chain terminated by a widening OneHot: each row scatters
    /// `k` indicator slots into its dense slot group.
    FusedOneHot { name: String, leaf: Leaf, k: usize, dest: Dest },
    /// Two i64 leaves crossed by one Cartesian, then a unary i64 tail.
    FusedCartesian {
        name: String,
        left: Leaf,
        right: Leaf,
        m: i64,
        post: Vec<Step>,
        dest: Dest,
    },
    /// Non-linear / unsupported subgraph: evaluated per tile with
    /// reference semantics, then scattered into the packed slot.
    General { name: String, node: usize, dest: Dest },
}

/// Compiled fit-phase plan for one VocabGen node (§3.1): how to produce
/// its input values per tile so the table is built inside the streaming
/// walk instead of a separate reference-executor pass.
#[derive(Debug, Clone)]
enum FitPlan {
    /// Linear unary chain ending in i64 — runs on the fused tile scratch.
    Chain { key: String, expected: usize, leaf: Leaf },
    /// Anything else — evaluated per tile with reference semantics.
    General { key: String, expected: usize, node: usize },
}

impl FitPlan {
    fn key_expected(&self) -> (&str, usize) {
        match self {
            FitPlan::Chain { key, expected, .. } => (key, *expected),
            FitPlan::General { key, expected, .. } => (key, *expected),
        }
    }
}

/// A compiled DAG + layout, executable tile-at-a-time straight into
/// trainer-layout buffers. `Send + Sync`: plain owned data, no `Rc`.
#[derive(Debug, Clone)]
pub struct FusedEngine {
    dag: Dag,
    layout: PackLayout,
    sinks: Vec<SinkPlan>,
    fit_plans: Vec<FitPlan>,
    /// True when some VocabGen's input subgraph contains a VocabMap: its
    /// lookups can go out-of-vocabulary mid-stream, so the tiled walk
    /// cannot reproduce `Dag::fit` and [`fit`](Self::fit) falls back to
    /// the reference executor (detected at compile time).
    fit_needs_reference: bool,
    pub cfg: ExecConfig,
    n_dense: usize,
    n_sparse: usize,
    fused: usize,
}

/// Reused per-worker tile scratch. The second pair backs the right-hand
/// leaf of fused Cartesian chains.
struct TileBufs {
    f: Vec<f32>,
    i: Vec<i64>,
    f2: Vec<f32>,
    i2: Vec<i64>,
}

impl TileBufs {
    fn new(tile: usize) -> TileBufs {
        TileBufs {
            f: Vec::with_capacity(tile),
            i: Vec::with_capacity(tile),
            f2: Vec::new(),
            i2: Vec::new(),
        }
    }
}

/// One tile's disjoint output region.
struct TileJob<'a> {
    start: usize,
    rows: usize,
    dense: &'a mut [f32],
    sparse: &'a mut [i32],
    labels: &'a mut [f32],
}

impl FusedEngine {
    /// Lower `dag` into fused per-sink chains packing into the layout
    /// derived from its sinks, plus per-VocabGen fit plans. Fails only if
    /// the DAG has no label sink (no [`PackLayout`]); every sink shape is
    /// executable — unsupported shapes fall back to the general per-tile
    /// evaluator.
    pub fn compile(dag: &Dag, cfg: ExecConfig) -> Result<FusedEngine> {
        let layout = PackLayout::of(dag)?;
        let n_dense = layout.n_dense_slots();
        let n_sparse = layout.sparse_cols.len();
        let mut sinks = Vec::new();
        let mut fused = 0usize;
        let (mut di, mut dslot, mut si) = (0usize, 0usize, 0usize);
        for (name, input, role) in dag.sinks() {
            let dest = match role {
                SinkRole::Dense => {
                    let w = layout.dense_widths[di];
                    di += 1;
                    let d = Dest::Dense { off: dslot, w };
                    dslot += w;
                    d
                }
                SinkRole::SparseIndex => {
                    let d = Dest::Sparse(si);
                    si += 1;
                    d
                }
                SinkRole::Label => {
                    // The packer reads only `layout.label_col` (the last
                    // declared label sink); mirror that.
                    if name != layout.label_col {
                        continue;
                    }
                    Dest::Label
                }
            };
            match lower_sink(dag, name, input, dest) {
                Some(plan) => {
                    fused += 1;
                    sinks.push(plan);
                }
                None => sinks.push(SinkPlan::General {
                    name: name.to_string(),
                    node: input.0,
                    dest,
                }),
            }
        }

        // Fit plans: one per VocabGen node, in node order — insertion
        // order is part of the table's first-appearance semantics.
        let mut fit_plans = Vec::new();
        let mut fit_needs_reference = false;
        for node in &dag.nodes {
            if let Node::Op { spec: OpSpec::VocabGen { expected }, inputs, vocab_key } = node {
                let key = vocab_key
                    .clone()
                    .ok_or_else(|| EtlError::Vocab("VocabGen has no vocab key".into()))?;
                fit_needs_reference |= subgraph_contains_vocab_map(dag, inputs[0].0);
                let plan = match lower_leaf(dag, inputs[0]) {
                    Some((leaf, ColType::I64)) => {
                        FitPlan::Chain { key, expected: *expected, leaf }
                    }
                    _ => FitPlan::General { key, expected: *expected, node: inputs[0].0 },
                };
                fit_plans.push(plan);
            }
        }

        Ok(FusedEngine {
            dag: dag.clone(),
            layout,
            sinks,
            fit_plans,
            fit_needs_reference,
            cfg,
            n_dense,
            n_sparse,
            fused,
        })
    }

    /// Number of sinks compiled to fully-fused chains (vs general).
    pub fn fused_sink_count(&self) -> usize {
        self.fused
    }

    /// Total sinks in the compiled plan.
    pub fn sink_count(&self) -> usize {
        self.sinks.len()
    }

    /// The pack layout this engine targets.
    pub fn layout(&self) -> &PackLayout {
        &self.layout
    }

    /// Fit phase fused into the tiled walk (§3.1): stream `input` in row
    /// tiles — serially, because vocabulary indices are assigned in
    /// first-appearance order and row order is part of that contract —
    /// and insert into every VocabGen table as values stream by. The
    /// result is bit-identical to [`Dag::fit`]; a VocabGen upstream of
    /// another VocabGen replays through its in-progress table, which is
    /// exact because indices are assigned once and each tile's values are
    /// inserted before any downstream VocabGen of the same tile reads
    /// them.
    pub fn fit(&self, input: &Batch) -> Result<EtlState> {
        // A VocabMap inside a fit subgraph can go OOV mid-stream (its
        // source table is complete only after the full pass); the tiled
        // walk cannot reproduce that, so such DAGs — detected at compile
        // time — fit through the reference executor instead.
        if self.fit_needs_reference {
            return self.dag.fit(input);
        }
        let mut state = EtlState::default();
        self.fit_accumulate(input, &mut state)?;
        Ok(state)
    }

    /// Streaming fit: like [`fit`](Self::fit) but accumulating into an
    /// existing state, so vocabularies build up across shards as the
    /// ingest pipeline delivers them (continuous-training fit). Errors
    /// for DAGs whose fit subgraphs contain a VocabMap (no streaming
    /// semantics exist for that shape — see [`fit`](Self::fit)).
    pub fn fit_accumulate(&self, input: &Batch, state: &mut EtlState) -> Result<()> {
        if self.fit_needs_reference {
            return Err(EtlError::Vocab(
                "a VocabGen input subgraph contains a VocabMap; streaming fit cannot \
                 reproduce the reference pass for this shape — use Dag::fit"
                    .into(),
            ));
        }
        // Every table exists even for zero-row inputs — the reference fit
        // emits empty tables too.
        for plan in &self.fit_plans {
            let (key, expected) = plan.key_expected();
            if !state.vocabs.contains_key(key) {
                state
                    .vocabs
                    .insert(key.to_string(), VocabTable::with_capacity(expected));
            }
        }
        let rows = input.rows();
        if rows == 0 || self.fit_plans.is_empty() {
            return Ok(());
        }
        let tile = self.cfg.tile_rows.max(1);
        let mut bufs = TileBufs::new(tile);
        let mut memo: Vec<Option<Column>> = vec![None; self.dag.nodes.len()];
        let mut start = 0usize;
        while start < rows {
            let n = tile.min(rows - start);
            let range = start..start + n;
            let mut sub: Option<Batch> = None;
            for slot in memo.iter_mut() {
                *slot = None;
            }
            for plan in &self.fit_plans {
                match plan {
                    FitPlan::Chain { key, leaf, .. } => {
                        run_leaf_steps(
                            input, state, &range, key, "fit", leaf, &mut bufs.f, &mut bufs.i,
                        )?;
                        let table = state.vocabs.get_mut(key).expect("inserted above");
                        for &v in &bufs.i {
                            table.get_or_insert(v);
                        }
                    }
                    FitPlan::General { key, node, .. } => {
                        if sub.is_none() {
                            sub = Some(input.slice_rows(range.clone()));
                        }
                        let col = eval_owned(
                            &self.dag,
                            *node,
                            sub.as_ref().expect("just set"),
                            state,
                            &mut memo,
                        )?;
                        let data = col.as_i64()?;
                        let table = state.vocabs.get_mut(key).expect("inserted above");
                        for &v in data {
                            table.get_or_insert(v);
                        }
                    }
                }
            }
            start += n;
        }
        Ok(())
    }

    /// Apply + pack in one pass, allocating a fresh batch.
    pub fn execute(&self, input: &Batch, state: &EtlState) -> Result<PackedBatch> {
        let mut out = empty_batch();
        self.execute_into(input, state, &mut out)?;
        Ok(out)
    }

    /// Apply + pack in one pass into `out`, reusing its buffers (zero
    /// steady-state allocation when `out` comes from a [`BufferPool`]).
    pub fn execute_into(&self, input: &Batch, state: &EtlState, out: &mut PackedBatch) -> Result<()> {
        let rows = input.rows();
        // Host-only engine span; records on every exit path via drop.
        let _span = trace::begin(tkind::FUSED_EXEC, trace::LANE_NONE, rows as u64);
        out.rows = rows;
        out.n_dense = self.n_dense;
        out.n_sparse = self.n_sparse;
        out.dense.clear();
        out.dense.resize(rows * self.n_dense, 0.0);
        out.sparse.clear();
        out.sparse.resize(rows * self.n_sparse, 0);
        out.labels.clear();
        out.labels.resize(rows, 0.0);
        if rows == 0 {
            return Ok(());
        }

        let tile = self.cfg.tile_rows.max(1);
        let n_tiles = rows.div_ceil(tile);
        let threads = self.cfg.threads.max(1).min(n_tiles);

        // Carve the output into disjoint per-tile mutable regions.
        let mut jobs: Vec<TileJob<'_>> = Vec::with_capacity(n_tiles);
        {
            let mut d: &mut [f32] = &mut out.dense;
            let mut s: &mut [i32] = &mut out.sparse;
            let mut l: &mut [f32] = &mut out.labels;
            let mut start = 0usize;
            while start < rows {
                let n = tile.min(rows - start);
                let (dh, dt) = std::mem::take(&mut d).split_at_mut(n * self.n_dense);
                d = dt;
                let (sh, st) = std::mem::take(&mut s).split_at_mut(n * self.n_sparse);
                s = st;
                let (lh, lt) = std::mem::take(&mut l).split_at_mut(n);
                l = lt;
                jobs.push(TileJob { start, rows: n, dense: dh, sparse: sh, labels: lh });
                start += n;
            }
        }

        if threads <= 1 {
            let mut bufs = TileBufs::new(tile);
            for job in jobs {
                self.run_tile(input, state, job, &mut bufs)?;
            }
            return Ok(());
        }

        // Row-range data parallelism: round-robin tiles over a scoped
        // worker pool; disjoint output regions need no synchronization.
        let mut groups: Vec<Vec<TileJob<'_>>> = (0..threads).map(|_| Vec::new()).collect();
        for (i, job) in jobs.into_iter().enumerate() {
            groups[i % threads].push(job);
        }
        let results: Vec<Result<()>> = std::thread::scope(|scope| {
            let handles: Vec<_> = groups
                .into_iter()
                .map(|group| {
                    scope.spawn(move || -> Result<()> {
                        let mut bufs = TileBufs::new(tile);
                        for job in group {
                            self.run_tile(input, state, job, &mut bufs)?;
                        }
                        Ok(())
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("fused-exec worker panicked"))
                .collect()
        });
        for r in results {
            r?;
        }
        Ok(())
    }

    /// Packed payload bytes this engine's layout produces for `rows` rows
    /// (dense f32 slots + sparse i32 indices + f32 labels).
    pub fn packed_bytes_for(&self, rows: usize) -> u64 {
        (rows * (self.n_dense + self.n_sparse + 1) * 4) as u64
    }

    /// Apply + pack in one pass **directly into an arena staging slot**
    /// (the zero-copy path of [`crate::devmem`]): tiles land in
    /// arena-backed device staging memory, each packed byte written
    /// exactly once, with the slot's byte reservation enforced and its
    /// allocation counters maintained. In the steady state the slot's
    /// buffers are already sized, so this allocates nothing.
    pub fn execute_into_slot(
        &self,
        input: &Batch,
        state: &EtlState,
        slot: &mut crate::devmem::StagingSlot,
    ) -> Result<()> {
        let need = self.packed_bytes_for(input.rows());
        slot.pack_into(need, |out| self.execute_into(input, state, out))
    }

    /// Execute with a recycled destination buffer from `pool`.
    pub fn execute_pooled(
        &self,
        input: &Batch,
        state: &EtlState,
        pool: &BufferPool,
    ) -> Result<PackedBatch> {
        let mut out = pool.take();
        self.execute_into(input, state, &mut out)?;
        Ok(out)
    }

    /// Run every sink chain over one tile.
    fn run_tile(
        &self,
        input: &Batch,
        state: &EtlState,
        mut job: TileJob<'_>,
        bufs: &mut TileBufs,
    ) -> Result<()> {
        let range = job.start..job.start + job.rows;
        // Lazily sliced tile sub-batch + memo, shared by general sinks.
        let mut sub: Option<Batch> = None;
        let mut memo: Vec<Option<Column>> = Vec::new();
        for sink in &self.sinks {
            match sink {
                SinkPlan::Fused { name, leaf, dest } => {
                    let is_f32 = run_leaf_steps(
                        input, state, &range, name, role_of(*dest), leaf, &mut bufs.f,
                        &mut bufs.i,
                    )?;
                    pack_tile(
                        name, *dest, is_f32, &bufs.f, &bufs.i, &mut job, self.n_dense,
                        self.n_sparse,
                    )?;
                }
                SinkPlan::FusedOneHot { name, leaf, k, dest } => {
                    let k = *k;
                    run_leaf_steps(
                        input, state, &range, name, role_of(*dest), leaf, &mut bufs.f,
                        &mut bufs.i,
                    )?;
                    let Dest::Dense { off, .. } = *dest else {
                        return Err(EtlError::Coord(format!(
                            "OneHot sink {name} compiled to a non-dense destination"
                        )));
                    };
                    let nd = self.n_dense;
                    for (r, &v) in bufs.i.iter().enumerate() {
                        let base = r * nd + off;
                        kernels::one_hot_into(v, k, &mut job.dense[base..base + k]);
                    }
                }
                SinkPlan::FusedCartesian { name, left, right, m, post, dest } => {
                    run_leaf_steps(
                        input, state, &range, name, role_of(*dest), left, &mut bufs.f,
                        &mut bufs.i,
                    )?;
                    run_leaf_steps(
                        input, state, &range, name, role_of(*dest), right, &mut bufs.f2,
                        &mut bufs.i2,
                    )?;
                    for (a, &b) in bufs.i.iter_mut().zip(bufs.i2.iter()) {
                        *a = kernels::cartesian(*a, b, *m);
                    }
                    let is_f32 = apply_steps(post, state, &mut bufs.f, &mut bufs.i, false)?;
                    pack_tile(
                        name, *dest, is_f32, &bufs.f, &bufs.i, &mut job, self.n_dense,
                        self.n_sparse,
                    )?;
                }
                SinkPlan::General { name, node, dest } => {
                    if sub.is_none() {
                        sub = Some(input.slice_rows(range.clone()));
                        memo = vec![None; self.dag.nodes.len()];
                    }
                    let col = eval_owned(
                        &self.dag,
                        *node,
                        sub.as_ref().expect("just set"),
                        state,
                        &mut memo,
                    )?;
                    write_general(name, &col, *dest, &mut job, self.n_dense, self.n_sparse)?;
                }
            }
        }
        Ok(())
    }
}

/// Load `leaf.source` rows `range` into the tile scratch and run the
/// leaf's fused steps stage-at-a-time (hex sources fuse straight through
/// the leading Hex2Int — no staging copy of the raw tokens). Returns true
/// when the live buffer is `f` (f32 values), false when it is `i` (i64).
#[allow(clippy::too_many_arguments)]
fn run_leaf_steps(
    input: &Batch,
    state: &EtlState,
    range: &std::ops::Range<usize>,
    name: &str,
    role: &'static str,
    leaf: &Leaf,
    f: &mut Vec<f32>,
    i: &mut Vec<i64>,
) -> Result<bool> {
    let col = input
        .get(&leaf.source)
        .ok_or_else(|| EtlError::Dag(format!("input batch missing column {:?}", leaf.source)))?;
    if col.coltype() != leaf.src_type {
        return Err(EtlError::TypeMismatch { expected: leaf.src_type, got: col.coltype() });
    }
    if col.width() != 1 {
        return Err(EtlError::Coord(format!(
            "{role} sink {name} has width {} (expected 1)",
            col.width()
        )));
    }

    let mut next_step = 0usize;
    let is_f32 = match col {
        Column::F32 { data, .. } => {
            f.clear();
            f.extend_from_slice(&data[range.clone()]);
            true
        }
        Column::I64 { data, .. } => {
            i.clear();
            i.extend_from_slice(&data[range.clone()]);
            false
        }
        Column::Hex8 { data } => {
            debug_assert!(matches!(leaf.steps.first(), Some(Step::Hex2Int)));
            i.clear();
            i.extend(data[range.clone()].iter().map(|&v| kernels::hex2int(v)));
            next_step = 1;
            false
        }
    };
    apply_steps(&leaf.steps[next_step..], state, f, i, is_f32)
}

/// Run fused steps stage-at-a-time over the cache-resident tile buffers.
/// `is_f32` names the buffer currently holding live values; the updated
/// flag is returned (Bucketize moves values from `f` to `i`).
fn apply_steps(
    steps: &[Step],
    state: &EtlState,
    f: &mut Vec<f32>,
    i: &mut Vec<i64>,
    mut is_f32: bool,
) -> Result<bool> {
    for step in steps {
        match step {
            Step::FillMissingF32(d) => {
                for v in f.iter_mut() {
                    *v = kernels::fill_missing_f32(*v, *d);
                }
            }
            Step::Clamp { lo, hi } => {
                for v in f.iter_mut() {
                    *v = kernels::clamp(*v, *lo, *hi);
                }
            }
            Step::Logarithm => {
                for v in f.iter_mut() {
                    *v = kernels::logarithm(*v);
                }
            }
            Step::Bucketize(borders) => {
                i.clear();
                i.extend(f.iter().map(|&x| kernels::bucketize(x, borders)));
                is_f32 = false;
            }
            Step::Hex2Int => {
                return Err(EtlError::Dag(
                    "fused Hex2Int on a non-source position (compiler bug)".into(),
                ));
            }
            Step::FillMissingI64(d) => {
                for v in i.iter_mut() {
                    *v = kernels::fill_missing_i64(*v, *d);
                }
            }
            Step::Modulus(m) => {
                for v in i.iter_mut() {
                    *v = kernels::modulus(*v, *m);
                }
            }
            Step::SigridHash(m) => {
                for v in i.iter_mut() {
                    *v = kernels::sigrid_hash(*v, *m);
                }
            }
            Step::VocabReplay(key) => {
                let table = state
                    .vocabs
                    .get(key)
                    .ok_or_else(|| EtlError::Vocab(format!("vocab {key:?} not fitted")))?;
                let oov = table.len() as i64;
                for v in i.iter_mut() {
                    *v = table.get(*v).map(|x| x as i64).unwrap_or(oov);
                }
            }
            Step::VocabMap { key, oov } => {
                let table = state.vocabs.get(key).ok_or_else(|| {
                    EtlError::op("VocabMap", "no fitted vocabulary table provided")
                })?;
                match oov {
                    Some(d) => {
                        for v in i.iter_mut() {
                            *v = table.get(*v).map(|x| x as i64).unwrap_or(*d);
                        }
                    }
                    None => {
                        for v in i.iter_mut() {
                            *v = table.get(*v).map(|x| x as i64).ok_or_else(|| {
                                EtlError::Vocab(format!(
                                    "value {v} not present in fitted vocabulary (size {})",
                                    table.len()
                                ))
                            })?;
                        }
                    }
                }
            }
        }
    }
    Ok(is_f32)
}

/// Scatter a finished width-1 tile into its packed destination slot.
fn pack_tile(
    name: &str,
    dest: Dest,
    is_f32: bool,
    f: &[f32],
    i: &[i64],
    job: &mut TileJob<'_>,
    n_dense: usize,
    n_sparse: usize,
) -> Result<()> {
    match dest {
        Dest::Dense { off, w } => {
            debug_assert!(is_f32 && w == 1);
            for (r, &v) in f.iter().enumerate() {
                job.dense[r * n_dense + off] = v;
            }
        }
        Dest::Label => {
            debug_assert!(is_f32);
            job.labels.copy_from_slice(f);
        }
        Dest::Sparse(ci) => {
            debug_assert!(!is_f32);
            for (r, &v) in i.iter().enumerate() {
                if v < 0 || v > i32::MAX as i64 {
                    return Err(EtlError::Coord(format!(
                        "sparse index {v} out of i32 range in {name}"
                    )));
                }
                job.sparse[r * n_sparse + ci] = v as i32;
            }
        }
    }
    Ok(())
}

fn empty_batch() -> PackedBatch {
    PackedBatch::default()
}

/// Walk back from `from` through sinks and unary ops, collecting
/// `(spec, vocab_key)` in sink-to-source order. Returns the collected ops
/// plus the index of the node where the walk stopped (a source or a
/// non-unary op).
fn walk_unary(dag: &Dag, from: NodeId) -> (Vec<(&OpSpec, Option<&String>)>, usize) {
    let mut rev: Vec<(&OpSpec, Option<&String>)> = Vec::new();
    let mut cur = from;
    loop {
        match &dag.nodes[cur.0] {
            Node::Sink { input, .. } => cur = *input,
            Node::Op { spec, inputs, vocab_key } if inputs.len() == 1 => {
                rev.push((spec, vocab_key.as_ref()));
                cur = inputs[0];
            }
            _ => return (rev, cur.0),
        }
    }
}

/// Forward type-checked lowering of collected unary ops (sink-to-source
/// order) into fused [`Step`]s; returns the steps plus the chain's output
/// type. The widening OneHot never lowers here — it changes the dataflow
/// shape and is handled at the [`SinkPlan`] level by the caller.
fn lower_steps(
    rev: &[(&OpSpec, Option<&String>)],
    src_type: ColType,
) -> Option<(Vec<Step>, ColType)> {
    let mut ty = src_type;
    let mut steps = Vec::with_capacity(rev.len());
    for &(spec, key) in rev.iter().rev() {
        let step = match (spec, ty) {
            (OpSpec::FillMissing { dense_default, .. }, ColType::F32) => {
                Step::FillMissingF32(*dense_default)
            }
            (OpSpec::FillMissing { sparse_default, .. }, ColType::I64) => {
                Step::FillMissingI64(*sparse_default)
            }
            (OpSpec::Clamp { lo, hi }, ColType::F32) => Step::Clamp { lo: *lo, hi: *hi },
            (OpSpec::Logarithm, ColType::F32) => Step::Logarithm,
            (OpSpec::Bucketize { borders }, ColType::F32) => {
                ty = ColType::I64;
                Step::Bucketize(borders.clone())
            }
            (OpSpec::Hex2Int, ColType::Hex8) => {
                ty = ColType::I64;
                Step::Hex2Int
            }
            (OpSpec::Modulus { m }, ColType::I64) => Step::Modulus(*m),
            (OpSpec::SigridHash { m }, ColType::I64) => Step::SigridHash(*m),
            (OpSpec::VocabGen { .. }, ColType::I64) => Step::VocabReplay(key?.clone()),
            (OpSpec::VocabMap { oov }, ColType::I64) => {
                Step::VocabMap { key: key?.clone(), oov: *oov }
            }
            // OneHot (widening), type mismatches → not lowerable here.
            _ => return None,
        };
        steps.push(step);
    }
    Some((steps, ty))
}

/// Lower a strictly-unary subgraph rooted at `from` into a [`Leaf`];
/// `None` if the walk hits anything but a source (nested binary op,
/// OneHot, …) or a step fails to type-check.
fn lower_leaf(dag: &Dag, from: NodeId) -> Option<(Leaf, ColType)> {
    let (rev, stop) = walk_unary(dag, from);
    let Node::Source { field, coltype } = &dag.nodes[stop] else {
        return None;
    };
    let (steps, ty) = lower_steps(&rev, *coltype)?;
    // Hex sources are only fusable through a leading Hex2Int.
    if *coltype == ColType::Hex8 && !matches!(steps.first(), Some(Step::Hex2Int)) {
        return None;
    }
    Some((Leaf { source: field.clone(), src_type: *coltype, steps }, ty))
}

fn dest_accepts(dest: Dest, ty: ColType) -> bool {
    match dest {
        Dest::Dense { w, .. } => ty == ColType::F32 && w == 1,
        Dest::Label => ty == ColType::F32,
        Dest::Sparse(_) => ty == ColType::I64,
    }
}

/// Does any node reachable from `root` apply a VocabMap? (Fit subgraphs
/// containing one cannot stream — see [`FusedEngine::fit`].)
fn subgraph_contains_vocab_map(dag: &Dag, root: usize) -> bool {
    let mut seen = vec![false; dag.nodes.len()];
    let mut stack = vec![root];
    while let Some(i) = stack.pop() {
        if seen[i] {
            continue;
        }
        seen[i] = true;
        match &dag.nodes[i] {
            Node::Op { spec, inputs, .. } => {
                if matches!(spec, OpSpec::VocabMap { .. }) {
                    return true;
                }
                stack.extend(inputs.iter().map(|n| n.0));
            }
            Node::Sink { input, .. } => stack.push(input.0),
            Node::Source { .. } => {}
        }
    }
    false
}

/// Lower one sink subgraph into a fused plan, or `None` for the general
/// per-tile fallback. Fusable shapes: a linear unary chain; the same
/// chain terminated by a widening OneHot into the sink's dense slot
/// group; or two linear i64 chains crossed by exactly one Cartesian with
/// a unary i64 tail.
fn lower_sink(dag: &Dag, name: &str, from: NodeId, dest: Dest) -> Option<SinkPlan> {
    // Resolve sink aliasing to the first computational node.
    let mut cur = from;
    while let Node::Sink { input, .. } = &dag.nodes[cur.0] {
        cur = *input;
    }

    // Terminal widening OneHot: the rest must be a unary i64 leaf filling
    // the sink's whole slot group. (OneHot over a binary op falls through
    // to the general path via lower_leaf's walk stopping short.)
    if let Node::Op { spec: OpSpec::OneHot { k }, inputs, .. } = &dag.nodes[cur.0] {
        let (leaf, ty) = lower_leaf(dag, inputs[0])?;
        if ty != ColType::I64 || !matches!(dest, Dest::Dense { w, .. } if w == *k) {
            return None;
        }
        return Some(SinkPlan::FusedOneHot { name: name.to_string(), leaf, k: *k, dest });
    }

    // Linear unary chain straight from a source.
    if let Some((leaf, ty)) = lower_leaf(dag, cur) {
        if !dest_accepts(dest, ty) {
            return None;
        }
        return Some(SinkPlan::Fused { name: name.to_string(), leaf, dest });
    }

    // Not purely unary: exactly one Cartesian with a unary i64 tail?
    let (rev, stop) = walk_unary(dag, cur);
    let Node::Op { spec: OpSpec::Cartesian { m }, inputs, .. } = &dag.nodes[stop] else {
        return None;
    };
    let (left, lt) = lower_leaf(dag, inputs[0])?;
    let (right, rt) = lower_leaf(dag, inputs[1])?;
    if lt != ColType::I64 || rt != ColType::I64 {
        return None;
    }
    let (post, ty) = lower_steps(&rev, ColType::I64)?;
    if !dest_accepts(dest, ty) {
        return None;
    }
    Some(SinkPlan::FusedCartesian {
        name: name.to_string(),
        left,
        right,
        m: *m,
        post,
        dest,
    })
}

/// Reference-semantics evaluation of one node over a (tile) batch, memoized
/// per tile. Mirrors `Dag::apply`'s `eval_node` (including the VocabGen
/// replay-through-frozen-table apply semantics) without `Rc`, so the
/// engine stays `Send`.
fn eval_owned(
    dag: &Dag,
    i: usize,
    batch: &Batch,
    state: &EtlState,
    memo: &mut Vec<Option<Column>>,
) -> Result<Column> {
    if let Some(col) = &memo[i] {
        return Ok(col.clone());
    }
    let col = match &dag.nodes[i] {
        Node::Source { field, .. } => batch
            .get(field)
            .cloned()
            .ok_or_else(|| EtlError::Dag(format!("input batch missing column {field:?}")))?,
        Node::Op { spec, inputs, vocab_key } => {
            let mut cols = Vec::with_capacity(inputs.len());
            for &NodeId(j) in inputs {
                cols.push(eval_owned(dag, j, batch, state, memo)?);
            }
            let refs: Vec<&Column> = cols.iter().collect();
            let vocab = vocab_key.as_ref().and_then(|k| state.vocabs.get(k));
            match spec {
                OpSpec::VocabGen { .. } => {
                    let key = vocab_key
                        .as_ref()
                        .ok_or_else(|| EtlError::Vocab("VocabGen has no vocab key".into()))?;
                    let table = state
                        .vocabs
                        .get(key)
                        .ok_or_else(|| EtlError::Vocab(format!("vocab {key:?} not fitted")))?;
                    let data = refs[0].as_i64()?;
                    Column::i64(crate::etl::ops::vocab::vocab_map_oov(
                        data,
                        table,
                        table.len() as i64,
                    ))
                }
                _ => spec.apply(&refs, vocab)?,
            }
        }
        Node::Sink { input: NodeId(j), .. } => eval_owned(dag, *j, batch, state, memo)?,
    };
    memo[i] = Some(col.clone());
    Ok(col)
}

/// Scatter a general sink's tile column into the packed destination, with
/// the packer's exact shape/range checks (width-aware for dense sinks).
fn write_general(
    name: &str,
    col: &Column,
    dest: Dest,
    job: &mut TileJob<'_>,
    n_dense: usize,
    n_sparse: usize,
) -> Result<()> {
    match dest {
        Dest::Dense { off, w } => {
            let data = col.as_f32()?;
            if col.width() != w {
                return Err(EtlError::Coord(format!(
                    "dense sink {name} has width {} (expected {w})",
                    col.width()
                )));
            }
            for r in 0..job.rows {
                job.dense[r * n_dense + off..r * n_dense + off + w]
                    .copy_from_slice(&data[r * w..(r + 1) * w]);
            }
        }
        Dest::Label => {
            let data = col.as_f32()?;
            if data.len() != job.rows {
                return Err(EtlError::Coord(format!(
                    "label sink {name} has width {} (expected 1)",
                    col.width()
                )));
            }
            job.labels.copy_from_slice(data);
        }
        Dest::Sparse(ci) => {
            let data = col.as_i64()?;
            if col.width() != 1 {
                return Err(EtlError::Coord(format!(
                    "sparse sink {name} has width {} (expected 1)",
                    col.width()
                )));
            }
            for (r, &v) in data.iter().enumerate() {
                if v < 0 || v > i32::MAX as i64 {
                    return Err(EtlError::Coord(format!(
                        "sparse index {v} out of i32 range in {name}"
                    )));
                }
                job.sparse[r * n_sparse + ci] = v as i32;
            }
        }
    }
    Ok(())
}

/// A recycling pool of [`PackedBatch`] buffers: `take` a buffer, fill it
/// with [`FusedEngine::execute_into`], and `put` it back once consumed —
/// the steady-state loop then allocates nothing per batch.
#[derive(Debug, Default)]
pub struct BufferPool {
    free: Mutex<Vec<PackedBatch>>,
}

impl BufferPool {
    pub fn new() -> BufferPool {
        BufferPool::default()
    }

    /// Pop a recycled buffer (or a fresh empty one).
    pub fn take(&self) -> PackedBatch {
        self.free
            .lock()
            .expect("buffer pool poisoned")
            .pop()
            .unwrap_or_else(empty_batch)
    }

    /// Return a buffer for reuse.
    pub fn put(&self, batch: PackedBatch) {
        self.free.lock().expect("buffer pool poisoned").push(batch);
    }

    /// Buffers currently available.
    pub fn available(&self) -> usize {
        self.free.lock().expect("buffer pool poisoned").len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::packer::pack;
    use crate::dataio::dataset::DatasetSpec;
    use crate::etl::column::pack_hex;
    use crate::etl::pipelines::{build, PipelineKind};

    fn assert_packed_eq(a: &PackedBatch, b: &PackedBatch) {
        assert_eq!((a.rows, a.n_dense, a.n_sparse), (b.rows, b.n_dense, b.n_sparse));
        assert_eq!(a.sparse, b.sparse);
        assert_eq!(a.labels.len(), b.labels.len());
        for (x, y) in a.labels.iter().zip(&b.labels) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.dense.len(), b.dense.len());
        for (x, y) in a.dense.iter().zip(&b.dense) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    fn reference(dag: &Dag, batch: &Batch, state: &EtlState) -> PackedBatch {
        let out = dag.apply(batch, state).unwrap();
        let layout = PackLayout::of(dag).unwrap();
        pack(&out, &layout).unwrap()
    }

    #[test]
    fn engine_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<FusedEngine>();
        assert_send_sync::<BufferPool>();
    }

    #[test]
    fn fused_matches_reference_on_all_canned_pipelines() {
        let mut spec = DatasetSpec::dataset_i(0.002);
        spec.shards = 1;
        let shard = spec.shard(0, 7);
        for kind in PipelineKind::all() {
            let dag = build(kind, &spec.schema);
            let state = dag.fit(&shard).unwrap();
            let want = reference(&dag, &shard, &state);
            for (tile, threads) in [(shard.rows() + 1, 1), (1000, 1), (333, 4), (1, 2)] {
                let engine =
                    FusedEngine::compile(&dag, ExecConfig { tile_rows: tile, threads }).unwrap();
                // All canned-pipeline sinks are linear chains → fully fused.
                assert_eq!(engine.fused_sink_count(), engine.sink_count(), "{}", kind.label());
                let got = engine.execute(&shard, &state).unwrap();
                assert_packed_eq(&want, &got);
            }
        }
    }

    #[test]
    fn fused_fit_matches_reference_on_all_canned_pipelines() {
        let mut spec = DatasetSpec::dataset_i(0.002);
        spec.shards = 1;
        let shard = spec.shard(0, 9);
        for kind in PipelineKind::all() {
            let dag = build(kind, &spec.schema);
            let want = dag.fit(&shard).unwrap();
            for tile in [1, 97, shard.rows() + 1] {
                let engine =
                    FusedEngine::compile(&dag, ExecConfig { tile_rows: tile, threads: 2 })
                        .unwrap();
                let got = engine.fit(&shard).unwrap();
                assert_eq!(want, got, "{} tile={tile}", kind.label());
            }
        }
    }

    #[test]
    fn fit_with_vocab_map_in_fit_subgraph_falls_back_to_reference() {
        // VocabGen "kj" consumes VocabMap("ky") over a DIFFERENT column:
        // the reference fit resolves every lookup through ky's complete
        // table, but a tiled walk would see x-values before y has supplied
        // them (x is y reversed). The engine must detect the shape and
        // fall back, staying bit-identical; streaming fit refuses it.
        let mut dag = Dag::new("map-in-fit");
        let l = dag.source("label", ColType::F32);
        dag.sink("label", l, SinkRole::Label);
        let y = dag.source("y", ColType::I64);
        let gy = dag.vocab_op(OpSpec::VocabGen { expected: 8 }, y, "ky");
        dag.sink("sparse0", gy, SinkRole::SparseIndex);
        let x = dag.source("x", ColType::I64);
        let mx = dag.vocab_op(OpSpec::VocabMap { oov: None }, x, "ky");
        let gj = dag.vocab_op(OpSpec::VocabGen { expected: 8 }, mx, "kj");
        dag.sink("sparse1", gj, SinkRole::SparseIndex);

        let mut batch = Batch::new();
        batch.push("label", Column::f32(vec![0.0; 4])).unwrap();
        batch.push("y", Column::i64(vec![10, 20, 30, 40])).unwrap();
        batch.push("x", Column::i64(vec![40, 30, 20, 10])).unwrap();

        let want = dag.fit(&batch).unwrap();
        // Single-row tiles would hit the OOV without the fallback.
        let engine = FusedEngine::compile(&dag, ExecConfig { tile_rows: 1, threads: 1 }).unwrap();
        assert_eq!(engine.fit(&batch).unwrap(), want);
        let mut acc = EtlState::default();
        assert!(engine.fit_accumulate(&batch, &mut acc).is_err());
    }

    #[test]
    fn fit_accumulate_streams_across_shards() {
        // Fitting shard-by-shard through the tiled walk equals fitting the
        // concatenated stream in one pass (the reference `Dag::fit`).
        let mut spec = DatasetSpec::dataset_i(0.002);
        spec.shards = 3;
        let dag = build(PipelineKind::II, &spec.schema);
        let engine = FusedEngine::compile(&dag, ExecConfig { tile_rows: 128, threads: 1 }).unwrap();
        let mut streamed = EtlState::default();
        let mut concat = Batch::new();
        for i in 0..spec.shards {
            let shard = spec.shard(i, 4);
            engine.fit_accumulate(&shard, &mut streamed).unwrap();
            if concat.columns.is_empty() {
                concat = shard;
            } else {
                for ((_, dst), (_, src)) in concat.columns.iter_mut().zip(&shard.columns) {
                    match (dst, src) {
                        (Column::F32 { data: d, .. }, Column::F32 { data: s, .. }) => {
                            d.extend_from_slice(s)
                        }
                        (Column::Hex8 { data: d }, Column::Hex8 { data: s }) => {
                            d.extend_from_slice(s)
                        }
                        (Column::I64 { data: d, .. }, Column::I64 { data: s, .. }) => {
                            d.extend_from_slice(s)
                        }
                        _ => panic!("shard column types diverged"),
                    }
                }
            }
        }
        let whole = dag.fit(&concat).unwrap();
        assert_eq!(streamed, whole);
        // And the accumulated state is usable for apply.
        let packed = engine.execute(&spec.shard(0, 4), &streamed).unwrap();
        assert!(packed.rows > 0);
    }

    #[test]
    fn cartesian_diamond_fuses_and_matches_reference() {
        let mut dag = Dag::new("diamond");
        let l = dag.source("label", ColType::F32);
        dag.sink("label", l, SinkRole::Label);
        let d = dag.source("x", ColType::F32);
        let bk = dag.op(OpSpec::Bucketize { borders: vec![0.5, 2.0] }, &[d]);
        dag.sink("bucket", bk, SinkRole::SparseIndex);
        let c0 = dag.source("c0", ColType::Hex8);
        let c1 = dag.source("c1", ColType::Hex8);
        let h0 = dag.op(OpSpec::Hex2Int, &[c0]);
        let h1 = dag.op(OpSpec::Hex2Int, &[c1]);
        let cross = dag.op(OpSpec::Cartesian { m: 5000 }, &[h0, h1]);
        dag.sink("cross", cross, SinkRole::SparseIndex);

        let mut batch = Batch::new();
        batch.push("label", Column::f32(vec![1.0, 0.0, 1.0])).unwrap();
        batch.push("x", Column::f32(vec![0.1, f32::NAN, 7.0])).unwrap();
        batch
            .push("c0", Column::hex8(vec![pack_hex("1a3f").unwrap(); 3]))
            .unwrap();
        batch
            .push("c1", Column::hex8(vec![pack_hex("00ff").unwrap(); 3]))
            .unwrap();

        let state = EtlState::default();
        let want = reference(&dag, &batch, &state);
        let engine = FusedEngine::compile(&dag, ExecConfig { tile_rows: 2, threads: 2 }).unwrap();
        // The Cartesian diamond now fuses as a two-leaf chain.
        assert_eq!(engine.fused_sink_count(), engine.sink_count());
        let got = engine.execute(&batch, &state).unwrap();
        assert_packed_eq(&want, &got);
    }

    fn cartesian_post_dag() -> Dag {
        // hex ⊗ hex → Cartesian → SigridHash → Modulus → sparse sink,
        // plus a vocab-replayed left leaf to exercise stateful leaves.
        let mut dag = Dag::new("cart-post");
        let l = dag.source("label", ColType::F32);
        dag.sink("label", l, SinkRole::Label);
        let c0 = dag.source("c0", ColType::Hex8);
        let c1 = dag.source("c1", ColType::Hex8);
        let h0 = dag.op(OpSpec::Hex2Int, &[c0]);
        let m0 = dag.op(OpSpec::Modulus { m: 64 }, &[h0]);
        let g0 = dag.vocab_op(OpSpec::VocabGen { expected: 8 }, m0, "left");
        let h1 = dag.op(OpSpec::Hex2Int, &[c1]);
        let cross = dag.op(OpSpec::Cartesian { m: 100_000 }, &[g0, h1]);
        let sh = dag.op(OpSpec::SigridHash { m: 4096 }, &[cross]);
        let md = dag.op(OpSpec::Modulus { m: 1000 }, &[sh]);
        dag.sink("cross", md, SinkRole::SparseIndex);
        dag
    }

    fn cartesian_post_batch(rows: usize) -> Batch {
        let mut batch = Batch::new();
        batch
            .push("label", Column::f32((0..rows).map(|r| (r % 2) as f32).collect()))
            .unwrap();
        let toks: Vec<u64> = (0..rows)
            .map(|r| crate::dataio::synth::pack_hex_u32((r * 2654435761) as u32))
            .collect();
        batch.push("c0", Column::hex8(toks.clone())).unwrap();
        batch.push("c1", Column::hex8(toks.into_iter().rev().collect())).unwrap();
        batch
    }

    #[test]
    fn cartesian_with_post_ops_fuses_across_tile_shapes() {
        let dag = cartesian_post_dag();
        let batch = cartesian_post_batch(37);
        let state = dag.fit(&batch).unwrap();
        let want = reference(&dag, &batch, &state);
        // Single-row tiles, odd tiles, one big tile.
        for (tile, threads) in [(1, 1), (1, 3), (5, 2), (64, 1)] {
            let engine =
                FusedEngine::compile(&dag, ExecConfig { tile_rows: tile, threads }).unwrap();
            assert_eq!(engine.fused_sink_count(), engine.sink_count());
            let got = engine.execute(&batch, &state).unwrap();
            assert_packed_eq(&want, &got);
        }
        // Zero-row input (columns present, empty tiles): both sides agree.
        let empty = cartesian_post_batch(0);
        let engine = FusedEngine::compile(&dag, ExecConfig::default()).unwrap();
        let got = engine.execute(&empty, &state).unwrap();
        assert_eq!(got.rows, 0);
        assert_packed_eq(&reference(&dag, &empty, &state), &got);
    }

    #[test]
    fn onehot_fused_chain_matches_reference() {
        // x → Bucketize → OneHot(4) widening into the dense tensor, next
        // to an ordinary width-1 dense chain (interleaving check).
        let mut dag = Dag::new("onehot");
        let l = dag.source("label", ColType::F32);
        dag.sink("label", l, SinkRole::Label);
        let x = dag.source("x", ColType::F32);
        let bk = dag.op(OpSpec::Bucketize { borders: vec![0.0, 1.0, 5.0] }, &[x]);
        let oh = dag.op(OpSpec::OneHot { k: 4 }, &[bk]);
        dag.sink("onehot", oh, SinkRole::Dense);
        let y = dag.source("y", ColType::F32);
        let cl = dag.op(OpSpec::Clamp { lo: 0.0, hi: 1.0 }, &[y]);
        dag.sink("dense1", cl, SinkRole::Dense);

        let mut batch = Batch::new();
        batch
            .push("label", Column::f32(vec![1.0, 0.0, 1.0, 0.0, 1.0]))
            .unwrap();
        batch
            .push("x", Column::f32(vec![-1.0, 0.5, 3.0, 9.0, f32::NAN]))
            .unwrap();
        batch
            .push("y", Column::f32(vec![0.1, 0.2, 0.3, 0.4, 2.5]))
            .unwrap();

        let state = EtlState::default();
        let want = reference(&dag, &batch, &state);
        assert_eq!(want.n_dense, 5); // 4 OneHot slots + 1 plain dense
        // Single-row tiles, a tile split mid-batch, and one big tile.
        for (tile, threads) in [(1, 1), (2, 2), (64, 1)] {
            let engine =
                FusedEngine::compile(&dag, ExecConfig { tile_rows: tile, threads }).unwrap();
            assert_eq!(engine.fused_sink_count(), engine.sink_count());
            let got = engine.execute(&batch, &state).unwrap();
            assert_packed_eq(&want, &got);
        }
        // Empty-tile edge: zero rows with the right columns.
        let mut empty = Batch::new();
        empty.push("label", Column::f32(vec![])).unwrap();
        empty.push("x", Column::f32(vec![])).unwrap();
        empty.push("y", Column::f32(vec![])).unwrap();
        let engine = FusedEngine::compile(&dag, ExecConfig::default()).unwrap();
        let got = engine.execute(&empty, &state).unwrap();
        assert_eq!((got.rows, got.n_dense), (0, 5));
    }

    #[test]
    fn nested_cartesian_takes_general_path() {
        // (a ⊗ b) ⊗ c is not a fusable shape — general fallback, still
        // bit-identical to the reference executor.
        let mut dag = Dag::new("nested");
        let l = dag.source("label", ColType::F32);
        dag.sink("label", l, SinkRole::Label);
        let a = dag.source("a", ColType::I64);
        let b = dag.source("b", ColType::I64);
        let c = dag.source("c", ColType::I64);
        let x = dag.op(OpSpec::Cartesian { m: 1000 }, &[a, b]);
        let y = dag.op(OpSpec::Cartesian { m: 1000 }, &[x, c]);
        dag.sink("cross", y, SinkRole::SparseIndex);

        let mut batch = Batch::new();
        batch.push("label", Column::f32(vec![0.0, 1.0, 1.0])).unwrap();
        batch.push("a", Column::i64(vec![1, 2, 3])).unwrap();
        batch.push("b", Column::i64(vec![4, 5, 6])).unwrap();
        batch.push("c", Column::i64(vec![7, 8, 9])).unwrap();

        let state = EtlState::default();
        let want = reference(&dag, &batch, &state);
        let engine = FusedEngine::compile(&dag, ExecConfig { tile_rows: 2, threads: 2 }).unwrap();
        assert!(engine.fused_sink_count() < engine.sink_count());
        let got = engine.execute(&batch, &state).unwrap();
        assert_packed_eq(&want, &got);
    }

    #[test]
    fn empty_batch_executes() {
        let spec = DatasetSpec::dataset_i(0.001);
        let dag = build(PipelineKind::I, &spec.schema);
        let engine = FusedEngine::compile(&dag, ExecConfig::default()).unwrap();
        let got = engine.execute(&Batch::new(), &EtlState::default());
        // An empty batch has no columns at all — sources are missing.
        // A zero-row batch with the right columns works:
        let zero = spec.shard(9999, 42);
        if zero.rows() == 0 && !zero.columns.is_empty() {
            let p = engine.execute(&zero, &EtlState::default()).unwrap();
            assert_eq!(p.rows, 0);
        }
        assert!(got.is_err() || got.unwrap().rows == 0);
    }

    #[test]
    fn oov_replay_matches_reference_across_shards() {
        // Fit on shard 0, apply to shard 1 (unseen tokens → OOV index).
        let mut spec = DatasetSpec::dataset_i(0.002);
        spec.shards = 2;
        let dag = build(PipelineKind::II, &spec.schema);
        let state = dag.fit(&spec.shard(0, 42)).unwrap();
        let other = spec.shard(1, 42);
        let want = reference(&dag, &other, &state);
        let engine = FusedEngine::compile(&dag, ExecConfig { tile_rows: 777, threads: 3 }).unwrap();
        let got = engine.execute(&other, &state).unwrap();
        assert_packed_eq(&want, &got);
    }

    #[test]
    fn negative_sparse_index_is_rejected_like_pack() {
        let mut dag = Dag::new("neg");
        let l = dag.source("label", ColType::F32);
        dag.sink("label", l, SinkRole::Label);
        let s = dag.source("s", ColType::I64);
        dag.sink("sparse0", s, SinkRole::SparseIndex);
        let mut batch = Batch::new();
        batch.push("label", Column::f32(vec![0.0, 1.0])).unwrap();
        batch.push("s", Column::i64(vec![3, -1])).unwrap();
        let engine = FusedEngine::compile(&dag, ExecConfig::default()).unwrap();
        let err = engine.execute(&batch, &EtlState::default()).unwrap_err();
        assert!(err.to_string().contains("out of i32 range"), "{err}");
    }

    #[test]
    fn execute_into_slot_is_bit_identical_and_reuses_slot_memory() {
        use crate::devmem::DeviceArena;

        let mut spec = DatasetSpec::dataset_i(0.001);
        spec.shards = 1;
        let shard = spec.shard(0, 3);
        let dag = build(PipelineKind::II, &spec.schema);
        let engine = FusedEngine::compile(&dag, ExecConfig::default()).unwrap();
        let state = engine.fit(&shard).unwrap();
        let want = engine.execute(&shard, &state).unwrap();
        assert_eq!(engine.packed_bytes_for(shard.rows()), want.bytes());

        let arena = DeviceArena::with_slots(1);
        let mut ptr = std::ptr::null();
        for round in 0..3 {
            let mut slot = arena.acquire().unwrap();
            engine.execute_into_slot(&shard, &state, &mut slot).unwrap();
            assert_packed_eq(&want, slot.batch());
            assert_eq!(slot.packed_bytes(), want.bytes());
            if round == 0 {
                ptr = slot.batch().dense.as_ptr();
            } else {
                // Same allocation every round: packed in place, zero
                // steady-state allocation.
                assert_eq!(slot.batch().dense.as_ptr(), ptr);
            }
            arena.release(slot).unwrap();
        }
        let stats = arena.stats();
        assert_eq!(stats.steady_allocs, 0, "{stats:?}");
        assert_eq!(stats.packed_bytes, 3 * want.bytes());
    }

    #[test]
    fn buffer_pool_recycles_capacity() {
        let mut spec = DatasetSpec::dataset_i(0.001);
        spec.shards = 1;
        let shard = spec.shard(0, 3);
        let dag = build(PipelineKind::I, &spec.schema);
        let engine = FusedEngine::compile(&dag, ExecConfig::default()).unwrap();
        let state = EtlState::default();
        let pool = BufferPool::new();
        let b1 = engine.execute_pooled(&shard, &state, &pool).unwrap();
        let ptr = b1.dense.as_ptr();
        let cap = b1.dense.capacity();
        pool.put(b1);
        assert_eq!(pool.available(), 1);
        let b2 = engine.execute_pooled(&shard, &state, &pool).unwrap();
        // Same allocation reused: no steady-state allocation.
        assert_eq!(b2.dense.as_ptr(), ptr);
        assert_eq!(b2.dense.capacity(), cap);
        assert_eq!(pool.available(), 0);
    }
}
