//! Dataset schemas: which features exist, their kinds, and the canonical
//! Criteo-style layouts used throughout the evaluation (§4.1.1).

use crate::etl::column::ColType;

/// Feature kind as the paper partitions them (§2.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FeatureKind {
    /// Well-defined numeric attribute (user age, item price, counts).
    Dense,
    /// High-cardinality categorical token (user id, ad id) as hex string.
    Sparse,
    /// Binary click label.
    Label,
}

/// One field of the input schema.
#[derive(Debug, Clone, PartialEq)]
pub struct FieldSpec {
    pub name: String,
    pub kind: FeatureKind,
    /// Physical type of the raw column on disk.
    pub raw_type: ColType,
    /// Approximate cardinality for sparse features (drives vocab sizing
    /// and state placement in the planner).
    pub cardinality: Option<u64>,
}

/// A dataset schema: ordered fields, with convenience accessors.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Schema {
    pub fields: Vec<FieldSpec>,
}

impl Schema {
    pub fn dense_count(&self) -> usize {
        self.count(FeatureKind::Dense)
    }

    pub fn sparse_count(&self) -> usize {
        self.count(FeatureKind::Sparse)
    }

    fn count(&self, kind: FeatureKind) -> usize {
        self.fields.iter().filter(|f| f.kind == kind).count()
    }

    pub fn dense_fields(&self) -> impl Iterator<Item = &FieldSpec> {
        self.fields.iter().filter(|f| f.kind == FeatureKind::Dense)
    }

    pub fn sparse_fields(&self) -> impl Iterator<Item = &FieldSpec> {
        self.fields.iter().filter(|f| f.kind == FeatureKind::Sparse)
    }

    pub fn field(&self, name: &str) -> Option<&FieldSpec> {
        self.fields.iter().find(|f| f.name == name)
    }

    /// Raw bytes per row: f32 dense, 8-byte hex tokens, 4-byte label.
    pub fn raw_row_bytes(&self) -> usize {
        self.fields
            .iter()
            .map(|f| match f.raw_type {
                ColType::F32 => 4,
                ColType::Hex8 => 8,
                ColType::I64 => 8,
            })
            .sum()
    }

    /// Criteo Kaggle layout (Dataset-I): 1 label + 13 dense + 26 sparse.
    pub fn criteo_kaggle() -> Schema {
        Schema::tabular("criteo", 13, 26, 2_000_000)
    }

    /// Synthetic wide layout (Dataset-II): 504 dense + 42 sparse (§4.1.1).
    pub fn synthetic_wide() -> Schema {
        Schema::tabular("wide", 504, 42, 500_000)
    }

    /// Generic label + N dense + M sparse tabular schema.
    pub fn tabular(prefix: &str, dense: usize, sparse: usize, cardinality: u64) -> Schema {
        let mut fields = Vec::with_capacity(1 + dense + sparse);
        fields.push(FieldSpec {
            name: format!("{prefix}_label"),
            kind: FeatureKind::Label,
            raw_type: ColType::F32,
            cardinality: None,
        });
        for i in 0..dense {
            fields.push(FieldSpec {
                name: format!("{prefix}_i{i}"),
                kind: FeatureKind::Dense,
                raw_type: ColType::F32,
                cardinality: None,
            });
        }
        for i in 0..sparse {
            fields.push(FieldSpec {
                name: format!("{prefix}_c{i}"),
                kind: FeatureKind::Sparse,
                raw_type: ColType::Hex8,
                cardinality: Some(cardinality),
            });
        }
        Schema { fields }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn criteo_shape() {
        let s = Schema::criteo_kaggle();
        assert_eq!(s.dense_count(), 13);
        assert_eq!(s.sparse_count(), 26);
        assert_eq!(s.fields.len(), 40);
        // 4 (label) + 13*4 + 26*8 = 264 bytes/row raw.
        assert_eq!(s.raw_row_bytes(), 4 + 52 + 208);
    }

    #[test]
    fn wide_shape() {
        let s = Schema::synthetic_wide();
        assert_eq!(s.dense_count(), 504);
        assert_eq!(s.sparse_count(), 42);
    }

    #[test]
    fn field_lookup() {
        let s = Schema::criteo_kaggle();
        assert!(s.field("criteo_c0").is_some());
        assert!(s.field("nope").is_none());
        assert_eq!(s.field("criteo_c0").unwrap().kind, FeatureKind::Sparse);
    }
}
