//! Symbolic ETL DAG (paper Fig. 4/5): user pipelines are expressed as a
//! graph of operator nodes over schema fields, validated against the
//! schema, split into *fit* and *apply* phases, and then either executed
//! by the software reference executor here or compiled by `planner` into a
//! streaming vFPGA dataflow.

use std::collections::HashMap;
use std::rc::Rc;

use crate::error::{EtlError, Result};
use crate::etl::column::{Batch, ColType, Column};
use crate::etl::ops::vocab::{vocab_gen, VocabTable};
use crate::etl::ops::OpSpec;
use crate::etl::schema::Schema;

/// Node handle within a [`Dag`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

/// Role of a sink in the packed training batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SinkRole {
    /// Normalized dense feature (f32).
    Dense,
    /// Embedding index (i64 → packed as i32).
    SparseIndex,
    /// Training label.
    Label,
}

/// DAG node.
#[derive(Debug, Clone)]
pub enum Node {
    /// Reads a raw column from the input batch.
    Source { field: String, coltype: ColType },
    /// Applies an operator to upstream node outputs.
    Op {
        spec: OpSpec,
        inputs: Vec<NodeId>,
        /// Key identifying the vocabulary state shared between the fit
        /// (VocabGen) and apply (VocabMap) phases of a feature.
        vocab_key: Option<String>,
    },
    /// Declares a node output as a training-batch column.
    Sink { name: String, input: NodeId, role: SinkRole },
}

/// A validated-on-demand symbolic DAG over a schema.
#[derive(Debug, Clone, Default)]
pub struct Dag {
    pub name: String,
    pub nodes: Vec<Node>,
}

/// Fitted state: one vocabulary table per `vocab_key`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EtlState {
    pub vocabs: HashMap<String, VocabTable>,
}

impl EtlState {
    /// Total bytes of fitted state (drives planner placement).
    pub fn state_bytes(&self) -> usize {
        self.vocabs.values().map(|t| t.state_bytes()).sum()
    }
}

impl Dag {
    pub fn new(name: impl Into<String>) -> Dag {
        Dag { name: name.into(), nodes: Vec::new() }
    }

    pub fn source(&mut self, field: impl Into<String>, coltype: ColType) -> NodeId {
        self.nodes.push(Node::Source { field: field.into(), coltype });
        NodeId(self.nodes.len() - 1)
    }

    pub fn op(&mut self, spec: OpSpec, inputs: &[NodeId]) -> NodeId {
        self.nodes.push(Node::Op { spec, inputs: inputs.to_vec(), vocab_key: None });
        NodeId(self.nodes.len() - 1)
    }

    pub fn vocab_op(&mut self, spec: OpSpec, input: NodeId, key: impl Into<String>) -> NodeId {
        self.nodes.push(Node::Op {
            spec,
            inputs: vec![input],
            vocab_key: Some(key.into()),
        });
        NodeId(self.nodes.len() - 1)
    }

    pub fn sink(&mut self, name: impl Into<String>, input: NodeId, role: SinkRole) -> NodeId {
        self.nodes.push(Node::Sink { name: name.into(), input, role });
        NodeId(self.nodes.len() - 1)
    }

    pub fn sinks(&self) -> impl Iterator<Item = (&str, NodeId, SinkRole)> {
        self.nodes.iter().filter_map(|n| match n {
            Node::Sink { name, input, role } => Some((name.as_str(), *input, *role)),
            _ => None,
        })
    }

    pub fn ops(&self) -> impl Iterator<Item = (NodeId, &OpSpec)> {
        self.nodes.iter().enumerate().filter_map(|(i, n)| match n {
            Node::Op { spec, .. } => Some((NodeId(i), spec)),
            _ => None,
        })
    }

    /// Number of stateful operators.
    pub fn stateful_count(&self) -> usize {
        self.ops().filter(|(_, s)| s.is_stateful()).count()
    }

    /// Validate the DAG against a schema: references in range and forward-
    /// only (acyclic by construction), sources exist in the schema with
    /// matching types, operator arities and types line up, every VocabMap
    /// has a matching VocabGen on the same key, and at least one sink.
    pub fn validate(&self, schema: &Schema) -> Result<()> {
        let mut out_types: Vec<Option<ColType>> = vec![None; self.nodes.len()];
        let mut gen_keys: Vec<String> = Vec::new();
        let mut sink_count = 0usize;

        for (i, node) in self.nodes.iter().enumerate() {
            match node {
                Node::Source { field, coltype } => {
                    let spec = schema.field(field).ok_or_else(|| {
                        EtlError::Dag(format!("source field {field:?} not in schema"))
                    })?;
                    if spec.raw_type != *coltype {
                        return Err(EtlError::Dag(format!(
                            "source {field:?}: schema type {} != declared {}",
                            spec.raw_type, coltype
                        )));
                    }
                    out_types[i] = Some(*coltype);
                }
                Node::Op { spec, inputs, vocab_key } => {
                    if inputs.len() != spec.arity() {
                        return Err(EtlError::Dag(format!(
                            "{} expects {} inputs, got {}",
                            spec.name(),
                            spec.arity(),
                            inputs.len()
                        )));
                    }
                    let mut in_ty = None;
                    for &NodeId(j) in inputs {
                        if j >= i {
                            return Err(EtlError::Dag(format!(
                                "node {i} references forward node {j} (cycle)"
                            )));
                        }
                        let ty = out_types[j].ok_or_else(|| {
                            EtlError::Dag(format!("node {i} consumes a sink node {j}"))
                        })?;
                        if !spec.input_type().contains(&ty) {
                            return Err(EtlError::Dag(format!(
                                "{} cannot consume {} (node {j})",
                                spec.name(),
                                ty
                            )));
                        }
                        in_ty = Some(ty);
                    }
                    match spec {
                        OpSpec::VocabGen { .. } => {
                            let key = vocab_key.clone().ok_or_else(|| {
                                EtlError::Dag("VocabGen requires a vocab key".into())
                            })?;
                            if gen_keys.contains(&key) {
                                return Err(EtlError::Dag(format!(
                                    "duplicate VocabGen key {key:?}"
                                )));
                            }
                            gen_keys.push(key);
                        }
                        OpSpec::VocabMap { .. } => {
                            let key = vocab_key.as_ref().ok_or_else(|| {
                                EtlError::Dag("VocabMap requires a vocab key".into())
                            })?;
                            if !gen_keys.contains(key) {
                                return Err(EtlError::Dag(format!(
                                    "VocabMap key {key:?} has no matching VocabGen"
                                )));
                            }
                        }
                        _ => {}
                    }
                    out_types[i] = Some(spec.output_type(in_ty.expect("arity >= 1")));
                }
                Node::Sink { input: NodeId(j), role, name } => {
                    if *j >= i {
                        return Err(EtlError::Dag(format!("sink {name:?} references forward node")));
                    }
                    let ty = out_types[*j].ok_or_else(|| {
                        EtlError::Dag(format!("sink {name:?} consumes another sink"))
                    })?;
                    let ok = match role {
                        SinkRole::Dense | SinkRole::Label => ty == ColType::F32,
                        SinkRole::SparseIndex => ty == ColType::I64,
                    };
                    if !ok {
                        return Err(EtlError::Dag(format!(
                            "sink {name:?} role {role:?} incompatible with type {ty}"
                        )));
                    }
                    sink_count += 1;
                }
            }
        }
        if sink_count == 0 {
            return Err(EtlError::Dag("DAG has no sinks".into()));
        }
        Ok(())
    }

    /// **Fit phase**: run the DAG over a (sample of the) input and build all
    /// vocabulary tables. Only the subgraphs feeding VocabGen nodes are
    /// evaluated.
    pub fn fit(&self, input: &Batch) -> Result<EtlState> {
        let mut state = EtlState::default();
        let mut cache: Vec<Option<Rc<Column>>> = vec![None; self.nodes.len()];
        for (i, node) in self.nodes.iter().enumerate() {
            if let Node::Op { spec: OpSpec::VocabGen { expected }, inputs, vocab_key } = node {
                let NodeId(j) = inputs[0];
                let col = self.eval_node(j, input, &mut cache, &state)?;
                let key = vocab_key.clone().expect("validated");
                let table = vocab_gen(col.as_i64()?, *expected);
                state.vocabs.insert(key, table);
                let _ = i;
            }
        }
        Ok(state)
    }

    /// **Apply phase**: transform a batch using frozen state, producing the
    /// training-ready output batch (sink columns, in declaration order).
    ///
    /// Columns are shared through an `Rc` memo so linear chains move data
    /// instead of cloning it (§Perf: the clone-per-node executor was the
    /// top ETL hot-spot at ~40 columns × 3 ops each).
    pub fn apply(&self, input: &Batch, state: &EtlState) -> Result<Batch> {
        let mut cache: Vec<Option<Rc<Column>>> = vec![None; self.nodes.len()];
        let mut out = Batch::new();
        for node in &self.nodes {
            if let Node::Sink { name, input: NodeId(j), .. } = node {
                let rc = self.eval_node(*j, input, &mut cache, state)?;
                // Release our memo reference so a single-consumer column
                // is moved (not deep-cloned) into the output batch.
                cache[*j] = None;
                let col = Rc::try_unwrap(rc).unwrap_or_else(|rc| (*rc).clone());
                out.push(name.clone(), col)?;
            }
        }
        Ok(out)
    }

    fn eval_node(
        &self,
        i: usize,
        batch: &Batch,
        cache: &mut Vec<Option<Rc<Column>>>,
        state: &EtlState,
    ) -> Result<Rc<Column>> {
        if let Some(col) = &cache[i] {
            return Ok(Rc::clone(col));
        }
        let col = match &self.nodes[i] {
            Node::Source { field, .. } => batch
                .get(field)
                .cloned()
                .ok_or_else(|| EtlError::Dag(format!("input batch missing column {field:?}")))?,
            Node::Op { spec, inputs, vocab_key } => {
                let mut cols = Vec::with_capacity(inputs.len());
                for &NodeId(j) in inputs {
                    cols.push(self.eval_node(j, batch, cache, state)?);
                    // Operator inputs are consumed; drop the memo slot so
                    // intermediate buffers free as the chain advances.
                    cache[j] = None;
                }
                // Fast path: unary elementwise op on an exclusively-owned
                // column mutates in place (no alloc, single pass).
                if cols.len() == 1 && spec.arity() == 1 && !spec.is_stateful() {
                    if let Ok(mut owned) = Rc::try_unwrap(cols.pop().expect("one input")) {
                        if spec.apply_inplace(&mut owned) {
                            let rc = Rc::new(owned);
                            cache[i] = Some(Rc::clone(&rc));
                            return Ok(rc);
                        }
                        // No in-place form: fall through with the owned col.
                        cols.push(Rc::new(owned));
                    }
                }
                let refs: Vec<&Column> = cols.iter().map(|rc| rc.as_ref()).collect();
                let vocab = vocab_key.as_ref().and_then(|k| state.vocabs.get(k));
                match spec {
                    // In the apply phase VocabGen acts as the already-fitted
                    // mapping (fit/apply split, §3.1): replay through the
                    // frozen table.
                    OpSpec::VocabGen { .. } => {
                        let key = vocab_key.as_ref().expect("validated");
                        let table = state.vocabs.get(key).ok_or_else(|| {
                            EtlError::Vocab(format!("vocab {key:?} not fitted"))
                        })?;
                        let data = refs[0].as_i64()?;
                        Column::i64(crate::etl::ops::vocab::vocab_map_oov(
                            data,
                            table,
                            table.len() as i64,
                        ))
                    }
                    _ => spec.apply(&refs, vocab)?,
                }
            }
            Node::Sink { input: NodeId(j), .. } => {
                let rc = self.eval_node(*j, batch, cache, state)?;
                cache[i] = Some(Rc::clone(&rc));
                return Ok(rc);
            }
        };
        let rc = Rc::new(col);
        cache[i] = Some(Rc::clone(&rc));
        Ok(rc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::etl::column::pack_hex;

    fn tiny_schema() -> Schema {
        Schema::tabular("t", 1, 1, 100)
    }

    fn tiny_batch() -> Batch {
        let mut b = Batch::new();
        b.push("t_label", Column::f32(vec![1.0, 0.0, 1.0])).unwrap();
        b.push("t_i0", Column::f32(vec![-2.0, f32::NAN, 999.0])).unwrap();
        b.push(
            "t_c0",
            Column::hex8(vec![
                pack_hex("1a3f").unwrap(),
                pack_hex("00ff").unwrap(),
                pack_hex("1a3f").unwrap(),
            ]),
        )
        .unwrap();
        b
    }

    fn build_dag() -> Dag {
        let mut d = Dag::new("test");
        let label = d.source("t_label", ColType::F32);
        d.sink("label", label, SinkRole::Label);
        let dense = d.source("t_i0", ColType::F32);
        let fm = d.op(OpSpec::FillMissing { dense_default: 0.0, sparse_default: 0 }, &[dense]);
        let cl = d.op(OpSpec::Clamp { lo: 0.0, hi: f32::MAX }, &[fm]);
        let lg = d.op(OpSpec::Logarithm, &[cl]);
        d.sink("dense0", lg, SinkRole::Dense);
        let sparse = d.source("t_c0", ColType::Hex8);
        let h = d.op(OpSpec::Hex2Int, &[sparse]);
        let m = d.op(OpSpec::Modulus { m: 1000 }, &[h]);
        let g = d.vocab_op(OpSpec::VocabGen { expected: 16 }, m, "c0");
        d.sink("sparse0", g, SinkRole::SparseIndex);
        d
    }

    #[test]
    fn validates_ok() {
        build_dag().validate(&tiny_schema()).unwrap();
    }

    #[test]
    fn fit_then_apply_produces_training_batch() {
        let dag = build_dag();
        let batch = tiny_batch();
        let state = dag.fit(&batch).unwrap();
        assert_eq!(state.vocabs["c0"].len(), 2);
        let out = dag.apply(&batch, &state).unwrap();
        assert_eq!(out.rows(), 3);
        // dense0 = log(clamp(fill(x)) + 1)
        let dense = out.get("dense0").unwrap().as_f32().unwrap();
        assert_eq!(dense[0], 0.0); // -2 -> clamp 0 -> log1p 0
        assert_eq!(dense[1], 0.0); // NaN -> 0
        assert!((dense[2] - 1000f32.ln()).abs() < 1e-5);
        // sparse0 = vocab indices in first-appearance order
        let sparse = out.get("sparse0").unwrap().as_i64().unwrap();
        assert_eq!(sparse, &[0, 1, 0]);
    }

    #[test]
    fn rejects_unknown_source() {
        let mut d = Dag::new("bad");
        let s = d.source("nope", ColType::F32);
        d.sink("x", s, SinkRole::Dense);
        assert!(d.validate(&tiny_schema()).is_err());
    }

    #[test]
    fn rejects_type_mismatch() {
        let mut d = Dag::new("bad");
        let s = d.source("t_c0", ColType::Hex8);
        // Clamp cannot consume hex
        let c = d.op(OpSpec::Clamp { lo: 0.0, hi: 1.0 }, &[s]);
        d.sink("x", c, SinkRole::Dense);
        assert!(d.validate(&tiny_schema()).is_err());
    }

    #[test]
    fn rejects_vocabmap_without_gen() {
        let mut d = Dag::new("bad");
        let s = d.source("t_c0", ColType::Hex8);
        let h = d.op(OpSpec::Hex2Int, &[s]);
        let m = d.vocab_op(OpSpec::VocabMap { oov: None }, h, "orphan");
        d.sink("x", m, SinkRole::SparseIndex);
        assert!(d.validate(&tiny_schema()).is_err());
    }

    #[test]
    fn rejects_empty_dag() {
        let d = Dag::new("empty");
        assert!(d.validate(&tiny_schema()).is_err());
    }

    #[test]
    fn rejects_sink_type_mismatch() {
        let mut d = Dag::new("bad");
        let s = d.source("t_i0", ColType::F32);
        d.sink("x", s, SinkRole::SparseIndex); // f32 into sparse sink
        assert!(d.validate(&tiny_schema()).is_err());
    }
}
