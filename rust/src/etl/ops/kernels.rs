//! Scalar/streaming functional kernels for every operator in the paper's
//! operator pool (Table 1). These are the single source of truth for
//! operator semantics: the FPGA dataflow simulator, the CPU baseline and
//! the property tests all call into this module, so platform comparisons
//! are bit-identical by construction.

/// Clamp: restrict values to `[lo, hi]` (§3.2.1; paper's production config
/// clips negatives to zero with `lo = 0`).
#[inline]
pub fn clamp(x: f32, lo: f32, hi: f32) -> f32 {
    // NaNs pass through (handled by FillMissing upstream).
    if x < lo {
        lo
    } else if x > hi {
        hi
    } else {
        x
    }
}

/// Logarithm: `log(x + 1)` — reduces skew and compresses heavy tails.
#[inline]
pub fn logarithm(x: f32) -> f32 {
    (x + 1.0).ln()
}

/// FillMissing (dense): replace NaN with a default.
#[inline]
pub fn fill_missing_f32(x: f32, default: f32) -> f32 {
    if x.is_nan() {
        default
    } else {
        x
    }
}

/// FillMissing (sparse): replace the missing sentinel with a default token.
pub const MISSING_I64: i64 = i64::MIN;

#[inline]
pub fn fill_missing_i64(x: i64, default: i64) -> i64 {
    if x == MISSING_I64 {
        default
    } else {
        x
    }
}

/// Hex2Int: parse 8 packed ASCII hex chars (big-endian `u64`) into an
/// integer. Mirrors the FPGA implementation: translate each ASCII code to
/// its nibble and concatenate (II = 1).
///
/// Branchless SWAR (§Perf): for valid hex ASCII, `nibble = (b & 0x0F) +
/// 9·bit6(b)` — digits have bit 6 clear, letters (upper or lower) have it
/// set and their low nibble is 1–6. All eight bytes are decoded in
/// parallel inside the u64, then the nibbles are horizontally packed.
/// Malformed bytes decode to an unspecified nibble (the scalar reference
/// used by the validator decodes them as 0; generators only emit valid
/// hex — see `hex2int_checked` for the validating path).
#[inline]
pub fn hex2int(packed: u64) -> i64 {
    const LOW: u64 = 0x0F0F_0F0F_0F0F_0F0F;
    const ONE: u64 = 0x0101_0101_0101_0101;
    // Per-byte nibble value, one per byte lane. Byte lane i (LSB = least
    // significant hex digit) holds nibble n_i.
    let n = (packed & LOW) + 9 * ((packed >> 6) & ONE);
    // Horizontal pack: n_i·16^i via three fold steps.
    let x = (n | (n >> 4)) & 0x00FF_00FF_00FF_00FF;
    let x = (x | (x >> 8)) & 0x0000_FFFF_0000_FFFF;
    let x = (x | (x >> 16)) & 0x0000_0000_FFFF_FFFF;
    x as i64
}

/// Validating Hex2Int: returns `None` for non-hex bytes (ingest
/// validation path; the hot loop uses the branchless `hex2int`).
#[inline]
pub fn hex2int_checked(packed: u64) -> Option<i64> {
    for b in packed.to_be_bytes() {
        if !b.is_ascii_hexdigit() {
            return None;
        }
    }
    Some(hex2int(packed))
}

/// Modulus: positive modulus mapping IDs into `[0, m)` (e.g. (-7) mod 5 = 3).
#[inline]
pub fn modulus(x: i64, m: i64) -> i64 {
    debug_assert!(m > 0);
    x.rem_euclid(m)
}

/// SigridHash: bound categorical IDs via a 64-bit mix then positive mod.
/// (Named after Meta's torcharrow `sigrid_hash`.)
#[inline]
pub fn sigrid_hash(x: i64, m: i64) -> i64 {
    modulus(mix64(x as u64) as i64, m)
}

/// Cartesian: cross two categorical keys into a new key distinct from the
/// originals — `hash(a, b) mod m` (§2.2).
#[inline]
pub fn cartesian(a: i64, b: i64, m: i64) -> i64 {
    let h = mix64((a as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ mix64(b as u64));
    modulus(h as i64, m)
}

/// OneHot: encode `bin ∈ [0, k)` as an indicator row of width `k`.
/// Out-of-range bins produce an all-zero row (matching tf.one_hot).
#[inline]
pub fn one_hot_into(bin: i64, k: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), k);
    out.fill(0.0);
    if bin >= 0 && (bin as usize) < k {
        out[bin as usize] = 1.0;
    }
}

/// Bucketize: discretize a scalar by ascending bin borders; returns the
/// number of borders strictly below-or-equal, i.e. `x=37, borders=[10,20,40]
/// → bin 2` counting from 0 (the paper's example counts from 1).
#[inline]
pub fn bucketize(x: f32, borders: &[f32]) -> i64 {
    // Branchless-ish binary search over ascending borders.
    let mut lo = 0usize;
    let mut hi = borders.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if x >= borders[mid] {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo as i64
}

/// SplitMix64 finalizer — the hash core shared by SigridHash/Cartesian and
/// the vocabulary table.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::etl::column::pack_hex;

    #[test]
    fn clamp_paper_example() {
        // x=-1, [0,10] → 0
        assert_eq!(clamp(-1.0, 0.0, 10.0), 0.0);
        assert_eq!(clamp(5.0, 0.0, 10.0), 5.0);
        assert_eq!(clamp(11.0, 0.0, 10.0), 10.0);
    }

    #[test]
    fn logarithm_paper_example() {
        // x=999 → log(999+1)
        assert!((logarithm(999.0) - 1000f32.ln()).abs() < 1e-6);
        assert_eq!(logarithm(0.0), 0.0);
    }

    #[test]
    fn hex2int_paper_example() {
        // "0x1a3f" → 6719
        assert_eq!(hex2int(pack_hex("1a3f").unwrap()), 6719);
        assert_eq!(hex2int(pack_hex("00000000").unwrap()), 0);
        assert_eq!(hex2int(pack_hex("ffffffff").unwrap()), 0xffff_ffff);
        // Full 8 chars, upper case.
        assert_eq!(hex2int(pack_hex("DEADBEEF").unwrap()), 0xDEAD_BEEFu32 as i64);
    }

    #[test]
    fn modulus_paper_example() {
        // (-7) mod 5 → 3
        assert_eq!(modulus(-7, 5), 3);
        assert_eq!(modulus(7, 5), 2);
        assert_eq!(modulus(0, 5), 0);
    }

    #[test]
    fn one_hot_paper_example() {
        // bin=3, K=5 → [0,0,0,1,0]
        let mut out = [0f32; 5];
        one_hot_into(3, 5, &mut out);
        assert_eq!(out, [0.0, 0.0, 0.0, 1.0, 0.0]);
        one_hot_into(9, 5, &mut out);
        assert_eq!(out, [0.0; 5]);
        one_hot_into(-1, 5, &mut out);
        assert_eq!(out, [0.0; 5]);
    }

    #[test]
    fn bucketize_matches_linear_scan() {
        let borders = [10.0, 20.0, 40.0];
        for (x, want) in [(5.0, 0), (10.0, 1), (15.0, 1), (37.0, 2), (40.0, 3), (99.0, 3)] {
            assert_eq!(bucketize(x, &borders), want, "x={x}");
        }
    }

    #[test]
    fn fill_missing_handles_nan_and_sentinel() {
        assert_eq!(fill_missing_f32(f32::NAN, 0.5), 0.5);
        assert_eq!(fill_missing_f32(3.2, 0.0), 3.2);
        assert_eq!(fill_missing_i64(MISSING_I64, 7), 7);
        assert_eq!(fill_missing_i64(42, 7), 42);
    }

    #[test]
    fn sigrid_hash_bounded_and_stable() {
        for x in [-100i64, 0, 1, 1 << 40] {
            let h = sigrid_hash(x, 1000);
            assert!((0..1000).contains(&h));
            assert_eq!(h, sigrid_hash(x, 1000), "deterministic");
        }
    }

    #[test]
    fn cartesian_distinct_from_inputs() {
        let m = 1 << 20;
        let c1 = cartesian(42, 17, m);
        let c2 = cartesian(17, 42, m);
        assert!((0..m).contains(&c1));
        // Order matters for a cross feature.
        assert_ne!(c1, c2);
    }
}
